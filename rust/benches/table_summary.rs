//! Regenerates **Tables 3 & 4**: per-dataset efficiency scores for every
//! algorithm, and the cross-dataset sum-score summary.
//!
//! Paper protocol: every algorithm × every dataset × k ∈ {2,…,25} ×
//! n_exec runs; score S(A,X,q) per metric; sum over datasets.
//!
//! Scaled defaults keep the full run to a few minutes; set
//! `BENCH_DATASETS=all BENCH_NEXEC=3` for the complete 23-dataset sweep.
//!
//! ```bash
//! cargo bench --bench table_summary
//! ```

use bigmeans::bench_harness::report::{render_table4_markdown, write_report};
use bigmeans::bench_harness::{dataset_scores, paper_roster, run_experiment, table4};
use bigmeans::data::catalog;

fn main() {
    let n_exec: usize = std::env::var("BENCH_NEXEC")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let which = std::env::var("BENCH_DATASETS").unwrap_or_else(|_| "quick".into());
    let k_grid: Vec<usize> = if which == "all" {
        catalog::PAPER_K_GRID.to_vec()
    } else {
        vec![2, 5, 15, 25]
    };
    let entries = if which == "all" {
        catalog::catalog()
    } else {
        catalog::quick_subset()
    };

    println!("# Tables 3–4 regeneration ({} datasets, k grid {:?}, n_exec {})", entries.len(), k_grid, n_exec);
    let mut all_scores = Vec::new();
    let mut t3_lines = vec![
        "| Dataset | S(Big-Means, accuracy) | S(Big-Means, cpu) |".to_string(),
        "|---|---|---|".to_string(),
    ];
    let t0 = std::time::Instant::now();
    for entry in &entries {
        let data = entry.generate(20220418);
        let roster = paper_roster(entry);
        let exp = run_experiment(&data, &roster, &k_grid, n_exec, 42);
        let scores = dataset_scores(&exp);
        let bm = scores
            .iter()
            .find(|(n, _, _)| *n == "Big-Means")
            .expect("Big-Means in roster");
        println!(
            "[{:>5.1}s] {:<50} S_acc={:.3} S_cpu={:.3}",
            t0.elapsed().as_secs_f64(),
            entry.name,
            bm.1,
            bm.2
        );
        t3_lines.push(format!("| {} | {:.3} | {:.3} |", entry.name, bm.1, bm.2));
        all_scores.push(scores);
    }

    let t4 = table4(&all_scores);
    let md_t4 = render_table4_markdown(&t4, entries.len());
    println!("\n{md_t4}");
    let md_t3 = format!("## Table 3 — Big-Means scores per dataset\n{}\n", t3_lines.join("\n"));
    let path = write_report("table_3_4_summary.md", &format!("{md_t3}\n{md_t4}"));
    println!("report: {}", path.display());

    // Shape assertions (the paper's qualitative claims).
    let find = |name: &str| t4.iter().find(|r| r.algorithm == name).unwrap();
    let bm = find("Big-Means");
    println!(
        "\nshape check: Big-Means mean score {:.0}% (paper: 97%)",
        bm.mean_pct
    );
    for other in ["Forgy K-Means", "Ward's", "K-Means||", "LMBM-Clust"] {
        let o = find(other);
        println!(
            "  vs {:<16} mean {:.0}% → Big-Means {} ",
            other,
            o.mean_pct,
            if bm.mean_pct >= o.mean_pct { "wins/ties ✓" } else { "LOSES ✗" }
        );
    }
}
