//! Ablations for the design choices DESIGN.md calls out:
//!
//! * **A1 — chunk size s** (paper §4.1): the central trade-off. Too small →
//!   noisy approximation of the data shape; too large → no shaking, slower
//!   chunks. Sweeps s and reports final SSE + chunks processed.
//! * **A2 — DA-MSSC (q, s) grid** (paper §5.4): fixing q and growing s
//!   improves quality at cpu cost; growing q at fixed s burns cpu without
//!   quality gains.
//! * **A3 — degenerate-reinit strategy**: K-means++ vs uniform random.
//! * **A4 — keep-the-best on chunk objective** vs re-evaluating the
//!   incumbent on each fresh chunk (pairwise comparison variant).
//!
//! ```bash
//! cargo bench --bench ablation_chunk_size
//! ```

use std::time::Duration;

use bigmeans::baselines::{DaMssc, MsscAlgorithm};
use bigmeans::coordinator::config::{BigMeansConfig, ParallelMode, ReinitStrategy, StopCondition};
use bigmeans::data::Synth;
use bigmeans::BigMeans;

fn main() {
    let data = Synth::GaussianMixture {
        m: 200_000,
        n: 8,
        k_true: 12,
        spread: 0.6,
        box_half_width: 25.0,
    }
    .generate("ablation", 20220418);
    let k = 12;
    let budget = Duration::from_millis(1200);

    // --- A1: chunk size sweep ---
    println!("### A1 — chunk size trade-off (m=200k, k={k}, budget {budget:?})");
    println!("{:>8} {:>14} {:>9} {:>12} {:>9}", "s", "SSE", "chunks", "n_d", "improves");
    for &s in &[500usize, 1000, 2000, 4000, 8000, 16000, 32000, 64000] {
        let cfg = BigMeansConfig::new(k, s)
            .with_stop(StopCondition::MaxTime(budget))
            .with_parallel(ParallelMode::InnerParallel)
            .with_seed(7);
        let r = BigMeans::new(cfg).run(&data).expect("run");
        println!(
            "{:>8} {:>14.6e} {:>9} {:>12.3e} {:>9}",
            s,
            r.objective,
            r.counters.chunks,
            r.counters.distance_evals as f64,
            r.improvements
        );
    }
    println!("expected shape: SSE best at moderate s; extremes worse (paper §4.1).");

    // --- A2: DA-MSSC (q, s) grid ---
    println!("\n### A2 — DA-MSSC decompose/aggregate grid");
    println!("{:>8} {:>6} {:>14} {:>9}", "s", "q", "SSE", "cpu s");
    for &s in &[1000usize, 4000, 16000] {
        for &q in &[4usize, 10, 25] {
            let r = DaMssc::new(s, q).run(&data, k, 7).expect("da-mssc");
            println!("{:>8} {:>6} {:>14.6e} {:>9.3}", s, q, r.objective, r.cpu_total_secs());
        }
    }
    println!("expected shape: growing s helps quality; growing q mostly burns cpu (§5.4).");

    // --- A3: reinit strategy ---
    println!("\n### A3 — degenerate reinit: K-means++ vs random (5 seeds each)");
    for strategy in [ReinitStrategy::KmeansPP, ReinitStrategy::Random] {
        let mut sum = 0.0;
        for seed in 0..5u64 {
            let mut cfg = BigMeansConfig::new(k, 4000)
                .with_stop(StopCondition::MaxChunks(40))
                .with_parallel(ParallelMode::InnerParallel)
                .with_seed(seed);
            cfg.reinit = strategy;
            sum += BigMeans::new(cfg).run(&data).expect("run").objective;
        }
        println!("  {:?}: mean SSE {:.6e}", strategy, sum / 5.0);
    }

    // --- A4: candidates-per-draw in the greedy K-means++ (paper uses 3) ---
    println!("\n### A4 — K-means++ candidate count (paper §5.7 uses 3)");
    for candidates in [1usize, 3, 5] {
        let mut cfg = BigMeansConfig::new(k, 4000)
            .with_stop(StopCondition::MaxChunks(40))
            .with_parallel(ParallelMode::InnerParallel)
            .with_seed(3);
        cfg.candidates = candidates;
        let r = BigMeans::new(cfg).run(&data).expect("run");
        println!(
            "  candidates={candidates}: SSE {:.6e}, n_d {:.3e}",
            r.objective, r.counters.distance_evals as f64
        );
    }
}
