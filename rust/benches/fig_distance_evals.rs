//! Regenerates the **Figures 1–4 left panels**: number of distance-function
//! evaluations (`n_d`) vs k per algorithm, per dataset — the paper's
//! headline visual ("our algorithm performs significantly less distance
//! function evaluations than other algorithms on the largest datasets").
//!
//! Ward's/LMBM series exist but are orders of magnitude above the rest,
//! matching the paper's note that they were left off the plots.
//!
//! ```bash
//! cargo bench --bench fig_distance_evals
//! ```

use bigmeans::bench_harness::figures::{distance_evals_series, render_ascii};
use bigmeans::bench_harness::report::{series_csv, write_report};
use bigmeans::bench_harness::{paper_roster, run_experiment};
use bigmeans::data::catalog;

fn main() {
    let n_exec: usize = std::env::var("BENCH_NEXEC")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let which = std::env::var("BENCH_DATASETS").unwrap_or_else(|_| "quick".into());
    let entries = if which == "all" {
        catalog::catalog()
    } else {
        catalog::quick_subset()
    };
    let k_grid = [2usize, 5, 10, 15, 25];

    for entry in &entries {
        let data = entry.generate(20220418);
        let roster = paper_roster(entry);
        let exp = run_experiment(&data, &roster, &k_grid, n_exec, 42);
        let series = distance_evals_series(&exp);
        println!("\n{}", render_ascii(&series, &format!("n_d vs k — {}", entry.name), true));
        let csv = series_csv(&series, "distance_evals");
        let path = write_report(&format!("fig_nd_{}.csv", entry.table), &csv);
        println!("csv: {}", path.display());

        // Shape check: Big-means does fewer evals than the K-means-family
        // baselines at the largest k.
        let last = k_grid.len() - 1;
        let get = |name: &str| -> Option<f64> {
            series
                .iter()
                .find(|s| s.algorithm == name)
                .and_then(|s| s.values[last])
        };
        if let (Some(bm), Some(pp)) = (get("Big-Means"), get("K-Means++")) {
            println!(
                "  k={}: Big-Means n_d={bm:.2e}, K-Means++ n_d={pp:.2e} → {}",
                k_grid[last],
                if bm < pp { "fewer ✓" } else { "NOT fewer ✗" }
            );
        }
        if let (Some(bm), Some(w)) = (get("Big-Means"), get("Ward's")) {
            println!(
                "  k={}: Ward's n_d / Big-Means n_d = {:.1}× (orders above, off-plot in paper)",
                k_grid[last],
                w / bm
            );
        }
    }
}
