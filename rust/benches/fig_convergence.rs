//! Regenerates the **Figures 1–4 right panels** (mean objective vs k per
//! algorithm) plus the §4.1 convergence analysis: Big-means' incumbent
//! objective vs wall-clock under the two parallelisation strategies —
//! sequential chunks with parallel kernels (strategy 1) vs parallel chunks
//! (strategy 2).
//!
//! ```bash
//! cargo bench --bench fig_convergence
//! ```

use std::time::Duration;

use bigmeans::bench_harness::figures::{objective_series, render_ascii, ConvergenceTrace};
use bigmeans::bench_harness::report::{series_csv, write_report};
use bigmeans::bench_harness::{paper_roster, run_experiment};
use bigmeans::coordinator::config::{BigMeansConfig, ParallelMode, StopCondition};
use bigmeans::data::catalog;
use bigmeans::BigMeans;

fn main() {
    let n_exec: usize = std::env::var("BENCH_NEXEC")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let entries = catalog::quick_subset();
    let k_grid = [2usize, 5, 10, 15, 25];

    // Right panels: objective vs k.
    for entry in &entries {
        let data = entry.generate(20220418);
        let roster = paper_roster(entry);
        let exp = run_experiment(&data, &roster, &k_grid, n_exec, 42);
        let series = objective_series(&exp);
        println!("\n{}", render_ascii(&series, &format!("objective vs k — {}", entry.name), true));
        let csv = series_csv(&series, "objective");
        write_report(&format!("fig_obj_{}.csv", entry.table), &csv);
    }

    // Convergence traces: incumbent objective over time, both strategies.
    println!("\n### Big-means convergence (incumbent chunk objective vs time)");
    let entry = catalog::find("HEPMASS").unwrap();
    let data = entry.generate(20220418);
    let k = 15;
    for (label, mode) in [
        ("strategy1-inner-parallel", ParallelMode::InnerParallel),
        ("strategy2-chunk-parallel", ParallelMode::ChunkParallel),
        ("sequential", ParallelMode::Sequential),
    ] {
        // Sample the trace by running with increasing chunk budgets (the
        // incumbent is monotone, so the envelope reconstructs the trace).
        let mut trace = ConvergenceTrace::default();
        for &chunks in &[1u64, 2, 4, 8, 16, 32, 64] {
            let cfg = BigMeansConfig::new(k, entry.chunk_size)
                .with_stop(StopCondition::TimeOrChunks(Duration::from_secs(5), chunks))
                .with_parallel(mode)
                .with_seed(7);
            let mut cfg = cfg;
            cfg.skip_final_assignment = true;
            let t0 = std::time::Instant::now();
            let r = BigMeans::new(cfg).run(&data).expect("run");
            trace.record(t0.elapsed().as_secs_f64(), r.best_chunk_objective);
        }
        let monotone_in_chunks = trace
            .samples
            .windows(2)
            .all(|w| w[1].1 <= w[0].1 * 1.0001);
        println!("  {label:<26} {:?}", trace
            .samples
            .iter()
            .map(|(t, o)| format!("{:.2}s:{:.3e}", t, o))
            .collect::<Vec<_>>());
        println!(
            "    monotone improvement with chunk budget: {}",
            if monotone_in_chunks { "✓" } else { "✗ (stochastic crossing)" }
        );
        let csv: String = std::iter::once("elapsed_s,objective\n".to_string())
            .chain(trace.samples.iter().map(|(t, o)| format!("{t},{o}\n")))
            .collect();
        write_report(&format!("fig_convergence_{label}.csv"), &csv);
    }
}
