//! Regenerates a **per-dataset pair of tables** (summary Tables 5,7,9,…
//! and clustering-details Tables 6,8,10,…) in the paper's row format.
//!
//! ```bash
//! DATASET="Skin Segmentation" cargo bench --bench table_per_dataset
//! DATASET=all BENCH_NEXEC=3 cargo bench --bench table_per_dataset   # all 23
//! ```

use bigmeans::bench_harness::report::{
    render_details_markdown, render_summary_markdown, write_report,
};
use bigmeans::bench_harness::{details_table, paper_roster, run_experiment, summary_table};
use bigmeans::data::catalog::{self, CatalogEntry};

fn run_one(entry: &CatalogEntry, k_grid: &[usize], n_exec: usize) {
    let data = entry.generate(20220418);
    println!(
        "\n=== {} (paper Tables {}–{}) m={}, n={}, s={} ===",
        entry.name,
        entry.table,
        entry.table + 1,
        data.m(),
        data.n(),
        entry.chunk_size
    );
    let roster = paper_roster(entry);
    let exp = run_experiment(&data, &roster, k_grid, n_exec, 42);
    let summary = summary_table(&exp);
    let details = details_table(&exp);
    let md = format!(
        "{}\n{}",
        render_summary_markdown(&summary),
        render_details_markdown(&exp.dataset, &details)
    );
    println!("{md}");
    let path = write_report(&format!("table_{}_{}.md", entry.table, entry.table + 1), &md);
    println!("report: {}", path.display());
}

fn main() {
    let n_exec: usize = std::env::var("BENCH_NEXEC")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let which = std::env::var("DATASET").unwrap_or_else(|_| "Skin Segmentation".into());
    let k_grid: Vec<usize> = std::env::var("BENCH_KGRID")
        .ok()
        .map(|v| v.split(',').filter_map(|t| t.trim().parse().ok()).collect())
        .unwrap_or_else(|| vec![2, 5, 15, 25]);

    if which == "all" {
        for entry in catalog::catalog() {
            run_one(&entry, &k_grid, n_exec);
        }
    } else {
        let entry = catalog::find(&which).unwrap_or_else(|| {
            eprintln!("unknown dataset '{which}'");
            std::process::exit(2);
        });
        run_one(&entry, &k_grid, n_exec);
    }
}
