//! Hot-path micro-benchmarks (the §Perf deliverable):
//!
//! * assignment-step throughput, native serial vs native parallel vs PJRT
//!   (AOT HLO), in points/s and GFLOP/s against the 4·s·n·k roofline
//!   estimate;
//! * chunk-local Lloyd latency per engine;
//! * coordinator overhead: time per chunk *outside* the solver (sampling +
//!   incumbent bookkeeping) — DESIGN.md targets < 5%.
//!
//! ```bash
//! cargo bench --bench hot_path
//! ```

use std::time::{Duration, Instant};

use bigmeans::coordinator::config::{BigMeansConfig, ParallelMode, StopCondition};
use bigmeans::coordinator::solver::{ChunkSolver, NativeSolver};
use bigmeans::data::Synth;
use bigmeans::kernels;
use bigmeans::metrics::Counters;
use bigmeans::runtime::{default_artifacts_dir, PjrtSolver};
use bigmeans::util::threadpool::ThreadPool;
use bigmeans::BigMeans;

fn time_n<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    // Warmup + best-of-reps wall time.
    f();
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let (s, n, k) = (16384usize, 64usize, 32usize);
    let data = Synth::GaussianMixture {
        m: s,
        n,
        k_true: k,
        spread: 0.5,
        box_half_width: 20.0,
    }
    .generate("hot", 1);
    let pts = data.points();
    let mut c = Counters::new();
    let mut rng = bigmeans::util::rng::Rng::new(2);
    let cs = kernels::kmeanspp(pts, s, n, k, 1, &mut rng, &mut c);
    let flops = 4.0 * (s * n * k) as f64; // panel decomposition: 2 mul+add per (i,j,t)

    println!("### assignment-step throughput (s={s}, n={n}, k={k})");
    let mut report = |label: &str, secs: f64| {
        println!(
            "{:<26} {:>9.3} ms   {:>10.1} Mpts/s   {:>7.2} GFLOP/s",
            label,
            secs * 1e3,
            s as f64 / secs / 1e6,
            flops / secs / 1e9
        );
    };

    let serial = time_n(5, || {
        let mut c = Counters::new();
        std::hint::black_box(kernels::assign_accumulate(pts, &cs, s, n, k, &mut c));
    });
    report("native serial", serial);

    let pool = ThreadPool::with_default_size();
    let parallel = time_n(5, || {
        let mut c = Counters::new();
        std::hint::black_box(kernels::assign_accumulate_parallel(
            &pool, pts, &cs, s, n, k, &mut c,
        ));
    });
    report(&format!("native parallel ×{}", pool.size()), parallel);

    let artifacts = default_artifacts_dir();
    if artifacts.join("manifest.json").exists() {
        let solver = PjrtSolver::open(&artifacts, Default::default()).unwrap();
        let pjrt = time_n(5, || {
            let mut c = Counters::new();
            std::hint::black_box(solver.assign(pts, s, n, k, &cs, &mut c));
        });
        report("pjrt (AOT HLO)", pjrt);

        println!("\n### chunk Lloyd latency (to convergence)");
        let native = NativeSolver::sequential(Default::default());
        let lat_native = time_n(3, || {
            let mut c = Counters::new();
            std::hint::black_box(native.lloyd(pts, s, n, k, &cs, &mut c));
        });
        let lat_pjrt = time_n(3, || {
            let mut c = Counters::new();
            std::hint::black_box(solver.lloyd(pts, s, n, k, &cs, &mut c));
        });
        println!("  native : {:>9.3} ms", lat_native * 1e3);
        println!("  pjrt   : {:>9.3} ms", lat_pjrt * 1e3);
    } else {
        println!("(pjrt rows skipped — run `make artifacts`)");
    }

    // Coordinator overhead: total wall minus solver time, per chunk.
    println!("\n### coordinator overhead per chunk");
    let big = Synth::GaussianMixture {
        m: 400_000,
        n: 16,
        k_true: 8,
        spread: 0.5,
        box_half_width: 20.0,
    }
    .generate("coord", 3);
    let chunks = 40u64;
    let mut cfg = BigMeansConfig::new(8, 4096)
        .with_stop(StopCondition::TimeOrChunks(Duration::from_secs(30), chunks))
        .with_parallel(ParallelMode::Sequential)
        .with_seed(5);
    cfg.skip_final_assignment = true;
    let t0 = Instant::now();
    let r = BigMeans::new(cfg).run(&big).expect("run");
    let total = t0.elapsed().as_secs_f64();

    // Solver-only time: re-run the same chunk workload directly.
    let solver = NativeSolver::sequential(Default::default());
    let mut rng = bigmeans::util::rng::Rng::new(5);
    let mut sampler_time = 0.0;
    let mut solver_time = 0.0;
    let mut sampler = bigmeans::coordinator::sampler::ChunkSampler::new(4096, 16);
    let mut seed_c = cs[..8 * 16].to_vec();
    for _ in 0..chunks {
        let t = Instant::now();
        let (chunk, rows) = sampler.sample(&big, &mut rng);
        let chunk = chunk.to_vec();
        sampler_time += t.elapsed().as_secs_f64();
        let t = Instant::now();
        let mut cc = Counters::new();
        let out = solver.lloyd(&chunk, rows, 16, 8, &seed_c, &mut cc);
        solver_time += t.elapsed().as_secs_f64();
        seed_c = out.centroids;
    }
    let per_chunk_total = total / r.counters.chunks.max(1) as f64;
    let per_chunk_solver = solver_time / chunks as f64;
    let overhead = (per_chunk_total - per_chunk_solver).max(0.0);
    println!(
        "  total/chunk {:.3} ms | solver/chunk {:.3} ms | sampling/chunk {:.3} ms",
        per_chunk_total * 1e3,
        per_chunk_solver * 1e3,
        sampler_time / chunks as f64 * 1e3
    );
    println!(
        "  coordinator overhead ≈ {:.1}% (target < 5%)",
        overhead / per_chunk_total * 100.0
    );
}
