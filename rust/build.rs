//! Toolchain probe for the AVX-512 kernels.
//!
//! The stable `_mm512_*` f32 intrinsics landed in rustc 1.89, so the
//! `kernels::simd::avx512` module is compiled only when the active
//! compiler has them. Older toolchains simply compile the backend out:
//! `DistanceIsa::Avx512.available()` then returns false and runtime
//! dispatch falls back to AVX2, keeping the crate buildable everywhere
//! without feature flags or nightly.

use std::env;
use std::process::Command;

/// `$RUSTC --version` is at least `major.minor`. Conservative: any probe
/// failure reports false, which only disables the optional backend.
fn rustc_at_least(major: u32, minor: u32) -> bool {
    let rustc = env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let out = match Command::new(&rustc).arg("--version").output() {
        Ok(o) if o.status.success() => o,
        _ => return false,
    };
    let text = String::from_utf8_lossy(&out.stdout);
    // "rustc 1.89.0 (abc 2025-…)" — second token, split on non-digits so
    // nightly/beta suffixes ("1.91.0-nightly") parse too.
    let version = match text.split_whitespace().nth(1) {
        Some(v) => v,
        None => return false,
    };
    let mut parts = version.split(|c: char| !c.is_ascii_digit());
    let maj: u32 = parts.next().and_then(|p| p.parse().ok()).unwrap_or(0);
    let min: u32 = parts.next().and_then(|p| p.parse().ok()).unwrap_or(0);
    (maj, min) >= (major, minor)
}

fn main() {
    println!("cargo:rerun-if-changed=build.rs");
    // Always declare the cfg so `clippy -D warnings` under check-cfg stays
    // clean whether or not the gate fires.
    println!("cargo:rustc-check-cfg=cfg(bigmeans_avx512)");
    let arch = env::var("CARGO_CFG_TARGET_ARCH").unwrap_or_default();
    if arch == "x86_64" && rustc_at_least(1, 89) {
        println!("cargo:rustc-cfg=bigmeans_avx512");
    }
}
