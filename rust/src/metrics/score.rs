//! The paper's evaluation metrics: relative error `E_A`, the normalized
//! score `S(A, X, q)` (Tables 3–4), and min/mean/max summaries.

/// Relative error of an achieved objective vs the best-known value:
/// `E_A = (f̄ − f_best) / f_best × 100%` (paper §5.7). Can be negative when
/// a run beats the recorded best — the paper reports such entries too.
pub fn relative_error(f_achieved: f64, f_best: f64) -> f64 {
    debug_assert!(f_best > 0.0, "f_best must be positive");
    (f_achieved - f_best) / f_best * 100.0
}

/// Min/mean/max summary over a series of runs.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Summary {
    pub min: f64,
    pub mean: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(values: &[f64]) -> Summary {
        assert!(!values.is_empty(), "Summary::of on empty slice");
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        let mut sum = 0.0;
        for &v in values {
            min = min.min(v);
            max = max.max(v);
            sum += v;
        }
        Summary { min, mean: sum / values.len() as f64, max }
    }
}

/// The paper's normalized efficiency score:
///
/// `S(A, X, q) = 1 − (q_X(A) − min_A' q_X(A')) / (max_A' q_X(A') − min_A' q_X(A'))`
///
/// `q_values[i]` is metric `q` for algorithm `i` on dataset `X`; `None`
/// marks an algorithm that failed (out of memory / time) — it scores 0 and
/// does not participate in the min/max, matching the paper's protocol.
/// If all participating values are equal, everyone scores 1.
pub fn scores(q_values: &[Option<f64>]) -> Vec<f64> {
    let present: Vec<f64> = q_values.iter().filter_map(|v| *v).collect();
    if present.is_empty() {
        return vec![0.0; q_values.len()];
    }
    let lo = present.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = present.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = hi - lo;
    q_values
        .iter()
        .map(|v| match v {
            None => 0.0,
            Some(x) if span == 0.0 => {
                let _ = x;
                1.0
            }
            Some(x) => 1.0 - (x - lo) / span,
        })
        .collect()
}

/// Sum scores across datasets: `S(A, q) = Σ_X S(A, X, q)`.
/// `per_dataset[d][a]` = score of algorithm `a` on dataset `d`.
pub fn sum_scores(per_dataset: &[Vec<f64>]) -> Vec<f64> {
    if per_dataset.is_empty() {
        return Vec::new();
    }
    let n_alg = per_dataset[0].len();
    let mut out = vec![0.0; n_alg];
    for row in per_dataset {
        assert_eq!(row.len(), n_alg);
        for (acc, v) in out.iter_mut().zip(row) {
            *acc += v;
        }
    }
    out
}

/// Mean score across the two metrics (accuracy, cpu): `M(A, X)` in the paper.
pub fn mean_score(acc: f64, cpu: f64) -> f64 {
    0.5 * (acc + cpu)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relative_error_sign() {
        assert!((relative_error(110.0, 100.0) - 10.0).abs() < 1e-12);
        assert!((relative_error(95.0, 100.0) + 5.0).abs() < 1e-12);
        assert_eq!(relative_error(100.0, 100.0), 0.0);
    }

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 6.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 6.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
    }

    #[test]
    fn scores_best_gets_one_worst_gets_zero() {
        let s = scores(&[Some(1.0), Some(3.0), Some(2.0)]);
        assert_eq!(s[0], 1.0);
        assert_eq!(s[1], 0.0);
        assert!((s[2] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn scores_failure_scores_zero_and_excluded_from_range() {
        let s = scores(&[Some(1.0), None, Some(2.0)]);
        assert_eq!(s[0], 1.0);
        assert_eq!(s[1], 0.0);
        assert_eq!(s[2], 0.0);
    }

    #[test]
    fn scores_all_equal_all_one() {
        let s = scores(&[Some(5.0), Some(5.0)]);
        assert_eq!(s, vec![1.0, 1.0]);
    }

    #[test]
    fn sum_scores_adds_datasets() {
        let total = sum_scores(&[vec![1.0, 0.0], vec![0.5, 1.0]]);
        assert_eq!(total, vec![1.5, 1.0]);
    }

    #[test]
    fn paper_table4_shape_sanity() {
        // Big-means should out-sum a slow accurate method + a fast sloppy
        // method across two synthetic "datasets": this encodes the score
        // arithmetic the summary tables rely on.
        // dataset 1: [bigmeans, slow-accurate, fast-sloppy] accuracy q=E_A
        let acc1 = scores(&[Some(0.3), Some(0.1), Some(20.0)]);
        let cpu1 = scores(&[Some(1.0), Some(300.0), Some(0.9)]);
        let m: Vec<f64> = acc1
            .iter()
            .zip(&cpu1)
            .map(|(a, c)| mean_score(*a, *c))
            .collect();
        assert!(m[0] > m[1] && m[0] > m[2]);
    }
}
