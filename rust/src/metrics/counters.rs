//! Work counters matching the paper's reported quantities.
//!
//! The evaluation tables report, per run: `n_d` (number of distance
//! function evaluations), `n_full` (assignment+update iterations over the
//! full dataset), `n_s` (number of chunks processed), and the split CPU
//! times `cpu_init` / `cpu_full`. Every kernel and algorithm in this crate
//! threads a [`Counters`] through so the bench harness can print the same
//! columns.

/// Mutable work counters threaded through kernels and algorithms.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Counters {
    /// Distance-function evaluations (point↔centroid), the paper's `n_d`.
    pub distance_evals: u64,
    /// Distance evaluations *avoided* by triangle-inequality pruning (the
    /// bounded/Elkan kernel engines and the block-pruned final pass). Not
    /// included in `distance_evals`; an unpruned run would have performed
    /// `distance_evals + pruned_evals` (minus the rescans'
    /// bound-tightening evaluations).
    pub pruned_evals: u64,
    /// Store blocks of the final full-dataset pass whose bounding box was
    /// wholly owned by one centroid, so the whole block bypassed the
    /// k-wide assignment scan (see `store::prune`).
    pub pruned_blocks: u64,
    /// Lloyd iterations executed against the *full* dataset (`n_full`).
    pub full_iterations: u64,
    /// Lloyd iterations executed against chunks (not part of `n_full`).
    pub chunk_iterations: u64,
    /// Chunks processed (`n_s`).
    pub chunks: u64,
    /// Hamerly→Elkan switches taken by the hybrid kernel engine (one per
    /// chunk state at most — the switch is one-way).
    pub hybrid_switches: u64,
    /// Rescans observed by the hybrid engine's steady-state Hamerly steps
    /// (`(evals − m) / k` per step — exact under Hamerly accounting).
    /// Deterministic: derived from the merged per-step counters, so the
    /// serial and pool-parallel paths agree bit for bit.
    pub hybrid_rescans: u64,
    /// Rows examined by those same steps — the denominator of the
    /// observed rescan *rate* `hybrid_rescans / hybrid_scan_rows` that
    /// the learned switch threshold is priced against.
    pub hybrid_scan_rows: u64,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add_distance_evals(&mut self, n: u64) {
        self.distance_evals += n;
    }

    #[inline]
    pub fn add_pruned_evals(&mut self, n: u64) {
        self.pruned_evals += n;
    }

    /// Merge another counter set (e.g. from a parallel worker).
    pub fn merge(&mut self, other: &Counters) {
        self.distance_evals += other.distance_evals;
        self.pruned_evals += other.pruned_evals;
        self.pruned_blocks += other.pruned_blocks;
        self.full_iterations += other.full_iterations;
        self.chunk_iterations += other.chunk_iterations;
        self.chunks += other.chunks;
        self.hybrid_switches += other.hybrid_switches;
        self.hybrid_rescans += other.hybrid_rescans;
        self.hybrid_scan_rows += other.hybrid_scan_rows;
    }

    /// Observed hybrid rescan rate (0 when the hybrid Hamerly path never
    /// ran a steady-state step).
    pub fn hybrid_rescan_rate(&self) -> f64 {
        if self.hybrid_scan_rows == 0 {
            0.0
        } else {
            self.hybrid_rescans as f64 / self.hybrid_scan_rows as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_accumulates() {
        let mut a = Counters::new();
        a.add_distance_evals(10);
        a.chunks = 2;
        let mut b = Counters::new();
        b.add_distance_evals(5);
        b.full_iterations = 3;
        b.pruned_blocks = 4;
        a.merge(&b);
        assert_eq!(a.distance_evals, 15);
        assert_eq!(a.full_iterations, 3);
        assert_eq!(a.chunks, 2);
        assert_eq!(a.pruned_blocks, 4);
    }
}
