//! Bandit telemetry for the competitive portfolio tuner: per-arm pull
//! counts, reward traces, and work counters, in the same spirit as the
//! paper-protocol counters in [`super::counters`] — every number the tuner
//! acts on is also a number a report can print.
//!
//! The trace is deliberately dumb storage: the controllers in
//! [`crate::tuner::bandit`] keep their own sufficient statistics, and the
//! race records every pull here so runs can be audited (and asserted
//! bit-identical in the determinism tests) after the fact.

use crate::metrics::Counters;
use crate::util::json::{arr, num, obj, s, Json};

/// Aggregate statistics for one portfolio arm.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ArmTrace {
    /// Display label, e.g. `"0.5x/panel"`.
    pub label: String,
    /// Chunk rows this arm samples per shot.
    pub chunk_rows: usize,
    /// Kernel engine name (`panel` / `bounded`).
    pub kernel: String,
    /// Times the controller pulled this arm.
    pub pulls: u64,
    /// Pulls whose shot was accepted as the new incumbent.
    pub accepted: u64,
    /// Sum of observed rewards.
    pub total_reward: f64,
    /// Distance evaluations this arm spent (local search + scoring).
    pub distance_evals: u64,
    /// Distance evaluations the arm's bounded engine avoided.
    pub pruned_evals: u64,
}

impl ArmTrace {
    /// Mean observed reward (0 when never pulled).
    pub fn mean_reward(&self) -> f64 {
        if self.pulls == 0 {
            0.0
        } else {
            self.total_reward / self.pulls as f64
        }
    }

    /// Fold an arm's work counters into the trace.
    pub fn absorb_counters(&mut self, counters: &Counters) {
        self.distance_evals += counters.distance_evals;
        self.pruned_evals += counters.pruned_evals;
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("label", s(&self.label)),
            ("chunk_rows", num(self.chunk_rows as f64)),
            ("kernel", s(&self.kernel)),
            ("pulls", num(self.pulls as f64)),
            ("accepted", num(self.accepted as f64)),
            ("mean_reward", num(self.mean_reward())),
            ("total_reward", num(self.total_reward)),
            ("distance_evals", num(self.distance_evals as f64)),
            ("pruned_evals", num(self.pruned_evals as f64)),
        ])
    }
}

/// Whole-race telemetry: the pull order, the reward sequence, and per-arm
/// aggregates.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TunerTrace {
    /// Controller name (`ucb` / `softmax`).
    pub controller: String,
    /// Arm id of every pull, in scheduling order.
    pub pull_sequence: Vec<u32>,
    /// Reward of every pull, aligned with `pull_sequence`.
    pub rewards: Vec<f64>,
    /// Per-arm aggregates, indexed by arm id.
    pub arms: Vec<ArmTrace>,
}

impl TunerTrace {
    /// Empty trace over `arms` (labels pre-filled by the race).
    pub fn new(controller: &str, arms: Vec<ArmTrace>) -> Self {
        TunerTrace {
            controller: controller.to_string(),
            pull_sequence: Vec::new(),
            rewards: Vec::new(),
            arms,
        }
    }

    /// Record one pull of `arm` with its observed reward.
    pub fn record_pull(&mut self, arm: usize, reward: f64, accepted: bool) {
        self.pull_sequence.push(arm as u32);
        self.rewards.push(reward);
        let a = &mut self.arms[arm];
        a.pulls += 1;
        a.total_reward += reward;
        if accepted {
            a.accepted += 1;
        }
    }

    /// Total pulls recorded.
    pub fn total_pulls(&self) -> u64 {
        self.pull_sequence.len() as u64
    }

    /// Shots accepted as incumbent across all arms.
    pub fn total_accepted(&self) -> u64 {
        self.arms.iter().map(|a| a.accepted).sum()
    }

    /// The most-pulled arm (ties break to the lowest id); `None` before the
    /// first pull or on an empty portfolio.
    pub fn best_arm(&self) -> Option<usize> {
        self.arms
            .iter()
            .enumerate()
            .filter(|(_, a)| a.pulls > 0)
            .max_by(|(ia, a), (ib, b)| {
                a.pulls.cmp(&b.pulls).then(ib.cmp(ia))
            })
            .map(|(i, _)| i)
    }

    /// JSON document for reports (`BENCH_tuner.json`, `--json` summaries).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("controller", s(&self.controller)),
            (
                "pull_sequence",
                arr(self.pull_sequence.iter().map(|&a| num(a as f64)).collect()),
            ),
            ("rewards", arr(self.rewards.iter().map(|&r| num(r)).collect())),
            ("arms", arr(self.arms.iter().map(|a| a.to_json()).collect())),
            (
                "best_arm",
                self.best_arm().map(|i| num(i as f64)).unwrap_or(Json::Null),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(n: usize) -> TunerTrace {
        let arms = (0..n)
            .map(|i| ArmTrace {
                label: format!("arm{i}"),
                chunk_rows: 100 * (i + 1),
                kernel: "panel".into(),
                ..Default::default()
            })
            .collect();
        TunerTrace::new("ucb", arms)
    }

    #[test]
    fn pulls_accumulate_per_arm() {
        let mut t = trace(3);
        t.record_pull(1, 0.5, true);
        t.record_pull(1, 0.25, false);
        t.record_pull(2, 1.0, true);
        assert_eq!(t.pull_sequence, vec![1, 1, 2]);
        assert_eq!(t.arms[1].pulls, 2);
        assert_eq!(t.arms[1].accepted, 1);
        assert!((t.arms[1].mean_reward() - 0.375).abs() < 1e-12);
        assert_eq!(t.total_pulls(), 3);
        assert_eq!(t.total_accepted(), 2);
        assert_eq!(t.best_arm(), Some(1));
    }

    #[test]
    fn best_arm_ties_break_low_and_empty_is_none() {
        let mut t = trace(2);
        assert_eq!(t.best_arm(), None);
        t.record_pull(0, 0.0, false);
        t.record_pull(1, 0.0, false);
        assert_eq!(t.best_arm(), Some(0));
    }

    #[test]
    fn json_roundtrips() {
        let mut t = trace(2);
        t.record_pull(0, 0.75, true);
        let doc = t.to_json();
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("controller").unwrap().as_str(), Some("ucb"));
        assert_eq!(back.get("best_arm").unwrap().as_f64(), Some(0.0));
        assert_eq!(back.get("arms").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn counters_absorbed() {
        let mut a = ArmTrace::default();
        let mut c = Counters::new();
        c.add_distance_evals(10);
        c.add_pruned_evals(4);
        a.absorb_counters(&c);
        a.absorb_counters(&c);
        assert_eq!(a.distance_evals, 20);
        assert_eq!(a.pruned_evals, 8);
    }
}
