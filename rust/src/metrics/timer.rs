//! Wall-clock timing split into the paper's `cpu_init` / `cpu_full` phases.

use std::time::{Duration, Instant};

/// A stopwatch accumulating named phases.
#[derive(Debug, Default)]
pub struct PhaseTimer {
    /// Time spent in the initialization / search phase (`cpu_init`).
    pub init: Duration,
    /// Time spent in the final full-dataset phase (`cpu_full`).
    pub full: Duration,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure into the `init` phase.
    pub fn time_init<R>(&mut self, f: impl FnOnce() -> R) -> R {
        let t = Instant::now();
        let r = f();
        self.init += t.elapsed();
        r
    }

    /// Time a closure into the `full` phase.
    pub fn time_full<R>(&mut self, f: impl FnOnce() -> R) -> R {
        let t = Instant::now();
        let r = f();
        self.full += t.elapsed();
        r
    }

    /// Total `cpu = cpu_init + cpu_full` in seconds.
    pub fn total_secs(&self) -> f64 {
        (self.init + self.full).as_secs_f64()
    }

    pub fn init_secs(&self) -> f64 {
        self.init.as_secs_f64()
    }

    pub fn full_secs(&self) -> f64 {
        self.full.as_secs_f64()
    }
}

/// Simple deadline helper for the paper's `cpu_max` stop condition.
#[derive(Clone, Copy, Debug)]
pub struct Deadline {
    start: Instant,
    budget: Duration,
}

impl Deadline {
    pub fn new(budget: Duration) -> Self {
        Deadline { start: Instant::now(), budget }
    }

    pub fn unlimited() -> Self {
        Deadline { start: Instant::now(), budget: Duration::MAX }
    }

    #[inline]
    pub fn expired(&self) -> bool {
        self.start.elapsed() >= self.budget
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_accumulate() {
        let mut t = PhaseTimer::new();
        let x = t.time_init(|| 21 * 2);
        assert_eq!(x, 42);
        t.time_full(|| std::thread::sleep(Duration::from_millis(5)));
        assert!(t.full_secs() >= 0.004);
        assert!(t.total_secs() >= t.full_secs());
    }

    #[test]
    fn deadline_expiry() {
        let d = Deadline::new(Duration::from_millis(1));
        std::thread::sleep(Duration::from_millis(3));
        assert!(d.expired());
        assert!(!Deadline::unlimited().expired());
    }
}
