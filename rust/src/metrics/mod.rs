//! Evaluation metrics matching the paper's protocol: work counters
//! (`n_d`, `n_full`, `n_s`), phase timers (`cpu_init`/`cpu_full`),
//! relative error `E_A` and the normalized score system of Tables 3–4.

pub mod counters;
pub mod score;
pub mod timer;

pub use counters::Counters;
pub use score::{mean_score, relative_error, scores, sum_scores, Summary};
pub use timer::{Deadline, PhaseTimer};
