//! Evaluation metrics matching the paper's protocol: work counters
//! (`n_d`, `n_full`, `n_s`), phase timers (`cpu_init`/`cpu_full`),
//! relative error `E_A`, the normalized score system of Tables 3–4, and
//! the tuner's bandit telemetry (per-arm pulls and reward traces).

pub mod bandit;
pub mod counters;
pub mod score;
pub mod timer;

pub use bandit::{ArmTrace, TunerTrace};
pub use counters::Counters;
pub use score::{mean_score, relative_error, scores, sum_scores, Summary};
pub use timer::{Deadline, PhaseTimer};
