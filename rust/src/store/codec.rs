//! Block encode/decode: dtype conversion + optional codec.
//!
//! A block travels `&[f32]` → dtype bytes → codec bytes on write, and the
//! exact inverse on read. The shuffle stage is a byte transpose: with
//! element width `w`, byte lane `j` of every element is stored
//! contiguously (`out[j·count + i] = in[i·w + j]`), which turns float
//! payloads into long runs of slowly-varying bytes — the shape the LZ
//! stage (and any downstream compressor) actually bites on.

use crate::store::format::{Codec, Dtype};
use crate::util::error::Result;
use crate::util::half::{f16_from_f32, f32_from_f16};
use crate::util::lz;
use crate::{anyhow, bail};

/// Byte-transpose `data` (length a multiple of `width`).
pub fn shuffle(data: &[u8], width: usize) -> Vec<u8> {
    debug_assert_eq!(data.len() % width, 0);
    let count = data.len() / width;
    let mut out = vec![0u8; data.len()];
    for j in 0..width {
        let lane = &mut out[j * count..(j + 1) * count];
        for (i, slot) in lane.iter_mut().enumerate() {
            *slot = data[i * width + j];
        }
    }
    out
}

/// Inverse of [`shuffle`].
pub fn unshuffle(data: &[u8], width: usize) -> Vec<u8> {
    debug_assert_eq!(data.len() % width, 0);
    let count = data.len() / width;
    let mut out = vec![0u8; data.len()];
    for j in 0..width {
        let lane = &data[j * count..(j + 1) * count];
        for (i, &b) in lane.iter().enumerate() {
            out[i * width + j] = b;
        }
    }
    out
}

fn dtype_encode(values: &[f32], dtype: Dtype) -> Vec<u8> {
    let mut out = Vec::with_capacity(values.len() * dtype.width());
    match dtype {
        Dtype::F32 => {
            for &v in values {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Dtype::F64 => {
            for &v in values {
                out.extend_from_slice(&(v as f64).to_le_bytes());
            }
        }
        Dtype::F16 => {
            for &v in values {
                out.extend_from_slice(&f16_from_f32(v).to_le_bytes());
            }
        }
    }
    out
}

fn dtype_decode(bytes: &[u8], dtype: Dtype) -> Vec<f32> {
    match dtype {
        Dtype::F32 => bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect(),
        Dtype::F64 => bytes
            .chunks_exact(8)
            .map(|b| f64::from_le_bytes(b.try_into().unwrap()) as f32)
            .collect(),
        Dtype::F16 => bytes
            .chunks_exact(2)
            .map(|b| f32_from_f16(u16::from_le_bytes(b.try_into().unwrap())))
            .collect(),
    }
}

/// Per-dimension min/max of one block in the *decoded* domain: `n` mins
/// followed by `n` maxs. For `f16` the bounds are taken over the
/// quantised values (what a reader decodes), so they are valid for every
/// value the block will ever serve. This is the single implementation the
/// writer, the verifier, and `convert --add-summaries` all share — the
/// three must agree bit-for-bit for summary verification to be exact.
///
/// Any non-finite value (NaN, ±∞) **poisons its dimension**: the bounds
/// are pinned to the `(∞, −∞)` sentinels, which the pruner treats as
/// "never prunable". This is load-bearing for exactness — a NaN
/// coordinate makes every panel distance evaluate to `NaN.max(0.0) = 0`,
/// so a box that silently ignored the NaN could be classified as owned
/// while the unpruned scan labels the row differently.
pub fn block_minmax(values: &[f32], dtype: Dtype, n: usize) -> Vec<f32> {
    debug_assert_eq!(values.len() % n, 0);
    let mut out = vec![0f32; 2 * n];
    let (mins, maxs) = out.split_at_mut(n);
    mins.fill(f32::INFINITY);
    maxs.fill(f32::NEG_INFINITY);
    let mut poisoned = vec![false; n];
    for row in values.chunks_exact(n) {
        for (d, &raw) in row.iter().enumerate() {
            let v = match dtype {
                Dtype::F32 | Dtype::F64 => raw,
                Dtype::F16 => f32_from_f16(f16_from_f32(raw)),
            };
            if !v.is_finite() {
                poisoned[d] = true;
                continue;
            }
            if v < mins[d] {
                mins[d] = v;
            }
            if v > maxs[d] {
                maxs[d] = v;
            }
        }
    }
    for d in 0..n {
        if poisoned[d] {
            mins[d] = f32::INFINITY;
            maxs[d] = f32::NEG_INFINITY;
        }
    }
    out
}

/// Encode one block of `values` into its on-disk bytes.
pub fn encode_block(values: &[f32], dtype: Dtype, codec: Codec) -> Vec<u8> {
    let raw = dtype_encode(values, dtype);
    match codec {
        Codec::None => raw,
        Codec::Shuffle => shuffle(&raw, dtype.width()),
        Codec::Lz => lz::compress(&shuffle(&raw, dtype.width())),
    }
}

/// Decode one on-disk block back to exactly `values_len` f32 values.
/// Fails (rather than panicking) on any length mismatch or corrupt LZ
/// stream, so callers can attach the block index to the diagnostic.
pub fn decode_block(
    bytes: &[u8],
    values_len: usize,
    dtype: Dtype,
    codec: Codec,
) -> Result<Vec<f32>> {
    let raw_len = values_len
        .checked_mul(dtype.width())
        .ok_or_else(|| anyhow!("block of {values_len} values overflows"))?;
    match codec {
        Codec::None | Codec::Shuffle => {
            if bytes.len() != raw_len {
                bail!(
                    "encoded length {} does not match the {raw_len}-byte geometry",
                    bytes.len()
                );
            }
            match codec {
                // No intermediate copy: decode straight off the (possibly
                // mmap'd) encoded bytes — this is the default f32/none
                // read path.
                Codec::None => Ok(dtype_decode(bytes, dtype)),
                _ => Ok(dtype_decode(&unshuffle(bytes, dtype.width()), dtype)),
            }
        }
        Codec::Lz => {
            let shuffled = lz::decompress(bytes, raw_len)?;
            Ok(dtype_decode(&unshuffle(&shuffled, dtype.width()), dtype))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_values(count: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..count)
            .map(|i| match i % 5 {
                0 => 0.0,
                1 => -1.5,
                2 => rng.f32() * 1.0e4,
                3 => -(rng.f32() + 1.0e-3),
                _ => (i as f32).sqrt(),
            })
            .collect()
    }

    #[test]
    fn shuffle_is_a_bijection() {
        let data: Vec<u8> = (0..64u8).collect();
        for width in [1usize, 2, 4, 8] {
            let sh = shuffle(&data, width);
            assert_eq!(unshuffle(&sh, width), data, "width {width}");
            if width > 1 {
                assert_ne!(sh, data, "width {width} should permute");
            }
        }
    }

    #[test]
    fn shuffle_width_one_is_identity() {
        let data: Vec<u8> = (0..10u8).collect();
        assert_eq!(shuffle(&data, 1), data);
    }

    #[test]
    fn lossless_dtypes_roundtrip_bit_exact() {
        let values = sample_values(1000, 7);
        for dtype in [Dtype::F32, Dtype::F64] {
            for codec in [Codec::None, Codec::Shuffle, Codec::Lz] {
                let enc = encode_block(&values, dtype, codec);
                let dec = decode_block(&enc, values.len(), dtype, codec).unwrap();
                let a: Vec<u32> = values.iter().map(|v| v.to_bits()).collect();
                let b: Vec<u32> = dec.iter().map(|v| v.to_bits()).collect();
                assert_eq!(a, b, "{dtype:?}/{codec:?}");
            }
        }
    }

    #[test]
    fn f16_roundtrip_matches_quantiser() {
        let values = sample_values(500, 11);
        let expected: Vec<f32> =
            values.iter().map(|&v| f32_from_f16(f16_from_f32(v))).collect();
        for codec in [Codec::None, Codec::Shuffle, Codec::Lz] {
            let enc = encode_block(&values, Dtype::F16, codec);
            let dec = decode_block(&enc, values.len(), Dtype::F16, codec).unwrap();
            assert_eq!(dec, expected, "{codec:?}");
        }
    }

    #[test]
    fn length_mismatches_rejected() {
        let values = sample_values(64, 3);
        let enc = encode_block(&values, Dtype::F32, Codec::None);
        assert!(decode_block(&enc, values.len() + 1, Dtype::F32, Codec::None).is_err());
        assert!(decode_block(&enc[..enc.len() - 4], values.len(), Dtype::F32, Codec::None)
            .is_err());
        let lz = encode_block(&values, Dtype::F32, Codec::Lz);
        assert!(decode_block(&lz[..lz.len() - 1], values.len(), Dtype::F32, Codec::Lz).is_err());
    }

    #[test]
    fn block_minmax_bounds_decoded_values() {
        let values = sample_values(600, 13); // 200 rows × 3
        for dtype in [Dtype::F32, Dtype::F64, Dtype::F16] {
            let mm = block_minmax(&values, dtype, 3);
            let enc = encode_block(&values, dtype, Codec::Shuffle);
            let dec = decode_block(&enc, values.len(), dtype, Codec::Shuffle).unwrap();
            // Recomputing over the decoded values must reproduce the same
            // bits (the verify contract) …
            assert_eq!(block_minmax(&dec, dtype, 3), mm, "{dtype:?}");
            // … and every decoded value must sit inside its dimension's
            // bounds.
            for row in dec.chunks_exact(3) {
                for (d, &v) in row.iter().enumerate() {
                    assert!(v >= mm[d] && v <= mm[3 + d], "{dtype:?} dim {d}: {v}");
                }
            }
        }
    }

    #[test]
    fn non_finite_values_poison_their_dimension() {
        // 3 rows × 2 dims; dim 0 carries a NaN, dim 1 an infinity.
        let values = [1.0f32, 2.0, f32::NAN, 5.0, 3.0, f32::INFINITY];
        let mm = block_minmax(&values, Dtype::F32, 2);
        assert_eq!(mm[0], f32::INFINITY, "NaN dim must be unprunable");
        assert_eq!(mm[2], f32::NEG_INFINITY);
        assert_eq!(mm[1], f32::INFINITY, "inf dim must be unprunable");
        assert_eq!(mm[3], f32::NEG_INFINITY);
        // A clean block is unaffected.
        let clean = block_minmax(&[1.0f32, 2.0, 3.0, 5.0], Dtype::F32, 2);
        assert_eq!(clean, vec![1.0, 2.0, 3.0, 5.0]);
    }

    #[test]
    fn empty_block_roundtrips() {
        for codec in [Codec::None, Codec::Shuffle, Codec::Lz] {
            let enc = encode_block(&[], Dtype::F32, codec);
            assert_eq!(decode_block(&enc, 0, Dtype::F32, codec).unwrap(), Vec::<f32>::new());
        }
    }
}
