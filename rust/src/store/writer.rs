//! Streaming `.bmx` v3 writer.
//!
//! [`BlockWriter`] buffers appended rows until whole blocks are available,
//! encodes them (dtype conversion, codec, CRC-32, and — by default — the
//! per-block per-dimension min/max summary) **in parallel** on an owned
//! [`ThreadPool`] — encoding is the CPU cost of ingest, the write itself
//! is sequential — and streams the encoded blocks out back to back.
//! [`BlockWriter::finish`] flushes the final partial block, appends the
//! block-index table and the summary section, and patches the header (row
//! count, index offset/CRC, summary offset/CRC), so memory stays
//! O(pending rows + summaries) regardless of the dataset size.
//! [`add_summaries`] retrofits the summary section onto an existing v3
//! file by decoding (never re-encoding) its blocks.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Seek, SeekFrom, Write};
use std::path::Path;

use crate::bail;
use crate::data::source::DataSource;
use crate::store::codec::{block_minmax, encode_block};
use crate::store::format::{
    BlockEntry, StoreOptions, V3Header, BLOCK_ENTRY_LEN, BMX3_HEADER_LEN,
};
use crate::store::source::BlockStore;
use crate::util::error::{Context, Result};
use crate::util::hash::{crc32, Crc32};
use crate::util::threadpool::ThreadPool;

/// Streaming writer for the chunked v3 format.
pub struct BlockWriter {
    w: BufWriter<File>,
    n: usize,
    opts: StoreOptions,
    /// Rows awaiting encoding (row-major, `< block_rows` after each flush
    /// unless the caller batched more than one block).
    pending: Vec<f32>,
    rows: u64,
    entries: Vec<BlockEntry>,
    /// Per-block decoded-domain min/max (`2n` values per block), built
    /// alongside the entries when `opts.summaries` is set.
    summaries: Vec<f32>,
    cursor: u64,
    pool: ThreadPool,
}

impl BlockWriter {
    /// Create `path` and write a placeholder header (patched on
    /// [`BlockWriter::finish`]).
    pub fn create(path: &Path, n: usize, opts: StoreOptions) -> Result<Self> {
        if n == 0 || n > u32::MAX as usize {
            bail!("block store: invalid feature count {n}");
        }
        if opts.block_rows == 0 || opts.block_rows > u32::MAX as usize {
            bail!("block store: invalid block_rows {}", opts.block_rows);
        }
        let file =
            File::create(path).with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(file);
        let header = V3Header {
            m: 0,
            n: n as u32,
            block_rows: opts.block_rows as u32,
            dtype: opts.dtype,
            codec: opts.codec,
            index_off: 0,
            index_crc: 0,
            summary_off: 0,
            summary_crc: 0,
        };
        w.write_all(&header.encode())?;
        let workers = if opts.threads == 0 {
            std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4)
        } else {
            opts.threads
        };
        Ok(BlockWriter {
            w,
            n,
            opts,
            pending: Vec::new(),
            rows: 0,
            entries: Vec::new(),
            summaries: Vec::new(),
            cursor: BMX3_HEADER_LEN as u64,
            pool: ThreadPool::new(workers),
        })
    }

    /// Append one or more rows (`values.len()` must be a multiple of `n`).
    /// Whole blocks are encoded and written eagerly; feeding several
    /// blocks per call lets them encode in parallel.
    pub fn write_rows(&mut self, values: &[f32]) -> Result<()> {
        if values.len() % self.n != 0 {
            bail!(
                "block store: write of {} values is not a whole number of {}-wide rows",
                values.len(),
                self.n
            );
        }
        self.pending.extend_from_slice(values);
        self.rows += (values.len() / self.n) as u64;
        self.flush_complete_blocks(false)
    }

    /// Encode and write every complete block in `pending` (plus the final
    /// partial block when `all` is set).
    fn flush_complete_blocks(&mut self, all: bool) -> Result<()> {
        let block_values = self.opts.block_rows * self.n;
        let complete = self.pending.len() / block_values;
        let mut take = complete * block_values;
        if all && take < self.pending.len() {
            take = self.pending.len();
        }
        if take == 0 {
            return Ok(());
        }
        let (dtype, codec) = (self.opts.dtype, self.opts.codec);
        let (n, want_summaries) = (self.n, self.opts.summaries);
        let chunks: Vec<&[f32]> = self.pending[..take].chunks(block_values).collect();
        let mut encoded: Vec<(Vec<u8>, u32, Vec<f32>)> = Vec::new();
        if chunks.len() > 1 && self.pool.size() > 1 {
            encoded.resize_with(chunks.len(), Default::default);
            let jobs: Vec<_> = chunks
                .iter()
                .zip(encoded.iter_mut())
                .map(|(chunk, slot)| {
                    let chunk: &[f32] = chunk;
                    move || {
                        let bytes = encode_block(chunk, dtype, codec);
                        let crc = crc32(&bytes);
                        let mm = if want_summaries {
                            block_minmax(chunk, dtype, n)
                        } else {
                            Vec::new()
                        };
                        *slot = (bytes, crc, mm);
                    }
                })
                .collect();
            self.pool.scope_run_all(jobs);
        } else {
            for chunk in &chunks {
                let bytes = encode_block(chunk, dtype, codec);
                let crc = crc32(&bytes);
                let mm =
                    if want_summaries { block_minmax(chunk, dtype, n) } else { Vec::new() };
                encoded.push((bytes, crc, mm));
            }
        }
        for (bytes, crc, mm) in &encoded {
            self.w.write_all(bytes)?;
            self.entries.push(BlockEntry {
                offset: self.cursor,
                enc_len: bytes.len() as u64,
                crc: *crc,
            });
            self.summaries.extend_from_slice(mm);
            self.cursor += bytes.len() as u64;
        }
        self.pending.drain(..take);
        Ok(())
    }

    /// Flush the tail block, append the index table (and the summary
    /// section when enabled), patch the header, and return the row count.
    pub fn finish(mut self) -> Result<u64> {
        self.flush_complete_blocks(true)?;
        debug_assert!(self.pending.is_empty());
        let index_off = self.cursor;
        let mut index_crc = Crc32::new();
        for entry in &self.entries {
            let bytes = entry.encode();
            index_crc.update(&bytes);
            self.w.write_all(&bytes)?;
        }
        let mut summary_off = 0u64;
        let mut summary_crc = 0u32;
        if self.opts.summaries && !self.entries.is_empty() {
            debug_assert_eq!(self.summaries.len(), self.entries.len() * 2 * self.n);
            summary_off = index_off + (self.entries.len() * BLOCK_ENTRY_LEN) as u64;
            let bytes = summary_bytes(&self.summaries);
            summary_crc = crc32(&bytes);
            self.w.write_all(&bytes)?;
        }
        let header = V3Header {
            m: self.rows,
            n: self.n as u32,
            block_rows: self.opts.block_rows as u32,
            dtype: self.opts.dtype,
            codec: self.opts.codec,
            index_off,
            index_crc: index_crc.finalize(),
            summary_off,
            summary_crc,
        };
        self.w.flush()?;
        self.w.seek(SeekFrom::Start(0))?;
        self.w.write_all(&header.encode())?;
        self.w.flush()?;
        Ok(self.rows)
    }

    /// Blocks written so far (complete blocks only until `finish`).
    pub fn blocks_written(&self) -> usize {
        self.entries.len()
    }
}

/// Little-endian byte image of a summary vector (per block: `n` mins then
/// `n` maxs).
fn summary_bytes(summaries: &[f32]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(summaries.len() * 4);
    for v in summaries {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    bytes
}

/// Retrofit the per-block min/max summary section onto an existing v3
/// file **in place** — blocks are decoded (CRC-checked) but never
/// re-encoded: the section is appended at the end of the file and the
/// header's summary offset/CRC are patched. Returns `false` (and changes
/// nothing) when the file already carries summaries. `threads = 0` uses
/// the machine default for the parallel decode.
pub fn add_summaries(path: &Path, threads: usize) -> Result<bool> {
    let store = BlockStore::open(path)?;
    if store.has_summaries() {
        return Ok(false);
    }
    let summaries = store.compute_summaries(threads)?;
    let (n, nblocks) = (store.n(), store.blocks());
    debug_assert_eq!(summaries.len(), nblocks * 2 * n);
    drop(store); // release the mapping before rewriting the file
    let mut file = OpenOptions::new()
        .read(true)
        .write(true)
        .open(path)
        .with_context(|| format!("reopen {} for summary append", path.display()))?;
    let summary_off = file.metadata()?.len();
    let bytes = summary_bytes(&summaries);
    file.seek(SeekFrom::Start(summary_off))?;
    file.write_all(&bytes)?;
    // Make the appended section durable *before* the header points at it:
    // a crash from here back leaves `summary_off = 0` — a valid
    // pre-summary file — instead of a header referencing bytes that never
    // reached disk.
    file.sync_all()?;
    // Patch only the summary fields (bytes 36..48), offset and CRC in one
    // 12-byte write, so the rest of the header — and everything an old
    // reader looks at — is untouched.
    let mut patch = [0u8; 12];
    patch[0..8].copy_from_slice(&summary_off.to_le_bytes());
    patch[8..12].copy_from_slice(&crc32(&bytes).to_le_bytes());
    file.seek(SeekFrom::Start(36))?;
    file.write_all(&patch)?;
    file.sync_all()?;
    Ok(true)
}

/// Rows copied per slab when converting a whole [`DataSource`]: enough
/// blocks to keep every encode worker busy, capped so the slab buffer
/// stays modest.
fn slab_rows(block_rows: usize, workers: usize) -> usize {
    (block_rows * workers.max(4)).min(1 << 20).max(block_rows)
}

/// Stream an entire source into a v3 block store. Returns `(m, n)`.
/// This is the engine behind `bigmeans convert` and `generate`: memory is
/// bounded by one slab regardless of the dataset size.
pub fn copy_to_store(
    src: &dyn DataSource,
    path: &Path,
    opts: StoreOptions,
) -> Result<(usize, usize)> {
    let (m, n) = (src.m(), src.n());
    if m == 0 || n == 0 {
        bail!("block store: refusing to write an empty {m}×{n} store");
    }
    let mut writer = BlockWriter::create(path, n, opts)?;
    let slab = slab_rows(opts.block_rows, writer.pool.size());
    let mut buf = vec![0f32; slab.min(m) * n];
    let mut start = 0usize;
    while start < m {
        let rows = slab.min(m - start);
        src.read_rows(start, &mut buf[..rows * n]);
        writer.write_rows(&buf[..rows * n])?;
        start += rows;
    }
    let written = writer.finish()?;
    debug_assert_eq!(written as usize, m);
    Ok((m, n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::store::format::{Codec, Dtype};
    use crate::store::source::BlockStore;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bigmeans_store_writer_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{name}", std::process::id()))
    }

    fn toy(m: usize, n: usize) -> Dataset {
        Dataset::from_vec(
            "toy",
            (0..m * n).map(|x| (x as f32) * 0.25 - 3.0).collect(),
            m,
            n,
        )
    }

    #[test]
    fn incremental_writes_match_bulk_copy() {
        let d = toy(100, 3);
        let opts = StoreOptions { block_rows: 16, threads: 2, ..StoreOptions::default() };
        let p1 = tmp("incr.bmx");
        let p2 = tmp("bulk.bmx");
        let mut w = BlockWriter::create(&p1, 3, opts).unwrap();
        // Deliberately ragged pushes: 7 rows, 50 rows, the rest.
        w.write_rows(&d.points()[..7 * 3]).unwrap();
        w.write_rows(&d.points()[7 * 3..57 * 3]).unwrap();
        w.write_rows(&d.points()[57 * 3..]).unwrap();
        assert_eq!(w.finish().unwrap(), 100);
        copy_to_store(&d, &p2, opts).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
        let _ = std::fs::remove_file(&p1);
        let _ = std::fs::remove_file(&p2);
    }

    #[test]
    fn parallel_and_serial_encoding_produce_identical_files() {
        let d = toy(4096, 4);
        for codec in [Codec::None, Codec::Shuffle, Codec::Lz] {
            let base = StoreOptions { block_rows: 128, codec, ..StoreOptions::default() };
            let p1 = tmp(&format!("serial_{}.bmx", codec.name()));
            let p2 = tmp(&format!("parallel_{}.bmx", codec.name()));
            copy_to_store(&d, &p1, StoreOptions { threads: 1, ..base }).unwrap();
            copy_to_store(&d, &p2, StoreOptions { threads: 4, ..base }).unwrap();
            assert_eq!(
                std::fs::read(&p1).unwrap(),
                std::fs::read(&p2).unwrap(),
                "{codec:?}"
            );
            let _ = std::fs::remove_file(&p1);
            let _ = std::fs::remove_file(&p2);
        }
    }

    #[test]
    fn partial_tail_block_preserved() {
        let d = toy(37, 2); // 4 full 8-row blocks + a 5-row tail
        let p = tmp("tail.bmx");
        let opts = StoreOptions { block_rows: 8, ..StoreOptions::default() };
        copy_to_store(&d, &p, opts).unwrap();
        let store = BlockStore::open(&p).unwrap();
        assert_eq!((store.m(), store.n()), (37, 2));
        assert_eq!(store.blocks(), 5);
        let mut out = vec![0f32; 37 * 2];
        store.read_rows(0, &mut out);
        assert_eq!(out, d.points());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn f16_store_quantises_deterministically() {
        let d = toy(64, 2);
        let p = tmp("f16.bmx");
        let opts =
            StoreOptions { block_rows: 16, dtype: Dtype::F16, ..StoreOptions::default() };
        copy_to_store(&d, &p, opts).unwrap();
        let store = BlockStore::open(&p).unwrap();
        let mut out = vec![0f32; 64 * 2];
        store.read_rows(0, &mut out);
        let expected: Vec<f32> = d
            .points()
            .iter()
            .map(|&v| crate::util::half::f32_from_f16(crate::util::half::f16_from_f32(v)))
            .collect();
        assert_eq!(out, expected);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn invalid_shapes_rejected() {
        let p = tmp("bad.bmx");
        assert!(BlockWriter::create(&p, 0, StoreOptions::default()).is_err());
        let opts = StoreOptions { block_rows: 0, ..StoreOptions::default() };
        assert!(BlockWriter::create(&p, 2, opts).is_err());
        let mut w = BlockWriter::create(&p, 3, StoreOptions::default()).unwrap();
        assert!(w.write_rows(&[1.0, 2.0]).is_err());
        let _ = std::fs::remove_file(&p);
    }
}
