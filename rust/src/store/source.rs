//! [`BlockStore`] — the `.bmx` v3 reader: a [`DataSource`] that decodes
//! blocks on demand with per-block integrity checking and an LRU cache of
//! decoded blocks.
//!
//! Open cost is O(header + index): the block-index table is read and its
//! CRC validated, but **no payload byte is touched** — integrity is
//! checked per block on first decode, so a read path costs O(touched
//! blocks) however large the file is (this is what retires the v2
//! whole-payload-CRC cap). [`BlockStore::verify_all`] is the explicit
//! full scan: every block checked in parallel, the first corrupt block
//! named by index.
//!
//! Per the [`DataSource`] contract, corruption discovered *during a read*
//! panics with a diagnostic naming the block; constructors and
//! `verify_all` return errors instead.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::data::source::{AccessPattern, BlockSummaries, DataSource};
use crate::obs;
use crate::store::cache::{BlockCache, DEFAULT_CACHE_BYTES};
use crate::store::codec::{block_minmax, decode_block};
use crate::store::format::{BlockEntry, Codec, Dtype, V3Header, BLOCK_ENTRY_LEN, BMX3_HEADER_LEN};
use crate::util::error::{Context, Result};
use crate::util::hash::crc32;
use crate::util::sync::lock_recover;
use crate::util::threadpool::ThreadPool;
use crate::{anyhow, bail};

#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
use crate::util::mem::MmapRegion;

enum Backing {
    /// Whole-file mapping; encoded block bytes are sliced in place.
    #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
    Mmap(MmapRegion),
    /// Portable fallback: positioned buffered reads.
    Pread(Mutex<File>),
}

/// Scan summary returned by [`BlockStore::verify_all`].
#[derive(Clone, Copy, Debug)]
pub struct VerifyReport {
    /// Blocks checked.
    pub blocks: usize,
    /// Encoded payload bytes scanned.
    pub encoded_bytes: u64,
}

/// Out-of-core chunked `.bmx` v3 dataset.
pub struct BlockStore {
    name: String,
    m: usize,
    n: usize,
    block_rows: usize,
    dtype: Dtype,
    codec: Codec,
    entries: Vec<BlockEntry>,
    /// Per-block decoded-domain min/max (`2n` values per block) when the
    /// file carries the summary section.
    summaries: Option<Vec<f32>>,
    backing: Backing,
    cache: BlockCache,
    m_decoded: obs::Counter,
}

impl BlockStore {
    /// Open `path`, preferring a memory mapping (buffered positioned
    /// reads when mapping is unavailable), with the default cache budget.
    pub fn open(path: &Path) -> Result<BlockStore> {
        Self::open_opts(path, true, DEFAULT_CACHE_BYTES)
    }

    /// Open with the buffered-pread backing unconditionally.
    pub fn open_buffered(path: &Path) -> Result<BlockStore> {
        Self::open_opts(path, false, DEFAULT_CACHE_BYTES)
    }

    /// Open with explicit backing preference and decoded-block cache
    /// budget (bytes).
    pub fn open_opts(path: &Path, prefer_mmap: bool, cache_bytes: usize) -> Result<BlockStore> {
        let mut file =
            File::open(path).with_context(|| format!("open {}", path.display()))?;
        let label = path.display().to_string();
        let mut hdr_bytes = [0u8; BMX3_HEADER_LEN];
        file.read_exact(&mut hdr_bytes)
            .with_context(|| format!("read bmx v3 header of {label}"))?;
        let hdr = V3Header::decode(&hdr_bytes, &label)?;
        if hdr.m > usize::MAX as u64 / 2 {
            bail!("{label}: bmx v3 row count {} not addressable on this target", hdr.m);
        }
        let file_len = file.metadata()?.len();
        let blocks = hdr.blocks();
        let index_len = blocks
            .checked_mul(BLOCK_ENTRY_LEN as u64)
            .ok_or_else(|| anyhow!("{label}: block count {blocks} overflows"))?;
        let index_end = hdr
            .index_off
            .checked_add(index_len)
            .ok_or_else(|| anyhow!("{label}: bmx v3 index offset overflows"))?;
        if index_end > file_len {
            bail!(
                "{label}: truncated bmx v3 index (file holds {file_len} bytes, \
                 index needs [{}, {index_end}))",
                hdr.index_off
            );
        }
        if hdr.index_off < BMX3_HEADER_LEN as u64 {
            bail!("{label}: bmx v3 index offset {} inside the header", hdr.index_off);
        }
        // Read + validate the index table.
        let mut index_bytes = vec![0u8; index_len as usize];
        file.seek(SeekFrom::Start(hdr.index_off))?;
        file.read_exact(&mut index_bytes)
            .with_context(|| format!("read bmx v3 index of {label}"))?;
        let computed = crc32(&index_bytes);
        if computed != hdr.index_crc {
            bail!(
                "{label}: bmx v3 index checksum mismatch (expected {:#010x}, \
                 computed {computed:#010x}) — file corrupt or truncated mid-write",
                hdr.index_crc
            );
        }
        let entries: Vec<BlockEntry> =
            index_bytes.chunks_exact(BLOCK_ENTRY_LEN).map(BlockEntry::decode).collect();
        // Optional summary section (version-tolerant: zeroed offset =
        // pre-summary file, served exactly as before).
        let summaries = if hdr.summary_off != 0 {
            let summary_len = hdr.summary_len();
            let summary_end = hdr
                .summary_off
                .checked_add(summary_len)
                .ok_or_else(|| anyhow!("{label}: bmx v3 summary offset overflows"))?;
            if hdr.summary_off < index_end || summary_end > file_len {
                bail!(
                    "{label}: bmx v3 summary section [{}, {summary_end}) outside the \
                     file tail (index ends at {index_end}, file holds {file_len})",
                    hdr.summary_off
                );
            }
            let mut summary_raw = vec![0u8; summary_len as usize];
            file.seek(SeekFrom::Start(hdr.summary_off))?;
            file.read_exact(&mut summary_raw)
                .with_context(|| format!("read bmx v3 summaries of {label}"))?;
            let computed = crc32(&summary_raw);
            if computed != hdr.summary_crc {
                bail!(
                    "{label}: bmx v3 summary checksum mismatch (expected {:#010x}, \
                     computed {computed:#010x}) — file corrupt or truncated mid-write",
                    hdr.summary_crc
                );
            }
            Some(parse_summaries(&summary_raw, blocks as usize, hdr.n as usize, &label)?)
        } else {
            None
        };
        for (i, e) in entries.iter().enumerate() {
            let ok = e.offset >= BMX3_HEADER_LEN as u64
                && e.offset.checked_add(e.enc_len).is_some_and(|end| end <= hdr.index_off);
            if !ok {
                bail!(
                    "{label}: bmx v3 block {i} spans [{}, {}] outside the payload region",
                    e.offset,
                    e.offset as u128 + e.enc_len as u128
                );
            }
        }
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "bmx".into());
        let backing = 'backing: {
            #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
            {
                if prefer_mmap {
                    if let Some(region) = MmapRegion::map(&file, file_len as usize) {
                        region.advise(AccessPattern::Random.advice());
                        break 'backing Backing::Mmap(region);
                    }
                }
            }
            #[cfg(not(all(unix, target_endian = "little", target_pointer_width = "64")))]
            let _ = prefer_mmap;
            Backing::Pread(Mutex::new(file))
        };
        Ok(BlockStore {
            name,
            m: hdr.m as usize,
            n: hdr.n as usize,
            block_rows: hdr.block_rows as usize,
            dtype: hdr.dtype,
            codec: hdr.codec,
            entries,
            summaries,
            backing,
            cache: BlockCache::new(cache_bytes),
            m_decoded: obs::metrics().counter(
                "bigmeans_blocks_decoded_total",
                "Store blocks decoded (CRC + codec + dtype pass)",
                &[],
            ),
        })
    }

    /// Whether the file carries the per-block min/max summary section.
    pub fn has_summaries(&self) -> bool {
        self.summaries.is_some()
    }

    /// Recompute every block's summary from its decoded values (parallel;
    /// `threads = 0` uses the machine default). This is the engine behind
    /// `convert --add-summaries`; it CRC-checks each block as a side
    /// effect.
    pub fn compute_summaries(&self, threads: usize) -> Result<Vec<f32>> {
        let nblocks = self.entries.len();
        let n = self.n;
        let mut out = vec![0f32; nblocks * 2 * n];
        if nblocks == 0 {
            return Ok(out);
        }
        let workers = if threads == 0 {
            std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4)
        } else {
            threads
        };
        let pool = ThreadPool::new(workers.min(nblocks));
        let mut failures: Vec<Option<String>> = vec![None; nblocks];
        let jobs: Vec<_> = out
            .chunks_mut(2 * n)
            .zip(failures.iter_mut())
            .enumerate()
            .map(|(idx, (slot, fail))| {
                move || match self.checked_decode(idx) {
                    Ok(values) => {
                        slot.copy_from_slice(&block_minmax(&values, self.dtype, n));
                    }
                    Err(e) => *fail = Some(e.to_string()),
                }
            })
            .collect();
        pool.scope_run_all(jobs);
        if let Some(failure) = failures.into_iter().flatten().next() {
            bail!("block store '{}': {failure}", self.name);
        }
        Ok(out)
    }

    /// True when the payload is memory-mapped.
    pub fn is_mmap(&self) -> bool {
        #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
        {
            matches!(self.backing, Backing::Mmap(_))
        }
        #[cfg(not(all(unix, target_endian = "little", target_pointer_width = "64")))]
        {
            false
        }
    }

    /// Number of blocks.
    pub fn blocks(&self) -> usize {
        self.entries.len()
    }

    /// Rows per block (the last block may be shorter).
    pub fn block_rows(&self) -> usize {
        self.block_rows
    }

    /// On-disk element type.
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Per-block codec.
    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Decoded-block cache `(hits, misses)`.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.cache.stats()
    }

    /// The encoded byte range `[start, end)` of block `idx` (tests and
    /// diagnostics — this is where a corruption probe should flip bytes).
    pub fn block_byte_range(&self, idx: usize) -> (u64, u64) {
        let e = &self.entries[idx];
        (e.offset, e.offset + e.enc_len)
    }

    /// Rows held by block `idx`.
    fn rows_in_block(&self, idx: usize) -> usize {
        let start = idx * self.block_rows;
        self.block_rows.min(self.m - start)
    }

    /// Fetch the encoded bytes of `entry` and run `f` over them (zero-copy
    /// on the mmap backing). I/O failures are errors here — the read path
    /// turns them into panics, the verifier reports them cleanly.
    fn with_encoded<R>(&self, entry: &BlockEntry, f: impl FnOnce(&[u8]) -> R) -> Result<R> {
        match &self.backing {
            #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
            Backing::Mmap(region) => {
                let lo = entry.offset as usize;
                let hi = (entry.offset + entry.enc_len) as usize;
                Ok(f(&region.bytes()[lo..hi]))
            }
            Backing::Pread(file) => {
                let mut buf = vec![0u8; entry.enc_len as usize];
                {
                    // Poison-recovering: every use seeks to an absolute
                    // offset before reading, so a panic that poisoned the
                    // lock leaves no cursor state a later read depends on.
                    let mut fh = lock_recover(file);
                    fh.seek(SeekFrom::Start(entry.offset))
                        .with_context(|| format!("seek to offset {}", entry.offset))?;
                    fh.read_exact(&mut buf)
                        .with_context(|| format!("read {} encoded bytes", entry.enc_len))?;
                }
                Ok(f(&buf))
            }
        }
    }

    /// CRC-check and decode block `idx` (shared by the read path and the
    /// verifier).
    fn checked_decode(&self, idx: usize) -> Result<Vec<f32>> {
        let entry = self.entries[idx];
        let values_len = self.rows_in_block(idx) * self.n;
        let decoded = self.with_encoded(&entry, |bytes| {
            let computed = crc32(bytes);
            if computed != entry.crc {
                bail!(
                    "checksum mismatch (expected {:#010x}, computed {computed:#010x}) \
                     — file corrupt or truncated mid-write",
                    entry.crc
                );
            }
            decode_block(bytes, values_len, self.dtype, self.codec)
        });
        let flat = match decoded {
            Ok(inner) => inner,
            Err(io) => Err(io),
        };
        flat.with_context(|| format!("block {idx} of {}", self.entries.len()))
    }

    /// Decoded block `idx` through the LRU cache. Corruption panics with
    /// the block index (the [`DataSource`] read contract).
    fn block(&self, idx: usize) -> Arc<Vec<f32>> {
        if let Some(hit) = self.cache.get(idx) {
            return hit;
        }
        let _span = obs::tracer().span("store.decode", "block");
        let decoded = self.checked_decode(idx).unwrap_or_else(|e| {
            panic!("block store '{}': {e}", self.name);
        });
        self.m_decoded.inc();
        let arc = Arc::new(decoded);
        self.cache.insert(idx, Arc::clone(&arc));
        arc
    }

    /// Verify every block in parallel (CRC + full decode, plus — when the
    /// file carries summaries — per-block min/max consistency against the
    /// decoded values), returning the **first** corrupt block's
    /// diagnostic. `threads = 0` uses the machine default.
    pub fn verify_all(&self, threads: usize) -> Result<VerifyReport> {
        let nblocks = self.entries.len();
        if nblocks == 0 {
            return Ok(VerifyReport { blocks: 0, encoded_bytes: 0 });
        }
        let workers = if threads == 0 {
            std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4)
        } else {
            threads
        };
        let n = self.n;
        let pool = ThreadPool::new(workers.min(nblocks));
        let mut failures: Vec<Option<String>> = vec![None; nblocks];
        let jobs: Vec<_> = failures
            .iter_mut()
            .enumerate()
            .map(|(idx, slot)| {
                move || match self.checked_decode(idx) {
                    Err(e) => *slot = Some(e.to_string()),
                    Ok(values) => {
                        if let Some(summaries) = &self.summaries {
                            let stored = &summaries[idx * 2 * n..(idx + 1) * 2 * n];
                            let fresh = block_minmax(&values, self.dtype, n);
                            // Bit compare: writer and verifier share one
                            // min/max implementation over the same decoded
                            // values, so any difference is corruption.
                            let same = stored
                                .iter()
                                .zip(&fresh)
                                .all(|(a, b)| a.to_bits() == b.to_bits());
                            if !same {
                                *slot = Some(format!(
                                    "summary mismatch for block {idx}: stored min/max \
                                     disagrees with the decoded values"
                                ));
                            }
                        }
                    }
                }
            })
            .collect();
        pool.scope_run_all(jobs);
        if let Some(failure) = failures.into_iter().flatten().next() {
            bail!("block store '{}': {failure}", self.name);
        }
        Ok(VerifyReport {
            blocks: nblocks,
            encoded_bytes: self.entries.iter().map(|e| e.enc_len).sum(),
        })
    }
}

/// Decode the summary section after validating its exact length: it must
/// hold `blocks × dims × 2` little-endian f32 values (min + max per
/// dimension per block). Without this check `chunks_exact(4)` would
/// silently drop trailing bytes of a CRC-consistent but wrong-length
/// section, leaving a partial summary table that block pruning would
/// mis-trust.
fn parse_summaries(raw: &[u8], blocks: usize, n: usize, label: &str) -> Result<Vec<f32>> {
    let want = blocks
        .checked_mul(2 * n)
        .and_then(|v| v.checked_mul(4))
        .ok_or_else(|| anyhow!("{label}: bmx v3 summary geometry overflows"))?;
    if raw.len() != want {
        bail!(
            "{label}: wrong-length summary section ({} bytes, geometry of \
             {blocks} blocks x {n} dims needs exactly {want})",
            raw.len()
        );
    }
    Ok(raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
        .collect())
}

impl DataSource for BlockStore {
    fn name(&self) -> &str {
        &self.name
    }

    fn m(&self) -> usize {
        self.m
    }

    fn n(&self) -> usize {
        self.n
    }

    fn read_rows(&self, start: usize, out: &mut [f32]) {
        let n = self.n;
        assert_eq!(out.len() % n, 0, "read_rows: out shape");
        let rows = out.len() / n;
        assert!(start + rows <= self.m, "read_rows: range out of bounds");
        let mut row = start;
        let mut filled = 0usize;
        while filled < rows {
            let idx = row / self.block_rows;
            let within = row - idx * self.block_rows;
            let take = (self.block_rows - within).min(rows - filled);
            let block = self.block(idx);
            out[filled * n..(filled + take) * n]
                .copy_from_slice(&block[within * n..(within + take) * n]);
            row += take;
            filled += take;
        }
    }

    fn sample_rows(&self, indices: &[usize], out: &mut [f32]) {
        let n = self.n;
        assert_eq!(out.len(), indices.len() * n, "sample_rows: out shape");
        // Consecutive indices usually land in the same block (samplers
        // sort their draws for locality) — hold the last block across
        // iterations to skip even the cache lock.
        let mut held: Option<(usize, Arc<Vec<f32>>)> = None;
        for (slot, &i) in indices.iter().enumerate() {
            assert!(i < self.m, "sample_rows: row {i} out of bounds");
            let idx = i / self.block_rows;
            let block = match &held {
                Some((h, b)) if *h == idx => Arc::clone(b),
                _ => {
                    let b = self.block(idx);
                    held = Some((idx, Arc::clone(&b)));
                    b
                }
            };
            let within = i - idx * self.block_rows;
            out[slot * n..(slot + 1) * n]
                .copy_from_slice(&block[within * n..(within + 1) * n]);
        }
    }

    fn advise(&self, pattern: AccessPattern) {
        match &self.backing {
            #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
            Backing::Mmap(region) => region.advise(pattern.advice()),
            Backing::Pread(_) => {}
        }
    }

    fn block_summaries(&self) -> Option<BlockSummaries<'_>> {
        self.summaries.as_ref().map(|minmax| BlockSummaries {
            block_rows: self.block_rows,
            minmax: minmax.as_slice(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::store::format::StoreOptions;
    use crate::store::writer::copy_to_store;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bigmeans_store_source_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{name}", std::process::id()))
    }

    fn toy(m: usize, n: usize) -> Dataset {
        Dataset::from_vec(
            "toy",
            (0..m * n).map(|x| (x as f32) * 0.5 - 11.0).collect(),
            m,
            n,
        )
    }

    #[test]
    fn open_reads_geometry_without_touching_payload() {
        let d = toy(100, 4);
        let p = tmp("geom.bmx");
        let opts = StoreOptions { block_rows: 16, ..StoreOptions::default() };
        copy_to_store(&d, &p, opts).unwrap();
        let s = BlockStore::open(&p).unwrap();
        assert_eq!((s.m(), s.n()), (100, 4));
        assert_eq!(s.blocks(), 7);
        assert_eq!(s.block_rows(), 16);
        assert_eq!(s.cache_stats(), (0, 0));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn reads_cross_block_boundaries_and_hit_cache() {
        let d = toy(100, 4);
        let p = tmp("cross.bmx");
        let opts = StoreOptions { block_rows: 16, ..StoreOptions::default() };
        copy_to_store(&d, &p, opts).unwrap();
        for s in [BlockStore::open(&p).unwrap(), BlockStore::open_buffered(&p).unwrap()] {
            let mut out = vec![0f32; 40 * 4];
            s.read_rows(10, &mut out); // spans blocks 0..=3
            assert_eq!(out, &d.points()[10 * 4..50 * 4]);
            let (h0, m0) = s.cache_stats();
            assert_eq!(h0, 0);
            assert_eq!(m0, 4);
            s.read_rows(10, &mut out); // all warm now
            assert_eq!(s.cache_stats(), (4, 4));
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn gather_matches_dataset_on_every_backing() {
        let d = toy(333, 3);
        let p = tmp("gather.bmx");
        let opts = StoreOptions { block_rows: 32, ..StoreOptions::default() };
        copy_to_store(&d, &p, opts).unwrap();
        let idx = [0usize, 1, 31, 32, 33, 100, 100, 332, 5];
        let mut want = vec![0f32; idx.len() * 3];
        DataSource::sample_rows(&d, &idx, &mut want);
        for s in [BlockStore::open(&p).unwrap(), BlockStore::open_buffered(&p).unwrap()] {
            let mut got = vec![0f32; idx.len() * 3];
            s.sample_rows(&idx, &mut got);
            assert_eq!(got, want);
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn verify_all_passes_clean_and_names_corrupt_block() {
        let d = toy(200, 2);
        let p = tmp("verify.bmx");
        let opts = StoreOptions { block_rows: 20, ..StoreOptions::default() };
        copy_to_store(&d, &p, opts).unwrap();
        let s = BlockStore::open(&p).unwrap();
        let report = s.verify_all(2).unwrap();
        assert_eq!(report.blocks, 10);
        let (lo, _hi) = s.block_byte_range(6);
        drop(s);
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[lo as usize + 3] ^= 0x20;
        std::fs::write(&p, &bytes).unwrap();
        let s = BlockStore::open(&p).unwrap(); // open is O(index): still fine
        let err = s.verify_all(2).unwrap_err().to_string();
        assert!(err.contains("block 6"), "diagnostic must name the block: {err}");
        // A read that never touches block 6 stays clean.
        let mut row = vec![0f32; 2];
        s.read_rows(0, &mut row);
        assert_eq!(row, &d.points()[..2]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn corrupt_index_rejected_at_open() {
        let d = toy(64, 2);
        let p = tmp("index.bmx");
        // summaries: false keeps the index as the trailing section.
        let opts =
            StoreOptions { block_rows: 8, summaries: false, ..StoreOptions::default() };
        copy_to_store(&d, &p, opts).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let last = bytes.len() - 2; // inside the trailing index table
        bytes[last] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let err = BlockStore::open(&p).unwrap_err().to_string();
        assert!(err.contains("index checksum"), "unexpected error: {err}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn corrupt_summary_rejected_at_open() {
        let d = toy(64, 2);
        let p = tmp("summ.bmx");
        copy_to_store(&d, &p, StoreOptions { block_rows: 8, ..StoreOptions::default() })
            .unwrap();
        assert!(BlockStore::open(&p).unwrap().has_summaries());
        let mut bytes = std::fs::read(&p).unwrap();
        let last = bytes.len() - 2; // inside the trailing summary section
        bytes[last] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let err = BlockStore::open(&p).unwrap_err().to_string();
        assert!(err.contains("summary checksum"), "unexpected error: {err}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn wrong_length_summary_section_is_a_named_error() {
        let ok = parse_summaries(&[0u8; 2 * 2 * 2 * 4], 2, 2, "t").unwrap();
        assert_eq!(ok.len(), 2 * 2 * 2);
        for bad_len in [0usize, 3, 2 * 2 * 2 * 4 - 4, 2 * 2 * 2 * 4 + 1] {
            let raw = vec![0u8; bad_len];
            let err = parse_summaries(&raw, 2, 2, "t").unwrap_err().to_string();
            assert!(err.contains("wrong-length summary section"), "{err}");
        }
    }

    #[test]
    fn truncated_file_rejected_at_open() {
        let d = toy(64, 2);
        let p = tmp("trunc.bmx");
        copy_to_store(&d, &p, StoreOptions { block_rows: 8, ..StoreOptions::default() })
            .unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 40]).unwrap();
        assert!(BlockStore::open(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn read_of_corrupt_block_panics_with_block_index() {
        let d = toy(80, 2);
        let p = tmp("panic.bmx");
        let opts = StoreOptions { block_rows: 16, ..StoreOptions::default() };
        copy_to_store(&d, &p, opts).unwrap();
        let s = BlockStore::open(&p).unwrap();
        let (lo, _) = s.block_byte_range(2);
        drop(s);
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[lo as usize] ^= 0x01;
        std::fs::write(&p, &bytes).unwrap();
        let s = BlockStore::open(&p).unwrap();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut out = vec![0f32; 2];
            s.read_rows(40, &mut out); // row 40 lives in block 2
        }))
        .unwrap_err();
        let msg = caught
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "non-string panic".into());
        assert!(msg.contains("block 2"), "panic must name the block: {msg}");
        let _ = std::fs::remove_file(&p);
    }
}
