//! [`BlockStore`] — the `.bmx` v3 reader: a [`DataSource`] that decodes
//! blocks on demand with per-block integrity checking and an LRU cache of
//! decoded blocks.
//!
//! Open cost is O(header + index): the block-index table is read and its
//! CRC validated, but **no payload byte is touched** — integrity is
//! checked per block on first decode, so a read path costs O(touched
//! blocks) however large the file is (this is what retires the v2
//! whole-payload-CRC cap). [`BlockStore::verify_all`] is the explicit
//! full scan: every block checked in parallel, the first corrupt block
//! named by index.
//!
//! Per the [`DataSource`] contract, corruption discovered *during a read*
//! panics with a diagnostic naming the block; constructors and
//! `verify_all` return errors instead.
//!
//! **Decode-free f16 path.** When the file stores `dtype f16` with
//! `codec none` on the mmap backing, the payload *is* the matrix — raw
//! little-endian f16, no codec framing. Reads then skip the
//! decode-to-f32 slab copy and the decoded-block LRU entirely: each
//! row is widened f16→f32 element-by-element straight into the caller's
//! buffer, halving memory traffic. Widening is exact (every f16 value
//! is representable in f32) and performs the same per-element
//! conversion as `dtype_decode`, so labels and objectives are
//! bit-identical to the decode path. Block CRCs are still enforced —
//! once per block, on its first raw touch. Bypassed cache lookups are
//! counted in `bigmeans_store_cache_bypass_total`.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::data::source::{AccessPattern, BlockSummaries, DataSource};
use crate::obs;
use crate::store::cache::{BlockCache, DEFAULT_CACHE_BYTES};
use crate::store::codec::{block_minmax, decode_block};
use crate::store::format::{BlockEntry, Codec, Dtype, V3Header, BLOCK_ENTRY_LEN, BMX3_HEADER_LEN};
use crate::util::error::{Context, Result};
use crate::util::half::f32_from_f16;
use crate::util::hash::crc32;
use crate::util::sync::lock_recover;
use crate::util::threadpool::ThreadPool;
use crate::{anyhow, bail};

#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
use crate::util::mem::MmapRegion;

enum Backing {
    /// Whole-file mapping; encoded block bytes are sliced in place.
    #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
    Mmap(MmapRegion),
    /// Portable fallback: positioned buffered reads.
    Pread(Mutex<File>),
}

/// Scan summary returned by [`BlockStore::verify_all`].
#[derive(Clone, Copy, Debug)]
pub struct VerifyReport {
    /// Blocks checked.
    pub blocks: usize,
    /// Encoded payload bytes scanned.
    pub encoded_bytes: u64,
}

/// Out-of-core chunked `.bmx` v3 dataset.
pub struct BlockStore {
    name: String,
    m: usize,
    n: usize,
    block_rows: usize,
    dtype: Dtype,
    codec: Codec,
    entries: Vec<BlockEntry>,
    /// Per-block decoded-domain min/max (`2n` values per block) when the
    /// file carries the summary section.
    summaries: Option<Vec<f32>>,
    backing: Backing,
    cache: BlockCache,
    m_decoded: obs::Counter,
    /// Reads take the decode-free raw-f16 path (dtype f16, codec none,
    /// mmap backing; disable with [`Self::set_fused_f16`]).
    fused_f16: AtomicBool,
    /// Per-block "raw bytes CRC-verified" bitmap for the decode-free
    /// path, which never runs the decoder that normally checks CRCs.
    raw_checked: Vec<AtomicBool>,
    m_bypass: obs::Counter,
}

impl BlockStore {
    /// Open `path`, preferring a memory mapping (buffered positioned
    /// reads when mapping is unavailable), with the default cache budget.
    pub fn open(path: &Path) -> Result<BlockStore> {
        Self::open_opts(path, true, DEFAULT_CACHE_BYTES)
    }

    /// Open with the buffered-pread backing unconditionally.
    pub fn open_buffered(path: &Path) -> Result<BlockStore> {
        Self::open_opts(path, false, DEFAULT_CACHE_BYTES)
    }

    /// Open with explicit backing preference and decoded-block cache
    /// budget (bytes).
    pub fn open_opts(path: &Path, prefer_mmap: bool, cache_bytes: usize) -> Result<BlockStore> {
        let mut file =
            File::open(path).with_context(|| format!("open {}", path.display()))?;
        let label = path.display().to_string();
        let mut hdr_bytes = [0u8; BMX3_HEADER_LEN];
        file.read_exact(&mut hdr_bytes)
            .with_context(|| format!("read bmx v3 header of {label}"))?;
        let hdr = V3Header::decode(&hdr_bytes, &label)?;
        if hdr.m > usize::MAX as u64 / 2 {
            bail!("{label}: bmx v3 row count {} not addressable on this target", hdr.m);
        }
        let file_len = file.metadata()?.len();
        let blocks = hdr.blocks();
        let index_len = blocks
            .checked_mul(BLOCK_ENTRY_LEN as u64)
            .ok_or_else(|| anyhow!("{label}: block count {blocks} overflows"))?;
        let index_end = hdr
            .index_off
            .checked_add(index_len)
            .ok_or_else(|| anyhow!("{label}: bmx v3 index offset overflows"))?;
        if index_end > file_len {
            bail!(
                "{label}: truncated bmx v3 index (file holds {file_len} bytes, \
                 index needs [{}, {index_end}))",
                hdr.index_off
            );
        }
        if hdr.index_off < BMX3_HEADER_LEN as u64 {
            bail!("{label}: bmx v3 index offset {} inside the header", hdr.index_off);
        }
        // Read + validate the index table.
        let mut index_bytes = vec![0u8; index_len as usize];
        file.seek(SeekFrom::Start(hdr.index_off))?;
        file.read_exact(&mut index_bytes)
            .with_context(|| format!("read bmx v3 index of {label}"))?;
        let computed = crc32(&index_bytes);
        if computed != hdr.index_crc {
            bail!(
                "{label}: bmx v3 index checksum mismatch (expected {:#010x}, \
                 computed {computed:#010x}) — file corrupt or truncated mid-write",
                hdr.index_crc
            );
        }
        let entries: Vec<BlockEntry> =
            index_bytes.chunks_exact(BLOCK_ENTRY_LEN).map(BlockEntry::decode).collect();
        // Optional summary section (version-tolerant: zeroed offset =
        // pre-summary file, served exactly as before).
        let summaries = if hdr.summary_off != 0 {
            let summary_len = hdr.summary_len();
            let summary_end = hdr
                .summary_off
                .checked_add(summary_len)
                .ok_or_else(|| anyhow!("{label}: bmx v3 summary offset overflows"))?;
            if hdr.summary_off < index_end || summary_end > file_len {
                bail!(
                    "{label}: bmx v3 summary section [{}, {summary_end}) outside the \
                     file tail (index ends at {index_end}, file holds {file_len})",
                    hdr.summary_off
                );
            }
            let mut summary_raw = vec![0u8; summary_len as usize];
            file.seek(SeekFrom::Start(hdr.summary_off))?;
            file.read_exact(&mut summary_raw)
                .with_context(|| format!("read bmx v3 summaries of {label}"))?;
            let computed = crc32(&summary_raw);
            if computed != hdr.summary_crc {
                bail!(
                    "{label}: bmx v3 summary checksum mismatch (expected {:#010x}, \
                     computed {computed:#010x}) — file corrupt or truncated mid-write",
                    hdr.summary_crc
                );
            }
            Some(parse_summaries(&summary_raw, blocks as usize, hdr.n as usize, &label)?)
        } else {
            None
        };
        for (i, e) in entries.iter().enumerate() {
            let ok = e.offset >= BMX3_HEADER_LEN as u64
                && e.offset.checked_add(e.enc_len).is_some_and(|end| end <= hdr.index_off);
            if !ok {
                bail!(
                    "{label}: bmx v3 block {i} spans [{}, {}] outside the payload region",
                    e.offset,
                    e.offset as u128 + e.enc_len as u128
                );
            }
        }
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "bmx".into());
        let backing = 'backing: {
            #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
            {
                if prefer_mmap {
                    if let Some(region) = MmapRegion::map(&file, file_len as usize) {
                        region.advise(AccessPattern::Random.advice());
                        break 'backing Backing::Mmap(region);
                    }
                }
            }
            #[cfg(not(all(unix, target_endian = "little", target_pointer_width = "64")))]
            let _ = prefer_mmap;
            Backing::Pread(Mutex::new(file))
        };
        let nblocks = entries.len();
        let store = BlockStore {
            name,
            m: hdr.m as usize,
            n: hdr.n as usize,
            block_rows: hdr.block_rows as usize,
            dtype: hdr.dtype,
            codec: hdr.codec,
            entries,
            summaries,
            backing,
            cache: BlockCache::new(cache_bytes),
            m_decoded: obs::metrics().counter(
                "bigmeans_blocks_decoded_total",
                "Store blocks decoded (CRC + codec + dtype pass)",
                &[],
            ),
            fused_f16: AtomicBool::new(false),
            raw_checked: (0..nblocks).map(|_| AtomicBool::new(false)).collect(),
            m_bypass: obs::metrics().counter(
                "bigmeans_store_cache_bypass_total",
                "Decode-free f16 block reads that bypassed the decoded-f32 cache",
                &[],
            ),
        };
        store.set_fused_f16(true); // on by default whenever eligible
        Ok(store)
    }

    /// Whether the file carries the per-block min/max summary section.
    pub fn has_summaries(&self) -> bool {
        self.summaries.is_some()
    }

    /// Recompute every block's summary from its decoded values (parallel;
    /// `threads = 0` uses the machine default). This is the engine behind
    /// `convert --add-summaries`; it CRC-checks each block as a side
    /// effect.
    pub fn compute_summaries(&self, threads: usize) -> Result<Vec<f32>> {
        let nblocks = self.entries.len();
        let n = self.n;
        let mut out = vec![0f32; nblocks * 2 * n];
        if nblocks == 0 {
            return Ok(out);
        }
        let workers = if threads == 0 {
            std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4)
        } else {
            threads
        };
        let pool = ThreadPool::new(workers.min(nblocks));
        let mut failures: Vec<Option<String>> = vec![None; nblocks];
        let jobs: Vec<_> = out
            .chunks_mut(2 * n)
            .zip(failures.iter_mut())
            .enumerate()
            .map(|(idx, (slot, fail))| {
                move || match self.checked_decode(idx) {
                    Ok(values) => {
                        slot.copy_from_slice(&block_minmax(&values, self.dtype, n));
                    }
                    Err(e) => *fail = Some(e.to_string()),
                }
            })
            .collect();
        pool.scope_run_all(jobs);
        if let Some(failure) = failures.into_iter().flatten().next() {
            bail!("block store '{}': {failure}", self.name);
        }
        Ok(out)
    }

    /// True when the payload is memory-mapped.
    pub fn is_mmap(&self) -> bool {
        #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
        {
            matches!(self.backing, Backing::Mmap(_))
        }
        #[cfg(not(all(unix, target_endian = "little", target_pointer_width = "64")))]
        {
            false
        }
    }

    /// Number of blocks.
    pub fn blocks(&self) -> usize {
        self.entries.len()
    }

    /// Rows per block (the last block may be shorter).
    pub fn block_rows(&self) -> usize {
        self.block_rows
    }

    /// On-disk element type.
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Per-block codec.
    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Decoded-block cache `(hits, misses)`.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.cache.stats()
    }

    /// Whether reads currently take the decode-free f16 fast path.
    pub fn fused_f16_active(&self) -> bool {
        self.fused_f16.load(Ordering::Relaxed)
    }

    /// Enable/disable the decode-free f16 path. Enabling is a no-op on
    /// ineligible stores (dtype ≠ f16, codec ≠ none, or no mmap backing);
    /// disabling forces the decode-then-cache path, which the A/B bench
    /// rows and the fused ≡ decoded bit-identity tests rely on.
    pub fn set_fused_f16(&self, on: bool) {
        let eligible =
            self.dtype == Dtype::F16 && self.codec == Codec::None && self.is_mmap();
        self.fused_f16.store(on && eligible, Ordering::Relaxed);
    }

    /// The encoded byte range `[start, end)` of block `idx` (tests and
    /// diagnostics — this is where a corruption probe should flip bytes).
    pub fn block_byte_range(&self, idx: usize) -> (u64, u64) {
        let e = &self.entries[idx];
        (e.offset, e.offset + e.enc_len)
    }

    /// Rows held by block `idx`.
    fn rows_in_block(&self, idx: usize) -> usize {
        let start = idx * self.block_rows;
        self.block_rows.min(self.m - start)
    }

    /// Fetch the encoded bytes of `entry` and run `f` over them (zero-copy
    /// on the mmap backing). I/O failures are errors here — the read path
    /// turns them into panics, the verifier reports them cleanly.
    fn with_encoded<R>(&self, entry: &BlockEntry, f: impl FnOnce(&[u8]) -> R) -> Result<R> {
        match &self.backing {
            #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
            Backing::Mmap(region) => {
                let lo = entry.offset as usize;
                let hi = (entry.offset + entry.enc_len) as usize;
                Ok(f(&region.bytes()[lo..hi]))
            }
            Backing::Pread(file) => {
                let mut buf = vec![0u8; entry.enc_len as usize];
                {
                    // Poison-recovering: every use seeks to an absolute
                    // offset before reading, so a panic that poisoned the
                    // lock leaves no cursor state a later read depends on.
                    let mut fh = lock_recover(file);
                    fh.seek(SeekFrom::Start(entry.offset))
                        .with_context(|| format!("seek to offset {}", entry.offset))?;
                    fh.read_exact(&mut buf)
                        .with_context(|| format!("read {} encoded bytes", entry.enc_len))?;
                }
                Ok(f(&buf))
            }
        }
    }

    /// CRC-check and decode block `idx` (shared by the read path and the
    /// verifier).
    fn checked_decode(&self, idx: usize) -> Result<Vec<f32>> {
        let entry = self.entries[idx];
        let values_len = self.rows_in_block(idx) * self.n;
        let decoded = self.with_encoded(&entry, |bytes| {
            let computed = crc32(bytes);
            if computed != entry.crc {
                bail!(
                    "checksum mismatch (expected {:#010x}, computed {computed:#010x}) \
                     — file corrupt or truncated mid-write",
                    entry.crc
                );
            }
            decode_block(bytes, values_len, self.dtype, self.codec)
        });
        let flat = match decoded {
            Ok(inner) => inner,
            Err(io) => Err(io),
        };
        flat.with_context(|| format!("block {idx} of {}", self.entries.len()))
    }

    /// Run `f` over the raw little-endian f16 payload of block `idx`
    /// (the decode-free path). The CRC — which the decoder would
    /// normally enforce — is checked once per block, on its first raw
    /// touch, through a per-block bitmap; corruption panics naming the
    /// block, exactly like [`Self::block`].
    fn with_raw_f16<R>(&self, idx: usize, f: impl FnOnce(&[u8]) -> R) -> R {
        let entry = self.entries[idx];
        let values_len = self.rows_in_block(idx) * self.n;
        let res = self
            .with_encoded(&entry, |bytes| {
                if !self.raw_checked[idx].load(Ordering::Relaxed) {
                    let computed = crc32(bytes);
                    if computed != entry.crc {
                        bail!(
                            "checksum mismatch (expected {:#010x}, computed \
                             {computed:#010x}) — file corrupt or truncated mid-write",
                            entry.crc
                        );
                    }
                    if bytes.len() != values_len * 2 {
                        bail!(
                            "raw f16 block holds {} bytes, geometry needs exactly {}",
                            bytes.len(),
                            values_len * 2
                        );
                    }
                    self.raw_checked[idx].store(true, Ordering::Relaxed);
                }
                Ok(f(bytes))
            })
            .and_then(|inner| inner)
            .with_context(|| format!("block {idx} of {}", self.entries.len()));
        res.unwrap_or_else(|e| panic!("block store '{}': {e}", self.name))
    }

    /// Decoded block `idx` through the LRU cache. Corruption panics with
    /// the block index (the [`DataSource`] read contract).
    fn block(&self, idx: usize) -> Arc<Vec<f32>> {
        if let Some(hit) = self.cache.get(idx) {
            return hit;
        }
        let _span = obs::tracer().span("store.decode", "block");
        let decoded = self.checked_decode(idx).unwrap_or_else(|e| {
            panic!("block store '{}': {e}", self.name);
        });
        self.m_decoded.inc();
        let arc = Arc::new(decoded);
        self.cache.insert(idx, Arc::clone(&arc));
        arc
    }

    /// Verify every block in parallel (CRC + full decode, plus — when the
    /// file carries summaries — per-block min/max consistency against the
    /// decoded values), returning the **first** corrupt block's
    /// diagnostic. `threads = 0` uses the machine default.
    pub fn verify_all(&self, threads: usize) -> Result<VerifyReport> {
        let nblocks = self.entries.len();
        if nblocks == 0 {
            return Ok(VerifyReport { blocks: 0, encoded_bytes: 0 });
        }
        let workers = if threads == 0 {
            std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4)
        } else {
            threads
        };
        let n = self.n;
        let pool = ThreadPool::new(workers.min(nblocks));
        let mut failures: Vec<Option<String>> = vec![None; nblocks];
        let jobs: Vec<_> = failures
            .iter_mut()
            .enumerate()
            .map(|(idx, slot)| {
                move || match self.checked_decode(idx) {
                    Err(e) => *slot = Some(e.to_string()),
                    Ok(values) => {
                        if let Some(summaries) = &self.summaries {
                            let stored = &summaries[idx * 2 * n..(idx + 1) * 2 * n];
                            let fresh = block_minmax(&values, self.dtype, n);
                            // Bit compare: writer and verifier share one
                            // min/max implementation over the same decoded
                            // values, so any difference is corruption.
                            let same = stored
                                .iter()
                                .zip(&fresh)
                                .all(|(a, b)| a.to_bits() == b.to_bits());
                            if !same {
                                *slot = Some(format!(
                                    "summary mismatch for block {idx}: stored min/max \
                                     disagrees with the decoded values"
                                ));
                            }
                        }
                    }
                }
            })
            .collect();
        pool.scope_run_all(jobs);
        if let Some(failure) = failures.into_iter().flatten().next() {
            bail!("block store '{}': {failure}", self.name);
        }
        Ok(VerifyReport {
            blocks: nblocks,
            encoded_bytes: self.entries.iter().map(|e| e.enc_len).sum(),
        })
    }
}

/// Decode the summary section after validating its exact length: it must
/// hold `blocks × dims × 2` little-endian f32 values (min + max per
/// dimension per block). Without this check `chunks_exact(4)` would
/// silently drop trailing bytes of a CRC-consistent but wrong-length
/// section, leaving a partial summary table that block pruning would
/// mis-trust.
fn parse_summaries(raw: &[u8], blocks: usize, n: usize, label: &str) -> Result<Vec<f32>> {
    let want = blocks
        .checked_mul(2 * n)
        .and_then(|v| v.checked_mul(4))
        .ok_or_else(|| anyhow!("{label}: bmx v3 summary geometry overflows"))?;
    if raw.len() != want {
        bail!(
            "{label}: wrong-length summary section ({} bytes, geometry of \
             {blocks} blocks x {n} dims needs exactly {want})",
            raw.len()
        );
    }
    Ok(raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
        .collect())
}

/// Widen raw little-endian f16 payload bytes into `out`. Exact: every
/// f16 value is representable in f32 and this is the same per-element
/// conversion `dtype_decode` performs (no accumulation, no rounding),
/// so the fused path is bit-identical to decode-then-f32.
fn widen_f16(raw: &[u8], out: &mut [f32]) {
    debug_assert_eq!(raw.len(), out.len() * 2);
    for (slot, pair) in out.iter_mut().zip(raw.chunks_exact(2)) {
        *slot = f32_from_f16(u16::from_le_bytes([pair[0], pair[1]]));
    }
}

impl DataSource for BlockStore {
    fn name(&self) -> &str {
        &self.name
    }

    fn m(&self) -> usize {
        self.m
    }

    fn n(&self) -> usize {
        self.n
    }

    fn read_rows(&self, start: usize, out: &mut [f32]) {
        let n = self.n;
        assert_eq!(out.len() % n, 0, "read_rows: out shape");
        let rows = out.len() / n;
        assert!(start + rows <= self.m, "read_rows: range out of bounds");
        let mut row = start;
        let mut filled = 0usize;
        let fused = self.fused_f16_active();
        while filled < rows {
            let idx = row / self.block_rows;
            let within = row - idx * self.block_rows;
            let take = (self.block_rows - within).min(rows - filled);
            if fused {
                self.m_bypass.inc();
                self.with_raw_f16(idx, |bytes| {
                    widen_f16(
                        &bytes[within * n * 2..(within + take) * n * 2],
                        &mut out[filled * n..(filled + take) * n],
                    );
                });
            } else {
                let block = self.block(idx);
                out[filled * n..(filled + take) * n]
                    .copy_from_slice(&block[within * n..(within + take) * n]);
            }
            row += take;
            filled += take;
        }
    }

    fn sample_rows(&self, indices: &[usize], out: &mut [f32]) {
        let n = self.n;
        assert_eq!(out.len(), indices.len() * n, "sample_rows: out shape");
        if self.fused_f16_active() {
            // Decode-free gather: the raw f16 row is sliced straight off
            // the mapping, so there is no block Arc to hold. Count one
            // bypass per block *switch* to mirror the cache-lookup count
            // the decode path would have issued.
            let mut last: Option<usize> = None;
            for (slot, &i) in indices.iter().enumerate() {
                assert!(i < self.m, "sample_rows: row {i} out of bounds");
                let idx = i / self.block_rows;
                if last != Some(idx) {
                    self.m_bypass.inc();
                    last = Some(idx);
                }
                let within = i - idx * self.block_rows;
                self.with_raw_f16(idx, |bytes| {
                    widen_f16(
                        &bytes[within * n * 2..(within + 1) * n * 2],
                        &mut out[slot * n..(slot + 1) * n],
                    );
                });
            }
            return;
        }
        // Consecutive indices usually land in the same block (samplers
        // sort their draws for locality) — hold the last block across
        // iterations to skip even the cache lock.
        let mut held: Option<(usize, Arc<Vec<f32>>)> = None;
        for (slot, &i) in indices.iter().enumerate() {
            assert!(i < self.m, "sample_rows: row {i} out of bounds");
            let idx = i / self.block_rows;
            let block = match &held {
                Some((h, b)) if *h == idx => Arc::clone(b),
                _ => {
                    let b = self.block(idx);
                    held = Some((idx, Arc::clone(&b)));
                    b
                }
            };
            let within = i - idx * self.block_rows;
            out[slot * n..(slot + 1) * n]
                .copy_from_slice(&block[within * n..(within + 1) * n]);
        }
    }

    fn advise(&self, pattern: AccessPattern) {
        match &self.backing {
            #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
            Backing::Mmap(region) => region.advise(pattern.advice()),
            Backing::Pread(_) => {}
        }
    }

    fn block_summaries(&self) -> Option<BlockSummaries<'_>> {
        self.summaries.as_ref().map(|minmax| BlockSummaries {
            block_rows: self.block_rows,
            minmax: minmax.as_slice(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::store::format::StoreOptions;
    use crate::store::writer::copy_to_store;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bigmeans_store_source_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{name}", std::process::id()))
    }

    fn toy(m: usize, n: usize) -> Dataset {
        Dataset::from_vec(
            "toy",
            (0..m * n).map(|x| (x as f32) * 0.5 - 11.0).collect(),
            m,
            n,
        )
    }

    #[test]
    fn open_reads_geometry_without_touching_payload() {
        let d = toy(100, 4);
        let p = tmp("geom.bmx");
        let opts = StoreOptions { block_rows: 16, ..StoreOptions::default() };
        copy_to_store(&d, &p, opts).unwrap();
        let s = BlockStore::open(&p).unwrap();
        assert_eq!((s.m(), s.n()), (100, 4));
        assert_eq!(s.blocks(), 7);
        assert_eq!(s.block_rows(), 16);
        assert_eq!(s.cache_stats(), (0, 0));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn reads_cross_block_boundaries_and_hit_cache() {
        let d = toy(100, 4);
        let p = tmp("cross.bmx");
        let opts = StoreOptions { block_rows: 16, ..StoreOptions::default() };
        copy_to_store(&d, &p, opts).unwrap();
        for s in [BlockStore::open(&p).unwrap(), BlockStore::open_buffered(&p).unwrap()] {
            let mut out = vec![0f32; 40 * 4];
            s.read_rows(10, &mut out); // spans blocks 0..=3
            assert_eq!(out, &d.points()[10 * 4..50 * 4]);
            let (h0, m0) = s.cache_stats();
            assert_eq!(h0, 0);
            assert_eq!(m0, 4);
            s.read_rows(10, &mut out); // all warm now
            assert_eq!(s.cache_stats(), (4, 4));
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn gather_matches_dataset_on_every_backing() {
        let d = toy(333, 3);
        let p = tmp("gather.bmx");
        let opts = StoreOptions { block_rows: 32, ..StoreOptions::default() };
        copy_to_store(&d, &p, opts).unwrap();
        let idx = [0usize, 1, 31, 32, 33, 100, 100, 332, 5];
        let mut want = vec![0f32; idx.len() * 3];
        DataSource::sample_rows(&d, &idx, &mut want);
        for s in [BlockStore::open(&p).unwrap(), BlockStore::open_buffered(&p).unwrap()] {
            let mut got = vec![0f32; idx.len() * 3];
            s.sample_rows(&idx, &mut got);
            assert_eq!(got, want);
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn verify_all_passes_clean_and_names_corrupt_block() {
        let d = toy(200, 2);
        let p = tmp("verify.bmx");
        let opts = StoreOptions { block_rows: 20, ..StoreOptions::default() };
        copy_to_store(&d, &p, opts).unwrap();
        let s = BlockStore::open(&p).unwrap();
        let report = s.verify_all(2).unwrap();
        assert_eq!(report.blocks, 10);
        let (lo, _hi) = s.block_byte_range(6);
        drop(s);
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[lo as usize + 3] ^= 0x20;
        std::fs::write(&p, &bytes).unwrap();
        let s = BlockStore::open(&p).unwrap(); // open is O(index): still fine
        let err = s.verify_all(2).unwrap_err().to_string();
        assert!(err.contains("block 6"), "diagnostic must name the block: {err}");
        // A read that never touches block 6 stays clean.
        let mut row = vec![0f32; 2];
        s.read_rows(0, &mut row);
        assert_eq!(row, &d.points()[..2]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn corrupt_index_rejected_at_open() {
        let d = toy(64, 2);
        let p = tmp("index.bmx");
        // summaries: false keeps the index as the trailing section.
        let opts =
            StoreOptions { block_rows: 8, summaries: false, ..StoreOptions::default() };
        copy_to_store(&d, &p, opts).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let last = bytes.len() - 2; // inside the trailing index table
        bytes[last] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let err = BlockStore::open(&p).unwrap_err().to_string();
        assert!(err.contains("index checksum"), "unexpected error: {err}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn corrupt_summary_rejected_at_open() {
        let d = toy(64, 2);
        let p = tmp("summ.bmx");
        copy_to_store(&d, &p, StoreOptions { block_rows: 8, ..StoreOptions::default() })
            .unwrap();
        assert!(BlockStore::open(&p).unwrap().has_summaries());
        let mut bytes = std::fs::read(&p).unwrap();
        let last = bytes.len() - 2; // inside the trailing summary section
        bytes[last] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let err = BlockStore::open(&p).unwrap_err().to_string();
        assert!(err.contains("summary checksum"), "unexpected error: {err}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn wrong_length_summary_section_is_a_named_error() {
        let ok = parse_summaries(&[0u8; 2 * 2 * 2 * 4], 2, 2, "t").unwrap();
        assert_eq!(ok.len(), 2 * 2 * 2);
        for bad_len in [0usize, 3, 2 * 2 * 2 * 4 - 4, 2 * 2 * 2 * 4 + 1] {
            let raw = vec![0u8; bad_len];
            let err = parse_summaries(&raw, 2, 2, "t").unwrap_err().to_string();
            assert!(err.contains("wrong-length summary section"), "{err}");
        }
    }

    #[test]
    fn truncated_file_rejected_at_open() {
        let d = toy(64, 2);
        let p = tmp("trunc.bmx");
        copy_to_store(&d, &p, StoreOptions { block_rows: 8, ..StoreOptions::default() })
            .unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 40]).unwrap();
        assert!(BlockStore::open(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn fused_f16_reads_bit_match_decode_path_and_bypass_cache() {
        let d = toy(100, 5); // n = 5: every row widens through a ragged tail
        let p = tmp("fused.bmx");
        let opts =
            StoreOptions { block_rows: 16, dtype: Dtype::F16, ..StoreOptions::default() };
        copy_to_store(&d, &p, opts).unwrap();
        let fused = BlockStore::open(&p).unwrap();
        if !fused.is_mmap() {
            return; // no mmap on this target: the fused path cannot engage
        }
        assert!(fused.fused_f16_active());
        let decoded = BlockStore::open(&p).unwrap();
        decoded.set_fused_f16(false);
        assert!(!decoded.fused_f16_active());
        let mut a = vec![0f32; 40 * 5];
        let mut b = vec![0f32; 40 * 5];
        fused.read_rows(10, &mut a); // spans blocks 0..=3
        decoded.read_rows(10, &mut b);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a), bits(&b));
        // The fused store never touched the decoded-block cache; the
        // decode path populated it as always.
        assert_eq!(fused.cache_stats(), (0, 0));
        assert_ne!(decoded.cache_stats(), (0, 0));
        // Gather path, with repeats and block switches.
        let idx = [0usize, 1, 15, 16, 17, 50, 99, 99, 3];
        let mut ga = vec![0f32; idx.len() * 5];
        let mut gb = vec![0f32; idx.len() * 5];
        fused.sample_rows(&idx, &mut ga);
        decoded.sample_rows(&idx, &mut gb);
        assert_eq!(bits(&ga), bits(&gb));
        assert_eq!(fused.cache_stats(), (0, 0));
        // Re-enabling after a decode run flips the path back.
        decoded.set_fused_f16(true);
        assert!(decoded.fused_f16_active());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn fused_f16_requires_raw_codec_and_mmap() {
        let d = toy(64, 3);
        // f16 + shuffle: codec framing means no raw payload to slice.
        let p = tmp("fused_shuf.bmx");
        let opts = StoreOptions {
            block_rows: 16,
            dtype: Dtype::F16,
            codec: Codec::Shuffle,
            ..StoreOptions::default()
        };
        copy_to_store(&d, &p, opts).unwrap();
        let s = BlockStore::open(&p).unwrap();
        assert!(!s.fused_f16_active());
        s.set_fused_f16(true); // enabling an ineligible store is a no-op
        assert!(!s.fused_f16_active());
        let _ = std::fs::remove_file(&p);
        // f16 + raw, but buffered backing: pread cannot slice in place.
        let p = tmp("fused_pread.bmx");
        let opts =
            StoreOptions { block_rows: 16, dtype: Dtype::F16, ..StoreOptions::default() };
        copy_to_store(&d, &p, opts).unwrap();
        let s = BlockStore::open_buffered(&p).unwrap();
        assert!(!s.fused_f16_active());
        let _ = std::fs::remove_file(&p);
        // f32 + raw: nothing to widen.
        let p = tmp("fused_f32.bmx");
        copy_to_store(&d, &p, StoreOptions { block_rows: 16, ..StoreOptions::default() })
            .unwrap();
        let s = BlockStore::open(&p).unwrap();
        assert!(!s.fused_f16_active());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn fused_f16_read_of_corrupt_block_panics_with_block_index() {
        let d = toy(80, 2);
        let p = tmp("fused_panic.bmx");
        let opts =
            StoreOptions { block_rows: 16, dtype: Dtype::F16, ..StoreOptions::default() };
        copy_to_store(&d, &p, opts).unwrap();
        let s = BlockStore::open(&p).unwrap();
        if !s.is_mmap() {
            return;
        }
        let (lo, _) = s.block_byte_range(2);
        drop(s);
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[lo as usize] ^= 0x01;
        std::fs::write(&p, &bytes).unwrap();
        let s = BlockStore::open(&p).unwrap();
        assert!(s.fused_f16_active());
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut out = vec![0f32; 2];
            s.read_rows(40, &mut out); // row 40 lives in block 2
        }))
        .unwrap_err();
        let msg = caught
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "non-string panic".into());
        assert!(msg.contains("block 2"), "panic must name the block: {msg}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn read_of_corrupt_block_panics_with_block_index() {
        let d = toy(80, 2);
        let p = tmp("panic.bmx");
        let opts = StoreOptions { block_rows: 16, ..StoreOptions::default() };
        copy_to_store(&d, &p, opts).unwrap();
        let s = BlockStore::open(&p).unwrap();
        let (lo, _) = s.block_byte_range(2);
        drop(s);
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[lo as usize] ^= 0x01;
        std::fs::write(&p, &bytes).unwrap();
        let s = BlockStore::open(&p).unwrap();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut out = vec![0f32; 2];
            s.read_rows(40, &mut out); // row 40 lives in block 2
        }))
        .unwrap_err();
        let msg = caught
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "non-string panic".into());
        assert!(msg.contains("block 2"), "panic must name the block: {msg}");
        let _ = std::fs::remove_file(&p);
    }
}
