//! Block-level centroid pruning for the final full-dataset pass.
//!
//! The same triangle-inequality geometry the Elkan/Hamerly kernel engines
//! apply per *point* applies per *block*: every `.bmx` v3 block may carry a
//! per-dimension bounding box (the summary section, see
//! [`crate::store::format`]), and for a fixed centroid set the distance
//! from any point in the box to centroid `j` is bracketed by
//!
//! * `dmin(j)` — the distance from `c_j` to the box (0 if inside), and
//! * `dmax(j)` — the distance from `c_j` to the farthest box corner.
//!
//! If some centroid's `dmax` clears every other centroid's `dmin` — the
//! closest-centroid-to-box upper bound vs. the second-closest lower bound —
//! then **every** point of the block is strictly nearest that centroid, and
//! the final pass can label the whole block with a single-centroid distance
//! pass (`1` evaluation per point instead of `k`) without ever running the
//! k-wide scan. The comparison carries the same per-evaluation fp slack as
//! the kernel engines ([`crate::kernels::engine`]'s `eval_slack`), so a
//! pruned block can never disagree with the panel kernel: labels and the
//! objective stay bit-identical, enforced by `tests/store_v3.rs`.
//!
//! Degenerate centroids parked at `1e15` by the coordinator get a
//! *per-centroid* slack term, so their enormous norms inflate only their
//! own comparison (which they lose by ~30 orders of magnitude) instead of
//! disabling pruning globally.

use crate::kernels::engine::eval_slack;

/// The per-block pruning decision for one centroid set.
#[derive(Clone, Debug)]
pub struct PrunePlan {
    /// Rows per block (the geometry the decisions are indexed by).
    pub block_rows: usize,
    /// Per block: the owning centroid, or `None` when contested.
    pub owner: Vec<Option<u32>>,
}

impl PrunePlan {
    /// Number of blocks wholly owned by a single centroid.
    pub fn owned_blocks(&self) -> usize {
        self.owner.iter().filter(|o| o.is_some()).count()
    }

    /// Owner of the block containing `row`, if any.
    pub fn owner_of_row(&self, row: usize) -> Option<u32> {
        self.owner.get(row / self.block_rows).copied().flatten()
    }
}

/// Classify every block of a summary section against `centroids`
/// (row-major `(k, n)`). `minmax` holds `2n` values per block — `n` mins
/// then `n` maxs, as stored in the `.bmx` v3 summary section.
pub fn plan(
    minmax: &[f32],
    n: usize,
    block_rows: usize,
    centroids: &[f32],
    k: usize,
) -> PrunePlan {
    assert!(n > 0 && block_rows > 0 && k > 0, "prune: degenerate geometry");
    assert_eq!(minmax.len() % (2 * n), 0, "prune: summary shape");
    assert_eq!(centroids.len(), k * n, "prune: centroid shape");
    let nblocks = minmax.len() / (2 * n);
    let slack_factor = eval_slack(n);
    let c_sq: Vec<f64> = (0..k)
        .map(|j| {
            centroids[j * n..(j + 1) * n]
                .iter()
                .map(|&c| (c as f64) * (c as f64))
                .sum()
        })
        .collect();
    let mut owner = Vec::with_capacity(nblocks);
    let mut dmin = vec![0f64; k];
    let mut dmax = vec![0f64; k];
    for b in 0..nblocks {
        let lo = &minmax[b * 2 * n..b * 2 * n + n];
        let hi = &minmax[b * 2 * n + n..(b + 1) * 2 * n];
        owner.push(classify(lo, hi, centroids, &c_sq, k, n, slack_factor, &mut dmin, &mut dmax));
    }
    PrunePlan { block_rows, owner }
}

/// Decide one block: `Some(j)` when centroid `j` strictly wins every point
/// of the box `[lo, hi]` under the kernel engines' fp-slack model.
#[allow(clippy::too_many_arguments)]
fn classify(
    lo: &[f32],
    hi: &[f32],
    centroids: &[f32],
    c_sq: &[f64],
    k: usize,
    n: usize,
    slack_factor: f64,
    dmin: &mut [f64],
    dmax: &mut [f64],
) -> Option<u32> {
    // An empty/invalid box (all-NaN dimension keeps the ±∞ sentinels, or a
    // corrupt summary) is never prunable.
    if lo.iter().zip(hi).any(|(&l, &h)| !(l <= h)) {
        return None;
    }
    // Largest ‖x‖² inside the box — the box-wide analogue of the kernels'
    // per-point slack scale.
    let x_sq_max: f64 = lo
        .iter()
        .zip(hi)
        .map(|(&l, &h)| {
            let l = l as f64;
            let h = h as f64;
            (l * l).max(h * h)
        })
        .sum();
    let mut best = 0usize;
    for j in 0..k {
        let mut near = 0f64;
        let mut far = 0f64;
        let c = &centroids[j * n..(j + 1) * n];
        for d in 0..n {
            let cv = c[d] as f64;
            let l = lo[d] as f64;
            let h = hi[d] as f64;
            let gap = if cv < l {
                l - cv
            } else if cv > h {
                cv - h
            } else {
                0.0
            };
            near += gap * gap;
            let span = (cv - l).abs().max((h - cv).abs());
            far += span * span;
        }
        dmin[j] = near;
        dmax[j] = far;
        if far < dmax[best] {
            best = j;
        }
    }
    // Owned iff the candidate's farthest corner strictly clears every
    // other centroid's nearest approach, with both evaluations' slack
    // bands added (per-centroid, so a parked degenerate only inflates its
    // own — comfortably losing — comparison).
    let own_slack = (x_sq_max + c_sq[best]) * slack_factor;
    for j in 0..k {
        if j == best {
            continue;
        }
        let other_slack = (x_sq_max + c_sq[j]) * slack_factor;
        if dmax[best] + own_slack + other_slack >= dmin[j] {
            return None;
        }
    }
    Some(best as u32)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// mins then maxs for one block.
    fn mm(lo: &[f32], hi: &[f32]) -> Vec<f32> {
        let mut v = lo.to_vec();
        v.extend_from_slice(hi);
        v
    }

    #[test]
    fn tight_box_near_one_centroid_is_owned() {
        // Box around (0, 0); centroids at the origin and far away.
        let minmax = mm(&[-0.5, -0.5], &[0.5, 0.5]);
        let centroids = vec![0.0f32, 0.0, 100.0, 100.0];
        let p = plan(&minmax, 2, 8, &centroids, 2);
        assert_eq!(p.owner, vec![Some(0)]);
        assert_eq!(p.owned_blocks(), 1);
        assert_eq!(p.owner_of_row(3), Some(0));
        assert_eq!(p.owner_of_row(8), None); // past the only block
    }

    #[test]
    fn box_straddling_the_midline_is_contested() {
        // Box spans the bisector between the two centroids.
        let minmax = mm(&[-10.0, -1.0], &[10.0, 1.0]);
        let centroids = vec![-5.0f32, 0.0, 5.0, 0.0];
        let p = plan(&minmax, 2, 8, &centroids, 2);
        assert_eq!(p.owner, vec![None]);
        assert_eq!(p.owned_blocks(), 0);
    }

    #[test]
    fn parked_degenerate_centroid_does_not_block_pruning() {
        // Third centroid parked at the coordinator's 1e15 sentinel: its own
        // slack is huge but so is its distance — block stays owned.
        let minmax = mm(&[-0.5, -0.5], &[0.5, 0.5]);
        let centroids = vec![0.0f32, 0.0, 100.0, 100.0, 1.0e15, 1.0e15];
        let p = plan(&minmax, 2, 8, &centroids, 3);
        assert_eq!(p.owner, vec![Some(0)]);
    }

    #[test]
    fn near_tie_respects_slack_and_stays_contested() {
        // dmax(best) barely below dmin(other): the slack band must veto.
        let minmax = mm(&[-1.0, 0.0], &[-0.999_999, 0.0]);
        let centroids = vec![-2.0f32, 0.0, 0.0, 0.0]; // bisector at x = -1
        let p = plan(&minmax, 2, 8, &centroids, 2);
        assert_eq!(p.owner, vec![None]);
    }

    #[test]
    fn multiple_blocks_classified_independently() {
        let mut minmax = mm(&[-0.5, -0.5], &[0.5, 0.5]); // block 0 → centroid 0
        minmax.extend(mm(&[99.5, 99.5], &[100.5, 100.5])); // block 1 → centroid 1
        minmax.extend(mm(&[-10.0, -10.0], &[110.0, 110.0])); // block 2 contested
        let centroids = vec![0.0f32, 0.0, 100.0, 100.0];
        let p = plan(&minmax, 2, 4, &centroids, 2);
        assert_eq!(p.owner, vec![Some(0), Some(1), None]);
        assert_eq!(p.owner_of_row(0), Some(0));
        assert_eq!(p.owner_of_row(5), Some(1));
        assert_eq!(p.owner_of_row(9), None);
    }

    #[test]
    fn nan_summary_never_prunes() {
        let minmax = mm(&[f32::INFINITY, -0.5], &[f32::NEG_INFINITY, 0.5]);
        let centroids = vec![0.0f32, 0.0, 100.0, 100.0];
        let p = plan(&minmax, 2, 8, &centroids, 2);
        assert_eq!(p.owner, vec![None]);
    }
}
