//! The block-store storage engine: chunked `.bmx` **version 3**.
//!
//! The paper's thesis is decomposition — Big-means never needs more than a
//! bounded chunk of the dataset at once. v1/v2 `.bmx` decomposed the
//! *compute* but kept the *storage* monolithic: one flat f32 payload with
//! one whole-file CRC (O(file) to check, skipped above 4 GiB). v3
//! decomposes the storage the same way the algorithm decomposes the
//! problem: rows are grouped into fixed-size **blocks**, each independently
//! encoded (dtype conversion + optional codec) and independently
//! checksummed, with a trailing block-index table. Consequences:
//!
//! * **O(touched blocks) integrity** — opening validates header + index
//!   only; each block's CRC-32 is checked the first time that block is
//!   decoded. The v2 4 GiB eager-verify cap is retired: integrity cost now
//!   scales with what a run actually reads, not with file size.
//! * **Dtype variants** — payloads may be stored as `f32` (exact), `f64`
//!   (exact for f32 inputs), or `f16` (half footprint, quantised), always
//!   decoded to `f32` at the block boundary, using the v2 header's
//!   reserved dtype-tag idea for real.
//! * **Codecs** — per-block `none` | `shuffle` (byte transpose) | `lz`
//!   (shuffle + the homegrown LZ77 in [`crate::util::lz`]), all
//!   dependency-free.
//! * **Append-friendly ingest** — [`BlockWriter`] streams blocks out as
//!   rows arrive (per-block encode/CRC parallelised on the
//!   [`crate::util::threadpool::ThreadPool`]) and writes the index last,
//!   which is exactly the shape a streaming producer needs.
//! * **Warm sampling** — [`BlockStore`] keeps an LRU cache of *decoded*
//!   blocks ([`cache::BlockCache`]), so random chunk sampling pays
//!   decode + CRC once per block, not once per row.
//!
//! # On-disk layout (all little-endian)
//!
//! ```text
//! offset  size   field
//! 0       4      magic        b"BMX3" ("BMX" + ASCII version byte)
//! 4       8      m            u64  number of rows
//! 12      4      n            u32  features per row
//! 16      4      block_rows   u32  rows per block (last block may be short)
//! 20      1      dtype        u8   0 = f32 | 1 = f64 | 2 = f16
//! 21      1      codec        u8   0 = none | 1 = shuffle | 2 = lz
//! 22      2      reserved     zeroed
//! 24      8      index_off    u64  byte offset of the block-index table
//! 32      4      index_crc    u32  CRC-32 of the index-table bytes
//! 36      8      summary_off  u64  byte offset of the per-block min/max
//!                                  summary section (0 = absent)
//! 44      4      summary_crc  u32  CRC-32 of the summary-section bytes
//! 48      16     reserved     zeroed
//! 64      …      blocks       encoded blocks, back to back
//! index_off …    index        one 24-byte entry per block:
//!                               offset u64 | enc_len u64 | crc u32 | pad u32
//! summary_off …  summaries    per block: n × f32 min, then n × f32 max
//!                              (8·n bytes per block, decoded-value domain)
//! ```
//!
//! Block `i` holds rows `[i·block_rows, min(m, (i+1)·block_rows))`; its
//! encoded bytes are `codec(dtype(rows))` and `crc` covers the **encoded**
//! bytes, so verification never pays a decode it can skip. The index is
//! written last (patching `index_off`/`index_crc`/`m` into the header on
//! finish), keeping the writer single-pass.
//!
//! The **summary section** is the 2026 extension enabling the
//! centroid-pruned final pass ([`prune`]): per block, each dimension's
//! min/max over the *decoded* values (for `f16` that is the quantised
//! domain, so the bounds hold for everything a reader sees). It is
//! version-tolerant in both directions — the fields live in previously
//! zeroed reserved header bytes, so pre-extension readers ignore the
//! section (it sits past the index they stop at) and pre-extension files
//! decode as `summary_off = 0` = "no summaries". `bigmeans convert
//! --add-summaries` retrofits the section onto an existing file in place
//! (decode-only — blocks are never re-encoded), and `bigmeans verify`
//! cross-checks every stored summary against its block's decoded values.
//!
//! # Layering
//!
//! ```text
//! coordinators / tuner / streaming      (unchanged — they see DataSource)
//!         │
//! data::source::DataSource              read_rows / sample_rows / advise
//!         │
//! store::BlockStore                     block math + LRU BlockCache
//!         │            └── cache::BlockCache   decoded-block LRU
//! store::codec                          dtype ⇄ f32, shuffle, lz
//!         │            └── util::lz, util::half
//! store::format                         header / index encode-decode
//!         │
//! util::mem::MmapRegion | pread         raw bytes
//! ```
//!
//! Legacy v1/v2 files keep loading through [`crate::data::bmx`]; the
//! loader sniffs the magic and routes each file to the right reader. For
//! f32 payloads every codec is bit-lossless, so a seeded run through a
//! block store reproduces the in-memory run bit-for-bit (asserted in
//! `tests/store_v3.rs`).

pub mod cache;
pub mod codec;
pub mod format;
pub mod prune;
pub mod source;
pub mod writer;

pub use cache::{BlockCache, DEFAULT_CACHE_BYTES};
pub use format::{Codec, Dtype, StoreOptions, BMX3_MAGIC, DEFAULT_BLOCK_ROWS};
pub use prune::PrunePlan;
pub use source::{BlockStore, VerifyReport};
pub use writer::{add_summaries, copy_to_store, BlockWriter};
