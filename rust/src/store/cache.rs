//! LRU cache of decoded blocks.
//!
//! Chunk sampling draws scattered rows, and with blocks of a few thousand
//! rows a sample of size `s` touches at most `s` blocks — usually far
//! fewer once sampling revisits hot regions. Caching the *decoded* f32
//! blocks means a warm block costs one `memcpy` per row instead of a
//! read + CRC + codec + dtype pass.
//!
//! The cache is a plain `Mutex<HashMap>` with logical clock stamps and
//! scan-for-oldest eviction: block counts are modest (a 4 GiB store at
//! the default 4096×16 geometry has ~16k blocks, of which only the
//! resident fraction is in the map), so O(resident) eviction is cheaper
//! than maintaining an intrusive list — and the lock is held only for
//! map bookkeeping, never for decoding.
//!
//! Caching never changes served values (decoded blocks are immutable
//! `Arc`s), so the backend determinism contract is preserved by
//! construction.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::obs;

/// Default decoded-block budget (bytes).
pub const DEFAULT_CACHE_BYTES: usize = 128 << 20;

struct Slot {
    data: Arc<Vec<f32>>,
    stamp: u64,
}

struct CacheState {
    map: HashMap<usize, Slot>,
    clock: u64,
    resident_bytes: usize,
    hits: u64,
    misses: u64,
}

/// Thread-safe LRU over decoded blocks, keyed by block index.
pub struct BlockCache {
    inner: Mutex<CacheState>,
    cap_bytes: usize,
    m_hits: obs::Counter,
    m_misses: obs::Counter,
    m_resident: obs::Gauge,
}

impl BlockCache {
    /// A cache holding up to `cap_bytes` of decoded f32 data. A single
    /// block larger than the budget is still admitted (the budget then
    /// holds exactly that block).
    pub fn new(cap_bytes: usize) -> Self {
        let m = obs::metrics();
        BlockCache {
            inner: Mutex::new(CacheState {
                map: HashMap::new(),
                clock: 0,
                resident_bytes: 0,
                hits: 0,
                misses: 0,
            }),
            cap_bytes,
            m_hits: m.counter(
                "bigmeans_block_cache_hits_total",
                "Decoded-block cache lookups answered from memory",
                &[],
            ),
            m_misses: m.counter(
                "bigmeans_block_cache_misses_total",
                "Decoded-block cache lookups that required a block decode",
                &[],
            ),
            m_resident: m.gauge(
                "bigmeans_block_cache_resident_bytes",
                "Decoded f32 bytes currently held by the block cache",
                &[],
            ),
        }
    }

    /// Look up a decoded block, refreshing its recency on hit.
    pub fn get(&self, block: usize) -> Option<Arc<Vec<f32>>> {
        let mut st = self.inner.lock().unwrap();
        st.clock += 1;
        let stamp = st.clock;
        let hit = st.map.get_mut(&block).map(|slot| {
            slot.stamp = stamp;
            Arc::clone(&slot.data)
        });
        match &hit {
            Some(_) => st.hits += 1,
            None => st.misses += 1,
        }
        drop(st);
        match &hit {
            Some(_) => self.m_hits.inc(),
            None => self.m_misses.inc(),
        }
        hit
    }

    /// Insert a freshly decoded block, evicting least-recently-used
    /// entries until the budget holds. Inserting an already-present block
    /// (two threads decoded it concurrently) just refreshes it.
    pub fn insert(&self, block: usize, data: Arc<Vec<f32>>) {
        let bytes = data.len() * std::mem::size_of::<f32>();
        let mut st = self.inner.lock().unwrap();
        st.clock += 1;
        let stamp = st.clock;
        if let Some(slot) = st.map.get_mut(&block) {
            slot.stamp = stamp;
            return;
        }
        while !st.map.is_empty() && st.resident_bytes + bytes > self.cap_bytes {
            let oldest = st
                .map
                .iter()
                .min_by_key(|(_, slot)| slot.stamp)
                .map(|(&k, _)| k)
                .expect("non-empty map has a minimum");
            if let Some(evicted) = st.map.remove(&oldest) {
                st.resident_bytes -= evicted.data.len() * std::mem::size_of::<f32>();
            }
        }
        st.resident_bytes += bytes;
        st.map.insert(block, Slot { data, stamp });
        let resident = st.resident_bytes;
        drop(st);
        self.m_resident.set(resident as f64);
    }

    /// `(hits, misses)` since creation.
    pub fn stats(&self) -> (u64, u64) {
        let st = self.inner.lock().unwrap();
        (st.hits, st.misses)
    }

    /// Blocks currently resident.
    pub fn resident(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// Decoded bytes currently resident.
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().resident_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block(v: f32, len: usize) -> Arc<Vec<f32>> {
        Arc::new(vec![v; len])
    }

    #[test]
    fn hit_miss_accounting() {
        let c = BlockCache::new(1 << 20);
        assert!(c.get(0).is_none());
        c.insert(0, block(1.0, 8));
        assert_eq!(c.get(0).unwrap()[0], 1.0);
        assert_eq!(c.stats(), (1, 1));
        assert_eq!(c.resident(), 1);
        assert_eq!(c.resident_bytes(), 32);
    }

    #[test]
    fn lru_eviction_order() {
        // Budget fits two 100-element blocks (400 bytes each).
        let c = BlockCache::new(800);
        c.insert(0, block(0.0, 100));
        c.insert(1, block(1.0, 100));
        assert!(c.get(0).is_some()); // 0 is now the most recent
        c.insert(2, block(2.0, 100)); // evicts 1 (oldest)
        assert!(c.get(1).is_none());
        assert!(c.get(0).is_some());
        assert!(c.get(2).is_some());
        assert_eq!(c.resident(), 2);
    }

    #[test]
    fn oversized_block_still_admitted() {
        let c = BlockCache::new(16);
        c.insert(0, block(9.0, 1000));
        assert!(c.get(0).is_some());
        assert_eq!(c.resident(), 1);
        // The next insert evicts it (budget can't hold both).
        c.insert(1, block(1.0, 1000));
        assert!(c.get(0).is_none());
        assert!(c.get(1).is_some());
    }

    #[test]
    fn duplicate_insert_refreshes_without_double_counting() {
        let c = BlockCache::new(1 << 10);
        c.insert(0, block(1.0, 10));
        c.insert(0, block(1.0, 10));
        assert_eq!(c.resident(), 1);
        assert_eq!(c.resident_bytes(), 40);
    }
}
