//! The `.bmx` v3 on-disk geometry: header, dtype/codec tags, and the
//! trailing block-index table. See [`crate::store`] for the full layout.
//!
//! Everything here is pure byte-level encode/decode with checked
//! arithmetic — a corrupt or hostile header fails with a clean error at
//! open time instead of wrapping or panicking later.

use crate::util::error::Result;
use crate::{anyhow, bail};

/// v3 file magic: "BMX" + ASCII version byte.
pub const BMX3_MAGIC: [u8; 4] = *b"BMX3";

/// Header bytes before the first block.
pub const BMX3_HEADER_LEN: usize = 64;

/// Bytes per block-index entry (offset u64 | encoded length u64 | CRC-32
/// u32 | reserved u32).
pub const BLOCK_ENTRY_LEN: usize = 24;

/// Bytes per dimension in the optional per-block summary section (one f32
/// min + one f32 max).
pub const SUMMARY_DIM_LEN: usize = 8;

/// Default rows per block (≈ one chunk of the paper's default `s`).
pub const DEFAULT_BLOCK_ROWS: usize = 4096;

/// On-disk element type of the payload. Every dtype decodes to `f32` at
/// the block boundary; `F32` and `F64` are lossless for f32 inputs, `F16`
/// trades precision for half the footprint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    F64,
    F16,
}

impl Dtype {
    /// Bytes per stored element.
    pub fn width(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F64 => 8,
            Dtype::F16 => 2,
        }
    }

    /// Header tag byte.
    pub fn tag(self) -> u8 {
        match self {
            Dtype::F32 => 0,
            Dtype::F64 => 1,
            Dtype::F16 => 2,
        }
    }

    pub fn from_tag(tag: u8) -> Option<Dtype> {
        match tag {
            0 => Some(Dtype::F32),
            1 => Some(Dtype::F64),
            2 => Some(Dtype::F16),
            _ => None,
        }
    }

    /// Parse a CLI token (`f32` / `f64` / `f16`).
    pub fn parse(s: &str) -> Option<Dtype> {
        match s {
            "f32" => Some(Dtype::F32),
            "f64" => Some(Dtype::F64),
            "f16" => Some(Dtype::F16),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::F64 => "f64",
            Dtype::F16 => "f16",
        }
    }
}

/// Per-block codec applied to the dtype-encoded bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    /// Raw dtype bytes.
    None,
    /// Byte-transpose shuffle: lane `j` of every element stored
    /// contiguously. Same size, but groups the slowly-varying high bytes —
    /// the enabling transform for `Lz` (and for downstream compression by
    /// the filesystem or transport).
    Shuffle,
    /// Shuffle followed by the homegrown LZ77 codec
    /// ([`crate::util::lz`]).
    Lz,
}

impl Codec {
    pub fn tag(self) -> u8 {
        match self {
            Codec::None => 0,
            Codec::Shuffle => 1,
            Codec::Lz => 2,
        }
    }

    pub fn from_tag(tag: u8) -> Option<Codec> {
        match tag {
            0 => Some(Codec::None),
            1 => Some(Codec::Shuffle),
            2 => Some(Codec::Lz),
            _ => None,
        }
    }

    /// Parse a CLI token (`none` / `shuffle` / `lz`).
    pub fn parse(s: &str) -> Option<Codec> {
        match s {
            "none" => Some(Codec::None),
            "shuffle" => Some(Codec::Shuffle),
            "lz" => Some(Codec::Lz),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Codec::None => "none",
            Codec::Shuffle => "shuffle",
            Codec::Lz => "lz",
        }
    }
}

/// Knobs for writing a v3 store.
#[derive(Clone, Copy, Debug)]
pub struct StoreOptions {
    /// Rows per block (the last block may be shorter).
    pub block_rows: usize,
    /// On-disk element type.
    pub dtype: Dtype,
    /// Per-block codec.
    pub codec: Codec,
    /// Write the per-block per-dimension min/max summary section (enables
    /// the centroid-pruned final pass; `convert --add-summaries` can
    /// retrofit it).
    pub summaries: bool,
    /// Encode worker threads (0 = machine default).
    pub threads: usize,
}

impl Default for StoreOptions {
    fn default() -> Self {
        StoreOptions {
            block_rows: DEFAULT_BLOCK_ROWS,
            dtype: Dtype::F32,
            codec: Codec::None,
            summaries: true,
            threads: 0,
        }
    }
}

/// One row of the trailing block-index table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockEntry {
    /// Absolute byte offset of the encoded block.
    pub offset: u64,
    /// Encoded (post-codec) byte length.
    pub enc_len: u64,
    /// CRC-32 of the encoded bytes.
    pub crc: u32,
}

impl BlockEntry {
    pub fn encode(&self) -> [u8; BLOCK_ENTRY_LEN] {
        let mut out = [0u8; BLOCK_ENTRY_LEN];
        out[0..8].copy_from_slice(&self.offset.to_le_bytes());
        out[8..16].copy_from_slice(&self.enc_len.to_le_bytes());
        out[16..20].copy_from_slice(&self.crc.to_le_bytes());
        out
    }

    pub fn decode(bytes: &[u8]) -> BlockEntry {
        debug_assert_eq!(bytes.len(), BLOCK_ENTRY_LEN);
        BlockEntry {
            offset: u64::from_le_bytes(bytes[0..8].try_into().unwrap()),
            enc_len: u64::from_le_bytes(bytes[8..16].try_into().unwrap()),
            crc: u32::from_le_bytes(bytes[16..20].try_into().unwrap()),
        }
    }
}

/// Parsed (and size-validated) v3 header.
#[derive(Clone, Copy, Debug)]
pub struct V3Header {
    pub m: u64,
    pub n: u32,
    pub block_rows: u32,
    pub dtype: Dtype,
    pub codec: Codec,
    /// Absolute byte offset of the block-index table.
    pub index_off: u64,
    /// CRC-32 of the index-table bytes.
    pub index_crc: u32,
    /// Absolute byte offset of the optional per-block min/max summary
    /// section (0 = absent — the pre-summary v3 layout; readers treat
    /// those files exactly as before).
    pub summary_off: u64,
    /// CRC-32 of the summary-section bytes (meaningless when
    /// `summary_off == 0`).
    pub summary_crc: u32,
}

impl V3Header {
    /// Number of blocks the geometry implies.
    pub fn blocks(&self) -> u64 {
        if self.m == 0 {
            0
        } else {
            self.m.div_ceil(self.block_rows as u64)
        }
    }

    /// Bytes the summary section occupies for this geometry.
    pub fn summary_len(&self) -> u64 {
        self.blocks() * (self.n as u64) * (SUMMARY_DIM_LEN as u64)
    }

    pub fn encode(&self) -> [u8; BMX3_HEADER_LEN] {
        let mut out = [0u8; BMX3_HEADER_LEN];
        out[0..4].copy_from_slice(&BMX3_MAGIC);
        out[4..12].copy_from_slice(&self.m.to_le_bytes());
        out[12..16].copy_from_slice(&self.n.to_le_bytes());
        out[16..20].copy_from_slice(&self.block_rows.to_le_bytes());
        out[20] = self.dtype.tag();
        out[21] = self.codec.tag();
        out[24..32].copy_from_slice(&self.index_off.to_le_bytes());
        out[32..36].copy_from_slice(&self.index_crc.to_le_bytes());
        out[36..44].copy_from_slice(&self.summary_off.to_le_bytes());
        out[44..48].copy_from_slice(&self.summary_crc.to_le_bytes());
        out
    }

    /// Decode and sanity-check a header block (`label` names the file in
    /// errors). Geometry limits are enforced here so downstream usize
    /// arithmetic cannot overflow.
    pub fn decode(bytes: &[u8], label: &str) -> Result<V3Header> {
        if bytes.len() < BMX3_HEADER_LEN {
            bail!("{label}: truncated .bmx v3 header ({} bytes)", bytes.len());
        }
        if bytes[0..4] != BMX3_MAGIC {
            bail!("{label}: not a .bmx v3 file (bad magic)");
        }
        let m = u64::from_le_bytes(bytes[4..12].try_into().unwrap());
        let n = u32::from_le_bytes(bytes[12..16].try_into().unwrap());
        let block_rows = u32::from_le_bytes(bytes[16..20].try_into().unwrap());
        let dtype = Dtype::from_tag(bytes[20])
            .ok_or_else(|| anyhow!("{label}: unknown dtype tag {}", bytes[20]))?;
        let codec = Codec::from_tag(bytes[21])
            .ok_or_else(|| anyhow!("{label}: unknown codec tag {}", bytes[21]))?;
        let index_off = u64::from_le_bytes(bytes[24..32].try_into().unwrap());
        let index_crc = u32::from_le_bytes(bytes[32..36].try_into().unwrap());
        // Summary extension (2026): files written before it carry zeroed
        // reserved bytes here, which decode as "no summaries" — the
        // version-tolerant read path.
        let summary_off = u64::from_le_bytes(bytes[36..44].try_into().unwrap());
        let summary_crc = u32::from_le_bytes(bytes[44..48].try_into().unwrap());
        if n == 0 {
            bail!("{label}: bmx v3 header has n = 0");
        }
        if block_rows == 0 {
            bail!("{label}: bmx v3 header has block_rows = 0");
        }
        if m > u64::MAX / 2 || m.checked_mul(n as u64).is_none() {
            bail!("{label}: bmx v3 shape {m}×{n} not addressable");
        }
        // Largest decoded block must fit comfortably in usize arithmetic.
        (block_rows as u64)
            .checked_mul(n as u64)
            .and_then(|c| c.checked_mul(8))
            .filter(|&c| c <= usize::MAX as u64 / 4)
            .ok_or_else(|| {
                anyhow!("{label}: block geometry {block_rows}×{n} overflows")
            })?;
        Ok(V3Header {
            m,
            n,
            block_rows,
            dtype,
            codec,
            index_off,
            index_crc,
            summary_off,
            summary_crc,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip() {
        let h = V3Header {
            m: 123_456,
            n: 17,
            block_rows: 4096,
            dtype: Dtype::F16,
            codec: Codec::Lz,
            index_off: 0xDEAD_BEEF,
            index_crc: 0x1234_5678,
            summary_off: 0xFEED_F00D,
            summary_crc: 0x9ABC_DEF0,
        };
        let enc = h.encode();
        let back = V3Header::decode(&enc, "t").unwrap();
        assert_eq!(back.m, h.m);
        assert_eq!(back.n, h.n);
        assert_eq!(back.block_rows, h.block_rows);
        assert_eq!(back.dtype, h.dtype);
        assert_eq!(back.codec, h.codec);
        assert_eq!(back.index_off, h.index_off);
        assert_eq!(back.index_crc, h.index_crc);
        assert_eq!(back.summary_off, h.summary_off);
        assert_eq!(back.summary_crc, h.summary_crc);
        assert_eq!(back.blocks(), 123_456u64.div_ceil(4096));
        assert_eq!(back.summary_len(), back.blocks() * 17 * SUMMARY_DIM_LEN as u64);
    }

    #[test]
    fn zeroed_summary_fields_decode_as_absent() {
        // The pre-summary layout: reserved bytes 36..48 were zeroed.
        let mut h = V3Header {
            m: 100,
            n: 4,
            block_rows: 16,
            dtype: Dtype::F32,
            codec: Codec::None,
            index_off: 64,
            index_crc: 7,
            summary_off: 0,
            summary_crc: 0,
        };
        let back = V3Header::decode(&h.encode(), "t").unwrap();
        assert_eq!(back.summary_off, 0);
        h.summary_off = 9999;
        let back = V3Header::decode(&h.encode(), "t").unwrap();
        assert_eq!(back.summary_off, 9999);
    }

    #[test]
    fn entry_roundtrip() {
        let e = BlockEntry { offset: 64, enc_len: 99_999, crc: 0xCAFE_F00D };
        assert_eq!(BlockEntry::decode(&e.encode()), e);
    }

    #[test]
    fn hostile_headers_rejected() {
        let good = V3Header {
            m: 10,
            n: 2,
            block_rows: 4,
            dtype: Dtype::F32,
            codec: Codec::None,
            index_off: 64,
            index_crc: 0,
            summary_off: 0,
            summary_crc: 0,
        };
        let mut bad_magic = good.encode();
        bad_magic[3] = b'9';
        assert!(V3Header::decode(&bad_magic, "t").is_err());
        let mut zero_n = good.encode();
        zero_n[12..16].copy_from_slice(&0u32.to_le_bytes());
        assert!(V3Header::decode(&zero_n, "t").is_err());
        let mut zero_block = good.encode();
        zero_block[16..20].copy_from_slice(&0u32.to_le_bytes());
        assert!(V3Header::decode(&zero_block, "t").is_err());
        let mut bad_dtype = good.encode();
        bad_dtype[20] = 9;
        assert!(V3Header::decode(&bad_dtype, "t").is_err());
        let mut bad_codec = good.encode();
        bad_codec[21] = 9;
        assert!(V3Header::decode(&bad_codec, "t").is_err());
        let mut huge = good.encode();
        huge[4..12].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(V3Header::decode(&huge, "t").is_err());
        assert!(V3Header::decode(&good.encode()[..32], "t").is_err());
    }

    #[test]
    fn tags_and_tokens_roundtrip() {
        for d in [Dtype::F32, Dtype::F64, Dtype::F16] {
            assert_eq!(Dtype::from_tag(d.tag()), Some(d));
            assert_eq!(Dtype::parse(d.name()), Some(d));
        }
        for c in [Codec::None, Codec::Shuffle, Codec::Lz] {
            assert_eq!(Codec::from_tag(c.tag()), Some(c));
            assert_eq!(Codec::parse(c.name()), Some(c));
        }
        assert_eq!(Dtype::parse("f8"), None);
        assert_eq!(Codec::parse("zstd"), None);
    }
}
