//! A homegrown byte-oriented LZ77/LZSS codec — the `lz` block codec of the
//! `.bmx` v3 store, dependency-free by construction.
//!
//! Format: a sequence of groups, each a *flags* byte followed by eight
//! items (fewer in the final group). Flag bit `b` (LSB first) describes
//! item `b`:
//!
//! * `0` — a literal: one raw byte;
//! * `1` — a match: three bytes — `u16` LE back-distance (1..=65535 into
//!   the already-decoded output) and `u8` length-minus-4 (match lengths
//!   4..=259). Matches may self-overlap (RLE falls out naturally).
//!
//! The stream carries no decoded-length field of its own: block stores
//! know every block's decoded size from the header geometry, so
//! [`decompress`] takes the expected output length and validates the
//! stream against it — a corrupt or truncated stream fails with a clear
//! error instead of producing a silently short block.
//!
//! The compressor is a single-pass **hash-chain** matcher with one-step
//! **lazy matching**: every position is threaded into a per-bucket chain
//! of prior occurrences (up to [`CHAIN_LIMIT`] candidates examined, best
//! length wins, nearer candidate on ties), and before a match is emitted
//! the next position is probed — when it starts a strictly longer match,
//! one literal is emitted instead and the longer match taken. On
//! byte-shuffled float payloads this buys 10–20 % over the previous
//! greedy single-candidate matcher while leaving the stream format (and
//! [`decompress`]) untouched. Worst case the output is `9/8 · len + 1`
//! bytes (all literals); block stores record the encoded length per
//! block, so incompressible data is handled, never rejected.

use crate::bail;
use crate::util::error::Result;

/// Shortest encodable match.
const MIN_MATCH: usize = 4;

/// Longest encodable match (`u8` length field + [`MIN_MATCH`]).
const MAX_MATCH: usize = 255 + MIN_MATCH;

/// Largest encodable back-distance (`u16` field; 0 is invalid).
const MAX_DISTANCE: usize = u16::MAX as usize;

const HASH_BITS: u32 = 15;

/// Chain candidates examined per probe. Bounds worst-case compress time;
/// raising it trades speed for ratio.
const CHAIN_LIMIT: usize = 48;

/// Chain terminator (also the "never seen" head value).
const NIL: u32 = u32::MAX;

#[inline]
fn hash4(bytes: &[u8]) -> usize {
    let v = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

/// Hash-chain state: `head[h]` is the most recent position with hash `h`,
/// `prev[p]` the next-older position sharing `p`'s hash.
struct Chains {
    head: Vec<u32>,
    prev: Vec<u32>,
    /// Next position to thread into the chains (positions are inserted in
    /// strictly increasing order, exactly once).
    ins: usize,
}

impl Chains {
    fn new(len: usize) -> Chains {
        Chains { head: vec![NIL; 1 << HASH_BITS], prev: vec![NIL; len], ins: 0 }
    }

    /// Thread every position `< upto` into the chains.
    fn insert_below(&mut self, upto: usize, input: &[u8]) {
        let stop = upto.min(input.len().saturating_sub(MIN_MATCH - 1));
        while self.ins < stop {
            let h = hash4(&input[self.ins..]);
            self.prev[self.ins] = self.head[h];
            self.head[h] = self.ins as u32;
            self.ins += 1;
        }
        self.ins = self.ins.max(upto.min(input.len()));
    }

    /// Best match starting at `pos` among up to [`CHAIN_LIMIT`] chain
    /// candidates: `(length, distance)`, `length = 0` when none reaches
    /// [`MIN_MATCH`]. Strictly longer wins; the first (nearest) candidate
    /// wins ties, keeping distances small. Deterministic by construction.
    fn find(&self, pos: usize, input: &[u8]) -> (usize, usize) {
        if pos + MIN_MATCH > input.len() {
            return (0, 0);
        }
        let limit = (input.len() - pos).min(MAX_MATCH);
        let h = hash4(&input[pos..]);
        let mut cand = self.head[h];
        let mut best_len = 0usize;
        let mut best_dist = 0usize;
        let mut tries = CHAIN_LIMIT;
        while cand != NIL && tries > 0 {
            let c = cand as usize;
            debug_assert!(c < pos);
            if pos - c > MAX_DISTANCE {
                break; // older candidates are even farther
            }
            // Cheap rejection: a longer match must extend past the current
            // best's last byte.
            if best_len == 0 || input[c + best_len] == input[pos + best_len] {
                let mut len = 0usize;
                while len < limit && input[c + len] == input[pos + len] {
                    len += 1;
                }
                if len > best_len {
                    best_len = len;
                    best_dist = pos - c;
                    if len == limit {
                        break;
                    }
                }
            }
            cand = self.prev[c];
            tries -= 1;
        }
        if best_len >= MIN_MATCH {
            (best_len, best_dist)
        } else {
            (0, 0)
        }
    }
}

/// Compress `input`. Deterministic: the same bytes always produce the same
/// stream (the block CRC in the v3 index covers the *encoded* bytes).
pub fn compress(input: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(input.len() / 2 + 16);
    let mut chains = Chains::new(input.len());
    let mut pos = 0usize;
    let mut flag_pos = 0usize;
    let mut item = 0u8;
    // Probe carried over from a lazy deferral: the match already found at
    // the *current* `pos` by the previous iteration's look-ahead (the
    // chains are unchanged in between, so reusing it is exact and halves
    // the search work on lazy hits).
    let mut carried: Option<(usize, usize)> = None;
    while pos < input.len() {
        if item == 0 {
            flag_pos = out.len();
            out.push(0);
        }
        let (mut match_len, match_dist) = match carried.take() {
            Some(found) => found,
            None => {
                chains.insert_below(pos, input);
                chains.find(pos, input)
            }
        };
        if match_len > 0 && pos + 1 < input.len() {
            // One-step lazy matching: if the next position starts a
            // strictly longer match, emit this byte as a literal and let
            // the longer match win on the next iteration.
            chains.insert_below(pos + 1, input);
            let next = chains.find(pos + 1, input);
            if next.0 > match_len {
                match_len = 0;
                carried = Some(next);
            }
        }
        if match_len > 0 {
            out[flag_pos] |= 1 << item;
            out.push(match_dist as u8);
            out.push((match_dist >> 8) as u8);
            out.push((match_len - MIN_MATCH) as u8);
            // Thread the matched region into the chains so later positions
            // can reference overlapping repeats.
            chains.insert_below(pos + match_len, input);
            pos += match_len;
        } else {
            out.push(input[pos]);
            pos += 1;
        }
        item = (item + 1) % 8;
    }
    out
}

/// Decompress a [`compress`]-produced stream into exactly `output_len`
/// bytes. Fails on truncation, trailing garbage, out-of-range match
/// distances, or a stream that does not land exactly on `output_len`.
pub fn decompress(input: &[u8], output_len: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(output_len);
    let mut i = 0usize;
    while out.len() < output_len {
        if i >= input.len() {
            bail!("lz: truncated stream ({} of {output_len} bytes decoded)", out.len());
        }
        let flags = input[i];
        i += 1;
        let mut bit = 0u8;
        while bit < 8 && out.len() < output_len {
            if flags & (1 << bit) != 0 {
                if i + 3 > input.len() {
                    bail!("lz: truncated match token at byte {i}");
                }
                let dist = input[i] as usize | ((input[i + 1] as usize) << 8);
                let len = input[i + 2] as usize + MIN_MATCH;
                i += 3;
                if dist == 0 || dist > out.len() {
                    bail!("lz: match distance {dist} out of range at {} decoded bytes", out.len());
                }
                if out.len() + len > output_len {
                    bail!("lz: match overruns the {output_len}-byte output");
                }
                let start = out.len() - dist;
                for j in 0..len {
                    let b = out[start + j];
                    out.push(b);
                }
            } else {
                if i >= input.len() {
                    bail!("lz: truncated literal at byte {i}");
                }
                out.push(input[i]);
                i += 1;
            }
            bit += 1;
        }
    }
    if i != input.len() {
        bail!("lz: {} trailing bytes after the {output_len}-byte output", input.len() - i);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(data: &[u8]) -> Vec<u8> {
        let enc = compress(data);
        decompress(&enc, data.len()).unwrap()
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert_eq!(compress(&[]), Vec::<u8>::new());
        assert_eq!(decompress(&[], 0).unwrap(), Vec::<u8>::new());
        for data in [&b"a"[..], b"ab", b"abc", b"abcd"] {
            assert_eq!(roundtrip(data), data);
        }
    }

    #[test]
    fn repetitive_data_compresses_and_roundtrips() {
        let data: Vec<u8> = (0..10_000).map(|i| (i % 7) as u8).collect();
        let enc = compress(&data);
        assert!(enc.len() < data.len() / 4, "{} vs {}", enc.len(), data.len());
        assert_eq!(decompress(&enc, data.len()).unwrap(), data);
    }

    #[test]
    fn self_overlapping_match_rle() {
        let data = vec![0x42u8; 5000];
        let enc = compress(&data);
        assert!(enc.len() < 100, "RLE run should collapse, got {}", enc.len());
        assert_eq!(decompress(&enc, data.len()).unwrap(), data);
    }

    #[test]
    fn random_data_roundtrips_with_bounded_expansion() {
        let mut rng = Rng::new(0xC0DEC);
        let data: Vec<u8> = (0..65_536).map(|_| rng.next_u64() as u8).collect();
        let enc = compress(&data);
        assert!(enc.len() <= data.len() * 9 / 8 + 2, "expansion {}", enc.len());
        assert_eq!(decompress(&enc, data.len()).unwrap(), data);
    }

    #[test]
    fn structured_float_like_data_roundtrips() {
        // Byte-shuffled float payloads are long runs of near-constant
        // bytes — the case the store's `lz` codec exists for.
        let mut data = Vec::new();
        for lane in 0..4u8 {
            for i in 0..4096u32 {
                data.push(lane.wrapping_mul(37).wrapping_add((i / 256) as u8));
            }
        }
        let enc = compress(&data);
        assert!(enc.len() < data.len() / 8);
        assert_eq!(decompress(&enc, data.len()).unwrap(), data);
    }

    #[test]
    fn long_matches_cross_group_boundaries() {
        let mut data = b"the quick brown fox ".repeat(400);
        data.extend_from_slice(b"tail");
        assert_eq!(roundtrip(&data), data);
    }

    #[test]
    fn corrupt_streams_rejected() {
        let data: Vec<u8> = (0..1000).map(|i| (i % 11) as u8).collect();
        let enc = compress(&data);
        // Truncation.
        assert!(decompress(&enc[..enc.len() - 1], data.len()).is_err());
        // Wrong expected length (too short -> trailing bytes; too long ->
        // truncated stream).
        assert!(decompress(&enc, data.len() - 1).is_err());
        assert!(decompress(&enc, data.len() + 1).is_err());
        // A match token pointing before the start of the output.
        let bogus = [0x01u8, 0xFF, 0xFF, 0x00];
        assert!(decompress(&bogus, 300).is_err());
    }
}
