//! A homegrown byte-oriented LZ77/LZSS codec — the `lz` block codec of the
//! `.bmx` v3 store, dependency-free by construction.
//!
//! Format: a sequence of groups, each a *flags* byte followed by eight
//! items (fewer in the final group). Flag bit `b` (LSB first) describes
//! item `b`:
//!
//! * `0` — a literal: one raw byte;
//! * `1` — a match: three bytes — `u16` LE back-distance (1..=65535 into
//!   the already-decoded output) and `u8` length-minus-4 (match lengths
//!   4..=259). Matches may self-overlap (RLE falls out naturally).
//!
//! The stream carries no decoded-length field of its own: block stores
//! know every block's decoded size from the header geometry, so
//! [`decompress`] takes the expected output length and validates the
//! stream against it — a corrupt or truncated stream fails with a clear
//! error instead of producing a silently short block.
//!
//! The compressor is a greedy single-pass matcher with one candidate per
//! 4-byte hash bucket. Worst case the output is `9/8 · len + 1` bytes
//! (all literals); block stores record the encoded length per block, so
//! incompressible data is handled, never rejected.

use crate::bail;
use crate::util::error::Result;

/// Shortest encodable match.
const MIN_MATCH: usize = 4;

/// Longest encodable match (`u8` length field + [`MIN_MATCH`]).
const MAX_MATCH: usize = 255 + MIN_MATCH;

/// Largest encodable back-distance (`u16` field; 0 is invalid).
const MAX_DISTANCE: usize = u16::MAX as usize;

const HASH_BITS: u32 = 15;

#[inline]
fn hash4(bytes: &[u8]) -> usize {
    let v = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

/// Compress `input`. Deterministic: the same bytes always produce the same
/// stream (the block CRC in the v3 index covers the *encoded* bytes).
pub fn compress(input: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(input.len() / 2 + 16);
    let mut table = vec![usize::MAX; 1 << HASH_BITS];
    let mut pos = 0usize;
    let mut flag_pos = 0usize;
    let mut item = 0u8;
    while pos < input.len() {
        if item == 0 {
            flag_pos = out.len();
            out.push(0);
        }
        // Find the best (single-candidate) match at `pos`.
        let mut match_len = 0usize;
        let mut match_dist = 0usize;
        if pos + MIN_MATCH <= input.len() {
            let h = hash4(&input[pos..]);
            let cand = table[h];
            table[h] = pos;
            if cand != usize::MAX && pos - cand <= MAX_DISTANCE {
                let limit = (input.len() - pos).min(MAX_MATCH);
                let mut len = 0usize;
                while len < limit && input[cand + len] == input[pos + len] {
                    len += 1;
                }
                if len >= MIN_MATCH {
                    match_len = len;
                    match_dist = pos - cand;
                }
            }
        }
        if match_len > 0 {
            out[flag_pos] |= 1 << item;
            out.push(match_dist as u8);
            out.push((match_dist >> 8) as u8);
            out.push((match_len - MIN_MATCH) as u8);
            // Seed the hash table through the matched region so the next
            // positions can find overlapping repeats.
            let end = pos + match_len;
            let mut p = pos + 1;
            while p < end && p + MIN_MATCH <= input.len() {
                table[hash4(&input[p..])] = p;
                p += 1;
            }
            pos = end;
        } else {
            out.push(input[pos]);
            pos += 1;
        }
        item = (item + 1) % 8;
    }
    out
}

/// Decompress a [`compress`]-produced stream into exactly `output_len`
/// bytes. Fails on truncation, trailing garbage, out-of-range match
/// distances, or a stream that does not land exactly on `output_len`.
pub fn decompress(input: &[u8], output_len: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(output_len);
    let mut i = 0usize;
    while out.len() < output_len {
        if i >= input.len() {
            bail!("lz: truncated stream ({} of {output_len} bytes decoded)", out.len());
        }
        let flags = input[i];
        i += 1;
        let mut bit = 0u8;
        while bit < 8 && out.len() < output_len {
            if flags & (1 << bit) != 0 {
                if i + 3 > input.len() {
                    bail!("lz: truncated match token at byte {i}");
                }
                let dist = input[i] as usize | ((input[i + 1] as usize) << 8);
                let len = input[i + 2] as usize + MIN_MATCH;
                i += 3;
                if dist == 0 || dist > out.len() {
                    bail!("lz: match distance {dist} out of range at {} decoded bytes", out.len());
                }
                if out.len() + len > output_len {
                    bail!("lz: match overruns the {output_len}-byte output");
                }
                let start = out.len() - dist;
                for j in 0..len {
                    let b = out[start + j];
                    out.push(b);
                }
            } else {
                if i >= input.len() {
                    bail!("lz: truncated literal at byte {i}");
                }
                out.push(input[i]);
                i += 1;
            }
            bit += 1;
        }
    }
    if i != input.len() {
        bail!("lz: {} trailing bytes after the {output_len}-byte output", input.len() - i);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(data: &[u8]) -> Vec<u8> {
        let enc = compress(data);
        decompress(&enc, data.len()).unwrap()
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert_eq!(compress(&[]), Vec::<u8>::new());
        assert_eq!(decompress(&[], 0).unwrap(), Vec::<u8>::new());
        for data in [&b"a"[..], b"ab", b"abc", b"abcd"] {
            assert_eq!(roundtrip(data), data);
        }
    }

    #[test]
    fn repetitive_data_compresses_and_roundtrips() {
        let data: Vec<u8> = (0..10_000).map(|i| (i % 7) as u8).collect();
        let enc = compress(&data);
        assert!(enc.len() < data.len() / 4, "{} vs {}", enc.len(), data.len());
        assert_eq!(decompress(&enc, data.len()).unwrap(), data);
    }

    #[test]
    fn self_overlapping_match_rle() {
        let data = vec![0x42u8; 5000];
        let enc = compress(&data);
        assert!(enc.len() < 100, "RLE run should collapse, got {}", enc.len());
        assert_eq!(decompress(&enc, data.len()).unwrap(), data);
    }

    #[test]
    fn random_data_roundtrips_with_bounded_expansion() {
        let mut rng = Rng::new(0xC0DEC);
        let data: Vec<u8> = (0..65_536).map(|_| rng.next_u64() as u8).collect();
        let enc = compress(&data);
        assert!(enc.len() <= data.len() * 9 / 8 + 2, "expansion {}", enc.len());
        assert_eq!(decompress(&enc, data.len()).unwrap(), data);
    }

    #[test]
    fn structured_float_like_data_roundtrips() {
        // Byte-shuffled float payloads are long runs of near-constant
        // bytes — the case the store's `lz` codec exists for.
        let mut data = Vec::new();
        for lane in 0..4u8 {
            for i in 0..4096u32 {
                data.push(lane.wrapping_mul(37).wrapping_add((i / 256) as u8));
            }
        }
        let enc = compress(&data);
        assert!(enc.len() < data.len() / 8);
        assert_eq!(decompress(&enc, data.len()).unwrap(), data);
    }

    #[test]
    fn long_matches_cross_group_boundaries() {
        let mut data = b"the quick brown fox ".repeat(400);
        data.extend_from_slice(b"tail");
        assert_eq!(roundtrip(&data), data);
    }

    #[test]
    fn corrupt_streams_rejected() {
        let data: Vec<u8> = (0..1000).map(|i| (i % 11) as u8).collect();
        let enc = compress(&data);
        // Truncation.
        assert!(decompress(&enc[..enc.len() - 1], data.len()).is_err());
        // Wrong expected length (too short -> trailing bytes; too long ->
        // truncated stream).
        assert!(decompress(&enc, data.len() - 1).is_err());
        assert!(decompress(&enc, data.len() + 1).is_err());
        // A match token pointing before the start of the output.
        let bogus = [0x01u8, 0xFF, 0xFF, 0x00];
        assert!(decompress(&bogus, 300).is_err());
    }
}
