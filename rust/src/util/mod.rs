//! Substrate utilities built from scratch for the offline environment:
//! deterministic RNG, scoped thread pool, JSON, CLI parsing, property-test
//! driver, error handling, and a dense row-major matrix.

pub mod cli;
pub mod error;
pub mod json;
pub mod matrix;
pub mod prop;
pub mod rng;
pub mod threadpool;
