//! Substrate utilities built from scratch for the offline environment:
//! deterministic RNG, scoped thread pool, JSON, CLI parsing, property-test
//! driver, error handling, CRC-32, an LZ77 codec, binary16 conversions,
//! `madvise`/mmap shims, and a dense row-major matrix.

pub mod cli;
pub mod error;
pub mod half;
pub mod hash;
pub mod json;
pub mod lz;
pub mod matrix;
pub mod mem;
pub mod prop;
pub mod rng;
pub mod sync;
pub mod threadpool;
