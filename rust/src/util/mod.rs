//! Substrate utilities built from scratch for the offline environment:
//! deterministic RNG, scoped thread pool, JSON, CLI parsing, property-test
//! driver, error handling, CRC-32, `madvise` hints, and a dense row-major
//! matrix.

pub mod cli;
pub mod error;
pub mod hash;
pub mod json;
pub mod matrix;
pub mod mem;
pub mod prop;
pub mod rng;
pub mod threadpool;
