//! Tiny JSON parser + writer (no serde in the offline registry).
//!
//! Supports the subset the system needs: objects, arrays, strings with
//! standard escapes, f64 numbers, bools, null. Used for the AOT artifact
//! manifest (`artifacts/manifest.json`) and the bench-report output.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Returns an error message with byte offset on
    /// malformed input.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }
}

/// Convenience constructors.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}
pub fn num(x: f64) -> Json {
    Json::Num(x)
}
pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 scalar
                    let rest = &self.b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| "bad utf8 in string")?;
                    out.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = obj(vec![
            ("name", s("big-means")),
            ("k", num(25.0)),
            ("ratio", num(0.5)),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
            ("shape", arr(vec![num(4096.0), num(32.0)])),
        ]);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_whitespace_and_nesting() {
        let t = r#" { "a" : [ 1 , { "b" : "x\ny" } , null ] } "#;
        let v = Json::parse(t).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[1].get("b").unwrap().as_str(), Some("x\ny"));
        assert_eq!(a[2], Json::Null);
    }

    #[test]
    fn parse_numbers() {
        for (t, want) in [("-3.5", -3.5), ("1e3", 1000.0), ("0.25", 0.25), ("42", 42.0)] {
            assert_eq!(Json::parse(t).unwrap().as_f64(), Some(want));
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = s("quote\" slash\\ tab\t nl\n");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn unicode_passthrough() {
        let v = s("κ-means ∑‖x−c‖²");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }
}
