//! CRC-32 (IEEE 802.3, reflected polynomial `0xEDB88320`) — the checksum
//! the `.bmx` v2 header carries. Streaming so writers can fold the payload
//! in block by block; table-driven (one 1 KiB const table, built at compile
//! time), no dependencies.

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut bit = 0;
        while bit < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            bit += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// Streaming CRC-32 accumulator.
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Fold more bytes into the running checksum.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.state;
        for &b in bytes {
            c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    /// The checksum of everything folded in so far (does not consume the
    /// accumulator — more updates may follow).
    pub fn finalize(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The canonical CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..1024u32).map(|i| (i * 31 % 251) as u8).collect();
        let whole = crc32(&data);
        let mut acc = Crc32::new();
        for chunk in data.chunks(37) {
            acc.update(chunk);
        }
        assert_eq!(acc.finalize(), whole);
    }

    #[test]
    fn sensitive_to_single_bit_flips() {
        let mut data = vec![0u8; 64];
        let base = crc32(&data);
        data[40] ^= 0x01;
        assert_ne!(crc32(&data), base);
    }
}
