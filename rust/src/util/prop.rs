//! Lightweight property-based testing driver (no `proptest` offline).
//!
//! `check` runs a property over `cases` randomly generated inputs and, on
//! failure, performs greedy shrinking via the generator's `shrink` hook so
//! the panic message carries a near-minimal counterexample.

use crate::util::rng::Rng;

/// A generator of random values with an optional shrinker.
pub trait Gen {
    type Value: std::fmt::Debug + Clone;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller values; default = no shrinking.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `prop` over `cases` random inputs from `gen`, seeded deterministically.
/// Panics with the (shrunk) counterexample on the first failure.
pub fn check<G, P>(seed: u64, cases: usize, gen: &G, prop: P)
where
    G: Gen,
    P: Fn(&G::Value) -> bool,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if !prop(&v) {
            let minimal = shrink_loop(gen, v, &prop);
            panic!("property failed (case {case}, seed {seed}): counterexample = {minimal:?}");
        }
    }
}

fn shrink_loop<G, P>(gen: &G, mut v: G::Value, prop: &P) -> G::Value
where
    G: Gen,
    P: Fn(&G::Value) -> bool,
{
    // Greedy descent: keep taking the first failing shrink candidate.
    'outer: for _ in 0..1000 {
        for cand in gen.shrink(&v) {
            if !prop(&cand) {
                v = cand;
                continue 'outer;
            }
        }
        break;
    }
    v
}

/// Generator for a random clustering problem: `(points, k)` with points a
/// flat row-major buffer of `m×n` f32 in a bounded box. Shrinks by halving
/// the number of points.
pub struct ClusterProblemGen {
    pub m_range: (usize, usize),
    pub n_range: (usize, usize),
    pub k_max: usize,
    pub coord_range: (f32, f32),
}

/// A generated problem instance.
#[derive(Clone, Debug)]
pub struct ClusterProblem {
    pub points: Vec<f32>,
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl Default for ClusterProblemGen {
    fn default() -> Self {
        ClusterProblemGen {
            m_range: (1, 200),
            n_range: (1, 16),
            k_max: 8,
            coord_range: (-100.0, 100.0),
        }
    }
}

impl Gen for ClusterProblemGen {
    type Value = ClusterProblem;

    fn generate(&self, rng: &mut Rng) -> ClusterProblem {
        let m = self.m_range.0 + rng.usize(self.m_range.1 - self.m_range.0 + 1);
        let n = self.n_range.0 + rng.usize(self.n_range.1 - self.n_range.0 + 1);
        let k = 1 + rng.usize(self.k_max.min(m));
        let (lo, hi) = self.coord_range;
        let points = (0..m * n)
            .map(|_| lo + (hi - lo) * rng.f32())
            .collect();
        ClusterProblem { points, m, n, k }
    }

    fn shrink(&self, v: &ClusterProblem) -> Vec<ClusterProblem> {
        let mut out = Vec::new();
        if v.m > self.m_range.0.max(v.k) {
            let m2 = (v.m / 2).max(self.m_range.0).max(v.k);
            out.push(ClusterProblem {
                points: v.points[..m2 * v.n].to_vec(),
                m: m2,
                n: v.n,
                k: v.k,
            });
        }
        if v.k > 1 {
            out.push(ClusterProblem {
                points: v.points.clone(),
                m: v.m,
                n: v.n,
                k: v.k / 2,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_valid_problems() {
        let gen = ClusterProblemGen::default();
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let p = gen.generate(&mut rng);
            assert_eq!(p.points.len(), p.m * p.n);
            assert!(p.k >= 1 && p.k <= p.m);
        }
    }

    #[test]
    fn passing_property_passes() {
        check(1, 50, &ClusterProblemGen::default(), |p| p.k <= p.m);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        check(1, 50, &ClusterProblemGen::default(), |p| p.m > 100);
    }

    #[test]
    fn shrink_reduces_size() {
        let gen = ClusterProblemGen::default();
        let mut rng = Rng::new(5);
        let p = gen.generate(&mut rng);
        for sp in gen.shrink(&p) {
            assert!(sp.m < p.m || sp.k < p.k);
            assert_eq!(sp.points.len(), sp.m * sp.n);
        }
    }
}
