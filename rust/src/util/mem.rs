//! Raw memory management for the out-of-core backends, dependency-free:
//! a thin `madvise` shim over libc FFI plus [`MmapRegion`], the owned
//! read-only whole-file memory mapping shared by the `.bmx` v1/v2 reader,
//! the `.bmx` v3 block store, and the CSV `.idx` sidecar index.
//!
//! The hints are purely advisory: failures are ignored (the kernel may
//! reject unaligned or unsupported requests) and non-unix builds compile
//! to a no-op. `MmapRegion` itself exists only on little-endian 64-bit
//! unix targets — callers fall back to buffered positioned reads
//! elsewhere.

/// Expected access pattern for a mapped region.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Advice {
    /// No special pattern (`MADV_NORMAL`): default readahead.
    Normal,
    /// Random access (`MADV_RANDOM`): disable readahead — right for chunk
    /// sampling, which touches scattered pages.
    Random,
    /// Sequential access (`MADV_SEQUENTIAL`): aggressive readahead and
    /// early page reclaim — right for the blocked final pass.
    Sequential,
}

#[cfg(unix)]
mod sys {
    use std::ffi::c_void;
    use std::os::raw::c_int;

    extern "C" {
        pub fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
    }

    // POSIX values, identical on Linux and macOS.
    pub const MADV_NORMAL: c_int = 0;
    pub const MADV_RANDOM: c_int = 1;
    pub const MADV_SEQUENTIAL: c_int = 2;
}

/// Advise the kernel about the expected access pattern of `[ptr, ptr+len)`.
/// `ptr` should be the page-aligned base of an mmap'd region (mappings
/// returned by `mmap` always are).
pub fn madvise(ptr: *mut u8, len: usize, advice: Advice) {
    #[cfg(unix)]
    {
        if ptr.is_null() || len == 0 {
            return;
        }
        let adv = match advice {
            Advice::Normal => sys::MADV_NORMAL,
            Advice::Random => sys::MADV_RANDOM,
            Advice::Sequential => sys::MADV_SEQUENTIAL,
        };
        // Hint only — the return value is deliberately discarded.
        let _ = unsafe { sys::madvise(ptr as *mut std::ffi::c_void, len, adv) };
    }
    #[cfg(not(unix))]
    {
        let _ = (ptr, len, advice);
    }
}

/// Best-effort prefetch of the cache line containing `p` into L1 with
/// read intent. Purely a scheduling hint: prefetch instructions never
/// fault, so any address value is fine — callers still keep `p` inside
/// (or one-past) a live allocation via `wrapping_add` + clamping so the
/// *pointer arithmetic* stays defined. Compiles to a no-op on
/// architectures without a prefetch hint.
#[inline(always)]
pub fn prefetch_read(p: *const u8) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: `prefetcht0` is baseline SSE on x86_64 and never faults.
    unsafe {
        use core::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        _mm_prefetch::<{ _MM_HINT_T0 }>(p as *const i8);
    }
    #[cfg(target_arch = "aarch64")]
    // SAFETY: `prfm pldl1keep` is a hint; it never faults and writes
    // nothing.
    unsafe {
        core::arch::asm!("prfm pldl1keep, [{0}]", in(reg) p, options(nostack, preserves_flags));
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    let _ = p;
}

#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
mod map_sys {
    //! Raw `mmap` FFI — the process links libc anyway, so no crate needed.
    use std::ffi::c_void;
    use std::os::raw::c_int;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
}

/// An owned read-only memory mapping of a whole file.
#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
pub struct MmapRegion {
    ptr: *mut std::ffi::c_void,
    len: usize,
}

// Safety: the region is read-only for its whole lifetime and unmapped only
// on drop, so shared references from any thread are fine.
#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
unsafe impl Send for MmapRegion {}
#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
unsafe impl Sync for MmapRegion {}

#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
impl MmapRegion {
    /// Map the first `len` bytes of `file` read-only. Returns `None` for
    /// empty files or when the kernel refuses the mapping — callers fall
    /// back to buffered reads.
    pub fn map(file: &std::fs::File, len: usize) -> Option<MmapRegion> {
        use std::os::unix::io::AsRawFd;
        if len == 0 {
            return None;
        }
        let ptr = unsafe {
            map_sys::mmap(
                std::ptr::null_mut(),
                len,
                map_sys::PROT_READ,
                map_sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 || ptr.is_null() {
            None
        } else {
            Some(MmapRegion { ptr, len })
        }
    }

    /// The mapped bytes.
    pub fn bytes(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }

    /// Forward an access-pattern hint to `madvise` for the whole mapping.
    pub fn advise(&self, advice: Advice) {
        madvise(self.ptr as *mut u8, self.len, advice);
    }
}

#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
impl Drop for MmapRegion {
    fn drop(&mut self) {
        unsafe {
            map_sys::munmap(self.ptr, self.len);
        }
    }
}
