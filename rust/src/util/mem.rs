//! Raw memory-management hints — a thin `madvise` shim over libc FFI so
//! the crate stays dependency-free. Purely advisory: failures are ignored
//! (the kernel may reject unaligned or unsupported requests) and non-unix
//! builds compile to a no-op.

/// Expected access pattern for a mapped region.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Advice {
    /// No special pattern (`MADV_NORMAL`): default readahead.
    Normal,
    /// Random access (`MADV_RANDOM`): disable readahead — right for chunk
    /// sampling, which touches scattered pages.
    Random,
    /// Sequential access (`MADV_SEQUENTIAL`): aggressive readahead and
    /// early page reclaim — right for the blocked final pass.
    Sequential,
}

#[cfg(unix)]
mod sys {
    use std::ffi::c_void;
    use std::os::raw::c_int;

    extern "C" {
        pub fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
    }

    // POSIX values, identical on Linux and macOS.
    pub const MADV_NORMAL: c_int = 0;
    pub const MADV_RANDOM: c_int = 1;
    pub const MADV_SEQUENTIAL: c_int = 2;
}

/// Advise the kernel about the expected access pattern of `[ptr, ptr+len)`.
/// `ptr` should be the page-aligned base of an mmap'd region (mappings
/// returned by `mmap` always are).
pub fn madvise(ptr: *mut u8, len: usize, advice: Advice) {
    #[cfg(unix)]
    {
        if ptr.is_null() || len == 0 {
            return;
        }
        let adv = match advice {
            Advice::Normal => sys::MADV_NORMAL,
            Advice::Random => sys::MADV_RANDOM,
            Advice::Sequential => sys::MADV_SEQUENTIAL,
        };
        // Hint only — the return value is deliberately discarded.
        let _ = unsafe { sys::madvise(ptr as *mut std::ffi::c_void, len, adv) };
    }
    #[cfg(not(unix))]
    {
        let _ = (ptr, len, advice);
    }
}
