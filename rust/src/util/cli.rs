//! Minimal command-line argument parsing (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and subcommands, with typed getters and a generated usage string.

use std::collections::BTreeMap;

/// Parsed arguments: options + positionals after the subcommand.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]` (after an optional leading subcommand already
    /// consumed by the caller). `known_flags` are boolean switches that
    /// never consume a value — required to disambiguate
    /// `--verbose data.csv` (flag + positional) from `--k 5` (key + value).
    pub fn parse_with_flags<I: IntoIterator<Item = String>>(
        argv: I,
        known_flags: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.opts.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse with no declared flags (trailing `--x` still parses as a flag).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        Self::parse_with_flags(argv, &[])
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Enumerated option: the value of `--name` validated against
    /// `allowed` (first entry is the default when the flag is absent).
    /// Errors list the accepted values, e.g.
    /// `--backend expects one of ["mem", "mmap", "buffered"]`.
    pub fn choice<'a>(&'a self, name: &str, allowed: &[&'a str]) -> Result<&'a str, String> {
        assert!(!allowed.is_empty(), "choice(): allowed set must be non-empty");
        let v = self.get(name).unwrap_or(allowed[0]);
        if allowed.contains(&v) {
            Ok(v)
        } else {
            Err(format!("--{name} expects one of {allowed:?}, got '{v}'"))
        }
    }

    /// Comma-separated list of integers, e.g. `--k 2,3,5,10`.
    pub fn usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, String> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .map_err(|_| format!("--{name}: bad integer '{t}'"))
                })
                .collect(),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse_with_flags(toks.iter().map(|s| s.to_string()), &["verbose"]).unwrap()
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["--k", "5", "--s=4096", "--verbose", "data.csv"]);
        assert_eq!(a.get("k"), Some("5"));
        assert_eq!(a.get("s"), Some("4096"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["data.csv".to_string()]);
    }

    #[test]
    fn typed_getters_and_defaults() {
        let a = parse(&["--k", "7", "--tol", "0.001"]);
        assert_eq!(a.usize("k", 3).unwrap(), 7);
        assert_eq!(a.usize("missing", 3).unwrap(), 3);
        assert!((a.f64("tol", 1.0).unwrap() - 0.001).abs() < 1e-12);
        assert!(a.usize("tol", 0).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["--ks", "2,3,5, 10"]);
        assert_eq!(a.usize_list("ks", &[]).unwrap(), vec![2, 3, 5, 10]);
        assert_eq!(a.usize_list("missing", &[1]).unwrap(), vec![1]);
    }

    #[test]
    fn choice_validates_and_defaults() {
        let a = parse(&["--backend", "mmap"]);
        assert_eq!(a.choice("backend", &["mem", "mmap"]).unwrap(), "mmap");
        assert_eq!(a.choice("mode", &["inner", "seq"]).unwrap(), "inner");
        let bad = parse(&["--backend", "warp-drive"]);
        let err = bad.choice("backend", &["mem", "mmap"]).unwrap_err();
        assert!(err.contains("warp-drive") && err.contains("mem"));
    }

    #[test]
    fn trailing_flag_not_eating_positional() {
        let a = parse(&["--verbose", "--k", "2"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("k"), Some("2"));
    }
}
