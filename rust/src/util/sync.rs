//! Poison-recovering lock helpers.
//!
//! `Mutex::lock().unwrap()` turns one panicking thread into a process-wide
//! cascade: every later lock of the same mutex panics on the poison flag.
//! That was tolerable in a batch CLI (the run was lost anyway) but is an
//! availability bug in a long-running daemon — a single panicked worker
//! must not take down the serve loop. These helpers recover the guard from
//! a poisoned lock instead of propagating.
//!
//! Recovery is sound for every use in this crate: the protected state is
//! either re-derived after the guard is taken (job queues drained item by
//! item, file handles re-positioned with an absolute seek before every
//! read) or validated downstream (block CRCs), so a panic mid-critical-
//! section cannot leave state a recovered reader would mis-trust.

use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Lock `m`, recovering the guard if the mutex is poisoned.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Wait on `cv`, recovering the guard if the mutex was poisoned while the
/// waiter slept.
pub fn wait_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cv.wait(guard) {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Read-lock `l`, recovering the guard if the lock is poisoned.
pub fn read_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    match l.read() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Write-lock `l`, recovering the guard if the lock is poisoned.
pub fn write_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    match l.write() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recover_survives_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        // Panic while holding the guard: the mutex is now poisoned.
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        let mut g = lock_recover(&m);
        assert_eq!(*g, 7);
        *g = 8;
        drop(g);
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn rwlock_recover_survives_a_poisoned_lock() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(l.read().is_err(), "rwlock must actually be poisoned");
        assert_eq!(read_recover(&l).len(), 3);
        write_recover(&l).push(4);
        assert_eq!(read_recover(&l).len(), 4);
    }

    #[test]
    fn wait_recover_passes_through_on_healthy_lock() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let p2 = Arc::clone(&pair);
        let waiter = std::thread::spawn(move || {
            let (m, cv) = &*p2;
            let mut done = lock_recover(m);
            while !*done {
                done = wait_recover(cv, done);
            }
        });
        {
            let (m, cv) = &*pair;
            *lock_recover(m) = true;
            cv.notify_all();
        }
        waiter.join().unwrap();
    }
}
