//! Minimal work-stealing-free scoped thread pool.
//!
//! The offline build has no `rayon`, so this is the parallelism substrate:
//! a fixed set of worker threads fed from a shared injector queue, plus a
//! `scope`-style API (`run_all`, `parallel_for`) that blocks until every
//! submitted job finishes and propagates panics.
//!
//! Design notes: the pool is intentionally simple — one `Mutex<VecDeque>`
//! injector with a condvar. The clustering workloads submit coarse-grained
//! jobs (a whole chunk, a row-block of the distance matrix), so injector
//! contention is negligible; see `benches/hot_path.rs` for the measurement.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::obs;
use crate::util::sync::{lock_recover, wait_recover};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<std::collections::VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
    panicked: AtomicBool,
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
    m_jobs: obs::Counter,
}

impl ThreadPool {
    /// Spawn a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(std::collections::VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            panicked: AtomicBool::new(false),
        });
        let workers = (0..size)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("bigmeans-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        let m = obs::metrics();
        m.counter(
            "bigmeans_threadpool_threads_started_total",
            "Worker threads spawned by thread pools since process start",
            &[],
        )
        .add(size as u64);
        let m_jobs = m.counter(
            "bigmeans_threadpool_jobs_total",
            "Jobs submitted to thread-pool injector queues",
            &[],
        );
        ThreadPool { shared, workers, size, m_jobs }
    }

    /// Pool sized to the machine (logical cores).
    pub fn with_default_size() -> Self {
        Self::new(
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
        )
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a fire-and-forget job. Poison-recovering: a panicked job
    /// only ever poisons the injector between `push_back` calls, never
    /// mid-mutation, so the queue contents stay coherent.
    fn submit(&self, job: Job) {
        self.m_jobs.inc();
        let mut q = lock_recover(&self.shared.queue);
        q.push_back(job);
        drop(q);
        self.shared.available.notify_one();
    }

    /// Run every closure on the pool and block until all complete.
    /// Panics (after draining) if any job panicked.
    pub fn run_all<F>(&self, jobs: Vec<F>)
    where
        F: FnOnce() + Send + 'static,
    {
        self.run_all_boxed(jobs.into_iter().map(|j| Box::new(j) as Job).collect());
    }

    /// Run borrowed closures on the pool, blocking until every one has
    /// finished — a scoped execution in the spirit of `std::thread::scope`,
    /// but on the long-lived pool (no per-call thread spawns).
    ///
    /// The jobs may capture non-`'static` references: this function does
    /// not return until all of them have run to completion (or panicked and
    /// been drained), so nothing they borrow can dangle.
    pub fn scope_run_all<'scope, F>(&self, jobs: Vec<F>)
    where
        F: FnOnce() + Send + 'scope,
    {
        let boxed: Vec<Job> = jobs
            .into_iter()
            .map(|j| {
                let job: Box<dyn FnOnce() + Send + 'scope> = Box::new(j);
                // Safety: the job only needs to live until it has executed,
                // and `run_all_boxed` blocks this call until every job has
                // finished (the completion latch is decremented after the
                // job returns or panics). The 'scope borrows therefore
                // outlive all uses; erasing the lifetime is sound.
                unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'scope>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(job)
                }
            })
            .collect();
        self.run_all_boxed(boxed);
    }

    fn run_all_boxed(&self, jobs: Vec<Job>) {
        let pending = Arc::new((Mutex::new(jobs.len()), Condvar::new()));
        for job in jobs {
            let pending = Arc::clone(&pending);
            let sh = Arc::clone(&self.shared);
            self.submit(Box::new(move || {
                let result = catch_unwind(AssertUnwindSafe(job));
                if result.is_err() {
                    sh.panicked.store(true, Ordering::SeqCst);
                }
                let (lock, cv) = &*pending;
                let mut n = lock_recover(lock);
                *n -= 1;
                if *n == 0 {
                    cv.notify_all();
                }
            }));
        }
        let (lock, cv) = &*pending;
        let mut n = lock_recover(lock);
        while *n > 0 {
            n = wait_recover(cv, n);
        }
        if self.shared.panicked.swap(false, Ordering::SeqCst) {
            panic!("a pooled job panicked");
        }
    }

    /// Parallel-for over `0..n` in contiguous blocks: calls
    /// `body(start, end)` for each block. `body` must be `Sync` — it is
    /// shared by reference across workers via scoped threads semantics
    /// (we clone an `Arc`).
    pub fn parallel_for_blocks<F>(&self, n: usize, body: F)
    where
        F: Fn(usize, usize) + Send + Sync + 'static,
    {
        if n == 0 {
            return;
        }
        let nblocks = self.size.min(n);
        let body = Arc::new(body);
        let block = n.div_ceil(nblocks);
        let jobs: Vec<_> = (0..nblocks)
            .map(|b| {
                let body = Arc::clone(&body);
                move || {
                    let start = b * block;
                    let end = ((b + 1) * block).min(n);
                    if start < end {
                        body(start, end);
                    }
                }
            })
            .collect();
        self.run_all(jobs);
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + Default + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let results: Arc<Mutex<Vec<R>>> =
            Arc::new(Mutex::new((0..n).map(|_| R::default()).collect()));
        let f = Arc::new(f);
        let jobs: Vec<_> = items
            .into_iter()
            .enumerate()
            .map(|(i, item)| {
                let results = Arc::clone(&results);
                let f = Arc::clone(&f);
                move || {
                    let r = f(item);
                    lock_recover(&results)[i] = r;
                }
            })
            .collect();
        self.run_all(jobs);
        match Arc::try_unwrap(results)
            .unwrap_or_else(|_| panic!("map results still shared"))
            .into_inner()
        {
            Ok(v) => v,
            Err(poisoned) => poisoned.into_inner(),
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = lock_recover(&shared.queue);
            loop {
                if let Some(job) = q.pop_front() {
                    break Some(job);
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                q = wait_recover(&shared.available, q);
            }
        };
        match job {
            Some(job) => job(),
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A cheap atomic counter handle used by jobs to publish progress.
#[derive(Clone, Default)]
pub struct SharedCounter(Arc<AtomicUsize>);

impl SharedCounter {
    pub fn new() -> Self {
        Self::default()
    }
    #[inline]
    pub fn add(&self, v: usize) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }
    #[inline]
    pub fn get(&self) -> usize {
        self.0.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_all_executes_everything() {
        let pool = ThreadPool::new(4);
        let counter = SharedCounter::new();
        let jobs: Vec<_> = (0..100)
            .map(|_| {
                let c = counter.clone();
                move || c.add(1)
            })
            .collect();
        pool.run_all(jobs);
        assert_eq!(counter.get(), 100);
    }

    #[test]
    fn parallel_for_blocks_covers_range() {
        let pool = ThreadPool::new(3);
        let hits = Arc::new(Mutex::new(vec![0u8; 1000]));
        let h = Arc::clone(&hits);
        pool.parallel_for_blocks(1000, move |s, e| {
            let mut v = h.lock().unwrap();
            for i in s..e {
                v[i] += 1;
            }
        });
        assert!(hits.lock().unwrap().iter().all(|&x| x == 1));
    }

    #[test]
    fn scope_run_all_borrows_stack_data() {
        let pool = ThreadPool::new(4);
        let input: Vec<u64> = (0..64).collect();
        let mut out = vec![0u64; 64];
        {
            let jobs: Vec<_> = input
                .chunks(16)
                .zip(out.chunks_mut(16))
                .map(|(src, dst)| {
                    move || {
                        for (d, s) in dst.iter_mut().zip(src) {
                            *d = s * 3;
                        }
                    }
                })
                .collect();
            pool.scope_run_all(jobs);
        }
        assert!(out.iter().enumerate().all(|(i, &v)| v == i as u64 * 3));
    }

    #[test]
    #[should_panic(expected = "a pooled job panicked")]
    fn scoped_panics_propagate_after_drain() {
        let pool = ThreadPool::new(2);
        let data = vec![1u8; 4];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| {
                let _ = &data;
            }),
            Box::new(|| panic!("boom")),
        ];
        pool.scope_run_all(jobs);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..50).collect::<Vec<usize>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "a pooled job panicked")]
    fn panics_propagate() {
        let pool = ThreadPool::new(2);
        pool.run_all(vec![|| panic!("boom")]);
    }

    #[test]
    fn pool_stays_usable_after_a_panicked_batch() {
        // The daemon contract: one panicking job must not poison the pool.
        let pool = ThreadPool::new(2);
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_all(vec![|| panic!("boom")]);
        }));
        assert!(boom.is_err(), "panic must still propagate to the caller");
        for _ in 0..5 {
            let c = SharedCounter::new();
            let jobs: Vec<_> = (0..8)
                .map(|_| {
                    let c = c.clone();
                    move || c.add(1)
                })
                .collect();
            pool.run_all(jobs);
            assert_eq!(c.get(), 8);
        }
    }

    #[test]
    fn pool_survives_many_batches() {
        let pool = ThreadPool::new(2);
        for _ in 0..20 {
            let c = SharedCounter::new();
            let cc = c.clone();
            pool.run_all(vec![move || cc.add(5)]);
            assert_eq!(c.get(), 5);
        }
    }
}
