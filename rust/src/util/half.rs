//! IEEE 754 binary16 ("half") conversions — the `f16` dtype of the `.bmx`
//! v3 store. Stable Rust has no `f16` primitive, so the store keeps half
//! floats as raw `u16` bit patterns and converts at the block boundary:
//! encode with round-to-nearest-even on write, widen exactly on read.
//!
//! Properties the store relies on (asserted by the tests below):
//! * `f32_from_f16(f16_from_f32(x))` is exact for every value binary16
//!   represents (including subnormals and ±∞);
//! * out-of-range magnitudes saturate to ±∞, sub-subnormal magnitudes
//!   flush to ±0 — both deterministic;
//! * NaN stays NaN.

/// Round an `f32` to the nearest binary16 bit pattern (ties to even).
pub fn f16_from_f32(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7FFF_FFFF;
    if abs >= 0x7F80_0000 {
        // Infinity or NaN. Any NaN maps to a canonical quiet half NaN.
        return if abs > 0x7F80_0000 { sign | 0x7E00 } else { sign | 0x7C00 };
    }
    let e16 = (abs >> 23) as i32 - 127 + 15;
    if e16 >= 0x1F {
        return sign | 0x7C00; // overflow → ±∞
    }
    if e16 <= 0 {
        if e16 < -10 {
            return sign; // underflow past the smallest subnormal → ±0
        }
        // Subnormal result: shift the (implicit-bit) mantissa into place,
        // rounding to nearest even on the dropped bits.
        let man = (abs & 0x007F_FFFF) | 0x0080_0000;
        let shift = (14 - e16) as u32; // 14..=24
        let kept = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded = if rem > halfway || (rem == halfway && kept & 1 == 1) {
            kept + 1
        } else {
            kept
        };
        return sign | rounded as u16;
    }
    // Normal result: keep the top 10 mantissa bits, round on the low 13.
    let man = abs & 0x007F_FFFF;
    let kept = ((e16 as u32) << 10) | (man >> 13);
    let rem = man & 0x1FFF;
    let rounded = if rem > 0x1000 || (rem == 0x1000 && kept & 1 == 1) {
        kept + 1 // may carry into the exponent; 0x7C00 (±∞) is then correct
    } else {
        kept
    };
    sign | rounded as u16
}

/// Widen a binary16 bit pattern to `f32` (exact for every half value).
pub fn f32_from_f16(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x3FF) as u32;
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign); // ±0
        }
        // Subnormal: value = man · 2⁻²⁴ (exact in f32: man has ≤ 10 bits).
        let v = man as f32 * (1.0 / 16_777_216.0);
        return f32::from_bits(sign | v.to_bits());
    }
    if exp == 0x1F {
        return f32::from_bits(sign | 0x7F80_0000 | (man << 13)); // ±∞ / NaN
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for &v in &[
            0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, -2.25, 0.0999755859375, 65504.0, -65504.0,
            6.103515625e-5,  // smallest normal half
            5.9604645e-8,    // smallest subnormal half (2⁻²⁴)
        ] {
            let h = f16_from_f32(v);
            let back = f32_from_f16(h);
            assert_eq!(back.to_bits(), v.to_bits(), "{v} → {h:#06x} → {back}");
        }
    }

    #[test]
    fn double_roundtrip_is_stable() {
        // f32 → f16 → f32 → f16 must be a fixed point for every pattern.
        let mut h = 0u16;
        loop {
            let v = f32_from_f16(h);
            if !v.is_nan() {
                assert_eq!(f16_from_f32(v), h, "pattern {h:#06x}");
            }
            if h == u16::MAX {
                break;
            }
            h += 1;
        }
    }

    #[test]
    fn saturation_and_flush() {
        assert_eq!(f16_from_f32(1.0e9), 0x7C00);
        assert_eq!(f16_from_f32(-1.0e9), 0xFC00);
        assert_eq!(f16_from_f32(f32::INFINITY), 0x7C00);
        assert_eq!(f16_from_f32(f32::NEG_INFINITY), 0xFC00);
        assert_eq!(f16_from_f32(1.0e-10), 0x0000);
        assert_eq!(f16_from_f32(-1.0e-10), 0x8000);
        assert!(f32_from_f16(f16_from_f32(f32::NAN)).is_nan());
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2⁻¹¹ sits exactly between 1.0 and the next half (1 + 2⁻¹⁰):
        // ties-to-even keeps 1.0. Just above the tie rounds up.
        assert_eq!(f16_from_f32(1.0 + 0.00048828125), 0x3C00);
        assert_eq!(f16_from_f32(1.0 + 0.000489), 0x3C01);
        // 1 + 3·2⁻¹¹ ties between 0x3C01 and 0x3C02 → even (0x3C02).
        assert_eq!(f16_from_f32(1.0 + 3.0 * 0.00048828125), 0x3C02);
    }

    #[test]
    fn ordering_preserved_under_quantisation() {
        let mut prev = f32::NEG_INFINITY;
        for i in -100..=100 {
            let v = i as f32 * 0.37;
            let q = f32_from_f16(f16_from_f32(v));
            assert!(q >= prev, "quantisation must be monotone: {q} < {prev}");
            prev = q;
        }
    }
}
