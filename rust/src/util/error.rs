//! Minimal error handling (no `anyhow` offline): a message-chain error
//! type with the small API surface the crate uses — `Result`, `Error`,
//! the [`Context`] extension trait, and the `anyhow!` / `bail!` macros.
//!
//! Like `anyhow::Error`, [`Error`] deliberately does *not* implement
//! `std::error::Error`: that keeps the blanket `From<E: std::error::Error>`
//! conversion coherent, so `?` works on `io::Error` and friends.

use std::fmt;

/// A human-readable error message, possibly wrapping a cause.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }

    fn wrap(context: impl fmt::Display, cause: impl fmt::Display) -> Self {
        Error { msg: format!("{context}: {cause}") }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Crate-wide result type (defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::wrap(context, e))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::wrap(f(), e))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
///
/// Exported at the crate root (`use crate::anyhow;`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
///
/// Exported at the crate root (`use crate::bail;`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/3141592")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_chains_messages() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let o: Option<u32> = None;
        let e2 = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e2.to_string(), "missing 7");
    }

    #[test]
    fn bail_and_anyhow_format() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero not allowed (got {x})");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed (got 0)");
        assert_eq!(anyhow!("n={}", 2).to_string(), "n=2");
    }
}
