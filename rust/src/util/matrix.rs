//! Dense row-major `f32` matrix used for points and centroids.
//!
//! The clustering hot path works on flat `Vec<f32>` buffers; this wrapper
//! keeps the `(rows, cols)` shape attached and provides the small set of
//! views the kernels need without pulling in a linear-algebra crate.

/// Row-major dense matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { data: vec![0.0; rows * cols], rows, cols }
    }

    /// Wrap an existing buffer. Panics if `data.len() != rows * cols`.
    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "matrix shape mismatch");
        Matrix { data, rows, cols }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row view.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Full backing slice (row-major).
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Full mutable backing slice.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the backing vector.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Copy the given rows into a new matrix (gather).
    pub fn gather(&self, indices: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(indices.len(), self.cols);
        for (dst, &src) in indices.iter().enumerate() {
            out.row_mut(dst).copy_from_slice(self.row(src));
        }
        out
    }

    /// Element access (debug-checked).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Squared L2 norm of each row.
    pub fn row_sq_norms(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|i| self.row(i).iter().map(|x| x * x).sum())
            .collect()
    }

    /// Iterate rows.
    pub fn rows_iter(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_access() {
        let mut m = Matrix::zeros(3, 2);
        m.set(1, 1, 5.0);
        assert_eq!(m.get(1, 1), 5.0);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 2);
        assert_eq!(m.row(1), &[0.0, 5.0]);
    }

    #[test]
    fn gather_selects_rows() {
        let m = Matrix::from_vec(vec![1., 2., 3., 4., 5., 6.], 3, 2);
        let g = m.gather(&[2, 0]);
        assert_eq!(g.row(0), &[5., 6.]);
        assert_eq!(g.row(1), &[1., 2.]);
    }

    #[test]
    fn sq_norms() {
        let m = Matrix::from_vec(vec![3., 4., 0., 0.], 2, 2);
        assert_eq!(m.row_sq_norms(), vec![25.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch_panics() {
        Matrix::from_vec(vec![1.0; 5], 2, 3);
    }
}
