//! Deterministic pseudo-random number generation and sampling.
//!
//! The offline build has no `rand` crate, so this module provides the RNG
//! substrate the whole system uses: a SplitMix64-seeded xoshiro256++
//! generator plus the samplers the clustering algorithms need (uniform
//! integers without replacement, weighted discrete sampling for K-means++,
//! Gaussians via Box–Muller for the synthetic data generators).
//!
//! Everything is reproducible from a single `u64` seed; independent streams
//! are derived with [`Rng::split`] so parallel workers never share state.

/// xoshiro256++ PRNG (Blackman & Vigna). Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// SplitMix64 — used to expand a 64-bit seed into xoshiro state and to
/// derive independent child streams.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create an RNG from a 64-bit seed. Any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for parallel workers).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_A5A5_DEAD_BEEF)
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, bound). Uses Lemire's multiply-shift with
    /// rejection to avoid modulo bias.
    #[inline]
    pub fn usize(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "usize(bound): bound must be positive");
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound {
                return (m >> 64) as usize;
            }
            // rejection zone: lo < bound && lo < (2^64 mod bound)
            let t = bound.wrapping_neg() % bound;
            if lo >= t {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (one value per call; caches the pair).
    pub fn gaussian(&mut self) -> f64 {
        // Polar Box–Muller without caching keeps the struct Copy-free simple;
        // throughput is fine for data generation.
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Sample `count` distinct indices uniformly from [0, n) without
    /// replacement. O(count) expected when count ≪ n (hash-set rejection),
    /// O(n) partial Fisher–Yates otherwise.
    pub fn sample_indices(&mut self, n: usize, count: usize) -> Vec<usize> {
        assert!(count <= n, "cannot sample {count} distinct from {n}");
        if count * 3 >= n {
            // Partial Fisher–Yates over a full index vector.
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..count {
                let j = i + self.usize(n - i);
                idx.swap(i, j);
            }
            idx.truncate(count);
            idx
        } else {
            // Floyd's algorithm: count iterations, no O(n) allocation.
            let mut chosen = std::collections::HashSet::with_capacity(count * 2);
            let mut out = Vec::with_capacity(count);
            for j in (n - count)..n {
                let t = self.usize(j + 1);
                let pick = if chosen.contains(&t) { j } else { t };
                chosen.insert(pick);
                out.push(pick);
            }
            out
        }
    }

    /// Weighted discrete sampling: draw one index with P(i) ∝ weights[i].
    /// Weights must be non-negative with a positive sum; returns the last
    /// strictly-positive index if floating-point slack leaves the cursor
    /// past the end.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "weighted(): total weight must be > 0");
        let mut cursor = self.f64() * total;
        let mut last_pos = 0;
        for (i, &w) in weights.iter().enumerate() {
            if w > 0.0 {
                last_pos = i;
                if cursor < w {
                    return i;
                }
                cursor -= w;
            }
        }
        last_pos
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn usize_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = r.usize(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should be hit");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(9);
        for &(n, c) in &[(100usize, 5usize), (100, 90), (10, 10), (1, 1)] {
            let s = r.sample_indices(n, c);
            assert_eq!(s.len(), c);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), c, "indices must be distinct");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean} too far from 0");
        assert!((var - 1.0).abs() < 0.05, "var {var} too far from 1");
    }

    #[test]
    fn weighted_respects_zero_weights() {
        let mut r = Rng::new(13);
        let w = [0.0, 1.0, 0.0, 3.0, 0.0];
        let mut counts = [0usize; 5];
        for _ in 0..4_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[0] + counts[2] + counts[4], 0);
        let ratio = counts[3] as f64 / counts[1] as f64;
        assert!((2.0..4.5).contains(&ratio), "ratio {ratio} should be ~3");
    }

    #[test]
    fn weighted_concentrated_mass() {
        let mut r = Rng::new(17);
        let w = [0.0, 0.0, 5.0];
        for _ in 0..100 {
            assert_eq!(r.weighted(&w), 2);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut parent = Rng::new(42);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
