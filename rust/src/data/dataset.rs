//! In-memory dataset: a named `(m, n)` matrix of f32 features.

use crate::util::matrix::Matrix;

/// A dataset to cluster: `m` points in `n` dimensions, row-major.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    data: Matrix,
}

impl Dataset {
    pub fn new(name: impl Into<String>, data: Matrix) -> Self {
        Dataset { name: name.into(), data }
    }

    pub fn from_vec(name: impl Into<String>, data: Vec<f32>, m: usize, n: usize) -> Self {
        Dataset::new(name, Matrix::from_vec(data, m, n))
    }

    /// Number of points (paper's `m`).
    #[inline]
    pub fn m(&self) -> usize {
        self.data.rows()
    }

    /// Feature dimension (paper's `n`).
    #[inline]
    pub fn n(&self) -> usize {
        self.data.cols()
    }

    /// Flat row-major feature buffer.
    #[inline]
    pub fn points(&self) -> &[f32] {
        self.data.as_slice()
    }

    #[inline]
    pub fn matrix(&self) -> &Matrix {
        &self.data
    }

    pub fn matrix_mut(&mut self) -> &mut Matrix {
        &mut self.data
    }

    /// Gather a sample of rows into a new flat buffer.
    pub fn gather(&self, indices: &[usize]) -> Vec<f32> {
        let n = self.n();
        let mut out = Vec::with_capacity(indices.len() * n);
        for &i in indices {
            out.extend_from_slice(self.data.row(i));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let d = Dataset::from_vec("t", vec![1., 2., 3., 4., 5., 6.], 3, 2);
        assert_eq!(d.m(), 3);
        assert_eq!(d.n(), 2);
        assert_eq!(d.points().len(), 6);
    }

    #[test]
    fn gather_flattens_rows() {
        let d = Dataset::from_vec("t", vec![1., 2., 3., 4., 5., 6.], 3, 2);
        assert_eq!(d.gather(&[2, 0]), vec![5., 6., 1., 2.]);
    }
}
