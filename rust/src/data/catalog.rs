//! Catalog of benchmark datasets mirroring Table 1 of the paper.
//!
//! The real datasets (UCI / Kaggle / TSPLIB) are unavailable offline, so
//! each entry pairs the paper dataset's *shape profile* with a synthetic
//! generator of comparable difficulty (see DESIGN.md §Substitutions).
//! Sizes are scaled down by `SCALE` so the full evaluation suite runs in
//! minutes on a laptop while preserving the paper's *relative* structure:
//! the ordering by size, the chunk-size-to-m ratios, and the k-grid.
//! Normalized variants (min–max) mirror the paper's
//! "(normalized)" rows.

use crate::data::dataset::Dataset;
use crate::data::normalize::min_max_normalize;
use crate::data::synth::Synth;

/// The paper's k-grid (§5.7): every algorithm × dataset is run for each k.
pub const PAPER_K_GRID: [usize; 7] = [2, 3, 5, 10, 15, 20, 25];

/// One catalog entry = one experiment table in the paper's appendix.
#[derive(Clone, Debug)]
pub struct CatalogEntry {
    /// Paper dataset name.
    pub name: &'static str,
    /// Appendix table number of the summary table (e.g. 5 for Table 5).
    pub table: u32,
    /// Paper's (m, n) for reference.
    pub paper_m: usize,
    pub paper_n: usize,
    /// Scaled shape we generate.
    pub m: usize,
    pub n: usize,
    /// Scaled Big-means chunk size (paper's `s`, same m-ratio).
    pub chunk_size: usize,
    /// Scaled `cpu_max` budget (seconds) for Big-means' search phase.
    pub cpu_max_secs: f64,
    /// Min–max normalize after generation (the "(normalized)" variants).
    pub normalized: bool,
    /// Generator recipe.
    pub synth: Synth,
}

impl CatalogEntry {
    /// Generate the dataset (deterministic in `seed`).
    pub fn generate(&self, seed: u64) -> Dataset {
        let mut d = self.synth.generate(self.name, seed ^ (self.table as u64) << 32);
        if self.normalized {
            min_max_normalize(d.matrix_mut());
        }
        d
    }
}

fn gm(m: usize, n: usize, k_true: usize, spread: f64) -> Synth {
    Synth::GaussianMixture { m, n, k_true, spread, box_half_width: 20.0 }
}

fn noisy(m: usize, n: usize, k_true: usize, spread: f64, scale_max: f64) -> Synth {
    Synth::Noisy { m, n, k_true, spread, noise_frac: 0.08, scale_max }
}

/// The full 23-experiment catalog (19 datasets + 4 normalized variants),
/// ordered by descending paper size exactly like Table 1 + Table 3.
pub fn catalog() -> Vec<CatalogEntry> {
    // Scaled sizes keep m·n work ≤ ~2M cells for the largest sets.
    vec![
        CatalogEntry {
            name: "CORD-19 Embeddings",
            table: 5,
            paper_m: 599_616,
            paper_n: 768,
            m: 24_000,
            n: 96,
            chunk_size: 1280,
            cpu_max_secs: 1.2,
            normalized: false,
            synth: gm(24_000, 96, 12, 1.2),
        },
        CatalogEntry {
            name: "HEPMASS",
            table: 7,
            paper_m: 10_500_000,
            paper_n: 27,
            m: 160_000,
            n: 27,
            chunk_size: 1024,
            cpu_max_secs: 1.2,
            normalized: false,
            synth: gm(160_000, 27, 10, 1.0),
        },
        CatalogEntry {
            name: "US Census Data 1990",
            table: 9,
            paper_m: 2_458_285,
            paper_n: 68,
            m: 60_000,
            n: 68,
            chunk_size: 512,
            cpu_max_secs: 0.8,
            normalized: false,
            synth: noisy(60_000, 68, 8, 0.8, 8.0),
        },
        CatalogEntry {
            name: "Gisette",
            table: 11,
            paper_m: 13_500,
            paper_n: 5000,
            m: 6_000,
            n: 128,
            chunk_size: 2048,
            cpu_max_secs: 1.0,
            normalized: false,
            synth: gm(6_000, 128, 6, 2.0),
        },
        CatalogEntry {
            name: "Music Analysis",
            table: 13,
            paper_m: 106_574,
            paper_n: 518,
            m: 16_000,
            n: 64,
            chunk_size: 900,
            cpu_max_secs: 1.0,
            normalized: false,
            synth: gm(16_000, 64, 10, 1.5),
        },
        CatalogEntry {
            name: "Protein Homology",
            table: 15,
            paper_m: 145_751,
            paper_n: 74,
            m: 36_000,
            n: 74,
            chunk_size: 4096,
            cpu_max_secs: 1.0,
            normalized: false,
            synth: noisy(36_000, 74, 6, 1.0, 20.0),
        },
        CatalogEntry {
            name: "MiniBooNE Particle Identification",
            table: 17,
            paper_m: 130_064,
            paper_n: 50,
            m: 33_000,
            n: 50,
            chunk_size: 8192,
            cpu_max_secs: 1.0,
            normalized: false,
            synth: noisy(33_000, 50, 5, 0.8, 60.0),
        },
        CatalogEntry {
            name: "MiniBooNE Particle Identification (normalized)",
            table: 19,
            paper_m: 130_064,
            paper_n: 50,
            m: 33_000,
            n: 50,
            chunk_size: 3072,
            cpu_max_secs: 0.8,
            normalized: true,
            synth: noisy(33_000, 50, 5, 0.8, 60.0),
        },
        CatalogEntry {
            name: "MFCCs for Speech Emotion Recognition",
            table: 21,
            paper_m: 85_134,
            paper_n: 58,
            m: 22_000,
            n: 58,
            chunk_size: 3072,
            cpu_max_secs: 0.8,
            normalized: false,
            synth: gm(22_000, 58, 8, 0.7),
        },
        CatalogEntry {
            name: "ISOLET",
            table: 23,
            paper_m: 7_797,
            paper_n: 617,
            m: 4_000,
            n: 96,
            chunk_size: 1024,
            cpu_max_secs: 0.8,
            normalized: false,
            synth: gm(4_000, 96, 26, 1.2),
        },
        CatalogEntry {
            name: "Sensorless Drive Diagnosis",
            table: 25,
            paper_m: 58_509,
            paper_n: 48,
            m: 15_000,
            n: 48,
            chunk_size: 8192,
            cpu_max_secs: 0.6,
            normalized: false,
            synth: noisy(15_000, 48, 11, 0.6, 40.0),
        },
        CatalogEntry {
            name: "Sensorless Drive Diagnosis (normalized)",
            table: 27,
            paper_m: 58_509,
            paper_n: 48,
            m: 15_000,
            n: 48,
            chunk_size: 900,
            cpu_max_secs: 0.5,
            normalized: true,
            synth: noisy(15_000, 48, 11, 0.6, 40.0),
        },
        CatalogEntry {
            name: "Online News Popularity",
            table: 29,
            paper_m: 39_644,
            paper_n: 58,
            m: 10_000,
            n: 58,
            chunk_size: 2560,
            cpu_max_secs: 0.5,
            normalized: false,
            synth: noisy(10_000, 58, 7, 1.0, 30.0),
        },
        CatalogEntry {
            name: "Gas Sensor Array Drift",
            table: 31,
            paper_m: 13_910,
            paper_n: 128,
            m: 7_000,
            n: 128,
            chunk_size: 2304,
            cpu_max_secs: 0.8,
            normalized: false,
            synth: noisy(7_000, 128, 6, 1.5, 25.0),
        },
        CatalogEntry {
            name: "3D Road Network",
            table: 33,
            paper_m: 434_874,
            paper_n: 3,
            m: 110_000,
            n: 3,
            chunk_size: 25_000,
            cpu_max_secs: 0.6,
            normalized: false,
            synth: Synth::Sine { m: 110_000, n: 3, k_true: 40, spread: 0.35 },
        },
        CatalogEntry {
            name: "Skin Segmentation",
            table: 35,
            paper_m: 245_057,
            paper_n: 3,
            m: 62_000,
            n: 3,
            chunk_size: 2048,
            cpu_max_secs: 0.4,
            normalized: false,
            synth: Synth::RandomClusters { m: 62_000, n: 3, k_true: 12, max_spread: 3.0 },
        },
        CatalogEntry {
            name: "KEGG Metabolic Relation Network (Directed)",
            table: 37,
            paper_m: 53_413,
            paper_n: 20,
            m: 14_000,
            n: 20,
            chunk_size: 13_000,
            cpu_max_secs: 0.5,
            normalized: false,
            synth: noisy(14_000, 20, 8, 0.5, 80.0),
        },
        CatalogEntry {
            name: "Shuttle Control",
            table: 39,
            paper_m: 58_000,
            paper_n: 9,
            m: 15_000,
            n: 9,
            chunk_size: 14_500,
            cpu_max_secs: 0.5,
            normalized: false,
            synth: noisy(15_000, 9, 7, 0.4, 100.0),
        },
        CatalogEntry {
            name: "Shuttle Control (normalized)",
            table: 41,
            paper_m: 58_000,
            paper_n: 9,
            m: 15_000,
            n: 9,
            chunk_size: 512,
            cpu_max_secs: 0.3,
            normalized: true,
            synth: noisy(15_000, 9, 7, 0.4, 100.0),
        },
        CatalogEntry {
            name: "EEG Eye State",
            table: 43,
            paper_m: 14_980,
            paper_n: 14,
            m: 7_500,
            n: 14,
            chunk_size: 7_400,
            cpu_max_secs: 0.6,
            normalized: false,
            synth: noisy(7_500, 14, 5, 0.5, 200.0),
        },
        CatalogEntry {
            name: "EEG Eye State (normalized)",
            table: 45,
            paper_m: 14_980,
            paper_n: 14,
            m: 7_500,
            n: 14,
            chunk_size: 7_400,
            cpu_max_secs: 0.4,
            normalized: true,
            synth: noisy(7_500, 14, 5, 0.5, 200.0),
        },
        CatalogEntry {
            name: "Pla85900",
            table: 47,
            paper_m: 85_900,
            paper_n: 2,
            m: 22_000,
            n: 2,
            chunk_size: 3_600,
            cpu_max_secs: 0.4,
            normalized: false,
            synth: Synth::Grid { m: 22_000, n: 2, per_side: 6, spread: 1.2 },
        },
        CatalogEntry {
            name: "D15112",
            table: 49,
            paper_m: 15_112,
            paper_n: 2,
            m: 7_500,
            n: 2,
            chunk_size: 1_900,
            cpu_max_secs: 0.3,
            normalized: false,
            synth: Synth::Grid { m: 7_500, n: 2, per_side: 4, spread: 1.5 },
        },
    ]
}

/// Look up an entry by (case-insensitive prefix of) name.
pub fn find(name: &str) -> Option<CatalogEntry> {
    let lower = name.to_lowercase();
    catalog()
        .into_iter()
        .find(|e| e.name.to_lowercase().starts_with(&lower))
}

/// A small quick-run subset for smoke benches and examples.
pub fn quick_subset() -> Vec<CatalogEntry> {
    catalog()
        .into_iter()
        .filter(|e| {
            matches!(
                e.name,
                "Skin Segmentation" | "Shuttle Control" | "EEG Eye State" | "D15112"
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twenty_three_experiments_like_table3() {
        assert_eq!(catalog().len(), 23);
    }

    #[test]
    fn ordered_by_descending_paper_size() {
        // Table 3 / the appendix order, which the paper keeps size-sorted
        // except for one inversion it carries itself (Skin Segmentation is
        // listed before the slightly larger KEGG set).
        let sizes: Vec<usize> = catalog()
            .iter()
            .filter(|e| !e.normalized) // normalized rows interleave in the paper
            .map(|e| e.paper_m * e.paper_n)
            .collect();
        let inversions = sizes.windows(2).filter(|w| w[0] < w[1]).count();
        assert!(inversions <= 1, "at most the paper's own inversion: {sizes:?}");
        assert_eq!(sizes[0], *sizes.iter().max().unwrap(), "largest set first");
    }

    #[test]
    fn chunk_sizes_fit() {
        for e in catalog() {
            assert!(e.chunk_size <= e.m, "{}: s > m", e.name);
            assert!(e.chunk_size >= 128, "{}: s too small", e.name);
        }
    }

    #[test]
    fn generation_shape_and_determinism() {
        let e = find("D15112").unwrap();
        let a = e.generate(1);
        let b = e.generate(1);
        assert_eq!(a.m(), e.m);
        assert_eq!(a.n(), e.n);
        assert_eq!(a.points(), b.points());
    }

    #[test]
    fn normalized_entries_in_unit_box() {
        let e = find("EEG Eye State (norm").unwrap();
        assert!(e.normalized);
        let d = e.generate(3);
        for &v in d.points() {
            assert!((-1e-6..=1.0 + 1e-6).contains(&(v as f64)), "value {v} out of [0,1]");
        }
    }

    #[test]
    fn find_is_prefix_case_insensitive() {
        assert!(find("hepmass").is_some());
        assert!(find("HEPMASS").is_some());
        assert!(find("nonexistent dataset").is_none());
    }
}
