//! Feature normalization. The paper evaluates min–max normalized variants
//! of several datasets ("Min-max scaling was used for normalization of
//! data set values for better clusterization").

use crate::util::matrix::Matrix;

/// In-place min–max scaling per feature column to [0, 1]. Constant columns
/// map to 0. Returns the per-column (min, max) pairs for inverse mapping.
pub fn min_max_normalize(data: &mut Matrix) -> Vec<(f32, f32)> {
    let (m, n) = (data.rows(), data.cols());
    let mut ranges = vec![(f32::INFINITY, f32::NEG_INFINITY); n];
    for i in 0..m {
        let row = data.row(i);
        for j in 0..n {
            let v = row[j];
            if v < ranges[j].0 {
                ranges[j].0 = v;
            }
            if v > ranges[j].1 {
                ranges[j].1 = v;
            }
        }
    }
    for i in 0..m {
        let row = data.row_mut(i);
        for j in 0..n {
            let (lo, hi) = ranges[j];
            let span = hi - lo;
            row[j] = if span > 0.0 { (row[j] - lo) / span } else { 0.0 };
        }
    }
    ranges
}

/// Z-score standardization per column (mean 0, std 1). Constant columns
/// map to 0. Provided for API completeness; the paper uses min–max.
pub fn standardize(data: &mut Matrix) -> Vec<(f32, f32)> {
    let (m, n) = (data.rows(), data.cols());
    let mut stats = vec![(0f32, 0f32); n];
    for j in 0..n {
        let mut sum = 0f64;
        for i in 0..m {
            sum += data.get(i, j) as f64;
        }
        let mean = sum / m as f64;
        let mut var = 0f64;
        for i in 0..m {
            let d = data.get(i, j) as f64 - mean;
            var += d * d;
        }
        let std = (var / m as f64).sqrt();
        stats[j] = (mean as f32, std as f32);
    }
    for i in 0..m {
        for j in 0..n {
            let (mean, std) = stats[j];
            let v = data.get(i, j);
            data.set(i, j, if std > 0.0 { (v - mean) / std } else { 0.0 });
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_max_maps_to_unit_interval() {
        let mut m = Matrix::from_vec(vec![0.0, 10.0, 5.0, 20.0, 10.0, 30.0], 3, 2);
        let ranges = min_max_normalize(&mut m);
        assert_eq!(ranges, vec![(0.0, 10.0), (10.0, 30.0)]);
        assert_eq!(m.row(0), &[0.0, 0.0]);
        assert_eq!(m.row(1), &[0.5, 0.5]);
        assert_eq!(m.row(2), &[1.0, 1.0]);
    }

    #[test]
    fn constant_column_maps_to_zero() {
        let mut m = Matrix::from_vec(vec![7.0, 1.0, 7.0, 2.0], 2, 2);
        min_max_normalize(&mut m);
        assert_eq!(m.get(0, 0), 0.0);
        assert_eq!(m.get(1, 0), 0.0);
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut m = Matrix::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 3, 2);
        standardize(&mut m);
        for j in 0..2 {
            let mean: f32 = (0..3).map(|i| m.get(i, j)).sum::<f32>() / 3.0;
            let var: f32 = (0..3).map(|i| m.get(i, j).powi(2)).sum::<f32>() / 3.0;
            assert!(mean.abs() < 1e-6);
            assert!((var - 1.0).abs() < 1e-5);
        }
    }
}
