//! `.bmx` — the Big-means matrix format, built for out-of-core clustering.
//!
//! Layout (all little-endian):
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"BMX1"
//! 4       8     m      u64   number of rows
//! 12      4     n      u32   features per row
//! 16      m·n·4 data   f32   row-major feature matrix
//! ```
//!
//! The 16-byte header keeps the payload 4-byte aligned, so on little-endian
//! unix targets the file can be memory-mapped and reinterpreted as `&[f32]`
//! directly — chunk sampling then touches only the pages it draws, and the
//! OS page cache does the working-set management. Everywhere else (or when
//! `mmap` fails) a buffered positioned-read backend decodes the same bytes
//! explicitly, so results are identical across backends.
//!
//! [`BmxWriter`] streams rows out with O(1) memory (the row count is
//! patched into the header on [`BmxWriter::finish`]), which is how datasets
//! that never fit in RAM get produced in the first place.

use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::Mutex;

use crate::data::dataset::Dataset;
use crate::data::source::DataSource;
use crate::util::error::{Context, Result};
use crate::{anyhow, bail};

/// File magic: "BMX" + format version 1.
pub const BMX_MAGIC: [u8; 4] = *b"BMX1";

/// Header bytes before the payload (magic + u64 m + u32 n).
pub const BMX_HEADER_LEN: usize = 16;

/// Streaming `.bmx` writer: create, push row blocks, finish.
pub struct BmxWriter {
    w: BufWriter<File>,
    n: usize,
    rows: u64,
}

impl BmxWriter {
    /// Create `path`, writing a header with a zero row count (patched on
    /// [`BmxWriter::finish`]).
    pub fn create(path: &Path, n: usize) -> Result<Self> {
        if n == 0 || n > u32::MAX as usize {
            bail!("bmx: invalid feature count {n}");
        }
        let file = File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(file);
        w.write_all(&BMX_MAGIC)?;
        w.write_all(&0u64.to_le_bytes())?;
        w.write_all(&(n as u32).to_le_bytes())?;
        Ok(BmxWriter { w, n, rows: 0 })
    }

    /// Append one or more rows (`values.len()` must be a multiple of `n`).
    pub fn write_rows(&mut self, values: &[f32]) -> Result<()> {
        if values.len() % self.n != 0 {
            bail!(
                "bmx: write of {} values is not a whole number of {}-wide rows",
                values.len(),
                self.n
            );
        }
        let mut buf = [0u8; 4096];
        let mut filled = 0usize;
        for &v in values {
            buf[filled..filled + 4].copy_from_slice(&v.to_le_bytes());
            filled += 4;
            if filled == buf.len() {
                self.w.write_all(&buf)?;
                filled = 0;
            }
        }
        if filled > 0 {
            self.w.write_all(&buf[..filled])?;
        }
        self.rows += (values.len() / self.n) as u64;
        Ok(())
    }

    /// Flush, patch the row count into the header, and return it.
    pub fn finish(mut self) -> Result<u64> {
        self.w.flush()?;
        self.w.seek(SeekFrom::Start(4))?;
        self.w.write_all(&self.rows.to_le_bytes())?;
        self.w.flush()?;
        Ok(self.rows)
    }
}

/// Write an in-memory dataset out as `.bmx`.
pub fn save_bmx(ds: &Dataset, path: &Path) -> Result<()> {
    let mut w = BmxWriter::create(path, ds.n())?;
    w.write_rows(ds.points())?;
    let rows = w.finish()?;
    debug_assert_eq!(rows as usize, ds.m());
    Ok(())
}

#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
mod sys {
    //! Raw `mmap` FFI — the process links libc anyway, so no crate needed.
    use std::ffi::c_void;
    use std::os::raw::c_int;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
}

/// An owned read-only memory mapping of a whole file.
#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
struct MmapRegion {
    ptr: *mut std::ffi::c_void,
    len: usize,
}

// Safety: the region is read-only for its whole lifetime and unmapped only
// on drop, so shared references from any thread are fine.
#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
unsafe impl Send for MmapRegion {}
#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
unsafe impl Sync for MmapRegion {}

#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
impl MmapRegion {
    fn map(file: &File, len: usize) -> Option<MmapRegion> {
        use std::os::unix::io::AsRawFd;
        if len == 0 {
            return None;
        }
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 || ptr.is_null() {
            None
        } else {
            Some(MmapRegion { ptr, len })
        }
    }

    fn bytes(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }
}

#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
impl Drop for MmapRegion {
    fn drop(&mut self) {
        unsafe {
            sys::munmap(self.ptr, self.len);
        }
    }
}

enum Backing {
    /// Memory-mapped file; the payload is reinterpreted as `&[f32]` in
    /// place (little-endian 64-bit unix only).
    #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
    Mmap(MmapRegion),
    /// Portable fallback: positioned buffered reads decoding explicit
    /// little-endian bytes.
    Pread(Mutex<File>),
}

/// Out-of-core `.bmx` dataset: implements [`DataSource`] without loading
/// the payload.
pub struct BmxSource {
    name: String,
    m: usize,
    n: usize,
    backing: Backing,
}

/// Parse + validate the header; returns `(m, n, total_file_bytes)` with
/// every size arithmetic checked, so a corrupt or hostile header fails
/// here with a clean error instead of wrapping and panicking later.
fn read_header(file: &mut File, path: &Path) -> Result<(usize, usize, u64)> {
    let mut hdr = [0u8; BMX_HEADER_LEN];
    file.read_exact(&mut hdr)
        .with_context(|| format!("read bmx header of {}", path.display()))?;
    if hdr[0..4] != BMX_MAGIC {
        bail!("{}: not a .bmx file (bad magic)", path.display());
    }
    let m64 = u64::from_le_bytes(hdr[4..12].try_into().unwrap());
    let n = u32::from_le_bytes(hdr[12..16].try_into().unwrap()) as usize;
    if n == 0 {
        bail!("{}: bmx header has n = 0", path.display());
    }
    let need = m64
        .checked_mul(n as u64)
        .and_then(|c| c.checked_mul(4))
        .and_then(|c| c.checked_add(BMX_HEADER_LEN as u64))
        .ok_or_else(|| {
            anyhow!("{}: bmx header shape {m64}×{n} overflows", path.display())
        })?;
    if m64 > usize::MAX as u64 / 2 {
        bail!("{}: bmx row count {m64} not addressable", path.display());
    }
    let actual = file.metadata()?.len();
    if actual < need {
        bail!(
            "{}: truncated bmx payload ({} bytes, header promises {})",
            path.display(),
            actual,
            need
        );
    }
    Ok((m64 as usize, n, need))
}

impl BmxSource {
    /// Open `path`, preferring a memory mapping (falls back to buffered
    /// positioned reads when mapping is unavailable).
    pub fn open(path: &Path) -> Result<BmxSource> {
        let mut file = File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let (m, n, total) = read_header(&mut file, path)?;
        let name = stem(path);
        #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
        {
            if let Some(region) = MmapRegion::map(&file, total as usize) {
                return Ok(BmxSource { name, m, n, backing: Backing::Mmap(region) });
            }
        }
        let _ = total;
        Ok(BmxSource { name, m, n, backing: Backing::Pread(Mutex::new(file)) })
    }

    /// Open `path` with the buffered-pread backend unconditionally (tests,
    /// and platforms where mapping misbehaves).
    pub fn open_buffered(path: &Path) -> Result<BmxSource> {
        let mut file = File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let (m, n, _total) = read_header(&mut file, path)?;
        Ok(BmxSource {
            name: stem(path),
            m,
            n,
            backing: Backing::Pread(Mutex::new(file)),
        })
    }

    /// True when the payload is memory-mapped (vs buffered reads).
    pub fn is_mmap(&self) -> bool {
        #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
        {
            matches!(self.backing, Backing::Mmap(_))
        }
        #[cfg(not(all(unix, target_endian = "little", target_pointer_width = "64")))]
        {
            false
        }
    }

    #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
    fn mapped_data(region: &MmapRegion, m: usize, n: usize) -> &[f32] {
        let payload = &region.bytes()[BMX_HEADER_LEN..BMX_HEADER_LEN + m * n * 4];
        debug_assert_eq!(payload.as_ptr() as usize % std::mem::align_of::<f32>(), 0);
        // Safety: the slice is in-bounds, 4-byte aligned (page base + 16),
        // lives as long as `region`, and every bit pattern is a valid f32.
        unsafe { std::slice::from_raw_parts(payload.as_ptr() as *const f32, m * n) }
    }

    /// Positioned read of rows starting at `start` into `out`, under an
    /// already-held file lock, reusing `scratch` for the byte staging —
    /// callers doing many reads (chunk gathers) lock and allocate once.
    fn pread_into(&self, f: &mut File, scratch: &mut Vec<u8>, start: usize, out: &mut [f32]) {
        let byte_off = BMX_HEADER_LEN as u64 + (start as u64) * (self.n as u64) * 4;
        f.seek(SeekFrom::Start(byte_off))
            .unwrap_or_else(|e| panic!("bmx '{}': seek failed: {e}", self.name));
        scratch.resize(out.len() * 4, 0);
        f.read_exact(&mut scratch[..])
            .unwrap_or_else(|e| panic!("bmx '{}': read failed: {e}", self.name));
        for (dst, src) in out.iter_mut().zip(scratch.chunks_exact(4)) {
            *dst = f32::from_le_bytes(src.try_into().unwrap());
        }
    }
}

impl DataSource for BmxSource {
    fn name(&self) -> &str {
        &self.name
    }

    fn m(&self) -> usize {
        self.m
    }

    fn n(&self) -> usize {
        self.n
    }

    fn read_rows(&self, start: usize, out: &mut [f32]) {
        assert_eq!(out.len() % self.n, 0, "read_rows: out shape");
        let rows = out.len() / self.n;
        assert!(start + rows <= self.m, "read_rows: range out of bounds");
        match &self.backing {
            #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
            Backing::Mmap(region) => {
                let data = Self::mapped_data(region, self.m, self.n);
                out.copy_from_slice(&data[start * self.n..(start + rows) * self.n]);
            }
            Backing::Pread(file) => {
                let mut f = file.lock().unwrap();
                let mut scratch = Vec::new();
                self.pread_into(&mut f, &mut scratch, start, out);
            }
        }
    }

    fn sample_rows(&self, indices: &[usize], out: &mut [f32]) {
        let n = self.n;
        assert_eq!(out.len(), indices.len() * n, "sample_rows: out shape");
        match &self.backing {
            #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
            Backing::Mmap(region) => {
                let data = Self::mapped_data(region, self.m, self.n);
                for (slot, &i) in indices.iter().enumerate() {
                    out[slot * n..(slot + 1) * n]
                        .copy_from_slice(&data[i * n..(i + 1) * n]);
                }
            }
            Backing::Pread(file) => {
                // One lock + one scratch buffer for the whole gather.
                let mut f = file.lock().unwrap();
                let mut scratch = Vec::new();
                for (slot, &i) in indices.iter().enumerate() {
                    self.pread_into(&mut f, &mut scratch, i, &mut out[slot * n..(slot + 1) * n]);
                }
            }
        }
    }

    fn contiguous(&self) -> Option<&[f32]> {
        match &self.backing {
            #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
            Backing::Mmap(region) => Some(Self::mapped_data(region, self.m, self.n)),
            Backing::Pread(_) => None,
        }
    }
}

fn stem(path: &Path) -> String {
    path.file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "bmx".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bigmeans_bmx_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{name}", std::process::id()))
    }

    fn toy() -> Dataset {
        Dataset::from_vec(
            "toy",
            (0..40).map(|x| x as f32 * 0.5 - 7.25).collect(),
            10,
            4,
        )
    }

    #[test]
    fn roundtrip_via_writer() {
        let p = tmp("roundtrip.bmx");
        let d = toy();
        save_bmx(&d, &p).unwrap();
        let src = BmxSource::open(&p).unwrap();
        assert_eq!(src.m(), 10);
        assert_eq!(src.n(), 4);
        let mut all = vec![0f32; 40];
        src.read_rows(0, &mut all);
        assert_eq!(all, d.points());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn streamed_writer_patches_row_count() {
        let p = tmp("streamed.bmx");
        let mut w = BmxWriter::create(&p, 3).unwrap();
        w.write_rows(&[1.0, 2.0, 3.0]).unwrap();
        w.write_rows(&[4.0, 5.0, 6.0, 7.0, 8.0, 9.0]).unwrap();
        assert_eq!(w.finish().unwrap(), 3);
        let src = BmxSource::open(&p).unwrap();
        assert_eq!((src.m(), src.n()), (3, 3));
        let mut row = vec![0f32; 3];
        src.read_rows(2, &mut row);
        assert_eq!(row, vec![7.0, 8.0, 9.0]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn mmap_and_buffered_agree() {
        let p = tmp("agree.bmx");
        let d = toy();
        save_bmx(&d, &p).unwrap();
        let fast = BmxSource::open(&p).unwrap();
        let slow = BmxSource::open_buffered(&p).unwrap();
        assert!(!slow.is_mmap());
        let idx = [9usize, 0, 4, 4, 7];
        let mut a = vec![0f32; idx.len() * 4];
        let mut b = vec![0f32; idx.len() * 4];
        fast.sample_rows(&idx, &mut a);
        slow.sample_rows(&idx, &mut b);
        assert_eq!(a, b);
        assert_eq!(a, d.gather(&idx));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn contiguous_only_for_mmap() {
        let p = tmp("contig.bmx");
        save_bmx(&toy(), &p).unwrap();
        let fast = BmxSource::open(&p).unwrap();
        let slow = BmxSource::open_buffered(&p).unwrap();
        assert!(slow.contiguous().is_none());
        if fast.is_mmap() {
            assert_eq!(fast.contiguous().unwrap(), toy().points());
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn bad_magic_and_truncation_rejected() {
        let p = tmp("bad.bmx");
        std::fs::write(&p, b"NOPE............").unwrap();
        assert!(BmxSource::open(&p).is_err());
        // Valid header promising more rows than the payload holds.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&BMX_MAGIC);
        bytes.extend_from_slice(&5u64.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&1.0f32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(BmxSource::open(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }
}
