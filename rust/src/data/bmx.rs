//! `.bmx` — the Big-means matrix format, built for out-of-core clustering.
//!
//! Current (version 2) layout, all little-endian:
//!
//! ```text
//! offset  size  field
//! 0       4     magic     b"BMX2"  ("BMX" + ASCII version byte)
//! 4       8     m         u64   number of rows
//! 12      4     n         u32   features per row
//! 16      4     checksum  u32   CRC-32 (IEEE) of the payload bytes
//! 20      12    reserved  zeroed (future: dtype tag, flags)
//! 32      m·n·4 data      f32   row-major feature matrix
//! ```
//!
//! The 32-byte header keeps the payload 4-byte aligned, so on little-endian
//! unix targets the file can be memory-mapped and reinterpreted as `&[f32]`
//! directly — chunk sampling then touches only the pages it draws, and the
//! OS page cache does the working-set management. Everywhere else (or when
//! `mmap` fails) a buffered positioned-read backend decodes the same bytes
//! explicitly, so results are identical across backends.
//!
//! The checksum is validated once on open (a clear error beats silently
//! clustering corrupt floats) for payloads up to
//! [`BMX_VERIFY_EAGER_LIMIT`]; beyond that the scan would defeat the
//! out-of-core design, so it is skipped with a note. Legacy version-1
//! files (16-byte header, no checksum) still load, with a warning
//! suggesting reconversion.
//!
//! Mapped sources forward [`AccessPattern`] hints to `madvise` —
//! `MADV_RANDOM` while chunks are sampled, `MADV_SEQUENTIAL` for the
//! blocked final pass — through the dependency-free
//! [`crate::util::mem`] shim.
//!
//! [`BmxWriter`] streams rows out with O(1) memory (the row count and
//! checksum are patched into the header on [`BmxWriter::finish`]), which is
//! how datasets that never fit in RAM get produced in the first place.

use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::Mutex;

use crate::data::dataset::Dataset;
use crate::data::source::{AccessPattern, DataSource};
use crate::util::error::{Context, Result};
use crate::util::hash::{crc32, Crc32};
use crate::{anyhow, bail};

/// Legacy file magic: "BMX" + format version 1 (no checksum).
pub const BMX_MAGIC: [u8; 4] = *b"BMX1";

/// Current file magic: "BMX" + format version 2 (CRC-32 in the header).
pub const BMX_MAGIC_V2: [u8; 4] = *b"BMX2";

/// Header bytes before the payload in a version-1 file.
pub const BMX_HEADER_LEN: usize = 16;

/// Header bytes before the payload in a version-2 file.
pub const BMX_HEADER_LEN_V2: usize = 32;

/// Streaming `.bmx` writer: create, push row blocks, finish. Writes the
/// current (version 2) format, folding the payload into a running CRC-32.
pub struct BmxWriter {
    w: BufWriter<File>,
    n: usize,
    rows: u64,
    crc: Crc32,
}

impl BmxWriter {
    /// Create `path`, writing a header with a zero row count and checksum
    /// (both patched on [`BmxWriter::finish`]).
    pub fn create(path: &Path, n: usize) -> Result<Self> {
        if n == 0 || n > u32::MAX as usize {
            bail!("bmx: invalid feature count {n}");
        }
        let file = File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(file);
        w.write_all(&BMX_MAGIC_V2)?;
        w.write_all(&0u64.to_le_bytes())?;
        w.write_all(&(n as u32).to_le_bytes())?;
        w.write_all(&0u32.to_le_bytes())?; // checksum placeholder
        w.write_all(&[0u8; BMX_HEADER_LEN_V2 - 20])?; // reserved
        Ok(BmxWriter { w, n, rows: 0, crc: Crc32::new() })
    }

    /// Append one or more rows (`values.len()` must be a multiple of `n`).
    pub fn write_rows(&mut self, values: &[f32]) -> Result<()> {
        if values.len() % self.n != 0 {
            bail!(
                "bmx: write of {} values is not a whole number of {}-wide rows",
                values.len(),
                self.n
            );
        }
        let mut buf = [0u8; 4096];
        let mut filled = 0usize;
        for &v in values {
            buf[filled..filled + 4].copy_from_slice(&v.to_le_bytes());
            filled += 4;
            if filled == buf.len() {
                self.crc.update(&buf);
                self.w.write_all(&buf)?;
                filled = 0;
            }
        }
        if filled > 0 {
            self.crc.update(&buf[..filled]);
            self.w.write_all(&buf[..filled])?;
        }
        self.rows += (values.len() / self.n) as u64;
        Ok(())
    }

    /// Flush, patch the row count and payload checksum into the header,
    /// and return the row count.
    pub fn finish(mut self) -> Result<u64> {
        self.w.flush()?;
        self.w.seek(SeekFrom::Start(4))?;
        self.w.write_all(&self.rows.to_le_bytes())?;
        self.w.seek(SeekFrom::Start(16))?;
        self.w.write_all(&self.crc.finalize().to_le_bytes())?;
        self.w.flush()?;
        Ok(self.rows)
    }
}

/// Write an in-memory dataset out as `.bmx`.
pub fn save_bmx(ds: &Dataset, path: &Path) -> Result<()> {
    let mut w = BmxWriter::create(path, ds.n())?;
    w.write_rows(ds.points())?;
    let rows = w.finish()?;
    debug_assert_eq!(rows as usize, ds.m());
    Ok(())
}

#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
use crate::util::mem::MmapRegion;

enum Backing {
    /// Memory-mapped file; the payload is reinterpreted as `&[f32]` in
    /// place (little-endian 64-bit unix only).
    #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
    Mmap(MmapRegion),
    /// Portable fallback: positioned buffered reads decoding explicit
    /// little-endian bytes.
    Pread(Mutex<File>),
}

/// Out-of-core `.bmx` dataset: implements [`DataSource`] without loading
/// the payload.
pub struct BmxSource {
    name: String,
    m: usize,
    n: usize,
    header_len: usize,
    backing: Backing,
}

/// Parsed `.bmx` header.
struct BmxHeader {
    m: usize,
    n: usize,
    /// Payload offset (16 for v1, 32 for v2).
    header_len: usize,
    /// Expected CRC-32 of the payload (v2 files only).
    checksum: Option<u32>,
    /// Header + payload bytes the file must hold.
    need: u64,
}

/// Parse + validate the header, with every size arithmetic checked, so a
/// corrupt or hostile header fails here with a clean error instead of
/// wrapping and panicking later. Accepts both the current v2 layout and
/// legacy v1 (the caller warns about the missing checksum).
fn read_header(file: &mut File, path: &Path) -> Result<BmxHeader> {
    let mut hdr = [0u8; BMX_HEADER_LEN];
    file.read_exact(&mut hdr)
        .with_context(|| format!("read bmx header of {}", path.display()))?;
    let (header_len, versioned) = if hdr[0..4] == BMX_MAGIC_V2 {
        (BMX_HEADER_LEN_V2, true)
    } else if hdr[0..4] == BMX_MAGIC {
        (BMX_HEADER_LEN, false)
    } else if hdr[0..4] == crate::store::format::BMX3_MAGIC {
        bail!(
            "{}: .bmx v3 block-store file — open it through the block backend \
             (`--backend block`) / `crate::store::BlockStore`, not the legacy reader",
            path.display()
        );
    } else {
        bail!("{}: not a .bmx file (bad magic)", path.display());
    };
    let m64 = u64::from_le_bytes(hdr[4..12].try_into().unwrap());
    let n = u32::from_le_bytes(hdr[12..16].try_into().unwrap()) as usize;
    let checksum = if versioned {
        let mut ext = [0u8; BMX_HEADER_LEN_V2 - BMX_HEADER_LEN];
        file.read_exact(&mut ext)
            .with_context(|| format!("read bmx v2 header of {}", path.display()))?;
        Some(u32::from_le_bytes(ext[0..4].try_into().unwrap()))
    } else {
        None
    };
    if n == 0 {
        bail!("{}: bmx header has n = 0", path.display());
    }
    let need = m64
        .checked_mul(n as u64)
        .and_then(|c| c.checked_mul(4))
        .and_then(|c| c.checked_add(header_len as u64))
        .ok_or_else(|| {
            anyhow!("{}: bmx header shape {m64}×{n} overflows", path.display())
        })?;
    if m64 > usize::MAX as u64 / 2 {
        bail!("{}: bmx row count {m64} not addressable", path.display());
    }
    let actual = file.metadata()?.len();
    if actual < need {
        bail!(
            "{}: truncated bmx payload ({} bytes, header promises {})",
            path.display(),
            actual,
            need
        );
    }
    Ok(BmxHeader { m: m64 as usize, n, header_len, checksum, need })
}

/// Largest payload validated eagerly on open. Above this, the full-file
/// CRC scan would defeat the out-of-core point of the format (an O(1)
/// open turning into minutes of cold I/O that also evicts the page
/// cache), so validation is skipped with a stderr note instead — the
/// checksum stays in the header for explicit offline verification.
pub const BMX_VERIFY_EAGER_LIMIT: u64 = 4 << 30;

/// Whether to validate `hdr`'s checksum at open time; warns when the
/// payload is too large to scan eagerly.
fn should_verify(hdr: &BmxHeader, path: &Path) -> bool {
    if hdr.checksum.is_none() {
        return false;
    }
    let payload = hdr.need - hdr.header_len as u64;
    if payload > BMX_VERIFY_EAGER_LIMIT {
        crate::log_info!(
            "data.bmx",
            "skipping checksum validation of {} ({payload} payload bytes \
             exceeds the {BMX_VERIFY_EAGER_LIMIT}-byte eager-verify limit)",
            path.display()
        );
        return false;
    }
    true
}

/// Compare an expected vs computed payload CRC, failing with the (single,
/// shared) corruption diagnostic.
fn check_crc(expected: u32, computed: u32, path: &Path) -> Result<()> {
    if computed != expected {
        bail!(
            "{}: bmx payload checksum mismatch (file corrupt or truncated mid-write); \
             expected {expected:#010x}, computed {computed:#010x}",
            path.display()
        );
    }
    Ok(())
}

/// Validate the payload checksum through buffered reads (the non-mmap
/// path), leaving the file position unspecified.
fn verify_crc_pread(file: &mut File, hdr: &BmxHeader, path: &Path) -> Result<()> {
    if !should_verify(hdr, path) {
        return Ok(());
    }
    let expected = hdr.checksum.expect("should_verify requires a checksum");
    file.seek(SeekFrom::Start(hdr.header_len as u64))?;
    let payload = hdr.need - hdr.header_len as u64;
    let mut crc = Crc32::new();
    let mut buf = vec![0u8; (1usize << 20).min(payload.max(1) as usize)];
    let mut left = payload;
    while left > 0 {
        let take = buf.len().min(left as usize);
        file.read_exact(&mut buf[..take])
            .with_context(|| format!("read bmx payload of {}", path.display()))?;
        crc.update(&buf[..take]);
        left -= take as u64;
    }
    check_crc(expected, crc.finalize(), path)
}

/// Explicit offline integrity check of a v2 file: CRC the whole payload
/// through buffered reads **regardless** of [`BMX_VERIFY_EAGER_LIMIT`]
/// (this is the scan the open-time note defers to). Returns the payload
/// byte count. v1 files fail (nothing to verify against); v3 files are
/// verified per block by the store instead.
pub fn verify_bmx(path: &Path) -> Result<u64> {
    let mut file = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let hdr = read_header(&mut file, path)?;
    let Some(expected) = hdr.checksum else {
        bail!(
            "{}: legacy v1 .bmx carries no checksum — reconvert (`bigmeans convert`) \
             to get integrity checking",
            path.display()
        );
    };
    let payload = hdr.need - hdr.header_len as u64;
    file.seek(SeekFrom::Start(hdr.header_len as u64))?;
    let mut crc = Crc32::new();
    let mut buf = vec![0u8; (1usize << 20).min(payload.max(1) as usize)];
    let mut left = payload;
    while left > 0 {
        let take = buf.len().min(left as usize);
        file.read_exact(&mut buf[..take])
            .with_context(|| format!("read bmx payload of {}", path.display()))?;
        crc.update(&buf[..take]);
        left -= take as u64;
    }
    check_crc(expected, crc.finalize(), path)?;
    Ok(payload)
}

/// Warn (once per open) when a legacy v1 file without a checksum loads.
fn warn_v1(hdr: &BmxHeader, path: &Path) {
    if hdr.checksum.is_none() {
        crate::log_warn!(
            "data.bmx",
            "{} is a v1 .bmx without a payload checksum; rewrite it \
             (`bigmeans convert` / `generate`) to add integrity checking",
            path.display()
        );
    }
}

impl BmxSource {
    /// Open `path`, preferring a memory mapping (falls back to buffered
    /// positioned reads when mapping is unavailable). Version-2 files have
    /// their payload CRC validated here — a corrupt file fails to open
    /// instead of clustering garbage; v1 files load with a warning.
    pub fn open(path: &Path) -> Result<BmxSource> {
        let mut file = File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let hdr = read_header(&mut file, path)?;
        warn_v1(&hdr, path);
        let name = stem(path);
        #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
        {
            if let Some(region) = MmapRegion::map(&file, hdr.need as usize) {
                if should_verify(&hdr, path) {
                    let expected = hdr.checksum.expect("should_verify requires a checksum");
                    // One sequential pass over the mapping, then drop back
                    // to the random-access default for chunk sampling.
                    region.advise(AccessPattern::Sequential.advice());
                    let payload =
                        &region.bytes()[hdr.header_len..hdr.need as usize];
                    let computed = crc32(payload);
                    region.advise(AccessPattern::Random.advice());
                    check_crc(expected, computed, path)?;
                } else {
                    region.advise(AccessPattern::Random.advice());
                }
                return Ok(BmxSource {
                    name,
                    m: hdr.m,
                    n: hdr.n,
                    header_len: hdr.header_len,
                    backing: Backing::Mmap(region),
                });
            }
        }
        verify_crc_pread(&mut file, &hdr, path)?;
        Ok(BmxSource {
            name,
            m: hdr.m,
            n: hdr.n,
            header_len: hdr.header_len,
            backing: Backing::Pread(Mutex::new(file)),
        })
    }

    /// Open `path` with the buffered-pread backend unconditionally (tests,
    /// and platforms where mapping misbehaves).
    pub fn open_buffered(path: &Path) -> Result<BmxSource> {
        let mut file = File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let hdr = read_header(&mut file, path)?;
        warn_v1(&hdr, path);
        verify_crc_pread(&mut file, &hdr, path)?;
        Ok(BmxSource {
            name: stem(path),
            m: hdr.m,
            n: hdr.n,
            header_len: hdr.header_len,
            backing: Backing::Pread(Mutex::new(file)),
        })
    }

    /// True when the payload is memory-mapped (vs buffered reads).
    pub fn is_mmap(&self) -> bool {
        #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
        {
            matches!(self.backing, Backing::Mmap(_))
        }
        #[cfg(not(all(unix, target_endian = "little", target_pointer_width = "64")))]
        {
            false
        }
    }

    #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
    fn mapped_data(region: &MmapRegion, header_len: usize, m: usize, n: usize) -> &[f32] {
        let payload = &region.bytes()[header_len..header_len + m * n * 4];
        debug_assert_eq!(payload.as_ptr() as usize % std::mem::align_of::<f32>(), 0);
        // Safety: the slice is in-bounds, 4-byte aligned (page base + a
        // 4-byte-multiple header), lives as long as `region`, and every
        // bit pattern is a valid f32.
        unsafe { std::slice::from_raw_parts(payload.as_ptr() as *const f32, m * n) }
    }

    /// Positioned read of rows starting at `start` into `out`, under an
    /// already-held file lock, reusing `scratch` for the byte staging —
    /// callers doing many reads (chunk gathers) lock and allocate once.
    fn pread_into(&self, f: &mut File, scratch: &mut Vec<u8>, start: usize, out: &mut [f32]) {
        let byte_off = self.header_len as u64 + (start as u64) * (self.n as u64) * 4;
        f.seek(SeekFrom::Start(byte_off))
            .unwrap_or_else(|e| panic!("bmx '{}': seek failed: {e}", self.name));
        scratch.resize(out.len() * 4, 0);
        f.read_exact(&mut scratch[..])
            .unwrap_or_else(|e| panic!("bmx '{}': read failed: {e}", self.name));
        for (dst, src) in out.iter_mut().zip(scratch.chunks_exact(4)) {
            *dst = f32::from_le_bytes(src.try_into().unwrap());
        }
    }
}

impl DataSource for BmxSource {
    fn name(&self) -> &str {
        &self.name
    }

    fn m(&self) -> usize {
        self.m
    }

    fn n(&self) -> usize {
        self.n
    }

    fn read_rows(&self, start: usize, out: &mut [f32]) {
        assert_eq!(out.len() % self.n, 0, "read_rows: out shape");
        let rows = out.len() / self.n;
        assert!(start + rows <= self.m, "read_rows: range out of bounds");
        match &self.backing {
            #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
            Backing::Mmap(region) => {
                let data = Self::mapped_data(region, self.header_len, self.m, self.n);
                out.copy_from_slice(&data[start * self.n..(start + rows) * self.n]);
            }
            Backing::Pread(file) => {
                let mut f = file.lock().unwrap();
                let mut scratch = Vec::new();
                self.pread_into(&mut f, &mut scratch, start, out);
            }
        }
    }

    fn sample_rows(&self, indices: &[usize], out: &mut [f32]) {
        let n = self.n;
        assert_eq!(out.len(), indices.len() * n, "sample_rows: out shape");
        match &self.backing {
            #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
            Backing::Mmap(region) => {
                let data = Self::mapped_data(region, self.header_len, self.m, self.n);
                for (slot, &i) in indices.iter().enumerate() {
                    out[slot * n..(slot + 1) * n]
                        .copy_from_slice(&data[i * n..(i + 1) * n]);
                }
            }
            Backing::Pread(file) => {
                // One lock + one scratch buffer for the whole gather.
                let mut f = file.lock().unwrap();
                let mut scratch = Vec::new();
                for (slot, &i) in indices.iter().enumerate() {
                    self.pread_into(&mut f, &mut scratch, i, &mut out[slot * n..(slot + 1) * n]);
                }
            }
        }
    }

    fn contiguous(&self) -> Option<&[f32]> {
        match &self.backing {
            #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
            Backing::Mmap(region) => {
                Some(Self::mapped_data(region, self.header_len, self.m, self.n))
            }
            Backing::Pread(_) => None,
        }
    }

    fn advise(&self, pattern: AccessPattern) {
        match &self.backing {
            #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
            Backing::Mmap(region) => region.advise(pattern.advice()),
            Backing::Pread(_) => {}
        }
    }
}

fn stem(path: &Path) -> String {
    path.file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "bmx".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bigmeans_bmx_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{name}", std::process::id()))
    }

    fn toy() -> Dataset {
        Dataset::from_vec(
            "toy",
            (0..40).map(|x| x as f32 * 0.5 - 7.25).collect(),
            10,
            4,
        )
    }

    #[test]
    fn roundtrip_via_writer() {
        let p = tmp("roundtrip.bmx");
        let d = toy();
        save_bmx(&d, &p).unwrap();
        let src = BmxSource::open(&p).unwrap();
        assert_eq!(src.m(), 10);
        assert_eq!(src.n(), 4);
        let mut all = vec![0f32; 40];
        src.read_rows(0, &mut all);
        assert_eq!(all, d.points());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn streamed_writer_patches_row_count() {
        let p = tmp("streamed.bmx");
        let mut w = BmxWriter::create(&p, 3).unwrap();
        w.write_rows(&[1.0, 2.0, 3.0]).unwrap();
        w.write_rows(&[4.0, 5.0, 6.0, 7.0, 8.0, 9.0]).unwrap();
        assert_eq!(w.finish().unwrap(), 3);
        let src = BmxSource::open(&p).unwrap();
        assert_eq!((src.m(), src.n()), (3, 3));
        let mut row = vec![0f32; 3];
        src.read_rows(2, &mut row);
        assert_eq!(row, vec![7.0, 8.0, 9.0]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn mmap_and_buffered_agree() {
        let p = tmp("agree.bmx");
        let d = toy();
        save_bmx(&d, &p).unwrap();
        let fast = BmxSource::open(&p).unwrap();
        let slow = BmxSource::open_buffered(&p).unwrap();
        assert!(!slow.is_mmap());
        let idx = [9usize, 0, 4, 4, 7];
        let mut a = vec![0f32; idx.len() * 4];
        let mut b = vec![0f32; idx.len() * 4];
        fast.sample_rows(&idx, &mut a);
        slow.sample_rows(&idx, &mut b);
        assert_eq!(a, b);
        assert_eq!(a, d.gather(&idx));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn contiguous_only_for_mmap() {
        let p = tmp("contig.bmx");
        save_bmx(&toy(), &p).unwrap();
        let fast = BmxSource::open(&p).unwrap();
        let slow = BmxSource::open_buffered(&p).unwrap();
        assert!(slow.contiguous().is_none());
        if fast.is_mmap() {
            assert_eq!(fast.contiguous().unwrap(), toy().points());
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn corrupt_payload_rejected_by_checksum() {
        let p = tmp("corrupt.bmx");
        save_bmx(&toy(), &p).unwrap();
        // Flip one payload byte; both open paths must refuse the file.
        let mut bytes = std::fs::read(&p).unwrap();
        let idx = BMX_HEADER_LEN_V2 + 17;
        bytes[idx] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let err = BmxSource::open(&p).unwrap_err().to_string();
        assert!(err.contains("checksum"), "unexpected error: {err}");
        let err = BmxSource::open_buffered(&p).unwrap_err().to_string();
        assert!(err.contains("checksum"), "unexpected error: {err}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn legacy_v1_files_still_load() {
        // Hand-build a v1 file (16-byte header, no checksum): it must load
        // (with a warning on stderr) and serve identical values.
        let p = tmp("legacy.bmx");
        let d = toy();
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&BMX_MAGIC);
        bytes.extend_from_slice(&(d.m() as u64).to_le_bytes());
        bytes.extend_from_slice(&(d.n() as u32).to_le_bytes());
        for &v in d.points() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&p, &bytes).unwrap();
        for src in [BmxSource::open(&p).unwrap(), BmxSource::open_buffered(&p).unwrap()] {
            assert_eq!((src.m(), src.n()), (d.m(), d.n()));
            let mut all = vec![0f32; d.m() * d.n()];
            src.read_rows(0, &mut all);
            assert_eq!(all, d.points());
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn advise_is_safe_on_both_backends() {
        let p = tmp("advise.bmx");
        save_bmx(&toy(), &p).unwrap();
        for src in [BmxSource::open(&p).unwrap(), BmxSource::open_buffered(&p).unwrap()] {
            src.advise(AccessPattern::Random);
            src.advise(AccessPattern::Sequential);
            src.advise(AccessPattern::Normal);
            let mut row = vec![0f32; 4];
            src.read_rows(3, &mut row);
            assert_eq!(row, &toy().points()[12..16]);
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn bad_magic_and_truncation_rejected() {
        let p = tmp("bad.bmx");
        std::fs::write(&p, b"NOPE............").unwrap();
        assert!(BmxSource::open(&p).is_err());
        // Valid header promising more rows than the payload holds.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&BMX_MAGIC);
        bytes.extend_from_slice(&5u64.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&1.0f32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(BmxSource::open(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }
}
