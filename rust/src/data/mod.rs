//! Data subsystem: datasets, out-of-core sources, synthetic generators
//! mirroring the paper's evaluation suite, normalization, and file IO.
//!
//! # The `DataSource` abstraction
//!
//! Every clustering pipeline in this crate consumes a [`DataSource`] — a
//! read-only view of an `(m, n)` row-major f32 matrix that may be larger
//! than memory. The coordinator needs only three operations: the shape,
//! contiguous block reads (`read_rows`, used by the final full-dataset
//! pass and the streaming producer), and random-index gathers
//! (`sample_rows`, used by chunk sampling). Backends:
//!
//! | backend                        | module              | residency                    |
//! |--------------------------------|---------------------|------------------------------|
//! | [`Dataset`]                    | [`dataset`]         | fully in RAM                 |
//! | [`BmxSource`]                  | [`bmx`]             | mmap / buffered pread        |
//! | [`crate::store::BlockStore`]   | [`crate::store`]    | per-block decode + LRU cache |
//! | [`CsvSource`]                  | [`csv_source`]      | row index only, parse-on-read|
//!
//! All backends are deterministic and value-identical for the same
//! underlying data: a seeded Big-means run produces bit-for-bit the same
//! objective whichever backend serves the bytes (asserted in
//! `tests/integration_out_of_core.rs` and `tests/store_v3.rs`).
//!
//! # The `.bmx` on-disk formats
//!
//! The **current** `.bmx` format is version 3 — a chunked block store with
//! per-block CRC-32 integrity, dtype variants (f32/f64/f16), and optional
//! dependency-free codecs; its layout and layering are documented in
//! [`crate::store`]. [`loader::open_source`] sniffs the magic
//! (`BMX1`/`BMX2`/`BMX3`) and routes each file to the right reader, so
//! legacy files keep working.
//!
//! Versions 1/2 are flat little-endian f32 matrices behind a small header
//! (v2, 32 bytes):
//!
//! ```text
//! offset  size   field
//! 0       4      magic b"BMX2" ("BMX" + ASCII version byte)
//! 4       8      m (u64, number of rows)
//! 12      4      n (u32, features per row)
//! 16      4      CRC-32 of the payload (validated on open, ≤ 4 GiB)
//! 20      12     reserved
//! 32      m·n·4  row-major f32 payload
//! ```
//!
//! The v2 header size keeps the payload 4-byte aligned so the whole file
//! can be memory-mapped and read in place; legacy `BMX1` files (16-byte
//! header, no checksum) still load with a warning. Produce v3 files with
//! [`convert::csv_to_block_store`] / [`crate::store::copy_to_store`] /
//! [`crate::store::BlockWriter`], and legacy v2 with
//! [`convert::csv_to_bmx`], [`bmx::save_bmx`], or [`bmx::BmxWriter`]; the
//! CLI exposes `bigmeans convert <in.csv> <out.bmx>` (v3 by default,
//! `--format v2` for the flat file) and `bigmeans verify <file.bmx>`.

pub mod bmx;
pub mod catalog;
pub mod convert;
pub mod csv_source;
pub mod dataset;
pub mod loader;
pub mod normalize;
pub mod source;
pub mod synth;

pub use bmx::{save_bmx, BmxSource, BmxWriter};
pub use catalog::{catalog, find, CatalogEntry, PAPER_K_GRID};
pub use convert::{csv_to_block_store, csv_to_bmx};
pub use csv_source::CsvSource;
pub use dataset::Dataset;
pub use loader::{bmx_version, open_source, open_source_with};
pub use source::{AccessPattern, DataBackend, DataSource};
pub use synth::Synth;
