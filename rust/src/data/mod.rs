//! Data subsystem: datasets, synthetic generators mirroring the paper's
//! evaluation suite, normalization, and file IO.

pub mod catalog;
pub mod dataset;
pub mod loader;
pub mod normalize;
pub mod synth;

pub use catalog::{catalog, find, CatalogEntry, PAPER_K_GRID};
pub use dataset::Dataset;
pub use synth::Synth;
