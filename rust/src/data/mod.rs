//! Data subsystem: datasets, out-of-core sources, synthetic generators
//! mirroring the paper's evaluation suite, normalization, and file IO.
//!
//! # The `DataSource` abstraction
//!
//! Every clustering pipeline in this crate consumes a [`DataSource`] — a
//! read-only view of an `(m, n)` row-major f32 matrix that may be larger
//! than memory. The coordinator needs only three operations: the shape,
//! contiguous block reads (`read_rows`, used by the final full-dataset
//! pass and the streaming producer), and random-index gathers
//! (`sample_rows`, used by chunk sampling). Backends:
//!
//! | backend                | module         | residency                    |
//! |------------------------|----------------|------------------------------|
//! | [`Dataset`]            | [`dataset`]    | fully in RAM                 |
//! | [`BmxSource`]          | [`bmx`]        | mmap / buffered pread        |
//! | [`CsvSource`]          | [`csv_source`] | row index only, parse-on-read|
//!
//! All backends are deterministic and value-identical for the same
//! underlying data: a seeded Big-means run produces bit-for-bit the same
//! objective whichever backend serves the bytes (asserted in
//! `tests/integration_out_of_core.rs`).
//!
//! # The `.bmx` on-disk format
//!
//! `.bmx` is the crate's out-of-core native format — a flat little-endian
//! f32 matrix behind a small header (version 2, 32 bytes):
//!
//! ```text
//! offset  size   field
//! 0       4      magic b"BMX2" ("BMX" + ASCII version byte)
//! 4       8      m (u64, number of rows)
//! 12      4      n (u32, features per row)
//! 16      4      CRC-32 of the payload (validated on open)
//! 20      12     reserved
//! 32      m·n·4  row-major f32 payload
//! ```
//!
//! The header size keeps the payload 4-byte aligned so the whole file can
//! be memory-mapped and read in place; legacy `BMX1` files (16-byte
//! header, no checksum) still load with a warning. Produce `.bmx` files
//! with [`convert::csv_to_bmx`] (blockwise through [`CsvSource`], O(block)
//! memory plus the 8-byte/row offset index — shrinkable by
//! [`CsvSource::open_with_stride`]), [`bmx::save_bmx`], or incrementally
//! with [`bmx::BmxWriter`]; the CLI exposes
//! `bigmeans convert <in.csv> <out.bmx>`.

pub mod bmx;
pub mod catalog;
pub mod convert;
pub mod csv_source;
pub mod dataset;
pub mod loader;
pub mod normalize;
pub mod source;
pub mod synth;

pub use bmx::{save_bmx, BmxSource, BmxWriter};
pub use catalog::{catalog, find, CatalogEntry, PAPER_K_GRID};
pub use convert::csv_to_bmx;
pub use csv_source::CsvSource;
pub use dataset::Dataset;
pub use loader::{open_source, open_source_with};
pub use source::{AccessPattern, DataBackend, DataSource};
pub use synth::Synth;
