//! The out-of-core dataset abstraction.
//!
//! Big-means only ever touches bounded chunks (the paper's decomposition
//! principle), so nothing in the algorithm requires the dataset to be
//! resident in RAM. [`DataSource`] captures exactly the access pattern the
//! coordinator needs — row count, dimensionality, contiguous block reads
//! for the final full pass, and random-index gathers for chunk sampling —
//! and every pipeline (sequential, chunk-parallel, streaming) works against
//! it. Three backends implement it:
//!
//! * [`crate::data::Dataset`] — the classic fully-resident matrix;
//! * [`crate::data::BmxSource`] — a memory-mapped (or buffered-pread)
//!   `.bmx` flat binary file: clusters data larger than RAM;
//! * [`crate::data::CsvSource`] — a row-indexed CSV reader that never holds
//!   more than one chunk of parsed values.
//!
//! Determinism contract: for a fixed RNG seed, every backend must hand the
//! coordinator byte-identical chunk buffers for the same underlying data —
//! the integration suite asserts bit-for-bit equal objectives across
//! backends.

use crate::data::dataset::Dataset;

/// How a pipeline is about to touch a source — forwarded by backends that
/// can act on it (the mmap'd `.bmx` source turns these into `madvise`
/// calls; everything else ignores them).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessPattern {
    /// Scattered row gathers (chunk sampling): readahead off.
    Random,
    /// Front-to-back block reads (the final full pass, streaming
    /// production): aggressive readahead.
    Sequential,
    /// No particular pattern.
    Normal,
}

impl AccessPattern {
    /// The `madvise` advice this pattern maps to (used by every mapped
    /// backend — `.bmx` v1/v2 and the v3 block store).
    pub fn advice(self) -> crate::util::mem::Advice {
        use crate::util::mem::Advice;
        match self {
            AccessPattern::Random => Advice::Random,
            AccessPattern::Sequential => Advice::Sequential,
            AccessPattern::Normal => Advice::Normal,
        }
    }
}

/// How dataset *files* are accessed (see [`crate::data::loader::open_source`],
/// which the CLI threads `BigMeansConfig::backend` through).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataBackend {
    /// Materialize the file fully in RAM (the classic path).
    InMemory,
    /// Out-of-core: memory-map a `.bmx` file and gather chunks on demand.
    Mmap,
    /// Out-of-core: buffered positioned reads (`.bmx`) or a row-indexed
    /// parse-on-read view (`.csv`) — no mmap, bounded memory.
    Buffered,
    /// Out-of-core: the chunked `.bmx` v3 block store
    /// ([`crate::store::BlockStore`]) — per-block integrity, dtype/codec
    /// decode on read, LRU block cache. Prefers mmap, falls back to
    /// buffered positioned reads.
    Block,
}

/// Per-block bounding-box summaries exposed by block-structured sources
/// (`store::BlockStore` when the file carries the summary section). Block
/// `b` holds rows `[b·block_rows, min(m, (b+1)·block_rows))`; its entry in
/// `minmax` is `n` per-dimension minima followed by `n` maxima, in the
/// decoded value domain. The final full-dataset pass feeds these to
/// `store::prune` to skip the k-wide assignment scan for blocks wholly
/// owned by one centroid.
#[derive(Clone, Copy, Debug)]
pub struct BlockSummaries<'a> {
    /// Rows per block (the last block may be shorter).
    pub block_rows: usize,
    /// `2n` values per block: mins then maxs.
    pub minmax: &'a [f32],
}

/// Read-only access to an `(m, n)` row-major f32 dataset, possibly larger
/// than memory.
///
/// Implementations must be cheap to share across threads (`Send + Sync`):
/// the chunk-parallel pipeline hands one `&dyn DataSource` to every worker.
///
/// I/O errors inside `read_rows` / `sample_rows` panic with a descriptive
/// message: the kernels treat shape violations the same way, and threading
/// `Result` through the assignment hot loop would cost more than it buys —
/// sources validate their backing store up front in their constructors.
pub trait DataSource: Send + Sync {
    /// Dataset display name (reports, logs).
    fn name(&self) -> &str;

    /// Number of points (the paper's `m`).
    fn m(&self) -> usize;

    /// Feature dimension (the paper's `n`).
    fn n(&self) -> usize;

    /// Copy the contiguous row range `[start, start + out.len() / n)` into
    /// `out` (row-major). `out.len()` must be a multiple of `n` and the
    /// range must lie inside the dataset.
    fn read_rows(&self, start: usize, out: &mut [f32]);

    /// Gather arbitrary rows by index into `out` (`indices.len() × n`).
    /// The default loops [`DataSource::read_rows`]; backends with cheap
    /// random access override it.
    fn sample_rows(&self, indices: &[usize], out: &mut [f32]) {
        let n = self.n();
        assert_eq!(out.len(), indices.len() * n, "sample_rows: out shape");
        for (slot, &i) in indices.iter().enumerate() {
            self.read_rows(i, &mut out[slot * n..(slot + 1) * n]);
        }
    }

    /// The whole dataset as one resident slice, when available (in-memory
    /// and mmap backends). Lets full-dataset passes skip the block copy.
    fn contiguous(&self) -> Option<&[f32]> {
        None
    }

    /// Hint the upcoming access pattern. Backends that can exploit it
    /// (mmap → `madvise`) override this; the default is a no-op, and the
    /// hint never changes observable values — only paging behaviour.
    fn advise(&self, _pattern: AccessPattern) {}

    /// Per-block bounding-box summaries, when the backing store carries
    /// them (the `.bmx` v3 summary section). Consumers must treat them as
    /// an *optimisation hint only* — pruning decisions derived from them
    /// are required to leave labels and objectives bit-identical.
    fn block_summaries(&self) -> Option<BlockSummaries<'_>> {
        None
    }
}

impl DataSource for Dataset {
    fn name(&self) -> &str {
        &self.name
    }

    fn m(&self) -> usize {
        Dataset::m(self)
    }

    fn n(&self) -> usize {
        Dataset::n(self)
    }

    fn read_rows(&self, start: usize, out: &mut [f32]) {
        let n = Dataset::n(self);
        assert_eq!(out.len() % n, 0, "read_rows: out shape");
        let rows = out.len() / n;
        out.copy_from_slice(&self.points()[start * n..(start + rows) * n]);
    }

    fn sample_rows(&self, indices: &[usize], out: &mut [f32]) {
        let n = Dataset::n(self);
        assert_eq!(out.len(), indices.len() * n, "sample_rows: out shape");
        let all = self.points();
        for (slot, &i) in indices.iter().enumerate() {
            out[slot * n..(slot + 1) * n].copy_from_slice(&all[i * n..(i + 1) * n]);
        }
    }

    fn contiguous(&self) -> Option<&[f32]> {
        Some(self.points())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::from_vec("t", (0..24).map(|x| x as f32).collect(), 6, 4)
    }

    #[test]
    fn dataset_read_rows_block() {
        let d = toy();
        let src: &dyn DataSource = &d;
        assert_eq!(src.m(), 6);
        assert_eq!(src.n(), 4);
        assert_eq!(src.name(), "t");
        let mut out = vec![0f32; 8];
        src.read_rows(2, &mut out);
        assert_eq!(out, &d.points()[8..16]);
    }

    #[test]
    fn dataset_sample_rows_matches_gather() {
        let d = toy();
        let src: &dyn DataSource = &d;
        let idx = [5usize, 0, 3];
        let mut out = vec![0f32; 12];
        src.sample_rows(&idx, &mut out);
        assert_eq!(out, d.gather(&idx));
    }

    #[test]
    fn default_sample_rows_agrees_with_override() {
        // A wrapper that forces the default (read_rows-based) gather.
        struct Plain<'a>(&'a Dataset);
        impl DataSource for Plain<'_> {
            fn name(&self) -> &str {
                DataSource::name(self.0)
            }
            fn m(&self) -> usize {
                self.0.m()
            }
            fn n(&self) -> usize {
                self.0.n()
            }
            fn read_rows(&self, start: usize, out: &mut [f32]) {
                self.0.read_rows(start, out);
            }
        }
        let d = toy();
        let idx = [1usize, 1, 4, 2];
        let mut a = vec![0f32; 16];
        let mut b = vec![0f32; 16];
        Plain(&d).sample_rows(&idx, &mut a);
        d.sample_rows(&idx, &mut b);
        assert_eq!(a, b);
        assert!(Plain(&d).contiguous().is_none());
        assert!(d.contiguous().is_some());
    }
}
