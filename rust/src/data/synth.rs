//! Synthetic dataset generators.
//!
//! The paper's 19 public datasets are not available in this offline
//! environment (see DESIGN.md §Substitutions); these generators produce the
//! synthetic families the paper's own future-work section proposes —
//! Gaussian mixtures, clusters on a regular grid, clusters along a sine
//! curve, and random-size clusters at random locations — plus a heavy-tail
//! "noisy" variant that mimics the hard, unnormalized UCI sets where plain
//! K-means lands far from `f_best`.

use crate::data::dataset::Dataset;
use crate::util::rng::Rng;

/// Specification of a synthetic dataset.
#[derive(Clone, Debug)]
pub enum Synth {
    /// `k_true` isotropic Gaussian blobs with random centers in a box.
    GaussianMixture {
        m: usize,
        n: usize,
        k_true: usize,
        spread: f64,
        box_half_width: f64,
    },
    /// Blobs centered on a regular integer grid (paper future-work item).
    Grid { m: usize, n: usize, per_side: usize, spread: f64 },
    /// Blobs centered along a sine curve in the first two dims.
    Sine { m: usize, n: usize, k_true: usize, spread: f64 },
    /// Random-size clusters at random locations with per-cluster spreads.
    RandomClusters { m: usize, n: usize, k_true: usize, max_spread: f64 },
    /// Gaussian mixture + uniform background noise + per-feature scale
    /// imbalance (mimics unnormalized sensor data).
    Noisy {
        m: usize,
        n: usize,
        k_true: usize,
        spread: f64,
        noise_frac: f64,
        scale_max: f64,
    },
}

impl Synth {
    pub fn m(&self) -> usize {
        match self {
            Synth::GaussianMixture { m, .. }
            | Synth::Grid { m, .. }
            | Synth::Sine { m, .. }
            | Synth::RandomClusters { m, .. }
            | Synth::Noisy { m, .. } => *m,
        }
    }

    pub fn n(&self) -> usize {
        match self {
            Synth::GaussianMixture { n, .. }
            | Synth::Grid { n, .. }
            | Synth::Sine { n, .. }
            | Synth::RandomClusters { n, .. }
            | Synth::Noisy { n, .. } => *n,
        }
    }

    /// Generate the dataset deterministically from `seed`.
    pub fn generate(&self, name: &str, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let (m, n) = (self.m(), self.n());
        let mut data = vec![0f32; m * n];
        match *self {
            Synth::GaussianMixture { k_true, spread, box_half_width, .. } => {
                let centers = random_centers(&mut rng, k_true, n, box_half_width);
                fill_blobs(&mut rng, &mut data, m, n, &centers, &vec![spread; k_true]);
            }
            Synth::Grid { per_side, spread, .. } => {
                // Grid of per_side^2 centers in the first two dims, spacing 10.
                let mut centers = Vec::new();
                for gx in 0..per_side {
                    for gy in 0..per_side {
                        let mut c = vec![0f64; n];
                        c[0] = gx as f64 * 10.0;
                        if n > 1 {
                            c[1] = gy as f64 * 10.0;
                        }
                        centers.push(c);
                    }
                }
                let k = centers.len();
                fill_blobs(&mut rng, &mut data, m, n, &centers, &vec![spread; k]);
            }
            Synth::Sine { k_true, spread, .. } => {
                let centers: Vec<Vec<f64>> = (0..k_true)
                    .map(|j| {
                        let x = j as f64 / (k_true.max(2) - 1) as f64 * 4.0 * std::f64::consts::PI;
                        let mut c = vec![0f64; n];
                        c[0] = x;
                        if n > 1 {
                            c[1] = 5.0 * x.sin();
                        }
                        c
                    })
                    .collect();
                fill_blobs(&mut rng, &mut data, m, n, &centers, &vec![spread; k_true]);
            }
            Synth::RandomClusters { k_true, max_spread, .. } => {
                let centers = random_centers(&mut rng, k_true, n, 50.0);
                let spreads: Vec<f64> =
                    (0..k_true).map(|_| rng.range_f64(0.05, max_spread)).collect();
                // Random sizes: weights from a squared uniform for skew.
                let mut weights: Vec<f64> = (0..k_true).map(|_| rng.f64().powi(2) + 0.05).collect();
                let total: f64 = weights.iter().sum();
                for w in &mut weights {
                    *w /= total;
                }
                fill_blobs_weighted(&mut rng, &mut data, m, n, &centers, &spreads, &weights);
            }
            Synth::Noisy { k_true, spread, noise_frac, scale_max, .. } => {
                let centers = random_centers(&mut rng, k_true, n, 20.0);
                fill_blobs(&mut rng, &mut data, m, n, &centers, &vec![spread; k_true]);
                // Background noise rows.
                let noise_rows = (m as f64 * noise_frac) as usize;
                for _ in 0..noise_rows {
                    let i = rng.usize(m);
                    for j in 0..n {
                        data[i * n + j] = rng.range_f64(-40.0, 40.0) as f32;
                    }
                }
                // Per-feature scale imbalance (unnormalized-sensor mimic).
                let scales: Vec<f64> = (0..n).map(|_| rng.range_f64(1.0, scale_max)).collect();
                for i in 0..m {
                    for j in 0..n {
                        data[i * n + j] *= scales[j] as f32;
                    }
                }
            }
        }
        Dataset::from_vec(name, data, m, n)
    }
}

fn random_centers(rng: &mut Rng, k: usize, n: usize, half_width: f64) -> Vec<Vec<f64>> {
    (0..k)
        .map(|_| (0..n).map(|_| rng.range_f64(-half_width, half_width)).collect())
        .collect()
}

fn fill_blobs(
    rng: &mut Rng,
    data: &mut [f32],
    m: usize,
    n: usize,
    centers: &[Vec<f64>],
    spreads: &[f64],
) {
    let k = centers.len();
    let weights = vec![1.0 / k as f64; k];
    fill_blobs_weighted(rng, data, m, n, centers, spreads, &weights);
}

fn fill_blobs_weighted(
    rng: &mut Rng,
    data: &mut [f32],
    m: usize,
    n: usize,
    centers: &[Vec<f64>],
    spreads: &[f64],
    weights: &[f64],
) {
    for i in 0..m {
        let j = rng.weighted(weights);
        let c = &centers[j];
        let s = spreads[j];
        for d in 0..n {
            data[i * n + d] = (c[d] + s * rng.gaussian()) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_spec() {
        let specs = [
            Synth::GaussianMixture { m: 500, n: 4, k_true: 3, spread: 0.5, box_half_width: 20.0 },
            Synth::Grid { m: 300, n: 3, per_side: 2, spread: 0.2 },
            Synth::Sine { m: 200, n: 2, k_true: 5, spread: 0.1 },
            Synth::RandomClusters { m: 400, n: 5, k_true: 4, max_spread: 2.0 },
            Synth::Noisy { m: 250, n: 6, k_true: 3, spread: 0.4, noise_frac: 0.05, scale_max: 10.0 },
        ];
        for (i, s) in specs.iter().enumerate() {
            let d = s.generate(&format!("t{i}"), 42);
            assert_eq!(d.m(), s.m());
            assert_eq!(d.n(), s.n());
            assert!(d.points().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let s = Synth::GaussianMixture { m: 100, n: 3, k_true: 2, spread: 1.0, box_half_width: 10.0 };
        let a = s.generate("a", 7);
        let b = s.generate("b", 7);
        let c = s.generate("c", 8);
        assert_eq!(a.points(), b.points());
        assert_ne!(a.points(), c.points());
    }

    #[test]
    fn gaussian_mixture_is_clusterable() {
        // Lloyd seeded at the blob centers should get near-zero SSE/point.
        use crate::kernels::{lloyd, LloydParams};
        use crate::metrics::Counters;
        let s = Synth::GaussianMixture { m: 600, n: 2, k_true: 3, spread: 0.05, box_half_width: 30.0 };
        let d = s.generate("t", 11);
        let mut c = Counters::new();
        let seed: Vec<f32> = d.points()[..6].to_vec();
        let r = lloyd(d.points(), &seed, 600, 2, 3, LloydParams::default(), None, &mut c);
        // Not asserting global optimum (seeding may collapse), just sanity.
        assert!(r.objective.is_finite());
        assert!(r.iters >= 1);
    }

    #[test]
    fn noisy_has_scale_imbalance() {
        let s = Synth::Noisy { m: 500, n: 4, k_true: 3, spread: 0.5, noise_frac: 0.1, scale_max: 50.0 };
        let d = s.generate("t", 3);
        // Feature variances should differ by a large factor.
        let mut var = vec![0f64; 4];
        for j in 0..4 {
            let vals: Vec<f64> = (0..500).map(|i| d.points()[i * 4 + j] as f64).collect();
            let mean = vals.iter().sum::<f64>() / 500.0;
            var[j] = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / 500.0;
        }
        let hi = var.iter().cloned().fold(0.0, f64::max);
        let lo = var.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(hi / lo > 4.0, "variance ratio {}", hi / lo);
    }
}
