//! Dataset conversion: stream CSV into the out-of-core `.bmx` formats.
//!
//! The conversion is O(block) in memory and reuses [`CsvSource`] as its
//! reader, so the values written to `.bmx` are — by construction — exactly
//! the values the buffered CSV backend would serve. Convert once, then
//! cluster the `.bmx` file through the mmap/block backend any number of
//! times. [`csv_to_block_store`] writes the current chunked v3 format
//! (per-block CRC, dtype, codec — see [`crate::store`]); [`csv_to_bmx`]
//! keeps producing legacy v2 flat files.

use std::path::Path;

use crate::data::bmx::BmxWriter;
use crate::data::csv_source::CsvSource;
use crate::data::source::DataSource;
use crate::store::{copy_to_store, StoreOptions};
use crate::util::error::Result;

/// Rows converted per block (bounds memory at `block × n` floats).
const CONVERT_BLOCK_ROWS: usize = 8192;

/// Convert a numeric CSV (optional header, blank lines tolerated) into
/// `.bmx`. Returns `(m, n)` of the written matrix. Malformed input
/// (ragged rows, non-numeric fields, no data) is rejected up front by the
/// indexing pass.
pub fn csv_to_bmx(csv: &Path, bmx: &Path) -> Result<(usize, usize)> {
    let src = CsvSource::open(csv)?;
    let (m, n) = (src.m(), src.n());
    let mut writer = BmxWriter::create(bmx, n)?;
    let mut block = vec![0f32; CONVERT_BLOCK_ROWS.min(m) * n];
    let mut start = 0usize;
    while start < m {
        let rows = CONVERT_BLOCK_ROWS.min(m - start);
        src.read_rows(start, &mut block[..rows * n]);
        writer.write_rows(&block[..rows * n])?;
        start += rows;
    }
    let rows = writer.finish()?;
    debug_assert_eq!(rows as usize, m);
    Ok((m, n))
}

/// Convert a numeric CSV into the chunked `.bmx` v3 block store. Returns
/// `(m, n)`. Same validation and memory profile as [`csv_to_bmx`]; the
/// block geometry, dtype, and codec come from `opts`.
pub fn csv_to_block_store(csv: &Path, bmx: &Path, opts: StoreOptions) -> Result<(usize, usize)> {
    let src = CsvSource::open(csv)?;
    copy_to_store(&src, bmx, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::bmx::BmxSource;
    use crate::data::loader;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bigmeans_convert_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{name}", std::process::id()))
    }

    #[test]
    fn converted_bmx_matches_materialized_csv() {
        let csv = tmp("a.csv");
        let bmx = tmp("a.bmx");
        std::fs::write(&csv, "x,y,z\n1,2,3\n4.5,5,6\n-7,8.25,9\n").unwrap();
        let (m, n) = csv_to_bmx(&csv, &bmx).unwrap();
        assert_eq!((m, n), (3, 3));
        let full = loader::load_csv(&csv, None).unwrap();
        let src = BmxSource::open(&bmx).unwrap();
        assert_eq!((src.m(), src.n()), (3, 3));
        let mut out = vec![0f32; 9];
        src.read_rows(0, &mut out);
        assert_eq!(out, full.points());
        let _ = std::fs::remove_file(&csv);
        let _ = std::fs::remove_file(&bmx);
    }

    #[test]
    fn bad_csv_rejected() {
        let csv = tmp("b.csv");
        let bmx = tmp("b.bmx");
        std::fs::write(&csv, "1,2\n3,oops\n").unwrap();
        assert!(csv_to_bmx(&csv, &bmx).is_err());
        std::fs::write(&csv, "header,only\n").unwrap();
        assert!(csv_to_bmx(&csv, &bmx).is_err());
        let _ = std::fs::remove_file(&csv);
        let _ = std::fs::remove_file(&bmx);
    }

    #[test]
    fn csv_to_block_store_matches_v2_values() {
        use crate::data::loader::open_source;
        use crate::data::source::DataBackend;
        let csv = tmp("v3.csv");
        let v2 = tmp("v2.bmx");
        let v3 = tmp("v3.bmx");
        let mut text = String::new();
        for i in 0..300 {
            text.push_str(&format!("{},{},{}\n", i, i * 2, 300 - i));
        }
        std::fs::write(&csv, text).unwrap();
        assert_eq!(csv_to_bmx(&csv, &v2).unwrap(), (300, 3));
        let opts = StoreOptions { block_rows: 64, ..StoreOptions::default() };
        assert_eq!(csv_to_block_store(&csv, &v3, opts).unwrap(), (300, 3));
        let a = open_source(&v2, DataBackend::Buffered).unwrap();
        let b = open_source(&v3, DataBackend::Block).unwrap();
        let mut va = vec![0f32; 300 * 3];
        let mut vb = vec![0f32; 300 * 3];
        a.read_rows(0, &mut va);
        b.read_rows(0, &mut vb);
        assert_eq!(va, vb);
        let _ = std::fs::remove_file(&csv);
        let _ = std::fs::remove_file(&v2);
        let _ = std::fs::remove_file(&v3);
    }

    #[test]
    fn many_rows_cross_block_boundary() {
        let csv = tmp("c.csv");
        let bmx = tmp("c.bmx");
        let mut text = String::new();
        let m = CONVERT_BLOCK_ROWS + 37;
        for i in 0..m {
            text.push_str(&format!("{},{}\n", i, m - i));
        }
        std::fs::write(&csv, text).unwrap();
        assert_eq!(csv_to_bmx(&csv, &bmx).unwrap(), (m, 2));
        let src = BmxSource::open(&bmx).unwrap();
        assert_eq!(src.m(), m);
        let mut last = vec![0f32; 2];
        src.read_rows(m - 1, &mut last);
        assert_eq!(last, vec![(m - 1) as f32, 1.0]);
        let _ = std::fs::remove_file(&csv);
        let _ = std::fs::remove_file(&bmx);
    }
}
