//! Dataset IO: CSV (headerless or headered numeric), the legacy flat
//! binary format (`.fbin`: u32 m, u32 n, then m·n little-endian f32), and
//! materialized loads of the out-of-core `.bmx` format (see
//! [`crate::data::bmx`] for the header spec and the non-materializing
//! [`crate::data::BmxSource`]).

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::bail;
use crate::util::error::{Context, Result};

use crate::data::dataset::Dataset;

/// Load a numeric CSV. Skips a header row if the first field of the first
/// line doesn't parse as a number. `limit` optionally caps rows read.
pub fn load_csv(path: &Path, limit: Option<usize>) -> Result<Dataset> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let reader = BufReader::new(file);
    let mut data: Vec<f32> = Vec::new();
    let mut n = 0usize;
    let mut m = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let fields: Vec<&str> = trimmed.split(',').map(|f| f.trim()).collect();
        if m == 0 && n == 0 {
            // Header detection: first field not numeric → skip.
            if fields[0].parse::<f32>().is_err() {
                continue;
            }
            n = fields.len();
        }
        if fields.len() != n {
            bail!(
                "{}:{}: expected {} fields, got {}",
                path.display(),
                lineno + 1,
                n,
                fields.len()
            );
        }
        for f in &fields {
            data.push(
                f.parse::<f32>()
                    .with_context(|| format!("{}:{}: bad number '{f}'", path.display(), lineno + 1))?,
            );
        }
        m += 1;
        if let Some(cap) = limit {
            if m >= cap {
                break;
            }
        }
    }
    if m == 0 {
        bail!("{}: no data rows", path.display());
    }
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "csv".into());
    Ok(Dataset::from_vec(name, data, m, n))
}

/// Write the flat binary format.
pub fn save_fbin(ds: &Dataset, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    w.write_all(&(ds.m() as u32).to_le_bytes())?;
    w.write_all(&(ds.n() as u32).to_le_bytes())?;
    for &v in ds.points() {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read the flat binary format.
pub fn load_fbin(path: &Path) -> Result<Dataset> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(file);
    let mut hdr = [0u8; 8];
    r.read_exact(&mut hdr).context("fbin header")?;
    let m = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
    let n = u32::from_le_bytes(hdr[4..8].try_into().unwrap()) as usize;
    let mut buf = vec![0u8; m * n * 4];
    r.read_exact(&mut buf).context("fbin body truncated")?;
    let data: Vec<f32> = buf
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
        .collect();
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "fbin".into());
    Ok(Dataset::from_vec(name, data, m, n))
}

/// Format version of a `.bmx` file (1, 2, or 3), sniffed from the magic.
pub fn bmx_version(path: &Path) -> Result<u8> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)
        .with_context(|| format!("read bmx magic of {}", path.display()))?;
    match &magic {
        b"BMX1" => Ok(1),
        b"BMX2" => Ok(2),
        b"BMX3" => Ok(3),
        _ => bail!("{}: not a .bmx file (bad magic)", path.display()),
    }
}

/// Materialize a `.bmx` file (any version) into an in-memory [`Dataset`].
pub fn load_bmx(path: &Path) -> Result<Dataset> {
    use crate::data::bmx::BmxSource;
    use crate::data::source::DataSource;
    use crate::store::BlockStore;
    let src: Box<dyn DataSource> = if bmx_version(path)? == 3 {
        Box::new(BlockStore::open(path)?)
    } else {
        Box::new(BmxSource::open(path)?)
    };
    let (m, n) = (src.m(), src.n());
    let mut data = vec![0f32; m * n];
    if m > 0 {
        src.read_rows(0, &mut data);
    }
    Ok(Dataset::from_vec(src.name().to_string(), data, m, n))
}

/// Load by extension: `.csv`, `.fbin` or `.bmx`.
pub fn load(path: &Path) -> Result<Dataset> {
    match path.extension().and_then(|e| e.to_str()) {
        Some("csv") => load_csv(path, None),
        Some("fbin") => load_fbin(path),
        Some("bmx") => load_bmx(path),
        other => bail!("unsupported dataset extension {:?}", other),
    }
}

/// Open a dataset file through the chosen [`DataBackend`] — the single
/// place where `BigMeansConfig::backend` is turned into a live
/// [`DataSource`]. Uses a dense (stride-1) CSV offset index; see
/// [`open_source_with`] for the stride knob.
pub fn open_source(
    path: &Path,
    backend: crate::data::source::DataBackend,
) -> Result<Box<dyn crate::data::source::DataSource>> {
    open_source_with(path, backend, 1)
}

/// [`open_source`] with an explicit CSV index stride
/// (`BigMeansConfig::index_stride` / CLI `--index-stride`): the buffered
/// CSV backend records only every `index_stride`-th row offset, shrinking
/// the in-RAM index by that factor at the cost of scanning at most
/// `index_stride − 1` rows past a seek. Other backends ignore the stride.
pub fn open_source_with(
    path: &Path,
    backend: crate::data::source::DataBackend,
    index_stride: usize,
) -> Result<Box<dyn crate::data::source::DataSource>> {
    use crate::data::bmx::BmxSource;
    use crate::data::csv_source::CsvSource;
    use crate::data::source::DataBackend;
    use crate::store::BlockStore;
    let ext = path.extension().and_then(|e| e.to_str());
    match backend {
        DataBackend::InMemory => Ok(Box::new(load(path)?)),
        DataBackend::Mmap => match ext {
            // The magic decides which reader serves the file: v3 block
            // stores and legacy v1/v2 flat files share the extension.
            Some("bmx") => match bmx_version(path)? {
                3 => Ok(Box::new(BlockStore::open(path)?)),
                _ => Ok(Box::new(BmxSource::open(path)?)),
            },
            other => bail!(
                "mmap backend needs a .bmx file, got {:?} (run `bigmeans convert` first)",
                other
            ),
        },
        DataBackend::Buffered => match ext {
            Some("bmx") => match bmx_version(path)? {
                3 => Ok(Box::new(BlockStore::open_buffered(path)?)),
                _ => Ok(Box::new(BmxSource::open_buffered(path)?)),
            },
            Some("csv") => Ok(Box::new(CsvSource::open_with_stride(path, index_stride.max(1))?)),
            other => bail!("buffered backend supports .bmx and .csv, got {:?}", other),
        },
        DataBackend::Block => match ext {
            Some("bmx") => match bmx_version(path)? {
                3 => Ok(Box::new(BlockStore::open(path)?)),
                v => bail!(
                    "{}: legacy v{v} .bmx — the block backend needs the chunked v3 \
                     format (rewrite with `bigmeans convert` or `bigmeans generate`)",
                    path.display()
                ),
            },
            other => bail!(
                "block backend needs a .bmx v3 file, got {:?} (run `bigmeans convert` first)",
                other
            ),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bigmeans_loader_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn csv_roundtrip_with_header() {
        let p = tmp("a.csv");
        std::fs::write(&p, "x,y\n1.5,2\n3,4.25\n").unwrap();
        let d = load_csv(&p, None).unwrap();
        assert_eq!(d.m(), 2);
        assert_eq!(d.n(), 2);
        assert_eq!(d.points(), &[1.5, 2.0, 3.0, 4.25]);
    }

    #[test]
    fn csv_headerless_and_limit() {
        let p = tmp("b.csv");
        std::fs::write(&p, "1,2\n3,4\n5,6\n").unwrap();
        let d = load_csv(&p, Some(2)).unwrap();
        assert_eq!(d.m(), 2);
    }

    #[test]
    fn csv_ragged_rejected() {
        let p = tmp("c.csv");
        std::fs::write(&p, "1,2\n3\n").unwrap();
        assert!(load_csv(&p, None).is_err());
    }

    #[test]
    fn fbin_roundtrip() {
        let p = tmp("d.fbin");
        let d = Dataset::from_vec("d", vec![1.0, -2.5, 3.25, 4.0], 2, 2);
        save_fbin(&d, &p).unwrap();
        let back = load_fbin(&p).unwrap();
        assert_eq!(back.m(), 2);
        assert_eq!(back.n(), 2);
        assert_eq!(back.points(), d.points());
    }

    #[test]
    fn open_source_respects_backend_and_extension() {
        use crate::data::source::{DataBackend, DataSource};
        let csv = tmp("os.csv");
        std::fs::write(&csv, "1,2\n3,4\n").unwrap();
        let mem = open_source(&csv, DataBackend::InMemory).unwrap();
        let buffered = open_source(&csv, DataBackend::Buffered).unwrap();
        assert_eq!(mem.m(), 2);
        assert_eq!(buffered.m(), 2);
        // CSV cannot be mmap'd — needs conversion first.
        assert!(open_source(&csv, DataBackend::Mmap).is_err());
        let bmx = tmp("os.bmx");
        crate::data::bmx::save_bmx(&load_csv(&csv, None).unwrap(), &bmx).unwrap();
        let mapped = open_source(&bmx, DataBackend::Mmap).unwrap();
        assert_eq!((mapped.m(), mapped.n()), (2, 2));
        let _ = std::fs::remove_file(&csv);
        let _ = std::fs::remove_file(&bmx);
    }

    #[test]
    fn fbin_truncated_rejected() {
        let p = tmp("e.fbin");
        std::fs::write(&p, [2u8, 0, 0, 0, 2, 0, 0, 0, 1, 2, 3]).unwrap();
        assert!(load_fbin(&p).is_err());
    }
}
