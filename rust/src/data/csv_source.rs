//! Out-of-core CSV access: a row-indexed, buffered reader implementing
//! [`DataSource`] without ever materializing the feature matrix.
//!
//! [`CsvSource::open`] makes one streaming pass to detect the header,
//! validate field counts, and record each data row's byte span. After that
//! the source holds only the index (16 bytes per row — orders of magnitude
//! smaller than the parsed data) plus one shared file handle; chunk gathers
//! seek to the recorded spans and parse straight into the caller's buffer,
//! so at no point does more than one chunk of parsed values exist.

use std::fs::File;
use std::io::{BufRead, BufReader, Read, Seek, SeekFrom};
use std::path::Path;
use std::sync::Mutex;

use crate::bail;
use crate::data::source::DataSource;
use crate::util::error::{Context, Result};

/// Byte span of one data row inside the file.
#[derive(Clone, Copy, Debug)]
struct RowSpan {
    offset: u64,
    len: u32,
}

/// A numeric CSV file exposed as an out-of-core [`DataSource`].
pub struct CsvSource {
    name: String,
    n: usize,
    spans: Vec<RowSpan>,
    file: Mutex<File>,
}

impl CsvSource {
    /// Index `path`: one streaming pass recording row spans. Skips a header
    /// row (first line whose first field is not numeric) and blank lines;
    /// rejects ragged rows and non-numeric fields — after `open` succeeds,
    /// every indexed row is known to parse, so reads cannot fail on
    /// content (only on the file mutating underneath, which panics).
    pub fn open(path: &Path) -> Result<CsvSource> {
        let file = File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut reader = BufReader::new(file);
        let mut spans: Vec<RowSpan> = Vec::new();
        let mut n = 0usize;
        let mut offset = 0u64;
        let mut line = String::new();
        let mut lineno = 0usize;
        loop {
            line.clear();
            let read = reader.read_line(&mut line)?;
            if read == 0 {
                break;
            }
            lineno += 1;
            let trimmed = line.trim();
            if !trimmed.is_empty() {
                let fields = trimmed.split(',').count();
                let first = trimmed.split(',').next().unwrap_or("").trim();
                if n == 0 && spans.is_empty() && first.parse::<f32>().is_err() {
                    // Header row: skip.
                } else {
                    if n == 0 {
                        n = fields;
                    }
                    if fields != n {
                        bail!(
                            "{}:{}: expected {} fields, got {}",
                            path.display(),
                            lineno,
                            n,
                            fields
                        );
                    }
                    for f in trimmed.split(',') {
                        let f = f.trim();
                        if f.parse::<f32>().is_err() {
                            bail!("{}:{}: bad number '{f}'", path.display(), lineno);
                        }
                    }
                    if read > u32::MAX as usize {
                        bail!("{}:{}: row too long", path.display(), lineno);
                    }
                    spans.push(RowSpan { offset, len: read as u32 });
                }
            }
            offset += read as u64;
        }
        if spans.is_empty() {
            bail!("{}: no data rows", path.display());
        }
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "csv".into());
        let file = reader.into_inner();
        Ok(CsvSource { name, n, spans, file: Mutex::new(file) })
    }

    fn parse_row(&self, bytes: &[u8], row: usize, out: &mut [f32]) {
        let text = std::str::from_utf8(bytes)
            .unwrap_or_else(|_| panic!("csv '{}': row {row} is not utf-8", self.name));
        let mut fields = text.trim().split(',');
        for (j, slot) in out.iter_mut().enumerate() {
            let field = fields
                .next()
                .unwrap_or_else(|| panic!("csv '{}': row {row} too short", self.name))
                .trim();
            *slot = field.parse::<f32>().unwrap_or_else(|_| {
                panic!("csv '{}': row {row} field {j}: bad number '{field}'", self.name)
            });
        }
    }
}

impl DataSource for CsvSource {
    fn name(&self) -> &str {
        &self.name
    }

    fn m(&self) -> usize {
        self.spans.len()
    }

    fn n(&self) -> usize {
        self.n
    }

    fn read_rows(&self, start: usize, out: &mut [f32]) {
        assert_eq!(out.len() % self.n, 0, "read_rows: out shape");
        let rows = out.len() / self.n;
        assert!(start + rows <= self.spans.len(), "read_rows: out of bounds");
        if rows == 0 {
            return;
        }
        // Row spans are ascending in the file, so a contiguous row range is
        // one byte range (possibly including skipped blank lines): fetch it
        // with a single seek + read, then parse each row from the buffer.
        let first = self.spans[start];
        let last = self.spans[start + rows - 1];
        let total = (last.offset + last.len as u64 - first.offset) as usize;
        let mut buf = vec![0u8; total];
        {
            let mut f = self.file.lock().unwrap();
            f.seek(SeekFrom::Start(first.offset))
                .unwrap_or_else(|e| panic!("csv '{}': seek failed: {e}", self.name));
            f.read_exact(&mut buf)
                .unwrap_or_else(|e| panic!("csv '{}': read failed: {e}", self.name));
        }
        for (slot, row) in (start..start + rows).enumerate() {
            let span = self.spans[row];
            let lo = (span.offset - first.offset) as usize;
            let bytes = &buf[lo..lo + span.len as usize];
            self.parse_row(bytes, row, &mut out[slot * self.n..(slot + 1) * self.n]);
        }
    }

    fn sample_rows(&self, indices: &[usize], out: &mut [f32]) {
        assert_eq!(out.len(), indices.len() * self.n, "sample_rows: out shape");
        // One lock + one reused buffer for the whole gather.
        let mut f = self.file.lock().unwrap();
        let mut buf = Vec::new();
        for (slot, &row) in indices.iter().enumerate() {
            let span = self.spans[row];
            buf.resize(span.len as usize, 0);
            f.seek(SeekFrom::Start(span.offset))
                .unwrap_or_else(|e| panic!("csv '{}': seek failed: {e}", self.name));
            f.read_exact(&mut buf[..])
                .unwrap_or_else(|e| panic!("csv '{}': read failed: {e}", self.name));
            self.parse_row(&buf, row, &mut out[slot * self.n..(slot + 1) * self.n]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::loader;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bigmeans_csv_source_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{name}", std::process::id()))
    }

    #[test]
    fn indexes_with_header_and_blank_lines() {
        let p = tmp("hdr.csv");
        std::fs::write(&p, "x,y\n1.5,2\n\n3,4.25\n-1,0\n").unwrap();
        let src = CsvSource::open(&p).unwrap();
        assert_eq!(src.m(), 3);
        assert_eq!(src.n(), 2);
        let mut out = vec![0f32; 6];
        src.read_rows(0, &mut out);
        assert_eq!(out, vec![1.5, 2.0, 3.0, 4.25, -1.0, 0.0]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn random_gather_matches_materialized_load() {
        let p = tmp("gather.csv");
        let mut text = String::new();
        for i in 0..50 {
            text.push_str(&format!("{},{},{}\n", i, i * 2, 0.25 * i as f32));
        }
        std::fs::write(&p, text).unwrap();
        let src = CsvSource::open(&p).unwrap();
        let full = loader::load_csv(&p, None).unwrap();
        let idx = [49usize, 0, 17, 17, 3];
        let mut out = vec![0f32; idx.len() * 3];
        src.sample_rows(&idx, &mut out);
        assert_eq!(out, full.gather(&idx));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn ragged_rejected_and_no_rows_rejected() {
        let p = tmp("ragged.csv");
        std::fs::write(&p, "1,2\n3\n").unwrap();
        assert!(CsvSource::open(&p).is_err());
        std::fs::write(&p, "only,header\n").unwrap();
        assert!(CsvSource::open(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn crlf_lines_parse() {
        let p = tmp("crlf.csv");
        std::fs::write(&p, "1,2\r\n3,4\r\n").unwrap();
        let src = CsvSource::open(&p).unwrap();
        assert_eq!(src.m(), 2);
        let mut out = vec![0f32; 4];
        src.read_rows(0, &mut out);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0]);
        let _ = std::fs::remove_file(&p);
    }
}
