//! Out-of-core CSV access: a row-indexed, buffered reader implementing
//! [`DataSource`] without ever materializing the feature matrix.
//!
//! [`CsvSource::open`] makes one streaming pass to detect the header,
//! validate field counts, and record row offsets. After that the source
//! holds only the offset index plus one shared file handle; reads seek to
//! the recorded offsets and parse straight into the caller's buffer, so at
//! no point does more than one chunk of parsed values exist.
//!
//! ## Stride-sampled index
//!
//! By default every data row's byte offset is recorded (8 bytes per row).
//! [`CsvSource::open_with_stride`] records only every `stride`-th offset —
//! an *anchor* — shrinking the in-RAM index by the stride factor: a
//! billion-row CSV indexes in 8 GB at stride 1 but 256 MB at stride 32.
//! The trade is seek granularity: accessing row `i` seeks to anchor
//! `⌊i/stride⌋` and scans forward at most `stride − 1` rows inside the
//! window. Values served are identical for every stride (asserted by the
//! unit tests below); only the I/O pattern changes.

use std::fs::File;
use std::io::{BufRead, BufReader, Seek, SeekFrom};
use std::path::Path;
use std::sync::Mutex;

use crate::bail;
use crate::data::source::DataSource;
use crate::util::error::{Context, Result};

/// A numeric CSV file exposed as an out-of-core [`DataSource`].
pub struct CsvSource {
    name: String,
    n: usize,
    /// Total data rows.
    m: usize,
    /// Index stride: `anchors[a]` is the byte offset of data row
    /// `a * stride`.
    stride: usize,
    anchors: Vec<u64>,
    file: Mutex<File>,
}

impl CsvSource {
    /// Index `path` with a full (stride-1) offset index.
    pub fn open(path: &Path) -> Result<CsvSource> {
        Self::open_with_stride(path, 1)
    }

    /// Index `path`, recording one offset per `stride` data rows. One
    /// streaming pass validates every row (skipping a header line whose
    /// first field is not numeric, and blank lines; rejecting ragged rows
    /// and non-numeric fields) — after `open` succeeds, every indexed row
    /// is known to parse, so reads cannot fail on content (only on the
    /// file mutating underneath, which panics).
    pub fn open_with_stride(path: &Path, stride: usize) -> Result<CsvSource> {
        if stride == 0 {
            bail!("csv index stride must be ≥ 1");
        }
        let file = File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut reader = BufReader::new(file);
        let mut anchors: Vec<u64> = Vec::new();
        let mut m = 0usize;
        let mut n = 0usize;
        let mut offset = 0u64;
        let mut line = String::new();
        let mut lineno = 0usize;
        loop {
            line.clear();
            let read = reader.read_line(&mut line)?;
            if read == 0 {
                break;
            }
            lineno += 1;
            let trimmed = line.trim();
            if !trimmed.is_empty() {
                let fields = trimmed.split(',').count();
                let first = trimmed.split(',').next().unwrap_or("").trim();
                if n == 0 && m == 0 && first.parse::<f32>().is_err() {
                    // Header row: skip.
                } else {
                    if n == 0 {
                        n = fields;
                    }
                    if fields != n {
                        bail!(
                            "{}:{}: expected {} fields, got {}",
                            path.display(),
                            lineno,
                            n,
                            fields
                        );
                    }
                    for f in trimmed.split(',') {
                        let f = f.trim();
                        if f.parse::<f32>().is_err() {
                            bail!("{}:{}: bad number '{f}'", path.display(), lineno);
                        }
                    }
                    if m % stride == 0 {
                        anchors.push(offset);
                    }
                    m += 1;
                }
            }
            offset += read as u64;
        }
        if m == 0 {
            bail!("{}: no data rows", path.display());
        }
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "csv".into());
        let file = reader.into_inner();
        Ok(CsvSource { name, n, m, stride, anchors, file: Mutex::new(file) })
    }

    /// Configured index stride.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Offsets held in RAM (≈ `m / stride`; what the stride shrinks).
    pub fn indexed_offsets(&self) -> usize {
        self.anchors.len()
    }

    fn parse_row(&self, text: &str, row: usize, out: &mut [f32]) {
        let mut fields = text.split(',');
        for (j, slot) in out.iter_mut().enumerate() {
            let field = fields
                .next()
                .unwrap_or_else(|| panic!("csv '{}': row {row} too short", self.name))
                .trim();
            *slot = field.parse::<f32>().unwrap_or_else(|_| {
                panic!("csv '{}': row {row} field {j}: bad number '{field}'", self.name)
            });
        }
    }

    /// Parse `count` consecutive data rows starting at data row `row` into
    /// `out`: seek to the nearest anchor at or before `row`, then scan
    /// forward line by line (skipping blank lines, which the index also
    /// skipped). `reader` and `line` are caller-owned so a whole gather
    /// reuses one buffer — seeking a `BufReader` discards its contents but
    /// keeps the allocation.
    fn scan_rows(
        &self,
        reader: &mut BufReader<&File>,
        line: &mut String,
        row: usize,
        count: usize,
        out: &mut [f32],
    ) {
        debug_assert!(row + count <= self.m);
        debug_assert_eq!(out.len(), count * self.n);
        if count == 0 {
            return;
        }
        let anchor = row / self.stride;
        let mut skip = row - anchor * self.stride;
        reader
            .seek(SeekFrom::Start(self.anchors[anchor]))
            .unwrap_or_else(|e| panic!("csv '{}': seek failed: {e}", self.name));
        let mut filled = 0usize;
        while filled < count {
            line.clear();
            let read = reader
                .read_line(line)
                .unwrap_or_else(|e| panic!("csv '{}': read failed: {e}", self.name));
            if read == 0 {
                panic!(
                    "csv '{}': file truncated while scanning row {}",
                    self.name,
                    row + filled
                );
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            if skip > 0 {
                skip -= 1;
                continue;
            }
            let slot = filled;
            self.parse_row(trimmed, row + slot, &mut out[slot * self.n..(slot + 1) * self.n]);
            filled += 1;
        }
    }
}

impl DataSource for CsvSource {
    fn name(&self) -> &str {
        &self.name
    }

    fn m(&self) -> usize {
        self.m
    }

    fn n(&self) -> usize {
        self.n
    }

    fn read_rows(&self, start: usize, out: &mut [f32]) {
        assert_eq!(out.len() % self.n, 0, "read_rows: out shape");
        let rows = out.len() / self.n;
        assert!(start + rows <= self.m, "read_rows: out of bounds");
        let f = self.file.lock().unwrap();
        let mut reader = BufReader::new(&*f);
        let mut line = String::new();
        self.scan_rows(&mut reader, &mut line, start, rows, out);
    }

    fn sample_rows(&self, indices: &[usize], out: &mut [f32]) {
        assert_eq!(out.len(), indices.len() * self.n, "sample_rows: out shape");
        // One lock + one reader/line buffer for the whole gather; each
        // index seeks within its own stride window.
        let f = self.file.lock().unwrap();
        let mut reader = BufReader::new(&*f);
        let mut line = String::new();
        for (slot, &row) in indices.iter().enumerate() {
            assert!(row < self.m, "sample_rows: row {row} out of bounds");
            self.scan_rows(
                &mut reader,
                &mut line,
                row,
                1,
                &mut out[slot * self.n..(slot + 1) * self.n],
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::loader;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bigmeans_csv_source_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{name}", std::process::id()))
    }

    #[test]
    fn indexes_with_header_and_blank_lines() {
        let p = tmp("hdr.csv");
        std::fs::write(&p, "x,y\n1.5,2\n\n3,4.25\n-1,0\n").unwrap();
        let src = CsvSource::open(&p).unwrap();
        assert_eq!(src.m(), 3);
        assert_eq!(src.n(), 2);
        assert_eq!(src.stride(), 1);
        let mut out = vec![0f32; 6];
        src.read_rows(0, &mut out);
        assert_eq!(out, vec![1.5, 2.0, 3.0, 4.25, -1.0, 0.0]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn random_gather_matches_materialized_load() {
        let p = tmp("gather.csv");
        let mut text = String::new();
        for i in 0..50 {
            text.push_str(&format!("{},{},{}\n", i, i * 2, 0.25 * i as f32));
        }
        std::fs::write(&p, text).unwrap();
        let src = CsvSource::open(&p).unwrap();
        let full = loader::load_csv(&p, None).unwrap();
        let idx = [49usize, 0, 17, 17, 3];
        let mut out = vec![0f32; idx.len() * 3];
        src.sample_rows(&idx, &mut out);
        assert_eq!(out, full.gather(&idx));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn ragged_rejected_and_no_rows_rejected() {
        let p = tmp("ragged.csv");
        std::fs::write(&p, "1,2\n3\n").unwrap();
        assert!(CsvSource::open(&p).is_err());
        std::fs::write(&p, "only,header\n").unwrap();
        assert!(CsvSource::open(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn crlf_lines_parse() {
        let p = tmp("crlf.csv");
        std::fs::write(&p, "1,2\r\n3,4\r\n").unwrap();
        let src = CsvSource::open(&p).unwrap();
        assert_eq!(src.m(), 2);
        let mut out = vec![0f32; 4];
        src.read_rows(0, &mut out);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn zero_stride_rejected() {
        let p = tmp("zstride.csv");
        std::fs::write(&p, "1,2\n").unwrap();
        assert!(CsvSource::open_with_stride(&p, 0).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn strided_index_shrinks_and_serves_identical_values() {
        // Header + blank lines + CRLF mixed in, so the stride-window scan
        // exercises every skip path.
        let p = tmp("stride.csv");
        let mut text = String::from("a,b\n");
        for i in 0..97 {
            let sep = if i % 7 == 0 { "\r\n" } else { "\n" };
            text.push_str(&format!("{},{}{sep}", i, i * 3));
            if i % 13 == 0 {
                text.push('\n'); // blank line
            }
        }
        std::fs::write(&p, text).unwrap();
        let dense = CsvSource::open(&p).unwrap();
        assert_eq!(dense.m(), 97);
        assert_eq!(dense.indexed_offsets(), 97);
        for stride in [2usize, 5, 16, 97, 500] {
            let sparse = CsvSource::open_with_stride(&p, stride).unwrap();
            assert_eq!(sparse.m(), 97, "stride {stride}");
            assert_eq!(
                sparse.indexed_offsets(),
                97usize.div_ceil(stride),
                "stride {stride}"
            );
            // Block reads across window boundaries.
            let mut a = vec![0f32; 97 * 2];
            let mut b = vec![0f32; 97 * 2];
            dense.read_rows(0, &mut a);
            sparse.read_rows(0, &mut b);
            assert_eq!(a, b, "stride {stride}: full read");
            let mut a = vec![0f32; 10 * 2];
            let mut b = vec![0f32; 10 * 2];
            dense.read_rows(43, &mut a);
            sparse.read_rows(43, &mut b);
            assert_eq!(a, b, "stride {stride}: mid-file block");
            // Scattered gathers, including within-window neighbours.
            let idx = [96usize, 0, 44, 45, 46, 13, 13, 95];
            let mut a = vec![0f32; idx.len() * 2];
            let mut b = vec![0f32; idx.len() * 2];
            dense.sample_rows(&idx, &mut a);
            sparse.sample_rows(&idx, &mut b);
            assert_eq!(a, b, "stride {stride}: gather");
        }
        let _ = std::fs::remove_file(&p);
    }
}
