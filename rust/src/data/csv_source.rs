//! Out-of-core CSV access: a row-indexed, buffered reader implementing
//! [`DataSource`] without ever materializing the feature matrix.
//!
//! [`CsvSource::open`] makes one streaming pass to detect the header,
//! validate field counts, and record row offsets. After that the source
//! holds only the offset index plus one shared file handle; reads seek to
//! the recorded offsets and parse straight into the caller's buffer, so at
//! no point does more than one chunk of parsed values exist.
//!
//! ## Stride-sampled index
//!
//! By default every data row's byte offset is recorded (8 bytes per row).
//! [`CsvSource::open_with_stride`] records only every `stride`-th offset —
//! an *anchor* — shrinking the index by the stride factor: a billion-row
//! CSV indexes in 8 GB at stride 1 but 256 MB at stride 32. The trade is
//! seek granularity: accessing row `i` seeks to anchor `⌊i/stride⌋` and
//! scans forward at most `stride − 1` rows inside the window. Values
//! served are identical for every stride (asserted by the unit tests
//! below); only the I/O pattern changes.
//!
//! ## The `.idx` sidecar (fully on-disk index)
//!
//! The indexing pass is O(file) — wasteful to repeat on every open, and
//! the in-RAM anchors are the residual memory footprint of this backend.
//! Both are closed by a persistent sidecar: the first open writes the
//! anchor table to `<file>.csv.idx` (atomically, best-effort — a
//! read-only directory just skips persistence), and later opens validate
//! the sidecar against the CSV's byte length + mtime + requested stride
//! **plus a content fingerprint** (CRC-32 of the file's first and last
//! pages) and, on match, **memory-map it** instead of rescanning — an
//! O(index) reopen with zero resident anchor memory. Any mismatch (CSV
//! rewritten, different stride, corrupt sidecar, fingerprint drift)
//! silently falls back to a fresh scan that rewrites the sidecar. The
//! fingerprint closes the classic stamp-cache blind spot — a same-size
//! rewrite within one mtime granule on a coarse-timestamp filesystem —
//! for any edit touching either end of the file; an edit confined to the
//! untouched middle of a large file remains the (accepted) residual risk.

use std::fs::File;
use std::io::{BufRead, BufReader, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::bail;
use crate::data::source::DataSource;
use crate::util::error::{Context, Result};
use crate::util::hash::crc32;
use crate::util::sync::lock_recover;

#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
use crate::util::mem::MmapRegion;

/// Sidecar magic: "BM" + CSV-index + format version 2 (v2 added the
/// content fingerprint at header bytes 60..64; v1 sidecars simply fail
/// the magic check and trigger one rescan that rewrites them).
const IDX_MAGIC: [u8; 8] = *b"BMCSIDX2";

/// Sidecar header bytes before the anchor table (keeps anchors 8-aligned).
const IDX_HEADER_LEN: usize = 64;

/// Bytes fingerprinted at each end of the CSV.
const FP_PAGE: u64 = 4096;

/// Identity stamp of a CSV file: the sidecar is valid only while both the
/// byte length and the mtime it recorded still match.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct CsvStamp {
    len: u64,
    mtime_secs: u64,
    mtime_nanos: u32,
}

impl CsvStamp {
    fn of(path: &Path) -> Result<CsvStamp> {
        let meta = std::fs::metadata(path)
            .with_context(|| format!("stat {}", path.display()))?;
        let (mtime_secs, mtime_nanos) = meta
            .modified()
            .ok()
            .and_then(|t| t.duration_since(std::time::UNIX_EPOCH).ok())
            .map(|d| (d.as_secs(), d.subsec_nanos()))
            .unwrap_or((0, 0));
        Ok(CsvStamp { len: meta.len(), mtime_secs, mtime_nanos })
    }
}

/// Cheap content fingerprint: CRC-32 over the first and last [`FP_PAGE`]
/// bytes of the CSV (the whole file when shorter than one page). Catches
/// the same-size-rewrite-within-one-mtime-granule edit the stamp cannot.
fn content_fingerprint(path: &Path, len: u64) -> Result<u32> {
    let mut f =
        File::open(path).with_context(|| format!("fingerprint {}", path.display()))?;
    let mut buf = vec![0u8; len.min(FP_PAGE) as usize];
    f.read_exact(&mut buf)
        .with_context(|| format!("fingerprint head of {}", path.display()))?;
    if len > FP_PAGE {
        let mut tail = vec![0u8; FP_PAGE as usize];
        f.seek(SeekFrom::Start(len - FP_PAGE))?;
        f.read_exact(&mut tail)
            .with_context(|| format!("fingerprint tail of {}", path.display()))?;
        buf.extend_from_slice(&tail);
    }
    Ok(crc32(&buf))
}

/// Where the anchor table lives: scanned into RAM, or served from the
/// mmap'd sidecar (zero resident anchor memory).
enum AnchorStore {
    Ram(Vec<u64>),
    #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
    Mapped { region: MmapRegion, count: usize },
}

impl AnchorStore {
    fn count(&self) -> usize {
        match self {
            AnchorStore::Ram(v) => v.len(),
            #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
            AnchorStore::Mapped { count, .. } => *count,
        }
    }

    fn get(&self, i: usize) -> u64 {
        match self {
            AnchorStore::Ram(v) => v[i],
            #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
            AnchorStore::Mapped { region, .. } => {
                let at = IDX_HEADER_LEN + i * 8;
                let bytes = &region.bytes()[at..at + 8];
                u64::from_le_bytes(bytes.try_into().unwrap())
            }
        }
    }
}

/// A numeric CSV file exposed as an out-of-core [`DataSource`].
pub struct CsvSource {
    name: String,
    n: usize,
    /// Total data rows.
    m: usize,
    /// Index stride: anchor `a` is the byte offset of data row
    /// `a * stride`.
    stride: usize,
    anchors: AnchorStore,
    /// Whether the index came from a valid `.idx` sidecar (vs a scan).
    from_sidecar: bool,
    file: Mutex<File>,
}

/// The sidecar path for a CSV: `data.csv` → `data.csv.idx`.
pub fn sidecar_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".idx");
    PathBuf::from(os)
}

fn encode_sidecar_header(
    stamp: &CsvStamp,
    fingerprint: u32,
    n: usize,
    m: usize,
    stride: usize,
    count: usize,
    anchors_crc: u32,
) -> [u8; IDX_HEADER_LEN] {
    let mut hdr = [0u8; IDX_HEADER_LEN];
    hdr[0..8].copy_from_slice(&IDX_MAGIC);
    hdr[8..16].copy_from_slice(&stamp.len.to_le_bytes());
    hdr[16..24].copy_from_slice(&stamp.mtime_secs.to_le_bytes());
    hdr[24..28].copy_from_slice(&stamp.mtime_nanos.to_le_bytes());
    hdr[28..32].copy_from_slice(&(n as u32).to_le_bytes());
    hdr[32..40].copy_from_slice(&(m as u64).to_le_bytes());
    hdr[40..48].copy_from_slice(&(stride as u64).to_le_bytes());
    hdr[48..56].copy_from_slice(&(count as u64).to_le_bytes());
    hdr[56..60].copy_from_slice(&anchors_crc.to_le_bytes());
    hdr[60..64].copy_from_slice(&fingerprint.to_le_bytes());
    hdr
}

/// Best-effort persist of a freshly scanned index (atomic via tmp +
/// rename). Failure (read-only directory, quota) is silently ignored —
/// the in-RAM anchors stay authoritative for this open.
fn store_sidecar(
    idx_path: &Path,
    stamp: &CsvStamp,
    fingerprint: u32,
    n: usize,
    m: usize,
    stride: usize,
    anchors: &[u64],
) {
    let mut payload = Vec::with_capacity(anchors.len() * 8);
    for &a in anchors {
        payload.extend_from_slice(&a.to_le_bytes());
    }
    let hdr =
        encode_sidecar_header(stamp, fingerprint, n, m, stride, anchors.len(), crc32(&payload));
    let tmp = {
        let mut os = idx_path.as_os_str().to_os_string();
        os.push(".tmp");
        PathBuf::from(os)
    };
    let write = || -> std::io::Result<()> {
        let mut f = File::create(&tmp)?;
        f.write_all(&hdr)?;
        f.write_all(&payload)?;
        f.flush()?;
        std::fs::rename(&tmp, idx_path)
    };
    if write().is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
}

/// Try to satisfy an open from the sidecar. `None` on any mismatch —
/// missing file, stale stamp, different stride, bad checksum — in which
/// case the caller rescans.
fn load_sidecar(
    idx_path: &Path,
    stamp: &CsvStamp,
    fingerprint: u32,
    stride: usize,
) -> Option<(usize, usize, AnchorStore)> {
    let mut f = File::open(idx_path).ok()?;
    let mut hdr = [0u8; IDX_HEADER_LEN];
    f.read_exact(&mut hdr).ok()?;
    if hdr[0..8] != IDX_MAGIC {
        return None;
    }
    let csv_len = u64::from_le_bytes(hdr[8..16].try_into().unwrap());
    let mtime_secs = u64::from_le_bytes(hdr[16..24].try_into().unwrap());
    let mtime_nanos = u32::from_le_bytes(hdr[24..28].try_into().unwrap());
    let n = u32::from_le_bytes(hdr[28..32].try_into().unwrap()) as usize;
    let m = u64::from_le_bytes(hdr[32..40].try_into().unwrap());
    let idx_stride = u64::from_le_bytes(hdr[40..48].try_into().unwrap());
    let count = u64::from_le_bytes(hdr[48..56].try_into().unwrap());
    let anchors_crc = u32::from_le_bytes(hdr[56..60].try_into().unwrap());
    let idx_fingerprint = u32::from_le_bytes(hdr[60..64].try_into().unwrap());
    let fresh = csv_len == stamp.len
        && mtime_secs == stamp.mtime_secs
        && mtime_nanos == stamp.mtime_nanos
        && idx_fingerprint == fingerprint;
    if !fresh || idx_stride != stride as u64 || n == 0 || m == 0 {
        return None;
    }
    if m > usize::MAX as u64 / 2 || count != m.div_ceil(idx_stride.max(1)) {
        return None;
    }
    let payload_len = count.checked_mul(8)?;
    let expect_len = (IDX_HEADER_LEN as u64).checked_add(payload_len)?;
    if f.metadata().ok()?.len() != expect_len {
        return None;
    }
    #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
    {
        if let Some(region) = MmapRegion::map(&f, expect_len as usize) {
            if crc32(&region.bytes()[IDX_HEADER_LEN..]) != anchors_crc {
                return None;
            }
            return Some((
                m as usize,
                n,
                AnchorStore::Mapped { region, count: count as usize },
            ));
        }
    }
    // Portable fallback: read the anchors into RAM (still skips the
    // O(file) CSV scan).
    let mut payload = vec![0u8; payload_len as usize];
    f.read_exact(&mut payload).ok()?;
    if crc32(&payload) != anchors_crc {
        return None;
    }
    let anchors: Vec<u64> = payload
        .chunks_exact(8)
        .map(|b| u64::from_le_bytes(b.try_into().unwrap()))
        .collect();
    Some((m as usize, n, AnchorStore::Ram(anchors)))
}

impl CsvSource {
    /// Index `path` with a full (stride-1) offset index.
    pub fn open(path: &Path) -> Result<CsvSource> {
        Self::open_with_stride(path, 1)
    }

    /// Index `path`, recording one offset per `stride` data rows. A valid
    /// `.idx` sidecar (see the module docs) satisfies the open in
    /// O(index); otherwise one streaming pass validates every row
    /// (skipping a header line whose first field is not numeric, and
    /// blank lines; rejecting ragged rows and non-numeric fields) and the
    /// sidecar is (re)written. After `open` succeeds, every indexed row
    /// is known to parse, so reads cannot fail on content (only on the
    /// file mutating underneath, which panics).
    pub fn open_with_stride(path: &Path, stride: usize) -> Result<CsvSource> {
        if stride == 0 {
            bail!("csv index stride must be ≥ 1");
        }
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "csv".into());
        let stamp = CsvStamp::of(path)?;
        let fingerprint = content_fingerprint(path, stamp.len)?;
        let idx_path = sidecar_path(path);
        if let Some((m, n, anchors)) = load_sidecar(&idx_path, &stamp, fingerprint, stride) {
            let file = File::open(path)
                .with_context(|| format!("open {}", path.display()))?;
            return Ok(CsvSource {
                name,
                n,
                m,
                stride,
                anchors,
                from_sidecar: true,
                file: Mutex::new(file),
            });
        }
        let file = File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut reader = BufReader::new(file);
        let mut anchors: Vec<u64> = Vec::new();
        let mut m = 0usize;
        let mut n = 0usize;
        let mut offset = 0u64;
        let mut line = String::new();
        let mut lineno = 0usize;
        loop {
            line.clear();
            let read = reader.read_line(&mut line)?;
            if read == 0 {
                break;
            }
            lineno += 1;
            let trimmed = line.trim();
            if !trimmed.is_empty() {
                let fields = trimmed.split(',').count();
                let first = trimmed.split(',').next().unwrap_or("").trim();
                if n == 0 && m == 0 && first.parse::<f32>().is_err() {
                    // Header row: skip.
                } else {
                    if n == 0 {
                        n = fields;
                    }
                    if fields != n {
                        bail!(
                            "{}:{}: expected {} fields, got {}",
                            path.display(),
                            lineno,
                            n,
                            fields
                        );
                    }
                    for f in trimmed.split(',') {
                        let f = f.trim();
                        if f.parse::<f32>().is_err() {
                            bail!("{}:{}: bad number '{f}'", path.display(), lineno);
                        }
                    }
                    if m % stride == 0 {
                        anchors.push(offset);
                    }
                    m += 1;
                }
            }
            offset += read as u64;
        }
        if m == 0 {
            bail!("{}: no data rows", path.display());
        }
        store_sidecar(&idx_path, &stamp, fingerprint, n, m, stride, &anchors);
        let file = reader.into_inner();
        Ok(CsvSource {
            name,
            n,
            m,
            stride,
            anchors: AnchorStore::Ram(anchors),
            from_sidecar: false,
            file: Mutex::new(file),
        })
    }

    /// Configured index stride.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Offsets the index holds (≈ `m / stride`; what the stride shrinks).
    pub fn indexed_offsets(&self) -> usize {
        self.anchors.count()
    }

    /// Whether this open was satisfied from the `.idx` sidecar (vs a full
    /// scan).
    pub fn index_from_sidecar(&self) -> bool {
        self.from_sidecar
    }

    fn parse_row(&self, text: &str, row: usize, out: &mut [f32]) {
        let mut fields = text.split(',');
        for (j, slot) in out.iter_mut().enumerate() {
            let field = fields
                .next()
                .unwrap_or_else(|| panic!("csv '{}': row {row} too short", self.name))
                .trim();
            *slot = field.parse::<f32>().unwrap_or_else(|_| {
                panic!("csv '{}': row {row} field {j}: bad number '{field}'", self.name)
            });
        }
    }

    /// Parse `count` consecutive data rows starting at data row `row` into
    /// `out`: seek to the nearest anchor at or before `row`, then scan
    /// forward line by line (skipping blank lines, which the index also
    /// skipped). `reader` and `line` are caller-owned so a whole gather
    /// reuses one buffer — seeking a `BufReader` discards its contents but
    /// keeps the allocation.
    fn scan_rows(
        &self,
        reader: &mut BufReader<&File>,
        line: &mut String,
        row: usize,
        count: usize,
        out: &mut [f32],
    ) {
        debug_assert!(row + count <= self.m);
        debug_assert_eq!(out.len(), count * self.n);
        if count == 0 {
            return;
        }
        let anchor = row / self.stride;
        let mut skip = row - anchor * self.stride;
        reader
            .seek(SeekFrom::Start(self.anchors.get(anchor)))
            .unwrap_or_else(|e| panic!("csv '{}': seek failed: {e}", self.name));
        let mut filled = 0usize;
        while filled < count {
            line.clear();
            let read = reader
                .read_line(line)
                .unwrap_or_else(|e| panic!("csv '{}': read failed: {e}", self.name));
            if read == 0 {
                panic!(
                    "csv '{}': file truncated while scanning row {}",
                    self.name,
                    row + filled
                );
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            if skip > 0 {
                skip -= 1;
                continue;
            }
            let slot = filled;
            self.parse_row(trimmed, row + slot, &mut out[slot * self.n..(slot + 1) * self.n]);
            filled += 1;
        }
    }
}

impl DataSource for CsvSource {
    fn name(&self) -> &str {
        &self.name
    }

    fn m(&self) -> usize {
        self.m
    }

    fn n(&self) -> usize {
        self.n
    }

    fn read_rows(&self, start: usize, out: &mut [f32]) {
        assert_eq!(out.len() % self.n, 0, "read_rows: out shape");
        let rows = out.len() / self.n;
        assert!(start + rows <= self.m, "read_rows: out of bounds");
        // Poison-recovering: scan_rows always seeks to an absolute anchor
        // first, so no cursor state survives a panicked holder.
        let f = lock_recover(&self.file);
        let mut reader = BufReader::new(&*f);
        let mut line = String::new();
        self.scan_rows(&mut reader, &mut line, start, rows, out);
    }

    fn sample_rows(&self, indices: &[usize], out: &mut [f32]) {
        assert_eq!(out.len(), indices.len() * self.n, "sample_rows: out shape");
        // One lock + one reader/line buffer for the whole gather; each
        // index seeks within its own stride window.
        let f = lock_recover(&self.file);
        let mut reader = BufReader::new(&*f);
        let mut line = String::new();
        for (slot, &row) in indices.iter().enumerate() {
            assert!(row < self.m, "sample_rows: row {row} out of bounds");
            self.scan_rows(
                &mut reader,
                &mut line,
                row,
                1,
                &mut out[slot * self.n..(slot + 1) * self.n],
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::loader;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bigmeans_csv_source_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{name}", std::process::id()))
    }

    fn cleanup(p: &Path) {
        let _ = std::fs::remove_file(sidecar_path(p));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn indexes_with_header_and_blank_lines() {
        let p = tmp("hdr.csv");
        std::fs::write(&p, "x,y\n1.5,2\n\n3,4.25\n-1,0\n").unwrap();
        let src = CsvSource::open(&p).unwrap();
        assert_eq!(src.m(), 3);
        assert_eq!(src.n(), 2);
        assert_eq!(src.stride(), 1);
        let mut out = vec![0f32; 6];
        src.read_rows(0, &mut out);
        assert_eq!(out, vec![1.5, 2.0, 3.0, 4.25, -1.0, 0.0]);
        cleanup(&p);
    }

    #[test]
    fn random_gather_matches_materialized_load() {
        let p = tmp("gather.csv");
        let mut text = String::new();
        for i in 0..50 {
            text.push_str(&format!("{},{},{}\n", i, i * 2, 0.25 * i as f32));
        }
        std::fs::write(&p, text).unwrap();
        let src = CsvSource::open(&p).unwrap();
        let full = loader::load_csv(&p, None).unwrap();
        let idx = [49usize, 0, 17, 17, 3];
        let mut out = vec![0f32; idx.len() * 3];
        src.sample_rows(&idx, &mut out);
        assert_eq!(out, full.gather(&idx));
        cleanup(&p);
    }

    #[test]
    fn ragged_rejected_and_no_rows_rejected() {
        let p = tmp("ragged.csv");
        std::fs::write(&p, "1,2\n3\n").unwrap();
        assert!(CsvSource::open(&p).is_err());
        std::fs::write(&p, "only,header\n").unwrap();
        assert!(CsvSource::open(&p).is_err());
        cleanup(&p);
    }

    #[test]
    fn crlf_lines_parse() {
        let p = tmp("crlf.csv");
        std::fs::write(&p, "1,2\r\n3,4\r\n").unwrap();
        let src = CsvSource::open(&p).unwrap();
        assert_eq!(src.m(), 2);
        let mut out = vec![0f32; 4];
        src.read_rows(0, &mut out);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0]);
        cleanup(&p);
    }

    #[test]
    fn zero_stride_rejected() {
        let p = tmp("zstride.csv");
        std::fs::write(&p, "1,2\n").unwrap();
        assert!(CsvSource::open_with_stride(&p, 0).is_err());
        cleanup(&p);
    }

    #[test]
    fn strided_index_shrinks_and_serves_identical_values() {
        // Header + blank lines + CRLF mixed in, so the stride-window scan
        // exercises every skip path.
        let p = tmp("stride.csv");
        let mut text = String::from("a,b\n");
        for i in 0..97 {
            let sep = if i % 7 == 0 { "\r\n" } else { "\n" };
            text.push_str(&format!("{},{}{sep}", i, i * 3));
            if i % 13 == 0 {
                text.push('\n'); // blank line
            }
        }
        std::fs::write(&p, text).unwrap();
        let dense = CsvSource::open(&p).unwrap();
        assert_eq!(dense.m(), 97);
        assert_eq!(dense.indexed_offsets(), 97);
        for stride in [2usize, 5, 16, 97, 500] {
            let sparse = CsvSource::open_with_stride(&p, stride).unwrap();
            assert_eq!(sparse.m(), 97, "stride {stride}");
            assert_eq!(
                sparse.indexed_offsets(),
                97usize.div_ceil(stride),
                "stride {stride}"
            );
            // Block reads across window boundaries.
            let mut a = vec![0f32; 97 * 2];
            let mut b = vec![0f32; 97 * 2];
            dense.read_rows(0, &mut a);
            sparse.read_rows(0, &mut b);
            assert_eq!(a, b, "stride {stride}: full read");
            let mut a = vec![0f32; 10 * 2];
            let mut b = vec![0f32; 10 * 2];
            dense.read_rows(43, &mut a);
            sparse.read_rows(43, &mut b);
            assert_eq!(a, b, "stride {stride}: mid-file block");
            // Scattered gathers, including within-window neighbours.
            let idx = [96usize, 0, 44, 45, 46, 13, 13, 95];
            let mut a = vec![0f32; idx.len() * 2];
            let mut b = vec![0f32; idx.len() * 2];
            dense.sample_rows(&idx, &mut a);
            sparse.sample_rows(&idx, &mut b);
            assert_eq!(a, b, "stride {stride}: gather");
        }
        cleanup(&p);
    }

    #[test]
    fn sidecar_written_once_and_reused_on_reopen() {
        let p = tmp("sidecar.csv");
        let mut text = String::new();
        for i in 0..200 {
            text.push_str(&format!("{},{}\n", i, 200 - i));
        }
        std::fs::write(&p, text).unwrap();
        let first = CsvSource::open_with_stride(&p, 4).unwrap();
        assert!(!first.index_from_sidecar(), "first open must scan");
        assert!(sidecar_path(&p).exists(), "scan must persist the sidecar");
        let second = CsvSource::open_with_stride(&p, 4).unwrap();
        assert!(second.index_from_sidecar(), "reopen must use the sidecar");
        assert_eq!(second.m(), 200);
        assert_eq!(second.n(), 2);
        assert_eq!(second.indexed_offsets(), 50);
        // Identical values through both index paths.
        let idx = [0usize, 3, 4, 7, 199, 100];
        let mut a = vec![0f32; idx.len() * 2];
        let mut b = vec![0f32; idx.len() * 2];
        first.sample_rows(&idx, &mut a);
        second.sample_rows(&idx, &mut b);
        assert_eq!(a, b);
        cleanup(&p);
    }

    #[test]
    fn sidecar_invalidated_by_csv_change_and_stride_mismatch() {
        let p = tmp("stale.csv");
        std::fs::write(&p, "1,2\n3,4\n5,6\n").unwrap();
        let _ = CsvSource::open(&p).unwrap();
        assert!(CsvSource::open(&p).unwrap().index_from_sidecar());
        // A different stride cannot reuse the stride-1 sidecar …
        let strided = CsvSource::open_with_stride(&p, 2).unwrap();
        assert!(!strided.index_from_sidecar());
        // … and rewriting the CSV (new length) invalidates it again.
        std::fs::write(&p, "10,20\n30,40\n50,60\n70,80\n").unwrap();
        let reopened = CsvSource::open_with_stride(&p, 2).unwrap();
        assert!(!reopened.index_from_sidecar());
        assert_eq!(reopened.m(), 4);
        let mut out = vec![0f32; 2];
        reopened.read_rows(3, &mut out);
        assert_eq!(out, vec![70.0, 80.0]);
        cleanup(&p);
    }

    #[test]
    fn same_size_rewrite_within_mtime_granule_detected_by_fingerprint() {
        let p = tmp("granule.csv");
        std::fs::write(&p, "1,2\n30,4\n5,6\n").unwrap();
        let _ = CsvSource::open(&p).unwrap();
        assert!(CsvSource::open(&p).unwrap().index_from_sidecar());
        // Same-byte-length rewrite with different content.
        std::fs::write(&p, "10,2\n3,4\n5,6\n").unwrap();
        // Forge the sidecar's stamp to the rewritten file's stamp — this
        // is exactly what a same-size rewrite inside one mtime granule
        // looks like on a coarse-timestamp filesystem.
        let stamp = CsvStamp::of(&p).unwrap();
        let idx = sidecar_path(&p);
        let mut bytes = std::fs::read(&idx).unwrap();
        bytes[8..16].copy_from_slice(&stamp.len.to_le_bytes());
        bytes[16..24].copy_from_slice(&stamp.mtime_secs.to_le_bytes());
        bytes[24..28].copy_from_slice(&stamp.mtime_nanos.to_le_bytes());
        std::fs::write(&idx, &bytes).unwrap();
        // The content fingerprint catches what the stamp cannot.
        let src = CsvSource::open(&p).unwrap();
        assert!(!src.index_from_sidecar(), "stale sidecar must be rejected");
        assert_eq!(src.m(), 3);
        let mut out = vec![0f32; 6];
        src.read_rows(0, &mut out);
        assert_eq!(out, vec![10.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        // The rescan healed the sidecar with the fresh fingerprint.
        assert!(CsvSource::open(&p).unwrap().index_from_sidecar());
        cleanup(&p);
    }

    #[test]
    fn corrupt_sidecar_falls_back_to_scan() {
        let p = tmp("corruptidx.csv");
        std::fs::write(&p, "1,2\n3,4\n5,6\n").unwrap();
        let _ = CsvSource::open(&p).unwrap();
        let idx = sidecar_path(&p);
        // Flip a byte inside the anchor table: checksum mismatch → rescan.
        let mut bytes = std::fs::read(&idx).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x55;
        std::fs::write(&idx, &bytes).unwrap();
        let src = CsvSource::open(&p).unwrap();
        assert!(!src.index_from_sidecar());
        let mut out = vec![0f32; 6];
        src.read_rows(0, &mut out);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        // The rescan healed the sidecar.
        assert!(CsvSource::open(&p).unwrap().index_from_sidecar());
        cleanup(&p);
    }
}
