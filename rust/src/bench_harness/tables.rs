//! Table generation matching the paper's formats:
//!
//! * per-dataset **summary tables** (Tables 5, 7, 9, …): per k, the
//!   relative error `E_A` (min/mean/max) and CPU seconds (min/mean/max)
//!   per algorithm, with the per-algorithm grand means at the bottom;
//! * per-dataset **clustering-details tables** (Tables 6, 8, 10, …):
//!   `s`, `n_s`, `cpu_max`, `n_full`, `n_d` per k;
//! * the cross-dataset **score summaries** (Tables 3 and 4).

use crate::metrics::{mean_score, relative_error, scores, Summary};

use super::runner::{f_best, ExperimentRuns};

/// One summary-table row: algorithm × k.
#[derive(Clone, Debug)]
pub struct SummaryRow {
    pub algorithm: &'static str,
    pub k: usize,
    pub f_best: f64,
    /// None when every repetition failed ("—").
    pub ea: Option<Summary>,
    pub cpu: Option<Summary>,
}

/// Per-dataset summary table (the paper's Tables 5, 7, …).
#[derive(Debug)]
pub struct SummaryTable {
    pub dataset: String,
    pub rows: Vec<SummaryRow>,
    /// Grand mean E_A and cpu per algorithm (the "Mean:" row).
    pub algo_means: Vec<(&'static str, Option<f64>, Option<f64>)>,
}

/// Build the summary table from raw runs.
pub fn summary_table(exp: &ExperimentRuns) -> SummaryTable {
    let mut rows = Vec::new();
    for (ki, &k) in exp.k_grid.iter().enumerate() {
        let Some(fb) = f_best(exp, ki) else { continue };
        for per_algo in &exp.cells {
            let cell = &per_algo[ki];
            let objectives = cell.objectives();
            let (ea, cpu) = if objectives.is_empty() {
                (None, None)
            } else {
                let errs: Vec<f64> =
                    objectives.iter().map(|&f| relative_error(f, fb)).collect();
                (Some(Summary::of(&errs)), Some(Summary::of(&cell.cpu_totals())))
            };
            rows.push(SummaryRow { algorithm: cell.algorithm, k, f_best: fb, ea, cpu });
        }
    }
    // Grand means per algorithm across k (paper's bottom "Mean:" row).
    let mut algo_means = Vec::new();
    for per_algo in &exp.cells {
        let name = per_algo[0].algorithm;
        let mut eas = Vec::new();
        let mut cpus = Vec::new();
        for row in rows.iter().filter(|r| r.algorithm == name) {
            if let (Some(ea), Some(cpu)) = (row.ea, row.cpu) {
                eas.push(ea.mean);
                cpus.push(cpu.mean);
            }
        }
        let mean = |v: &[f64]| {
            (!v.is_empty()).then(|| v.iter().sum::<f64>() / v.len() as f64)
        };
        algo_means.push((name, mean(&eas), mean(&cpus)));
    }
    SummaryTable { dataset: exp.dataset.clone(), rows, algo_means }
}

/// One clustering-details row (paper's Tables 6, 8, …).
#[derive(Clone, Debug)]
pub struct DetailRow {
    pub algorithm: &'static str,
    pub k: usize,
    pub n_exec: usize,
    /// Chunks processed (Big-means / DA-MSSC only).
    pub n_s: u64,
    /// Full-dataset iterations.
    pub n_full: u64,
    /// Mean distance evaluations.
    pub n_d: u64,
    pub cpu_init_mean: f64,
    pub cpu_full_mean: f64,
}

/// Build the details table.
pub fn details_table(exp: &ExperimentRuns) -> Vec<DetailRow> {
    let mut rows = Vec::new();
    for (ki, &k) in exp.k_grid.iter().enumerate() {
        for per_algo in &exp.cells {
            let cell = &per_algo[ki];
            let succeeded: Vec<_> = cell.runs.iter().flatten().collect();
            if succeeded.is_empty() {
                continue;
            }
            let counters = cell.mean_counters();
            let mean = |f: &dyn Fn(&&crate::baselines::AlgoResult) -> f64| {
                succeeded.iter().map(f).sum::<f64>() / succeeded.len() as f64
            };
            rows.push(DetailRow {
                algorithm: cell.algorithm,
                k,
                n_exec: exp.n_exec,
                n_s: counters.chunks,
                n_full: counters.full_iterations,
                n_d: counters.distance_evals,
                cpu_init_mean: mean(&|r| r.cpu_init_secs),
                cpu_full_mean: mean(&|r| r.cpu_full_secs),
            });
        }
    }
    rows
}

/// Per-dataset scores for Table 3/4: `(algorithm, S_accuracy, S_cpu)`.
pub fn dataset_scores(exp: &ExperimentRuns) -> Vec<(&'static str, f64, f64)> {
    let table = summary_table(exp);
    // Metric per algorithm = grand mean E_A / cpu (the paper scores the
    // final mean values at the bottom of each summary table).
    let names: Vec<&'static str> = table.algo_means.iter().map(|m| m.0).collect();
    let ea_vals: Vec<Option<f64>> = table.algo_means.iter().map(|m| m.1).collect();
    let cpu_vals: Vec<Option<f64>> = table.algo_means.iter().map(|m| m.2).collect();
    let s_ea = scores(&ea_vals);
    let s_cpu = scores(&cpu_vals);
    names
        .into_iter()
        .zip(s_ea.into_iter().zip(s_cpu))
        .map(|(n, (a, c))| (n, a, c))
        .collect()
}

/// Table 4: sum scores across datasets. Input: per-dataset score triples.
#[derive(Clone, Debug)]
pub struct Table4Row {
    pub algorithm: &'static str,
    pub accuracy_sum: f64,
    pub cpu_sum: f64,
    pub accuracy_pct: f64,
    pub cpu_pct: f64,
    pub mean_pct: f64,
}

pub fn table4(all: &[Vec<(&'static str, f64, f64)>]) -> Vec<Table4Row> {
    if all.is_empty() {
        return Vec::new();
    }
    let names: Vec<&'static str> = all[0].iter().map(|t| t.0).collect();
    let n_datasets = all.len() as f64;
    names
        .iter()
        .enumerate()
        .map(|(i, &name)| {
            let acc: f64 = all.iter().map(|d| d[i].1).sum();
            let cpu: f64 = all.iter().map(|d| d[i].2).sum();
            let mean: f64 = all
                .iter()
                .map(|d| mean_score(d[i].1, d[i].2))
                .sum::<f64>();
            Table4Row {
                algorithm: name,
                accuracy_sum: acc,
                cpu_sum: cpu,
                accuracy_pct: acc / n_datasets * 100.0,
                cpu_pct: cpu / n_datasets * 100.0,
                mean_pct: mean / n_datasets * 100.0,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::AlgoResult;
    use crate::bench_harness::runner::CellRuns;
    use crate::metrics::Counters;

    fn result(obj: f64, cpu: f64) -> Option<AlgoResult> {
        Some(AlgoResult {
            centroids: vec![],
            objective: obj,
            cpu_init_secs: cpu,
            cpu_full_secs: 0.0,
            counters: Counters::new(),
        })
    }

    fn fake_exp() -> ExperimentRuns {
        ExperimentRuns {
            dataset: "fake".into(),
            k_grid: vec![2],
            n_exec: 2,
            cells: vec![
                vec![CellRuns {
                    algorithm: "Big-Means",
                    k: 2,
                    runs: vec![result(100.0, 0.1), result(102.0, 0.12)],
                }],
                vec![CellRuns {
                    algorithm: "Slowpoke",
                    k: 2,
                    runs: vec![result(110.0, 3.0), result(120.0, 3.5)],
                }],
                vec![CellRuns { algorithm: "Broken", k: 2, runs: vec![None, None] }],
            ],
        }
    }

    #[test]
    fn summary_relative_errors_vs_fbest() {
        let t = summary_table(&fake_exp());
        let bm = t.rows.iter().find(|r| r.algorithm == "Big-Means").unwrap();
        assert_eq!(bm.f_best, 100.0);
        let ea = bm.ea.unwrap();
        assert!((ea.min - 0.0).abs() < 1e-9);
        assert!((ea.max - 2.0).abs() < 1e-9);
        let broken = t.rows.iter().find(|r| r.algorithm == "Broken").unwrap();
        assert!(broken.ea.is_none(), "all-failed must render as —");
    }

    #[test]
    fn scores_best_one_worst_zero_failed_zero() {
        let s = dataset_scores(&fake_exp());
        let find = |n: &str| s.iter().find(|t| t.0 == n).unwrap();
        assert_eq!(find("Big-Means").1, 1.0); // best accuracy
        assert_eq!(find("Big-Means").2, 1.0); // best cpu
        assert_eq!(find("Slowpoke").1, 0.0);
        assert_eq!(find("Broken").1, 0.0);
        assert_eq!(find("Broken").2, 0.0);
    }

    #[test]
    fn table4_aggregates_percentages() {
        let d1 = dataset_scores(&fake_exp());
        let d2 = dataset_scores(&fake_exp());
        let t4 = table4(&[d1, d2]);
        let bm = t4.iter().find(|r| r.algorithm == "Big-Means").unwrap();
        assert!((bm.accuracy_sum - 2.0).abs() < 1e-9);
        assert!((bm.accuracy_pct - 100.0).abs() < 1e-9);
        assert!((bm.mean_pct - 100.0).abs() < 1e-9);
    }

    #[test]
    fn details_rows_for_successes_only() {
        let rows = details_table(&fake_exp());
        assert_eq!(rows.len(), 2, "Broken must not appear");
    }
}
