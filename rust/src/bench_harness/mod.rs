//! Bench harness regenerating every table and figure of the paper's
//! evaluation (see DESIGN.md experiment index):
//!
//! * [`runner`] — k-grid × n_exec experiment execution over the roster;
//! * [`tables`] — Tables 3–4 (scores) and 5–50 (per-dataset summaries and
//!   clustering details);
//! * [`figures`] — Figures 1–4 series (distance evals / objective vs k)
//!   and convergence traces;
//! * [`report`] — markdown/CSV rendering into `target/reports/`;
//! * [`compare`] — bench regression gating (`bench --compare`): diff two
//!   bench JSON documents and flag perf leaves beyond a tolerance.

pub mod compare;
pub mod figures;
pub mod report;
pub mod runner;
pub mod tables;

pub use runner::{paper_roster, quick_roster, run_experiment, BigMeansAlgo, ExperimentRuns};
pub use tables::{dataset_scores, details_table, summary_table, table4};
