//! Figure data matching the paper's Figures 1–4: per dataset, the number
//! of distance-function evaluations (`n_d`) and the achieved objective vs
//! the number of clusters k, one series per algorithm; plus the
//! convergence traces (objective vs wall-clock) used in the analysis.

use super::runner::ExperimentRuns;

/// One figure series: y-values per k for one algorithm.
#[derive(Clone, Debug)]
pub struct Series {
    pub algorithm: &'static str,
    pub k_grid: Vec<usize>,
    pub values: Vec<Option<f64>>,
}

/// Figures 1–4 (left panels): mean distance evaluations vs k.
pub fn distance_evals_series(exp: &ExperimentRuns) -> Vec<Series> {
    exp.cells
        .iter()
        .map(|per_algo| Series {
            algorithm: per_algo[0].algorithm,
            k_grid: exp.k_grid.clone(),
            values: per_algo
                .iter()
                .map(|cell| {
                    (!cell.all_failed()).then(|| cell.mean_counters().distance_evals as f64)
                })
                .collect(),
        })
        .collect()
}

/// Figures 1–4 (right panels): mean objective vs k.
pub fn objective_series(exp: &ExperimentRuns) -> Vec<Series> {
    exp.cells
        .iter()
        .map(|per_algo| Series {
            algorithm: per_algo[0].algorithm,
            k_grid: exp.k_grid.clone(),
            values: per_algo
                .iter()
                .map(|cell| {
                    let objs = cell.objectives();
                    (!objs.is_empty()).then(|| objs.iter().sum::<f64>() / objs.len() as f64)
                })
                .collect(),
        })
        .collect()
}

/// Mean CPU seconds vs k (the paper reports these in the tables; plotted
/// here as a figure series for the report).
pub fn cpu_series(exp: &ExperimentRuns) -> Vec<Series> {
    exp.cells
        .iter()
        .map(|per_algo| Series {
            algorithm: per_algo[0].algorithm,
            k_grid: exp.k_grid.clone(),
            values: per_algo
                .iter()
                .map(|cell| {
                    let cpus = cell.cpu_totals();
                    (!cpus.is_empty()).then(|| cpus.iter().sum::<f64>() / cpus.len() as f64)
                })
                .collect(),
        })
        .collect()
}

/// A convergence trace: (elapsed seconds, best chunk objective) samples
/// from one Big-means run — the §4.1 "objective vs time" analysis.
#[derive(Clone, Debug, Default)]
pub struct ConvergenceTrace {
    pub samples: Vec<(f64, f64)>,
}

impl ConvergenceTrace {
    pub fn record(&mut self, elapsed_secs: f64, objective: f64) {
        self.samples.push((elapsed_secs, objective));
    }

    /// Objectives must be non-increasing over time (keep-the-best).
    pub fn is_monotone(&self) -> bool {
        self.samples.windows(2).all(|w| w[1].1 <= w[0].1 + 1e-12)
    }
}

/// Render a series set as an ASCII sparkline table (for terminal output).
pub fn render_ascii(series: &[Series], title: &str, log_scale: bool) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(out, "### {title}");
    for s in series {
        let _ = write!(out, "{:<22}", s.algorithm);
        let finite: Vec<f64> = s
            .values
            .iter()
            .flatten()
            .map(|&v| if log_scale { v.max(1.0).log10() } else { v })
            .collect();
        if finite.is_empty() {
            let _ = writeln!(out, " (all failed)");
            continue;
        }
        let lo = finite.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = finite.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let ticks = ['\u{2581}', '\u{2582}', '\u{2583}', '\u{2584}', '\u{2585}', '\u{2586}', '\u{2587}', '\u{2588}'];
        for v in &s.values {
            match v {
                None => out.push('·'),
                Some(v) => {
                    let v = if log_scale { v.max(1.0).log10() } else { *v };
                    let t = if hi > lo { (v - lo) / (hi - lo) } else { 0.5 };
                    let idx = ((t * 7.0).round() as usize).min(7);
                    out.push(ticks[idx]);
                }
            }
        }
        let _ = writeln!(out, "  [{:.3e} … {:.3e}]", lo, hi);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::AlgoResult;
    use crate::bench_harness::runner::CellRuns;
    use crate::metrics::Counters;

    fn cell(name: &'static str, k: usize, objs: &[f64], nd: u64) -> CellRuns {
        CellRuns {
            algorithm: name,
            k,
            runs: objs
                .iter()
                .map(|&o| {
                    let mut c = Counters::new();
                    c.add_distance_evals(nd);
                    Some(AlgoResult {
                        centroids: vec![],
                        objective: o,
                        cpu_init_secs: 0.0,
                        cpu_full_secs: 0.1,
                        counters: c,
                    })
                })
                .collect(),
        }
    }

    fn exp() -> ExperimentRuns {
        ExperimentRuns {
            dataset: "d".into(),
            k_grid: vec![2, 5],
            n_exec: 1,
            cells: vec![
                vec![cell("A", 2, &[10.0], 100), cell("A", 5, &[5.0], 250)],
                vec![cell("B", 2, &[12.0], 1000), cell("B", 5, &[6.0], 2500)],
            ],
        }
    }

    #[test]
    fn series_extraction() {
        let e = exp();
        let nd = distance_evals_series(&e);
        assert_eq!(nd[0].values, vec![Some(100.0), Some(250.0)]);
        assert_eq!(nd[1].values, vec![Some(1000.0), Some(2500.0)]);
        let obj = objective_series(&e);
        assert_eq!(obj[0].values, vec![Some(10.0), Some(5.0)]);
        let cpu = cpu_series(&e);
        assert_eq!(cpu[0].values, vec![Some(0.1), Some(0.1)]);
    }

    #[test]
    fn trace_monotonicity() {
        let mut t = ConvergenceTrace::default();
        t.record(0.0, 10.0);
        t.record(1.0, 8.0);
        t.record(2.0, 8.0);
        assert!(t.is_monotone());
        t.record(3.0, 9.0);
        assert!(!t.is_monotone());
    }

    #[test]
    fn ascii_render_handles_gaps() {
        let s = vec![Series {
            algorithm: "A",
            k_grid: vec![2, 3],
            values: vec![Some(1.0), None],
        }];
        let text = render_ascii(&s, "t", false);
        assert!(text.contains('·'));
        assert!(text.contains("A"));
    }
}
