//! Report rendering: the tables/figures as markdown + CSV under
//! `target/reports/`, in the same row format the paper prints.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use super::figures::Series;
use super::tables::{DetailRow, SummaryTable, Table4Row};

/// Reports directory (created on demand).
pub fn reports_dir() -> PathBuf {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("target/reports");
    let _ = std::fs::create_dir_all(&dir);
    dir
}

fn fmt_opt(v: Option<f64>, prec: usize) -> String {
    match v {
        Some(x) => format!("{x:.prec$}"),
        None => "—".to_string(),
    }
}

/// Render a summary table as markdown (paper Tables 5, 7, …).
pub fn render_summary_markdown(t: &SummaryTable) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "## Summary — {}", t.dataset);
    let _ = writeln!(
        out,
        "| algorithm | k | f_best* | E_A min | E_A mean | E_A max | cpu min | cpu mean | cpu max |"
    );
    let _ = writeln!(out, "|---|---|---|---|---|---|---|---|---|");
    for r in &t.rows {
        let _ = writeln!(
            out,
            "| {} | {} | {:.6e} | {} | {} | {} | {} | {} | {} |",
            r.algorithm,
            r.k,
            r.f_best,
            fmt_opt(r.ea.map(|s| s.min), 2),
            fmt_opt(r.ea.map(|s| s.mean), 2),
            fmt_opt(r.ea.map(|s| s.max), 2),
            fmt_opt(r.cpu.map(|s| s.min), 3),
            fmt_opt(r.cpu.map(|s| s.mean), 3),
            fmt_opt(r.cpu.map(|s| s.max), 3),
        );
    }
    let _ = writeln!(out, "\n**Mean over k:**\n");
    let _ = writeln!(out, "| algorithm | E_A mean | cpu mean |");
    let _ = writeln!(out, "|---|---|---|");
    for (name, ea, cpu) in &t.algo_means {
        let _ = writeln!(out, "| {} | {} | {} |", name, fmt_opt(*ea, 2), fmt_opt(*cpu, 3));
    }
    out
}

/// Render the details table as markdown (paper Tables 6, 8, …).
pub fn render_details_markdown(dataset: &str, rows: &[DetailRow]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "## Clustering details — {dataset}");
    let _ = writeln!(
        out,
        "| algorithm | k | n_exec | n_s | n_full | n_d | cpu_init | cpu_full |"
    );
    let _ = writeln!(out, "|---|---|---|---|---|---|---|---|");
    for r in rows {
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {} | {:.2e} | {:.3} | {:.3} |",
            r.algorithm, r.k, r.n_exec, r.n_s, r.n_full, r.n_d as f64, r.cpu_init_mean, r.cpu_full_mean,
        );
    }
    out
}

/// Render Table 4 (the headline cross-dataset comparison).
pub fn render_table4_markdown(rows: &[Table4Row], n_datasets: usize) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "## Table 4 — Summary of sum scores ({n_datasets} datasets)");
    let _ = writeln!(
        out,
        "| Algorithm | Accuracy | CPU time | Accuracy (%) | CPU time (%) | Mean score (%) |"
    );
    let _ = writeln!(out, "|---|---|---|---|---|---|");
    for r in rows {
        let _ = writeln!(
            out,
            "| {} | {:.3} | {:.3} | {:.0} | {:.0} | {:.0} |",
            r.algorithm, r.accuracy_sum, r.cpu_sum, r.accuracy_pct, r.cpu_pct, r.mean_pct,
        );
    }
    out
}

/// CSV for a figure series set (one row per (algorithm, k)).
pub fn series_csv(series: &[Series], value_name: &str) -> String {
    let mut out = format!("algorithm,k,{value_name}\n");
    for s in series {
        for (i, &k) in s.k_grid.iter().enumerate() {
            let v = s.values[i].map(|v| v.to_string()).unwrap_or_default();
            let _ = writeln!(out, "{},{},{}", s.algorithm, k, v);
        }
    }
    out
}

/// Write a report file; returns the path.
pub fn write_report(name: &str, content: &str) -> PathBuf {
    let path = reports_dir().join(name);
    std::fs::write(&path, content).expect("write report");
    path
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Summary;

    #[test]
    fn markdown_renders_dashes_for_failures() {
        let t = SummaryTable {
            dataset: "d".into(),
            rows: vec![super::super::tables::SummaryRow {
                algorithm: "Ward's",
                k: 2,
                f_best: 10.0,
                ea: None,
                cpu: None,
            }],
            algo_means: vec![("Ward's", None, None)],
        };
        let md = render_summary_markdown(&t);
        assert!(md.contains("| Ward's | 2 |"));
        assert!(md.contains("—"));
    }

    #[test]
    fn summary_includes_values() {
        let s = Summary { min: 0.1, mean: 0.2, max: 0.3 };
        let t = SummaryTable {
            dataset: "d".into(),
            rows: vec![super::super::tables::SummaryRow {
                algorithm: "Big-Means",
                k: 5,
                f_best: 123.0,
                ea: Some(s),
                cpu: Some(s),
            }],
            algo_means: vec![("Big-Means", Some(0.2), Some(0.2))],
        };
        let md = render_summary_markdown(&t);
        assert!(md.contains("0.20"));
        assert!(md.contains("1.230000e2") || md.contains("1.23e2") || md.contains("123"));
    }

    #[test]
    fn csv_format() {
        let s = vec![Series {
            algorithm: "A",
            k_grid: vec![2, 3],
            values: vec![Some(7.0), None],
        }];
        let csv = series_csv(&s, "nd");
        assert!(csv.starts_with("algorithm,k,nd\n"));
        assert!(csv.contains("A,2,7"));
        assert!(csv.contains("A,3,\n"));
    }

    #[test]
    fn report_written_to_disk() {
        let p = write_report("test_report.md", "# hello");
        assert!(p.exists());
        let _ = std::fs::remove_file(p);
    }
}
