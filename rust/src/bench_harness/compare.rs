//! Bench regression gating: diff two bench JSON documents and flag the
//! performance leaves that got worse than a tolerance.
//!
//! `bench --compare BASELINE.json --tolerance PCT` runs a suite, then
//! feeds the freshly written output document and the committed baseline
//! through [`compare_docs`]; any regression beyond the tolerance makes
//! the binary exit nonzero, so CI can gate on "no suite got slower".
//!
//! Only leaves whose key names mark them as performance measurements are
//! compared (wall times, throughputs, speedups, overhead ratios) — the
//! configuration echo, objectives, and counters are deterministic and
//! belong to correctness tests, not a noise-tolerant perf gate. Direction
//! is inferred from the key name: `*_secs`/`*_ms`/`*_ratio` regress
//! upward, `*qps`/`*_per_s(ec)`/`*speedup`/`*reduction`/`*mb_per_s`
//! regress downward. A baseline key missing from the candidate is always
//! a regression (a renamed metric must re-baseline explicitly).

use crate::util::json::Json;

/// Noise floor: leaves where both sides are below this are skipped —
/// relative tolerance on a sub-millisecond timing is pure jitter.
pub const COMPARE_NOISE_FLOOR: f64 = 1e-3;

/// Which way a measured leaf regresses, inferred from its key name.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Direction {
    /// Wall times, latencies, overhead ratios: bigger is worse.
    LowerIsBetter,
    /// Throughputs, speedups, pruning reductions: smaller is worse.
    HigherIsBetter,
    /// Config echo, objectives, counters: not a perf leaf — skip.
    NotPerf,
}

fn direction(key: &str) -> Direction {
    // Higher-is-better suffixes first: "warm_speedup" must not fall into
    // a generic substring trap, and "*_per_s" covers rows_per_sec too.
    for suffix in ["qps", "_per_s", "_per_sec", "speedup", "reduction", "mb_per_s"] {
        if key.ends_with(suffix) {
            return Direction::HigherIsBetter;
        }
    }
    for suffix in ["secs", "_ms", "_ratio"] {
        if key.ends_with(suffix) {
            return Direction::LowerIsBetter;
        }
    }
    Direction::NotPerf
}

/// Diff `candidate` against `baseline`: returns one human-readable line
/// per regression beyond `tolerance_pct` (empty = gate passes). Walks the
/// baseline document, so candidate-only keys (new metrics) never fail.
pub fn compare_docs(baseline: &Json, candidate: &Json, tolerance_pct: f64) -> Vec<String> {
    let mut regressions = Vec::new();
    walk(baseline, candidate, "", tolerance_pct, &mut regressions);
    regressions
}

fn walk(base: &Json, cand: &Json, path: &str, tol: f64, out: &mut Vec<String>) {
    match base {
        Json::Obj(map) => {
            for (key, bval) in map {
                let sub = if path.is_empty() { key.clone() } else { format!("{path}.{key}") };
                match cand.get(key) {
                    Some(cval) => walk(bval, cval, &sub, tol, out),
                    None => {
                        if leaf_is_perf(bval, key) {
                            out.push(format!("{sub}: present in baseline, missing in candidate"));
                        }
                    }
                }
            }
        }
        Json::Arr(items) => {
            let Json::Arr(cand_items) = cand else {
                out.push(format!("{path}: baseline is an array, candidate is not"));
                return;
            };
            for (i, bval) in items.iter().enumerate() {
                let sub = format!("{path}[{}]", label_for(bval, i));
                match cand_items.get(i) {
                    Some(cval) => walk(bval, cval, &sub, tol, out),
                    None => out.push(format!("{sub}: missing in candidate")),
                }
            }
        }
        Json::Num(bnum) => {
            let key = path.rsplit('.').next().unwrap_or(path);
            let dir = direction(key);
            if dir == Direction::NotPerf {
                return;
            }
            let Some(cnum) = cand.as_f64() else {
                out.push(format!("{path}: baseline is a number, candidate is not"));
                return;
            };
            check_leaf(path, *bnum, cnum, dir, tol, out);
        }
        _ => {}
    }
}

/// A stable array-element label: the element's `name`/`codec`/`workload`
/// tag when it has one, else the index.
fn label_for(element: &Json, index: usize) -> String {
    for tag in ["name", "workload", "codec", "dtype", "multiplier"] {
        if let Some(v) = element.get(tag) {
            if let Some(text) = v.as_str() {
                return text.to_string();
            }
            if let Some(x) = v.as_f64() {
                return format!("{tag}={x}");
            }
        }
    }
    index.to_string()
}

fn leaf_is_perf(value: &Json, key: &str) -> bool {
    matches!(value, Json::Num(_)) && direction(key) != Direction::NotPerf
}

fn check_leaf(path: &str, base: f64, cand: f64, dir: Direction, tol: f64, out: &mut Vec<String>) {
    if !base.is_finite() || !cand.is_finite() {
        out.push(format!("{path}: non-finite value (baseline {base}, candidate {cand})"));
        return;
    }
    if base.abs().max(cand.abs()) < COMPARE_NOISE_FLOOR {
        return; // both below the noise floor — jitter, not signal
    }
    let factor = tol / 100.0;
    let (worse, allowed) = match dir {
        Direction::LowerIsBetter => (cand > base * (1.0 + factor), base * (1.0 + factor)),
        Direction::HigherIsBetter => (cand < base * (1.0 - factor), base * (1.0 - factor)),
        Direction::NotPerf => return,
    };
    if worse {
        let change = if base.abs() > 1e-12 { (cand / base - 1.0) * 100.0 } else { f64::INFINITY };
        out.push(format!(
            "{path}: {cand:.6} vs baseline {base:.6} ({change:+.1}%, allowed {allowed:.6})"
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{arr, num, obj, s};

    fn doc(secs: f64, qps: f64) -> Json {
        obj(vec![
            ("m", num(1000.0)),
            ("wall_secs", num(secs)),
            ("qps", num(qps)),
            (
                "cases",
                arr(vec![obj(vec![
                    ("name", s("panel_uniform")),
                    ("secs", num(secs)),
                    ("distance_evals", num(5e6)),
                ])]),
            ),
        ])
    }

    #[test]
    fn identical_documents_pass() {
        let base = doc(2.0, 100.0);
        assert!(compare_docs(&base, &base, 10.0).is_empty());
    }

    #[test]
    fn improvements_and_non_perf_drift_pass() {
        let base = doc(2.0, 100.0);
        // Faster, higher throughput, and a changed counter: all fine.
        let mut cand = doc(1.0, 250.0);
        if let Json::Obj(map) = &mut cand {
            map.insert("m".into(), num(9999.0));
        }
        assert!(compare_docs(&base, &cand, 10.0).is_empty());
    }

    #[test]
    fn slower_time_and_lower_throughput_fail() {
        let base = doc(2.0, 100.0);
        let cand = doc(3.0, 50.0);
        let regressions = compare_docs(&base, &cand, 25.0);
        // wall_secs, qps, and the per-case secs all regressed.
        assert_eq!(regressions.len(), 3, "{regressions:?}");
        assert!(regressions.iter().any(|r| r.starts_with("wall_secs:")));
        assert!(regressions.iter().any(|r| r.starts_with("qps:")));
        assert!(regressions.iter().any(|r| r.contains("cases[panel_uniform].secs")));
    }

    #[test]
    fn tolerance_is_respected() {
        let base = doc(2.0, 100.0);
        let cand = doc(2.2, 95.0); // +10% on both timings, -5% qps
        assert!(compare_docs(&base, &cand, 25.0).is_empty());
        // At 5% the two timing leaves fail; qps sits exactly on the edge
        // (strict inequality) and passes.
        assert_eq!(compare_docs(&base, &cand, 5.0).len(), 2);
    }

    #[test]
    fn missing_perf_key_is_a_regression() {
        let base = doc(2.0, 100.0);
        let mut cand = doc(2.0, 100.0);
        if let Json::Obj(map) = &mut cand {
            map.remove("qps");
        }
        let regressions = compare_docs(&base, &cand, 25.0);
        assert_eq!(regressions.len(), 1);
        assert!(regressions[0].contains("missing in candidate"));
    }

    #[test]
    fn noise_floor_skips_tiny_timings() {
        let base = obj(vec![("warm_secs", num(2e-4))]);
        let cand = obj(vec![("warm_secs", num(9e-4))]); // 4.5× but microseconds
        assert!(compare_docs(&base, &cand, 10.0).is_empty());
    }

    #[test]
    fn ratio_keys_regress_upward() {
        let base = obj(vec![("obs_enabled_vs_disabled_ratio", num(1.0))]);
        let cand = obj(vec![("obs_enabled_vs_disabled_ratio", num(1.6))]);
        assert_eq!(compare_docs(&base, &cand, 25.0).len(), 1);
        assert!(compare_docs(&base, &cand, 100.0).is_empty());
    }
}
