//! Experiment runner: executes the §5 algorithm roster over a catalog
//! dataset with the paper's protocol (k-grid × n_exec repetitions),
//! collecting the per-run records the tables and figures are built from.

use std::time::Duration;

use crate::baselines::{
    AlgoFailure, AlgoResult, DaMssc, ForgyKMeans, KMeansPP, KMeansParallel, LmbmClust,
    MsscAlgorithm, Wards,
};
use crate::coordinator::config::{BigMeansConfig, ParallelMode, StopCondition};
use crate::coordinator::BigMeans;
use crate::data::catalog::CatalogEntry;
use crate::data::dataset::Dataset;
use crate::metrics::Counters;

/// Big-means wrapped as an [`MsscAlgorithm`] so the harness treats it
/// uniformly with the baselines.
pub struct BigMeansAlgo {
    pub chunk_size: usize,
    pub cpu_max: Duration,
    /// Optional chunk cap (deterministic harness runs).
    pub max_chunks: Option<u64>,
    pub parallel: ParallelMode,
    pub threads: usize,
}

impl BigMeansAlgo {
    pub fn for_entry(entry: &CatalogEntry) -> Self {
        BigMeansAlgo {
            chunk_size: entry.chunk_size,
            cpu_max: Duration::from_secs_f64(entry.cpu_max_secs),
            max_chunks: None,
            parallel: ParallelMode::InnerParallel,
            threads: 0,
        }
    }
}

impl MsscAlgorithm for BigMeansAlgo {
    fn name(&self) -> &'static str {
        "Big-Means"
    }

    fn run(&self, data: &Dataset, k: usize, seed: u64) -> Result<AlgoResult, AlgoFailure> {
        let stop = match self.max_chunks {
            Some(c) => StopCondition::TimeOrChunks(self.cpu_max, c),
            None => StopCondition::MaxTime(self.cpu_max),
        };
        let cfg = BigMeansConfig::new(k, self.chunk_size)
            .with_stop(stop)
            .with_parallel(self.parallel)
            .with_seed(seed);
        let r = BigMeans::new(BigMeansConfig { threads: self.threads, ..cfg })
            .run(data)
            .map_err(AlgoFailure::Invalid)?;
        Ok(AlgoResult {
            centroids: r.centroids,
            objective: r.objective,
            cpu_init_secs: r.cpu_init_secs,
            cpu_full_secs: r.cpu_full_secs,
            counters: r.counters,
        })
    }
}

/// The roster in the paper's column order.
pub fn paper_roster(entry: &CatalogEntry) -> Vec<Box<dyn MsscAlgorithm>> {
    vec![
        Box::new(BigMeansAlgo::for_entry(entry)),
        Box::new(ForgyKMeans::default()),
        Box::new(Wards::default()),
        Box::new(KMeansPP::default()),
        Box::new(KMeansParallel::default()),
        Box::new(LmbmClust {
            // Scale the budget with the harness: LMBM gets 20× Big-means'
            // budget before it's declared over-budget (mirrors the paper
            // where LMBM ran for hours but *did* run on medium sets).
            time_budget_secs: (entry.cpu_max_secs * 20.0).max(5.0),
            ..Default::default()
        }),
        Box::new(DaMssc::new(entry.chunk_size, 10)),
    ]
}

/// A small roster for fast benches (Big-means + the two cheap baselines).
pub fn quick_roster(entry: &CatalogEntry) -> Vec<Box<dyn MsscAlgorithm>> {
    vec![
        Box::new(BigMeansAlgo::for_entry(entry)),
        Box::new(ForgyKMeans::default()),
        Box::new(KMeansPP::default()),
    ]
}

/// One algorithm × one k: all repetition outcomes.
#[derive(Debug)]
pub struct CellRuns {
    pub algorithm: &'static str,
    pub k: usize,
    /// Per-repetition outcome; None = failure (OOM / budget), the paper's
    /// "—" entries.
    pub runs: Vec<Option<AlgoResult>>,
}

impl CellRuns {
    pub fn objectives(&self) -> Vec<f64> {
        self.runs
            .iter()
            .flatten()
            .map(|r| r.objective)
            .collect()
    }

    pub fn cpu_totals(&self) -> Vec<f64> {
        self.runs
            .iter()
            .flatten()
            .map(|r| r.cpu_total_secs())
            .collect()
    }

    pub fn all_failed(&self) -> bool {
        self.runs.iter().all(|r| r.is_none())
    }

    pub fn mean_counters(&self) -> Counters {
        let mut total = Counters::new();
        let mut count = 0u64;
        for r in self.runs.iter().flatten() {
            total.merge(&r.counters);
            count += 1;
        }
        if count > 0 {
            total.distance_evals /= count;
            total.pruned_evals /= count;
            total.full_iterations /= count;
            total.chunk_iterations /= count;
            total.chunks /= count;
        }
        total
    }
}

/// Full experiment output for one dataset: `cells[algo][k_index]`.
#[derive(Debug)]
pub struct ExperimentRuns {
    pub dataset: String,
    pub k_grid: Vec<usize>,
    pub n_exec: usize,
    pub cells: Vec<Vec<CellRuns>>,
}

/// Run `roster` over `data` for every `k` in `k_grid`, `n_exec` times each.
pub fn run_experiment(
    data: &Dataset,
    roster: &[Box<dyn MsscAlgorithm>],
    k_grid: &[usize],
    n_exec: usize,
    base_seed: u64,
) -> ExperimentRuns {
    let mut cells = Vec::with_capacity(roster.len());
    for algo in roster {
        let mut per_algo = Vec::with_capacity(k_grid.len());
        for &k in k_grid {
            let mut runs = Vec::with_capacity(n_exec);
            for rep in 0..n_exec {
                let seed = base_seed
                    .wrapping_mul(0x9E37_79B9)
                    .wrapping_add((k as u64) << 32)
                    .wrapping_add(rep as u64);
                runs.push(algo.run(data, k, seed).ok());
            }
            per_algo.push(CellRuns { algorithm: algo.name(), k, runs });
        }
        cells.push(per_algo);
    }
    ExperimentRuns {
        dataset: data.name.clone(),
        k_grid: k_grid.to_vec(),
        n_exec,
        cells,
    }
}

/// Best (minimum) objective seen anywhere in the experiment for a given k —
/// the harness's `f_best` (the paper uses literature values; ours are
/// computed from the strongest roster run, marked `*` in the report).
pub fn f_best(exp: &ExperimentRuns, k_index: usize) -> Option<f64> {
    let mut best = f64::INFINITY;
    for per_algo in &exp.cells {
        for r in per_algo[k_index].runs.iter().flatten() {
            if r.objective < best {
                best = r.objective;
            }
        }
    }
    best.is_finite().then_some(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::catalog;

    #[test]
    fn quick_experiment_has_complete_grid() {
        let entry = catalog::find("D15112").unwrap();
        let data = entry.generate(1);
        let mut roster = quick_roster(&entry);
        // Tighten Big-means for test speed.
        roster[0] = Box::new(BigMeansAlgo {
            chunk_size: 512,
            cpu_max: Duration::from_millis(100),
            max_chunks: Some(5),
            parallel: ParallelMode::Sequential,
            threads: 1,
        });
        let exp = run_experiment(&data, &roster, &[2, 3], 2, 42);
        assert_eq!(exp.cells.len(), 3);
        assert_eq!(exp.cells[0].len(), 2);
        assert_eq!(exp.cells[0][0].runs.len(), 2);
        assert!(!exp.cells[0][0].all_failed());
        let fb = f_best(&exp, 0).unwrap();
        assert!(fb.is_finite() && fb > 0.0);
        // f_best is the min across all runs.
        for per_algo in &exp.cells {
            for r in per_algo[0].runs.iter().flatten() {
                assert!(r.objective >= fb);
            }
        }
    }

    #[test]
    fn failures_recorded_as_none() {
        let entry = catalog::find("D15112").unwrap();
        let data = entry.generate(2);
        let roster: Vec<Box<dyn MsscAlgorithm>> = vec![Box::new(Wards {
            memory_cap_bytes: 1, // force OOM
        })];
        let exp = run_experiment(&data, &roster, &[2], 2, 1);
        assert!(exp.cells[0][0].all_failed());
        assert!(f_best(&exp, 0).is_none());
    }
}
