//! Chunk-solver abstraction: the engine that runs the MSSC local search on
//! one chunk. Two implementations share exact semantics:
//!
//! * [`NativeSolver`] — rust kernels (any shape, optional inner parallelism);
//! * `runtime::PjrtSolver` — the AOT HLO executables via the PJRT C API.

use crate::kernels::{self, KernelEngine, KernelEngineKind, LloydParams, LloydResult};
use crate::metrics::Counters;
use crate::util::threadpool::ThreadPool;

/// How the coordinator's final full-dataset pass should run for a solver.
pub enum FinalPassMode<'a> {
    /// The canonical native pass: panel-decomposition arithmetic for every
    /// point, block pruning from store summaries, and the double-buffered
    /// decode/assign pipeline on the given pool (`None` = serial). See
    /// `coordinator::bigmeans`.
    Canonical(Option<&'a ThreadPool>),
    /// Opaque engine (PJRT): the coordinator streams fixed-size slabs
    /// through [`ChunkSolver::assign`] exactly as before.
    Solver,
}

/// Engine interface for chunk-local search and assignment passes.
///
/// Not `Send`/`Sync`: the PJRT client is single-threaded (`Rc` inside the
/// `xla` crate). The chunk-parallel pipeline (strategy 2) therefore builds
/// its own per-worker [`NativeSolver`]s instead of sharing a trait object.
pub trait ChunkSolver {
    /// Lloyd local search on `points` (`rows×n`) seeded by `seed_centroids`
    /// (`k×n`). Returns converged centroids + stats.
    fn lloyd(
        &self,
        points: &[f32],
        rows: usize,
        n: usize,
        k: usize,
        seed_centroids: &[f32],
        counters: &mut Counters,
    ) -> LloydResult;

    /// Nearest-centroid assignment: `(labels, min_sq_dists)`.
    fn assign(
        &self,
        points: &[f32],
        rows: usize,
        n: usize,
        k: usize,
        centroids: &[f32],
        counters: &mut Counters,
    ) -> (Vec<u32>, Vec<f32>);

    /// Human-readable engine name (for reports).
    fn name(&self) -> &'static str;

    /// Which final-pass implementation this solver supports. Defaults to
    /// the slab-streaming [`ChunkSolver::assign`] path; native solvers
    /// opt into the canonical pruned + double-buffered pipeline.
    fn final_pass_mode(&self) -> FinalPassMode<'_> {
        FinalPassMode::Solver
    }
}

/// Native rust engine.
pub struct NativeSolver {
    pub params: LloydParams,
    pub pool: Option<ThreadPool>,
    /// Assignment-step strategy (panel / bounded), shared by the Lloyd
    /// loop and the stateless assignment passes.
    engine: Box<dyn KernelEngine>,
}

impl NativeSolver {
    pub fn new(params: LloydParams, threads: usize) -> Self {
        Self::with_kernel(params, threads, KernelEngineKind::Panel)
    }

    /// Build with an explicit kernel engine selection.
    pub fn with_kernel(params: LloydParams, threads: usize, kernel: KernelEngineKind) -> Self {
        Self::with_kernel_threshold(params, threads, kernel, None)
    }

    /// Build with an explicit kernel engine and hybrid switch threshold
    /// (`None` = the engine default; see
    /// [`KernelEngineKind::build_with_threshold`]).
    pub fn with_kernel_threshold(
        params: LloydParams,
        threads: usize,
        kernel: KernelEngineKind,
        hybrid_threshold: Option<f64>,
    ) -> Self {
        let pool = match threads {
            1 => None,
            0 => Some(ThreadPool::with_default_size()),
            t => Some(ThreadPool::new(t)),
        };
        NativeSolver { params, pool, engine: kernel.build_with_threshold(hybrid_threshold) }
    }

    /// Fully sequential solver (deterministic tests).
    pub fn sequential(params: LloydParams) -> Self {
        Self::sequential_with_kernel(params, KernelEngineKind::Panel)
    }

    /// Fully sequential solver with an explicit kernel engine.
    pub fn sequential_with_kernel(params: LloydParams, kernel: KernelEngineKind) -> Self {
        Self::sequential_with_kernel_threshold(params, kernel, None)
    }

    /// Fully sequential solver with an explicit kernel engine and hybrid
    /// switch threshold.
    pub fn sequential_with_kernel_threshold(
        params: LloydParams,
        kernel: KernelEngineKind,
        hybrid_threshold: Option<f64>,
    ) -> Self {
        NativeSolver { params, pool: None, engine: kernel.build_with_threshold(hybrid_threshold) }
    }

    /// Name of the configured kernel engine.
    pub fn kernel_name(&self) -> &'static str {
        self.engine.name()
    }
}

impl ChunkSolver for NativeSolver {
    fn lloyd(
        &self,
        points: &[f32],
        rows: usize,
        n: usize,
        k: usize,
        seed_centroids: &[f32],
        counters: &mut Counters,
    ) -> LloydResult {
        kernels::lloyd_with_engine(
            points,
            seed_centroids,
            rows,
            n,
            k,
            self.params,
            self.pool.as_ref(),
            self.engine.as_ref(),
            counters,
        )
    }

    fn assign(
        &self,
        points: &[f32],
        rows: usize,
        n: usize,
        k: usize,
        centroids: &[f32],
        counters: &mut Counters,
    ) -> (Vec<u32>, Vec<f32>) {
        match &self.pool {
            Some(pool) if rows >= 4096 => {
                let out = kernels::assign_accumulate_parallel(
                    pool, points, centroids, rows, n, k, counters,
                );
                (out.labels, out.mins)
            }
            _ => self.engine.assign_once(points, centroids, rows, n, k, counters),
        }
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn final_pass_mode(&self) -> FinalPassMode<'_> {
        FinalPassMode::Canonical(self.pool.as_ref())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_lloyd_improves_seed() {
        let solver = NativeSolver::sequential(LloydParams::default());
        let pts: Vec<f32> = (0..100)
            .flat_map(|i| {
                let b = if i < 50 { 0.0 } else { 10.0 };
                [b + (i % 5) as f32 * 0.01, b]
            })
            .collect();
        let seed = vec![1.0f32, 1.0, 9.0, 9.0];
        let mut c = Counters::new();
        let r = solver.lloyd(&pts, 100, 2, 2, &seed, &mut c);
        let mut c2 = Counters::new();
        let before = kernels::objective(&pts, &seed, 100, 2, 2, &mut c2);
        assert!(r.objective <= before);
        assert_eq!(solver.name(), "native");
    }

    #[test]
    fn native_assign_matches_kernels() {
        let solver = NativeSolver::sequential(LloydParams::default());
        let pts = vec![0.0f32, 0.0, 10.0, 10.0];
        let cs = vec![0.0f32, 0.0, 9.0, 9.0];
        let mut c = Counters::new();
        let (labels, mins) = solver.assign(&pts, 2, 2, 2, &cs, &mut c);
        assert_eq!(labels, vec![0, 1]);
        assert_eq!(mins, vec![0.0, 2.0]);
    }
}
