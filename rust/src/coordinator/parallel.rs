//! Chunk-parallel Big-means (the paper's parallelisation strategy 2):
//! several workers process chunks concurrently against a shared incumbent.
//!
//! The unit of work is a *shot* ([`ShotExecutor::run_shot`]): snapshot the
//! incumbent (lock-free Arc clone), sample a chunk, reseed degenerates, run
//! the local search, and *offer* the result — accepted only if it still
//! beats the incumbent at offer time. Workers race, but the incumbent
//! objective is monotone by construction. The shot is exposed as a reusable
//! service (rather than being inlined in the worker loop) so other
//! schedulers — notably the competitive portfolio tuner in
//! [`crate::tuner`] — can drive the same search step with their own arm
//! selection and scoring policies.
//!
//! Chunk budgets are enforced with an atomic ticket counter: a worker takes
//! a ticket *before* sampling and exits once the budget is spent, so a
//! `MaxChunks` run processes exactly that many chunks. With one worker this
//! makes the pipeline fully deterministic — the out-of-core tests use that
//! to assert bit-identical results across data backends. Time budgets are
//! still signalled by the coordinator thread through the `done` flag.
//!
//! The coordinator sleeps on a condvar that workers signal after every
//! chunk (and on exit), waking either on progress or at the wall-clock
//! deadline — no polling loop, so short budgets stop with microsecond
//! rather than millisecond tail latency.
//!
//! The dataset is shared as `&dyn DataSource`, so workers gather their
//! chunks straight from an mmap'd or indexed on-disk source — chunk-level
//! parallelism composes with out-of-core data for free.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::coordinator::bigmeans::{reseed, BigMeansResult};
use crate::coordinator::config::{BigMeansConfig, StopCondition};
use crate::coordinator::incumbent::{SharedIncumbent, Solution};
use crate::coordinator::sampler::ChunkSampler;
use crate::coordinator::solver::{ChunkSolver, NativeSolver};
use crate::coordinator::stop::StopState;
use crate::data::source::{AccessPattern, DataSource};
use crate::kernels::update::degenerate_indices;
use crate::metrics::{Counters, PhaseTimer};
use crate::obs;
use crate::util::rng::Rng;

/// Crash-path test hook: when `BIGMEANS_PANIC_IN_SHOT` is set, the first
/// shot panics inside its `shot.lloyd` span. The env var is read once
/// (relaxed `OnceLock`), so production shots pay one branch on a cached
/// bool. Used by `tests/integration_panic.rs` to prove a mid-run panic
/// still leaves a valid trace file and a diagnostics dump.
#[inline]
fn maybe_injected_panic() {
    static INJECT: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    if *INJECT.get_or_init(|| std::env::var_os("BIGMEANS_PANIC_IN_SHOT").is_some()) {
        panic!("injected shot panic (BIGMEANS_PANIC_IN_SHOT)");
    }
}

/// Worker-progress monitor: chunk totals plus worker liveness under one
/// mutex, with a condvar the coordinator blocks on. Workers notify after
/// each processed chunk and once on exit, so the coordinator wakes exactly
/// when the stop condition can have changed (or at its time deadline).
struct Progress {
    state: Mutex<ProgressState>,
    changed: Condvar,
}

#[derive(Clone, Copy)]
struct ProgressState {
    chunks: u64,
    finished_workers: usize,
}

impl Progress {
    fn new() -> Self {
        Progress {
            state: Mutex::new(ProgressState { chunks: 0, finished_workers: 0 }),
            changed: Condvar::new(),
        }
    }

    fn record_chunk(&self) {
        let mut st = self.state.lock().unwrap();
        st.chunks += 1;
        drop(st);
        self.changed.notify_all();
    }

    fn record_exit(&self) {
        let mut st = self.state.lock().unwrap();
        st.finished_workers += 1;
        drop(st);
        self.changed.notify_all();
    }
}

/// Scores a shot's converged centroids for incumbent comparison. Receives
/// the centroids, the degenerate slot indices, and the worker's counters;
/// returns the objective stored in the offered [`Solution`]. Passing no
/// scorer keeps the paper's chunk objective — the tuner installs a
/// validation-objective scorer so arms with different chunk sizes compete
/// on a common scale.
pub type ShotScorer<'a> = dyn Fn(&[f32], &[usize], &mut Counters) -> f64 + Sync + 'a;

/// Outcome of one shot.
#[derive(Clone, Debug)]
pub struct ShotReport {
    /// Chunk-local SSE of the converged centroids.
    pub chunk_objective: f64,
    /// Objective offered to the incumbent (the chunk objective, or the
    /// scorer's output when one is installed).
    pub offered_objective: f64,
    /// Whether the incumbent accepted the offer.
    pub accepted: bool,
    /// Lloyd iterations the local search took.
    pub iters: u32,
}

/// One worker's reusable shot state: a sequential solver plus a chunk
/// sampler whose buffers persist across shots (the chunk loop stays
/// allocation-free after warmup). Chunk-level parallelism replaces
/// kernel-level parallelism (the two strategies of paper §3 are
/// alternatives, not composed), so the solver is always sequential here.
pub struct ShotExecutor<'a> {
    cfg: &'a BigMeansConfig,
    data: &'a dyn DataSource,
    chunk_rows: usize,
    solver: NativeSolver,
    sampler: ChunkSampler,
    obs: ShotObs,
}

/// Registry handles cached per executor, labeled by engine and ISA. All
/// recording is delta-based off the worker's own [`Counters`], so the
/// metrics are pure observers of work that would happen identically
/// without them.
struct ShotObs {
    distance_evals: obs::Counter,
    pruned_evals: obs::Counter,
    chunks: obs::Counter,
    hybrid_switches: obs::Counter,
    shot_duration: obs::Histogram,
}

impl ShotObs {
    fn new(kernel: crate::kernels::KernelEngineKind) -> ShotObs {
        let m = obs::metrics();
        let engine = kernel.name();
        let isa = crate::kernels::active_isa().name();
        let eng = [("engine", engine), ("isa", isa)];
        ShotObs {
            distance_evals: m.counter(
                "bigmeans_distance_evals_total",
                "Exact point-to-centroid distance evaluations (paper n_d)",
                &eng,
            ),
            pruned_evals: m.counter(
                "bigmeans_pruned_evals_total",
                "Distance evaluations avoided by bound-based pruning",
                &eng,
            ),
            chunks: m.counter(
                "bigmeans_chunks_total",
                "Chunks processed by shots (paper n_s)",
                &[("engine", engine)],
            ),
            hybrid_switches: m.counter(
                "bigmeans_hybrid_switches_total",
                "Hybrid engine switches between Elkan and rescan strategies",
                &[("engine", engine)],
            ),
            shot_duration: m.histogram(
                "bigmeans_shot_duration_seconds",
                "Wall time of one Big-means shot (sample, reseed, local search)",
                &[("engine", engine)],
            ),
        }
    }
}

impl<'a> ShotExecutor<'a> {
    /// Executor with the configured chunk size and kernel engine.
    pub fn new(cfg: &'a BigMeansConfig, data: &'a dyn DataSource) -> Self {
        Self::with_chunk_size_threshold(cfg, data, cfg.chunk_size, cfg.kernel, cfg.hybrid_threshold)
    }

    /// Executor with an explicit chunk size / kernel engine (one tuner
    /// arm); the hybrid switch threshold comes from the config.
    pub fn with_chunk_size(
        cfg: &'a BigMeansConfig,
        data: &'a dyn DataSource,
        chunk_size: usize,
        kernel: crate::kernels::KernelEngineKind,
    ) -> Self {
        Self::with_chunk_size_threshold(cfg, data, chunk_size, kernel, cfg.hybrid_threshold)
    }

    /// Executor with everything explicit, including the hybrid switch
    /// threshold (threshold-arm tuner races price several values of it).
    pub fn with_chunk_size_threshold(
        cfg: &'a BigMeansConfig,
        data: &'a dyn DataSource,
        chunk_size: usize,
        kernel: crate::kernels::KernelEngineKind,
        hybrid_threshold: Option<f64>,
    ) -> Self {
        let rows = chunk_size.min(data.m()).max(1);
        ShotExecutor {
            cfg,
            data,
            chunk_rows: rows,
            solver: NativeSolver::sequential_with_kernel_threshold(
                cfg.lloyd,
                kernel,
                hybrid_threshold,
            ),
            sampler: ChunkSampler::new(rows, data.n()),
            obs: ShotObs::new(kernel),
        }
    }

    /// Rows per sampled chunk (after clamping to the dataset).
    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    /// Execute one shot against `incumbent`: snapshot, sample, reseed, run
    /// the local search, then offer the result. The offered objective is
    /// the chunk objective unless a `scorer` re-prices the centroids.
    pub fn run_shot(
        &mut self,
        incumbent: &SharedIncumbent,
        rng: &mut Rng,
        counters: &mut Counters,
        scorer: Option<&ShotScorer>,
    ) -> ShotReport {
        let tracer = obs::tracer();
        let sink = obs::report_sink();
        // One branch when everything is off: no clock reads, no deltas.
        let t0 = (tracer.enabled() || obs::metrics().enabled() || sink.enabled())
            .then(Instant::now);
        let base_evals = counters.distance_evals;
        let base_pruned = counters.pruned_evals;
        let base_switches = counters.hybrid_switches;
        let _shot_span = tracer.span("shot", "run_shot");
        let (n, k) = (self.data.n(), self.cfg.k);
        let snap = incumbent.snapshot();
        let (chunk, rows) = {
            let _span = tracer.span("shot.sample", "sample");
            self.sampler.sample(self.data, rng)
        };
        let mut seed_c = snap.centroids.clone();
        {
            let _span = tracer.span("shot.reseed", "reseed");
            reseed(
                self.cfg,
                chunk,
                rows,
                n,
                k,
                &mut seed_c,
                &snap.degenerate,
                rng,
                counters,
            );
        }
        let result = {
            let _span = tracer.span("shot.lloyd", "lloyd");
            maybe_injected_panic();
            self.solver.lloyd(chunk, rows, n, k, &seed_c, counters)
        };
        counters.chunk_iterations += result.iters as u64;
        counters.chunks += 1;
        let degenerate = degenerate_indices(&result.counts);
        let offered = match scorer {
            Some(score) => {
                let _span = tracer.span("shot.score", "score");
                score(&result.centroids, &degenerate, counters)
            }
            None => result.objective,
        };
        let accepted = {
            let _span = tracer.span("shot.offer", "offer");
            incumbent.offer(Solution {
                degenerate,
                centroids: result.centroids,
                objective: offered,
            })
        };
        if let Some(t0) = t0 {
            self.obs.shot_duration.observe(t0.elapsed());
            self.obs.distance_evals.add(counters.distance_evals - base_evals);
            self.obs.pruned_evals.add(counters.pruned_evals - base_pruned);
            self.obs.hybrid_switches.add(counters.hybrid_switches - base_switches);
            self.obs.chunks.inc();
            sink.record_shot(
                result.objective,
                offered,
                accepted,
                result.iters,
                Some(t0.elapsed().as_secs_f64()),
            );
        }
        ShotReport {
            chunk_objective: result.objective,
            offered_objective: offered,
            accepted,
            iters: result.iters,
        }
    }
}

/// Run the chunk-parallel pipeline. Called from `BigMeans::run`.
///
/// Each worker owns a [`ShotExecutor`] (sequential solver + sampler) and
/// races the others through the shared ticket pool.
pub fn run_chunk_parallel(
    cfg: &BigMeansConfig,
    data: &dyn DataSource,
) -> Result<BigMeansResult, String> {
    let (m, n, k) = (data.m(), data.n(), cfg.k);
    cfg.validate(m, n)?;
    let workers = cfg.worker_count();
    // Chunk budget as a ticket pool (u64::MAX = time-bounded only).
    let max_chunks = match cfg.stop {
        StopCondition::MaxChunks(c) => c,
        StopCondition::TimeOrChunks(_, c) => c,
        StopCondition::MaxTime(_) => u64::MAX,
    };

    let incumbent = Arc::new(SharedIncumbent::new(Solution::all_degenerate(k, n)));
    let done = Arc::new(AtomicBool::new(false));
    let tickets = Arc::new(AtomicU64::new(0));
    let progress = Arc::new(Progress::new());
    let mut timer = PhaseTimer::new();
    let mut root_rng = Rng::new(cfg.seed);

    // Every worker samples scattered chunk rows — readahead off.
    data.advise(AccessPattern::Random);
    let (improvements, counters) = timer.time_init(|| {
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for _w in 0..workers {
                let mut rng = root_rng.split();
                let incumbent = Arc::clone(&incumbent);
                let done = Arc::clone(&done);
                let tickets = Arc::clone(&tickets);
                let progress = Arc::clone(&progress);
                let cfg = cfg.clone();
                let data_ref = data;
                handles.push(scope.spawn(move || {
                    let mut shot = ShotExecutor::new(&cfg, data_ref);
                    let mut counters = Counters::new();
                    let mut improvements = 0u64;
                    loop {
                        if done.load(Ordering::Relaxed) {
                            break;
                        }
                        if tickets.fetch_add(1, Ordering::Relaxed) >= max_chunks {
                            break;
                        }
                        let report =
                            shot.run_shot(&incumbent, &mut rng, &mut counters, None);
                        if report.accepted {
                            improvements += 1;
                        }
                        progress.record_chunk();
                    }
                    progress.record_exit();
                    (improvements, counters)
                }));
            }
            // Coordinator: block on the progress condvar until the stop
            // condition trips or every worker has retired (ticket pool
            // exhausted). Chunk budgets are exact via the tickets; time
            // budgets wake at the deadline through `wait_timeout`.
            let mut stop = StopState::new(cfg.stop);
            let deadline = match cfg.stop {
                StopCondition::MaxTime(t) | StopCondition::TimeOrChunks(t, _) => {
                    Some(Instant::now() + t)
                }
                StopCondition::MaxChunks(_) => None,
            };
            {
                let mut st = progress.state.lock().unwrap();
                loop {
                    while stop.chunks() < st.chunks {
                        stop.record_chunk();
                    }
                    if stop.should_stop() || st.finished_workers == workers {
                        break;
                    }
                    st = match deadline {
                        Some(dl) => {
                            let now = Instant::now();
                            if now >= dl {
                                break;
                            }
                            progress.changed.wait_timeout(st, dl - now).unwrap().0
                        }
                        None => progress.changed.wait(st).unwrap(),
                    };
                }
            }
            done.store(true, Ordering::Relaxed);
            let mut improvements = 0u64;
            let mut counters = Counters::new();
            for h in handles {
                let (imp, c) = h.join().expect("worker panicked");
                improvements += imp;
                counters.merge(&c);
            }
            (improvements, counters)
        })
    });

    // Assemble the final result through the shared finish path.
    let final_solution = {
        let snap = incumbent.snapshot();
        Solution {
            centroids: snap.centroids.clone(),
            objective: snap.objective,
            degenerate: snap.degenerate.clone(),
        }
    };
    // Final full-dataset pass uses an inner-parallel native solver.
    let final_solver = NativeSolver::with_kernel_threshold(
        cfg.lloyd,
        cfg.threads,
        cfg.kernel,
        cfg.hybrid_threshold,
    );
    Ok(crate::coordinator::bigmeans::finish(
        cfg,
        &final_solver,
        data,
        final_solution,
        improvements,
        counters,
        timer,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::bigmeans::BigMeans;
    use crate::coordinator::config::{ParallelMode, StopCondition};
    use crate::data::synth::Synth;
    use std::time::Duration;

    #[test]
    fn parallel_run_matches_quality_of_sequential() {
        let data = Synth::GaussianMixture {
            m: 6000,
            n: 4,
            k_true: 5,
            spread: 0.2,
            box_half_width: 25.0,
        }
        .generate("t", 1);
        let base = BigMeansConfig::new(5, 512)
            .with_stop(StopCondition::MaxTime(Duration::from_millis(300)))
            .with_seed(3);
        let seq = BigMeans::new(
            base.clone().with_parallel(ParallelMode::Sequential),
        )
        .run(&data)
        .unwrap();
        let par = BigMeans::new(
            base.clone()
                .with_parallel(ParallelMode::ChunkParallel),
        )
        .run(&data)
        .unwrap();
        assert!(par.objective.is_finite());
        // Parallel explores at least as many chunks and lands in the same
        // quality ballpark (2x slack — different chunk draws).
        assert!(par.objective <= seq.objective * 2.0);
        assert!(par.counters.chunks >= 1);
    }

    #[test]
    fn parallel_counters_merge_all_workers() {
        let data = Synth::GaussianMixture {
            m: 3000,
            n: 3,
            k_true: 3,
            spread: 0.3,
            box_half_width: 20.0,
        }
        .generate("t", 2);
        let cfg = BigMeansConfig::new(3, 256)
            .with_stop(StopCondition::MaxTime(Duration::from_millis(200)))
            .with_parallel(ParallelMode::ChunkParallel);
        let r = BigMeans::new(cfg).run(&data).unwrap();
        assert!(r.counters.chunks > 0);
        assert!(r.counters.distance_evals > 0);
        assert!(r.improvements >= 1);
    }

    #[test]
    fn chunk_budget_is_exact() {
        // The ticket pool guarantees exactly `MaxChunks` chunks regardless
        // of worker count.
        let data = Synth::GaussianMixture {
            m: 4000,
            n: 3,
            k_true: 3,
            spread: 0.3,
            box_half_width: 20.0,
        }
        .generate("t", 3);
        for threads in [1usize, 4] {
            let mut cfg = BigMeansConfig::new(3, 256)
                .with_stop(StopCondition::MaxChunks(12))
                .with_parallel(ParallelMode::ChunkParallel)
                .with_seed(5);
            cfg.threads = threads;
            let r = BigMeans::new(cfg).run(&data).unwrap();
            assert_eq!(r.counters.chunks, 12, "threads={threads}");
        }
    }

    #[test]
    fn condvar_coordinator_handles_every_stop_condition() {
        // The wakeup-driven coordinator must terminate promptly for chunk
        // budgets (worker notifications), time budgets (deadline wait), and
        // the combined rule — with no polling to keep it alive.
        let data = Synth::GaussianMixture {
            m: 2000,
            n: 3,
            k_true: 3,
            spread: 0.3,
            box_half_width: 20.0,
        }
        .generate("t", 5);
        let conditions = [
            StopCondition::MaxChunks(3),
            StopCondition::MaxTime(Duration::from_millis(40)),
            StopCondition::TimeOrChunks(Duration::from_millis(500), 4),
        ];
        for stop in conditions {
            let mut cfg = BigMeansConfig::new(3, 128)
                .with_stop(stop)
                .with_parallel(ParallelMode::ChunkParallel)
                .with_seed(3);
            cfg.threads = 2;
            let t0 = std::time::Instant::now();
            let r = BigMeans::new(cfg).run(&data).unwrap();
            assert!(r.counters.chunks >= 1, "{stop:?}");
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "{stop:?} took {:?}",
                t0.elapsed()
            );
        }
    }

    #[test]
    fn single_worker_runs_are_reproducible() {
        let data = Synth::GaussianMixture {
            m: 5000,
            n: 4,
            k_true: 4,
            spread: 0.25,
            box_half_width: 20.0,
        }
        .generate("t", 4);
        let mk = || {
            let mut cfg = BigMeansConfig::new(4, 512)
                .with_stop(StopCondition::MaxChunks(10))
                .with_parallel(ParallelMode::ChunkParallel)
                .with_seed(9);
            cfg.threads = 1;
            cfg
        };
        let a = BigMeans::new(mk()).run(&data).unwrap();
        let b = BigMeans::new(mk()).run(&data).unwrap();
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
        assert_eq!(a.counters, b.counters);
    }
}
