//! Stop-condition evaluation for the Big-means search phase.

use std::time::Instant;

use crate::coordinator::config::StopCondition;

/// Tracks elapsed time and chunk count against a [`StopCondition`].
#[derive(Debug)]
pub struct StopState {
    start: Instant,
    chunks: u64,
    condition: StopCondition,
}

impl StopState {
    pub fn new(condition: StopCondition) -> Self {
        StopState { start: Instant::now(), chunks: 0, condition }
    }

    /// Record one processed chunk.
    pub fn record_chunk(&mut self) {
        self.chunks += 1;
    }

    pub fn chunks(&self) -> u64 {
        self.chunks
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Should the search stop now?
    pub fn should_stop(&self) -> bool {
        match self.condition {
            StopCondition::MaxTime(t) => self.start.elapsed() >= t,
            StopCondition::MaxChunks(c) => self.chunks >= c,
            StopCondition::TimeOrChunks(t, c) => {
                self.start.elapsed() >= t || self.chunks >= c
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn chunk_limit() {
        let mut s = StopState::new(StopCondition::MaxChunks(3));
        assert!(!s.should_stop());
        for _ in 0..3 {
            s.record_chunk();
        }
        assert!(s.should_stop());
        assert_eq!(s.chunks(), 3);
    }

    #[test]
    fn time_limit() {
        let s = StopState::new(StopCondition::MaxTime(Duration::from_millis(1)));
        std::thread::sleep(Duration::from_millis(3));
        assert!(s.should_stop());
    }

    #[test]
    fn combined_trips_on_either() {
        let mut s = StopState::new(StopCondition::TimeOrChunks(
            Duration::from_secs(3600),
            2,
        ));
        assert!(!s.should_stop());
        s.record_chunk();
        s.record_chunk();
        assert!(s.should_stop());
    }
}
