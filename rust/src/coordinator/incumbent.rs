//! Incumbent solution management: the "keep the best" state of Algorithm 3.
//!
//! The incumbent is the best set of centroids found so far, judged by the
//! *chunk* objective (the paper's point: no global objective is ever
//! computed during the search). [`SharedIncumbent`] wraps it for the
//! chunk-parallel pipeline: lock-free reads of a versioned snapshot via
//! `arc-swap`-style atomic pointer replacement built on `Mutex` +
//! generation counter (reads clone an `Arc`, never blocking writers long).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A candidate / incumbent solution.
#[derive(Clone, Debug)]
pub struct Solution {
    /// Row-major `(k, n)` centroids. Degenerate slots hold the position
    /// they had when they last emptied (or the PAD sentinel on the very
    /// first chunk).
    pub centroids: Vec<f32>,
    /// Chunk objective that earned incumbency.
    pub objective: f64,
    /// Which centroids are currently degenerate.
    pub degenerate: Vec<usize>,
}

impl Solution {
    /// The "all degenerate" initial incumbent of Algorithm 3 (line 2).
    pub fn all_degenerate(k: usize, n: usize) -> Self {
        Solution {
            centroids: vec![0.0; k * n],
            objective: f64::INFINITY,
            degenerate: (0..k).collect(),
        }
    }

    pub fn is_initial(&self) -> bool {
        self.objective.is_infinite()
    }
}

/// Thread-shared incumbent with versioning.
pub struct SharedIncumbent {
    inner: Mutex<Arc<Solution>>,
    version: AtomicU64,
}

impl SharedIncumbent {
    pub fn new(initial: Solution) -> Self {
        SharedIncumbent {
            inner: Mutex::new(Arc::new(initial)),
            version: AtomicU64::new(0),
        }
    }

    /// Snapshot the current incumbent (cheap Arc clone).
    pub fn snapshot(&self) -> Arc<Solution> {
        self.inner.lock().unwrap().clone()
    }

    /// Monotone version counter — bumps on every accepted improvement.
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Offer a candidate; accepted iff strictly better than the incumbent
    /// at comparison time ("keep the best"). Returns true if accepted.
    pub fn offer(&self, candidate: Solution) -> bool {
        let mut guard = self.inner.lock().unwrap();
        if candidate.objective < guard.objective {
            *guard = Arc::new(candidate);
            self.version.fetch_add(1, Ordering::Release);
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sol(obj: f64) -> Solution {
        Solution { centroids: vec![0.0; 4], objective: obj, degenerate: vec![] }
    }

    #[test]
    fn initial_is_all_degenerate_and_infinite() {
        let s = Solution::all_degenerate(3, 2);
        assert!(s.is_initial());
        assert_eq!(s.degenerate, vec![0, 1, 2]);
        assert_eq!(s.centroids.len(), 6);
    }

    #[test]
    fn keep_the_best_only_improvements() {
        let inc = SharedIncumbent::new(sol(10.0));
        assert!(!inc.offer(sol(10.0))); // ties rejected
        assert!(!inc.offer(sol(12.0)));
        assert_eq!(inc.version(), 0);
        assert!(inc.offer(sol(9.0)));
        assert_eq!(inc.version(), 1);
        assert_eq!(inc.snapshot().objective, 9.0);
    }

    #[test]
    fn concurrent_offers_keep_minimum() {
        let inc = Arc::new(SharedIncumbent::new(Solution::all_degenerate(2, 2)));
        let mut handles = Vec::new();
        for t in 0..8 {
            let inc = Arc::clone(&inc);
            handles.push(std::thread::spawn(move || {
                for i in 0..100 {
                    inc.offer(sol((t * 100 + i) as f64 + 1.0));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(inc.snapshot().objective, 1.0);
    }
}
