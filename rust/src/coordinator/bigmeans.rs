//! Big-means (Algorithm 3 of the paper): the sequential chunk pipeline.
//!
//! ```text
//! C ← all-degenerate; f_opt ← ∞
//! while stop condition not met:
//!     P  ← uniform random sample of s vectors from X
//!     C' ← C with degenerate centroids reinitialised (K-means++ on P)
//!     C''← KMeans(P, C')                     // chunk-local search
//!     if f(C'', P) < f_opt: C ← C''; f_opt ← f(C'', P)   // keep the best
//! A ← assign each x ∈ X to its closest centroid in C     // final pass
//! ```
//!
//! The chunk loop is the *global* search: resampling chunks is the natural
//! shaking step, and "keep the best" fixes the incumbent. Only chunk
//! objectives are ever compared — the full objective is computed once, in
//! the final pass.

use crate::coordinator::config::{BigMeansConfig, ParallelMode, ReinitStrategy};
use crate::coordinator::incumbent::Solution;
use crate::coordinator::sampler::ChunkSampler;
use crate::coordinator::solver::{ChunkSolver, NativeSolver};
use crate::coordinator::stop::StopState;
use crate::data::source::{AccessPattern, DataSource};
use crate::kernels::{self, update::degenerate_indices};
use crate::metrics::{Counters, PhaseTimer};
use crate::util::rng::Rng;

/// Result of a Big-means run.
#[derive(Clone, Debug)]
pub struct BigMeansResult {
    /// Final centroids, row-major `(k, n)`.
    pub centroids: Vec<f32>,
    /// Full-dataset objective `f(C, X)` (NaN if the final pass was skipped).
    pub objective: f64,
    /// Point-to-cluster assignment (empty if the final pass was skipped).
    pub assignment: Vec<u32>,
    /// Best chunk objective found during the search.
    pub best_chunk_objective: f64,
    /// Work counters (`n_d`, `n_s`, iteration counts).
    pub counters: Counters,
    /// Phase timing (`cpu_init` = search, `cpu_full` = final pass).
    pub cpu_init_secs: f64,
    pub cpu_full_secs: f64,
    /// Number of chunks whose result was accepted as incumbent.
    pub improvements: u64,
}

/// The Big-means clustering engine.
pub struct BigMeans {
    config: BigMeansConfig,
    solver: Box<dyn ChunkSolver>,
}

impl BigMeans {
    /// Build with the configured native engine. (PJRT engine: construct via
    /// `runtime::pjrt_bigmeans`, which injects a `PjrtSolver`.)
    pub fn new(config: BigMeansConfig) -> Self {
        let threads = match config.parallel {
            ParallelMode::Sequential => 1,
            _ => config.threads,
        };
        let solver =
            Box::new(NativeSolver::with_kernel(config.lloyd, threads, config.kernel));
        BigMeans { config, solver }
    }

    /// Build with a custom chunk solver (PJRT or test doubles).
    pub fn with_solver(config: BigMeansConfig, solver: Box<dyn ChunkSolver>) -> Self {
        BigMeans { config, solver }
    }

    pub fn config(&self) -> &BigMeansConfig {
        &self.config
    }

    /// Run on any [`DataSource`] — an in-memory [`crate::data::Dataset`],
    /// an mmap'd [`crate::data::BmxSource`], or an indexed
    /// [`crate::data::CsvSource`]. `&Dataset` coerces, so existing
    /// `run(&dataset)` call sites keep working.
    pub fn run(&self, data: &dyn DataSource) -> Result<BigMeansResult, String> {
        let (m, n) = (data.m(), data.n());
        self.config.validate(m, n)?;
        match self.config.parallel {
            // Strategy 2 builds per-worker native solvers (PJRT is
            // single-threaded; see ChunkSolver docs).
            ParallelMode::ChunkParallel => {
                crate::coordinator::parallel::run_chunk_parallel(&self.config, data)
            }
            _ => Ok(self.run_sequential(data)),
        }
    }

    fn run_sequential(&self, data: &dyn DataSource) -> BigMeansResult {
        let cfg = &self.config;
        let (m, n, k) = (data.m(), data.n(), cfg.k);
        let s = cfg.chunk_size.min(m);
        let mut rng = Rng::new(cfg.seed);
        let mut counters = Counters::new();
        let mut timer = PhaseTimer::new();
        let mut sampler = ChunkSampler::new(s, n);
        let mut incumbent = Solution::all_degenerate(k, n);
        let mut improvements = 0u64;
        let mut stop = StopState::new(cfg.stop);

        // Chunk sampling gathers scattered rows — turn readahead off.
        data.advise(AccessPattern::Random);
        timer.time_init(|| {
            while !stop.should_stop() {
                let (chunk, rows) = sampler.sample(data, &mut rng);
                // C' ← incumbent with degenerates reseeded on this chunk.
                let mut seed = incumbent.centroids.clone();
                reseed(
                    cfg,
                    chunk,
                    rows,
                    n,
                    k,
                    &mut seed,
                    &incumbent.degenerate,
                    &mut rng,
                    &mut counters,
                );
                // C'' ← local search.
                let result = self.solver.lloyd(chunk, rows, n, k, &seed, &mut counters);
                counters.chunk_iterations += result.iters as u64;
                counters.chunks += 1;
                stop.record_chunk();
                // Keep the best (chunk objectives only).
                if result.objective < incumbent.objective {
                    incumbent = Solution {
                        degenerate: degenerate_indices(&result.counts),
                        centroids: result.centroids,
                        objective: result.objective,
                    };
                    improvements += 1;
                }
            }
        });

        finish(cfg, self.solver.as_ref(), data, incumbent, improvements, counters, timer)
    }
}

/// Rows per block of the final full-dataset pass. Fixed (rather than "all
/// of m") so the pass streams out-of-core sources in bounded memory — and
/// so every backend runs the exact same arithmetic: identical block
/// boundaries plus row-ordered f64 accumulation make the reported objective
/// bit-for-bit independent of where the bytes live.
pub(crate) const FINAL_PASS_BLOCK_ROWS: usize = 8192;

/// Final full-dataset pass + result assembly (shared between the
/// sequential and chunk-parallel pipelines). Streams the source in
/// [`FINAL_PASS_BLOCK_ROWS`]-row blocks; resident sources (in-memory,
/// mmap) are sliced in place, others are copied block-by-block.
pub(crate) fn finish(
    cfg: &BigMeansConfig,
    solver: &dyn ChunkSolver,
    data: &dyn DataSource,
    incumbent: Solution,
    improvements: u64,
    mut counters: Counters,
    mut timer: PhaseTimer,
) -> BigMeansResult {
    let (m, n, k) = (data.m(), data.n(), cfg.k);
    let mut centroids = incumbent.centroids.clone();
    // Degenerate slots never earned points; park them far away so the
    // final assignment ignores them (mirrors the L2 PAD contract).
    for &j in &incumbent.degenerate {
        for v in &mut centroids[j * n..(j + 1) * n] {
            *v = 1.0e15;
        }
    }
    let (assignment, objective) = if cfg.skip_final_assignment {
        (Vec::new(), f64::NAN)
    } else {
        // The final pass streams the source front to back — let the OS
        // read ahead of the block loop.
        data.advise(AccessPattern::Sequential);
        timer.time_full(|| {
            let resident = data.contiguous();
            let mut labels = Vec::with_capacity(m);
            let mut obj = 0f64;
            let mut scratch = Vec::new();
            let mut start = 0usize;
            while start < m {
                let rows = FINAL_PASS_BLOCK_ROWS.min(m - start);
                let block: &[f32] = match resident {
                    Some(all) => &all[start * n..(start + rows) * n],
                    None => {
                        scratch.resize(rows * n, 0.0);
                        data.read_rows(start, &mut scratch[..rows * n]);
                        &scratch[..rows * n]
                    }
                };
                let (l, mins) =
                    solver.assign(block, rows, n, k, &centroids, &mut counters);
                labels.extend_from_slice(&l);
                for &d in &mins {
                    obj += d as f64;
                }
                start += rows;
            }
            counters.full_iterations += 1;
            (labels, obj)
        })
    };
    BigMeansResult {
        centroids,
        objective,
        assignment,
        best_chunk_objective: incumbent.objective,
        counters,
        cpu_init_secs: timer.init_secs(),
        cpu_full_secs: timer.full_secs(),
        improvements,
    }
}

/// Reinitialise degenerate centroid slots on the current chunk.
#[allow(clippy::too_many_arguments)]
pub(crate) fn reseed(
    cfg: &BigMeansConfig,
    chunk: &[f32],
    rows: usize,
    n: usize,
    k: usize,
    seed: &mut [f32],
    degenerate: &[usize],
    rng: &mut Rng,
    counters: &mut Counters,
) {
    if degenerate.is_empty() {
        return;
    }
    if degenerate.len() == k {
        // First chunk (all degenerate): full init.
        let init = match cfg.reinit {
            ReinitStrategy::KmeansPP => {
                kernels::kmeanspp(chunk, rows, n, k, cfg.candidates, rng, counters)
            }
            ReinitStrategy::Random => {
                let idx = rng.sample_indices(rows, k);
                let mut c = vec![0f32; k * n];
                for (slot, &i) in idx.iter().enumerate() {
                    c[slot * n..(slot + 1) * n]
                        .copy_from_slice(&chunk[i * n..(i + 1) * n]);
                }
                c
            }
        };
        seed.copy_from_slice(&init);
        return;
    }
    match cfg.reinit {
        ReinitStrategy::KmeansPP => kernels::reseed_degenerate(
            chunk,
            rows,
            n,
            k,
            seed,
            degenerate,
            cfg.candidates,
            rng,
            counters,
        ),
        ReinitStrategy::Random => {
            kernels::reseed_degenerate_random(chunk, rows, n, seed, degenerate, rng)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::StopCondition;
    use crate::data::dataset::Dataset;
    use crate::data::synth::Synth;

    fn blobs(m: usize, k_true: usize, seed: u64) -> Dataset {
        Synth::GaussianMixture {
            m,
            n: 4,
            k_true,
            spread: 0.2,
            box_half_width: 25.0,
        }
        .generate("blobs", seed)
    }

    fn quick_config(k: usize, s: usize, chunks: u64) -> BigMeansConfig {
        BigMeansConfig::new(k, s)
            .with_stop(StopCondition::MaxChunks(chunks))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(7)
    }

    #[test]
    fn clusters_blobs_close_to_reference_kmeans() {
        let data = blobs(4000, 5, 1);
        let bm = BigMeans::new(quick_config(5, 512, 30));
        let r = bm.run(&data).unwrap();
        assert_eq!(r.centroids.len(), 5 * 4);
        assert_eq!(r.assignment.len(), 4000);
        assert!(r.objective.is_finite());
        // Multi-start reference: full-data Lloyd from k-means++ seeds.
        let mut counters = Counters::new();
        let mut rng = Rng::new(3);
        let seed =
            kernels::kmeanspp(data.points(), 4000, 4, 5, 3, &mut rng, &mut counters);
        let reference = kernels::lloyd(
            data.points(),
            &seed,
            4000,
            4,
            5,
            Default::default(),
            None,
            &mut counters,
        );
        // Big-means should land within 25% of a full-data K-means run.
        assert!(
            r.objective <= reference.objective * 1.25,
            "bigmeans {} vs reference {}",
            r.objective,
            reference.objective
        );
    }

    #[test]
    fn improvements_monotone_and_counted() {
        let data = blobs(2000, 3, 2);
        let bm = BigMeans::new(quick_config(3, 256, 20));
        let r = bm.run(&data).unwrap();
        assert!(r.improvements >= 1);
        assert!(r.counters.chunks == 20);
        assert!(r.counters.distance_evals > 0);
        assert!(r.best_chunk_objective.is_finite());
    }

    #[test]
    fn deterministic_given_seed_sequential() {
        let data = blobs(1500, 3, 3);
        let a = BigMeans::new(quick_config(3, 200, 10)).run(&data).unwrap();
        let b = BigMeans::new(quick_config(3, 200, 10)).run(&data).unwrap();
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.objective, b.objective);
        assert_eq!(a.counters, b.counters);
    }

    #[test]
    fn skip_final_assignment() {
        let data = blobs(1000, 2, 4);
        let mut cfg = quick_config(2, 128, 5);
        cfg.skip_final_assignment = true;
        let r = BigMeans::new(cfg).run(&data).unwrap();
        assert!(r.objective.is_nan());
        assert!(r.assignment.is_empty());
        assert!(r.best_chunk_objective.is_finite());
    }

    #[test]
    fn chunk_bigger_than_dataset_clamps() {
        let data = blobs(300, 2, 5);
        let r = BigMeans::new(quick_config(2, 10_000, 3)).run(&data).unwrap();
        assert!(r.objective.is_finite());
    }

    #[test]
    fn invalid_config_rejected() {
        let data = blobs(100, 2, 6);
        let bad = BigMeans::new(quick_config(0, 128, 3));
        assert!(bad.run(&data).is_err());
    }

    #[test]
    fn random_reinit_ablation_runs() {
        let data = blobs(1000, 3, 7);
        let mut cfg = quick_config(3, 200, 10);
        cfg.reinit = ReinitStrategy::Random;
        let r = BigMeans::new(cfg).run(&data).unwrap();
        assert!(r.objective.is_finite());
    }

    #[test]
    fn time_budget_stops() {
        use std::time::Duration;
        let data = blobs(2000, 3, 8);
        let cfg = BigMeansConfig::new(3, 256)
            .with_stop(StopCondition::MaxTime(Duration::from_millis(50)))
            .with_parallel(ParallelMode::Sequential);
        let t = std::time::Instant::now();
        let r = BigMeans::new(cfg).run(&data).unwrap();
        assert!(t.elapsed() < Duration::from_secs(5));
        assert!(r.counters.chunks >= 1);
    }
}
