//! Big-means (Algorithm 3 of the paper): the sequential chunk pipeline.
//!
//! ```text
//! C ← all-degenerate; f_opt ← ∞
//! while stop condition not met:
//!     P  ← uniform random sample of s vectors from X
//!     C' ← C with degenerate centroids reinitialised (K-means++ on P)
//!     C''← KMeans(P, C')                     // chunk-local search
//!     if f(C'', P) < f_opt: C ← C''; f_opt ← f(C'', P)   // keep the best
//! A ← assign each x ∈ X to its closest centroid in C     // final pass
//! ```
//!
//! The chunk loop is the *global* search: resampling chunks is the natural
//! shaking step, and "keep the best" fixes the incumbent. Only chunk
//! objectives are ever compared — the full objective is computed once, in
//! the final pass.

use crate::coordinator::config::{BigMeansConfig, ParallelMode, ReinitStrategy};
use crate::coordinator::incumbent::Solution;
use crate::coordinator::sampler::ChunkSampler;
use crate::coordinator::solver::{ChunkSolver, FinalPassMode, NativeSolver};
use crate::coordinator::stop::StopState;
use crate::data::source::{AccessPattern, DataSource};
use crate::kernels::assign::PREFETCH_ROWS_AHEAD;
use crate::kernels::distance::{sq_dist_decomp, sq_norm};
use crate::kernels::{self, update::degenerate_indices};
use crate::metrics::{Counters, PhaseTimer};
use crate::obs;
use crate::store::prune::{self, PrunePlan};
use crate::util::mem;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

/// Result of a Big-means run.
#[derive(Clone, Debug)]
pub struct BigMeansResult {
    /// Final centroids, row-major `(k, n)`.
    pub centroids: Vec<f32>,
    /// Full-dataset objective `f(C, X)` (NaN if the final pass was skipped).
    pub objective: f64,
    /// Point-to-cluster assignment (empty if the final pass was skipped).
    pub assignment: Vec<u32>,
    /// Best chunk objective found during the search.
    pub best_chunk_objective: f64,
    /// Work counters (`n_d`, `n_s`, iteration counts).
    pub counters: Counters,
    /// Phase timing (`cpu_init` = search, `cpu_full` = final pass).
    pub cpu_init_secs: f64,
    pub cpu_full_secs: f64,
    /// Number of chunks whose result was accepted as incumbent.
    pub improvements: u64,
}

/// The Big-means clustering engine.
pub struct BigMeans {
    config: BigMeansConfig,
    solver: Box<dyn ChunkSolver>,
}

impl BigMeans {
    /// Build with the configured native engine. (PJRT engine: construct via
    /// `runtime::pjrt_bigmeans`, which injects a `PjrtSolver`.)
    pub fn new(config: BigMeansConfig) -> Self {
        let threads = match config.parallel {
            ParallelMode::Sequential => 1,
            _ => config.threads,
        };
        let solver = Box::new(NativeSolver::with_kernel_threshold(
            config.lloyd,
            threads,
            config.kernel,
            config.hybrid_threshold,
        ));
        BigMeans { config, solver }
    }

    /// Build with a custom chunk solver (PJRT or test doubles).
    pub fn with_solver(config: BigMeansConfig, solver: Box<dyn ChunkSolver>) -> Self {
        BigMeans { config, solver }
    }

    pub fn config(&self) -> &BigMeansConfig {
        &self.config
    }

    /// Run on any [`DataSource`] — an in-memory [`crate::data::Dataset`],
    /// an mmap'd [`crate::data::BmxSource`], or an indexed
    /// [`crate::data::CsvSource`]. `&Dataset` coerces, so existing
    /// `run(&dataset)` call sites keep working.
    pub fn run(&self, data: &dyn DataSource) -> Result<BigMeansResult, String> {
        let (m, n) = (data.m(), data.n());
        self.config.validate(m, n)?;
        match self.config.parallel {
            // Strategy 2 builds per-worker native solvers (PJRT is
            // single-threaded; see ChunkSolver docs).
            ParallelMode::ChunkParallel => {
                crate::coordinator::parallel::run_chunk_parallel(&self.config, data)
            }
            _ => Ok(self.run_sequential(data)),
        }
    }

    fn run_sequential(&self, data: &dyn DataSource) -> BigMeansResult {
        let cfg = &self.config;
        let (m, n, k) = (data.m(), data.n(), cfg.k);
        let s = cfg.chunk_size.min(m);
        let mut rng = Rng::new(cfg.seed);
        let mut counters = Counters::new();
        let mut timer = PhaseTimer::new();
        let mut sampler = ChunkSampler::new(s, n);
        let mut incumbent = Solution::all_degenerate(k, n);
        let mut improvements = 0u64;
        let mut stop = StopState::new(cfg.stop);

        // Chunk sampling gathers scattered rows — turn readahead off.
        data.advise(AccessPattern::Random);
        timer.time_init(|| {
            while !stop.should_stop() {
                let (chunk, rows) = sampler.sample(data, &mut rng);
                // C' ← incumbent with degenerates reseeded on this chunk.
                let mut seed = incumbent.centroids.clone();
                reseed(
                    cfg,
                    chunk,
                    rows,
                    n,
                    k,
                    &mut seed,
                    &incumbent.degenerate,
                    &mut rng,
                    &mut counters,
                );
                // C'' ← local search.
                let result = self.solver.lloyd(chunk, rows, n, k, &seed, &mut counters);
                counters.chunk_iterations += result.iters as u64;
                counters.chunks += 1;
                stop.record_chunk();
                // Keep the best (chunk objectives only).
                if result.objective < incumbent.objective {
                    incumbent = Solution {
                        degenerate: degenerate_indices(&result.counts),
                        centroids: result.centroids,
                        objective: result.objective,
                    };
                    improvements += 1;
                }
            }
        });

        finish(cfg, self.solver.as_ref(), data, incumbent, improvements, counters, timer)
    }
}

/// Rows per slab of the final full-dataset pass, bounding the resident
/// memory of out-of-core streaming (two slabs live at once under the
/// double buffer). The canonical pass is per-point deterministic — slab
/// and shard boundaries never change labels or the objective; this
/// constant only shapes memory and overlap granularity.
pub(crate) const FINAL_PASS_BLOCK_ROWS: usize = 8192;

/// Minimum rows per shard of a final-pass slab segment — one panel block,
/// so tiny fragments don't swamp the job queue.
pub(crate) const FINAL_PASS_SHARD_ROWS: usize = 256;

/// Soft cap on one shard's point bytes: a shard that fits a typical L2
/// slice keeps the prefetched norm-pass rows resident for the panel pass
/// that re-reads them.
pub(crate) const SLAB_TILE_L2_BYTES: usize = 1 << 20;

/// Shard size for one slab segment: roughly even across `workers`, at
/// least [`FINAL_PASS_SHARD_ROWS`], capped so one shard's points fit
/// [`SLAB_TILE_L2_BYTES`] (the floor wins for very wide rows). Shard
/// boundaries never change per-point results, only load balance.
fn slab_shard_rows(rows: usize, n: usize, workers: usize) -> usize {
    let cap = (SLAB_TILE_L2_BYTES / (4 * n.max(1))).max(FINAL_PASS_SHARD_ROWS);
    let shard = rows.div_ceil(workers.max(1)).clamp(FINAL_PASS_SHARD_ROWS, cap);
    debug_assert!(
        shard * n * 4 <= SLAB_TILE_L2_BYTES || shard == FINAL_PASS_SHARD_ROWS,
        "shard of {shard} rows x {n} dims overflows the L2 tile budget"
    );
    shard
}

/// Final full-dataset pass + result assembly (shared between the
/// sequential and chunk-parallel pipelines).
///
/// Native solvers run the **canonical pruned pipeline**
/// ([`canonical_final_pass`]): one per-point arithmetic (the fused
/// `‖x‖² − 2x·c + ‖c‖²` panel) for every backend and thread count, block
/// pruning from `.bmx` v3 summaries, and a double-buffered decode/assign
/// overlap on the pool. Opaque solvers (PJRT) keep the historical
/// slab-streaming path through [`ChunkSolver::assign`].
pub(crate) fn finish(
    cfg: &BigMeansConfig,
    solver: &dyn ChunkSolver,
    data: &dyn DataSource,
    incumbent: Solution,
    improvements: u64,
    mut counters: Counters,
    mut timer: PhaseTimer,
) -> BigMeansResult {
    let (m, n, k) = (data.m(), data.n(), cfg.k);
    let mut centroids = incumbent.centroids.clone();
    // Degenerate slots never earned points; park them far away so the
    // final assignment ignores them (mirrors the L2 PAD contract).
    for &j in &incumbent.degenerate {
        for v in &mut centroids[j * n..(j + 1) * n] {
            *v = 1.0e15;
        }
    }
    let (assignment, objective) = if cfg.skip_final_assignment {
        (Vec::new(), f64::NAN)
    } else {
        // The final pass streams the source front to back — let the OS
        // read ahead of the block loop.
        data.advise(AccessPattern::Sequential);
        timer.time_full(|| {
            let out = match solver.final_pass_mode() {
                FinalPassMode::Canonical(pool) => {
                    canonical_final_pass(pool, data, &centroids, k, &mut counters)
                }
                FinalPassMode::Solver => {
                    solver_final_pass(solver, data, &centroids, k, &mut counters)
                }
            };
            counters.full_iterations += 1;
            out
        })
    };
    BigMeansResult {
        centroids,
        objective,
        assignment,
        best_chunk_objective: incumbent.objective,
        counters,
        cpu_init_secs: timer.init_secs(),
        cpu_full_secs: timer.full_secs(),
        improvements,
    }
}

/// The historical final pass for opaque solvers: stream the source in
/// [`FINAL_PASS_BLOCK_ROWS`]-row slabs through [`ChunkSolver::assign`].
fn solver_final_pass(
    solver: &dyn ChunkSolver,
    data: &dyn DataSource,
    centroids: &[f32],
    k: usize,
    counters: &mut Counters,
) -> (Vec<u32>, f64) {
    let (m, n) = (data.m(), data.n());
    let resident = data.contiguous();
    let mut labels = Vec::with_capacity(m);
    let mut obj = 0f64;
    let mut scratch = Vec::new();
    let mut start = 0usize;
    while start < m {
        let rows = FINAL_PASS_BLOCK_ROWS.min(m - start);
        let block: &[f32] = match resident {
            Some(all) => &all[start * n..(start + rows) * n],
            None => {
                scratch.resize(rows * n, 0.0);
                data.read_rows(start, &mut scratch[..rows * n]);
                &scratch[..rows * n]
            }
        };
        let (l, mins) = solver.assign(block, rows, n, k, centroids, counters);
        labels.extend_from_slice(&l);
        for &d in &mins {
            obj += d as f64;
        }
        start += rows;
    }
    (labels, obj)
}

/// One maximal run of rows inside a slab that shares a pruning decision:
/// `(offset-within-slab, rows, owner)`. `owner = Some(j)` means every row
/// of the run lives in store blocks wholly owned by centroid `j`.
type Segment = (usize, usize, Option<u32>);

/// Split slab `[start, start + rows)` into ownership segments against the
/// prune plan (one contested segment when there is no plan).
fn slab_segments(plan: Option<&PrunePlan>, start: usize, rows: usize) -> Vec<Segment> {
    let Some(plan) = plan else {
        return vec![(0, rows, None)];
    };
    let mut segs: Vec<Segment> = Vec::new();
    let mut row = start;
    let end = start + rows;
    while row < end {
        let block_end = ((row / plan.block_rows) + 1) * plan.block_rows;
        let take = block_end.min(end) - row;
        let owner = plan.owner_of_row(row);
        match segs.last_mut() {
            Some((_, seg_rows, seg_owner)) if *seg_owner == owner => *seg_rows += take,
            _ => segs.push((row - start, take, owner)),
        }
        row += take;
    }
    segs
}

/// Label every row of an owned segment with its block's centroid and
/// price it with a single decomposition evaluation — bit-identical to the
/// panel's winning value for that pair, which is what makes whole-block
/// pruning invisible in the output.
fn assign_owned_rows(
    points: &[f32],
    centroid: &[f32],
    c_sq_j: f32,
    n: usize,
    owner: u32,
    labels: &mut [u32],
    mins: &mut [f32],
) {
    let limit = points.len();
    for (i, x) in points.chunks_exact(n).enumerate() {
        // Owned segments are a pure linear walk with one evaluation per
        // row — memory-bound, so hint the streamed rows a little ahead.
        // Clamping to one-past-end keeps the pointer arithmetic defined;
        // the hint itself never faults.
        let ahead = (i + PREFETCH_ROWS_AHEAD) * n;
        mem::prefetch_read(points.as_ptr().wrapping_add(ahead.min(limit)) as *const u8);
        let x_sq = sq_norm(x);
        labels[i] = owner;
        mins[i] = sq_dist_decomp(x, x_sq, centroid, c_sq_j);
    }
}

/// Carve the assignment work of one slab into boxed jobs writing disjoint
/// `labels`/`mins` windows. Contested segments are sharded roughly evenly
/// across `workers`; shard boundaries never change per-point results, only
/// load balance.
#[allow(clippy::too_many_arguments)]
fn push_slab_jobs<'scope>(
    jobs: &mut Vec<Box<dyn FnOnce() + Send + 'scope>>,
    points: &'scope [f32],
    centroids: &'scope [f32],
    c_sq: &'scope [f32],
    n: usize,
    k: usize,
    segments: &[Segment],
    mut labels: &'scope mut [u32],
    mut mins: &'scope mut [f32],
    workers: usize,
) {
    let mut consumed = 0usize;
    for &(off, rows, owner) in segments {
        debug_assert_eq!(off, consumed);
        // `mem::take` moves the remainder out of the loop variable so the
        // split-off head keeps the full `'scope` lifetime the boxed jobs
        // need.
        let (lab_seg, lab_rest) = std::mem::take(&mut labels).split_at_mut(rows);
        let (min_seg, min_rest) = std::mem::take(&mut mins).split_at_mut(rows);
        labels = lab_rest;
        mins = min_rest;
        let pts = &points[off * n..(off + rows) * n];
        // Shard every segment (owned segments too — a fully-pruned pass
        // would otherwise run one job per segment and idle the pool).
        let shard = slab_shard_rows(rows, n, workers);
        let mut lab_left = lab_seg;
        let mut min_left = min_seg;
        let mut done = 0usize;
        while done < rows {
            let take = shard.min(rows - done);
            let (lab_s, lab_r) = std::mem::take(&mut lab_left).split_at_mut(take);
            let (min_s, min_r) = std::mem::take(&mut min_left).split_at_mut(take);
            lab_left = lab_r;
            min_left = min_r;
            let shard_pts = &pts[done * n..(done + take) * n];
            match owner {
                Some(j) => {
                    let c = &centroids[j as usize * n..(j as usize + 1) * n];
                    let c_sq_j = c_sq[j as usize];
                    jobs.push(Box::new(move || {
                        assign_owned_rows(shard_pts, c, c_sq_j, n, j, lab_s, min_s);
                    }));
                }
                None => {
                    jobs.push(Box::new(move || {
                        kernels::panel_assign_into(
                            shard_pts, centroids, c_sq, take, n, k, lab_s, min_s,
                        );
                    }));
                }
            }
            done += take;
        }
        consumed += rows;
    }
}

/// The canonical native final pass.
///
/// * **One arithmetic everywhere** — every contested row goes through the
///   fused panel kernel, every owned row through the bit-identical
///   single-pair decomposition, and the objective is the row-ordered f64
///   sum of the per-point minima. Labels and objective are therefore
///   bit-identical across backends, thread counts, and pruned/unpruned
///   paths (gated by `tests/store_v3.rs`).
/// * **Block pruning** — when the source exposes `.bmx` v3 min/max
///   summaries, blocks wholly owned by one centroid
///   ([`crate::store::prune`]) skip the k-wide scan: `1` evaluation per
///   row instead of `k`, counted in `Counters::pruned_evals` /
///   `Counters::pruned_blocks`. (The single evaluation is still needed —
///   the objective prices every point exactly.)
/// * **Double buffering** — on the pool path, slab `i + 1` is decoded
///   (read + CRC + codec) by one pool job while the assignment shards of
///   slab `i` run on the remaining workers, so out-of-core decode
///   overlaps compute instead of stalling between slabs.
pub(crate) fn canonical_final_pass(
    pool: Option<&ThreadPool>,
    data: &dyn DataSource,
    centroids: &[f32],
    k: usize,
    counters: &mut Counters,
) -> (Vec<u32>, f64) {
    let (m, n) = (data.m(), data.n());
    if m == 0 {
        return (Vec::new(), 0.0);
    }
    let tracer = obs::tracer();
    let _pass_span = tracer.span("final.pass", "canonical_final_pass");
    let c_sq: Vec<f32> = (0..k).map(|j| sq_norm(&centroids[j * n..(j + 1) * n])).collect();
    let plan = data
        .block_summaries()
        .map(|s| prune::plan(s.minmax, n, s.block_rows, centroids, k));
    let mut labels = vec![0u32; m];

    let mut contested_rows = 0u64;
    let mut owned_rows = 0u64;
    let workers = pool.map(|p| p.size()).unwrap_or(1);
    // Row-ordered objective: a single f64 accumulator fed in global row
    // order, so the value is independent of sharding, slab geometry, and
    // worker count — the strongest determinism contract the final pass
    // has carried so far.
    let mut objective = 0f64;

    match data.contiguous() {
        Some(all) => {
            // Resident source: no copies, no prefetch — one job list over
            // the whole range.
            let mut mins = vec![0f32; m];
            let segments = slab_segments(plan.as_ref(), 0, m);
            for &(_, rows, owner) in &segments {
                match owner {
                    Some(_) => owned_rows += rows as u64,
                    None => contested_rows += rows as u64,
                }
            }
            match pool {
                Some(pool) => {
                    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
                    push_slab_jobs(
                        &mut jobs, all, centroids, &c_sq, n, k, &segments, &mut labels,
                        &mut mins, workers,
                    );
                    pool.scope_run_all(jobs);
                }
                None => {
                    run_segments_serial(
                        all, centroids, &c_sq, n, k, &segments, &mut labels, &mut mins,
                    );
                }
            }
            for &d in &mins {
                objective += d as f64;
            }
        }
        None => {
            // Out-of-core source: stream FINAL_PASS_BLOCK_ROWS-row slabs.
            // The mins buffer is per-slab (folded into the objective after
            // each slab), so the pass's extra resident memory stays O(slab)
            // — only the labels, which are part of the result, scale with m.
            let slab_rows = FINAL_PASS_BLOCK_ROWS;
            let nslabs = m.div_ceil(slab_rows);
            let buf_rows = slab_rows.min(m);
            let mut cur = vec![0f32; buf_rows * n];
            let mut nxt = vec![0f32; buf_rows * n];
            let mut mins_slab = vec![0f32; buf_rows];
            data.read_rows(0, &mut cur[..buf_rows * n]);
            let mut labels_rest: &mut [u32] = &mut labels;
            for s in 0..nslabs {
                let _slab_span = tracer.span("final.slab", "slab");
                let start = s * slab_rows;
                let rows = slab_rows.min(m - start);
                let (lab_slab, lab_tail) = labels_rest.split_at_mut(rows);
                labels_rest = lab_tail;
                let segments = slab_segments(plan.as_ref(), start, rows);
                for &(_, seg_rows, owner) in &segments {
                    match owner {
                        Some(_) => owned_rows += seg_rows as u64,
                        None => contested_rows += seg_rows as u64,
                    }
                }
                let next = (s + 1 < nslabs).then(|| {
                    let nstart = (s + 1) * slab_rows;
                    (nstart, slab_rows.min(m - nstart))
                });
                match pool {
                    Some(pool) => {
                        // Double buffer: the decode of slab s+1 rides in the
                        // same scope as the assignment shards of slab s.
                        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
                        if let Some((nstart, nrows)) = next {
                            let buf = &mut nxt[..nrows * n];
                            jobs.push(Box::new(move || data.read_rows(nstart, buf)));
                        }
                        push_slab_jobs(
                            &mut jobs,
                            &cur[..rows * n],
                            centroids,
                            &c_sq,
                            n,
                            k,
                            &segments,
                            lab_slab,
                            &mut mins_slab[..rows],
                            workers,
                        );
                        pool.scope_run_all(jobs);
                    }
                    None => {
                        run_segments_serial(
                            &cur[..rows * n],
                            centroids,
                            &c_sq,
                            n,
                            k,
                            &segments,
                            lab_slab,
                            &mut mins_slab[..rows],
                        );
                        if let Some((nstart, nrows)) = next {
                            data.read_rows(nstart, &mut nxt[..nrows * n]);
                        }
                    }
                }
                for &d in &mins_slab[..rows] {
                    objective += d as f64;
                }
                std::mem::swap(&mut cur, &mut nxt);
            }
        }
    }

    counters.add_distance_evals(contested_rows * k as u64 + owned_rows);
    counters.add_pruned_evals(owned_rows * (k as u64 - 1));
    if let Some(plan) = &plan {
        counters.pruned_blocks += plan.owned_blocks() as u64;
    }
    let metrics = obs::metrics();
    if metrics.enabled() {
        let eng = [("engine", "final"), ("isa", kernels::active_isa().name())];
        metrics
            .counter(
                "bigmeans_distance_evals_total",
                "Exact point-to-centroid distance evaluations (paper n_d)",
                &eng,
            )
            .add(contested_rows * k as u64 + owned_rows);
        metrics
            .counter(
                "bigmeans_pruned_evals_total",
                "Distance evaluations avoided by bound-based pruning",
                &eng,
            )
            .add(owned_rows * (k as u64 - 1));
        metrics
            .counter(
                "bigmeans_pruned_blocks_total",
                "Blocks skipped whole by bounding-box pruning in the final pass",
                &[],
            )
            .add(plan.as_ref().map(|p| p.owned_blocks() as u64).unwrap_or(0));
    }
    (labels, objective)
}

/// Serial twin of [`push_slab_jobs`] (pool-less runs).
#[allow(clippy::too_many_arguments)]
fn run_segments_serial(
    points: &[f32],
    centroids: &[f32],
    c_sq: &[f32],
    n: usize,
    k: usize,
    segments: &[Segment],
    labels: &mut [u32],
    mins: &mut [f32],
) {
    for &(off, rows, owner) in segments {
        let pts = &points[off * n..(off + rows) * n];
        let lab = &mut labels[off..off + rows];
        let mn = &mut mins[off..off + rows];
        match owner {
            Some(j) => {
                let c = &centroids[j as usize * n..(j as usize + 1) * n];
                assign_owned_rows(pts, c, c_sq[j as usize], n, j, lab, mn);
            }
            None => kernels::panel_assign_into(pts, centroids, c_sq, rows, n, k, lab, mn),
        }
    }
}

/// Reinitialise degenerate centroid slots on the current chunk.
#[allow(clippy::too_many_arguments)]
pub(crate) fn reseed(
    cfg: &BigMeansConfig,
    chunk: &[f32],
    rows: usize,
    n: usize,
    k: usize,
    seed: &mut [f32],
    degenerate: &[usize],
    rng: &mut Rng,
    counters: &mut Counters,
) {
    if degenerate.is_empty() {
        return;
    }
    if degenerate.len() == k {
        // First chunk (all degenerate): full init.
        let init = match cfg.reinit {
            ReinitStrategy::KmeansPP => {
                kernels::kmeanspp(chunk, rows, n, k, cfg.candidates, rng, counters)
            }
            ReinitStrategy::Random => {
                let idx = rng.sample_indices(rows, k);
                let mut c = vec![0f32; k * n];
                for (slot, &i) in idx.iter().enumerate() {
                    c[slot * n..(slot + 1) * n]
                        .copy_from_slice(&chunk[i * n..(i + 1) * n]);
                }
                c
            }
        };
        seed.copy_from_slice(&init);
        return;
    }
    match cfg.reinit {
        ReinitStrategy::KmeansPP => kernels::reseed_degenerate(
            chunk,
            rows,
            n,
            k,
            seed,
            degenerate,
            cfg.candidates,
            rng,
            counters,
        ),
        ReinitStrategy::Random => {
            kernels::reseed_degenerate_random(chunk, rows, n, seed, degenerate, rng)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::StopCondition;
    use crate::data::dataset::Dataset;
    use crate::data::synth::Synth;

    fn blobs(m: usize, k_true: usize, seed: u64) -> Dataset {
        Synth::GaussianMixture {
            m,
            n: 4,
            k_true,
            spread: 0.2,
            box_half_width: 25.0,
        }
        .generate("blobs", seed)
    }

    fn quick_config(k: usize, s: usize, chunks: u64) -> BigMeansConfig {
        BigMeansConfig::new(k, s)
            .with_stop(StopCondition::MaxChunks(chunks))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(7)
    }

    #[test]
    fn clusters_blobs_close_to_reference_kmeans() {
        let data = blobs(4000, 5, 1);
        let bm = BigMeans::new(quick_config(5, 512, 30));
        let r = bm.run(&data).unwrap();
        assert_eq!(r.centroids.len(), 5 * 4);
        assert_eq!(r.assignment.len(), 4000);
        assert!(r.objective.is_finite());
        // Multi-start reference: full-data Lloyd from k-means++ seeds.
        let mut counters = Counters::new();
        let mut rng = Rng::new(3);
        let seed =
            kernels::kmeanspp(data.points(), 4000, 4, 5, 3, &mut rng, &mut counters);
        let reference = kernels::lloyd(
            data.points(),
            &seed,
            4000,
            4,
            5,
            Default::default(),
            None,
            &mut counters,
        );
        // Big-means should land within 25% of a full-data K-means run.
        assert!(
            r.objective <= reference.objective * 1.25,
            "bigmeans {} vs reference {}",
            r.objective,
            reference.objective
        );
    }

    #[test]
    fn improvements_monotone_and_counted() {
        let data = blobs(2000, 3, 2);
        let bm = BigMeans::new(quick_config(3, 256, 20));
        let r = bm.run(&data).unwrap();
        assert!(r.improvements >= 1);
        assert!(r.counters.chunks == 20);
        assert!(r.counters.distance_evals > 0);
        assert!(r.best_chunk_objective.is_finite());
    }

    #[test]
    fn deterministic_given_seed_sequential() {
        let data = blobs(1500, 3, 3);
        let a = BigMeans::new(quick_config(3, 200, 10)).run(&data).unwrap();
        let b = BigMeans::new(quick_config(3, 200, 10)).run(&data).unwrap();
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.objective, b.objective);
        assert_eq!(a.counters, b.counters);
    }

    #[test]
    fn skip_final_assignment() {
        let data = blobs(1000, 2, 4);
        let mut cfg = quick_config(2, 128, 5);
        cfg.skip_final_assignment = true;
        let r = BigMeans::new(cfg).run(&data).unwrap();
        assert!(r.objective.is_nan());
        assert!(r.assignment.is_empty());
        assert!(r.best_chunk_objective.is_finite());
    }

    #[test]
    fn chunk_bigger_than_dataset_clamps() {
        let data = blobs(300, 2, 5);
        let r = BigMeans::new(quick_config(2, 10_000, 3)).run(&data).unwrap();
        assert!(r.objective.is_finite());
    }

    #[test]
    fn invalid_config_rejected() {
        let data = blobs(100, 2, 6);
        let bad = BigMeans::new(quick_config(0, 128, 3));
        assert!(bad.run(&data).is_err());
    }

    #[test]
    fn random_reinit_ablation_runs() {
        let data = blobs(1000, 3, 7);
        let mut cfg = quick_config(3, 200, 10);
        cfg.reinit = ReinitStrategy::Random;
        let r = BigMeans::new(cfg).run(&data).unwrap();
        assert!(r.objective.is_finite());
    }

    /// A resident dataset wearing block summaries — lets the canonical
    /// final pass be driven with handcrafted geometry, independent of any
    /// search convergence.
    struct SummarySource {
        inner: Dataset,
        block_rows: usize,
        minmax: Vec<f32>,
        /// Pretend to be out-of-core to exercise the slab/double-buffer
        /// path.
        hide_contiguous: bool,
    }

    impl crate::data::source::DataSource for SummarySource {
        fn name(&self) -> &str {
            "summary-source"
        }
        fn m(&self) -> usize {
            self.inner.m()
        }
        fn n(&self) -> usize {
            self.inner.n()
        }
        fn read_rows(&self, start: usize, out: &mut [f32]) {
            crate::data::source::DataSource::read_rows(&self.inner, start, out)
        }
        fn contiguous(&self) -> Option<&[f32]> {
            if self.hide_contiguous {
                None
            } else {
                Some(self.inner.points())
            }
        }
        fn block_summaries(&self) -> Option<crate::data::source::BlockSummaries<'_>> {
            Some(crate::data::source::BlockSummaries {
                block_rows: self.block_rows,
                minmax: &self.minmax,
            })
        }
    }

    /// Two tight, far-apart blobs grouped so 32-row blocks are pure.
    fn grouped_two_blob_source(hide_contiguous: bool) -> SummarySource {
        let mut rng = Rng::new(77);
        let n = 3;
        let block_rows = 32;
        let mut pts = Vec::new();
        for c in 0..2 {
            let base = if c == 0 { 0.0f32 } else { 100.0 };
            for _ in 0..64 {
                for _ in 0..n {
                    pts.push(base + 0.1 * rng.gaussian() as f32);
                }
            }
        }
        let inner = Dataset::from_vec("two-blobs", pts, 128, n);
        let minmax: Vec<f32> = inner
            .points()
            .chunks(block_rows * n)
            .flat_map(|block| {
                crate::store::codec::block_minmax(block, crate::store::Dtype::F32, n)
            })
            .collect();
        SummarySource { inner, block_rows, minmax, hide_contiguous }
    }

    #[test]
    fn canonical_final_pass_pruned_matches_unpruned_bitwise() {
        let centroids = vec![0.0f32, 0.0, 0.0, 100.0, 100.0, 100.0];
        for hide in [false, true] {
            let src = grouped_two_blob_source(hide);
            let plain = src.inner.clone();
            let mut c_pruned = Counters::new();
            let mut c_plain = Counters::new();
            let (lab_a, obj_a) =
                canonical_final_pass(None, &src, &centroids, 2, &mut c_pruned);
            let (lab_b, obj_b) =
                canonical_final_pass(None, &plain, &centroids, 2, &mut c_plain);
            assert_eq!(lab_a, lab_b, "hide={hide}");
            assert_eq!(obj_a.to_bits(), obj_b.to_bits(), "hide={hide}");
            assert_eq!(lab_a[..64], vec![0u32; 64][..], "hide={hide}");
            assert_eq!(lab_a[64..], vec![1u32; 64][..], "hide={hide}");
            // All 4 pure blocks owned: every row avoids k−1 = 1 eval.
            assert_eq!(c_pruned.pruned_blocks, 4, "hide={hide}");
            assert_eq!(c_pruned.pruned_evals, 128, "hide={hide}");
            assert_eq!(c_pruned.distance_evals, 128, "hide={hide}");
            assert_eq!(c_plain.pruned_blocks, 0, "hide={hide}");
            assert_eq!(c_plain.distance_evals, 256, "hide={hide}");
            // The pool path (shards + double buffer) must agree bit for
            // bit with the serial path.
            let pool = ThreadPool::new(3);
            let mut c_pool = Counters::new();
            let (lab_p, obj_p) =
                canonical_final_pass(Some(&pool), &src, &centroids, 2, &mut c_pool);
            assert_eq!(lab_p, lab_a, "hide={hide}");
            assert_eq!(obj_p.to_bits(), obj_a.to_bits(), "hide={hide}");
            assert_eq!(c_pool.pruned_blocks, 4, "hide={hide}");
        }
    }

    #[test]
    fn canonical_final_pass_contested_when_centroids_share_a_block() {
        // Both centroids inside every block's box → nothing prunes, and
        // the result still matches the plain panel pass.
        let src = grouped_two_blob_source(true);
        let centroids = vec![0.0f32, 0.0, 0.0, 0.2, 0.2, 0.2];
        let mut c1 = Counters::new();
        let mut c2 = Counters::new();
        let (lab_a, obj_a) = canonical_final_pass(None, &src, &centroids, 2, &mut c1);
        let (lab_b, obj_b) =
            canonical_final_pass(None, &src.inner, &centroids, 2, &mut c2);
        assert_eq!(lab_a, lab_b);
        assert_eq!(obj_a.to_bits(), obj_b.to_bits());
        assert_eq!(c1.pruned_blocks, 0);
        assert_eq!(c1.distance_evals, 256);
    }

    #[test]
    fn slab_segments_merge_runs_and_respect_boundaries() {
        let plan = PrunePlan {
            block_rows: 10,
            owner: vec![Some(0), Some(0), None, Some(1), Some(1), Some(2)],
        };
        // Rows 5..55 span blocks 0..=5 partially.
        let segs = slab_segments(Some(&plan), 5, 50);
        assert_eq!(
            segs,
            vec![(0, 15, Some(0)), (15, 10, None), (25, 20, Some(1)), (45, 10, Some(2))]
        );
        // No plan → one contested segment.
        assert_eq!(slab_segments(None, 5, 50), vec![(0, 50, None)]);
        // Rows beyond the plan's blocks are contested.
        let segs = slab_segments(Some(&plan), 55, 10);
        assert_eq!(segs, vec![(0, 5, Some(2)), (5, 5, None)]);
    }

    #[test]
    fn time_budget_stops() {
        use std::time::Duration;
        let data = blobs(2000, 3, 8);
        let cfg = BigMeansConfig::new(3, 256)
            .with_stop(StopCondition::MaxTime(Duration::from_millis(50)))
            .with_parallel(ParallelMode::Sequential);
        let t = std::time::Instant::now();
        let r = BigMeans::new(cfg).run(&data).unwrap();
        assert!(t.elapsed() < Duration::from_secs(5));
        assert!(r.counters.chunks >= 1);
    }
}
