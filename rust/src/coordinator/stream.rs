//! Streaming Big-means: clustering an unbounded data stream (paper §4.1:
//! "the analyzed dataset can be continuously replenished by new data
//! portions … the principle of decomposition and the iterative improvement
//! nature of our algorithm allows one to obtain accurate clustering results
//! within a predefined time frame even for an infinitely large dataset").
//!
//! A bounded chunk queue connects a producer (the stream source) to the
//! Big-means consumer loop. Backpressure: when the queue is full the
//! producer blocks — the paper's "process as many portions as the time
//! budget allows" semantics fall out naturally.
//!
//! Streaming computes no full-dataset objective (by design — there is no
//! full dataset), but an optional **drift check** keeps a reservoir sample
//! of everything that flowed past and periodically prices the incumbent on
//! it ([`StreamingBigMeans::with_validation`], CLI `--validate-every N`).
//! A validation objective that *rises* between checks means the stream has
//! drifted away from the centroids — the trigger the drift-aware scoring
//! of the streaming follow-up paper (arXiv 2410.14548) is built on. Off by
//! default: the reservoir and the periodic scoring cost nothing unless
//! enabled.
//!
//! Detection can optionally *remediate* ([`DriftAction::Reseed`], CLI
//! `--drift-action reseed`): when a drift event fires, the centroid
//! contributing the most SSE on the reservoir — the one the stream moved
//! away from hardest — is re-seeded by a K-means++ D² draw **from the
//! validation reservoir** (which, unlike any single chunk, remembers the
//! whole stream so far), and the incumbent's chunk objective is reset so
//! the next chunk re-earns incumbency under the new centroid set.
//! Remediations are counted in [`StreamResult::remediations`].

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::bigmeans::reseed;
use crate::coordinator::config::BigMeansConfig;
use crate::coordinator::incumbent::Solution;
use crate::coordinator::solver::{ChunkSolver, NativeSolver};
use crate::coordinator::stop::StopState;
use crate::data::source::{AccessPattern, DataSource};
use crate::kernels::update::degenerate_indices;
use crate::metrics::Counters;
use crate::tuner::config::validation_rng;
use crate::tuner::validation::Reservoir;
use crate::util::rng::Rng;
use crate::util::sync::{lock_recover, wait_recover};

/// A chunk of streamed points (row-major `rows × n`).
#[derive(Clone, Debug)]
pub struct StreamChunk {
    pub points: Vec<f32>,
    pub rows: usize,
}

/// Bounded blocking queue of chunks.
pub struct ChunkQueue {
    inner: Mutex<QueueState>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct QueueState {
    items: VecDeque<StreamChunk>,
    closed: bool,
}

impl ChunkQueue {
    pub fn new(capacity: usize) -> Arc<Self> {
        Arc::new(ChunkQueue {
            inner: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity: capacity.max(1),
        })
    }

    /// Blocking push; returns false if the queue is closed.
    /// Poison-recovering: a panicked producer or consumer must not wedge
    /// the other side of a long-running stream.
    pub fn push(&self, chunk: StreamChunk) -> bool {
        let mut st = lock_recover(&self.inner);
        while st.items.len() >= self.capacity && !st.closed {
            st = wait_recover(&self.not_full, st);
        }
        if st.closed {
            return false;
        }
        st.items.push_back(chunk);
        self.not_empty.notify_one();
        true
    }

    /// Blocking pop; None when closed and drained.
    pub fn pop(&self) -> Option<StreamChunk> {
        let mut st = lock_recover(&self.inner);
        loop {
            if let Some(c) = st.items.pop_front() {
                self.not_full.notify_one();
                return Some(c);
            }
            if st.closed {
                return None;
            }
            st = wait_recover(&self.not_empty, st);
        }
    }

    /// Close the queue: producers stop, consumers drain.
    pub fn close(&self) {
        let mut st = lock_recover(&self.inner);
        st.closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.inner).items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Feed a [`DataSource`] into the queue as sequential `rows_per_chunk`-row
/// chunks — the producer half of the paper's "continuously replenished"
/// scenario for data that lives on disk. Memory is bounded: exactly one
/// chunk buffer is in flight per push (ownership moves into the queue, and
/// backpressure blocks here when consumers lag). Returns the number of
/// chunks pushed; stops early if the queue is closed.
pub fn produce_from_source(
    source: &dyn DataSource,
    queue: &ChunkQueue,
    rows_per_chunk: usize,
) -> u64 {
    assert!(rows_per_chunk > 0, "rows_per_chunk must be positive");
    let (m, n) = (source.m(), source.n());
    // The producer walks the source front to back — enable readahead.
    source.advise(AccessPattern::Sequential);
    let mut start = 0usize;
    let mut pushed = 0u64;
    while start < m {
        let rows = rows_per_chunk.min(m - start);
        let mut points = vec![0f32; rows * n];
        source.read_rows(start, &mut points);
        if !queue.push(StreamChunk { points, rows }) {
            break;
        }
        pushed += 1;
        start += rows;
    }
    pushed
}

/// One periodic drift-check measurement.
#[derive(Clone, Copy, Debug)]
pub struct ValidationPoint {
    /// Chunks consumed when the measurement was taken.
    pub chunk: u64,
    /// Incumbent **mean per-point** SSE on the reservoir at that moment.
    /// (The mean, not the sum: the reservoir may still be filling, and a
    /// growing sample must not read as drift.)
    pub objective: f64,
}

/// Relative rise between consecutive validation objectives that counts as
/// a drift event (the stream moved away from the centroids).
pub const DRIFT_TOLERANCE: f64 = 0.05;

/// Default reservoir rows for the drift check.
pub const DEFAULT_VALIDATION_ROWS: usize = 2048;

/// What a drift event does beyond being counted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriftAction {
    /// Count and trace only (the default).
    None,
    /// Re-seed the worst-contributing centroid via a K-means++ draw from
    /// the validation reservoir.
    Reseed,
}

/// Result of a streaming run.
#[derive(Clone, Debug)]
pub struct StreamResult {
    pub centroids: Vec<f32>,
    pub best_chunk_objective: f64,
    pub chunks_processed: u64,
    pub improvements: u64,
    pub counters: Counters,
    /// Periodic incumbent-on-reservoir objectives (empty when the drift
    /// check is disabled).
    pub validation_trace: Vec<ValidationPoint>,
    /// Consecutive-check rises beyond [`DRIFT_TOLERANCE`].
    pub drift_events: u64,
    /// Drift events answered with a reservoir re-seed
    /// ([`DriftAction::Reseed`]).
    pub remediations: u64,
}

/// Incumbent-publish hook: called with `(centroids, objective, ordinal)`
/// every time the incumbent improves (`ordinal` counts improvements,
/// starting at 1). See [`StreamingBigMeans::with_publish`].
pub type PublishFn = Box<dyn Fn(&[f32], f64, u64) + Send + Sync>;

/// Streaming Big-means consumer: pulls chunks from the queue, improves the
/// incumbent, stops on the configured condition or when the stream closes.
pub struct StreamingBigMeans {
    config: BigMeansConfig,
    solver: Box<dyn ChunkSolver>,
    n: usize,
    /// Drift check cadence in chunks (0 = off).
    validate_every: u64,
    /// Reservoir capacity for the drift check.
    validation_rows: usize,
    /// What a drift event triggers.
    drift_action: DriftAction,
    /// Invoked on every incumbent improvement (the stream→registry
    /// publish contract of serve mode).
    publish: Option<PublishFn>,
}

impl StreamingBigMeans {
    pub fn new(config: BigMeansConfig, n: usize) -> Self {
        let solver = Box::new(NativeSolver::with_kernel(
            config.lloyd,
            config.threads,
            config.kernel,
        ));
        StreamingBigMeans {
            config,
            solver,
            n,
            validate_every: 0,
            validation_rows: DEFAULT_VALIDATION_ROWS,
            drift_action: DriftAction::None,
            publish: None,
        }
    }

    /// Enable the periodic drift check: every `every` chunks, price the
    /// incumbent on a `rows`-capacity reservoir of the stream so far.
    /// `every = 0` disables it (the default).
    pub fn with_validation(mut self, every: u64, rows: usize) -> Self {
        self.validate_every = every;
        self.validation_rows = rows.max(1);
        self
    }

    /// What to do when a drift event fires (requires the drift check to
    /// be enabled to ever trigger).
    pub fn with_drift_action(mut self, action: DriftAction) -> Self {
        self.drift_action = action;
        self
    }

    /// Install an incumbent-publish hook, called synchronously with
    /// `(centroids, objective, ordinal)` each time a chunk improves the
    /// incumbent. This is the producer half of serve mode's hot-swap
    /// contract: the CLI wires it to write a model artifact that a
    /// watching daemon picks up mid-flight. The hook runs on the consumer
    /// thread — keep it cheap (an atomic file write, not a blocking RPC).
    pub fn with_publish(mut self, hook: PublishFn) -> Self {
        self.publish = Some(hook);
        self
    }

    /// Consume the queue until it closes or the stop condition trips.
    pub fn run(&self, queue: &ChunkQueue) -> StreamResult {
        let cfg = &self.config;
        let (n, k) = (self.n, cfg.k);
        let mut rng = Rng::new(cfg.seed);
        let mut counters = Counters::new();
        let mut incumbent = Solution::all_degenerate(k, n);
        let mut improvements = 0u64;
        let mut stop = StopState::new(cfg.stop);
        let mut reservoir = (self.validate_every > 0)
            .then(|| Reservoir::new(self.validation_rows, n, validation_rng(cfg.seed)));
        let mut validation_trace: Vec<ValidationPoint> = Vec::new();
        let mut drift_events = 0u64;
        let mut remediations = 0u64;

        while !stop.should_stop() {
            let Some(chunk) = queue.pop() else { break };
            if chunk.rows < k {
                continue; // too small to carry k clusters — skip, keep draining
            }
            debug_assert_eq!(chunk.points.len(), chunk.rows * n);
            let mut seed = incumbent.centroids.clone();
            reseed(
                cfg,
                &chunk.points,
                chunk.rows,
                n,
                k,
                &mut seed,
                &incumbent.degenerate,
                &mut rng,
                &mut counters,
            );
            let result =
                self.solver
                    .lloyd(&chunk.points, chunk.rows, n, k, &seed, &mut counters);
            counters.chunk_iterations += result.iters as u64;
            counters.chunks += 1;
            stop.record_chunk();
            let improved = result.objective < incumbent.objective;
            // Report-sink tap (no-op unless `--report` enabled it): the
            // stream loop is its own chunk pipeline, not a ShotExecutor,
            // so it records its descent trace here. No per-chunk timing —
            // streaming never reads the clock per chunk.
            crate::obs::report_sink().record_shot(
                result.objective,
                result.objective,
                improved,
                result.iters,
                None,
            );
            if improved {
                incumbent = Solution {
                    degenerate: degenerate_indices(&result.counts),
                    centroids: result.centroids,
                    objective: result.objective,
                };
                improvements += 1;
                if let Some(hook) = &self.publish {
                    hook(&incumbent.centroids, incumbent.objective, improvements);
                }
            }
            if let Some(res) = reservoir.as_mut() {
                res.observe_rows(&chunk.points, chunk.rows);
                if counters.chunks % self.validate_every == 0 && !incumbent.is_initial() {
                    let sum = res.objective(
                        &incumbent.centroids,
                        &incumbent.degenerate,
                        k,
                        cfg.kernel,
                        &mut counters,
                    );
                    let obj = sum / res.len() as f64;
                    let drifted = validation_trace
                        .last()
                        .is_some_and(|last| obj > last.objective * (1.0 + DRIFT_TOLERANCE));
                    if drifted {
                        drift_events += 1;
                        if self.drift_action == DriftAction::Reseed {
                            remediate(cfg, res, n, k, &mut incumbent, &mut rng, &mut counters);
                            remediations += 1;
                        }
                    }
                    validation_trace
                        .push(ValidationPoint { chunk: counters.chunks, objective: obj });
                }
            }
        }
        StreamResult {
            centroids: incumbent.centroids,
            best_chunk_objective: incumbent.objective,
            chunks_processed: counters.chunks,
            improvements,
            counters,
            validation_trace,
            drift_events,
            remediations,
        }
    }
}

/// Answer a drift event: rank centroids by their SSE contribution on the
/// reservoir, re-seed the worst one with a K-means++ D² draw from the
/// reservoir rows, and reset the incumbent's chunk objective so the next
/// chunk re-earns incumbency under the remediated centroid set.
fn remediate(
    cfg: &BigMeansConfig,
    reservoir: &Reservoir,
    n: usize,
    k: usize,
    incumbent: &mut Solution,
    rng: &mut Rng,
    counters: &mut Counters,
) {
    let points = reservoir.points();
    let rows = reservoir.len();
    if rows == 0 {
        return;
    }
    // Park degenerate slots (as validation scoring does), then rank the
    // live centroids by reservoir SSE.
    let mut parked = incumbent.centroids.clone();
    for &j in &incumbent.degenerate {
        for v in &mut parked[j * n..(j + 1) * n] {
            *v = crate::tuner::validation::DEGENERATE_PAD;
        }
    }
    let engine = cfg.kernel.build();
    let (labels, mins) = engine.assign_once(points, &parked, rows, n, k, counters);
    let mut sse = vec![0f64; k];
    for (label, d) in labels.iter().zip(&mins) {
        sse[*label as usize] += *d as f64;
    }
    let worst = (0..k)
        .filter(|j| !incumbent.degenerate.contains(j))
        .max_by(|&a, &b| sse[a].total_cmp(&sse[b]));
    let Some(worst) = worst else { return };
    // Draw against the *parked* copy: degenerate slots must not count as
    // alive at their stale positions, or the D² weights would steer the
    // replacement away from exactly the regions they once covered. Only
    // the worst slot's new position is copied back — degenerate slots
    // keep their stored positions (the incumbent's usual semantics).
    crate::kernels::reseed_degenerate(
        points,
        rows,
        n,
        k,
        &mut parked,
        &[worst],
        cfg.candidates,
        rng,
        counters,
    );
    incumbent.centroids[worst * n..(worst + 1) * n]
        .copy_from_slice(&parked[worst * n..(worst + 1) * n]);
    incumbent.objective = f64::INFINITY;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{ParallelMode, StopCondition};
    use crate::util::rng::Rng;

    fn blob_chunk(rng: &mut Rng, rows: usize) -> StreamChunk {
        let centers = [(0.0f32, 0.0f32), (30.0, 30.0), (0.0, 30.0)];
        let mut points = Vec::with_capacity(rows * 2);
        for _ in 0..rows {
            let (cx, cy) = centers[rng.usize(3)];
            points.push(cx + 0.3 * rng.gaussian() as f32);
            points.push(cy + 0.3 * rng.gaussian() as f32);
        }
        StreamChunk { points, rows }
    }

    #[test]
    fn queue_backpressure_and_close() {
        let q = ChunkQueue::new(2);
        assert!(q.push(StreamChunk { points: vec![0.0; 2], rows: 1 }));
        assert!(q.push(StreamChunk { points: vec![0.0; 2], rows: 1 }));
        assert_eq!(q.len(), 2);
        // Producer would block now; close from another thread unblocks.
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.push(StreamChunk { points: vec![0.0; 2], rows: 1 }));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(!h.join().unwrap(), "push into closed queue must return false");
        // Drain the two queued chunks, then None.
        assert!(q.pop().is_some());
        assert!(q.pop().is_some());
        assert!(q.pop().is_none());
    }

    #[test]
    fn streaming_improves_over_chunks() {
        let cfg = BigMeansConfig::new(3, 256)
            .with_stop(StopCondition::MaxChunks(50))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(1);
        let engine = StreamingBigMeans::new(cfg, 2);
        let q = ChunkQueue::new(4);
        let qp = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            let mut rng = Rng::new(42);
            for _ in 0..30 {
                if !qp.push(blob_chunk(&mut rng, 256)) {
                    break;
                }
            }
            qp.close();
        });
        let r = engine.run(&q);
        producer.join().unwrap();
        assert_eq!(r.chunks_processed, 30);
        assert!(r.improvements >= 1);
        assert!(r.best_chunk_objective.is_finite());
        // Centroids should sit near the three stream blobs.
        let mut found = 0;
        for &(cx, cy) in &[(0.0f32, 0.0f32), (30.0, 30.0), (0.0, 30.0)] {
            for j in 0..3 {
                let c = &r.centroids[j * 2..j * 2 + 2];
                if (c[0] - cx).abs() < 2.0 && (c[1] - cy).abs() < 2.0 {
                    found += 1;
                    break;
                }
            }
        }
        assert_eq!(found, 3, "centroids {:?}", r.centroids);
    }

    #[test]
    fn produce_from_source_covers_dataset_in_order() {
        use crate::data::dataset::Dataset;
        let d = Dataset::from_vec("t", (0..20).map(|x| x as f32).collect(), 10, 2);
        let q = ChunkQueue::new(16);
        let pushed = produce_from_source(&d, &q, 4);
        q.close();
        assert_eq!(pushed, 3); // 4 + 4 + 2 rows
        let mut rows_seen = 0usize;
        let mut flat = Vec::new();
        while let Some(c) = q.pop() {
            rows_seen += c.rows;
            flat.extend_from_slice(&c.points);
        }
        assert_eq!(rows_seen, 10);
        assert_eq!(flat, d.points());
    }

    #[test]
    fn streaming_from_disk_source_clusters() {
        use crate::data::bmx::{save_bmx, BmxSource};
        use crate::data::dataset::Dataset;
        // Three tight blobs written to a temp .bmx, streamed chunk-by-chunk.
        let mut rng = Rng::new(5);
        let mut pts = Vec::new();
        let centers = [(0.0f32, 0.0f32), (30.0, 30.0), (0.0, 30.0)];
        for i in 0..1500 {
            let (cx, cy) = centers[i % 3];
            pts.push(cx + 0.3 * rng.gaussian() as f32);
            pts.push(cy + 0.3 * rng.gaussian() as f32);
        }
        let d = Dataset::from_vec("blobs", pts, 1500, 2);
        let path = std::env::temp_dir()
            .join(format!("bigmeans_stream_{}.bmx", std::process::id()));
        save_bmx(&d, &path).unwrap();
        let src = BmxSource::open(&path).unwrap();

        let cfg = BigMeansConfig::new(3, 256)
            .with_stop(StopCondition::MaxChunks(50))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(1);
        let engine = StreamingBigMeans::new(cfg, 2);
        let q = ChunkQueue::new(4);
        let qp = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            let pushed = produce_from_source(&src, &qp, 256);
            qp.close();
            pushed
        });
        let r = engine.run(&q);
        let pushed = producer.join().unwrap();
        assert_eq!(pushed, 6); // ceil(1500 / 256): five full chunks + a 220-row tail
        assert_eq!(r.chunks_processed, 6);
        assert!(r.best_chunk_objective.is_finite());
        // Centroids should sit near the three blobs.
        for &(cx, cy) in &centers {
            let hit = (0..3).any(|j| {
                let c = &r.centroids[j * 2..j * 2 + 2];
                (c[0] - cx).abs() < 2.0 && (c[1] - cy).abs() < 2.0
            });
            assert!(hit, "no centroid near ({cx},{cy}): {:?}", r.centroids);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn validation_disabled_by_default() {
        let cfg = BigMeansConfig::new(3, 256)
            .with_stop(StopCondition::MaxChunks(10))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(1);
        let engine = StreamingBigMeans::new(cfg, 2);
        let q = ChunkQueue::new(4);
        let qp = Arc::clone(&q);
        std::thread::spawn(move || {
            let mut rng = Rng::new(3);
            for _ in 0..10 {
                if !qp.push(blob_chunk(&mut rng, 128)) {
                    break;
                }
            }
            qp.close();
        });
        let r = engine.run(&q);
        assert!(r.validation_trace.is_empty());
        assert_eq!(r.drift_events, 0);
    }

    #[test]
    fn drift_check_traces_stationary_stream() {
        // A stationary stream: the periodic reservoir objective exists and
        // never rises past the drift tolerance.
        let cfg = BigMeansConfig::new(3, 256)
            .with_stop(StopCondition::MaxChunks(40))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(5);
        // A reservoir big enough to keep every streamed row: consecutive
        // checks then share their whole prefix, so the mean objective is
        // extremely stable on a stationary stream.
        let engine = StreamingBigMeans::new(cfg, 2).with_validation(8, 1 << 17);
        let q = ChunkQueue::new(4);
        let qp = Arc::clone(&q);
        std::thread::spawn(move || {
            let mut rng = Rng::new(17);
            for _ in 0..40 {
                if !qp.push(blob_chunk(&mut rng, 1024)) {
                    break;
                }
            }
            qp.close();
        });
        let r = engine.run(&q);
        assert_eq!(r.chunks_processed, 40);
        assert_eq!(r.validation_trace.len(), 5); // every 8 chunks
        assert!(r.validation_trace.iter().all(|p| p.objective.is_finite()));
        assert!(
            r.validation_trace.windows(2).all(|w| w[1].chunk > w[0].chunk),
            "trace chunks must be increasing"
        );
        assert_eq!(r.drift_events, 0, "trace: {:?}", r.validation_trace);
    }

    #[test]
    fn drift_check_flags_a_moved_stream() {
        // Halfway through, the blobs jump to new locations: the reservoir
        // mixes old and new data while the incumbent still sits on the old
        // centers, so the periodic objective must rise — a drift event.
        let cfg = BigMeansConfig::new(3, 256)
            .with_stop(StopCondition::MaxChunks(60))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(9);
        let engine = StreamingBigMeans::new(cfg, 2).with_validation(5, 512);
        let q = ChunkQueue::new(4);
        let qp = Arc::clone(&q);
        std::thread::spawn(move || {
            let mut rng = Rng::new(23);
            for i in 0..60 {
                let shift = if i < 30 { 0.0f32 } else { 200.0 };
                let mut chunk = blob_chunk(&mut rng, 256);
                for v in &mut chunk.points {
                    *v += shift;
                }
                if !qp.push(chunk) {
                    break;
                }
            }
            qp.close();
        });
        let r = engine.run(&q);
        assert_eq!(r.chunks_processed, 60);
        assert!(!r.validation_trace.is_empty());
        assert!(
            r.drift_events >= 1,
            "expected a drift event after the stream moved: {:?}",
            r.validation_trace
        );
    }

    /// A stream whose blobs jump halfway through: shared by the
    /// remediation tests so the action comparison is apples-to-apples.
    fn moved_stream(q: Arc<ChunkQueue>, producer_seed: u64) {
        std::thread::spawn(move || {
            let mut rng = Rng::new(producer_seed);
            for i in 0..60 {
                let shift = if i < 30 { 0.0f32 } else { 200.0 };
                let mut chunk = blob_chunk(&mut rng, 256);
                for v in &mut chunk.points {
                    *v += shift;
                }
                if !q.push(chunk) {
                    break;
                }
            }
            q.close();
        });
    }

    #[test]
    fn drift_reseed_remediates_a_moved_stream() {
        let cfg = BigMeansConfig::new(3, 256)
            .with_stop(StopCondition::MaxChunks(60))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(9);
        let engine = StreamingBigMeans::new(cfg, 2)
            .with_validation(5, 512)
            .with_drift_action(DriftAction::Reseed);
        let q = ChunkQueue::new(4);
        moved_stream(Arc::clone(&q), 23);
        let r = engine.run(&q);
        assert_eq!(r.chunks_processed, 60);
        assert!(r.drift_events >= 1, "trace: {:?}", r.validation_trace);
        assert_eq!(
            r.remediations, r.drift_events,
            "reseed action must answer every drift event"
        );
        assert!(r.centroids.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn remediate_replaces_the_worst_contributor() {
        use crate::metrics::Counters;
        use crate::tuner::validation::Reservoir;
        // Reservoir: two tight groups at 0 and 100 (1-D). Incumbent:
        // centroid 0 covers the origin group, centroid 1 sits at 50 —
        // every 100-group point maps to it with huge error, so it is the
        // worst contributor and must be re-seeded onto a reservoir point.
        let cfg = BigMeansConfig::new(2, 16).with_parallel(ParallelMode::Sequential);
        let mut res = Reservoir::new(64, 1, Rng::new(3));
        let pts: Vec<f32> = (0..32)
            .map(|i| if i % 2 == 0 { (i % 4) as f32 * 0.01 } else { 100.0 })
            .collect();
        res.observe_rows(&pts, 32);
        let mut incumbent = Solution {
            centroids: vec![0.0, 50.0],
            objective: 123.0,
            degenerate: vec![],
        };
        let mut rng = Rng::new(7);
        let mut counters = Counters::new();
        super::remediate(&cfg, &res, 1, 2, &mut incumbent, &mut rng, &mut counters);
        assert!(incumbent.objective.is_infinite(), "incumbency must be reset");
        assert!(
            (incumbent.centroids[1] - 50.0).abs() > 1.0,
            "worst centroid must move off 50: {:?}",
            incumbent.centroids
        );
        assert!(
            pts.iter().any(|&p| (p - incumbent.centroids[1]).abs() < 1e-6),
            "replacement must be a reservoir point: {:?}",
            incumbent.centroids
        );
        assert!((incumbent.centroids[0]).abs() < 1.0, "healthy centroid untouched");
    }

    #[test]
    fn drift_action_none_never_remediates() {
        let cfg = BigMeansConfig::new(3, 256)
            .with_stop(StopCondition::MaxChunks(60))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(9);
        let engine = StreamingBigMeans::new(cfg, 2).with_validation(5, 512);
        let q = ChunkQueue::new(4);
        moved_stream(Arc::clone(&q), 23);
        let r = engine.run(&q);
        assert!(r.drift_events >= 1);
        assert_eq!(r.remediations, 0);
    }

    #[test]
    fn publish_hook_fires_on_every_improvement() {
        let cfg = BigMeansConfig::new(3, 256)
            .with_stop(StopCondition::MaxChunks(30))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(1);
        let published: Arc<Mutex<Vec<(Vec<f32>, f64, u64)>>> =
            Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&published);
        let engine = StreamingBigMeans::new(cfg, 2).with_publish(Box::new(
            move |centroids, objective, ordinal| {
                sink.lock().unwrap().push((centroids.to_vec(), objective, ordinal));
            },
        ));
        let q = ChunkQueue::new(4);
        let qp = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            let mut rng = Rng::new(42);
            for _ in 0..30 {
                if !qp.push(blob_chunk(&mut rng, 256)) {
                    break;
                }
            }
            qp.close();
        });
        let r = engine.run(&q);
        producer.join().unwrap();
        let seen = published.lock().unwrap();
        assert_eq!(seen.len() as u64, r.improvements, "one publish per improvement");
        assert!(
            seen.iter().enumerate().all(|(i, (_, _, ord))| *ord == i as u64 + 1),
            "ordinals must count improvements from 1"
        );
        let last = seen.last().expect("at least one improvement");
        assert_eq!(last.0, r.centroids, "last publish must be the final incumbent");
        assert_eq!(last.1, r.best_chunk_objective);
        assert!(
            seen.windows(2).all(|w| w[1].1 < w[0].1),
            "published objectives must be strictly improving"
        );
    }

    #[test]
    fn undersized_chunks_skipped() {
        let cfg = BigMeansConfig::new(3, 256)
            .with_stop(StopCondition::MaxChunks(10))
            .with_parallel(ParallelMode::Sequential);
        let engine = StreamingBigMeans::new(cfg, 2);
        let q = ChunkQueue::new(4);
        let qp = Arc::clone(&q);
        std::thread::spawn(move || {
            let mut rng = Rng::new(9);
            qp.push(StreamChunk { points: vec![1.0; 4], rows: 2 }); // < k
            qp.push(blob_chunk(&mut rng, 64));
            qp.close();
        });
        let r = engine.run(&q);
        assert_eq!(r.chunks_processed, 1);
    }
}
