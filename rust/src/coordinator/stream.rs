//! Streaming Big-means: clustering an unbounded data stream (paper §4.1:
//! "the analyzed dataset can be continuously replenished by new data
//! portions … the principle of decomposition and the iterative improvement
//! nature of our algorithm allows one to obtain accurate clustering results
//! within a predefined time frame even for an infinitely large dataset").
//!
//! A bounded chunk queue connects a producer (the stream source) to the
//! Big-means consumer loop. Backpressure: when the queue is full the
//! producer blocks — the paper's "process as many portions as the time
//! budget allows" semantics fall out naturally.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::bigmeans::reseed;
use crate::coordinator::config::BigMeansConfig;
use crate::coordinator::incumbent::Solution;
use crate::coordinator::solver::{ChunkSolver, NativeSolver};
use crate::coordinator::stop::StopState;
use crate::data::source::{AccessPattern, DataSource};
use crate::kernels::update::degenerate_indices;
use crate::metrics::Counters;
use crate::util::rng::Rng;

/// A chunk of streamed points (row-major `rows × n`).
#[derive(Clone, Debug)]
pub struct StreamChunk {
    pub points: Vec<f32>,
    pub rows: usize,
}

/// Bounded blocking queue of chunks.
pub struct ChunkQueue {
    inner: Mutex<QueueState>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct QueueState {
    items: VecDeque<StreamChunk>,
    closed: bool,
}

impl ChunkQueue {
    pub fn new(capacity: usize) -> Arc<Self> {
        Arc::new(ChunkQueue {
            inner: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity: capacity.max(1),
        })
    }

    /// Blocking push; returns false if the queue is closed.
    pub fn push(&self, chunk: StreamChunk) -> bool {
        let mut st = self.inner.lock().unwrap();
        while st.items.len() >= self.capacity && !st.closed {
            st = self.not_full.wait(st).unwrap();
        }
        if st.closed {
            return false;
        }
        st.items.push_back(chunk);
        self.not_empty.notify_one();
        true
    }

    /// Blocking pop; None when closed and drained.
    pub fn pop(&self) -> Option<StreamChunk> {
        let mut st = self.inner.lock().unwrap();
        loop {
            if let Some(c) = st.items.pop_front() {
                self.not_full.notify_one();
                return Some(c);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Close the queue: producers stop, consumers drain.
    pub fn close(&self) {
        let mut st = self.inner.lock().unwrap();
        st.closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Feed a [`DataSource`] into the queue as sequential `rows_per_chunk`-row
/// chunks — the producer half of the paper's "continuously replenished"
/// scenario for data that lives on disk. Memory is bounded: exactly one
/// chunk buffer is in flight per push (ownership moves into the queue, and
/// backpressure blocks here when consumers lag). Returns the number of
/// chunks pushed; stops early if the queue is closed.
pub fn produce_from_source(
    source: &dyn DataSource,
    queue: &ChunkQueue,
    rows_per_chunk: usize,
) -> u64 {
    assert!(rows_per_chunk > 0, "rows_per_chunk must be positive");
    let (m, n) = (source.m(), source.n());
    // The producer walks the source front to back — enable readahead.
    source.advise(AccessPattern::Sequential);
    let mut start = 0usize;
    let mut pushed = 0u64;
    while start < m {
        let rows = rows_per_chunk.min(m - start);
        let mut points = vec![0f32; rows * n];
        source.read_rows(start, &mut points);
        if !queue.push(StreamChunk { points, rows }) {
            break;
        }
        pushed += 1;
        start += rows;
    }
    pushed
}

/// Result of a streaming run.
#[derive(Clone, Debug)]
pub struct StreamResult {
    pub centroids: Vec<f32>,
    pub best_chunk_objective: f64,
    pub chunks_processed: u64,
    pub improvements: u64,
    pub counters: Counters,
}

/// Streaming Big-means consumer: pulls chunks from the queue, improves the
/// incumbent, stops on the configured condition or when the stream closes.
pub struct StreamingBigMeans {
    config: BigMeansConfig,
    solver: Box<dyn ChunkSolver>,
    n: usize,
}

impl StreamingBigMeans {
    pub fn new(config: BigMeansConfig, n: usize) -> Self {
        let solver = Box::new(NativeSolver::with_kernel(
            config.lloyd,
            config.threads,
            config.kernel,
        ));
        StreamingBigMeans { config, solver, n }
    }

    /// Consume the queue until it closes or the stop condition trips.
    pub fn run(&self, queue: &ChunkQueue) -> StreamResult {
        let cfg = &self.config;
        let (n, k) = (self.n, cfg.k);
        let mut rng = Rng::new(cfg.seed);
        let mut counters = Counters::new();
        let mut incumbent = Solution::all_degenerate(k, n);
        let mut improvements = 0u64;
        let mut stop = StopState::new(cfg.stop);

        while !stop.should_stop() {
            let Some(chunk) = queue.pop() else { break };
            if chunk.rows < k {
                continue; // too small to carry k clusters — skip, keep draining
            }
            debug_assert_eq!(chunk.points.len(), chunk.rows * n);
            let mut seed = incumbent.centroids.clone();
            reseed(
                cfg,
                &chunk.points,
                chunk.rows,
                n,
                k,
                &mut seed,
                &incumbent.degenerate,
                &mut rng,
                &mut counters,
            );
            let result =
                self.solver
                    .lloyd(&chunk.points, chunk.rows, n, k, &seed, &mut counters);
            counters.chunk_iterations += result.iters as u64;
            counters.chunks += 1;
            stop.record_chunk();
            if result.objective < incumbent.objective {
                incumbent = Solution {
                    degenerate: degenerate_indices(&result.counts),
                    centroids: result.centroids,
                    objective: result.objective,
                };
                improvements += 1;
            }
        }
        StreamResult {
            centroids: incumbent.centroids,
            best_chunk_objective: incumbent.objective,
            chunks_processed: counters.chunks,
            improvements,
            counters,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{ParallelMode, StopCondition};
    use crate::util::rng::Rng;

    fn blob_chunk(rng: &mut Rng, rows: usize) -> StreamChunk {
        let centers = [(0.0f32, 0.0f32), (30.0, 30.0), (0.0, 30.0)];
        let mut points = Vec::with_capacity(rows * 2);
        for _ in 0..rows {
            let (cx, cy) = centers[rng.usize(3)];
            points.push(cx + 0.3 * rng.gaussian() as f32);
            points.push(cy + 0.3 * rng.gaussian() as f32);
        }
        StreamChunk { points, rows }
    }

    #[test]
    fn queue_backpressure_and_close() {
        let q = ChunkQueue::new(2);
        assert!(q.push(StreamChunk { points: vec![0.0; 2], rows: 1 }));
        assert!(q.push(StreamChunk { points: vec![0.0; 2], rows: 1 }));
        assert_eq!(q.len(), 2);
        // Producer would block now; close from another thread unblocks.
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.push(StreamChunk { points: vec![0.0; 2], rows: 1 }));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(!h.join().unwrap(), "push into closed queue must return false");
        // Drain the two queued chunks, then None.
        assert!(q.pop().is_some());
        assert!(q.pop().is_some());
        assert!(q.pop().is_none());
    }

    #[test]
    fn streaming_improves_over_chunks() {
        let cfg = BigMeansConfig::new(3, 256)
            .with_stop(StopCondition::MaxChunks(50))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(1);
        let engine = StreamingBigMeans::new(cfg, 2);
        let q = ChunkQueue::new(4);
        let qp = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            let mut rng = Rng::new(42);
            for _ in 0..30 {
                if !qp.push(blob_chunk(&mut rng, 256)) {
                    break;
                }
            }
            qp.close();
        });
        let r = engine.run(&q);
        producer.join().unwrap();
        assert_eq!(r.chunks_processed, 30);
        assert!(r.improvements >= 1);
        assert!(r.best_chunk_objective.is_finite());
        // Centroids should sit near the three stream blobs.
        let mut found = 0;
        for &(cx, cy) in &[(0.0f32, 0.0f32), (30.0, 30.0), (0.0, 30.0)] {
            for j in 0..3 {
                let c = &r.centroids[j * 2..j * 2 + 2];
                if (c[0] - cx).abs() < 2.0 && (c[1] - cy).abs() < 2.0 {
                    found += 1;
                    break;
                }
            }
        }
        assert_eq!(found, 3, "centroids {:?}", r.centroids);
    }

    #[test]
    fn produce_from_source_covers_dataset_in_order() {
        use crate::data::dataset::Dataset;
        let d = Dataset::from_vec("t", (0..20).map(|x| x as f32).collect(), 10, 2);
        let q = ChunkQueue::new(16);
        let pushed = produce_from_source(&d, &q, 4);
        q.close();
        assert_eq!(pushed, 3); // 4 + 4 + 2 rows
        let mut rows_seen = 0usize;
        let mut flat = Vec::new();
        while let Some(c) = q.pop() {
            rows_seen += c.rows;
            flat.extend_from_slice(&c.points);
        }
        assert_eq!(rows_seen, 10);
        assert_eq!(flat, d.points());
    }

    #[test]
    fn streaming_from_disk_source_clusters() {
        use crate::data::bmx::{save_bmx, BmxSource};
        use crate::data::dataset::Dataset;
        // Three tight blobs written to a temp .bmx, streamed chunk-by-chunk.
        let mut rng = Rng::new(5);
        let mut pts = Vec::new();
        let centers = [(0.0f32, 0.0f32), (30.0, 30.0), (0.0, 30.0)];
        for i in 0..1500 {
            let (cx, cy) = centers[i % 3];
            pts.push(cx + 0.3 * rng.gaussian() as f32);
            pts.push(cy + 0.3 * rng.gaussian() as f32);
        }
        let d = Dataset::from_vec("blobs", pts, 1500, 2);
        let path = std::env::temp_dir()
            .join(format!("bigmeans_stream_{}.bmx", std::process::id()));
        save_bmx(&d, &path).unwrap();
        let src = BmxSource::open(&path).unwrap();

        let cfg = BigMeansConfig::new(3, 256)
            .with_stop(StopCondition::MaxChunks(50))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(1);
        let engine = StreamingBigMeans::new(cfg, 2);
        let q = ChunkQueue::new(4);
        let qp = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            let pushed = produce_from_source(&src, &qp, 256);
            qp.close();
            pushed
        });
        let r = engine.run(&q);
        let pushed = producer.join().unwrap();
        assert_eq!(pushed, 6); // ceil(1500 / 256): five full chunks + a 220-row tail
        assert_eq!(r.chunks_processed, 6);
        assert!(r.best_chunk_objective.is_finite());
        // Centroids should sit near the three blobs.
        for &(cx, cy) in &centers {
            let hit = (0..3).any(|j| {
                let c = &r.centroids[j * 2..j * 2 + 2];
                (c[0] - cx).abs() < 2.0 && (c[1] - cy).abs() < 2.0
            });
            assert!(hit, "no centroid near ({cx},{cy}): {:?}", r.centroids);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn undersized_chunks_skipped() {
        let cfg = BigMeansConfig::new(3, 256)
            .with_stop(StopCondition::MaxChunks(10))
            .with_parallel(ParallelMode::Sequential);
        let engine = StreamingBigMeans::new(cfg, 2);
        let q = ChunkQueue::new(4);
        let qp = Arc::clone(&q);
        std::thread::spawn(move || {
            let mut rng = Rng::new(9);
            qp.push(StreamChunk { points: vec![1.0; 4], rows: 2 }); // < k
            qp.push(blob_chunk(&mut rng, 64));
            qp.close();
        });
        let r = engine.run(&q);
        assert_eq!(r.chunks_processed, 1);
    }
}
