//! VNS-Big-means: the paper's named future-work extension ("Construct a
//! novel MSSC heuristic by incorporating the VNS scheme into the proposed
//! algorithm").
//!
//! Big-means' shaking strength is governed by the chunk size: smaller
//! chunks perturb the incumbent harder (§4.1). Variable Neighbourhood
//! Search systematises that: maintain a ladder of chunk sizes
//! `s_1 > s_2 > … > s_q` (neighbourhood structures, weakest shaking
//! first). After a chunk fails to improve the incumbent, move one rung
//! down (stronger shaking); on improvement, reset to the top rung —
//! classic VNS "move or next neighbourhood" control.

use crate::coordinator::bigmeans::{finish, reseed, BigMeansResult};
use crate::coordinator::config::BigMeansConfig;
use crate::coordinator::incumbent::Solution;
use crate::coordinator::sampler::ChunkSampler;
use crate::coordinator::solver::{ChunkSolver, NativeSolver};
use crate::coordinator::stop::StopState;
use crate::data::source::{AccessPattern, DataSource};
use crate::kernels::update::degenerate_indices;
use crate::metrics::{Counters, PhaseTimer};
use crate::util::rng::Rng;

/// VNS configuration on top of a Big-means config.
#[derive(Clone, Debug)]
pub struct VnsConfig {
    /// Base Big-means configuration. `base.chunk_size` is ignored in
    /// favour of the ladder.
    pub base: BigMeansConfig,
    /// Chunk-size ladder, weakest shaking (largest s) first. Must be
    /// non-empty and descending.
    pub ladder: Vec<usize>,
}

impl VnsConfig {
    /// Default ladder: geometric descent from `s` by factors of 2, at
    /// least 4 rungs, floored at `4·k`.
    pub fn new(base: BigMeansConfig) -> Self {
        let mut ladder = Vec::new();
        let mut s = base.chunk_size;
        let floor = (4 * base.k).max(8);
        while s >= floor && ladder.len() < 6 {
            ladder.push(s);
            s /= 2;
        }
        if ladder.is_empty() {
            ladder.push(base.chunk_size);
        }
        VnsConfig { base, ladder }
    }

    pub fn validate(&self, m: usize) -> Result<(), String> {
        if self.ladder.is_empty() {
            return Err("VNS ladder must be non-empty".into());
        }
        if self.ladder.windows(2).any(|w| w[0] <= w[1]) {
            return Err("VNS ladder must be strictly descending".into());
        }
        if *self.ladder.last().unwrap() < self.base.k {
            return Err("smallest rung must hold k points".into());
        }
        self.base.validate(m, 0)
    }
}

/// Result of a VNS run: the Big-means result plus rung statistics.
#[derive(Clone, Debug)]
pub struct VnsResult {
    pub inner: BigMeansResult,
    /// Chunks processed per ladder rung.
    pub rung_chunks: Vec<u64>,
    /// Improvements found per ladder rung.
    pub rung_improvements: Vec<u64>,
}

/// Run VNS-Big-means (sequential pipeline). Accepts any [`DataSource`]
/// (`&Dataset` coerces).
pub fn run_vns(cfg: &VnsConfig, data: &dyn DataSource) -> Result<VnsResult, String> {
    let (m, n, k) = (data.m(), data.n(), cfg.base.k);
    cfg.validate(m)?;
    let solver =
        NativeSolver::with_kernel(cfg.base.lloyd, cfg.base.threads, cfg.base.kernel);
    let mut rng = Rng::new(cfg.base.seed);
    let mut counters = Counters::new();
    let mut timer = PhaseTimer::new();
    let mut incumbent = Solution::all_degenerate(k, n);
    let mut improvements = 0u64;
    let mut rung_chunks = vec![0u64; cfg.ladder.len()];
    let mut rung_improvements = vec![0u64; cfg.ladder.len()];
    let mut stop = StopState::new(cfg.base.stop);
    // One sampler per rung (reusable buffers).
    let mut samplers: Vec<ChunkSampler> = cfg
        .ladder
        .iter()
        .map(|&s| ChunkSampler::new(s.min(m), n))
        .collect();
    let mut rung = 0usize;

    data.advise(AccessPattern::Random);
    timer.time_init(|| {
        while !stop.should_stop() {
            let (chunk, rows) = samplers[rung].sample(data, &mut rng);
            let mut seed = incumbent.centroids.clone();
            reseed(
                &cfg.base,
                chunk,
                rows,
                n,
                k,
                &mut seed,
                &incumbent.degenerate,
                &mut rng,
                &mut counters,
            );
            let result = solver.lloyd(chunk, rows, n, k, &seed, &mut counters);
            counters.chunk_iterations += result.iters as u64;
            counters.chunks += 1;
            rung_chunks[rung] += 1;
            stop.record_chunk();
            // Acceptance must compare like with like: a k-centroid fit on a
            // small chunk over-fits (lower per-row SSE that doesn't
            // generalise). Candidates from lower rungs are therefore scored
            // on a fresh top-rung-size *validation* chunk; rung-0 results
            // already are top-rung chunks and keep their Lloyd objective.
            let score = if rung == 0 {
                result.objective
            } else {
                let (vchunk, vrows) = samplers[0].sample(data, &mut rng);
                let (_, mins) =
                    solver.assign(vchunk, vrows, n, k, &result.centroids, &mut counters);
                mins.iter().map(|&d| d as f64).sum()
            };
            if score < incumbent.objective {
                incumbent = Solution {
                    degenerate: degenerate_indices(&result.counts),
                    centroids: result.centroids,
                    objective: score,
                };
                improvements += 1;
                rung_improvements[rung] += 1;
                rung = 0; // improvement → back to the weakest shaking
            } else {
                rung = (rung + 1) % cfg.ladder.len(); // escalate shaking
            }
        }
    });

    // `incumbent.objective` holds the per-row score (see above); the final
    // pass recomputes the true full-dataset SSE.
    let inner = finish(
        &cfg.base,
        &solver,
        data,
        incumbent,
        improvements,
        counters,
        timer,
    );
    Ok(VnsResult { inner, rung_chunks, rung_improvements })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{ParallelMode, StopCondition};
    use crate::data::dataset::Dataset;
    use crate::data::synth::Synth;

    fn blobs(seed: u64) -> Dataset {
        Synth::GaussianMixture {
            m: 8_000,
            n: 4,
            k_true: 6,
            spread: 0.25,
            box_half_width: 20.0,
        }
        .generate("vns", seed)
    }

    fn base(chunks: u64) -> BigMeansConfig {
        BigMeansConfig::new(6, 1024)
            .with_stop(StopCondition::MaxChunks(chunks))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(11)
    }

    #[test]
    fn ladder_construction() {
        let cfg = VnsConfig::new(base(10));
        assert!(!cfg.ladder.is_empty());
        assert!(cfg.ladder.windows(2).all(|w| w[0] > w[1]));
        assert!(cfg.validate(8_000).is_ok());
    }

    #[test]
    fn invalid_ladders_rejected() {
        let mut cfg = VnsConfig::new(base(10));
        cfg.ladder = vec![];
        assert!(cfg.validate(8_000).is_err());
        cfg.ladder = vec![100, 200];
        assert!(cfg.validate(8_000).is_err());
        cfg.ladder = vec![100, 3];
        assert!(cfg.validate(8_000).is_err()); // smallest rung < k
    }

    #[test]
    fn vns_runs_and_spreads_over_rungs() {
        let data = blobs(1);
        let cfg = VnsConfig::new(base(40));
        let r = run_vns(&cfg, &data).unwrap();
        assert!(r.inner.objective.is_finite());
        assert_eq!(r.rung_chunks.iter().sum::<u64>(), 40);
        // With 40 chunks and frequent non-improvements, at least two rungs
        // must have been visited.
        assert!(r.rung_chunks.iter().filter(|&&c| c > 0).count() >= 2);
    }

    #[test]
    fn vns_quality_comparable_to_plain_bigmeans() {
        let data = blobs(2);
        let vns = run_vns(&VnsConfig::new(base(50)), &data).unwrap();
        let plain = crate::BigMeans::new(base(50)).run(&data).unwrap();
        // Same budget → same ballpark; VNS may win on multimodal data.
        assert!(
            vns.inner.objective <= plain.objective * 1.15,
            "vns {:.4e} vs plain {:.4e}",
            vns.inner.objective,
            plain.objective
        );
    }

    #[test]
    fn improvement_resets_to_top_rung() {
        // Indirect check via statistics: the top rung must process the
        // most chunks (every improvement resets to it).
        let data = blobs(3);
        let r = run_vns(&VnsConfig::new(base(60)), &data).unwrap();
        let max_rung = r
            .rung_chunks
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(i, _)| i)
            .unwrap();
        assert_eq!(max_rung, 0, "rung stats {:?}", r.rung_chunks);
    }
}
