//! Chunk sampling: uniform random samples from the dataset (the paper's
//! sampling method — O(s) per chunk, no pass over the full data, and the
//! reason Big-means is order-independent, §3).
//!
//! Sampling goes through [`DataSource`], so chunks can be gathered from an
//! in-memory matrix, an mmap'd `.bmx` file, or an indexed CSV without the
//! coordinator knowing the difference. The index sequence depends only on
//! the RNG, never on the backend — the out-of-core integration tests rely
//! on that to get bit-identical runs across backends.

use crate::data::source::DataSource;
use crate::util::rng::Rng;

/// Draws uniform chunks from a data source. Reusable buffer to keep the
/// chunk loop allocation-free after warmup.
pub struct ChunkSampler {
    chunk_size: usize,
    buf: Vec<f32>,
    indices: Vec<usize>,
}

impl ChunkSampler {
    pub fn new(chunk_size: usize, n: usize) -> Self {
        ChunkSampler {
            chunk_size,
            buf: Vec::with_capacity(chunk_size * n),
            indices: Vec::new(),
        }
    }

    /// Sample a chunk of `min(chunk_size, m)` distinct rows into the
    /// internal buffer; returns `(points, rows)`.
    pub fn sample<'a>(&'a mut self, data: &dyn DataSource, rng: &mut Rng) -> (&'a [f32], usize) {
        let m = data.m();
        let n = data.n();
        let s = self.chunk_size.min(m);
        self.indices = rng.sample_indices(m, s);
        self.buf.resize(s * n, 0.0);
        data.sample_rows(&self.indices, &mut self.buf[..s * n]);
        (&self.buf[..s * n], s)
    }

    /// Row indices of the most recent chunk.
    pub fn last_indices(&self) -> &[usize] {
        &self.indices
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;

    #[test]
    fn chunk_rows_come_from_dataset() {
        let d = Dataset::from_vec("t", (0..40).map(|x| x as f32).collect(), 10, 4);
        let mut s = ChunkSampler::new(4, 4);
        let mut rng = Rng::new(1);
        let (chunk, rows) = s.sample(&d, &mut rng);
        assert_eq!(rows, 4);
        let chunk = chunk.to_vec();
        for (slot, &i) in s.last_indices().iter().enumerate() {
            assert_eq!(
                &chunk[slot * 4..slot * 4 + 4],
                &d.points()[i * 4..i * 4 + 4]
            );
        }
    }

    #[test]
    fn chunk_clamped_to_m() {
        let d = Dataset::from_vec("t", vec![1.0; 12], 3, 4);
        let mut s = ChunkSampler::new(100, 4);
        let mut rng = Rng::new(2);
        let (_, rows) = s.sample(&d, &mut rng);
        assert_eq!(rows, 3);
    }

    #[test]
    fn chunks_vary_between_draws() {
        let d = Dataset::from_vec("t", (0..2000).map(|x| x as f32).collect(), 500, 4);
        let mut s = ChunkSampler::new(10, 4);
        let mut rng = Rng::new(3);
        let first: Vec<usize> = {
            s.sample(&d, &mut rng);
            s.last_indices().to_vec()
        };
        s.sample(&d, &mut rng);
        assert_ne!(first, s.last_indices());
    }

    #[test]
    fn index_sequence_is_backend_independent() {
        // Two sources with the same shape but different contents must draw
        // the same index sequence under the same seed: indices depend only
        // on the RNG.
        let a = Dataset::from_vec("a", vec![0.0; 2000], 500, 4);
        let b = Dataset::from_vec("b", vec![1.0; 2000], 500, 4);
        let mut sa = ChunkSampler::new(16, 4);
        let mut sb = ChunkSampler::new(16, 4);
        let mut ra = Rng::new(77);
        let mut rb = Rng::new(77);
        sa.sample(&a, &mut ra);
        sb.sample(&b, &mut rb);
        assert_eq!(sa.last_indices(), sb.last_indices());
    }
}
