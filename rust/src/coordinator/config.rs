//! Big-means configuration (Algorithm 3's knobs plus engine selection).

use std::time::Duration;

use crate::kernels::lloyd::LloydParams;

pub use crate::kernels::engine::KernelEngineKind;

/// How degenerate (empty) centroids are reinitialised between chunks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReinitStrategy {
    /// K-means++ D² seeding on the current chunk (the paper's choice).
    KmeansPP,
    /// Uniform random points from the chunk (ablation comparator).
    Random,
}

/// Which compute engine runs the chunk-local search.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Native rust kernels (any shape).
    Native,
    /// AOT-compiled HLO via PJRT (pads to the nearest artifact variant);
    /// falls back to native when no variant fits.
    Pjrt,
}

/// Stop condition for the global search phase.
#[derive(Clone, Copy, Debug)]
pub enum StopCondition {
    /// Wall-clock budget (paper's `cpu_max`).
    MaxTime(Duration),
    /// Maximum number of chunks (paper's alternative stop rule).
    MaxChunks(u64),
    /// Whichever of the two trips first.
    TimeOrChunks(Duration, u64),
}

pub use crate::data::source::DataBackend;

/// Parallelisation mode (paper §3, two strategies).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParallelMode {
    /// Sequential chunk loop; K-means/K-means++ internally parallel
    /// (strategy 1 — what the paper's experiments used).
    InnerParallel,
    /// Chunks processed concurrently by workers sharing the incumbent
    /// (strategy 2).
    ChunkParallel,
    /// Fully sequential (for deterministic tests and ablations).
    Sequential,
}

/// Full Big-means configuration.
#[derive(Clone, Debug)]
pub struct BigMeansConfig {
    /// Number of clusters `k`.
    pub k: usize,
    /// Chunk size `s` (must be ≤ m; clamped at runtime).
    pub chunk_size: usize,
    /// Stop condition for the search phase.
    pub stop: StopCondition,
    /// Lloyd convergence parameters for chunk-local search.
    pub lloyd: LloydParams,
    /// Degenerate-centroid reinitialisation strategy.
    pub reinit: ReinitStrategy,
    /// K-means++ candidate count per draw (paper uses 3).
    pub candidates: usize,
    /// Engine for the chunk-local search.
    pub engine: Engine,
    /// Kernel engine for native assignment steps (`panel` = exact blocked
    /// panel, `bounded` = Hamerly-pruned exact, `elkan` = per-centroid
    /// Elkan bounds + inter-centroid test; all label-identical results).
    pub kernel: KernelEngineKind,
    /// Parallelisation mode.
    pub parallel: ParallelMode,
    /// How dataset *files* are opened — consumed by
    /// [`crate::data::loader::open_source`] (the CLI passes
    /// `cfg.backend` there before running).
    pub backend: DataBackend,
    /// CSV offset-index stride for the buffered backend (1 = index every
    /// row). Larger strides shrink the in-RAM index by the same factor at
    /// the cost of scanning at most `index_stride − 1` rows past a seek;
    /// served values are identical. Consumed by
    /// [`crate::data::loader::open_source_with`].
    pub index_stride: usize,
    /// Worker threads (`InnerParallel`: kernel threads; `ChunkParallel`:
    /// concurrent chunks). 0 = machine default.
    pub threads: usize,
    /// RNG seed (chunks, seeding draws).
    pub seed: u64,
    /// Skip the final full-dataset assignment (paper §4.1 notes it is
    /// optional for some applications).
    pub skip_final_assignment: bool,
    /// Rescan-rate cutoff for the hybrid kernel engine's Hamerly→Elkan
    /// switch. `None` keeps the engine's built-in default (0.25);
    /// `--mode tune` with threshold arms learns a per-dataset value and
    /// records it in the `.bmm` meta so later runs can reuse it. Ignored
    /// by the other engines.
    pub hybrid_threshold: Option<f64>,
}

impl BigMeansConfig {
    /// Paper-default configuration for a given `k` and chunk size.
    pub fn new(k: usize, chunk_size: usize) -> Self {
        BigMeansConfig {
            k,
            chunk_size,
            stop: StopCondition::TimeOrChunks(Duration::from_secs(10), 10_000),
            lloyd: LloydParams::default(),
            reinit: ReinitStrategy::KmeansPP,
            candidates: 3,
            engine: Engine::Native,
            kernel: KernelEngineKind::Panel,
            parallel: ParallelMode::InnerParallel,
            backend: DataBackend::InMemory,
            index_stride: 1,
            threads: 0,
            seed: 0xB16_3EA5,
            skip_final_assignment: false,
            hybrid_threshold: None,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_stop(mut self, stop: StopCondition) -> Self {
        self.stop = stop;
        self
    }

    pub fn with_engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    pub fn with_kernel(mut self, kernel: KernelEngineKind) -> Self {
        self.kernel = kernel;
        self
    }

    pub fn with_parallel(mut self, mode: ParallelMode) -> Self {
        self.parallel = mode;
        self
    }

    pub fn with_backend(mut self, backend: DataBackend) -> Self {
        self.backend = backend;
        self
    }

    pub fn with_hybrid_threshold(mut self, threshold: Option<f64>) -> Self {
        self.hybrid_threshold = threshold;
        self
    }

    /// Concurrent workers this config asks for: `threads`, with 0 meaning
    /// the machine's logical-core count (shared by the chunk-parallel
    /// pipeline and the tuner race so both modes resolve `--threads`
    /// identically).
    pub fn worker_count(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4)
        } else {
            self.threads
        }
    }

    /// Validate against a dataset shape.
    pub fn validate(&self, m: usize, _n: usize) -> Result<(), String> {
        if self.k == 0 {
            return Err("k must be ≥ 1".into());
        }
        if self.chunk_size == 0 {
            return Err("chunk_size must be ≥ 1".into());
        }
        if self.k > self.chunk_size.min(m) {
            return Err(format!(
                "k={} exceeds min(chunk_size, m)={}",
                self.k,
                self.chunk_size.min(m)
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = BigMeansConfig::new(5, 4096);
        assert_eq!(c.candidates, 3);
        assert_eq!(c.reinit, ReinitStrategy::KmeansPP);
        assert_eq!(c.backend, DataBackend::InMemory);
        assert_eq!(c.index_stride, 1);
        assert_eq!(c.kernel, KernelEngineKind::Panel);
        assert!((c.lloyd.tol - 1e-4).abs() < 1e-12);
        assert_eq!(c.lloyd.max_iters, 300);
    }

    #[test]
    fn validation() {
        let c = BigMeansConfig::new(5, 4096);
        assert!(c.validate(10_000, 8).is_ok());
        assert!(c.validate(3, 8).is_err()); // k > m
        let bad = BigMeansConfig::new(0, 4096);
        assert!(bad.validate(100, 8).is_err());
        let bad2 = BigMeansConfig::new(10, 4);
        assert!(bad2.validate(100, 8).is_err()); // k > s
    }
}
