//! Layer 3: the Big-means coordinator — the paper's system contribution.
//!
//! * [`bigmeans`] — Algorithm 3, sequential chunk pipeline;
//! * [`parallel`] — chunk-parallel pipeline (paper's strategy 2), plus the
//!   reusable [`parallel::ShotExecutor`] the tuner races drive;
//! * [`stream`] — unbounded-stream variant with a backpressured queue;
//! * [`incumbent`] — "keep the best" state, shared-memory safe;
//! * [`sampler`] — uniform chunk sampling;
//! * [`solver`] — the engine abstraction (native kernels / PJRT);
//! * [`stop`] / [`config`] — stop rules and configuration.

pub mod bigmeans;
pub mod config;
pub mod incumbent;
pub mod parallel;
pub mod sampler;
pub mod solver;
pub mod stop;
pub mod stream;
pub mod vns;

pub use bigmeans::{BigMeans, BigMeansResult};
pub use config::{
    BigMeansConfig, DataBackend, Engine, ParallelMode, ReinitStrategy, StopCondition,
};
pub use parallel::{ShotExecutor, ShotReport};
pub use solver::{ChunkSolver, FinalPassMode, NativeSolver};
pub use stream::{
    produce_from_source, ChunkQueue, DriftAction, PublishFn, StreamChunk, StreamResult,
    StreamingBigMeans, ValidationPoint,
};
pub use vns::{run_vns, VnsConfig, VnsResult};
