//! Bandit controllers: the policies that decide which arm fires the next
//! shot.
//!
//! Both controllers share the same contract:
//!
//! * every arm is pulled at least once before any exploitation (unpulled
//!   arms are selected first, in id order — deterministic, and it seeds
//!   the statistics the policies need);
//! * rewards are the objective-improvement signal of
//!   [`improvement_reward`] — monotone in how much a shot lowered the
//!   validation objective, clamped to `[0, 1]`;
//! * a single-arm portfolio degenerates gracefully (the only arm is
//!   selected forever, no division by zero, no panic).
//!
//! `tests/property_tuner.rs` pins all three properties down.

use crate::util::rng::Rng;

/// Reward for moving the incumbent (validation) objective from `before`
/// to `after`: the relative improvement, clamped to `[0, 1]`.
///
/// * the first finite objective (from the all-degenerate start,
///   `before = ∞`) earns the full reward of 1;
/// * no improvement (or a non-finite result) earns 0;
/// * for a fixed `before`, the reward is monotone: a lower `after` never
///   earns less.
pub fn improvement_reward(before: f64, after: f64) -> f64 {
    if !after.is_finite() {
        return 0.0;
    }
    if !before.is_finite() {
        return 1.0;
    }
    if before <= 0.0 || after >= before {
        return 0.0;
    }
    ((before - after) / before).clamp(0.0, 1.0)
}

/// An online arm-selection policy. Implementations own their sufficient
/// statistics; the race records the full trace separately
/// ([`crate::metrics::bandit::TunerTrace`]).
pub trait BanditController: Send {
    /// Pick the arm for the next pull. `rng` is the controller's dedicated
    /// stream (UCB ignores it; softmax samples from it).
    fn select(&mut self, rng: &mut Rng) -> usize;

    /// Record the reward observed for `arm`.
    fn update(&mut self, arm: usize, reward: f64);

    /// Policy name (`ucb` / `softmax`).
    fn name(&self) -> &'static str;
}

#[derive(Clone, Copy, Debug, Default)]
struct ArmStats {
    pulls: u64,
    total_reward: f64,
}

impl ArmStats {
    fn mean(&self) -> f64 {
        if self.pulls == 0 {
            0.0
        } else {
            self.total_reward / self.pulls as f64
        }
    }
}

/// UCB1 (Auer et al.): pull the arm maximising
/// `mean + c·√(ln t / pulls)`. Deterministic — ties break to the lowest
/// arm id, so a single-worker race is bit-reproducible.
pub struct UcbController {
    exploration: f64,
    arms: Vec<ArmStats>,
    total_pulls: u64,
}

impl UcbController {
    /// `exploration` is the constant `c` (√2 is the textbook value; the
    /// default config uses 1.0, biasing slightly toward exploitation).
    pub fn new(num_arms: usize, exploration: f64) -> Self {
        assert!(num_arms >= 1, "UcbController needs at least one arm");
        UcbController {
            exploration: exploration.max(0.0),
            arms: vec![ArmStats::default(); num_arms],
            total_pulls: 0,
        }
    }
}

impl BanditController for UcbController {
    fn select(&mut self, _rng: &mut Rng) -> usize {
        if let Some(i) = self.arms.iter().position(|a| a.pulls == 0) {
            return i;
        }
        let t = self.total_pulls.max(1) as f64;
        let mut best = 0usize;
        let mut best_value = f64::NEG_INFINITY;
        for (i, a) in self.arms.iter().enumerate() {
            let bonus = self.exploration * (t.ln() / a.pulls as f64).sqrt();
            let value = a.mean() + bonus;
            if value > best_value {
                best_value = value;
                best = i;
            }
        }
        best
    }

    fn update(&mut self, arm: usize, reward: f64) {
        self.arms[arm].pulls += 1;
        self.arms[arm].total_reward += reward.max(0.0);
        self.total_pulls += 1;
    }

    fn name(&self) -> &'static str {
        "ucb"
    }
}

/// Boltzmann (softmax) selection: `P(i) ∝ exp(mean_i / τ)`. Low
/// temperatures exploit, high temperatures explore; the exponentials are
/// shifted by the max mean for numerical stability, so every weight is in
/// `(0, 1]` and the distribution is always proper.
pub struct SoftmaxController {
    temperature: f64,
    arms: Vec<ArmStats>,
}

impl SoftmaxController {
    pub fn new(num_arms: usize, temperature: f64) -> Self {
        assert!(num_arms >= 1, "SoftmaxController needs at least one arm");
        SoftmaxController {
            temperature: temperature.max(1e-6),
            arms: vec![ArmStats::default(); num_arms],
        }
    }
}

impl BanditController for SoftmaxController {
    fn select(&mut self, rng: &mut Rng) -> usize {
        if let Some(i) = self.arms.iter().position(|a| a.pulls == 0) {
            return i;
        }
        if self.arms.len() == 1 {
            return 0;
        }
        let hi = self.arms.iter().map(|a| a.mean()).fold(f64::NEG_INFINITY, f64::max);
        let weights: Vec<f64> =
            self.arms.iter().map(|a| ((a.mean() - hi) / self.temperature).exp()).collect();
        rng.weighted(&weights)
    }

    fn update(&mut self, arm: usize, reward: f64) {
        self.arms[arm].pulls += 1;
        self.arms[arm].total_reward += reward.max(0.0);
    }

    fn name(&self) -> &'static str {
        "softmax"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reward_shape() {
        assert_eq!(improvement_reward(f64::INFINITY, 10.0), 1.0);
        assert_eq!(improvement_reward(10.0, 10.0), 0.0);
        assert_eq!(improvement_reward(10.0, 12.0), 0.0);
        assert_eq!(improvement_reward(10.0, f64::NAN), 0.0);
        assert!((improvement_reward(10.0, 5.0) - 0.5).abs() < 1e-12);
        assert!((improvement_reward(10.0, 0.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ucb_prefers_the_better_arm() {
        let mut c = UcbController::new(2, 0.5);
        let mut rng = Rng::new(1);
        let mut pulls = [0u64; 2];
        for _ in 0..200 {
            let arm = c.select(&mut rng);
            pulls[arm] += 1;
            c.update(arm, if arm == 1 { 0.8 } else { 0.1 });
        }
        assert!(pulls[1] > pulls[0] * 2, "pulls: {pulls:?}");
    }

    #[test]
    fn softmax_prefers_the_better_arm() {
        let mut c = SoftmaxController::new(2, 0.05);
        let mut rng = Rng::new(2);
        let mut pulls = [0u64; 2];
        for _ in 0..200 {
            let arm = c.select(&mut rng);
            pulls[arm] += 1;
            c.update(arm, if arm == 0 { 0.9 } else { 0.2 });
        }
        assert!(pulls[0] > pulls[1] * 2, "pulls: {pulls:?}");
    }
}
