//! The race executor: competitor arms fire Big-means shots against one
//! shared incumbent, scheduled by a bandit controller.
//!
//! ```text
//! workers (shared ThreadPool)
//!    │  select arm (controller, under one lock — selection order is the
//!    │  recorded pull order)
//!    ▼
//! arm state (per-arm lock: RNG stream + ShotExecutor + counters)
//!    │  ShotExecutor::run_shot — snapshot → sample → reseed → local
//!    ▼  search → score on the common validation set → offer
//! SharedIncumbent (winning centroids propagate to *every* arm's next
//!    │  shot, exactly as the paper's parallel scheme propagates across
//!    ▼  workers)
//! controller.update(reward) + trace.record_pull
//! ```
//!
//! Shots are offered to the incumbent under their **validation** objective
//! (chunk objectives are incomparable across sample sizes), so "keep the
//! best" stays monotone on one common scale. With one worker the whole
//! race is deterministic: the controller lock serialises pulls, every arm
//! owns its dedicated RNG stream, and the ticket pool makes the shot
//! budget exact.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::coordinator::bigmeans::{finish, BigMeansResult};
use crate::coordinator::config::{BigMeansConfig, StopCondition};
use crate::coordinator::incumbent::{SharedIncumbent, Solution};
use crate::coordinator::parallel::{ShotExecutor, ShotScorer};
use crate::coordinator::solver::NativeSolver;
use crate::data::source::{AccessPattern, DataSource};
use crate::metrics::bandit::TunerTrace;
use crate::metrics::{Counters, PhaseTimer};
use crate::obs;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

use super::bandit::{improvement_reward, BanditController, SoftmaxController, UcbController};
use super::config::{arm_rng, controller_rng, validation_rng, ControllerKind, TunerConfig};
use super::portfolio::Portfolio;
use super::validation::ValidationSet;

/// Result of a tuned run.
#[derive(Clone, Debug)]
pub struct RaceResult {
    /// The usual Big-means result (final full-dataset pass included).
    /// `best_chunk_objective` holds the winning **validation** objective —
    /// the quantity the incumbent was selected by.
    pub result: BigMeansResult,
    /// Bandit telemetry: pull order, rewards, per-arm aggregates.
    pub trace: TunerTrace,
    /// Validation objective of the winning incumbent.
    pub validation_objective: f64,
    /// Chunk rows of the most-pulled arm (the tuner's answer to "what
    /// sample size should I have configured?").
    pub chosen_chunk_rows: usize,
    /// Hybrid switch threshold of the most-pulled arm (`None` when the
    /// winning arm carried no override). Recorded in the `.bmm` meta by
    /// `--mode tune --save-model` so later runs can reuse it.
    pub chosen_threshold: Option<f64>,
}

/// Per-arm mutable state: the dedicated RNG stream, the shot executor
/// (sampler buffers + solver), and the arm's work counters.
struct ArmState<'a> {
    rng: Rng,
    exec: ShotExecutor<'a>,
    counters: Counters,
}

/// Controller + trace under one lock: the selection order *is* the
/// recorded pull order.
struct Scheduler {
    controller: Box<dyn BanditController>,
    rng: Rng,
    trace: TunerTrace,
}

/// Per-arm observability handles (pure observers — never consulted by the
/// race, so they cannot perturb pull order or rewards).
struct ArmObs {
    label: String,
    pulls: obs::Counter,
    accepted: obs::Counter,
}

impl ArmObs {
    fn new(label: String) -> ArmObs {
        let m = obs::metrics();
        ArmObs {
            pulls: m.counter(
                "bigmeans_tuner_arm_pulls_total",
                "Bandit pulls (shots fired) per tuner arm",
                &[("arm", &label)],
            ),
            accepted: m.counter(
                "bigmeans_tuner_arm_accepted_total",
                "Accepted incumbent offers per tuner arm",
                &[("arm", &label)],
            ),
            label,
        }
    }
}

/// Run a competitive race over the portfolio. Shot budget / time budget
/// come from `cfg.stop` exactly as in the chunk-parallel pipeline.
pub fn run_race(
    cfg: &BigMeansConfig,
    tuner: &TunerConfig,
    data: &dyn DataSource,
) -> Result<RaceResult, String> {
    let (m, n, k) = (data.m(), data.n(), cfg.k);
    cfg.validate(m, n)?;
    let portfolio = Portfolio::build(cfg, tuner, m)?;
    let workers = cfg.worker_count();
    let max_shots = match cfg.stop {
        StopCondition::MaxChunks(c) => c,
        StopCondition::TimeOrChunks(_, c) => c,
        StopCondition::MaxTime(_) => u64::MAX,
    };
    let deadline = match cfg.stop {
        StopCondition::MaxTime(t) | StopCondition::TimeOrChunks(t, _) => {
            Some(Instant::now() + t)
        }
        StopCondition::MaxChunks(_) => None,
    };

    let mut timer = PhaseTimer::new();
    // Chunk sampling and the validation gather are scattered reads.
    data.advise(AccessPattern::Random);
    let validation = ValidationSet::sample(
        data,
        tuner.validation_rows,
        &mut validation_rng(cfg.seed),
        cfg.kernel,
    );

    let incumbent = SharedIncumbent::new(Solution::all_degenerate(k, n));
    let done = AtomicBool::new(false);
    let tickets = AtomicU64::new(0);
    let controller: Box<dyn BanditController> = match tuner.controller {
        ControllerKind::Ucb => {
            Box::new(UcbController::new(portfolio.len(), tuner.exploration))
        }
        ControllerKind::Softmax => {
            Box::new(SoftmaxController::new(portfolio.len(), tuner.temperature))
        }
    };
    let sched = Mutex::new(Scheduler {
        controller,
        rng: controller_rng(cfg.seed),
        trace: TunerTrace::new(tuner.controller.name(), portfolio.traces()),
    });
    let arm_states: Vec<Mutex<ArmState>> = portfolio
        .arms
        .iter()
        .map(|arm| {
            Mutex::new(ArmState {
                rng: arm_rng(cfg.seed, arm.id),
                exec: ShotExecutor::with_chunk_size_threshold(
                    cfg,
                    data,
                    arm.chunk_rows,
                    arm.kernel,
                    arm.threshold,
                ),
                counters: Counters::new(),
            })
        })
        .collect();
    let arm_obs: Vec<ArmObs> =
        portfolio.arms.iter().map(|arm| ArmObs::new(arm.label())).collect();
    let scorer = |centroids: &[f32], degenerate: &[usize], counters: &mut Counters| {
        validation.objective(centroids, degenerate, k, counters)
    };
    let scorer_ref: &ShotScorer = &scorer;

    // The shots of every arm run as rounds on one shared pool: each worker
    // loops select → shoot → update until the ticket pool (or the clock)
    // runs out. Panics propagate through `scope_run_all`.
    let pool = ThreadPool::new(workers);
    timer.time_init(|| {
        let jobs: Vec<_> = (0..workers)
            .map(|_| {
                || loop {
                    if done.load(Ordering::Relaxed) {
                        break;
                    }
                    if let Some(dl) = deadline {
                        if Instant::now() >= dl {
                            done.store(true, Ordering::Relaxed);
                            break;
                        }
                    }
                    if tickets.fetch_add(1, Ordering::Relaxed) >= max_shots {
                        break;
                    }
                    let arm_id = {
                        let mut s = sched.lock().unwrap();
                        let Scheduler { controller, rng, .. } = &mut *s;
                        controller.select(rng)
                    };
                    let obs_arm = &arm_obs[arm_id];
                    let tracer = obs::tracer();
                    let _pull_span = tracer
                        .enabled()
                        .then(|| tracer.span_dyn("tuner.pull", obs_arm.label.clone()));
                    let (report, before) = {
                        let mut st = arm_states[arm_id].lock().unwrap();
                        let before = incumbent.snapshot().objective;
                        let ArmState { rng, exec, counters } = &mut *st;
                        (exec.run_shot(&incumbent, rng, counters, Some(scorer_ref)), before)
                    };
                    obs_arm.pulls.inc();
                    if report.accepted {
                        obs_arm.accepted.inc();
                    }
                    // Reward only *accepted* offers: with several workers the
                    // `before` snapshot can go stale while a shot runs, and a
                    // rejected offer must not earn credit against it. At one
                    // worker this is identical to the unconditional reward
                    // (accepted ⟺ offered < before), keeping races
                    // bit-reproducible.
                    let reward = if report.accepted {
                        improvement_reward(before, report.offered_objective)
                    } else {
                        0.0
                    };
                    let mut s = sched.lock().unwrap();
                    s.controller.update(arm_id, reward);
                    s.trace.record_pull(arm_id, reward, report.accepted);
                }
            })
            .collect();
        pool.scope_run_all(jobs);
    });

    // Fold per-arm counters into the run totals and the telemetry.
    let mut counters = Counters::new();
    let mut sched = sched.into_inner().unwrap();
    for (i, st) in arm_states.into_iter().enumerate() {
        let st = st.into_inner().unwrap();
        sched.trace.arms[i].absorb_counters(&st.counters);
        counters.merge(&st.counters);
    }
    let trace = sched.trace;
    let improvements = trace.total_accepted();
    let best_arm = trace.best_arm();
    let chosen_chunk_rows =
        best_arm.map(|i| portfolio.arms[i].chunk_rows).unwrap_or(cfg.chunk_size.min(m));
    let chosen_threshold =
        best_arm.and_then(|i| portfolio.arms[i].threshold).or(cfg.hybrid_threshold);

    let snap = incumbent.snapshot();
    let validation_objective = snap.objective;
    let final_solution = Solution {
        centroids: snap.centroids.clone(),
        objective: snap.objective,
        degenerate: snap.degenerate.clone(),
    };
    let final_solver = NativeSolver::with_kernel_threshold(
        cfg.lloyd,
        cfg.threads,
        cfg.kernel,
        chosen_threshold.or(cfg.hybrid_threshold),
    );
    let result = finish(
        cfg,
        &final_solver,
        data,
        final_solution,
        improvements,
        counters,
        timer,
    );
    Ok(RaceResult { result, trace, validation_objective, chosen_chunk_rows, chosen_threshold })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::ParallelMode;
    use crate::data::synth::Synth;
    use crate::tuner::config::ArmSpec;

    fn blobs(m: usize, seed: u64) -> crate::data::dataset::Dataset {
        Synth::GaussianMixture {
            m,
            n: 4,
            k_true: 4,
            spread: 0.2,
            box_half_width: 25.0,
        }
        .generate("race", seed)
    }

    fn base_cfg(shots: u64) -> BigMeansConfig {
        let mut cfg = BigMeansConfig::new(4, 256)
            .with_stop(StopCondition::MaxChunks(shots))
            .with_parallel(ParallelMode::ChunkParallel)
            .with_seed(11);
        cfg.threads = 1;
        cfg
    }

    #[test]
    fn race_runs_and_accounts_every_shot() {
        let data = blobs(6000, 1);
        let tuner = TunerConfig::default()
            .with_arms(vec![ArmSpec::new(0.5), ArmSpec::new(1.0), ArmSpec::new(2.0)]);
        let r = run_race(&base_cfg(12), &tuner, &data).unwrap();
        assert_eq!(r.trace.total_pulls(), 12);
        assert_eq!(r.result.counters.chunks, 12);
        assert_eq!(r.trace.pull_sequence.len(), 12);
        assert!(r.result.objective.is_finite());
        assert!(r.validation_objective.is_finite());
        assert!(r.chosen_chunk_rows >= 4);
        // Every arm explored at least once before the budget ran out.
        assert!(r.trace.arms.iter().all(|a| a.pulls >= 1));
        // Per-arm pulls sum to the budget.
        let total: u64 = r.trace.arms.iter().map(|a| a.pulls).sum();
        assert_eq!(total, 12);
    }

    #[test]
    fn threshold_arms_race_and_record_the_winner() {
        use crate::kernels::engine::KernelEngineKind;
        let data = blobs(4000, 5);
        let hybrid = |t: f64| ArmSpec {
            kernel: Some(KernelEngineKind::Hybrid),
            threshold: Some(t),
            ..ArmSpec::new(1.0)
        };
        let tuner =
            TunerConfig::default().with_arms(vec![hybrid(0.05), hybrid(0.25), hybrid(1.0)]);
        let r = run_race(&base_cfg(9), &tuner, &data).unwrap();
        assert_eq!(r.trace.total_pulls(), 9);
        let t = r.chosen_threshold.expect("all arms carry a threshold");
        assert!([0.05, 0.25, 1.0].contains(&t));
        assert!(r.result.objective.is_finite());
        // Labels distinguish the arms.
        assert_eq!(r.trace.arms[0].label, "1x/hybrid@0.05");
    }

    #[test]
    fn single_arm_portfolio_degenerates_gracefully() {
        let data = blobs(3000, 2);
        for controller in [ControllerKind::Ucb, ControllerKind::Softmax] {
            let tuner = TunerConfig::default()
                .with_controller(controller)
                .with_arms(vec![ArmSpec::new(1.0)]);
            let r = run_race(&base_cfg(6), &tuner, &data).unwrap();
            assert_eq!(r.trace.arms.len(), 1);
            assert_eq!(r.trace.arms[0].pulls, 6);
            assert!(r.result.objective.is_finite());
        }
    }

    #[test]
    fn multi_worker_race_exhausts_ticket_pool() {
        let data = blobs(8000, 3);
        let mut cfg = base_cfg(16);
        cfg.threads = 4;
        let tuner = TunerConfig::default();
        let r = run_race(&cfg, &tuner, &data).unwrap();
        assert_eq!(r.result.counters.chunks, 16);
        assert_eq!(r.trace.total_pulls(), 16);
        assert!(r.result.objective.is_finite());
    }

    #[test]
    fn time_budget_stops_the_race() {
        use std::time::Duration;
        let data = blobs(4000, 4);
        let mut cfg = base_cfg(0);
        cfg.stop = StopCondition::MaxTime(Duration::from_millis(80));
        cfg.threads = 2;
        let t0 = Instant::now();
        let r = run_race(&cfg, &TunerConfig::default(), &data).unwrap();
        assert!(t0.elapsed() < Duration::from_secs(10));
        assert!(r.trace.total_pulls() >= 1);
    }
}
