//! The competitor portfolio: the concrete arms a race schedules.
//!
//! An arm is a *resolved* grid entry — multiplier applied to the base
//! chunk size, clamped to the dataset, kernel override resolved against
//! the run's configured engine. Resolution happens once, up front, so the
//! race and the telemetry agree on arm ids for the whole run.

use crate::coordinator::config::BigMeansConfig;
use crate::kernels::engine::KernelEngineKind;
use crate::metrics::bandit::ArmTrace;

use super::config::TunerConfig;

/// One competitor: a chunk size, a kernel engine, and (for hybrid arms)
/// an optional switch-threshold override.
#[derive(Clone, Debug, PartialEq)]
pub struct Arm {
    /// Index into the portfolio (stable for the whole race).
    pub id: usize,
    /// The grid multiplier that produced this arm.
    pub multiplier: f64,
    /// Rows per sampled chunk.
    pub chunk_rows: usize,
    /// Kernel engine running this arm's local search.
    pub kernel: KernelEngineKind,
    /// Hybrid Hamerly→Elkan switch threshold (`None` = the run's
    /// configured threshold, falling back to the engine default).
    pub threshold: Option<f64>,
}

impl Arm {
    /// Display label, e.g. `"0.5x/panel"` or `"1x/hybrid@0.1"`.
    pub fn label(&self) -> String {
        match self.threshold {
            Some(t) => format!("{}x/{}@{t}", self.multiplier, self.kernel.name()),
            None => format!("{}x/{}", self.multiplier, self.kernel.name()),
        }
    }

    /// Fresh telemetry slot for this arm.
    pub fn trace(&self) -> ArmTrace {
        ArmTrace {
            label: self.label(),
            chunk_rows: self.chunk_rows,
            kernel: self.kernel.name().to_string(),
            ..Default::default()
        }
    }
}

/// The resolved competitor set.
#[derive(Clone, Debug)]
pub struct Portfolio {
    pub arms: Vec<Arm>,
}

impl Portfolio {
    /// Resolve the grid against a dataset of `m` rows: scale, clamp to
    /// `[k, m]`, resolve kernel overrides, and collapse duplicates (two
    /// specs that clamp to the same `(rows, kernel, threshold)` triple
    /// would race identical competitors and only dilute the budget).
    pub fn build(
        cfg: &BigMeansConfig,
        tuner: &TunerConfig,
        m: usize,
    ) -> Result<Portfolio, String> {
        if tuner.arms.is_empty() {
            return Err("tuner: the arm grid is empty".into());
        }
        let m = m.max(1);
        let lo = cfg.k.max(1).min(m);
        let mut arms: Vec<Arm> = Vec::new();
        for spec in &tuner.arms {
            if !spec.multiplier.is_finite() || spec.multiplier <= 0.0 {
                return Err(format!(
                    "tuner: arm multiplier must be > 0, got {}",
                    spec.multiplier
                ));
            }
            let raw = (cfg.chunk_size as f64 * spec.multiplier).round() as usize;
            let rows = raw.clamp(lo, m);
            let kernel = spec.kernel.unwrap_or(cfg.kernel);
            let threshold = spec.threshold.or(cfg.hybrid_threshold);
            if arms.iter().any(|a| {
                a.chunk_rows == rows && a.kernel == kernel && a.threshold == threshold
            }) {
                continue;
            }
            arms.push(Arm {
                id: arms.len(),
                multiplier: spec.multiplier,
                chunk_rows: rows,
                kernel,
                threshold,
            });
        }
        Ok(Portfolio { arms })
    }

    pub fn len(&self) -> usize {
        self.arms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arms.is_empty()
    }

    /// Telemetry slots for every arm, in id order.
    pub fn traces(&self) -> Vec<ArmTrace> {
        self.arms.iter().map(|a| a.trace()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::config::ArmSpec;

    fn cfg(k: usize, s: usize) -> BigMeansConfig {
        BigMeansConfig::new(k, s)
    }

    #[test]
    fn arms_scale_and_clamp() {
        let tuner = TunerConfig::default().with_arms(vec![
            ArmSpec::new(0.001), // clamps up to k
            ArmSpec::new(0.5),
            ArmSpec::new(1.0),
            ArmSpec::new(1_000.0), // clamps down to m
        ]);
        let p = Portfolio::build(&cfg(5, 1000), &tuner, 10_000).unwrap();
        let rows: Vec<usize> = p.arms.iter().map(|a| a.chunk_rows).collect();
        assert_eq!(rows, vec![5, 500, 1000, 10_000]);
        assert_eq!(p.arms[1].label(), "0.5x/panel");
        assert!(p.arms.iter().enumerate().all(|(i, a)| a.id == i));
    }

    #[test]
    fn duplicate_arms_collapse() {
        // Everything clamps to m → one arm survives.
        let tuner = TunerConfig::default()
            .with_arms(vec![ArmSpec::new(10.0), ArmSpec::new(20.0), ArmSpec::new(30.0)]);
        let p = Portfolio::build(&cfg(3, 1000), &tuner, 2000).unwrap();
        assert_eq!(p.len(), 1);
        assert_eq!(p.arms[0].chunk_rows, 2000);
    }

    #[test]
    fn kernel_override_separates_otherwise_equal_arms() {
        let tuner = TunerConfig::default().with_arms(vec![
            ArmSpec { kernel: Some(KernelEngineKind::Panel), ..ArmSpec::new(1.0) },
            ArmSpec { kernel: Some(KernelEngineKind::Bounded), ..ArmSpec::new(1.0) },
            ArmSpec { kernel: Some(KernelEngineKind::Elkan), ..ArmSpec::new(1.0) },
        ]);
        let p = Portfolio::build(&cfg(3, 256), &tuner, 5000).unwrap();
        assert_eq!(p.len(), 3);
        assert_eq!(p.arms[0].kernel, KernelEngineKind::Panel);
        assert_eq!(p.arms[1].kernel, KernelEngineKind::Bounded);
        assert_eq!(p.arms[2].kernel, KernelEngineKind::Elkan);
        assert_eq!(p.arms[2].label(), "1x/elkan");
    }

    #[test]
    fn threshold_separates_otherwise_equal_arms() {
        let hybrid = |t: Option<f64>| ArmSpec {
            kernel: Some(KernelEngineKind::Hybrid),
            threshold: t,
            ..ArmSpec::new(1.0)
        };
        let tuner = TunerConfig::default().with_arms(vec![
            hybrid(Some(0.1)),
            hybrid(Some(0.5)),
            hybrid(Some(0.1)), // duplicate — collapses
            hybrid(None),
        ]);
        let p = Portfolio::build(&cfg(3, 256), &tuner, 5000).unwrap();
        assert_eq!(p.len(), 3);
        assert_eq!(p.arms[0].threshold, Some(0.1));
        assert_eq!(p.arms[0].label(), "1x/hybrid@0.1");
        assert_eq!(p.arms[1].threshold, Some(0.5));
        assert_eq!(p.arms[2].threshold, None);
        assert_eq!(p.arms[2].label(), "1x/hybrid");
        // A run-level threshold resolves `None` arms, merging them with an
        // explicit arm at the same value.
        let cfg_t = cfg(3, 256).with_hybrid_threshold(Some(0.5));
        let p = Portfolio::build(&cfg_t, &tuner, 5000).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p.arms[1].threshold, Some(0.5));
    }

    #[test]
    fn empty_grid_rejected() {
        let tuner = TunerConfig::default().with_arms(vec![]);
        assert!(Portfolio::build(&cfg(3, 256), &tuner, 1000).is_err());
    }
}
