//! Tuner configuration: the arm grid, the controller choice, and the
//! deterministic RNG stream layout.
//!
//! ## RNG stream layout
//!
//! A race draws randomness in three places — the validation reservoir, the
//! controller (softmax sampling), and each arm's chunk sampling / reseeding
//! — and every consumer gets its **own** stream derived from
//! `(BigMeansConfig::seed, salt, index)`:
//!
//! ```text
//! validation  ← stream(seed, SALT_VALIDATION, 0)
//! controller  ← stream(seed, SALT_CONTROLLER, 0)
//! arm i       ← stream(seed, SALT_ARM,        i)
//! ```
//!
//! Because an arm's draws never depend on when the controller pulls it,
//! a single-worker race is bit-reproducible, and adding an arm to the grid
//! leaves every other arm's chunk sequence untouched — the property the
//! determinism tests in `tests/integration_tuner.rs` pin down.

use crate::kernels::engine::KernelEngineKind;
use crate::util::rng::Rng;

/// Which bandit policy schedules the arms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ControllerKind {
    /// UCB1 with a tunable exploration constant.
    Ucb,
    /// Boltzmann (softmax) selection over mean rewards.
    Softmax,
}

impl ControllerKind {
    /// Parse a CLI token (`ucb` / `softmax`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "ucb" => Some(ControllerKind::Ucb),
            "softmax" => Some(ControllerKind::Softmax),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ControllerKind::Ucb => "ucb",
            ControllerKind::Softmax => "softmax",
        }
    }
}

/// One entry of the arm grid: a sample-size multiplier applied to the base
/// chunk size, plus optional kernel-engine and hybrid-switch-threshold
/// overrides.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ArmSpec {
    /// Chunk rows = `round(multiplier × BigMeansConfig::chunk_size)`,
    /// clamped to `[k, m]`.
    pub multiplier: f64,
    /// Kernel engine for this arm (`None` = the run's configured engine).
    pub kernel: Option<KernelEngineKind>,
    /// Hybrid Hamerly→Elkan switch threshold for this arm (`None` = the
    /// run's configured threshold, falling back to the engine default).
    /// Only meaningful with the hybrid kernel — the race prices a small
    /// threshold grid and records the winner in the model meta.
    pub threshold: Option<f64>,
}

impl ArmSpec {
    pub fn new(multiplier: f64) -> Self {
        ArmSpec { multiplier, kernel: None, threshold: None }
    }
}

/// Configuration of the competition layer.
#[derive(Clone, Debug)]
pub struct TunerConfig {
    /// Arm-selection policy.
    pub controller: ControllerKind,
    /// The competitor grid.
    pub arms: Vec<ArmSpec>,
    /// UCB exploration constant `c` (ignored by softmax).
    pub exploration: f64,
    /// Softmax temperature `τ` (ignored by UCB).
    pub temperature: f64,
    /// Rows in the reservoir-sampled validation set all arms are scored
    /// against (clamped to the dataset size).
    pub validation_rows: usize,
}

impl Default for TunerConfig {
    fn default() -> Self {
        TunerConfig {
            controller: ControllerKind::Ucb,
            arms: [0.25, 0.5, 1.0, 2.0, 4.0].iter().map(|&m| ArmSpec::new(m)).collect(),
            exploration: 1.0,
            temperature: 0.1,
            validation_rows: 4096,
        }
    }
}

impl TunerConfig {
    pub fn with_controller(mut self, controller: ControllerKind) -> Self {
        self.controller = controller;
        self
    }

    pub fn with_arms(mut self, arms: Vec<ArmSpec>) -> Self {
        self.arms = arms;
        self
    }

    /// Parse a CLI grid spec: comma-separated entries of `MULT`,
    /// `MULT:KERNEL`, or `MULT:KERNEL@THRESHOLD`, e.g. `0.25,0.5,1,2`,
    /// `1:panel,1:bounded,4`, or `1:hybrid@0.1,1:hybrid@0.5`. The `@T`
    /// suffix sets the hybrid Hamerly→Elkan switch threshold for that arm.
    pub fn parse_arms(spec: &str) -> Result<Vec<ArmSpec>, String> {
        let mut arms = Vec::new();
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (mult_text, kernel, threshold) = match entry.split_once(':') {
                None => (entry, None, None),
                Some((m, k)) => {
                    let (kernel_text, threshold) = match k.split_once('@') {
                        None => (k.trim(), None),
                        Some((kt, t)) => {
                            let value: f64 = t.trim().parse().map_err(|_| {
                                format!("--arms: bad threshold '{}' in '{entry}'", t.trim())
                            })?;
                            if !value.is_finite() || value < 0.0 {
                                return Err(format!(
                                    "--arms: threshold must be ≥ 0, got '{entry}'"
                                ));
                            }
                            (kt.trim(), Some(value))
                        }
                    };
                    let kind = KernelEngineKind::parse(kernel_text).ok_or_else(|| {
                        format!("--arms: unknown kernel '{kernel_text}' in '{entry}'")
                    })?;
                    (m.trim(), Some(kind), threshold)
                }
            };
            let mult_text = mult_text.strip_suffix('x').unwrap_or(mult_text);
            let multiplier: f64 = mult_text
                .parse()
                .map_err(|_| format!("--arms: bad multiplier '{entry}'"))?;
            if !multiplier.is_finite() || multiplier <= 0.0 {
                return Err(format!("--arms: multiplier must be > 0, got '{entry}'"));
            }
            arms.push(ArmSpec { multiplier, kernel, threshold });
        }
        if arms.is_empty() {
            return Err("--arms: empty grid".into());
        }
        Ok(arms)
    }
}

const SALT_VALIDATION: u64 = 0x7475_6E65_5641_4C30; // "tuneVAL0"
const SALT_CONTROLLER: u64 = 0x7475_6E65_4354_524C; // "tuneCTRL"
const SALT_ARM: u64 = 0x7475_6E65_4152_4D30; // "tuneARM0"

/// Derive the stream for `(seed, salt, index)`. `Rng::new` splitmixes the
/// input, so a simple odd-multiplier mix is enough to separate streams.
fn stream(seed: u64, salt: u64, index: u64) -> Rng {
    Rng::new(
        seed ^ salt.rotate_left(17)
            ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(31),
    )
}

/// Stream that samples the validation reservoir.
pub fn validation_rng(seed: u64) -> Rng {
    stream(seed, SALT_VALIDATION, 0)
}

/// Stream the controller uses for stochastic selection (softmax).
pub fn controller_rng(seed: u64) -> Rng {
    stream(seed, SALT_CONTROLLER, 0)
}

/// Stream arm `arm` uses for chunk sampling and reseeding.
pub fn arm_rng(seed: u64, arm: usize) -> Rng {
    stream(seed, SALT_ARM, arm as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_arms_grid() {
        let arms = TunerConfig::parse_arms("0.25, 0.5x ,1:bounded,2:panel,1:elkan").unwrap();
        assert_eq!(arms.len(), 5);
        assert_eq!(arms[0], ArmSpec::new(0.25));
        assert_eq!(arms[1], ArmSpec::new(0.5));
        assert_eq!(arms[2].kernel, Some(KernelEngineKind::Bounded));
        assert_eq!(arms[3].kernel, Some(KernelEngineKind::Panel));
        assert_eq!(arms[4].kernel, Some(KernelEngineKind::Elkan));
        assert!(arms.iter().all(|a| a.threshold.is_none()));
    }

    #[test]
    fn parse_arms_threshold_suffix() {
        let arms =
            TunerConfig::parse_arms("1:hybrid@0.1, 2x:hybrid@0.5 ,1:hybrid,0.5").unwrap();
        assert_eq!(arms.len(), 4);
        assert_eq!(arms[0].kernel, Some(KernelEngineKind::Hybrid));
        assert_eq!(arms[0].threshold, Some(0.1));
        assert_eq!(arms[1].multiplier, 2.0);
        assert_eq!(arms[1].threshold, Some(0.5));
        assert_eq!(arms[2].threshold, None);
        assert_eq!(arms[3], ArmSpec::new(0.5));
        // Zero is a valid threshold (switch on any rescan at all).
        let zero = TunerConfig::parse_arms("1:hybrid@0").unwrap();
        assert_eq!(zero[0].threshold, Some(0.0));
    }

    #[test]
    fn parse_arms_rejects_garbage() {
        assert!(TunerConfig::parse_arms("").is_err());
        assert!(TunerConfig::parse_arms("abc").is_err());
        assert!(TunerConfig::parse_arms("-1").is_err());
        assert!(TunerConfig::parse_arms("0").is_err());
        assert!(TunerConfig::parse_arms("1:warp").is_err());
        assert!(TunerConfig::parse_arms("1:hybrid@").is_err());
        assert!(TunerConfig::parse_arms("1:hybrid@nan").is_err());
        assert!(TunerConfig::parse_arms("1:hybrid@-0.5").is_err());
    }

    #[test]
    fn controller_kind_parses() {
        assert_eq!(ControllerKind::parse("ucb"), Some(ControllerKind::Ucb));
        assert_eq!(ControllerKind::parse("softmax"), Some(ControllerKind::Softmax));
        assert!(ControllerKind::parse("greedy").is_none());
        assert_eq!(ControllerKind::Ucb.name(), "ucb");
    }

    #[test]
    fn streams_are_distinct_and_reproducible() {
        let mut a0 = arm_rng(42, 0);
        let mut a0b = arm_rng(42, 0);
        let mut a1 = arm_rng(42, 1);
        let mut v = validation_rng(42);
        let mut c = controller_rng(42);
        for _ in 0..16 {
            assert_eq!(a0.next_u64(), a0b.next_u64());
        }
        let mut a0 = arm_rng(42, 0);
        let same_arm = (0..64).filter(|_| a0.next_u64() == a1.next_u64()).count();
        assert_eq!(same_arm, 0);
        let same_vc = (0..64).filter(|_| v.next_u64() == c.next_u64()).count();
        assert_eq!(same_vc, 0);
    }
}
