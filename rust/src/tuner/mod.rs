//! The competitive portfolio tuner: online sample-size optimisation across
//! racing Big-means workers.
//!
//! The paper leaves the sample size `s` as a hand-tuned hyperparameter;
//! its follow-up (*Superior Parallel Big Data Clustering through
//! Competitive Stochastic Sample Size Optimization in Big-means*, arXiv
//! 2403.18766) shows that letting parallel workers **compete** over
//! stochastically varied sample sizes dominates any fixed choice. This
//! subsystem is that competition layer — it sits above the coordinators
//! and below the CLI:
//!
//! ```text
//! CLI --mode tune (--tuner ucb|softmax, --arms grid)
//!         │
//! tuner::race::run_race            — the competition loop
//!         │        ├─ tuner::portfolio::Portfolio   (arms: s-multiplier × engine)
//!         │        ├─ tuner::bandit::BanditController (ucb / softmax)
//!         │        └─ tuner::validation::ValidationSet (common reservoir objective)
//!         ▼
//! coordinator::parallel::ShotExecutor — one Big-means shot per pull
//!         ▼
//! kernels (panel | bounded engines)  +  DataSource backends
//! ```
//!
//! Every shot is scored on one shared reservoir-sampled validation set
//! (chunk objectives are incomparable across sample sizes) and winning
//! centroids feed a [`SharedIncumbent`](crate::coordinator::incumbent) —
//! so arms cooperate on the solution while competing for the budget.
//! Determinism: single-worker races are bit-reproducible thanks to the
//! per-arm RNG stream layout in [`config`].

pub mod bandit;
pub mod config;
pub mod portfolio;
pub mod race;
pub mod validation;

pub use bandit::{improvement_reward, BanditController, SoftmaxController, UcbController};
pub use config::{ArmSpec, ControllerKind, TunerConfig};
pub use portfolio::{Arm, Portfolio};
pub use race::{run_race, RaceResult};
pub use validation::{Reservoir, ValidationSet};
