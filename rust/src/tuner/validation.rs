//! The common validation objective arms are scored against.
//!
//! Chunk objectives are not comparable across sample sizes (a bigger chunk
//! means a bigger SSE), so the race prices every shot on one shared,
//! reservoir-sampled validation set instead — the drift-aware scoring idea
//! of the Big-means streaming follow-up (arXiv 2410.14548). Two entry
//! points share the scoring kernel:
//!
//! * [`ValidationSet`] — a fixed sample drawn once from a [`DataSource`]
//!   (the tuner race: the dataset size is known, so a uniform
//!   without-replacement draw *is* the reservoir);
//! * [`Reservoir`] — Algorithm R over rows whose total count is unknown
//!   (the streaming drift check in [`crate::coordinator::stream`]).
//!
//! Scoring accumulates squared distances in f64, in row order, through a
//! [`KernelEngine`](crate::kernels::KernelEngine) — so a fixed seed gives
//! a bit-reproducible score regardless of data backend, matching the
//! determinism contract of the rest of the system.

use crate::data::source::DataSource;
use crate::kernels::engine::KernelEngineKind;
use crate::metrics::Counters;
use crate::util::rng::Rng;

/// Where degenerate centroid slots are parked before scoring (mirrors the
/// final-pass parking in the coordinator's `finish`): far enough that no
/// real point ever picks them. Public so the streaming drift remediation
/// can park the same way before ranking centroids on the reservoir.
pub const DEGENERATE_PAD: f32 = 1.0e15;

/// SSE of `centroids` on `points`, with degenerate slots parked out of the
/// way first. The shared scoring kernel of both validation flavours.
#[allow(clippy::too_many_arguments)]
fn score_points(
    points: &[f32],
    rows: usize,
    n: usize,
    k: usize,
    centroids: &[f32],
    degenerate: &[usize],
    kernel: KernelEngineKind,
    counters: &mut Counters,
) -> f64 {
    debug_assert_eq!(centroids.len(), k * n);
    let mut parked = centroids.to_vec();
    for &j in degenerate {
        for v in &mut parked[j * n..(j + 1) * n] {
            *v = DEGENERATE_PAD;
        }
    }
    let engine = kernel.build();
    let (_labels, mins) = engine.assign_once(points, &parked, rows, n, k, counters);
    mins.iter().map(|&d| d as f64).sum()
}

/// A fixed validation sample with a common scoring objective.
pub struct ValidationSet {
    points: Vec<f32>,
    rows: usize,
    n: usize,
    kernel: KernelEngineKind,
}

impl ValidationSet {
    /// Draw `rows` distinct rows uniformly from `data` (clamped to the
    /// dataset). Indices are sorted before the gather for locality on
    /// out-of-core sources; the drawn *set* depends only on the RNG, so a
    /// fixed seed yields the same sample on every backend.
    pub fn sample(
        data: &dyn DataSource,
        rows: usize,
        rng: &mut Rng,
        kernel: KernelEngineKind,
    ) -> ValidationSet {
        let (m, n) = (data.m(), data.n());
        let take = rows.min(m).max(1);
        let mut idx = rng.sample_indices(m, take);
        idx.sort_unstable();
        let mut points = vec![0f32; take * n];
        data.sample_rows(&idx, &mut points);
        ValidationSet { points, rows: take, n, kernel }
    }

    /// Wrap an already-materialised sample (tests, streaming snapshots).
    pub fn from_rows(points: Vec<f32>, rows: usize, n: usize, kernel: KernelEngineKind) -> Self {
        assert_eq!(points.len(), rows * n, "validation: points shape");
        assert!(rows > 0, "validation: empty sample");
        ValidationSet { points, rows, n, kernel }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Validation SSE of `centroids` (`k × n`, `degenerate` slots parked).
    pub fn objective(
        &self,
        centroids: &[f32],
        degenerate: &[usize],
        k: usize,
        counters: &mut Counters,
    ) -> f64 {
        score_points(
            &self.points,
            self.rows,
            self.n,
            k,
            centroids,
            degenerate,
            self.kernel,
            counters,
        )
    }
}

/// Fixed-capacity uniform sample over a row stream of unknown length
/// (Vitter's Algorithm R): after `seen` rows, every row is resident with
/// probability `cap / seen`.
pub struct Reservoir {
    n: usize,
    cap: usize,
    seen: u64,
    points: Vec<f32>,
    rng: Rng,
}

impl Reservoir {
    pub fn new(cap: usize, n: usize, rng: Rng) -> Self {
        let cap = cap.max(1);
        Reservoir { n, cap, seen: 0, points: Vec::with_capacity(cap * n), rng }
    }

    /// Offer `rows` row-major rows to the reservoir.
    pub fn observe_rows(&mut self, points: &[f32], rows: usize) {
        debug_assert_eq!(points.len(), rows * self.n);
        for r in 0..rows {
            let row = &points[r * self.n..(r + 1) * self.n];
            self.seen += 1;
            if self.points.len() < self.cap * self.n {
                self.points.extend_from_slice(row);
            } else {
                let j = self.rng.usize(self.seen as usize);
                if j < self.cap {
                    self.points[j * self.n..(j + 1) * self.n].copy_from_slice(row);
                }
            }
        }
    }

    /// Rows currently resident.
    pub fn len(&self) -> usize {
        self.points.len() / self.n
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Total rows offered so far.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// The resident sample, row-major (`len() × n`). Streaming drift
    /// remediation draws replacement centroids from exactly this sample.
    pub fn points(&self) -> &[f32] {
        &self.points
    }

    /// Validation SSE of `centroids` on the current reservoir contents.
    pub fn objective(
        &self,
        centroids: &[f32],
        degenerate: &[usize],
        k: usize,
        kernel: KernelEngineKind,
        counters: &mut Counters,
    ) -> f64 {
        assert!(!self.is_empty(), "reservoir: objective of an empty sample");
        score_points(
            &self.points,
            self.len(),
            self.n,
            k,
            centroids,
            degenerate,
            kernel,
            counters,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;

    fn toy(m: usize, n: usize) -> Dataset {
        Dataset::from_vec("t", (0..m * n).map(|x| x as f32).collect(), m, n)
    }

    #[test]
    fn sample_is_deterministic_and_clamped() {
        let d = toy(100, 3);
        let a = ValidationSet::sample(&d, 16, &mut Rng::new(7), KernelEngineKind::Panel);
        let b = ValidationSet::sample(&d, 16, &mut Rng::new(7), KernelEngineKind::Panel);
        assert_eq!(a.rows(), 16);
        assert_eq!(a.points, b.points);
        let big = ValidationSet::sample(&d, 10_000, &mut Rng::new(7), KernelEngineKind::Panel);
        assert_eq!(big.rows(), 100);
    }

    #[test]
    fn objective_prices_centroids_and_parks_degenerates() {
        // Two clusters at 0 and 10 in 1-D; centroid 1 degenerate.
        let v = ValidationSet::from_rows(
            vec![0.0, 0.0, 10.0, 10.0],
            4,
            1,
            KernelEngineKind::Panel,
        );
        let mut c = Counters::new();
        // Both centroids live: perfect fit.
        let exact = v.objective(&[0.0, 10.0], &[], 2, &mut c);
        assert_eq!(exact, 0.0);
        // Second slot degenerate (parked): everything maps to centroid 0.
        let parked = v.objective(&[0.0, 10.0], &[1], 2, &mut c);
        assert_eq!(parked, 200.0);
        assert!(c.distance_evals > 0);
    }

    #[test]
    fn engines_score_identically() {
        let d = toy(256, 4);
        let mut counters = Counters::new();
        let pan = ValidationSet::sample(&d, 64, &mut Rng::new(3), KernelEngineKind::Panel);
        let bnd = ValidationSet::sample(&d, 64, &mut Rng::new(3), KernelEngineKind::Bounded);
        let cents: Vec<f32> = (0..12).map(|x| x as f32 * 10.0).collect();
        let a = pan.objective(&cents, &[], 3, &mut counters);
        let b = bnd.objective(&cents, &[], 3, &mut counters);
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn reservoir_fills_then_keeps_uniform_size() {
        let mut r = Reservoir::new(8, 2, Rng::new(9));
        assert!(r.is_empty());
        let chunk: Vec<f32> = (0..40).map(|x| x as f32).collect();
        r.observe_rows(&chunk, 20);
        assert_eq!(r.len(), 8);
        assert_eq!(r.seen(), 20);
        r.observe_rows(&chunk, 20);
        assert_eq!(r.len(), 8);
        assert_eq!(r.seen(), 40);
        let mut c = Counters::new();
        let obj = r.objective(&[0.0, 0.0], &[], 1, KernelEngineKind::Panel, &mut c);
        assert!(obj.is_finite() && obj > 0.0);
    }

    #[test]
    fn reservoir_is_seed_deterministic() {
        let chunk: Vec<f32> = (0..300).map(|x| (x % 17) as f32).collect();
        let run = || {
            let mut r = Reservoir::new(10, 3, Rng::new(4));
            r.observe_rows(&chunk, 100);
            r.points.clone()
        };
        assert_eq!(run(), run());
    }
}
