//! Ward's agglomerative hierarchical clustering (paper §5.5).
//!
//! Exact Lance–Williams implementation with the Ward minimum-variance
//! linkage: start from singletons, repeatedly merge the pair with minimal
//!
//! `d(A,B) = |A||B| / (|A|+|B|) · ‖c_A − c_B‖²`
//!
//! O(m²) memory and O(m³)-ish time via a nearest-neighbour array with lazy
//! repair. Exactly like the paper's runs, datasets whose distance matrix
//! exceeds the memory cap fail with `OutOfMemory` and score "—" in the
//! tables.

use crate::baselines::common::{AlgoFailure, AlgoResult, MsscAlgorithm};
use crate::data::dataset::Dataset;
use crate::kernels;
use crate::metrics::{Counters, PhaseTimer};

/// Ward's method with a memory cap mimicking the paper's 504 GB box scaled
/// to this harness (default 512 MiB for the n² f32 matrix ≈ m ≤ ~11,500).
pub struct Wards {
    pub memory_cap_bytes: u64,
}

impl Default for Wards {
    fn default() -> Self {
        Wards { memory_cap_bytes: 512 << 20 }
    }
}

impl MsscAlgorithm for Wards {
    fn name(&self) -> &'static str {
        "Ward's"
    }

    fn run(&self, data: &Dataset, k: usize, _seed: u64) -> Result<AlgoResult, AlgoFailure> {
        let (m, n) = (data.m(), data.n());
        if k == 0 || k > m {
            return Err(AlgoFailure::Invalid(format!("k={k} out of range for m={m}")));
        }
        let required = (m as u64) * (m as u64) * 4;
        if required > self.memory_cap_bytes {
            return Err(AlgoFailure::OutOfMemory {
                required_bytes: required,
                cap_bytes: self.memory_cap_bytes,
            });
        }
        let mut counters = Counters::new();
        let mut timer = PhaseTimer::new();
        let points = data.points();

        // Ward runs entirely in the "init" phase (deterministic,
        // hierarchical); the "full" phase is just centroid extraction.
        let (centroids, objective) = timer.time_init(|| {
            // Active cluster state.
            let mut size = vec![1f64; m];
            let mut centroid: Vec<f64> = points.iter().map(|&x| x as f64).collect();
            let mut alive = vec![true; m];

            // Dense Ward-distance matrix (upper use only, kept square for
            // simple indexing).
            let mut dist = vec![0f32; m * m];
            for i in 0..m {
                for j in (i + 1)..m {
                    let d = ward_dist(
                        &centroid[i * n..(i + 1) * n],
                        &centroid[j * n..(j + 1) * n],
                        1.0,
                        1.0,
                    );
                    dist[i * m + j] = d as f32;
                    dist[j * m + i] = d as f32;
                }
            }
            counters.add_distance_evals((m * (m - 1) / 2) as u64);

            // Nearest-neighbour cache per cluster.
            let mut nn = vec![usize::MAX; m];
            for i in 0..m {
                nn[i] = nearest_alive(&dist, &alive, m, i);
            }

            let mut remaining = m;
            while remaining > k {
                // Find the globally closest pair via the NN cache.
                let mut bi = usize::MAX;
                let mut bd = f32::INFINITY;
                for i in 0..m {
                    if alive[i] && nn[i] != usize::MAX {
                        let d = dist[i * m + nn[i]];
                        if d < bd {
                            bd = d;
                            bi = i;
                        }
                    }
                }
                let a = bi;
                let b = nn[bi];
                debug_assert!(alive[a] && alive[b]);

                // Merge b into a: new centroid + Lance-Williams update.
                let (sa, sb) = (size[a], size[b]);
                let st = sa + sb;
                for d in 0..n {
                    let ca = centroid[a * n + d];
                    let cb = centroid[b * n + d];
                    centroid[a * n + d] = (sa * ca + sb * cb) / st;
                }
                size[a] = st;
                alive[b] = false;
                remaining -= 1;

                // Recompute Ward distance from the merged cluster to all
                // alive clusters (Lance–Williams for Ward reduces to the
                // centroid formula since we track centroids directly).
                for j in 0..m {
                    if alive[j] && j != a {
                        let d = ward_dist(
                            &centroid[a * n..(a + 1) * n],
                            &centroid[j * n..(j + 1) * n],
                            size[a],
                            size[j],
                        ) as f32;
                        dist[a * m + j] = d;
                        dist[j * m + a] = d;
                    }
                }
                counters.add_distance_evals(remaining as u64);

                // Repair NN caches touching a or b.
                for i in 0..m {
                    if alive[i] && (nn[i] == a || nn[i] == b || i == a) {
                        nn[i] = nearest_alive(&dist, &alive, m, i);
                    }
                }
            }

            let mut centroids = Vec::with_capacity(k * n);
            for i in 0..m {
                if alive[i] {
                    centroids.extend(centroid[i * n..(i + 1) * n].iter().map(|&x| x as f32));
                }
            }
            let obj = kernels::objective(points, &centroids, m, n, k, &mut counters);
            (centroids, obj)
        });

        Ok(AlgoResult {
            centroids,
            objective,
            cpu_init_secs: timer.init_secs(),
            cpu_full_secs: timer.full_secs(),
            counters,
        })
    }
}

/// Ward linkage distance between clusters with given centroids and sizes.
fn ward_dist(ca: &[f64], cb: &[f64], sa: f64, sb: f64) -> f64 {
    let mut d2 = 0f64;
    for (a, b) in ca.iter().zip(cb) {
        let d = a - b;
        d2 += d * d;
    }
    sa * sb / (sa + sb) * d2
}

fn nearest_alive(dist: &[f32], alive: &[bool], m: usize, i: usize) -> usize {
    let mut best = usize::MAX;
    let mut bd = f32::INFINITY;
    for j in 0..m {
        if j != i && alive[j] {
            let d = dist[i * m + j];
            if d < bd {
                bd = d;
                best = j;
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Synth;

    #[test]
    fn merges_blobs_correctly() {
        // 3 well-separated blobs of 20 points → Ward at k=3 must put each
        // centroid inside a blob.
        let data = Synth::GaussianMixture {
            m: 60,
            n: 2,
            k_true: 3,
            spread: 0.05,
            box_half_width: 30.0,
        }
        .generate("t", 5);
        let r = Wards::default().run(&data, 3, 0).unwrap();
        // Every point should be within ~1.0 of its centroid.
        let mut c = Counters::new();
        let (_, mins) = kernels::assign_only(data.points(), &r.centroids, 60, 2, 3, &mut c);
        assert!(mins.iter().all(|&d| d < 1.0), "loose centroid: {:?}", r.centroids);
    }

    #[test]
    fn deterministic() {
        let data = Synth::GaussianMixture {
            m: 40,
            n: 3,
            k_true: 2,
            spread: 0.3,
            box_half_width: 10.0,
        }
        .generate("t", 6);
        let a = Wards::default().run(&data, 2, 1).unwrap();
        let b = Wards::default().run(&data, 2, 999).unwrap();
        assert_eq!(a.centroids, b.centroids, "Ward must ignore the seed");
    }

    #[test]
    fn memory_cap_enforced_like_paper_dashes() {
        let data = Dataset::from_vec("big", vec![0.0; 4000 * 2], 4000, 2);
        let w = Wards { memory_cap_bytes: 1 << 20 }; // 1 MiB cap
        match w.run(&data, 2, 0) {
            Err(AlgoFailure::OutOfMemory { required_bytes, .. }) => {
                assert_eq!(required_bytes, 4000 * 4000 * 4);
            }
            other => panic!("expected OOM, got {other:?}"),
        }
    }

    #[test]
    fn k_equals_m_returns_points() {
        let data = Dataset::from_vec("t", vec![0.0, 0.0, 5.0, 5.0], 2, 2);
        let r = Wards::default().run(&data, 2, 0).unwrap();
        assert_eq!(r.objective, 0.0);
    }
}
