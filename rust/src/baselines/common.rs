//! Shared interface for the competitive MSSC algorithms of paper §5.
//!
//! Every baseline (and Big-means itself, via an adapter in the bench
//! harness) exposes the same `run(dataset, k, seed)` entry point and
//! reports the same result record, so the evaluation tables can be
//! generated uniformly.

use crate::data::dataset::Dataset;
use crate::metrics::Counters;

/// Outcome of one algorithm execution.
#[derive(Clone, Debug)]
pub struct AlgoResult {
    /// Final centroids `(k × n)`.
    pub centroids: Vec<f32>,
    /// Full-dataset MSSC objective of those centroids.
    pub objective: f64,
    /// `cpu_init`: initialization / search phase seconds.
    pub cpu_init_secs: f64,
    /// `cpu_full`: full-dataset clustering phase seconds.
    pub cpu_full_secs: f64,
    /// Work counters (`n_d`, `n_full`, …).
    pub counters: Counters,
}

impl AlgoResult {
    pub fn cpu_total_secs(&self) -> f64 {
        self.cpu_init_secs + self.cpu_full_secs
    }
}

/// Why an algorithm produced no result on a dataset (the paper's "—"
/// entries, scored 0).
#[derive(Clone, Debug, PartialEq)]
pub enum AlgoFailure {
    /// Estimated memory exceeds the configured cap (Ward's on large m).
    OutOfMemory { required_bytes: u64, cap_bytes: u64 },
    /// Estimated/observed runtime exceeds the harness budget (LMBM on
    /// huge sets).
    OverTimeBudget { budget_secs: f64 },
    /// Configuration invalid for this dataset (k > m, …).
    Invalid(String),
}

impl std::fmt::Display for AlgoFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AlgoFailure::OutOfMemory { required_bytes, cap_bytes } => write!(
                f,
                "out of memory: needs {required_bytes} bytes (cap {cap_bytes})"
            ),
            AlgoFailure::OverTimeBudget { budget_secs } => {
                write!(f, "over time budget ({budget_secs}s)")
            }
            AlgoFailure::Invalid(msg) => write!(f, "invalid: {msg}"),
        }
    }
}

/// Uniform interface over the §5 algorithms.
pub trait MsscAlgorithm {
    /// Algorithm display name as it appears in the paper's tables.
    fn name(&self) -> &'static str;

    /// Cluster `data` into `k` clusters. `seed` controls all randomness.
    fn run(&self, data: &Dataset, k: usize, seed: u64) -> Result<AlgoResult, AlgoFailure>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failure_display() {
        let f = AlgoFailure::OutOfMemory { required_bytes: 100, cap_bytes: 10 };
        assert!(f.to_string().contains("out of memory"));
        assert!(AlgoFailure::OverTimeBudget { budget_secs: 1.0 }
            .to_string()
            .contains("budget"));
    }

    #[test]
    fn result_totals() {
        let r = AlgoResult {
            centroids: vec![],
            objective: 1.0,
            cpu_init_secs: 0.25,
            cpu_full_secs: 0.5,
            counters: Counters::new(),
        };
        assert!((r.cpu_total_secs() - 0.75).abs() < 1e-12);
    }
}
