//! LMBM-Clust (paper §5.6): clustering via the nonsmooth optimization
//! formulation, after Karmitsa, Bagirov & Taheri (Pattern Recognition 2018).
//!
//! Works on the nonsmooth objective (paper eq. 11)
//!
//! `f_k(c_1,…,c_k) = (1/m) Σ_x min_j ‖c_j − x‖²`
//!
//! with the incremental seeding of Ordin & Bagirov (eq. 12): solve the
//! (k−1)-problem, then seed centroid k by optimising the auxiliary
//! problem `f̄_k(y) = (1/m) Σ_x min(r_{k−1}(x), ‖y − x‖²)`, then polish the
//! full k-problem.
//!
//! The inner optimiser is a limited-memory bundle/quasi-Newton method:
//! subgradients of the piecewise-smooth objective drive an L-BFGS two-loop
//! recursion with Armijo backtracking — the same limited-memory machinery
//! LMBM uses (we omit the bundle's null steps; on MSSC the subdifferential
//! is a singleton almost everywhere, so the simplification preserves the
//! method's accuracy/cost profile: full O(m·n·k) passes per gradient,
//! hours-scale growth with m — see DESIGN.md §Substitutions).

use crate::baselines::common::{AlgoFailure, AlgoResult, MsscAlgorithm};
use crate::data::dataset::Dataset;
use crate::kernels::{self, distance::sq_dist};
use crate::metrics::{Counters, PhaseTimer};
use crate::util::rng::Rng;

/// LMBM-Clust configuration.
pub struct LmbmClust {
    /// L-BFGS memory (pairs).
    pub memory: usize,
    /// Max optimiser iterations per (sub)problem.
    pub max_iters: usize,
    /// Gradient-norm tolerance.
    pub tol: f64,
    /// Candidate points evaluated when seeding the auxiliary problem.
    pub aux_candidates: usize,
    /// Wall-clock budget; exceeded → `OverTimeBudget` (reproduces the
    /// paper's missing LMBM entries on the largest sets).
    pub time_budget_secs: f64,
}

impl Default for LmbmClust {
    fn default() -> Self {
        LmbmClust {
            memory: 7,
            max_iters: 60,
            tol: 1e-5,
            aux_candidates: 8,
            time_budget_secs: 600.0,
        }
    }
}

/// Objective (eq. 11) and subgradient at `c` (flattened k×n).
fn value_and_subgrad(
    points: &[f32],
    m: usize,
    n: usize,
    k: usize,
    c: &[f64],
    grad: &mut [f64],
    counters: &mut Counters,
) -> f64 {
    grad.fill(0.0);
    let inv_m = 1.0 / m as f64;
    let mut total = 0.0;
    for i in 0..m {
        let x = &points[i * n..(i + 1) * n];
        let mut best = f64::INFINITY;
        let mut bj = 0usize;
        for j in 0..k {
            let mut d = 0f64;
            for t in 0..n {
                let diff = c[j * n + t] - x[t] as f64;
                d += diff * diff;
            }
            if d < best {
                best = d;
                bj = j;
            }
        }
        total += best;
        for t in 0..n {
            grad[bj * n + t] += 2.0 * inv_m * (c[bj * n + t] - x[t] as f64);
        }
    }
    counters.add_distance_evals((m * k) as u64);
    total * inv_m
}

/// Auxiliary objective (eq. 12) and subgradient w.r.t. the new center y.
fn aux_value_and_subgrad(
    points: &[f32],
    m: usize,
    n: usize,
    r: &[f64],
    y: &[f64],
    grad: &mut [f64],
    counters: &mut Counters,
) -> f64 {
    grad.fill(0.0);
    let inv_m = 1.0 / m as f64;
    let mut total = 0.0;
    for i in 0..m {
        let x = &points[i * n..(i + 1) * n];
        let mut d = 0f64;
        for t in 0..n {
            let diff = y[t] - x[t] as f64;
            d += diff * diff;
        }
        if d < r[i] {
            total += d;
            for t in 0..n {
                grad[t] += 2.0 * inv_m * (y[t] - x[t] as f64);
            }
        } else {
            total += r[i];
        }
    }
    counters.add_distance_evals(m as u64);
    total * inv_m
}

/// Limited-memory quasi-Newton descent on a nonsmooth objective.
/// `eval(x, grad) -> f` must fill `grad` with a subgradient.
fn lmbm_minimize<F>(
    x: &mut [f64],
    memory: usize,
    max_iters: usize,
    tol: f64,
    mut eval: F,
) -> f64
where
    F: FnMut(&[f64], &mut [f64]) -> f64,
{
    let dim = x.len();
    let mut grad = vec![0.0; dim];
    let mut f = eval(x, &mut grad);
    let mut s_hist: std::collections::VecDeque<Vec<f64>> = Default::default();
    let mut y_hist: std::collections::VecDeque<Vec<f64>> = Default::default();

    for _ in 0..max_iters {
        let gnorm = grad.iter().map(|g| g * g).sum::<f64>().sqrt();
        if gnorm < tol {
            break;
        }
        // Two-loop recursion for the search direction.
        let mut q = grad.clone();
        let mut alphas = Vec::with_capacity(s_hist.len());
        for (s, y) in s_hist.iter().rev().zip(y_hist.iter().rev()) {
            let sy: f64 = s.iter().zip(y).map(|(a, b)| a * b).sum();
            if sy <= 1e-12 {
                alphas.push(0.0);
                continue;
            }
            let alpha = s.iter().zip(&q).map(|(a, b)| a * b).sum::<f64>() / sy;
            for (qi, yi) in q.iter_mut().zip(y) {
                *qi -= alpha * yi;
            }
            alphas.push(alpha);
        }
        // Initial Hessian scaling.
        if let (Some(s), Some(y)) = (s_hist.back(), y_hist.back()) {
            let sy: f64 = s.iter().zip(y).map(|(a, b)| a * b).sum();
            let yy: f64 = y.iter().map(|v| v * v).sum();
            if sy > 1e-12 && yy > 1e-12 {
                let gamma = sy / yy;
                for qi in q.iter_mut() {
                    *qi *= gamma;
                }
            }
        }
        for ((s, y), alpha) in s_hist.iter().zip(y_hist.iter()).zip(alphas.iter().rev()) {
            let sy: f64 = s.iter().zip(y).map(|(a, b)| a * b).sum();
            if sy <= 1e-12 {
                continue;
            }
            let beta = y.iter().zip(&q).map(|(a, b)| a * b).sum::<f64>() / sy;
            for (qi, si) in q.iter_mut().zip(s) {
                *qi += (alpha - beta) * si;
            }
        }
        // Descent direction.
        let dir: Vec<f64> = q.iter().map(|v| -v).collect();
        let dg: f64 = dir.iter().zip(&grad).map(|(a, b)| a * b).sum();
        let dir = if dg < 0.0 {
            dir
        } else {
            grad.iter().map(|g| -g).collect() // fall back to steepest descent
        };

        // Armijo backtracking.
        let mut step = 1.0f64;
        let c1 = 1e-4;
        let dg: f64 = dir.iter().zip(&grad).map(|(a, b)| a * b).sum();
        let mut new_x = vec![0.0; dim];
        let mut new_grad = vec![0.0; dim];
        let mut accepted = false;
        for _ in 0..30 {
            for i in 0..dim {
                new_x[i] = x[i] + step * dir[i];
            }
            let nf = eval(&new_x, &mut new_grad);
            if nf <= f + c1 * step * dg {
                // Update memory.
                let s_vec: Vec<f64> = new_x.iter().zip(x.iter()).map(|(a, b)| a - b).collect();
                let y_vec: Vec<f64> =
                    new_grad.iter().zip(&grad).map(|(a, b)| a - b).collect();
                s_hist.push_back(s_vec);
                y_hist.push_back(y_vec);
                if s_hist.len() > memory {
                    s_hist.pop_front();
                    y_hist.pop_front();
                }
                x.copy_from_slice(&new_x);
                grad.copy_from_slice(&new_grad);
                f = nf;
                accepted = true;
                break;
            }
            step *= 0.5;
        }
        if !accepted {
            break; // no descent found — serious-step failure, stop
        }
    }
    f
}

impl MsscAlgorithm for LmbmClust {
    fn name(&self) -> &'static str {
        "LMBM-Clust"
    }

    fn run(&self, data: &Dataset, k: usize, seed: u64) -> Result<AlgoResult, AlgoFailure> {
        let (m, n) = (data.m(), data.n());
        if k == 0 || k > m {
            return Err(AlgoFailure::Invalid(format!("k={k} out of range for m={m}")));
        }
        let start = std::time::Instant::now();
        let mut rng = Rng::new(seed);
        let mut counters = Counters::new();
        let mut timer = PhaseTimer::new();
        let points = data.points();

        let centroids_f64 = timer.time_init(|| {
            // k = 1: the mean (exact optimum).
            let mut c: Vec<f64> = vec![0.0; n];
            for i in 0..m {
                for t in 0..n {
                    c[t] += points[i * n + t] as f64;
                }
            }
            for v in c.iter_mut() {
                *v /= m as f64;
            }

            // Incrementally add centers 2..k.
            for kk in 2..=k {
                if start.elapsed().as_secs_f64() > self.time_budget_secs {
                    return Err(AlgoFailure::OverTimeBudget {
                        budget_secs: self.time_budget_secs,
                    });
                }
                // r_{k-1}(x): distance to current centers.
                let kc = kk - 1;
                let c32: Vec<f32> = c.iter().map(|&v| v as f32).collect();
                let mut r = vec![0f64; m];
                for i in 0..m {
                    let x = &points[i * n..(i + 1) * n];
                    let mut best = f64::INFINITY;
                    for j in 0..kc {
                        let d = sq_dist(x, &c32[j * n..(j + 1) * n]) as f64;
                        best = best.min(d);
                    }
                    r[i] = best;
                }
                counters.add_distance_evals((m * kc) as u64);

                // Auxiliary problem: candidates = points with largest r
                // (plus random draws), optimise y, keep the best.
                let mut best_y: Option<(f64, Vec<f64>)> = None;
                let mut cand_idx: Vec<usize> = (0..m).collect();
                cand_idx.sort_by(|&a, &b| r[b].partial_cmp(&r[a]).unwrap());
                let mut candidates: Vec<usize> =
                    cand_idx[..self.aux_candidates.min(m) / 2 + 1].to_vec();
                for _ in 0..self.aux_candidates / 2 {
                    candidates.push(rng.usize(m));
                }
                for &ci in &candidates {
                    let mut y: Vec<f64> =
                        points[ci * n..(ci + 1) * n].iter().map(|&v| v as f64).collect();
                    let fy = lmbm_minimize(
                        &mut y,
                        self.memory,
                        self.max_iters / 2,
                        self.tol,
                        |yv, g| aux_value_and_subgrad(points, m, n, &r, yv, g, &mut counters),
                    );
                    if best_y.as_ref().map(|(bf, _)| fy < *bf).unwrap_or(true) {
                        best_y = Some((fy, y));
                    }
                }
                c.extend(best_y.expect("at least one candidate").1);

                // Polish the full kk-problem.
                lmbm_minimize(&mut c, self.memory, self.max_iters, self.tol, |cv, g| {
                    value_and_subgrad(points, m, n, kk, cv, g, &mut counters)
                });
            }
            Ok(c)
        })?;

        let centroids: Vec<f32> = centroids_f64.iter().map(|&v| v as f32).collect();
        let objective = timer.time_full(|| {
            kernels::objective(points, &centroids, m, n, k, &mut counters)
        });
        Ok(AlgoResult {
            centroids,
            objective,
            cpu_init_secs: timer.init_secs(),
            cpu_full_secs: timer.full_secs(),
            counters,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Synth;

    fn blobs(m: usize, k_true: usize, seed: u64) -> Dataset {
        Synth::GaussianMixture {
            m,
            n: 2,
            k_true,
            spread: 0.2,
            box_half_width: 15.0,
        }
        .generate("t", seed)
    }

    #[test]
    fn k1_is_exact_mean() {
        let data = Dataset::from_vec("t", vec![0.0, 0.0, 2.0, 0.0, 4.0, 6.0], 3, 2);
        let r = LmbmClust::default().run(&data, 1, 0).unwrap();
        assert!((r.centroids[0] - 2.0).abs() < 1e-4);
        assert!((r.centroids[1] - 2.0).abs() < 1e-4);
    }

    #[test]
    fn finds_separated_blobs_accurately() {
        let data = blobs(400, 3, 1);
        let r = LmbmClust::default().run(&data, 3, 2).unwrap();
        // Compare against multi-start k-means++: LMBM should be competitive
        // (within 10%) — its selling point is accuracy.
        let pp = crate::baselines::kmeans_pp::MultiStartKMeansPP {
            inner: crate::baselines::kmeans_pp::KMeansPP {
                threads: 1,
                ..Default::default()
            },
            restarts: 5,
        };
        let ref_r = pp.run(&data, 3, 2).unwrap();
        assert!(
            r.objective <= ref_r.objective * 1.10,
            "LMBM {} vs multistart++ {}",
            r.objective,
            ref_r.objective
        );
    }

    #[test]
    fn time_budget_enforced() {
        let data = blobs(3000, 5, 3);
        let algo = LmbmClust { time_budget_secs: 0.0, ..Default::default() };
        match algo.run(&data, 5, 1) {
            Err(AlgoFailure::OverTimeBudget { .. }) => {}
            other => panic!("expected budget failure, got {other:?}"),
        }
    }

    #[test]
    fn cost_grows_with_m() {
        // The paper's critique: LMBM needs many full passes.
        let small = blobs(200, 2, 4);
        let big = blobs(800, 2, 4);
        let algo = LmbmClust::default();
        let a = algo.run(&small, 2, 1).unwrap();
        let b = algo.run(&big, 2, 1).unwrap();
        assert!(b.counters.distance_evals > a.counters.distance_evals * 2);
    }
}
