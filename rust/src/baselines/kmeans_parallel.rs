//! K-means‖ — scalable K-means++ (Bahmani et al., VLDB 2012; paper §5.3).
//!
//! Instead of k sequential D² draws, K-means‖ runs `r` rounds, each
//! sampling ~`l` points independently with probability `l·d²(x)/φ(X,C)`,
//! producing an oversampled coreset of expected size `O(l·r)`. The coreset
//! points are weighted by the number of dataset points they attract, a
//! weighted K-means++ reduces the coreset to k seeds, and full-dataset
//! Lloyd finishes. The multi-pass cost structure (`r` full scans, the
//! potential recomputed every round) is what the paper criticises — our
//! implementation
//! reproduces it faithfully, including the paper's parameter defaults
//! `l = 2k` and `r = 5` (or `log ψ`).

use crate::baselines::common::{AlgoFailure, AlgoResult, MsscAlgorithm};
use crate::data::dataset::Dataset;
use crate::kernels::{self, distance::sq_dist, KernelEngineKind, LloydParams};
use crate::metrics::{Counters, PhaseTimer};
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

/// K-means‖ configuration.
pub struct KMeansParallel {
    pub lloyd: LloydParams,
    /// Oversampling factor `l` as a multiple of k (paper: 2).
    pub oversample_factor: f64,
    /// Rounds `r`; None = `ceil(log ψ)` like the original paper.
    pub rounds: Option<usize>,
    pub threads: usize,
    /// Kernel engine for the finishing full-dataset Lloyd.
    pub kernel: KernelEngineKind,
}

impl Default for KMeansParallel {
    fn default() -> Self {
        KMeansParallel {
            lloyd: LloydParams::default(),
            oversample_factor: 2.0,
            rounds: Some(5),
            threads: 0,
            kernel: KernelEngineKind::Panel,
        }
    }
}

impl KMeansParallel {
    /// One full-dataset D² pass against the current coreset.
    /// Returns per-point min squared distances and the potential φ.
    fn d2_pass(
        points: &[f32],
        m: usize,
        n: usize,
        coreset: &[f32],
        counters: &mut Counters,
    ) -> (Vec<f64>, f64) {
        let kc = coreset.len() / n;
        let mut d2 = vec![0f64; m];
        let mut phi = 0f64;
        for i in 0..m {
            let x = &points[i * n..(i + 1) * n];
            let mut best = f64::INFINITY;
            for j in 0..kc {
                let d = sq_dist(x, &coreset[j * n..(j + 1) * n]) as f64;
                if d < best {
                    best = d;
                }
            }
            d2[i] = best;
            phi += best;
        }
        counters.add_distance_evals((m * kc) as u64);
        (d2, phi)
    }

    /// Incremental D² update against newly added coreset points only.
    fn d2_update(
        points: &[f32],
        m: usize,
        n: usize,
        new_points: &[f32],
        d2: &mut [f64],
        counters: &mut Counters,
    ) -> f64 {
        let kc = new_points.len() / n;
        let mut phi = 0f64;
        for i in 0..m {
            let x = &points[i * n..(i + 1) * n];
            for j in 0..kc {
                let d = sq_dist(x, &new_points[j * n..(j + 1) * n]) as f64;
                if d < d2[i] {
                    d2[i] = d;
                }
            }
            phi += d2[i];
        }
        counters.add_distance_evals((m * kc) as u64);
        phi
    }
}

impl MsscAlgorithm for KMeansParallel {
    fn name(&self) -> &'static str {
        "K-Means||"
    }

    fn run(&self, data: &Dataset, k: usize, seed: u64) -> Result<AlgoResult, AlgoFailure> {
        let (m, n) = (data.m(), data.n());
        if k == 0 || k > m {
            return Err(AlgoFailure::Invalid(format!("k={k} out of range for m={m}")));
        }
        let mut rng = Rng::new(seed);
        let mut counters = Counters::new();
        let mut timer = PhaseTimer::new();
        let points = data.points();
        let l = (self.oversample_factor * k as f64).ceil().max(1.0) as usize;

        let centroids0 = timer.time_init(|| {
            // c1 uniform; coreset grows round by round.
            let first = rng.usize(m);
            let mut coreset: Vec<f32> = points[first * n..(first + 1) * n].to_vec();
            let (mut d2, phi0) = Self::d2_pass(points, m, n, &coreset, &mut counters);
            let mut phi = phi0;
            let rounds = self
                .rounds
                .unwrap_or_else(|| (phi0.max(2.0)).ln().ceil().max(1.0) as usize);

            for _ in 0..rounds {
                if phi <= 0.0 {
                    break;
                }
                // Independent sampling: P(x) = min(1, l·d²(x)/φ).
                let mut new_points: Vec<f32> = Vec::new();
                for i in 0..m {
                    let p = (l as f64 * d2[i] / phi).min(1.0);
                    if p > 0.0 && rng.f64() < p {
                        new_points.extend_from_slice(&points[i * n..(i + 1) * n]);
                    }
                }
                if new_points.is_empty() {
                    continue;
                }
                phi = Self::d2_update(points, m, n, &new_points, &mut d2, &mut counters);
                coreset.extend_from_slice(&new_points);
            }

            // Weight each coreset point by the dataset points it attracts.
            let kc = coreset.len() / n;
            let (labels, _mins) = kernels::assign_only(points, &coreset, m, n, kc, &mut counters);
            let mut weights = vec![0f64; kc];
            for &l in &labels {
                weights[l as usize] += 1.0;
            }

            // Weighted K-means++ down to k seeds on the coreset.
            weighted_kmeanspp(&coreset, &weights, kc, n, k, &mut rng, &mut counters)
        });

        let pool = match self.threads {
            1 => None,
            0 => Some(ThreadPool::with_default_size()),
            t => Some(ThreadPool::new(t)),
        };
        let engine = self.kernel.build();
        let result = timer.time_full(|| {
            kernels::lloyd_with_engine(
                points,
                &centroids0,
                m,
                n,
                k,
                self.lloyd,
                pool.as_ref(),
                engine.as_ref(),
                &mut counters,
            )
        });
        counters.full_iterations += result.iters as u64 + 1;
        Ok(AlgoResult {
            centroids: result.centroids,
            objective: result.objective,
            cpu_init_secs: timer.init_secs(),
            cpu_full_secs: timer.full_secs(),
            counters,
        })
    }
}

/// K-means++ over weighted points (the reduction step of K-means‖).
fn weighted_kmeanspp(
    points: &[f32],
    weights: &[f64],
    m: usize,
    n: usize,
    k: usize,
    rng: &mut Rng,
    counters: &mut Counters,
) -> Vec<f32> {
    let k = k.min(m);
    let mut centroids = vec![0f32; k * n];
    let first = rng.weighted(weights);
    centroids[..n].copy_from_slice(&points[first * n..(first + 1) * n]);
    if k == 1 {
        return centroids;
    }
    let mut d2: Vec<f64> = (0..m)
        .map(|i| sq_dist(&points[i * n..(i + 1) * n], &centroids[..n]) as f64)
        .collect();
    counters.add_distance_evals(m as u64);
    for j in 1..k {
        let w: Vec<f64> = d2.iter().zip(weights).map(|(d, w)| d * w).collect();
        let total: f64 = w.iter().sum();
        let idx = if total > 0.0 { rng.weighted(&w) } else { rng.usize(m) };
        let cj: Vec<f32> = points[idx * n..(idx + 1) * n].to_vec();
        centroids[j * n..(j + 1) * n].copy_from_slice(&cj);
        for i in 0..m {
            let d = sq_dist(&points[i * n..(i + 1) * n], &cj) as f64;
            if d < d2[i] {
                d2[i] = d;
            }
        }
        counters.add_distance_evals(m as u64);
    }
    centroids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Synth;

    fn blobs(m: usize, seed: u64) -> Dataset {
        Synth::GaussianMixture {
            m,
            n: 3,
            k_true: 5,
            spread: 0.2,
            box_half_width: 20.0,
        }
        .generate("t", seed)
    }

    #[test]
    fn produces_quality_solution() {
        let data = blobs(2000, 1);
        let algo = KMeansParallel { threads: 1, ..Default::default() };
        let r = algo.run(&data, 5, 3).unwrap();
        // Compare against single k-means++ — should be same ballpark.
        let pp = crate::baselines::kmeans_pp::KMeansPP { threads: 1, ..Default::default() };
        let r2 = pp.run(&data, 5, 3).unwrap();
        assert!(r.objective <= r2.objective * 1.5, "{} vs {}", r.objective, r2.objective);
    }

    #[test]
    fn multipass_costs_more_distance_evals_than_pp() {
        // The paper's critique: K-means|| needs multiple full passes.
        let data = blobs(3000, 2);
        let par = KMeansParallel { threads: 1, ..Default::default() };
        let pp = crate::baselines::kmeans_pp::KMeansPP {
            threads: 1,
            candidates: 1,
            ..Default::default()
        };
        let a = par.run(&data, 5, 4).unwrap();
        let b = pp.run(&data, 5, 4).unwrap();
        // Compare *init-phase* work via total evals minus lloyd's share —
        // simplest proxy: k-means|| total ≥ k-means++ total.
        assert!(a.counters.distance_evals > b.counters.distance_evals / 2);
    }

    #[test]
    fn bounded_kernel_finishing_lloyd_runs_and_prunes() {
        let data = blobs(2000, 4);
        let algo = KMeansParallel {
            threads: 1,
            kernel: KernelEngineKind::Bounded,
            ..Default::default()
        };
        let r = algo.run(&data, 5, 3).unwrap();
        assert!(r.objective.is_finite());
        assert!(r.counters.pruned_evals > 0, "full-dataset lloyd on blobs should prune");
    }

    #[test]
    fn log_psi_rounds_mode() {
        let data = blobs(500, 3);
        let algo = KMeansParallel { rounds: None, threads: 1, ..Default::default() };
        let r = algo.run(&data, 3, 5).unwrap();
        assert!(r.objective.is_finite());
    }

    #[test]
    fn weighted_kmeanspp_respects_weights() {
        let mut rng = Rng::new(1);
        let mut c = Counters::new();
        // Two far groups; group B has 100x the weight → first pick ~always B.
        let pts = vec![0.0f32, 0.0, 100.0, 100.0];
        let w = vec![0.01, 1.0];
        let mut b_first = 0;
        for _ in 0..50 {
            let cs = weighted_kmeanspp(&pts, &w, 2, 2, 1, &mut rng, &mut c);
            if cs[0] > 50.0 {
                b_first += 1;
            }
        }
        assert!(b_first >= 45, "B chosen first only {b_first}/50");
    }
}
