//! Forgy K-means (paper §5.2): uniform random initial centroids from the
//! dataset, then full-dataset Lloyd to convergence. The simplest baseline —
//! fast init, but the global Lloyd iterations dominate on big data and the
//! solution quality depends entirely on the draw.

use crate::baselines::common::{AlgoFailure, AlgoResult, MsscAlgorithm};
use crate::data::dataset::Dataset;
use crate::kernels::{self, LloydParams};
use crate::metrics::{Counters, PhaseTimer};
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

/// Forgy-initialised K-means.
pub struct ForgyKMeans {
    pub lloyd: LloydParams,
    /// Worker threads for the Lloyd steps (0 = machine default, 1 = serial).
    pub threads: usize,
}

impl Default for ForgyKMeans {
    fn default() -> Self {
        ForgyKMeans { lloyd: LloydParams::default(), threads: 0 }
    }
}

impl MsscAlgorithm for ForgyKMeans {
    fn name(&self) -> &'static str {
        "Forgy K-Means"
    }

    fn run(&self, data: &Dataset, k: usize, seed: u64) -> Result<AlgoResult, AlgoFailure> {
        let (m, n) = (data.m(), data.n());
        if k == 0 || k > m {
            return Err(AlgoFailure::Invalid(format!("k={k} out of range for m={m}")));
        }
        let mut rng = Rng::new(seed);
        let mut counters = Counters::new();
        let mut timer = PhaseTimer::new();

        // Init phase: uniform distinct rows.
        let centroids0 = timer.time_init(|| {
            let idx = rng.sample_indices(m, k);
            data.gather(&idx)
        });

        // Full phase: Lloyd on the whole dataset.
        let pool = match self.threads {
            1 => None,
            0 => Some(ThreadPool::with_default_size()),
            t => Some(ThreadPool::new(t)),
        };
        let result = timer.time_full(|| {
            kernels::lloyd(
                data.points(),
                &centroids0,
                m,
                n,
                k,
                self.lloyd,
                pool.as_ref(),
                &mut counters,
            )
        });
        counters.full_iterations += result.iters as u64 + 1;
        Ok(AlgoResult {
            centroids: result.centroids,
            objective: result.objective,
            cpu_init_secs: timer.init_secs(),
            cpu_full_secs: timer.full_secs(),
            counters,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Synth;

    #[test]
    fn clusters_blobs() {
        let data = Synth::GaussianMixture {
            m: 1000,
            n: 3,
            k_true: 4,
            spread: 0.2,
            box_half_width: 20.0,
        }
        .generate("t", 1);
        let algo = ForgyKMeans { threads: 1, ..Default::default() };
        let r = algo.run(&data, 4, 7).unwrap();
        assert!(r.objective.is_finite());
        assert_eq!(r.centroids.len(), 12);
        assert!(r.counters.full_iterations >= 2);
        assert!(r.counters.distance_evals > 0);
    }

    #[test]
    fn rejects_bad_k() {
        let data = Dataset::from_vec("t", vec![0.0; 8], 4, 2);
        let algo = ForgyKMeans { threads: 1, ..Default::default() };
        assert!(algo.run(&data, 0, 1).is_err());
        assert!(algo.run(&data, 5, 1).is_err());
    }

    #[test]
    fn deterministic_per_seed() {
        let data = Synth::GaussianMixture {
            m: 500,
            n: 2,
            k_true: 3,
            spread: 0.3,
            box_half_width: 10.0,
        }
        .generate("t", 2);
        let algo = ForgyKMeans { threads: 1, ..Default::default() };
        let a = algo.run(&data, 3, 5).unwrap();
        let b = algo.run(&data, 3, 5).unwrap();
        assert_eq!(a.objective, b.objective);
        assert_eq!(a.centroids, b.centroids);
    }
}
