//! K-means++ baseline (paper tables' "K-Means++" column): D² seeding over
//! the full dataset followed by full-dataset Lloyd. Accurate but the
//! O(m·k·n) seeding pass is expensive on big data — exactly the cost
//! profile the paper reports (large `cpu_init`).
//!
//! Also provides [`MultiStartKMeansPP`], the classic multi-restart variant
//! (§1.2, "multi-start K-means").

use crate::baselines::common::{AlgoFailure, AlgoResult, MsscAlgorithm};
use crate::data::dataset::Dataset;
use crate::kernels::{self, LloydParams};
use crate::metrics::{Counters, PhaseTimer};
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

/// Single-start K-means++ → Lloyd.
pub struct KMeansPP {
    pub lloyd: LloydParams,
    /// Candidates per D² draw (paper §5.7: 3).
    pub candidates: usize,
    pub threads: usize,
}

impl Default for KMeansPP {
    fn default() -> Self {
        KMeansPP { lloyd: LloydParams::default(), candidates: 3, threads: 0 }
    }
}

impl MsscAlgorithm for KMeansPP {
    fn name(&self) -> &'static str {
        "K-Means++"
    }

    fn run(&self, data: &Dataset, k: usize, seed: u64) -> Result<AlgoResult, AlgoFailure> {
        let (m, n) = (data.m(), data.n());
        if k == 0 || k > m {
            return Err(AlgoFailure::Invalid(format!("k={k} out of range for m={m}")));
        }
        let mut rng = Rng::new(seed);
        let mut counters = Counters::new();
        let mut timer = PhaseTimer::new();
        let centroids0 = timer.time_init(|| {
            kernels::kmeanspp(data.points(), m, n, k, self.candidates, &mut rng, &mut counters)
        });
        let pool = match self.threads {
            1 => None,
            0 => Some(ThreadPool::with_default_size()),
            t => Some(ThreadPool::new(t)),
        };
        let result = timer.time_full(|| {
            kernels::lloyd(
                data.points(),
                &centroids0,
                m,
                n,
                k,
                self.lloyd,
                pool.as_ref(),
                &mut counters,
            )
        });
        counters.full_iterations += result.iters as u64 + 1;
        Ok(AlgoResult {
            centroids: result.centroids,
            objective: result.objective,
            cpu_init_secs: timer.init_secs(),
            cpu_full_secs: timer.full_secs(),
            counters,
        })
    }
}

/// Multi-start K-means++ : `restarts` independent runs, keep the best.
pub struct MultiStartKMeansPP {
    pub inner: KMeansPP,
    pub restarts: usize,
}

impl MsscAlgorithm for MultiStartKMeansPP {
    fn name(&self) -> &'static str {
        "Multi-start K-Means++"
    }

    fn run(&self, data: &Dataset, k: usize, seed: u64) -> Result<AlgoResult, AlgoFailure> {
        let mut best: Option<AlgoResult> = None;
        let mut total_init = 0.0;
        let mut total_full = 0.0;
        let mut counters = Counters::new();
        for r in 0..self.restarts.max(1) {
            let run = self.inner.run(data, k, seed.wrapping_add(r as u64 * 0x9E37))?;
            total_init += run.cpu_init_secs;
            total_full += run.cpu_full_secs;
            counters.merge(&run.counters);
            if best.as_ref().map(|b| run.objective < b.objective).unwrap_or(true) {
                best = Some(run);
            }
        }
        let mut best = best.expect("restarts >= 1");
        best.cpu_init_secs = total_init;
        best.cpu_full_secs = total_full;
        best.counters = counters;
        Ok(best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Synth;

    fn blobs(seed: u64) -> Dataset {
        Synth::GaussianMixture {
            m: 800,
            n: 3,
            k_true: 4,
            spread: 0.15,
            box_half_width: 15.0,
        }
        .generate("t", seed)
    }

    #[test]
    fn beats_or_matches_forgy_on_average() {
        // K-means++ seeding should on average land at least as good a local
        // minimum as a single uniform draw.
        let data = blobs(1);
        let pp = KMeansPP { threads: 1, ..Default::default() };
        let forgy = crate::baselines::forgy::ForgyKMeans {
            threads: 1,
            ..Default::default()
        };
        let mut pp_sum = 0.0;
        let mut forgy_sum = 0.0;
        for s in 0..8 {
            pp_sum += pp.run(&data, 4, s).unwrap().objective;
            forgy_sum += forgy.run(&data, 4, s).unwrap().objective;
        }
        assert!(
            pp_sum <= forgy_sum * 1.05,
            "kmeans++ mean {pp_sum} should be ≤ forgy mean {forgy_sum}"
        );
    }

    #[test]
    fn init_phase_counted_separately() {
        let data = blobs(2);
        let pp = KMeansPP { threads: 1, ..Default::default() };
        let r = pp.run(&data, 4, 3).unwrap();
        assert!(r.cpu_init_secs > 0.0);
        assert!(r.cpu_full_secs > 0.0);
    }

    #[test]
    fn multistart_never_worse_than_single() {
        let data = blobs(3);
        let single = KMeansPP { threads: 1, ..Default::default() };
        let multi = MultiStartKMeansPP {
            inner: KMeansPP { threads: 1, ..Default::default() },
            restarts: 4,
        };
        let s = single.run(&data, 4, 9).unwrap();
        let m = multi.run(&data, 4, 9).unwrap();
        assert!(m.objective <= s.objective + 1e-9);
        assert!(m.counters.distance_evals > s.counters.distance_evals);
    }
}
