//! Baseline MSSC algorithms from the paper's §5 (competitive algorithms):
//! Forgy K-means, K-means++ (single + multi-start), K-means‖, Ward's,
//! LMBM-Clust, DA-MSSC, and lightweight coresets — all implemented from
//! scratch on the shared kernel substrate so their distance-eval counters
//! (`n_d`) and phase timings are directly comparable with Big-means.

pub mod common;
pub mod coreset;
pub mod da_mssc;
pub mod forgy;
pub mod kmeans_parallel;
pub mod kmeans_pp;
pub mod lmbm;
pub mod ward;

pub use common::{AlgoFailure, AlgoResult, MsscAlgorithm};
pub use coreset::LightweightCoreset;
pub use da_mssc::DaMssc;
pub use forgy::ForgyKMeans;
pub use kmeans_parallel::KMeansParallel;
pub use kmeans_pp::{KMeansPP, MultiStartKMeansPP};
pub use lmbm::LmbmClust;
pub use ward::Wards;
