//! Lightweight coresets (Bachem, Lucic & Krause, KDD 2018; paper §5.1).
//!
//! Builds an (ε, k)-lightweight coreset by importance sampling with the
//! mixture distribution (paper eq. 10)
//!
//! `q(x) = ½·1/|X| + ½·‖x − μ‖² / Σ_x' ‖x' − μ‖²`
//!
//! then runs weighted K-means on the coreset. The paper's critique — the
//! distribution needs two full passes over X — is visible directly in the
//! distance-eval counters; Big-means' uniform sampling needs zero.

use crate::baselines::common::{AlgoFailure, AlgoResult, MsscAlgorithm};
use crate::data::dataset::Dataset;
use crate::kernels::engine::{KernelEngine, KernelEngineKind, LloydState};
use crate::kernels::{self, distance::sq_dist, LloydParams};
use crate::metrics::{Counters, PhaseTimer};
use crate::util::rng::Rng;

/// Lightweight-coreset K-means.
pub struct LightweightCoreset {
    /// Coreset size.
    pub coreset_size: usize,
    pub lloyd: LloydParams,
    pub candidates: usize,
    /// Kernel engine for the weighted Lloyd on the coreset.
    pub kernel: KernelEngineKind,
}

impl LightweightCoreset {
    pub fn new(coreset_size: usize) -> Self {
        LightweightCoreset {
            coreset_size,
            lloyd: LloydParams::default(),
            candidates: 3,
            kernel: KernelEngineKind::Panel,
        }
    }

    /// Sample the coreset: returns (points, weights).
    /// Two full passes over X (mean, then norms) — the paper's point.
    pub fn sample(
        &self,
        data: &Dataset,
        rng: &mut Rng,
        counters: &mut Counters,
    ) -> (Vec<f32>, Vec<f64>) {
        let (m, n) = (data.m(), data.n());
        let points = data.points();
        // Pass 1: mean.
        let mut mu = vec![0f64; n];
        for i in 0..m {
            for t in 0..n {
                mu[t] += points[i * n + t] as f64;
            }
        }
        for v in mu.iter_mut() {
            *v /= m as f64;
        }
        let mu32: Vec<f32> = mu.iter().map(|&v| v as f32).collect();
        // Pass 2: ‖x − μ‖².
        let mut d2 = vec![0f64; m];
        let mut total = 0f64;
        for i in 0..m {
            let d = sq_dist(&points[i * n..(i + 1) * n], &mu32) as f64;
            d2[i] = d;
            total += d;
        }
        counters.add_distance_evals(m as u64);

        // q(x) and importance weights w(x) = 1 / (|C|·q(x)).
        let size = self.coreset_size.min(m);
        let mut coreset = Vec::with_capacity(size * n);
        let mut weights = Vec::with_capacity(size);
        let q: Vec<f64> = d2
            .iter()
            .map(|&d| 0.5 / m as f64 + if total > 0.0 { 0.5 * d / total } else { 0.0 })
            .collect();
        for _ in 0..size {
            let idx = rng.weighted(&q);
            coreset.extend_from_slice(&points[idx * n..(idx + 1) * n]);
            weights.push(1.0 / (size as f64 * q[idx]));
        }
        (coreset, weights)
    }
}

impl MsscAlgorithm for LightweightCoreset {
    fn name(&self) -> &'static str {
        "Lightweight Coreset"
    }

    fn run(&self, data: &Dataset, k: usize, seed: u64) -> Result<AlgoResult, AlgoFailure> {
        let (m, n) = (data.m(), data.n());
        let size = self.coreset_size.min(m);
        if k == 0 || k > size {
            return Err(AlgoFailure::Invalid(format!("k={k} out of range for coreset {size}")));
        }
        let mut rng = Rng::new(seed);
        let mut counters = Counters::new();
        let mut timer = PhaseTimer::new();

        let engine = self.kernel.build();
        let centroids = timer.time_init(|| {
            let (coreset, weights) = self.sample(data, &mut rng, &mut counters);
            // Weighted Lloyd on the coreset.
            let seed_c =
                kernels::kmeanspp(&coreset, size, n, k, self.candidates, &mut rng, &mut counters);
            weighted_lloyd(
                &coreset,
                &weights,
                size,
                n,
                k,
                seed_c,
                self.lloyd,
                engine.as_ref(),
                &mut counters,
            )
        });

        let objective = timer.time_full(|| {
            kernels::objective(data.points(), &centroids, m, n, k, &mut counters)
        });
        counters.full_iterations += 1;
        Ok(AlgoResult {
            centroids,
            objective,
            cpu_init_secs: timer.init_secs(),
            cpu_full_secs: timer.full_secs(),
            counters,
        })
    }
}

/// Lloyd over weighted points, assignment routed through a
/// [`KernelEngine`] with persistent bounds — the bounded engine prunes the
/// coreset iterations exactly like an unweighted chunk (the weights only
/// enter the reduction, not the nearest-centroid search).
#[allow(clippy::too_many_arguments)]
fn weighted_lloyd(
    points: &[f32],
    weights: &[f64],
    m: usize,
    n: usize,
    k: usize,
    mut centroids: Vec<f32>,
    params: LloydParams,
    engine: &dyn KernelEngine,
    counters: &mut Counters,
) -> Vec<f32> {
    let mut prev = f64::INFINITY;
    let mut state = LloydState::new(m);
    let mut old = vec![0f32; k * n];
    for _ in 0..params.max_iters {
        // The engine's unweighted sums/counts are discarded — the weighted
        // reduction below needs its own pass anyway, and coresets are small
        // by construction (O(size·n), not O(dataset)), so sharing the
        // engine's pruned search is the win worth taking.
        let out = engine.assign_step(points, &centroids, m, n, k, &mut state, counters);
        let mut sums = vec![0f64; k * n];
        let mut wsum = vec![0f64; k];
        let mut obj = 0f64;
        for i in 0..m {
            let j = out.labels[i] as usize;
            obj += weights[i] * out.mins[i] as f64;
            wsum[j] += weights[i];
            for t in 0..n {
                sums[j * n + t] += weights[i] * points[i * n + t] as f64;
            }
        }
        old.copy_from_slice(&centroids);
        for j in 0..k {
            if wsum[j] > 0.0 {
                for t in 0..n {
                    centroids[j * n + t] = (sums[j * n + t] / wsum[j]) as f32;
                }
            }
        }
        state.apply_update(&old, &centroids, k, n);
        if (prev - obj).abs() <= params.tol * obj.max(1e-300) {
            break;
        }
        prev = obj;
    }
    centroids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Synth;

    fn blobs(seed: u64) -> Dataset {
        Synth::GaussianMixture {
            m: 4000,
            n: 3,
            k_true: 4,
            spread: 0.2,
            box_half_width: 20.0,
        }
        .generate("t", seed)
    }

    #[test]
    fn coreset_solution_close_to_full_kmeans() {
        let data = blobs(1);
        let cs = LightweightCoreset::new(512).run(&data, 4, 2).unwrap();
        let pp = crate::baselines::kmeans_pp::KMeansPP {
            threads: 1,
            ..Default::default()
        }
        .run(&data, 4, 2)
        .unwrap();
        assert!(
            cs.objective <= pp.objective * 1.3,
            "coreset {} vs full {}",
            cs.objective,
            pp.objective
        );
    }

    #[test]
    fn weights_are_importance_weights() {
        let data = blobs(2);
        let algo = LightweightCoreset::new(256);
        let mut rng = Rng::new(3);
        let mut c = Counters::new();
        let (coreset, weights) = algo.sample(&data, &mut rng, &mut c);
        assert_eq!(coreset.len(), 256 * 3);
        assert_eq!(weights.len(), 256);
        // Total weight approximates m.
        let total: f64 = weights.iter().sum();
        let m = data.m() as f64;
        assert!((total - m).abs() / m < 0.35, "Σw = {total}, m = {m}");
    }

    #[test]
    fn bounded_kernel_runs_and_prunes() {
        let data = blobs(4);
        let mut algo = LightweightCoreset::new(512);
        algo.kernel = KernelEngineKind::Bounded;
        let r = algo.run(&data, 4, 2).unwrap();
        assert!(r.objective.is_finite());
        assert!(r.counters.pruned_evals > 0, "weighted lloyd on blobs should prune");
    }

    #[test]
    fn two_full_passes_counted() {
        // The distance-eval counter shows the q(x) construction pass.
        let data = blobs(3);
        let algo = LightweightCoreset::new(128);
        let mut rng = Rng::new(1);
        let mut c = Counters::new();
        algo.sample(&data, &mut rng, &mut c);
        assert!(c.distance_evals >= data.m() as u64);
    }
}
