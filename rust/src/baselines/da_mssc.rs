//! DA-MSSC — Decomposition/Aggregation MSSC (paper §5.4, after
//! Krassovitskiy, Mladenovic & Mussabayev, MOTOR 2020).
//!
//! Two phases: (1) split the dataset into `q` chunks of size `s`, cluster
//! each independently into k clusters (K-means++ init + Lloyd), pooling all
//! q·k resulting centroids; (2) cluster the pool itself into k clusters and
//! return those centers. The paper uses DA-MSSC as the contrast showing why
//! Big-means' *sequential incumbent* beats *independent aggregation*.

use crate::baselines::common::{AlgoFailure, AlgoResult, MsscAlgorithm};
use crate::data::dataset::Dataset;
use crate::kernels::{self, LloydParams};
use crate::metrics::{Counters, PhaseTimer};
use crate::util::rng::Rng;

/// DA-MSSC configuration.
pub struct DaMssc {
    /// Chunk size `s`.
    pub chunk_size: usize,
    /// Number of chunks `q`.
    pub chunks: usize,
    pub lloyd: LloydParams,
    /// K-means++ candidates per draw.
    pub candidates: usize,
}

impl DaMssc {
    pub fn new(chunk_size: usize, chunks: usize) -> Self {
        DaMssc {
            chunk_size,
            chunks,
            lloyd: LloydParams::default(),
            candidates: 3,
        }
    }
}

impl MsscAlgorithm for DaMssc {
    fn name(&self) -> &'static str {
        "DA-MSSC"
    }

    fn run(&self, data: &Dataset, k: usize, seed: u64) -> Result<AlgoResult, AlgoFailure> {
        let (m, n) = (data.m(), data.n());
        let s = self.chunk_size.min(m);
        if k == 0 || k > s {
            return Err(AlgoFailure::Invalid(format!("k={k} out of range for s={s}")));
        }
        let mut rng = Rng::new(seed);
        let mut counters = Counters::new();
        let mut timer = PhaseTimer::new();
        let points = data.points();

        // Phase 1: independent chunk clusterings → centroid pool.
        let pool: Vec<f32> = timer.time_init(|| {
            let mut pool = Vec::with_capacity(self.chunks * k * n);
            for _ in 0..self.chunks {
                let idx = rng.sample_indices(m, s);
                let chunk = data.gather(&idx);
                let seed_c =
                    kernels::kmeanspp(&chunk, s, n, k, self.candidates, &mut rng, &mut counters);
                let r = kernels::lloyd(&chunk, &seed_c, s, n, k, self.lloyd, None, &mut counters);
                counters.chunks += 1;
                counters.chunk_iterations += r.iters as u64;
                // Pool only non-degenerate centroids.
                for (j, &count) in r.counts.iter().enumerate() {
                    if count > 0 {
                        pool.extend_from_slice(&r.centroids[j * n..(j + 1) * n]);
                    }
                }
            }
            pool
        });
        let pool_size = pool.len() / n;
        if pool_size < k {
            return Err(AlgoFailure::Invalid(format!(
                "aggregation pool ({pool_size}) smaller than k={k}"
            )));
        }

        // Phase 2: cluster the pool, then a final full-dataset objective.
        let (centroids, objective) = timer.time_full(|| {
            let seed_c =
                kernels::kmeanspp(&pool, pool_size, n, k, self.candidates, &mut rng, &mut counters);
            let r = kernels::lloyd(&pool, &seed_c, pool_size, n, k, self.lloyd, None, &mut counters);
            let obj = kernels::objective(points, &r.centroids, m, n, k, &mut counters);
            (r.centroids, obj)
        });
        counters.full_iterations += 1;
        Ok(AlgoResult {
            centroids,
            objective,
            cpu_init_secs: timer.init_secs(),
            cpu_full_secs: timer.full_secs(),
            counters,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Synth;

    fn blobs(seed: u64) -> Dataset {
        Synth::GaussianMixture {
            m: 3000,
            n: 3,
            k_true: 4,
            spread: 0.2,
            box_half_width: 20.0,
        }
        .generate("t", seed)
    }

    #[test]
    fn produces_reasonable_solution() {
        let data = blobs(1);
        let r = DaMssc::new(256, 8).run(&data, 4, 3).unwrap();
        assert!(r.objective.is_finite());
        assert_eq!(r.centroids.len(), 12);
        assert_eq!(r.counters.chunks, 8);
    }

    #[test]
    fn paper_claim_bigmeans_beats_da_mssc_time_quality() {
        // §5.4: "the performance of the DA-MSSC was significantly worse
        // than ... other algorithms". With equal chunk budget, Big-means
        // should reach an equal-or-better objective.
        use crate::coordinator::config::{ParallelMode, StopCondition};
        let data = blobs(2);
        let da = DaMssc::new(256, 12).run(&data, 4, 5).unwrap();
        let cfg = crate::BigMeansConfig::new(4, 256)
            .with_stop(StopCondition::MaxChunks(12))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(5);
        let bm = crate::BigMeans::new(cfg).run(&data).unwrap();
        assert!(
            bm.objective <= da.objective * 1.15,
            "bigmeans {} vs da-mssc {}",
            bm.objective,
            da.objective
        );
    }

    #[test]
    fn small_pool_rejected() {
        let data = Dataset::from_vec("t", vec![0.0; 20], 10, 2);
        // chunks=1, k=5 but chunk likely collapses to ≤5 distinct pts.
        let r = DaMssc::new(5, 1).run(&data, 5, 1);
        // Either a valid run (pool exactly 5) or the Invalid error —
        // never a panic.
        match r {
            Ok(res) => assert!(res.objective.is_finite()),
            Err(AlgoFailure::Invalid(_)) => {}
            Err(other) => panic!("unexpected {other:?}"),
        }
    }
}
