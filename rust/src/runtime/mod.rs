//! Runtime: load + execute the AOT HLO artifacts via the PJRT C API.
//!
//! * [`artifact`] — manifest parsing + variant selection;
//! * [`pjrt`] — client, executable cache, padded execution;
//! * [`solver`] — the [`crate::coordinator::ChunkSolver`] implementation
//!   with native fallback, and `pjrt_bigmeans` to assemble an engine.

pub mod artifact;
pub mod pjrt;
pub mod solver;

pub use artifact::{Kind, Manifest, Variant};
pub use pjrt::PjrtRuntime;
pub use solver::{pjrt_bigmeans, PjrtSolver};

/// Default artifacts directory relative to the crate root.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
