//! PJRT execution of the AOT HLO artifacts (the xla crate, CPU client).
//!
//! Load path (see /opt/xla-example/load_hlo): HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Executables are compiled lazily per
//! variant and cached.
//!
//! Padding contract (mirrors `python/compile/model.py`):
//! * rows `s → s_v`: padded rows are zeros with mask 0.0 — they contribute
//!   nothing to mins/sums/counts and get label −1;
//! * features `n → n_v`: zero-filled columns in both points and centroids —
//!   distance-preserving;
//! * clusters `k → k_v`: padded centroid slots parked at `pad_centroid`
//!   (+1e15) — never nearest, stay degenerate, objective unaffected.
//!
//! The `xla` dependency is only available behind the `pjrt` cargo feature
//! (the offline build has no registry). Without it this module still
//! compiles: every execution entry point returns an error, so callers
//! ([`super::solver::PjrtSolver`]) transparently fall back to the native
//! kernels while manifest inspection keeps working.

use std::path::Path;

use crate::kernels::LloydResult;
use crate::metrics::Counters;
use crate::anyhow;
#[cfg(feature = "pjrt")]
use crate::util::error::Context;
use crate::util::error::Result;

use super::artifact::Manifest;
#[cfg(feature = "pjrt")]
use super::artifact::{Kind, Variant};

/// A compiled-artifact runtime bound to one PJRT CPU client.
///
/// Not `Send`/`Sync` — the xla crate's client is `Rc`-based. Use one
/// runtime per thread.
pub struct PjrtRuntime {
    manifest: Manifest,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    cache: std::cell::RefCell<
        std::collections::HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>,
    >,
}

impl PjrtRuntime {
    /// Open the artifacts directory (must contain `manifest.json`).
    #[cfg(feature = "pjrt")]
    pub fn open(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(PjrtRuntime { manifest, client, cache: Default::default() })
    }

    /// Open the artifacts directory (must contain `manifest.json`).
    /// Stub build: manifest inspection works, execution always errors.
    #[cfg(not(feature = "pjrt"))]
    pub fn open(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(PjrtRuntime { manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    #[cfg(feature = "pjrt")]
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn platform(&self) -> String {
        "disabled (built without the `pjrt` feature)".to_string()
    }

    #[cfg(feature = "pjrt")]
    fn executable(&self, v: &Variant) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&v.name) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(&v.path)
            .with_context(|| format!("parse HLO text {}", v.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compile {}", v.name))?,
        );
        self.cache.borrow_mut().insert(v.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Pad a `(rows × n)` point block into a `(s_v × n_v)` literal plus its
    /// mask literal.
    #[cfg(feature = "pjrt")]
    fn pad_points(
        v: &Variant,
        points: &[f32],
        rows: usize,
        n: usize,
    ) -> Result<(xla::Literal, xla::Literal)> {
        let mut buf = vec![0f32; v.s * v.n];
        for i in 0..rows {
            buf[i * v.n..i * v.n + n].copy_from_slice(&points[i * n..(i + 1) * n]);
        }
        let mut mask = vec![0f32; v.s];
        mask[..rows].fill(1.0);
        let pts = xla::Literal::vec1(&buf).reshape(&[v.s as i64, v.n as i64])?;
        let msk = xla::Literal::vec1(&mask).reshape(&[v.s as i64])?;
        Ok((pts, msk))
    }

    /// Pad `(k × n)` centroids into `(k_v × n_v)`: features zero-padded,
    /// extra cluster slots parked at `pad_centroid`.
    #[cfg(feature = "pjrt")]
    fn pad_centroids(v: &Variant, centroids: &[f32], k: usize, n: usize) -> Result<xla::Literal> {
        let mut buf = vec![0f32; v.k * v.n];
        for j in 0..v.k {
            let dst = &mut buf[j * v.n..(j + 1) * v.n];
            if j < k {
                dst[..n].copy_from_slice(&centroids[j * n..(j + 1) * n]);
            } else {
                dst.fill(v.pad_centroid);
            }
        }
        Ok(xla::Literal::vec1(&buf).reshape(&[v.k as i64, v.n as i64])?)
    }

    /// Lloyd local search on a chunk via the AOT executable.
    /// Errors if no variant fits `(rows, n, k)`.
    #[cfg(feature = "pjrt")]
    pub fn lloyd(
        &self,
        points: &[f32],
        rows: usize,
        n: usize,
        k: usize,
        seed_centroids: &[f32],
        counters: &mut Counters,
    ) -> Result<LloydResult> {
        let v = self
            .manifest
            .select(Kind::Lloyd, rows, n, k)
            .ok_or_else(|| anyhow!("no lloyd variant fits s={rows} n={n} k={k}"))?
            .clone();
        let exe = self.executable(&v)?;
        let (pts, mask) = Self::pad_points(&v, points, rows, n)?;
        let cs = Self::pad_centroids(&v, seed_centroids, k, n)?;
        let result = exe.execute::<xla::Literal>(&[pts, cs, mask])?[0][0]
            .to_literal_sync()?;
        // return_tuple=True → 4-tuple (centroids, objective, counts, iters).
        let (c_lit, obj_lit, counts_lit, iters_lit) = result.to_tuple4()?;
        let c_pad: Vec<f32> = c_lit.to_vec()?;
        let counts_pad: Vec<f32> = counts_lit.to_vec()?;
        let objective = obj_lit.to_vec::<f32>()?[0] as f64;
        let iters = iters_lit.to_vec::<i32>()?[0] as u32;

        // Un-pad.
        let mut centroids = vec![0f32; k * n];
        for j in 0..k {
            centroids[j * n..(j + 1) * n].copy_from_slice(&c_pad[j * v.n..j * v.n + n]);
        }
        let counts: Vec<u64> = counts_pad[..k].iter().map(|&c| c as u64).collect();
        // Semantic distance evals: (iters Lloyd assignments + 1 final) × rows × k,
        // matching the native path's accounting (padded lanes excluded).
        counters.add_distance_evals((iters as u64 + 1) * rows as u64 * k as u64);
        Ok(LloydResult { centroids, objective, counts, iters })
    }

    /// Stub: built without the `pjrt` feature — always errors so callers
    /// fall back to the native kernels.
    #[cfg(not(feature = "pjrt"))]
    pub fn lloyd(
        &self,
        _points: &[f32],
        _rows: usize,
        _n: usize,
        _k: usize,
        _seed_centroids: &[f32],
        _counters: &mut Counters,
    ) -> Result<LloydResult> {
        Err(anyhow!("pjrt lloyd unavailable: built without the `pjrt` feature"))
    }

    /// One assignment pass via the AOT executable, blocked over the largest
    /// fitting variant so arbitrarily large `rows` work.
    #[cfg(feature = "pjrt")]
    pub fn assign(
        &self,
        points: &[f32],
        rows: usize,
        n: usize,
        k: usize,
        centroids: &[f32],
        counters: &mut Counters,
    ) -> Result<(Vec<u32>, Vec<f32>)> {
        let block = self
            .manifest
            .max_s(Kind::Assign, n, k)
            .ok_or_else(|| anyhow!("no assign variant fits n={n} k={k}"))?;
        let mut labels = Vec::with_capacity(rows);
        let mut mins = Vec::with_capacity(rows);
        let mut start = 0usize;
        while start < rows {
            let take = block.min(rows - start);
            let v = self
                .manifest
                .select(Kind::Assign, take, n, k)
                .ok_or_else(|| anyhow!("no assign variant fits s={take} n={n} k={k}"))?
                .clone();
            let exe = self.executable(&v)?;
            let (pts, mask) =
                Self::pad_points(&v, &points[start * n..(start + take) * n], take, n)?;
            let cs = Self::pad_centroids(&v, centroids, k, n)?;
            let result = exe.execute::<xla::Literal>(&[pts, cs, mask])?[0][0]
                .to_literal_sync()?;
            let (labels_lit, mins_lit) = result.to_tuple2()?;
            let l: Vec<i32> = labels_lit.to_vec()?;
            let m: Vec<f32> = mins_lit.to_vec()?;
            labels.extend(l[..take].iter().map(|&x| x.max(0) as u32));
            mins.extend_from_slice(&m[..take]);
            start += take;
        }
        counters.add_distance_evals(rows as u64 * k as u64);
        Ok((labels, mins))
    }

    /// Stub: built without the `pjrt` feature — always errors so callers
    /// fall back to the native kernels.
    #[cfg(not(feature = "pjrt"))]
    pub fn assign(
        &self,
        _points: &[f32],
        _rows: usize,
        _n: usize,
        _k: usize,
        _centroids: &[f32],
        _counters: &mut Counters,
    ) -> Result<(Vec<u32>, Vec<f32>)> {
        Err(anyhow!("pjrt assign unavailable: built without the `pjrt` feature"))
    }

    /// K-means++ seeding via the AOT executable (randomness injected as
    /// uniforms). Errors if no variant fits — callers fall back to native.
    #[cfg(feature = "pjrt")]
    pub fn kmeanspp(
        &self,
        points: &[f32],
        rows: usize,
        n: usize,
        k: usize,
        uniforms: &[f32],
        counters: &mut Counters,
    ) -> Result<Vec<f32>> {
        let v = self
            .manifest
            .select(Kind::KmeansPP, rows, n, k)
            .ok_or_else(|| anyhow!("no kmeanspp variant fits s={rows} n={n} k={k}"))?
            .clone();
        let exe = self.executable(&v)?;
        let (pts, mask) = Self::pad_points(&v, points, rows, n)?;
        // Pad the uniforms to k_v (extra draws pick padded rows weight-0 —
        // harmless: we discard padded centroid slots below).
        let mut u = vec![0.5f32; v.k];
        u[..k].copy_from_slice(uniforms);
        let ul = xla::Literal::vec1(&u).reshape(&[v.k as i64])?;
        let result = exe.execute::<xla::Literal>(&[pts, mask, ul])?[0][0]
            .to_literal_sync()?;
        let c_lit = result.to_tuple1()?;
        let c_pad: Vec<f32> = c_lit.to_vec()?;
        let mut centroids = vec![0f32; k * n];
        for j in 0..k {
            centroids[j * n..(j + 1) * n].copy_from_slice(&c_pad[j * v.n..j * v.n + n]);
        }
        counters.add_distance_evals(rows as u64 * k as u64);
        Ok(centroids)
    }

    /// Stub: built without the `pjrt` feature — always errors so callers
    /// fall back to the native kernels.
    #[cfg(not(feature = "pjrt"))]
    pub fn kmeanspp(
        &self,
        _points: &[f32],
        _rows: usize,
        _n: usize,
        _k: usize,
        _uniforms: &[f32],
        _counters: &mut Counters,
    ) -> Result<Vec<f32>> {
        Err(anyhow!("pjrt kmeanspp unavailable: built without the `pjrt` feature"))
    }
}
