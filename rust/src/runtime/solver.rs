//! `PjrtSolver`: the [`ChunkSolver`] implementation backed by the AOT HLO
//! executables, with transparent native fallback for shapes no artifact
//! variant covers (e.g. n > 128 or k > 32 in the default family).

use std::path::Path;

use crate::util::error::Result;

use crate::coordinator::solver::{ChunkSolver, NativeSolver};
use crate::kernels::{LloydParams, LloydResult};
use crate::metrics::Counters;
use crate::util::rng::Rng;

use super::pjrt::PjrtRuntime;

/// PJRT-backed chunk solver with native fallback.
pub struct PjrtSolver {
    runtime: PjrtRuntime,
    fallback: NativeSolver,
    /// Count of chunk solves that actually ran on PJRT (vs fallback).
    pjrt_solves: std::cell::Cell<u64>,
    native_solves: std::cell::Cell<u64>,
}

impl PjrtSolver {
    pub fn open(artifacts_dir: &Path, params: LloydParams) -> Result<Self> {
        Ok(PjrtSolver {
            runtime: PjrtRuntime::open(artifacts_dir)?,
            fallback: NativeSolver::sequential(params),
            pjrt_solves: std::cell::Cell::new(0),
            native_solves: std::cell::Cell::new(0),
        })
    }

    pub fn runtime(&self) -> &PjrtRuntime {
        &self.runtime
    }

    /// (pjrt, native) chunk-solve counts — used by tests and reports to
    /// verify the hot path really runs on the AOT artifacts.
    pub fn solve_counts(&self) -> (u64, u64) {
        (self.pjrt_solves.get(), self.native_solves.get())
    }

    /// K-means++ on the AOT path with caller-supplied RNG; falls back to
    /// native seeding when no variant fits.
    pub fn kmeanspp(
        &self,
        points: &[f32],
        rows: usize,
        n: usize,
        k: usize,
        rng: &mut Rng,
        counters: &mut Counters,
    ) -> Vec<f32> {
        let uniforms: Vec<f32> = (0..k).map(|_| rng.f32()).collect();
        match self.runtime.kmeanspp(points, rows, n, k, &uniforms, counters) {
            Ok(c) => c,
            Err(_) => crate::kernels::kmeanspp(points, rows, n, k, 1, rng, counters),
        }
    }
}

impl ChunkSolver for PjrtSolver {
    fn lloyd(
        &self,
        points: &[f32],
        rows: usize,
        n: usize,
        k: usize,
        seed_centroids: &[f32],
        counters: &mut Counters,
    ) -> LloydResult {
        match self.runtime.lloyd(points, rows, n, k, seed_centroids, counters) {
            Ok(r) => {
                self.pjrt_solves.set(self.pjrt_solves.get() + 1);
                r
            }
            Err(_) => {
                self.native_solves.set(self.native_solves.get() + 1);
                self.fallback.lloyd(points, rows, n, k, seed_centroids, counters)
            }
        }
    }

    fn assign(
        &self,
        points: &[f32],
        rows: usize,
        n: usize,
        k: usize,
        centroids: &[f32],
        counters: &mut Counters,
    ) -> (Vec<u32>, Vec<f32>) {
        match self.runtime.assign(points, rows, n, k, centroids, counters) {
            Ok(r) => r,
            Err(_) => self.fallback.assign(points, rows, n, k, centroids, counters),
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Build a Big-means engine on the PJRT solver.
pub fn pjrt_bigmeans(
    config: crate::coordinator::config::BigMeansConfig,
    artifacts_dir: &Path,
) -> Result<crate::coordinator::bigmeans::BigMeans> {
    let solver = PjrtSolver::open(artifacts_dir, config.lloyd)?;
    Ok(crate::coordinator::bigmeans::BigMeans::with_solver(
        config,
        Box::new(solver),
    ))
}
