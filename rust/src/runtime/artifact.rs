//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. `artifacts/manifest.json` lists every emitted HLO variant
//! with its static shape `(s, n, k)` and padding constants.

use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};
use crate::{anyhow, bail};

use crate::util::json::Json;

/// Kind of computation an artifact implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Kind {
    /// Lloyd local search: (points, centroids, mask) → (centroids', obj, counts, iters).
    Lloyd,
    /// One assignment pass: (points, centroids, mask) → (labels, mins).
    Assign,
    /// K-means++ seeding: (points, mask, uniforms) → centroids.
    KmeansPP,
}

impl Kind {
    pub fn parse(s: &str) -> Result<Kind> {
        match s {
            "lloyd" => Ok(Kind::Lloyd),
            "assign" => Ok(Kind::Assign),
            "kmeanspp" => Ok(Kind::KmeansPP),
            other => bail!("unknown artifact kind '{other}'"),
        }
    }
}

/// One artifact variant.
#[derive(Clone, Debug)]
pub struct Variant {
    pub name: String,
    pub kind: Kind,
    pub s: usize,
    pub n: usize,
    pub k: usize,
    pub block_s: usize,
    pub tol: f64,
    pub max_iters: u32,
    pub pad_centroid: f32,
    pub path: PathBuf,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub variants: Vec<Variant>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let doc = Json::parse(&text).map_err(|e| anyhow!("parse manifest: {e}"))?;
        let version = doc
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing version"))?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let entries = doc
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing entries"))?;
        let mut variants = Vec::with_capacity(entries.len());
        for e in entries {
            let get_num = |key: &str| -> Result<f64> {
                e.get(key)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("entry missing numeric '{key}'"))
            };
            let get_str = |key: &str| -> Result<&str> {
                e.get(key)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry missing string '{key}'"))
            };
            let file = get_str("file")?;
            variants.push(Variant {
                name: get_str("name")?.to_string(),
                kind: Kind::parse(get_str("kind")?)?,
                s: get_num("s")? as usize,
                n: get_num("n")? as usize,
                k: get_num("k")? as usize,
                block_s: get_num("block_s")? as usize,
                tol: get_num("tol")?,
                max_iters: get_num("max_iters")? as u32,
                pad_centroid: get_num("pad_centroid")? as f32,
                path: dir.join(file),
            });
        }
        Ok(Manifest { variants })
    }

    /// Smallest variant of `kind` that fits `(s, n, k)` by padding
    /// (`s_v ≥ s`, `n_v ≥ n`, `k_v ≥ k`), minimising padded work
    /// `s_v · n_v · k_v`. None if nothing fits.
    pub fn select(&self, kind: Kind, s: usize, n: usize, k: usize) -> Option<&Variant> {
        self.variants
            .iter()
            .filter(|v| v.kind == kind && v.s >= s && v.n >= n && v.k >= k)
            .min_by_key(|v| v.s * v.n * v.k)
    }

    /// Largest chunk capacity available for a kind/n/k (used to block the
    /// final full-dataset pass).
    pub fn max_s(&self, kind: Kind, n: usize, k: usize) -> Option<usize> {
        self.variants
            .iter()
            .filter(|v| v.kind == kind && v.n >= n && v.k >= k)
            .map(|v| v.s)
            .max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, entries_json: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let text = format!(r#"{{"version": 1, "entries": {entries_json}}}"#);
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    fn entry(kind: &str, s: usize, n: usize, k: usize) -> String {
        format!(
            r#"{{"name": "{kind}_s{s}_n{n}_k{k}", "kind": "{kind}", "s": {s}, "n": {n},
                 "k": {k}, "block_s": 256, "tol": 0.0001, "max_iters": 100,
                 "file": "{kind}_s{s}_n{n}_k{k}.hlo.txt", "pad_centroid": 1e15}}"#
        )
    }

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("bigmeans_manifest_tests").join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn load_and_select_smallest_fit() {
        let dir = tmpdir("a");
        write_manifest(
            &dir,
            &format!(
                "[{},{},{}]",
                entry("lloyd", 1024, 16, 8),
                entry("lloyd", 4096, 16, 8),
                entry("lloyd", 1024, 64, 32)
            ),
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.variants.len(), 3);
        let v = m.select(Kind::Lloyd, 1000, 10, 5).unwrap();
        assert_eq!((v.s, v.n, v.k), (1024, 16, 8));
        let v2 = m.select(Kind::Lloyd, 2000, 10, 5).unwrap();
        assert_eq!(v2.s, 4096);
        let v3 = m.select(Kind::Lloyd, 100, 50, 20).unwrap();
        assert_eq!((v3.n, v3.k), (64, 32));
        assert!(m.select(Kind::Lloyd, 100, 300, 5).is_none()); // n too big
        assert!(m.select(Kind::Assign, 100, 10, 5).is_none()); // kind absent
    }

    #[test]
    fn max_s_picks_largest() {
        let dir = tmpdir("b");
        write_manifest(
            &dir,
            &format!("[{},{}]", entry("assign", 1024, 16, 8), entry("assign", 16384, 16, 8)),
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.max_s(Kind::Assign, 10, 8), Some(16384));
        assert_eq!(m.max_s(Kind::Assign, 32, 8), None);
    }

    #[test]
    fn bad_manifest_rejected() {
        let dir = tmpdir("c");
        std::fs::write(dir.join("manifest.json"), "{}").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::write(dir.join("manifest.json"), r#"{"version": 9, "entries": []}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn real_artifacts_manifest_loads() {
        // When `make artifacts` has run, validate the real manifest.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.variants.is_empty());
            for v in &m.variants {
                assert!(v.path.exists(), "missing artifact {}", v.path.display());
            }
        }
    }
}
