//! `bigmeans` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//! * `cluster`  — run Big-means on a dataset (catalog name or csv/fbin/bmx
//!   file; `--backend mmap|buffered` clusters files out-of-core)
//! * `convert`  — stream a CSV into the out-of-core `.bmx` format
//! * `table`    — regenerate a paper table for one dataset
//! * `summary`  — regenerate Tables 3–4 across the catalog
//! * `generate` — write a synthetic catalog dataset to .fbin/.bmx
//! * `catalog`  — list the dataset catalog
//! * `artifacts`— inspect the AOT artifact manifest

use std::path::PathBuf;
use std::time::Duration;

use bigmeans::bench_harness::{self, report, tables};
use bigmeans::coordinator::config::{
    BigMeansConfig, DataBackend, Engine, KernelEngineKind, ParallelMode, ReinitStrategy,
    StopCondition,
};
use bigmeans::data::{catalog, convert, loader, PAPER_K_GRID};
use bigmeans::runtime;
use bigmeans::util::cli::Args;
use bigmeans::{BigMeans, DataSource};

const USAGE: &str = "\
bigmeans — scalable K-means clustering for big data (Big-means, PatRec 2022)

USAGE: bigmeans <SUBCOMMAND> [OPTIONS]

SUBCOMMANDS:
  cluster <dataset>   Run Big-means. <dataset> = catalog name or a
                      .csv/.fbin/.bmx file path
      --k N             clusters (default 10)
      --s N             chunk size (default 4096)
      --time SECS       cpu_max budget (default 3)
      --chunks N        max chunks (default unlimited)
      --engine E        panel | bounded | pjrt (default panel)
                        panel   = exact blocked-panel kernels (fused
                                  distance panel + argmin)
                        bounded = Hamerly triangle-inequality pruning:
                                  label-identical to panel, skips most
                                  distance evals on settled chunks (see
                                  the `pruned evals` output line)
                        'native' is accepted as an alias for panel
      --mode M          inner | chunks | seq   (default inner)
      --backend B       mem | mmap | buffered  (default mem)
                        mmap/buffered cluster files out-of-core:
                        mmap = memory-mapped .bmx; buffered = positioned
                        reads (.bmx) or row-indexed parse-on-read (.csv)
      --reinit R        kmeanspp | random      (default kmeanspp)
      --threads N       worker threads (default: machine)
      --seed N          RNG seed
      --skip-final      skip the full-dataset assignment pass
  convert <in.csv> <out.bmx>   Convert a CSV into the .bmx format
                      (blockwise, memory bounded by the row index)
  table <dataset>     Regenerate the paper's per-dataset tables
      --k LIST          k grid (default 2,3,5,10,15,20,25)
      --n-exec N        repetitions (default 3)
      --full            use the full §5 roster (default: quick roster)
  summary             Regenerate Tables 3–4 over the whole catalog
      --n-exec N        repetitions per cell (default 2)
      --quick           four-dataset subset
  generate <name> <out.fbin|out.bmx>   Write a catalog dataset to disk
  catalog             List catalog datasets
  artifacts           Show the AOT manifest
";

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprint!("{USAGE}");
        std::process::exit(2);
    }
    let sub = argv.remove(0);
    let args = match Args::parse_with_flags(argv, &["full", "quick", "skip-final", "help"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match sub.as_str() {
        "cluster" => cmd_cluster(&args),
        "convert" => cmd_convert(&args),
        "table" => cmd_table(&args),
        "summary" => cmd_summary(&args),
        "generate" => cmd_generate(&args),
        "catalog" => cmd_catalog(),
        "artifacts" => cmd_artifacts(),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}'\n\n{USAGE}")),
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e}");
        1
    });
    std::process::exit(code);
}

/// Open the `cluster` dataset argument through the configured backend.
fn load_source(args: &Args, backend: DataBackend) -> Result<Box<dyn DataSource>, String> {
    let Some(name) = args.positional().first() else {
        return Err("missing <dataset> argument".into());
    };
    let is_file =
        name.ends_with(".csv") || name.ends_with(".fbin") || name.ends_with(".bmx");
    if !is_file {
        if backend != DataBackend::InMemory {
            return Err(format!(
                "--backend {backend:?} needs a dataset file; '{name}' is a catalog \
                 name, which is always generated in RAM (use `bigmeans generate \
                 {name} out.bmx` first)"
            ));
        }
        let entry = catalog::find(name)
            .ok_or_else(|| format!("no catalog dataset matching '{name}'"))?;
        let seed = args.u64("data-seed", 20220418)?;
        return Ok(Box::new(entry.generate(seed)));
    }
    loader::open_source(&PathBuf::from(name), backend).map_err(|e| e.to_string())
}

fn cmd_cluster(args: &Args) -> Result<(), String> {
    let backend = match args.choice("backend", &["mem", "mmap", "buffered"])? {
        "mmap" => DataBackend::Mmap,
        "buffered" => DataBackend::Buffered,
        _ => DataBackend::InMemory,
    };
    let k = args.usize("k", 10)?;
    let s = args.usize("s", 4096)?;
    let time = args.f64("time", 3.0)?;
    let chunks = args.u64("chunks", 0)?;
    let stop = if chunks > 0 {
        StopCondition::TimeOrChunks(Duration::from_secs_f64(time), chunks)
    } else {
        StopCondition::MaxTime(Duration::from_secs_f64(time))
    };
    let mode = match args.get_or("mode", "inner") {
        "inner" => ParallelMode::InnerParallel,
        "chunks" => ParallelMode::ChunkParallel,
        "seq" => ParallelMode::Sequential,
        other => return Err(format!("bad --mode '{other}'")),
    };
    let reinit = match args.get_or("reinit", "kmeanspp") {
        "kmeanspp" => ReinitStrategy::KmeansPP,
        "random" => ReinitStrategy::Random,
        other => return Err(format!("bad --reinit '{other}'")),
    };
    let engine_arg = args.choice("engine", &["panel", "native", "bounded", "pjrt"])?;
    let engine = if engine_arg == "pjrt" { Engine::Pjrt } else { Engine::Native };
    // `KernelEngineKind::parse` is the source of truth for kernel tokens;
    // "native" (compat alias) and "pjrt" fall back to the panel kernel.
    let kernel = KernelEngineKind::parse(engine_arg).unwrap_or(KernelEngineKind::Panel);
    let mut cfg = BigMeansConfig::new(k, s)
        .with_stop(stop)
        .with_parallel(mode)
        .with_backend(backend)
        .with_kernel(kernel)
        .with_seed(args.u64("seed", 0xB16_3EA5)?);
    cfg.reinit = reinit;
    cfg.threads = args.usize("threads", 0)?;
    cfg.skip_final_assignment = args.flag("skip-final");
    cfg.engine = engine;

    // The config's backend choice decides how the dataset file is opened.
    let data = load_source(args, cfg.backend)?;

    eprintln!(
        "dataset '{}': m={}, n={}  |  k={k}, s={s}, engine={engine:?}/{kernel:?}, mode={mode:?}, backend={backend:?}",
        data.name(),
        data.m(),
        data.n(),
    );
    let bm = match engine {
        Engine::Native => BigMeans::new(cfg),
        Engine::Pjrt => runtime::pjrt_bigmeans(cfg, &runtime::default_artifacts_dir())
            .map_err(|e| format!("pjrt engine: {e}"))?,
    };
    let t0 = std::time::Instant::now();
    let r = bm.run(data.as_ref())?;
    let wall = t0.elapsed().as_secs_f64();
    println!("objective (full SSE)     : {:.6e}", r.objective);
    println!("best chunk objective     : {:.6e}", r.best_chunk_objective);
    println!("chunks processed (n_s)   : {}", r.counters.chunks);
    println!("incumbent improvements   : {}", r.improvements);
    println!("distance evals (n_d)     : {:.3e}", r.counters.distance_evals as f64);
    if r.counters.pruned_evals > 0 {
        println!("pruned evals (avoided)   : {:.3e}", r.counters.pruned_evals as f64);
    }
    println!("cpu_init / cpu_full      : {:.3}s / {:.3}s", r.cpu_init_secs, r.cpu_full_secs);
    println!("wall time                : {wall:.3}s");
    Ok(())
}

fn cmd_convert(args: &Args) -> Result<(), String> {
    let pos = args.positional();
    if pos.len() != 2 {
        return Err("usage: convert <in.csv> <out.bmx>".into());
    }
    if !pos[1].ends_with(".bmx") {
        return Err(format!("output must be a .bmx path, got '{}'", pos[1]));
    }
    let t0 = std::time::Instant::now();
    let (m, n) = convert::csv_to_bmx(&PathBuf::from(&pos[0]), &PathBuf::from(&pos[1]))
        .map_err(|e| e.to_string())?;
    eprintln!(
        "wrote {} ({m} × {n}, {:.1} MiB) in {:.2}s",
        pos[1],
        (m * n * 4) as f64 / (1 << 20) as f64,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_table(args: &Args) -> Result<(), String> {
    let Some(name) = args.positional().first() else {
        return Err("missing <dataset> argument".into());
    };
    let entry = catalog::find(name)
        .ok_or_else(|| format!("no catalog dataset matching '{name}'"))?;
    let data = entry.generate(args.u64("data-seed", 20220418)?);
    let k_grid = args.usize_list("k", &PAPER_K_GRID)?;
    let n_exec = args.usize("n-exec", 3)?;
    let roster = if args.flag("full") {
        bench_harness::paper_roster(&entry)
    } else {
        bench_harness::quick_roster(&entry)
    };
    eprintln!(
        "running {} algorithms × {} k-values × {} reps on '{}' (m={}, n={})",
        roster.len(),
        k_grid.len(),
        n_exec,
        entry.name,
        data.m(),
        data.n()
    );
    let exp = bench_harness::run_experiment(&data, &roster, &k_grid, n_exec, 42);
    let summary = tables::summary_table(&exp);
    let details = tables::details_table(&exp);
    let md = format!(
        "{}\n{}",
        report::render_summary_markdown(&summary),
        report::render_details_markdown(&exp.dataset, &details)
    );
    println!("{md}");
    let path = report::write_report(&format!("table_{}.md", entry.table), &md);
    eprintln!("written to {}", path.display());
    Ok(())
}

fn cmd_summary(args: &Args) -> Result<(), String> {
    let n_exec = args.usize("n-exec", 2)?;
    let entries = if args.flag("quick") {
        catalog::quick_subset()
    } else {
        catalog::catalog()
    };
    let mut all_scores = Vec::new();
    for entry in &entries {
        let data = entry.generate(20220418);
        let roster = bench_harness::paper_roster(entry);
        eprintln!("[table {}] {} …", entry.table, entry.name);
        let exp = bench_harness::run_experiment(&data, &roster, &PAPER_K_GRID, n_exec, 42);
        all_scores.push(tables::dataset_scores(&exp));
    }
    let t4 = tables::table4(&all_scores);
    let md = report::render_table4_markdown(&t4, entries.len());
    println!("{md}");
    let path = report::write_report("table_3_4_summary.md", &md);
    eprintln!("written to {}", path.display());
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<(), String> {
    let pos = args.positional();
    if pos.len() != 2 {
        return Err("usage: generate <catalog-name> <out.fbin|out.bmx>".into());
    }
    let entry =
        catalog::find(&pos[0]).ok_or_else(|| format!("no catalog dataset '{}'", pos[0]))?;
    let data = entry.generate(args.u64("data-seed", 20220418)?);
    let out = PathBuf::from(&pos[1]);
    if pos[1].ends_with(".fbin") {
        loader::save_fbin(&data, &out).map_err(|e| e.to_string())?;
    } else if pos[1].ends_with(".bmx") {
        bigmeans::data::save_bmx(&data, &out).map_err(|e| e.to_string())?;
    } else {
        return Err("only .fbin / .bmx output supported".into());
    }
    eprintln!("wrote {} ({} × {})", out.display(), data.m(), data.n());
    Ok(())
}

fn cmd_catalog() -> Result<(), String> {
    println!(
        "{:<50} {:>9} {:>5} {:>9} {:>5} {:>8} {:>8}",
        "name", "paper_m", "p_n", "m", "n", "s", "cpu_max"
    );
    for e in catalog::catalog() {
        println!(
            "{:<50} {:>9} {:>5} {:>9} {:>5} {:>8} {:>8.2}",
            e.name, e.paper_m, e.paper_n, e.m, e.n, e.chunk_size, e.cpu_max_secs
        );
    }
    Ok(())
}

fn cmd_artifacts() -> Result<(), String> {
    let dir = runtime::default_artifacts_dir();
    let manifest = runtime::Manifest::load(&dir)
        .map_err(|e| format!("{e} (run `make artifacts` first)"))?;
    println!("{} variants in {}", manifest.variants.len(), dir.display());
    for v in &manifest.variants {
        println!(
            "  {:<28} kind={:<9} s={:<6} n={:<4} k={:<3} block_s={}",
            v.name,
            format!("{:?}", v.kind),
            v.s,
            v.n,
            v.k,
            v.block_s
        );
    }
    Ok(())
}
