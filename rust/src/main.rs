//! `bigmeans` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//! * `cluster`  — run Big-means on a dataset (catalog name or csv/fbin/bmx
//!   file; `--backend mmap|buffered|block` clusters files out-of-core)
//! * `convert`  — stream a CSV into the `.bmx` block store (v3; `--format
//!   v2` writes the legacy flat file)
//! * `verify`   — scan a `.bmx` file's checksums (v3: all blocks in
//!   parallel, naming the first corrupt block)
//! * `table`    — regenerate a paper table for one dataset
//! * `summary`  — regenerate Tables 3–4 across the catalog
//! * `generate` — write a synthetic catalog dataset to .fbin/.bmx
//! * `catalog`  — list the dataset catalog
//! * `artifacts`— inspect the AOT artifact manifest
//! * `serve`    — long-running TCP daemon answering batched assign/score
//!   queries from a `.bmm` model artifact, with `--watch` hot-swap
//! * `query`    — one-shot client for a running daemon

use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use bigmeans::bench_harness::{self, report, tables};
use bigmeans::coordinator::config::{
    BigMeansConfig, DataBackend, Engine, KernelEngineKind, ParallelMode, ReinitStrategy,
    StopCondition,
};
use bigmeans::coordinator::{produce_from_source, ChunkQueue, DriftAction, StreamingBigMeans};
use bigmeans::data::{catalog, convert, loader, PAPER_K_GRID};
use bigmeans::kernels::{
    active_isa, detect_isa, set_isa, DistanceIsa, DEFAULT_HYBRID_THRESHOLD,
};
use bigmeans::obs;
use bigmeans::runtime;
use bigmeans::serve::{spawn_watcher, Client, ModelArtifact, ModelRegistry, ServeOptions, Server};
use bigmeans::store::copy_to_store;
use bigmeans::tuner::{self, ControllerKind, TunerConfig};
use bigmeans::util::cli::Args;
use bigmeans::util::json::{num, obj, s as jstr, Json};
use bigmeans::{
    log_info, log_warn, BigMeans, BigMeansResult, BlockStore, Codec, DataSource, Dtype,
    StoreOptions,
};

const USAGE: &str = "\
bigmeans — scalable K-means clustering for big data (Big-means, PatRec 2022)

USAGE: bigmeans <SUBCOMMAND> [OPTIONS]

SUBCOMMANDS:
  cluster <dataset>   Run Big-means. <dataset> = catalog name or a
                      .csv/.fbin/.bmx file path
      --k N             clusters (default 10)
      --s N             chunk size (default 4096)
      --time SECS       cpu_max budget (default 3)
      --chunks N        max chunks (default unlimited)
      --engine E        panel | bounded | elkan | hybrid | pjrt
                        (default panel)
                        panel   = exact blocked-panel kernels (fused
                                  distance panel + argmin)
                        bounded = Hamerly triangle-inequality pruning:
                                  label-identical to panel, skips most
                                  distance evals on settled chunks (see
                                  the `pruned evals` output line)
                        elkan   = Elkan pruning: k per-centroid lower
                                  bounds + the inter-centroid-distance
                                  test; label-identical, prunes harder
                                  than bounded at O(m·k) bound memory
                        hybrid  = rescan-adaptive: each chunk starts on
                                  the Hamerly path and switches to Elkan
                                  once the observed rescan rate trips the
                                  threshold; label-identical to panel
                        'native' is accepted as an alias for panel
      --hybrid-threshold T  hybrid engine: rescan-rate cutoff for the
                        Hamerly→Elkan switch (default 0.25). `--mode
                        tune` with `:hybrid@T` arms learns a per-dataset
                        value; see --reuse-threshold
      --reuse-threshold P  load the learned hybrid threshold from the
                        `.bmm` model at P (written by `--mode tune
                        --save-model`); an explicit --hybrid-threshold
                        wins over it
      --isa I           auto | scalar | avx2 | neon | avx512 (default
                        auto): distance-kernel SIMD backend. Every choice
                        is bit-identical; auto detection prefers
                        avx512 > avx2 > neon > scalar, and a named ISA
                        the host lacks is rejected with the detected
                        list. (BIGMEANS_ISA env is the fallback when the
                        flag is absent; unlike --isa it falls back to
                        detection silently)
      --mode M          inner | chunks | seq | tune | stream | serve
                        (default inner)
                        tune   = competitive portfolio tuner: bandit-
                                 scheduled arms race over sample sizes
                        stream = sequential pass through the file as an
                                 unbounded stream (drift check optional)
                        serve  = alias for the `serve` subcommand (the
                                 positional argument is the .bmm model)
      --backend B       mem | mmap | buffered | block  (default mem)
                        mmap/buffered/block cluster files out-of-core:
                        mmap = memory-mapped .bmx; buffered = positioned
                        reads (.bmx) or row-indexed parse-on-read (.csv);
                        block = chunked .bmx v3 store (per-block CRC,
                        dtype/codec decode, LRU block cache)
      --index-stride N  buffered CSV: keep every Nth row offset
                        (index shrinks N×, seeks scan ≤ N−1 rows; default 1;
                        the index persists as a mmap'd .idx sidecar)
      --reinit R        kmeanspp | random      (default kmeanspp)
      --threads N       worker threads (default: machine)
      --seed N          RNG seed
      --skip-final      skip the full-dataset assignment pass
      --json            print a machine-readable run summary (objective,
                        counters incl. pruned evals, per-phase timings)
      --save-model P    write the winning model (centroids + geometry +
                        objective + provenance) to P as a `.bmm` artifact
                        for `bigmeans serve` (needs the final pass)
      --trace P         write the run's span timeline (shots, final-pass
                        slabs, block decodes, tuner pulls) to P as Chrome
                        trace-event JSON — open in Perfetto or
                        chrome://tracing (see docs/OBSERVABILITY.md)
      --metrics-out P   write the run's metric registry to P as Prometheus
                        text exposition (validate with `metrics-lint`)
      --metrics-push A  POST the final exposition to a Prometheus push
                        gateway at A (HOST:PORT) when the run exits —
                        batch runs finish faster than a scrape interval
      --report P        write a versioned run-report JSON to P: per-shot
                        objective descent, bandit audit (tune), drift
                        audit (stream), counters, config echo. Render it
                        with `bigmeans report P out.html`
      --diag P          flight-recorder crash-dump path (default
                        bigmeans.diag.json). The recorder is always on:
                        a panic or SIGTERM writes the most recent spans,
                        warn/error logs, and metric snapshots to P,
                        naming the span that was open when the run died
      --log-level L     error | warn | info | debug | trace (default info;
                        BIGMEANS_LOG env is the fallback) — accepted by
                        every subcommand
    tune mode only:
      --tuner T         ucb | softmax          (default ucb)
      --arms SPEC       grid of sample-size multipliers, each optionally
                        `:kernel` or `:kernel@threshold` (default
                        0.25,0.5,1,2,4), e.g. `0.5,1:panel,1:bounded,4`
                        or `1:hybrid@0.1,1:hybrid@0.25,1:hybrid@0.5` —
                        `@T` races hybrid switch thresholds; the winner
                        lands in the `.bmm` meta under --save-model
      --exploration C   UCB exploration constant (default 1.0)
      --temperature T   softmax temperature (default 0.1)
      --validation-rows N  reservoir validation sample size (default 4096)
    stream mode only:
      --validate-every N   drift check cadence in chunks (default 0 = off)
      --validation-rows N  drift reservoir capacity (default 2048)
      --drift-action A     none | reseed (default none): reseed = replace
                           the worst-contributing centroid with a
                           K-means++ draw from the validation reservoir
                           whenever a drift event fires
      --publish P          atomically rewrite P (.bmm) on every incumbent
                           improvement; a concurrent `serve --watch P`
                           daemon hot-swaps each publish mid-flight
  convert <in.csv> <out.bmx>   Convert a CSV into the .bmx format
                      (blockwise, memory bounded by the row index)
      --format F        v3 (chunked block store, default) | v2 (legacy flat)
      --block-rows N    v3: rows per block (default 4096)
      --dtype D         v3: f32 | f64 | f16 payload (default f32)
      --codec C         v3: none | shuffle | lz per-block codec (default none)
      --no-summaries    v3: skip the per-block min/max summary section
                        (disables the block-pruned final pass on this file)
      --threads N       v3: encode workers (default: machine)
  convert <file.bmx> --add-summaries   Retrofit the per-block min/max
                      summary section onto an existing v3 file in place
                      (decode-only — blocks are never re-encoded)
  verify <file.bmx>   Check every checksum in a .bmx file (v3: per-block
                      CRCs + min/max summary consistency when present)
      --threads N       v3: parallel block scanners (default: machine)
  table <dataset>     Regenerate the paper's per-dataset tables
      --k LIST          k grid (default 2,3,5,10,15,20,25)
      --n-exec N        repetitions (default 3)
      --full            use the full §5 roster (default: quick roster)
  summary             Regenerate Tables 3–4 over the whole catalog
      --n-exec N        repetitions per cell (default 2)
      --quick           four-dataset subset
  generate <name> <out.fbin|out.bmx>   Write a catalog dataset to disk
                      (.bmx output is v3; --format/--block-rows/--dtype/
                      --codec as in convert)
  catalog             List catalog datasets
  artifacts           Show the AOT manifest
  serve <model.bmm>   Run the clustering daemon: answers batched assign/
                      score queries over TCP, sharded across the thread
                      pool, bit-identical to the offline final pass
      --addr A          listen address (default 127.0.0.1:7171; port 0
                        picks an ephemeral port, printed on stderr)
      --threads N       batch-sharding workers (default: machine)
      --isa I           auto | scalar | avx2 | neon | avx512 (default
                        auto): distance-kernel SIMD backend
                        (bit-identical; unavailable ISAs are rejected
                        with the detected list)
      --max-batch N     largest accepted rows per request (default 2^20)
      --watch           poll the .bmm file and hot-swap refreshed models
                        without dropping in-flight requests
      --watch-ms N      watch poll cadence in ms (default 500)
      --metrics-addr A  expose the metric registry over HTTP at A, e.g.
                        127.0.0.1:9091 — `GET /metrics` is Prometheus
                        text exposition, `GET /healthz` a JSON health
                        document (model generation + swap history)
      --diag P          flight-recorder crash-dump path (without it the
                        recorder still runs, answering `query --op
                        dump-diagnostics`, but crashes dump nowhere)
      --json            print the serving stats document on exit
  query <host:port>   One-shot client for a running daemon
      --op O            assign | score | stats | ping | dump-diagnostics
                        | shutdown (default assign); dump-diagnostics
                        prints the daemon's flight-recorder document
      --file F          assign/score: dataset file (.csv/.fbin/.bmx) whose
                        leading rows become the query batch
      --rows N          assign/score: batch rows (default min(m, 1024))
      --json            machine-readable response (assign/score: labels;
                        stats already prints JSON)
  metrics-lint <a.prom> [b.prom]   Validate Prometheus exposition files
                      (CI's scrape gate); given a second, later scrape,
                      also check counter monotonicity across the two.
                      `.json` arguments are linted as `cluster --report`
                      run-report documents instead
  trace-lint <t.json> Validate a Chrome trace-event document
      --min-cats N      require ≥ N distinct span categories (default 1)
  report <run.json> <out.html>   Render a `cluster --report` document as
                      a self-contained HTML page (inline SVG descent and
                      latency charts, no external assets)

Metric families, trace schema, Grafana quickstart: docs/OBSERVABILITY.md
";

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprint!("{USAGE}");
        std::process::exit(2);
    }
    let sub = argv.remove(0);
    let flags = [
        "full",
        "quick",
        "skip-final",
        "json",
        "help",
        "no-summaries",
        "add-summaries",
        "watch",
    ];
    let args = match Args::parse_with_flags(argv, &flags) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = obs::log::init(args.get("log-level")) {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
    let code = match sub.as_str() {
        "cluster" => cmd_cluster(&args),
        "convert" => cmd_convert(&args),
        "verify" => cmd_verify(&args),
        "table" => cmd_table(&args),
        "summary" => cmd_summary(&args),
        "generate" => cmd_generate(&args),
        "catalog" => cmd_catalog(),
        "artifacts" => cmd_artifacts(),
        "serve" => cmd_serve(&args),
        "query" => cmd_query(&args),
        "metrics-lint" => cmd_metrics_lint(&args),
        "trace-lint" => cmd_trace_lint(&args),
        "report" => cmd_report(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}'\n\n{USAGE}")),
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e}");
        1
    });
    std::process::exit(code);
}

/// Open the `cluster` dataset argument through the configured backend.
fn load_source(
    args: &Args,
    backend: DataBackend,
    index_stride: usize,
) -> Result<Box<dyn DataSource>, String> {
    let Some(name) = args.positional().first() else {
        return Err("missing <dataset> argument".into());
    };
    let is_file =
        name.ends_with(".csv") || name.ends_with(".fbin") || name.ends_with(".bmx");
    if !is_file {
        if backend != DataBackend::InMemory {
            return Err(format!(
                "--backend {backend:?} needs a dataset file; '{name}' is a catalog \
                 name, which is always generated in RAM (use `bigmeans generate \
                 {name} out.bmx` first)"
            ));
        }
        let entry = catalog::find(name)
            .ok_or_else(|| format!("no catalog dataset matching '{name}'"))?;
        let seed = args.u64("data-seed", 20220418)?;
        return Ok(Box::new(entry.generate(seed)));
    }
    loader::open_source_with(&PathBuf::from(name), backend, index_stride)
        .map_err(|e| e.to_string())
}

/// Resolve `--isa` (auto | scalar | avx2 | neon | avx512) and pin the
/// distance-kernel backend before any kernel runs. `auto` re-runs
/// detection explicitly so a stale `BIGMEANS_ISA` env value cannot leak
/// into an `--isa auto` run; a named ISA the host lacks is an error
/// naming every detected ISA.
fn apply_isa_flag(args: &Args) -> Result<(), String> {
    match DistanceIsa::parse(args.choice("isa", &["auto", "scalar", "avx2", "neon", "avx512"])?)
    {
        Some(isa) => set_isa(isa),
        None => set_isa(detect_isa()),
    }
}

/// Resolve the hybrid switch threshold: an explicit `--hybrid-threshold`
/// wins over `--reuse-threshold P` (the value a `--mode tune
/// --save-model` run recorded in the model's meta).
fn resolve_hybrid_threshold(args: &Args) -> Result<Option<f64>, String> {
    if let Some(text) = args.get("hybrid-threshold") {
        let t: f64 =
            text.parse().map_err(|_| format!("--hybrid-threshold: bad value '{text}'"))?;
        if !t.is_finite() || t < 0.0 {
            return Err(format!("--hybrid-threshold must be ≥ 0, got '{text}'"));
        }
        return Ok(Some(t));
    }
    let Some(path) = args.get("reuse-threshold") else {
        return Ok(None);
    };
    if !path.ends_with(".bmm") {
        return Err(format!("--reuse-threshold needs a .bmm model path, got '{path}'"));
    }
    let artifact = ModelArtifact::load(&PathBuf::from(path))
        .map_err(|e| format!("--reuse-threshold: {e}"))?;
    let t = artifact.meta.get("hybrid_threshold").and_then(Json::as_f64).ok_or_else(|| {
        format!(
            "--reuse-threshold: '{path}' records no hybrid_threshold in its meta (write \
             one with `--mode tune --arms 1:hybrid@0.1,1:hybrid@0.25,1:hybrid@0.5 \
             --save-model {path}`)"
        )
    })?;
    log_info!("cluster", "reusing learned hybrid threshold {t} from {path}");
    Ok(Some(t))
}

/// `num` that degrades NaN/∞ to JSON null (NaN is not valid JSON).
fn fnum(x: f64) -> Json {
    if x.is_finite() {
        num(x)
    } else {
        Json::Null
    }
}

/// The machine-readable run summary (`--json`). Always includes the
/// pruned-eval counter and the per-phase timings — the human output only
/// mentions pruning when the bounded engine actually avoided work.
#[allow(clippy::too_many_arguments)]
fn run_summary_json(
    dataset: &str,
    m: usize,
    n: usize,
    k: usize,
    chunk_size: usize,
    engine: &str,
    mode: &str,
    hybrid_threshold: Option<f64>,
    r: &BigMeansResult,
    wall: f64,
) -> Json {
    obj(vec![
        ("dataset", jstr(dataset)),
        ("m", num(m as f64)),
        ("n", num(n as f64)),
        ("k", num(k as f64)),
        ("chunk_size", num(chunk_size as f64)),
        ("engine", jstr(engine)),
        ("isa", jstr(active_isa().name())),
        ("mode", jstr(mode)),
        ("objective", fnum(r.objective)),
        ("best_chunk_objective", fnum(r.best_chunk_objective)),
        ("chunks", num(r.counters.chunks as f64)),
        ("improvements", num(r.improvements as f64)),
        ("distance_evals", num(r.counters.distance_evals as f64)),
        ("pruned_evals", num(r.counters.pruned_evals as f64)),
        ("pruned_blocks", num(r.counters.pruned_blocks as f64)),
        ("hybrid_switches", num(r.counters.hybrid_switches as f64)),
        ("hybrid_threshold", hybrid_threshold.map(num).unwrap_or(Json::Null)),
        ("hybrid_rescans", num(r.counters.hybrid_rescans as f64)),
        ("hybrid_scan_rows", num(r.counters.hybrid_scan_rows as f64)),
        ("hybrid_rescan_rate", num(r.counters.hybrid_rescan_rate())),
        ("chunk_iterations", num(r.counters.chunk_iterations as f64)),
        ("full_iterations", num(r.counters.full_iterations as f64)),
        ("cpu_init_secs", num(r.cpu_init_secs)),
        ("cpu_full_secs", num(r.cpu_full_secs)),
        ("wall_secs", num(wall)),
    ])
}

fn cmd_cluster(args: &Args) -> Result<(), String> {
    let backend = match args.choice("backend", &["mem", "mmap", "buffered", "block"])? {
        "mmap" => DataBackend::Mmap,
        "buffered" => DataBackend::Buffered,
        "block" => DataBackend::Block,
        _ => DataBackend::InMemory,
    };
    let k = args.usize("k", 10)?;
    let s = args.usize("s", 4096)?;
    let time = args.f64("time", 3.0)?;
    let chunks = args.u64("chunks", 0)?;
    let stop = if chunks > 0 {
        StopCondition::TimeOrChunks(Duration::from_secs_f64(time), chunks)
    } else {
        StopCondition::MaxTime(Duration::from_secs_f64(time))
    };
    let mode_arg =
        args.choice("mode", &["inner", "chunks", "seq", "tune", "stream", "serve"])?;
    if mode_arg == "serve" {
        // `cluster --mode serve model.bmm` is the serve subcommand: no
        // dataset to load, no search to run.
        return cmd_serve(args);
    }
    let mode = match mode_arg {
        "chunks" | "tune" => ParallelMode::ChunkParallel,
        "seq" | "stream" => ParallelMode::Sequential,
        _ => ParallelMode::InnerParallel,
    };
    let reinit = match args.get_or("reinit", "kmeanspp") {
        "kmeanspp" => ReinitStrategy::KmeansPP,
        "random" => ReinitStrategy::Random,
        other => return Err(format!("bad --reinit '{other}'")),
    };
    let engine_arg =
        args.choice("engine", &["panel", "native", "bounded", "elkan", "hybrid", "pjrt"])?;
    apply_isa_flag(args)?;
    let engine = if engine_arg == "pjrt" { Engine::Pjrt } else { Engine::Native };
    // `KernelEngineKind::parse` is the source of truth for kernel tokens;
    // "native" (compat alias) and "pjrt" fall back to the panel kernel.
    let kernel = KernelEngineKind::parse(engine_arg).unwrap_or(KernelEngineKind::Panel);
    let hybrid_threshold = resolve_hybrid_threshold(args)?;
    let mut cfg = BigMeansConfig::new(k, s)
        .with_stop(stop)
        .with_parallel(mode)
        .with_backend(backend)
        .with_kernel(kernel)
        .with_hybrid_threshold(hybrid_threshold)
        .with_seed(args.u64("seed", 0xB16_3EA5)?);
    cfg.reinit = reinit;
    cfg.index_stride = args.usize("index-stride", 1)?;
    cfg.threads = args.usize("threads", 0)?;
    cfg.skip_final_assignment = args.flag("skip-final");
    cfg.engine = engine;

    // Observability sinks. All are pure observers: enabling them never
    // changes labels or objectives (gated by tests/property_obs.rs).
    let metrics_out = args.get("metrics-out").map(PathBuf::from);
    let metrics_push = args.get("metrics-push").map(str::to_string);
    if metrics_out.is_some() || metrics_push.is_some() {
        obs::metrics().enable();
        obs::register_core(kernel.name(), active_isa().name());
    }
    if let Some(p) = args.get("trace") {
        obs::tracer().enable(Path::new(p));
    }
    // The flight recorder is always on: a panic or SIGTERM dumps the last
    // few seconds of spans/logs/metric snapshots to the --diag path, and
    // the crash handlers close the --trace JSON so it stays parseable.
    // Handlers install first: they block SIGTERM before any obs thread
    // spawns, so the signal can only land on the watcher's sigwait.
    obs::install_crash_handlers();
    obs::recorder().enable(Path::new(args.get_or("diag", "bigmeans.diag.json")));
    let report_out = args.get("report").map(PathBuf::from);
    if report_out.is_some() {
        obs::report_sink().enable();
    }

    // The config's backend choice decides how the dataset file is opened.
    let data = load_source(args, cfg.backend, cfg.index_stride)?;

    log_info!(
        "cluster",
        "dataset '{}': m={}, n={}  |  k={k}, s={s}, engine={engine:?}/{kernel:?}, mode={mode_arg}, backend={backend:?}",
        data.name(),
        data.m(),
        data.n(),
    );
    log_info!("cluster", "distance kernels: isa={}", active_isa().name());
    match mode_arg {
        // The tune/stream paths drive native solvers directly; erroring
        // beats silently relabelling a PJRT request as native numbers.
        "tune" | "stream" if engine == Engine::Pjrt => {
            return Err(format!(
                "--engine pjrt is not supported with --mode {mode_arg}; use \
                 --engine panel or --engine bounded"
            ));
        }
        "tune" => {
            let run = run_tune(args, cfg, data);
            flush_obs(metrics_out.as_deref(), metrics_push.as_deref())?;
            return run;
        }
        "stream" => {
            let run = run_stream(args, cfg, data);
            flush_obs(metrics_out.as_deref(), metrics_push.as_deref())?;
            return run;
        }
        _ => {}
    }
    // The active threshold only exists for the hybrid engine: the
    // configured/learned override, or the engine's built-in default.
    let active_threshold = (kernel == KernelEngineKind::Hybrid)
        .then(|| cfg.hybrid_threshold.unwrap_or(DEFAULT_HYBRID_THRESHOLD));
    let bm = match engine {
        Engine::Native => BigMeans::new(cfg),
        Engine::Pjrt => runtime::pjrt_bigmeans(cfg, &runtime::default_artifacts_dir())
            .map_err(|e| format!("pjrt engine: {e}"))?,
    };
    let t0 = std::time::Instant::now();
    let r = bm.run(data.as_ref())?;
    let wall = t0.elapsed().as_secs_f64();
    println!("objective (full SSE)     : {:.6e}", r.objective);
    println!("best chunk objective     : {:.6e}", r.best_chunk_objective);
    println!("chunks processed (n_s)   : {}", r.counters.chunks);
    println!("incumbent improvements   : {}", r.improvements);
    println!("distance evals (n_d)     : {:.3e}", r.counters.distance_evals as f64);
    if r.counters.pruned_evals > 0 {
        println!("pruned evals (avoided)   : {:.3e}", r.counters.pruned_evals as f64);
    }
    if r.counters.pruned_blocks > 0 {
        println!("pruned blocks (final)    : {}", r.counters.pruned_blocks);
    }
    if let Some(t) = active_threshold {
        println!("hybrid threshold         : {t}");
        println!("hybrid rescan rate       : {:.4}", r.counters.hybrid_rescan_rate());
    }
    println!("cpu_init / cpu_full      : {:.3}s / {:.3}s", r.cpu_init_secs, r.cpu_full_secs);
    println!("wall time                : {wall:.3}s");
    if let Some(path) = args.get("save-model") {
        save_model(
            path,
            args,
            data.name(),
            engine_arg,
            mode_arg,
            k,
            s,
            data.n(),
            active_threshold,
            &r,
        )?;
    }
    if args.flag("json") {
        let doc = run_summary_json(
            data.name(),
            data.m(),
            data.n(),
            k,
            s,
            engine_arg,
            mode_arg,
            active_threshold,
            &r,
            wall,
        );
        println!("{}", doc.to_string());
    }
    if let Some(path) = report_out.as_deref() {
        let mut rep = obs::RunReport::new(mode_arg);
        rep.config = report_config(data.name(), data.m(), data.n(), k, s, engine_arg, backend);
        rep.shots = obs::report_sink().drain();
        rep.result = vec![
            ("objective", fnum(r.objective)),
            ("best_chunk_objective", fnum(r.best_chunk_objective)),
            ("improvements", num(r.improvements as f64)),
            ("hybrid_threshold", active_threshold.map(num).unwrap_or(Json::Null)),
            ("cpu_init_secs", num(r.cpu_init_secs)),
            ("cpu_full_secs", num(r.cpu_full_secs)),
            ("wall_secs", num(wall)),
        ];
        rep.counters = report_counters(&r.counters);
        write_report(path, &rep)?;
    }
    flush_obs(metrics_out.as_deref(), metrics_push.as_deref())
}

/// Flush the per-run observability sinks: the `--metrics-out` Prometheus
/// exposition, the `--metrics-push` gateway POST, and the `--trace`
/// Chrome trace document.
fn flush_obs(metrics_out: Option<&Path>, metrics_push: Option<&str>) -> Result<(), String> {
    if let Some(path) = metrics_out {
        std::fs::write(path, obs::metrics().render())
            .map_err(|e| format!("write metrics {}: {e}", path.display()))?;
        log_info!("obs", "wrote metrics exposition {}", path.display());
    }
    if let Some(addr) = metrics_push {
        obs::http::push_exposition(addr, "bigmeans", &obs::metrics().render())?;
        log_info!("obs", "pushed metrics exposition to {addr}");
    }
    if let Some(path) = obs::tracer().flush()? {
        log_info!("obs", "wrote trace {}", path.display());
    }
    Ok(())
}

/// Run-configuration echo shared by every mode's `--report` document.
fn report_config(
    dataset: &str,
    m: usize,
    n: usize,
    k: usize,
    chunk_size: usize,
    engine: &str,
    backend: DataBackend,
) -> Vec<(&'static str, Json)> {
    vec![
        ("dataset", jstr(dataset)),
        ("m", num(m as f64)),
        ("n", num(n as f64)),
        ("k", num(k as f64)),
        ("chunk_size", num(chunk_size as f64)),
        ("engine", jstr(engine)),
        ("isa", jstr(active_isa().name())),
        ("backend", jstr(&format!("{backend:?}"))),
    ]
}

/// The work counters every mode's `--report` document carries.
fn report_counters(c: &bigmeans::metrics::Counters) -> Vec<(&'static str, Json)> {
    vec![
        ("distance_evals", num(c.distance_evals as f64)),
        ("pruned_evals", num(c.pruned_evals as f64)),
        ("pruned_blocks", num(c.pruned_blocks as f64)),
        ("hybrid_switches", num(c.hybrid_switches as f64)),
        ("hybrid_rescans", num(c.hybrid_rescans as f64)),
        ("hybrid_scan_rows", num(c.hybrid_scan_rows as f64)),
        ("hybrid_rescan_rate", num(c.hybrid_rescan_rate())),
        ("chunks", num(c.chunks as f64)),
        ("chunk_iterations", num(c.chunk_iterations as f64)),
        ("full_iterations", num(c.full_iterations as f64)),
    ]
}

/// Lint and write one `--report` run-report JSON document.
fn write_report(path: &Path, report: &obs::RunReport) -> Result<(), String> {
    let doc = report.to_json();
    obs::report::lint_report(&doc).map_err(|e| format!("internal: {e}"))?;
    std::fs::write(path, doc.to_string() + "\n")
        .map_err(|e| format!("write report {}: {e}", path.display()))?;
    log_info!("obs", "wrote run report {}", path.display());
    Ok(())
}

/// `--mode tune`: race the arm portfolio under a bandit controller.
fn run_tune(args: &Args, cfg: BigMeansConfig, data: Box<dyn DataSource>) -> Result<(), String> {
    let controller = ControllerKind::parse(args.choice("tuner", &["ucb", "softmax"])?)
        .expect("choice() already validated the token");
    let mut tcfg = TunerConfig::default().with_controller(controller);
    if let Some(spec) = args.get("arms") {
        tcfg.arms = TunerConfig::parse_arms(spec)?;
    }
    tcfg.exploration = args.f64("exploration", tcfg.exploration)?;
    tcfg.temperature = args.f64("temperature", tcfg.temperature)?;
    tcfg.validation_rows = args.usize("validation-rows", tcfg.validation_rows)?;

    let t0 = std::time::Instant::now();
    let race = tuner::run_race(&cfg, &tcfg, data.as_ref())?;
    let wall = t0.elapsed().as_secs_f64();
    let r = &race.result;
    println!("objective (full SSE)     : {:.6e}", r.objective);
    println!("validation objective     : {:.6e}", race.validation_objective);
    println!("shots (n_s)              : {}", r.counters.chunks);
    println!("incumbent improvements   : {}", r.improvements);
    println!("chosen sample size       : {}", race.chosen_chunk_rows);
    if let Some(t) = race.chosen_threshold {
        println!("chosen hybrid threshold  : {t}");
        println!("hybrid rescan rate       : {:.4}", r.counters.hybrid_rescan_rate());
    }
    println!("controller               : {}", race.trace.controller);
    for arm in &race.trace.arms {
        println!(
            "  arm {:<16} rows {:>8}  pulls {:>5}  accepted {:>4}  mean reward {:.4}",
            arm.label, arm.chunk_rows, arm.pulls, arm.accepted, arm.mean_reward()
        );
    }
    println!("distance evals (n_d)     : {:.3e}", r.counters.distance_evals as f64);
    if r.counters.pruned_evals > 0 {
        println!("pruned evals (avoided)   : {:.3e}", r.counters.pruned_evals as f64);
    }
    println!("cpu_init / cpu_full      : {:.3}s / {:.3}s", r.cpu_init_secs, r.cpu_full_secs);
    println!("wall time                : {wall:.3}s");
    if let Some(path) = args.get("save-model") {
        // The learned threshold rides along in the meta, so a later
        // `cluster --reuse-threshold` or `serve` run can pick it up.
        save_model(
            path,
            args,
            data.name(),
            cfg.kernel.name(),
            "tune",
            cfg.k,
            race.chosen_chunk_rows,
            data.n(),
            race.chosen_threshold,
            r,
        )?;
    }
    if args.flag("json") {
        let kernel_name = cfg.kernel.name();
        let summary = run_summary_json(
            data.name(),
            data.m(),
            data.n(),
            cfg.k,
            cfg.chunk_size,
            kernel_name,
            "tune",
            race.chosen_threshold,
            r,
            wall,
        );
        let doc = obj(vec![
            ("run", summary),
            ("tuner", race.trace.to_json()),
            ("validation_objective", fnum(race.validation_objective)),
            ("chosen_chunk_rows", num(race.chosen_chunk_rows as f64)),
            ("chosen_threshold", race.chosen_threshold.map(num).unwrap_or(Json::Null)),
        ]);
        println!("{}", doc.to_string());
    }
    if let Some(path) = args.get("report").map(PathBuf::from) {
        let mut rep = obs::RunReport::new("tune");
        rep.config = report_config(
            data.name(),
            data.m(),
            data.n(),
            cfg.k,
            cfg.chunk_size,
            cfg.kernel.name(),
            cfg.backend,
        );
        rep.shots = obs::report_sink().drain();
        rep.result = vec![
            ("objective", fnum(r.objective)),
            ("validation_objective", fnum(race.validation_objective)),
            ("chosen_chunk_rows", num(race.chosen_chunk_rows as f64)),
            ("chosen_threshold", race.chosen_threshold.map(num).unwrap_or(Json::Null)),
            ("improvements", num(r.improvements as f64)),
            ("wall_secs", num(wall)),
        ];
        rep.counters = report_counters(&r.counters);
        rep.tuner = Some(race.trace.to_json());
        write_report(&path, &rep)?;
    }
    Ok(())
}

/// `--mode stream`: feed the source through the backpressured queue into
/// the streaming consumer, with the optional reservoir drift check.
fn run_stream(args: &Args, cfg: BigMeansConfig, data: Box<dyn DataSource>) -> Result<(), String> {
    cfg.validate(data.m(), data.n())?;
    let validate_every = args.u64("validate-every", 0)?;
    let validation_rows =
        args.usize("validation-rows", bigmeans::coordinator::stream::DEFAULT_VALIDATION_ROWS)?;
    let drift_action = match args.choice("drift-action", &["none", "reseed"])? {
        "reseed" => DriftAction::Reseed,
        _ => DriftAction::None,
    };
    if drift_action == DriftAction::Reseed && validate_every == 0 {
        return Err(
            "--drift-action reseed needs the drift check: set --validate-every N".into()
        );
    }
    let publish_path = match args.get("publish") {
        Some(p) if !p.ends_with(".bmm") => {
            return Err(format!("--publish output must be a .bmm path, got '{p}'"));
        }
        other => other.map(PathBuf::from),
    };
    let rows_per_chunk = cfg.chunk_size.max(1);
    // The config moves into the engine; the report echo needs these after.
    let (cfg_k, cfg_kernel, cfg_backend) = (cfg.k, cfg.kernel, cfg.backend);
    let n = data.n();
    let engine = StreamingBigMeans::new(cfg, n)
        .with_validation(validate_every, validation_rows)
        .with_drift_action(drift_action);
    let engine = match publish_path {
        None => engine,
        Some(path) => {
            // Every incumbent improvement becomes an atomically rewritten
            // `.bmm`; a `serve --watch` daemon hot-swaps each one. The
            // improvement ordinal is the publisher generation, so the
            // watcher's content-identity check sees monotonic progress.
            let dataset = data.name().to_string();
            engine.with_publish(Box::new(move |centroids, objective, ordinal| {
                let k = centroids.len() / n;
                let meta = obj(vec![
                    ("dataset", jstr(&dataset)),
                    ("mode", jstr("stream")),
                    ("improvement", num(ordinal as f64)),
                ]);
                let saved =
                    ModelArtifact::new(k, n, ordinal, objective, meta, centroids.to_vec())
                        .and_then(|a| a.save(&path));
                if let Err(e) = saved {
                    log_warn!("stream.publish", "deferred to next improvement ({e})");
                }
            }))
        }
    };
    let queue = ChunkQueue::new(8);
    let t0 = std::time::Instant::now();
    let r = std::thread::scope(|scope| {
        let producer_q = Arc::clone(&queue);
        let src: &dyn DataSource = data.as_ref();
        scope.spawn(move || {
            produce_from_source(src, &producer_q, rows_per_chunk);
            producer_q.close();
        });
        let r = engine.run(&queue);
        // The consumer may stop on its budget while the producer is blocked
        // on a full queue — close it so the producer unblocks and the scope
        // can join.
        queue.close();
        r
    });
    let wall = t0.elapsed().as_secs_f64();
    println!("best chunk objective     : {:.6e}", r.best_chunk_objective);
    println!("chunks processed (n_s)   : {}", r.chunks_processed);
    println!("incumbent improvements   : {}", r.improvements);
    if validate_every > 0 {
        println!("drift events             : {}", r.drift_events);
        if drift_action == DriftAction::Reseed {
            println!("drift remediations       : {}", r.remediations);
        }
        for p in &r.validation_trace {
            println!("  chunk {:>6}  validation mean SSE {:.6e}", p.chunk, p.objective);
        }
    }
    println!("distance evals (n_d)     : {:.3e}", r.counters.distance_evals as f64);
    println!("wall time                : {wall:.3}s");
    if args.flag("json") {
        let doc = obj(vec![
            ("dataset", jstr(data.name())),
            ("mode", jstr("stream")),
            ("best_chunk_objective", fnum(r.best_chunk_objective)),
            ("chunks", num(r.chunks_processed as f64)),
            ("improvements", num(r.improvements as f64)),
            ("distance_evals", num(r.counters.distance_evals as f64)),
            ("pruned_evals", num(r.counters.pruned_evals as f64)),
            ("drift_events", num(r.drift_events as f64)),
            ("remediations", num(r.remediations as f64)),
            (
                "validation_trace",
                bigmeans::util::json::arr(
                    r.validation_trace
                        .iter()
                        .map(|p| {
                            obj(vec![
                                ("chunk", num(p.chunk as f64)),
                                ("objective", fnum(p.objective)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("wall_secs", num(wall)),
        ]);
        println!("{}", doc.to_string());
    }
    if let Some(path) = args.get("report").map(PathBuf::from) {
        let mut rep = obs::RunReport::new("stream");
        rep.config = report_config(
            data.name(),
            data.m(),
            data.n(),
            cfg_k,
            rows_per_chunk,
            cfg_kernel.name(),
            cfg_backend,
        );
        rep.shots = obs::report_sink().drain();
        rep.result = vec![
            ("best_chunk_objective", fnum(r.best_chunk_objective)),
            ("chunks", num(r.chunks_processed as f64)),
            ("improvements", num(r.improvements as f64)),
            ("wall_secs", num(wall)),
        ];
        rep.counters = report_counters(&r.counters);
        rep.stream = Some(obj(vec![
            ("drift_events", num(r.drift_events as f64)),
            ("remediations", num(r.remediations as f64)),
            (
                "validation_trace",
                bigmeans::util::json::arr(
                    r.validation_trace
                        .iter()
                        .map(|p| {
                            obj(vec![
                                ("chunk", num(p.chunk as f64)),
                                ("objective", fnum(p.objective)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));
        write_report(&path, &rep)?;
    }
    Ok(())
}

/// `--save-model`: persist the winning centroids as a `.bmm` serving
/// artifact (publisher generation 1) with run provenance in the metadata.
/// A `Some` hybrid threshold (configured, or tuner-learned in `--mode
/// tune`) is recorded under `hybrid_threshold` so `--reuse-threshold`
/// and the serve stats document can surface it.
#[allow(clippy::too_many_arguments)]
fn save_model(
    path: &str,
    args: &Args,
    dataset: &str,
    engine: &str,
    mode: &str,
    k: usize,
    chunk_size: usize,
    n: usize,
    hybrid_threshold: Option<f64>,
    r: &BigMeansResult,
) -> Result<(), String> {
    if !path.ends_with(".bmm") {
        return Err(format!("--save-model output must be a .bmm path, got '{path}'"));
    }
    let mut meta_entries = vec![
        ("dataset", jstr(dataset)),
        ("engine", jstr(engine)),
        ("mode", jstr(mode)),
        ("k", num(k as f64)),
        ("n", num(n as f64)),
        ("chunk_size", num(chunk_size as f64)),
        ("seed", num(args.u64("seed", 0xB16_3EA5)? as f64)),
    ];
    if let Some(t) = hybrid_threshold {
        meta_entries.push(("hybrid_threshold", num(t)));
    }
    let meta = obj(meta_entries);
    ModelArtifact::new(k, n, 1, r.objective, meta, r.centroids.clone())
        .and_then(|a| a.save(&PathBuf::from(path)))
        .map_err(|e| e.to_string())?;
    log_info!(
        "cluster",
        "saved model artifact {path} (k={k}, n={n}, objective {:.6e})",
        r.objective
    );
    Ok(())
}

/// `serve <model.bmm>`: the long-running clustering daemon.
fn cmd_serve(args: &Args) -> Result<(), String> {
    let Some(model_path) = args.positional().first() else {
        return Err("usage: serve <model.bmm> [--addr HOST:PORT] [--watch]".into());
    };
    if !model_path.ends_with(".bmm") {
        return Err(format!("serve needs a .bmm model artifact, got '{model_path}'"));
    }
    let path = PathBuf::from(model_path);
    apply_isa_flag(args)?;
    // The flight recorder always runs (it feeds the dump-diagnostics op);
    // crashes only write a file when --diag names one. Handlers install
    // first so SIGTERM is blocked before any obs thread spawns.
    obs::install_crash_handlers();
    match args.get("diag") {
        Some(p) => obs::recorder().enable(Path::new(p)),
        None => obs::recorder().enable_unsinked(),
    }
    // Enable metrics before the model registry and server exist, so their
    // boot-time registrations (swap gauge, per-op families) record.
    let metrics_addr = args.get("metrics-addr");
    if metrics_addr.is_some() {
        obs::metrics().enable();
        obs::register_core("serve", active_isa().name());
    }
    let artifact = ModelArtifact::load(&path).map_err(|e| e.to_string())?;
    let identity = (artifact.generation, artifact.payload_crc());
    log_info!(
        "serve",
        "serving {model_path}: k={}, n={}, publisher generation {}, objective {:.6e}",
        artifact.k,
        artifact.n,
        artifact.generation,
        artifact.objective
    );
    log_info!("serve", "distance kernels: isa={}", active_isa().name());
    let registry = ModelRegistry::new(artifact);
    let metrics_server = match metrics_addr {
        None => None,
        Some(maddr) => {
            let health_registry = Arc::clone(&registry);
            let health: obs::http::HealthFn = Arc::new(move || {
                obj(vec![
                    ("status", jstr("ok")),
                    ("generation", num(health_registry.generation() as f64)),
                    ("swaps", num(health_registry.swaps() as f64)),
                    ("swap_history", health_registry.history_json()),
                ])
            });
            let ms =
                obs::MetricsServer::start_with_health(maddr, obs::metrics(), Some(health))?;
            log_info!("serve", "metrics exposition on http://{}/metrics", ms.addr());
            Some(ms)
        }
    };
    let opts = ServeOptions {
        threads: args.usize("threads", 0)?,
        max_batch_rows: args.usize("max-batch", 1 << 20)?,
    };
    let addr = args.get_or("addr", "127.0.0.1:7171");
    let server = Server::bind(addr, Arc::clone(&registry), opts).map_err(|e| e.to_string())?;
    eprintln!("listening on {}", server.local_addr());
    let stop = server.shutdown_handle();
    let watcher = if args.flag("watch") {
        let interval = Duration::from_millis(args.u64("watch-ms", 500)?.max(1));
        log_info!(
            "serve",
            "watching {model_path} for hot-swaps every {}ms",
            interval.as_millis()
        );
        Some(spawn_watcher(Arc::clone(&registry), path, interval, Arc::clone(&stop), identity))
    } else {
        None
    };
    let stats = server.stats();
    let run = server.run().map_err(|e| e.to_string());
    stop.store(true, Ordering::SeqCst);
    if let Some(handle) = watcher {
        let _ = handle.join();
    }
    if let Some(ms) = metrics_server {
        ms.shutdown();
    }
    run?;
    log_info!(
        "serve",
        "served {} requests ({} errors) across {} hot-swaps",
        stats.requests(),
        stats.errors(),
        registry.swaps()
    );
    if args.flag("json") {
        println!("{}", stats.to_json(&registry).to_string());
    }
    Ok(())
}

/// `query <host:port>`: one-shot client for a running daemon.
fn cmd_query(args: &Args) -> Result<(), String> {
    let Some(addr) = args.positional().first() else {
        return Err(
            "usage: query <host:port> \
             [--op assign|score|stats|ping|dump-diagnostics|shutdown]"
                .into(),
        );
    };
    let op = args
        .choice("op", &["assign", "score", "stats", "ping", "dump-diagnostics", "shutdown"])?;
    let mut client = Client::connect(addr).map_err(|e| e.to_string())?;
    match op {
        "stats" => {
            let (generation, json) = client.stats().map_err(|e| e.to_string())?;
            eprintln!("swap generation {generation}");
            println!("{json}");
            return Ok(());
        }
        "dump-diagnostics" => {
            let (generation, json) = client.dump_diagnostics().map_err(|e| e.to_string())?;
            eprintln!("swap generation {generation}");
            println!("{json}");
            return Ok(());
        }
        "ping" => {
            let generation = client.ping().map_err(|e| e.to_string())?;
            println!("pong (swap generation {generation})");
            return Ok(());
        }
        "shutdown" => {
            let generation = client.shutdown().map_err(|e| e.to_string())?;
            println!("daemon shutting down (swap generation {generation})");
            return Ok(());
        }
        _ => {}
    }
    let Some(file) = args.get("file") else {
        return Err(format!("--op {op} needs --file <dataset> (.csv/.fbin/.bmx)"));
    };
    let source = loader::open_source_with(&PathBuf::from(file), DataBackend::InMemory, 1)
        .map_err(|e| e.to_string())?;
    let (m, n) = (source.m(), source.n());
    let rows = args.usize("rows", m.min(1024))?.min(m);
    if rows == 0 {
        return Err(format!("'{file}' has no rows to send"));
    }
    let mut points = vec![0f32; rows * n];
    source.read_rows(0, &mut points);
    let t0 = std::time::Instant::now();
    if op == "assign" {
        let (generation, labels) =
            client.assign(&points, rows, n).map_err(|e| e.to_string())?;
        let wall = t0.elapsed().as_secs_f64();
        let distinct = labels.iter().collect::<std::collections::BTreeSet<_>>().len();
        println!(
            "assigned {rows} rows in {:.1}ms (swap generation {generation}, {distinct} \
             distinct labels)",
            wall * 1e3
        );
        if args.flag("json") {
            let doc = obj(vec![
                ("op", jstr("assign")),
                ("generation", num(generation as f64)),
                ("rows", num(rows as f64)),
                ("wall_secs", num(wall)),
                (
                    "labels",
                    bigmeans::util::json::arr(
                        labels.iter().map(|&l| num(l as f64)).collect(),
                    ),
                ),
            ]);
            println!("{}", doc.to_string());
        }
    } else {
        let (generation, labels, dists, objective) =
            client.score(&points, rows, n).map_err(|e| e.to_string())?;
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "scored {rows} rows in {:.1}ms (swap generation {generation}, batch SSE \
             {objective:.6e})",
            wall * 1e3
        );
        if args.flag("json") {
            let doc = obj(vec![
                ("op", jstr("score")),
                ("generation", num(generation as f64)),
                ("rows", num(rows as f64)),
                ("objective", fnum(objective)),
                ("wall_secs", num(wall)),
                (
                    "labels",
                    bigmeans::util::json::arr(
                        labels.iter().map(|&l| num(l as f64)).collect(),
                    ),
                ),
                (
                    "dists",
                    bigmeans::util::json::arr(
                        dists.iter().map(|&d| fnum(f64::from(d))).collect(),
                    ),
                ),
            ]);
            println!("{}", doc.to_string());
        }
    }
    Ok(())
}

/// `metrics-lint <file> [file]`: CI's lint gate. `.json` files validate
/// as run-report documents, everything else as Prometheus text
/// exposition; two expositions additionally get a counter-monotonicity
/// check in argument order (earlier scrape first).
fn cmd_metrics_lint(args: &Args) -> Result<(), String> {
    let pos = args.positional();
    if pos.is_empty() || pos.len() > 2 {
        return Err("usage: metrics-lint <scrape.prom|report.json> [later-scrape.prom]".into());
    }
    let read = |p: &str| std::fs::read_to_string(p).map_err(|e| format!("read {p}: {e}"));
    // Each file lints by its own extension (.json = run report, anything
    // else = Prometheus exposition), so a mixed invocation never tries to
    // JSON-parse a .prom scrape. Monotonicity is checked when two
    // expositions are given, in argument order (earlier scrape first).
    let mut expositions: Vec<(&str, obs::lint::Exposition)> = Vec::new();
    for p in pos {
        if p.ends_with(".json") {
            let doc = Json::parse(&read(p)?).map_err(|e| format!("{p}: {e}"))?;
            let shots = obs::report::lint_report(&doc).map_err(|e| format!("{p}: {e}"))?;
            println!("{p}: ok — run report, {shots} shots");
        } else {
            let exp = obs::lint::lint_exposition(&read(p)?).map_err(|e| format!("{p}: {e}"))?;
            println!("{p}: ok — {} families, {} samples", exp.families.len(), exp.samples);
            expositions.push((p.as_str(), exp));
        }
    }
    if let [(first_path, first), (later_path, second)] = &expositions[..] {
        let checked = obs::lint::check_monotone(first, second)
            .map_err(|e| format!("{first_path} -> {later_path}: {e}"))?;
        println!("{later_path}: ok — {checked} counter series monotone across the scrapes");
    }
    Ok(())
}

/// `trace-lint <out.trace.json>`: validate a Chrome trace-event document
/// (complete events with cat/name/ts/dur/pid/tid) and optionally require
/// a minimum number of distinct span categories.
fn cmd_trace_lint(args: &Args) -> Result<(), String> {
    let Some(path) = args.positional().first() else {
        return Err("usage: trace-lint <out.trace.json> [--min-cats N]".into());
    };
    let min_cats = args.usize("min-cats", 1)?;
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| format!("{path}: no traceEvents array"))?;
    let mut cats = std::collections::BTreeSet::new();
    for (i, ev) in events.iter().enumerate() {
        let field = |key: &str| {
            ev.get(key).ok_or_else(|| format!("{path}: event {i} missing '{key}'"))
        };
        let ph = field("ph")?.as_str().unwrap_or_default();
        if ph != "X" {
            return Err(format!("{path}: event {i} has ph '{ph}', expected 'X'"));
        }
        let cat = field("cat")?
            .as_str()
            .ok_or_else(|| format!("{path}: event {i} 'cat' is not a string"))?;
        if field("name")?.as_str().is_none() {
            return Err(format!("{path}: event {i} 'name' is not a string"));
        }
        for key in ["ts", "dur", "pid", "tid"] {
            if field(key)?.as_f64().is_none() {
                return Err(format!("{path}: event {i} '{key}' is not a number"));
            }
        }
        cats.insert(cat.to_string());
    }
    let listed = cats.iter().cloned().collect::<Vec<_>>().join(", ");
    if cats.len() < min_cats {
        return Err(format!(
            "{path}: {} distinct span categories ({listed}), need at least {min_cats}",
            cats.len()
        ));
    }
    println!("{path}: ok — {} events across {} categories ({listed})", events.len(), cats.len());
    Ok(())
}

/// `report <run.json> <out.html>`: render a `cluster --report` document
/// as a self-contained HTML page (lints the document first, so a broken
/// report fails loudly instead of rendering an empty page).
fn cmd_report(args: &Args) -> Result<(), String> {
    let pos = args.positional();
    if pos.len() != 2 {
        return Err("usage: report <run.json> <out.html>".into());
    }
    let text =
        std::fs::read_to_string(&pos[0]).map_err(|e| format!("read {}: {e}", pos[0]))?;
    let doc = Json::parse(&text).map_err(|e| format!("{}: {e}", pos[0]))?;
    let shots = obs::report::lint_report(&doc).map_err(|e| format!("{}: {e}", pos[0]))?;
    let html = obs::report::render_html(&doc);
    std::fs::write(&pos[1], &html).map_err(|e| format!("write {}: {e}", pos[1]))?;
    eprintln!(
        "wrote {} ({shots} shots, {:.1} KiB, self-contained)",
        pos[1],
        html.len() as f64 / 1024.0
    );
    Ok(())
}

/// Parse the shared v3 store knobs (`--block-rows`, `--dtype`, `--codec`,
/// `--no-summaries`, `--threads`) into [`StoreOptions`].
fn store_options(args: &Args) -> Result<StoreOptions, String> {
    let defaults = StoreOptions::default();
    let dtype = Dtype::parse(args.choice("dtype", &["f32", "f64", "f16"])?)
        .expect("choice() already validated the token");
    let codec = Codec::parse(args.choice("codec", &["none", "shuffle", "lz"])?)
        .expect("choice() already validated the token");
    let block_rows = args.usize("block-rows", defaults.block_rows)?;
    if block_rows == 0 {
        return Err("--block-rows must be ≥ 1".into());
    }
    Ok(StoreOptions {
        block_rows,
        dtype,
        codec,
        summaries: !args.flag("no-summaries"),
        threads: args.usize("threads", 0)?,
    })
}

/// Reject v3-only knobs when the output is not a v3 block store (`target`
/// names what was requested, e.g. "--format v2" or ".fbin output").
fn reject_v3_knobs(args: &Args, target: &str) -> Result<(), String> {
    for knob in ["block-rows", "dtype", "codec"] {
        if args.get(knob).is_some() {
            return Err(format!(
                "--{knob} only applies to .bmx v3 output, not {target}"
            ));
        }
    }
    if args.flag("no-summaries") {
        return Err(format!(
            "--no-summaries only applies to .bmx v3 output, not {target}"
        ));
    }
    Ok(())
}

fn cmd_convert(args: &Args) -> Result<(), String> {
    let pos = args.positional();
    if args.flag("add-summaries") {
        // Retrofit mode: decode an existing v3 store and append its
        // summary section in place — no re-encode, no new file.
        let [file] = pos else {
            return Err("usage: convert <file.bmx> --add-summaries".into());
        };
        if !file.ends_with(".bmx") {
            return Err(format!("--add-summaries needs a .bmx v3 file, got '{file}'"));
        }
        let path = PathBuf::from(file);
        if loader::bmx_version(&path).map_err(|e| e.to_string())? != 3 {
            return Err(format!(
                "'{file}' is a legacy flat .bmx; reconvert it to v3 first \
                 (`bigmeans convert` writes v3 by default)"
            ));
        }
        let t0 = std::time::Instant::now();
        let added = bigmeans::store::add_summaries(&path, args.usize("threads", 0)?)
            .map_err(|e| e.to_string())?;
        if added {
            eprintln!(
                "added per-block min/max summaries to {file} in {:.2}s",
                t0.elapsed().as_secs_f64()
            );
        } else {
            eprintln!("{file} already carries summaries — nothing to do");
        }
        return Ok(());
    }
    if pos.len() != 2 {
        return Err("usage: convert <in.csv> <out.bmx>".into());
    }
    if !pos[1].ends_with(".bmx") {
        return Err(format!("output must be a .bmx path, got '{}'", pos[1]));
    }
    let (src, dst) = (PathBuf::from(&pos[0]), PathBuf::from(&pos[1]));
    let format = args.choice("format", &["v3", "v2"])?;
    let t0 = std::time::Instant::now();
    let (m, n, label) = if format == "v2" {
        reject_v3_knobs(args, "--format v2")?;
        let (m, n) = convert::csv_to_bmx(&src, &dst).map_err(|e| e.to_string())?;
        (m, n, "v2 flat".to_string())
    } else {
        let opts = store_options(args)?;
        let (m, n) =
            convert::csv_to_block_store(&src, &dst, opts).map_err(|e| e.to_string())?;
        (m, n, format!("v3 {}/{}", opts.dtype.name(), opts.codec.name()))
    };
    let bytes = std::fs::metadata(&dst).map(|md| md.len()).unwrap_or(0);
    eprintln!(
        "wrote {} ({m} × {n}, {label}, {:.1} MiB on disk) in {:.2}s",
        pos[1],
        bytes as f64 / (1 << 20) as f64,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<(), String> {
    let Some(name) = args.positional().first() else {
        return Err("usage: verify <file.bmx>".into());
    };
    let path = PathBuf::from(name);
    let threads = args.usize("threads", 0)?;
    let t0 = std::time::Instant::now();
    match loader::bmx_version(&path).map_err(|e| e.to_string())? {
        3 => {
            let store = BlockStore::open(&path).map_err(|e| e.to_string())?;
            let report = store.verify_all(threads).map_err(|e| e.to_string())?;
            eprintln!(
                "ok: {} — {} blocks ({} × {}, {}/{}, {}), {:.1} MiB encoded payload \
                 verified in {:.2}s",
                name,
                report.blocks,
                store.m(),
                store.n(),
                store.dtype().name(),
                store.codec().name(),
                if store.has_summaries() {
                    "summaries consistent"
                } else {
                    "no summaries"
                },
                report.encoded_bytes as f64 / (1 << 20) as f64,
                t0.elapsed().as_secs_f64()
            );
        }
        _ => {
            let payload = bigmeans::data::bmx::verify_bmx(&path).map_err(|e| e.to_string())?;
            eprintln!(
                "ok: {} — {:.1} MiB payload CRC verified in {:.2}s (flat v2; reconvert \
                 to v3 for per-block integrity)",
                name,
                payload as f64 / (1 << 20) as f64,
                t0.elapsed().as_secs_f64()
            );
        }
    }
    Ok(())
}

fn cmd_table(args: &Args) -> Result<(), String> {
    let Some(name) = args.positional().first() else {
        return Err("missing <dataset> argument".into());
    };
    let entry = catalog::find(name)
        .ok_or_else(|| format!("no catalog dataset matching '{name}'"))?;
    let data = entry.generate(args.u64("data-seed", 20220418)?);
    let k_grid = args.usize_list("k", &PAPER_K_GRID)?;
    let n_exec = args.usize("n-exec", 3)?;
    let roster = if args.flag("full") {
        bench_harness::paper_roster(&entry)
    } else {
        bench_harness::quick_roster(&entry)
    };
    eprintln!(
        "running {} algorithms × {} k-values × {} reps on '{}' (m={}, n={})",
        roster.len(),
        k_grid.len(),
        n_exec,
        entry.name,
        data.m(),
        data.n()
    );
    let exp = bench_harness::run_experiment(&data, &roster, &k_grid, n_exec, 42);
    let summary = tables::summary_table(&exp);
    let details = tables::details_table(&exp);
    let md = format!(
        "{}\n{}",
        report::render_summary_markdown(&summary),
        report::render_details_markdown(&exp.dataset, &details)
    );
    println!("{md}");
    let path = report::write_report(&format!("table_{}.md", entry.table), &md);
    eprintln!("written to {}", path.display());
    Ok(())
}

fn cmd_summary(args: &Args) -> Result<(), String> {
    let n_exec = args.usize("n-exec", 2)?;
    let entries = if args.flag("quick") {
        catalog::quick_subset()
    } else {
        catalog::catalog()
    };
    let mut all_scores = Vec::new();
    for entry in &entries {
        let data = entry.generate(20220418);
        let roster = bench_harness::paper_roster(entry);
        eprintln!("[table {}] {} …", entry.table, entry.name);
        let exp = bench_harness::run_experiment(&data, &roster, &PAPER_K_GRID, n_exec, 42);
        all_scores.push(tables::dataset_scores(&exp));
    }
    let t4 = tables::table4(&all_scores);
    let md = report::render_table4_markdown(&t4, entries.len());
    println!("{md}");
    let path = report::write_report("table_3_4_summary.md", &md);
    eprintln!("written to {}", path.display());
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<(), String> {
    let pos = args.positional();
    if pos.len() != 2 {
        return Err("usage: generate <catalog-name> <out.fbin|out.bmx>".into());
    }
    let entry =
        catalog::find(&pos[0]).ok_or_else(|| format!("no catalog dataset '{}'", pos[0]))?;
    let data = entry.generate(args.u64("data-seed", 20220418)?);
    let out = PathBuf::from(&pos[1]);
    if pos[1].ends_with(".fbin") {
        reject_v3_knobs(args, ".fbin output")?;
        if args.get("format").is_some() {
            return Err("--format only applies to .bmx output".into());
        }
        loader::save_fbin(&data, &out).map_err(|e| e.to_string())?;
    } else if pos[1].ends_with(".bmx") {
        if args.choice("format", &["v3", "v2"])? == "v2" {
            reject_v3_knobs(args, "--format v2")?;
            bigmeans::data::save_bmx(&data, &out).map_err(|e| e.to_string())?;
        } else {
            let opts = store_options(args)?;
            copy_to_store(&data, &out, opts).map_err(|e| e.to_string())?;
        }
    } else {
        return Err("only .fbin / .bmx output supported".into());
    }
    eprintln!("wrote {} ({} × {})", out.display(), data.m(), data.n());
    Ok(())
}

fn cmd_catalog() -> Result<(), String> {
    println!(
        "{:<50} {:>9} {:>5} {:>9} {:>5} {:>8} {:>8}",
        "name", "paper_m", "p_n", "m", "n", "s", "cpu_max"
    );
    for e in catalog::catalog() {
        println!(
            "{:<50} {:>9} {:>5} {:>9} {:>5} {:>8} {:>8.2}",
            e.name, e.paper_m, e.paper_n, e.m, e.n, e.chunk_size, e.cpu_max_secs
        );
    }
    Ok(())
}

fn cmd_artifacts() -> Result<(), String> {
    let dir = runtime::default_artifacts_dir();
    let manifest = runtime::Manifest::load(&dir)
        .map_err(|e| format!("{e} (run `make artifacts` first)"))?;
    println!("{} variants in {}", manifest.variants.len(), dir.display());
    for v in &manifest.variants {
        println!(
            "  {:<28} kind={:<9} s={:<6} n={:<4} k={:<3} block_s={}",
            v.name,
            format!("{:?}", v.kind),
            v.s,
            v.n,
            v.k,
            v.block_s
        );
    }
    Ok(())
}
