//! Lloyd's K-means local search (Algorithm 1 of the paper), native rust
//! path. Matches the semantics of the AOT'd L2 `lloyd_chunk`: relative
//! objective tolerance + iteration cap, degenerate clusters left in place.
//!
//! The loop is engine-driven: a [`KernelEngine`] owns the assignment step
//! and a [`LloydState`] persists per-point bounds across iterations, so the
//! pruning engines (Hamerly-bounded, Elkan) skip most distance evaluations
//! once a chunk settles.
//! [`lloyd`] keeps the historical one-shot signature (panel engine);
//! [`lloyd_with_engine`] is the strategy-selectable entry point every
//! pipeline routes through.

use crate::metrics::Counters;
use crate::util::threadpool::ThreadPool;

use super::engine::{KernelEngine, LloydState, PanelEngine};
use super::update::update_centroids;

/// Convergence parameters (paper §5.7: rel-tol 1e-4, cap 300 on the full
/// dataset; chunks use the same rule).
#[derive(Clone, Copy, Debug)]
pub struct LloydParams {
    pub tol: f64,
    pub max_iters: u32,
}

impl Default for LloydParams {
    fn default() -> Self {
        LloydParams { tol: 1e-4, max_iters: 300 }
    }
}

/// Result of a Lloyd run.
#[derive(Clone, Debug)]
pub struct LloydResult {
    /// Final centroids, row-major `(k, n)`.
    pub centroids: Vec<f32>,
    /// SSE of the final centroids on this data.
    pub objective: f64,
    /// Cluster sizes from the final assignment.
    pub counts: Vec<u64>,
    /// Iterations executed (assignment+update pairs).
    pub iters: u32,
}

/// Run Lloyd to convergence with the default [`PanelEngine`], seeded by
/// `centroids`. `pool: Some(_)` uses the parallel assignment (paper's
/// parallelisation strategy 1).
pub fn lloyd(
    points: &[f32],
    centroids: &[f32],
    m: usize,
    n: usize,
    k: usize,
    params: LloydParams,
    pool: Option<&ThreadPool>,
    counters: &mut Counters,
) -> LloydResult {
    lloyd_with_engine(points, centroids, m, n, k, params, pool, &PanelEngine, counters)
}

/// Run Lloyd to convergence through a selectable [`KernelEngine`]. The
/// engine's [`LloydState`] lives for the whole run: each iteration is a
/// stateful `assign_step` followed by `update_centroids` and a bound
/// relaxation ([`LloydState::apply_update`]), so pruning engines carry
/// their bounds from one iteration to the next — including into the final
/// assignment that prices the returned centroids.
#[allow(clippy::too_many_arguments)]
pub fn lloyd_with_engine(
    points: &[f32],
    centroids: &[f32],
    m: usize,
    n: usize,
    k: usize,
    params: LloydParams,
    pool: Option<&ThreadPool>,
    engine: &dyn KernelEngine,
    counters: &mut Counters,
) -> LloydResult {
    assert!(m > 0, "lloyd on empty data");
    let mut c = centroids.to_vec();
    let mut old = vec![0f32; k * n];
    let mut state = LloydState::new(m);
    let mut prev_obj = f64::INFINITY;
    let mut iters = 0u32;

    while iters < params.max_iters {
        let out = match pool {
            Some(p) => engine.assign_step_parallel(p, points, &c, m, n, k, &mut state, counters),
            None => engine.assign_step(points, &c, m, n, k, &mut state, counters),
        };
        iters += 1;
        let obj = out.objective;
        old.copy_from_slice(&c);
        update_centroids(&out.sums, &out.counts, &mut c, k, n);
        state.apply_update(&old, &c, k, n);
        let rel = (prev_obj - obj).abs() / obj.max(1e-300);
        prev_obj = obj;
        if rel <= params.tol {
            break;
        }
    }

    // Final assignment so the reported objective/counts describe the
    // *returned* centroids (same contract as the AOT'd lloyd_chunk). The
    // bounds are valid for `c` (relaxed after the last update), so a
    // pruning engine prices the final centroids almost for free.
    let fin = match pool {
        Some(p) => engine.assign_step_parallel(p, points, &c, m, n, k, &mut state, counters),
        None => engine.assign_step(points, &c, m, n, k, &mut state, counters),
    };
    LloydResult { centroids: c, objective: fin.objective, counts: fin.counts, iters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn blobs(rng: &mut Rng, per: usize, centers: &[(f32, f32)], spread: f32) -> Vec<f32> {
        let mut pts = Vec::with_capacity(per * centers.len() * 2);
        for &(cx, cy) in centers {
            for _ in 0..per {
                pts.push(cx + spread * rng.gaussian() as f32);
                pts.push(cy + spread * rng.gaussian() as f32);
            }
        }
        pts
    }

    #[test]
    fn converges_on_separated_blobs() {
        let mut rng = Rng::new(1);
        let pts = blobs(&mut rng, 100, &[(0.0, 0.0), (20.0, 20.0)], 0.1);
        let seed = vec![1.0f32, 1.0, 19.0, 19.0];
        let mut c = Counters::new();
        let r = lloyd(&pts, &seed, 200, 2, 2, LloydParams::default(), None, &mut c);
        assert!(r.iters < 20, "should converge fast, took {}", r.iters);
        assert_eq!(r.counts, vec![100, 100]);
        // Final centroids near blob centers.
        let near = |c: &[f32], t: (f32, f32)| (c[0] - t.0).abs() < 0.2 && (c[1] - t.1).abs() < 0.2;
        assert!(near(&r.centroids[..2], (0.0, 0.0)) || near(&r.centroids[..2], (20.0, 20.0)));
    }

    #[test]
    fn objective_never_increases_across_reseeds() {
        // Lloyd from the converged solution must not worsen it.
        let mut rng = Rng::new(2);
        let pts = blobs(&mut rng, 50, &[(0.0, 0.0), (5.0, 5.0), (10.0, 0.0)], 0.5);
        let seed: Vec<f32> = pts[..6].to_vec();
        let mut c = Counters::new();
        let r1 = lloyd(&pts, &seed, 150, 2, 3, LloydParams::default(), None, &mut c);
        let r2 = lloyd(&pts, &r1.centroids, 150, 2, 3, LloydParams::default(), None, &mut c);
        assert!(r2.objective <= r1.objective + 1e-6 * r1.objective);
    }

    #[test]
    fn iteration_cap_respected() {
        let mut rng = Rng::new(3);
        let pts: Vec<f32> = (0..2000).map(|_| rng.f32()).collect();
        let seed: Vec<f32> = pts[..10].to_vec();
        let mut c = Counters::new();
        let r = lloyd(
            &pts,
            &seed,
            1000,
            2,
            5,
            LloydParams { tol: 0.0, max_iters: 4 },
            None,
            &mut c,
        );
        assert_eq!(r.iters, 4);
    }

    #[test]
    fn parallel_and_serial_agree() {
        let mut rng = Rng::new(4);
        let pts = blobs(&mut rng, 600, &[(0.0, 0.0), (8.0, 8.0), (16.0, 0.0)], 0.3);
        let seed: Vec<f32> = pts[..6].to_vec();
        let pool = ThreadPool::new(4);
        let mut c1 = Counters::new();
        let mut c2 = Counters::new();
        let a = lloyd(&pts, &seed, 1800, 2, 3, LloydParams::default(), None, &mut c1);
        let b = lloyd(&pts, &seed, 1800, 2, 3, LloydParams::default(), Some(&pool), &mut c2);
        assert_eq!(a.counts, b.counts);
        assert!((a.objective - b.objective).abs() < 1e-6 * a.objective);
    }

    #[test]
    fn bounded_engine_lloyd_matches_panel() {
        use crate::kernels::engine::{BoundedEngine, PanelEngine};
        let mut rng = Rng::new(6);
        let pts = blobs(&mut rng, 150, &[(0.0, 0.0), (12.0, 12.0), (0.0, 12.0)], 0.4);
        let seed: Vec<f32> = pts[..6].to_vec();
        let mut c1 = Counters::new();
        let mut c2 = Counters::new();
        let params = LloydParams::default();
        let a =
            lloyd_with_engine(&pts, &seed, 450, 2, 3, params, None, &PanelEngine, &mut c1);
        let b = lloyd_with_engine(
            &pts,
            &seed,
            450,
            2,
            3,
            params,
            None,
            &BoundedEngine::default(),
            &mut c2,
        );
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.iters, b.iters);
        assert!(
            (a.objective - b.objective).abs() <= 1e-9 * a.objective.abs(),
            "{} vs {}",
            a.objective,
            b.objective
        );
        assert!(c2.pruned_evals > 0, "no pruning on separated blobs");
        assert!(
            c2.distance_evals < c1.distance_evals,
            "bounded ({}) did not beat panel ({})",
            c2.distance_evals,
            c1.distance_evals
        );
    }

    #[test]
    fn distance_evals_accounted() {
        let mut rng = Rng::new(5);
        let pts: Vec<f32> = (0..100 * 3).map(|_| rng.f32()).collect();
        let seed: Vec<f32> = pts[..6].to_vec();
        let mut c = Counters::new();
        let r = lloyd(&pts, &seed, 100, 3, 2, LloydParams::default(), None, &mut c);
        // iters + 1 final assignment, each m*k evals.
        assert_eq!(c.distance_evals, (r.iters as u64 + 1) * 100 * 2);
    }
}
