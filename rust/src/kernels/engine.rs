//! Pluggable kernel engines for the assignment hot path.
//!
//! Every pipeline (sequential, chunk-parallel, streaming, VNS, baselines)
//! runs its Lloyd iterations through a [`KernelEngine`], selected by
//! [`KernelEngineKind`] in the configuration / CLI (`--engine`):
//!
//! * [`PanelEngine`] — the exact blocked-panel path: fused
//!   `‖x‖² − 2x·c + ‖c‖²` panel + in-register argmin
//!   ([`super::distance::sq_dist_panel_argmin`]), every point evaluated
//!   against every centroid each iteration.
//! * [`BoundedEngine`] — Hamerly-style triangle-inequality pruning: one
//!   upper and one lower bound per point, relaxed by per-centroid drift
//!   after each centroid update ([`LloydState::apply_update`]). A point
//!   whose (tightened) upper bound sits below its lower bound keeps its
//!   label with **one** distance evaluation instead of `k` — on separated
//!   clusters most of the chunk converges and the assignment cost drops
//!   toward `O(m)` per iteration. The tighten pass is batched by shared
//!   label, so each centroid row is loaded once per label group instead of
//!   once per point.
//! * [`ElkanEngine`] — Elkan-style pruning ("Using the Triangle Inequality
//!   to Accelerate k-Means"): one upper bound plus `k` per-centroid lower
//!   bounds per point, each relaxed by its own centroid's drift, composed
//!   with the inter-centroid-distance test (`d(c_l, c_j) ≥ 2·upper` rules
//!   centroid `j` out without touching the point). More memory
//!   (`O(m·k)` bounds) but finer pruning than Hamerly: a point only
//!   re-evaluates the centroids its bounds cannot exclude. The bound
//!   matrix is stored as `u16` quanta with one-sided rounding (see the
//!   quantisation slack model in [`super`]), so it costs 2 bytes per
//!   point-centroid pair instead of 8.
//! * [`HybridEngine`] — rescan-adaptive composition: every chunk starts on
//!   the Hamerly path (cheap `O(m)` bounds) and watches the observed
//!   rescan rate; once a step rescans more than a threshold fraction of
//!   the chunk, the state flips permanently to the Elkan path. Labels are
//!   identical either way — the switch only moves work between pruning
//!   strategies.
//!
//! Pruning in both engines is *exact*: every engine uses the identical
//! decomposition arithmetic, so labels, counts, and objectives agree
//! (cross-checked by `tests/property_engines.rs`). Evaluations avoided by
//! pruning are reported in [`crate::metrics::Counters::pruned_evals`] so
//! the paper's `n_d` tables can show the saving.
//!
//! The bounds live in a [`LloydState`] owned by the Lloyd loop and persist
//! across iterations; the parallel path hands each worker a disjoint slice
//! of the state (`split_at_mut`), so pruning composes with the row-blocked
//! `ThreadPool` assignment without locks.

use crate::metrics::Counters;
use crate::util::threadpool::ThreadPool;

use super::assign::{self, AssignOut};
use super::distance::{nearest2_decomp, sq_dist, sq_dist_decomp, sq_norm};

/// Which kernel engine runs the assignment step (config / CLI level).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelEngineKind {
    /// Exact blocked panel with fused argmin (the default).
    Panel,
    /// Hamerly-bound pruned exact assignment (2 bounds per point).
    Bounded,
    /// Elkan-bound pruned exact assignment (k+1 bounds per point plus the
    /// inter-centroid-distance test).
    Elkan,
    /// Rescan-adaptive Hamerly→Elkan composition (per-chunk switch).
    Hybrid,
}

impl KernelEngineKind {
    /// Instantiate the engine.
    pub fn build(self) -> Box<dyn KernelEngine> {
        self.build_with_threshold(None)
    }

    /// Instantiate the engine with an explicit hybrid switch threshold.
    /// Only the hybrid engine consults it — a learned or configured
    /// rescan-rate cutoff replaces [`HybridEngine::default`]'s fixed
    /// 0.25; `None` (and every other engine) is exactly [`Self::build`].
    pub fn build_with_threshold(self, hybrid_threshold: Option<f64>) -> Box<dyn KernelEngine> {
        match self {
            KernelEngineKind::Panel => Box::new(PanelEngine),
            KernelEngineKind::Bounded => Box::new(BoundedEngine::default()),
            KernelEngineKind::Elkan => Box::new(ElkanEngine::default()),
            KernelEngineKind::Hybrid => match hybrid_threshold {
                Some(t) => Box::new(HybridEngine { switch_threshold: t, ..HybridEngine::default() }),
                None => Box::new(HybridEngine::default()),
            },
        }
    }

    /// Parse a CLI token (`panel` / `bounded` / `elkan` / `hybrid`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "panel" => Some(KernelEngineKind::Panel),
            "bounded" => Some(KernelEngineKind::Bounded),
            "elkan" => Some(KernelEngineKind::Elkan),
            "hybrid" => Some(KernelEngineKind::Hybrid),
            _ => None,
        }
    }

    /// Canonical token (CLI/JSON labels).
    pub fn name(self) -> &'static str {
        match self {
            KernelEngineKind::Panel => "panel",
            KernelEngineKind::Bounded => "bounded",
            KernelEngineKind::Elkan => "elkan",
            KernelEngineKind::Hybrid => "hybrid",
        }
    }
}

/// Per-point assignment state that persists across Lloyd iterations.
///
/// For the bounded engine this holds the current label plus Hamerly
/// upper/lower bounds; the Elkan engine swaps the single lower bound for
/// `k` per-centroid lower bounds, quantised to `u16` (all in *distance*,
/// not squared-distance, domain — the triangle inequality is linear). The
/// panel engine never
/// activates it, and the vectors allocate lazily, so carrying a
/// `LloydState` through a panel run costs nothing.
#[derive(Clone, Debug)]
pub struct LloydState {
    m: usize,
    labels: Vec<u32>,
    /// Upper bound on the distance to the assigned centroid.
    upper: Vec<f64>,
    /// Hamerly: lower bound on the distance to every *other* centroid.
    lower: Vec<f64>,
    /// Elkan: per-centroid lower bounds, row-major `(m, k)`, stored as
    /// `u16` quanta of [`LloydState::q_scale`] with one-sided rounding so
    /// a dequantised bound never exceeds the true distance. Empty unless
    /// the Elkan engine activated the state.
    lower_q: Vec<u16>,
    /// `k` the Elkan bounds were allocated for (0 = Hamerly/none).
    bound_k: usize,
    /// Distance represented by one `lower_q` quantum, fixed for one bound
    /// lifetime (set whenever the Elkan bounds (re)initialise).
    q_scale: f64,
    /// Cached `‖x‖²` per point — invariant across iterations (the points
    /// of one Lloyd run never change), filled by the init pass.
    x_sq: Vec<f32>,
    /// Set by the first bounded assignment; `apply_update` is a no-op (and
    /// drift tracking is skipped entirely) while inactive.
    active: bool,
    /// The hybrid engine's per-chunk decision: once the observed rescan
    /// rate trips the switch, the state runs Elkan for the rest of its
    /// life. One-way by design — the trigger condition (a collapsed
    /// Hamerly lower bound) does not heal.
    hybrid_elkan: bool,
}

impl LloydState {
    /// Fresh state for `m` points. The bound vectors are allocated lazily
    /// by the first bounded assignment, so panel runs that thread a state
    /// through the Lloyd loop pay nothing for it.
    pub fn new(m: usize) -> Self {
        LloydState {
            m,
            labels: Vec::new(),
            upper: Vec::new(),
            lower: Vec::new(),
            lower_q: Vec::new(),
            bound_k: 0,
            q_scale: 0.0,
            x_sq: Vec::new(),
            active: false,
            hybrid_elkan: false,
        }
    }

    /// Number of points the state tracks.
    pub fn len(&self) -> usize {
        self.m
    }

    pub fn is_empty(&self) -> bool {
        self.m == 0
    }

    /// Materialise the per-point vectors (first bounded use).
    fn ensure_allocated(&mut self) {
        if self.labels.len() != self.m {
            self.labels = vec![0u32; self.m];
            self.upper = vec![0f64; self.m];
            self.lower = vec![0f64; self.m];
            self.x_sq = vec![0f32; self.m];
        } else if self.lower.len() != self.m {
            // The state was last driven by the Elkan engine (which never
            // allocates the single Hamerly bound): materialise it and force
            // a re-initialising pass.
            self.lower = vec![0f64; self.m];
            self.active = false;
        }
        if self.bound_k != 0 {
            // Elkan bounds from a previous engine are meaningless for the
            // Hamerly test (and would mis-route `apply_update`): drop them
            // and start the bounds over.
            self.lower_q = Vec::new();
            self.bound_k = 0;
            self.active = false;
        }
    }

    /// Materialise the per-point vectors plus the `(m, k)` quantised Elkan
    /// lower bounds (first Elkan use).
    fn ensure_allocated_elkan(&mut self, k: usize) {
        if self.labels.len() != self.m {
            self.labels = vec![0u32; self.m];
            self.upper = vec![0f64; self.m];
            self.x_sq = vec![0f32; self.m];
        }
        if self.bound_k != k || self.lower_q.len() != self.m * k {
            self.lower_q = vec![0u16; self.m * k];
            self.bound_k = k;
            self.active = false; // bounds for a different k are meaningless
        }
    }

    /// Whether a bounded assignment has initialised the bounds.
    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Labels from the most recent bounded assignment (meaningless while
    /// inactive).
    pub fn labels(&self) -> &[u32] {
        &self.labels
    }

    /// Relax the bounds for a centroid update `old → new`: each centroid's
    /// drift widens the upper bound of the points assigned to it. Hamerly
    /// state shrinks the single lower bound by the largest drift among the
    /// *other* centroids; Elkan state shrinks each per-centroid lower bound
    /// by that centroid's own drift. Call after every `update_centroids`;
    /// no-op while inactive.
    pub fn apply_update(
        &mut self,
        old_centroids: &[f32],
        new_centroids: &[f32],
        k: usize,
        n: usize,
    ) {
        if !self.active {
            return;
        }
        debug_assert_eq!(old_centroids.len(), k * n);
        debug_assert_eq!(new_centroids.len(), k * n);
        let mut drift = vec![0f64; k];
        // Largest and second-largest drift, so points assigned to the
        // fastest-moving centroid get the tighter (second-largest) bound.
        let mut max1 = 0f64;
        let mut max1_j = 0usize;
        let mut max2 = 0f64;
        for (j, d) in drift.iter_mut().enumerate() {
            let dj = (sq_dist(
                &old_centroids[j * n..(j + 1) * n],
                &new_centroids[j * n..(j + 1) * n],
            ) as f64)
                .sqrt();
            *d = dj;
            if dj > max1 {
                max2 = max1;
                max1 = dj;
                max1_j = j;
            } else if dj > max2 {
                max2 = dj;
            }
        }
        if max1 == 0.0 {
            return; // nothing moved — bounds stay exact
        }
        if self.bound_k == k && !self.lower_q.is_empty() {
            // Elkan: every centroid relaxes its own lower-bound column, in
            // whole quanta rounded *up* so the dequantised bound shrinks by
            // at least the true drift (admissible). `as u16` saturates, and
            // `saturating_sub` floors at zero, so extreme drifts merely
            // collapse the bound.
            let scale = self.q_scale;
            let mut dq = vec![0u16; k];
            for (q, dj) in dq.iter_mut().zip(&drift) {
                *q = (dj / scale).ceil() as u16;
            }
            for i in 0..self.labels.len() {
                let l = self.labels[i] as usize;
                self.upper[i] += drift[l];
                let row = &mut self.lower_q[i * k..(i + 1) * k];
                for (lb, q) in row.iter_mut().zip(&dq) {
                    *lb = lb.saturating_sub(*q);
                }
            }
        } else {
            for i in 0..self.labels.len() {
                let l = self.labels[i] as usize;
                self.upper[i] += drift[l];
                self.lower[i] -= if l == max1_j { max2 } else { max1 };
            }
        }
    }
}

/// A disjoint per-worker window into a [`LloydState`] (plus the rows of the
/// point block it covers) — the unit the parallel bounded path hands to
/// each `ThreadPool` worker.
struct StateSlice<'a> {
    labels: &'a mut [u32],
    upper: &'a mut [f64],
    lower: &'a mut [f64],
    x_sq: &'a mut [f32],
}

/// The Elkan analogue of [`StateSlice`]: `lower_q` windows `rows·k`
/// quantised per-centroid lower bounds.
struct ElkanSlice<'a> {
    labels: &'a mut [u32],
    upper: &'a mut [f64],
    lower_q: &'a mut [u16],
    x_sq: &'a mut [f32],
}

/// Strategy interface for the fused assignment step.
///
/// `assign_step` is the stateful per-iteration entry point Lloyd loops use;
/// `assign_once` is the stateless labels+mins pass (final full-dataset
/// assignment, D² weights). Engines are `Send + Sync` so one instance can
/// serve the pool-parallel path.
pub trait KernelEngine: Send + Sync {
    /// Engine kind (for reports and config round-trips).
    fn kind(&self) -> KernelEngineKind;

    /// Human-readable engine name.
    fn name(&self) -> &'static str;

    /// Fused assignment + per-cluster reduction for one Lloyd iteration,
    /// reading and updating the persistent `state`. `state.len()` must
    /// equal `m`.
    fn assign_step(
        &self,
        points: &[f32],
        centroids: &[f32],
        m: usize,
        n: usize,
        k: usize,
        state: &mut LloydState,
        counters: &mut Counters,
    ) -> AssignOut;

    /// Row-blocked parallel variant of [`KernelEngine::assign_step`]
    /// (per-worker state slices). Semantically identical to the serial
    /// path: labels, mins, and counts match exactly; f64 accumulations up
    /// to merge order.
    fn assign_step_parallel(
        &self,
        pool: &ThreadPool,
        points: &[f32],
        centroids: &[f32],
        m: usize,
        n: usize,
        k: usize,
        state: &mut LloydState,
        counters: &mut Counters,
    ) -> AssignOut;

    /// Stateless nearest-centroid pass: `(labels, min_sq_dists)`.
    fn assign_once(
        &self,
        points: &[f32],
        centroids: &[f32],
        m: usize,
        n: usize,
        k: usize,
        counters: &mut Counters,
    ) -> (Vec<u32>, Vec<f32>) {
        assign::assign_only(points, centroids, m, n, k, counters)
    }
}

/// The exact blocked-panel engine (fused panel + argmin, no pruning).
pub struct PanelEngine;

impl KernelEngine for PanelEngine {
    fn kind(&self) -> KernelEngineKind {
        KernelEngineKind::Panel
    }

    fn name(&self) -> &'static str {
        "panel"
    }

    fn assign_step(
        &self,
        points: &[f32],
        centroids: &[f32],
        m: usize,
        n: usize,
        k: usize,
        _state: &mut LloydState,
        counters: &mut Counters,
    ) -> AssignOut {
        assign::assign_accumulate(points, centroids, m, n, k, counters)
    }

    fn assign_step_parallel(
        &self,
        pool: &ThreadPool,
        points: &[f32],
        centroids: &[f32],
        m: usize,
        n: usize,
        k: usize,
        _state: &mut LloydState,
        counters: &mut Counters,
    ) -> AssignOut {
        assign::assign_accumulate_parallel(pool, points, centroids, m, n, k, counters)
    }
}

/// Hamerly-bound pruned exact assignment.
///
/// The prune test combines two safety slacks so a stale bound can never
/// keep a label the panel engine would change:
///
/// * a *relative* margin (`upper·(1+margin) ≤ lower`) covering the drift
///   accumulation across iterations, and
/// * an *absolute* squared-domain slack scaled by `‖x‖² + max‖c‖²`,
///   covering the cancellation error of the f32 `‖x‖² − 2x·c + ‖c‖²`
///   decomposition — which is absolute in the norms, not relative to the
///   distance, and dominates for tight clusters far from the origin.
///
/// Failing to prune only costs a rescan (still exact), so both slacks
/// trade a little pruning for label identity with the panel engine.
pub struct BoundedEngine {
    /// Relative safety slack on the prune test.
    pub margin: f64,
}

impl Default for BoundedEngine {
    fn default() -> Self {
        BoundedEngine { margin: 1e-2 }
    }
}

/// Absolute error bound (squared-distance domain) of one decomposition
/// evaluation: `(x_sq + c_sq_max) · eval_slack(n)`. The factor counts the
/// rounding steps of the lane-tiled dot product (`n / LANES` adds per
/// lane + reduction + the 3-term combination), padded generously — the
/// cost of overestimating is a few extra rescans, never a wrong label.
/// Shared with the block-level bounding-box pruner (`store::prune`), which
/// needs the same band to guarantee a skipped block could never flip a
/// panel label.
pub(crate) fn eval_slack(n: usize) -> f64 {
    (n as f64 / 16.0 + 8.0) * (f32::EPSILON as f64)
}

impl BoundedEngine {
    /// Serial bounded assignment over one contiguous row block. `slice`
    /// windows the persistent state for exactly these rows; `active` is the
    /// state flag captured before slicing (shared by all workers of one
    /// step).
    #[allow(clippy::too_many_arguments)]
    fn bounded_block(
        &self,
        points: &[f32],
        centroids: &[f32],
        n: usize,
        k: usize,
        c_sq: &[f32],
        slice: StateSlice<'_>,
        active: bool,
        counters: &mut Counters,
    ) -> AssignOut {
        let rows = slice.labels.len();
        debug_assert_eq!(points.len(), rows * n);
        debug_assert_eq!(centroids.len(), k * n);
        debug_assert_eq!(c_sq.len(), k);
        let StateSlice { labels, upper, lower, x_sq: x_sq_cache } = slice;
        let c_sq_max = c_sq.iter().cloned().fold(0f32, f32::max) as f64;
        let slack_factor = eval_slack(n);
        let mut out_labels = vec![0u32; rows];
        let mut mins = vec![0f32; rows];
        let mut sums = vec![0f64; k * n];
        let mut counts = vec![0u64; k];
        let mut objective = 0f64;
        let mut evals = 0u64;
        let mut pruned = 0u64;

        if !active {
            // Init pass: full best/second-best scan, caching the
            // iteration-invariant point norm alongside the bounds.
            for i in 0..rows {
                let x = &points[i * n..(i + 1) * n];
                let x_sq = sq_norm(x);
                x_sq_cache[i] = x_sq;
                evals += k as u64;
                let (j1, d1, d2) = nearest2_decomp(x, x_sq, centroids, c_sq, k, n);
                labels[i] = j1 as u32;
                upper[i] = (d1 as f64).sqrt();
                lower[i] = (d2 as f64).sqrt();
                out_labels[i] = j1 as u32;
                mins[i] = d1;
            }
        } else {
            // Tighten pass, batched by shared label: counting-sort the rows
            // by their current label so each centroid row is loaded once per
            // label *group* instead of once per point. Per-point values are
            // identical to the point-ordered pass — only the visit order of
            // the (independent) tighten evaluations changes; the objective
            // and sums are accumulated in row order below.
            let mut group_off = vec![0usize; k + 1];
            for &l in labels.iter() {
                group_off[l as usize + 1] += 1;
            }
            for j in 0..k {
                group_off[j + 1] += group_off[j];
            }
            let mut order = vec![0u32; rows];
            {
                let mut cursor = group_off.clone();
                for (i, &l) in labels.iter().enumerate() {
                    order[cursor[l as usize]] = i as u32;
                    cursor[l as usize] += 1;
                }
            }
            for l in 0..k {
                let c_l = &centroids[l * n..(l + 1) * n];
                let c_sq_l = c_sq[l];
                for &iu in &order[group_off[l]..group_off[l + 1]] {
                    let i = iu as usize;
                    let x = &points[i * n..(i + 1) * n];
                    let x_sq = x_sq_cache[i];
                    // Tighten: one exact evaluation against the assigned
                    // centroid. With the tightened upper bound below the
                    // lower bound on every other centroid, `l` is still the
                    // nearest and `d_l` is the exact min — no further
                    // evaluations.
                    let d_l = sq_dist_decomp(x, x_sq, c_l, c_sq_l);
                    let ub = (d_l as f64).sqrt();
                    upper[i] = ub;
                    // Prune test in the squared domain (avoids a division
                    // when converting the absolute slack): lower² must clear
                    // the margined upper² plus the decomposition's
                    // cancellation error band.
                    let thr = ub * (1.0 + self.margin);
                    let slack = (x_sq as f64 + c_sq_max) * slack_factor;
                    let lb = lower[i];
                    if lb > 0.0 && thr * thr + slack <= lb * lb {
                        evals += 1;
                        pruned += (k - 1) as u64;
                        out_labels[i] = l as u32;
                        mins[i] = d_l;
                    } else {
                        // Bounds inconclusive: full rescan (same arithmetic
                        // and tie-breaking as the panel path), refreshing
                        // both bounds from the exact best / second-best.
                        evals += (k + 1) as u64;
                        let (j1, d1, d2) = nearest2_decomp(x, x_sq, centroids, c_sq, k, n);
                        labels[i] = j1 as u32;
                        upper[i] = (d1 as f64).sqrt();
                        lower[i] = (d2 as f64).sqrt();
                        out_labels[i] = j1 as u32;
                        mins[i] = d1;
                    }
                }
            }
        }
        // Row-ordered reduction — bit-identical accumulation regardless of
        // the tighten pass's group order.
        for i in 0..rows {
            let best = out_labels[i] as usize;
            let best_d = mins[i];
            objective += best_d as f64;
            counts[best] += 1;
            let x = &points[i * n..(i + 1) * n];
            let srow = &mut sums[best * n..(best + 1) * n];
            for (sv, xv) in srow.iter_mut().zip(x) {
                *sv += *xv as f64;
            }
        }
        counters.add_distance_evals(evals);
        counters.add_pruned_evals(pruned);
        AssignOut { labels: out_labels, mins, sums, counts, objective }
    }
}

impl KernelEngine for BoundedEngine {
    fn kind(&self) -> KernelEngineKind {
        KernelEngineKind::Bounded
    }

    fn name(&self) -> &'static str {
        "bounded"
    }

    fn assign_step(
        &self,
        points: &[f32],
        centroids: &[f32],
        m: usize,
        n: usize,
        k: usize,
        state: &mut LloydState,
        counters: &mut Counters,
    ) -> AssignOut {
        assert_eq!(points.len(), m * n, "points shape");
        assert_eq!(centroids.len(), k * n, "centroids shape");
        assert_eq!(state.len(), m, "state length");
        assert!(k > 0, "k must be positive");
        state.ensure_allocated();
        let c_sq: Vec<f32> = (0..k).map(|j| sq_norm(&centroids[j * n..(j + 1) * n])).collect();
        let active = state.active;
        let slice = StateSlice {
            labels: &mut state.labels[..],
            upper: &mut state.upper[..],
            lower: &mut state.lower[..],
            x_sq: &mut state.x_sq[..],
        };
        let out = self.bounded_block(points, centroids, n, k, &c_sq, slice, active, counters);
        state.active = true;
        out
    }

    fn assign_step_parallel(
        &self,
        pool: &ThreadPool,
        points: &[f32],
        centroids: &[f32],
        m: usize,
        n: usize,
        k: usize,
        state: &mut LloydState,
        counters: &mut Counters,
    ) -> AssignOut {
        assert_eq!(points.len(), m * n, "points shape");
        assert_eq!(centroids.len(), k * n, "centroids shape");
        assert_eq!(state.len(), m, "state length");
        // The shared partition rule keeps thresholds and merge order
        // engine-independent.
        let Some(jobs) = assign::partition_rows(pool, m) else {
            return self.assign_step(points, centroids, m, n, k, state, counters);
        };
        state.ensure_allocated();
        let c_sq: Vec<f32> = (0..k).map(|j| sq_norm(&centroids[j * n..(j + 1) * n])).collect();
        let active = state.active;
        // Carve the state into disjoint per-worker windows (jobs tile
        // `0..m` in order, so successive split_at_mut calls line up).
        let mut views: Vec<(usize, StateSlice<'_>)> = Vec::with_capacity(jobs.len());
        {
            let mut lab_rest: &mut [u32] = &mut state.labels;
            let mut up_rest: &mut [f64] = &mut state.upper;
            let mut lo_rest: &mut [f64] = &mut state.lower;
            let mut xs_rest: &mut [f32] = &mut state.x_sq;
            for &(start, end) in &jobs {
                let rows = end - start;
                let (lab, lab_tail) = lab_rest.split_at_mut(rows);
                let (up, up_tail) = up_rest.split_at_mut(rows);
                let (lo, lo_tail) = lo_rest.split_at_mut(rows);
                let (xs, xs_tail) = xs_rest.split_at_mut(rows);
                lab_rest = lab_tail;
                up_rest = up_tail;
                lo_rest = lo_tail;
                xs_rest = xs_tail;
                views.push((start, StateSlice { labels: lab, upper: up, lower: lo, x_sq: xs }));
            }
        }
        let mut partials: Vec<Option<(usize, AssignOut, Counters)>> =
            (0..views.len()).map(|_| None).collect();
        let c_sq_ref: &[f32] = &c_sq;
        let closures: Vec<_> = views
            .into_iter()
            .zip(partials.iter_mut())
            .map(|((start, slice), slot)| {
                let rows = slice.labels.len();
                let pts = &points[start * n..(start + rows) * n];
                move || {
                    let mut local = Counters::new();
                    let out = self
                        .bounded_block(pts, centroids, n, k, c_sq_ref, slice, active, &mut local);
                    *slot = Some((start, out, local));
                }
            })
            .collect();
        pool.scope_run_all(closures);
        state.active = true;

        let mut labels = vec![0u32; m];
        let mut mins = vec![0f32; m];
        let mut sums = vec![0f64; k * n];
        let mut counts = vec![0u64; k];
        let mut objective = 0f64;
        for part in partials.into_iter().flatten() {
            let (start, out, local) = part;
            let rows = out.labels.len();
            labels[start..start + rows].copy_from_slice(&out.labels);
            mins[start..start + rows].copy_from_slice(&out.mins);
            for (acc, v) in sums.iter_mut().zip(&out.sums) {
                *acc += *v;
            }
            for (acc, v) in counts.iter_mut().zip(&out.counts) {
                *acc += *v;
            }
            objective += out.objective;
            counters.merge(&local);
        }
        AssignOut { labels, mins, sums, counts, objective }
    }
}

/// Elkan-bound pruned exact assignment.
///
/// Per point: one upper bound on the distance to the assigned centroid
/// plus `k` per-centroid lower bounds, persisted in [`LloydState`] and
/// relaxed per-centroid by [`LloydState::apply_update`]. Each iteration
/// tightens the upper bound with one exact evaluation, then rules out
/// centroid `j` when either
///
/// * the stored lower bound `lb_j` clears the margined upper bound, or
/// * the inter-centroid distance does: `d(c_l, c_j) ≥ 2·upper` implies by
///   the triangle inequality that `j` cannot beat the assigned centroid.
///
/// Only the surviving centroids are evaluated, in index order with strict
/// `<` — the same scan order and tie-breaking as the panel engine, so a
/// skipped centroid (guaranteed *strictly* worse by the margin + absolute
/// slack, exactly the [`BoundedEngine`] trust model) can never flip a
/// label. Inter-centroid distances are deflated by the margin before use
/// so their own rounding cannot over-prune.
pub struct ElkanEngine {
    /// Relative safety slack on the prune tests.
    pub margin: f64,
}

impl Default for ElkanEngine {
    fn default() -> Self {
        ElkanEngine { margin: 1e-2 }
    }
}

/// Per-step centroid geometry shared by every worker of one Elkan
/// assignment: deflated pairwise centroid distances and the deflated
/// half-distance to each centroid's nearest neighbour.
struct ElkanGeometry {
    /// `cc_lo[l*k + j]` ≤ true `d(c_l, c_j)` (distance domain).
    cc_lo: Vec<f64>,
    /// `s_lo[l]` ≤ `0.5 · min_{j≠l} d(c_l, c_j)`.
    s_lo: Vec<f64>,
    /// Distance per lower-bound quantum for this step (copied from the
    /// state, so every worker stores and dequantises identically).
    q_scale: f64,
}

/// Distance represented by one `u16` lower-bound quantum: sized so the
/// largest distance a run can plausibly produce (`2·max‖x‖ + max‖c‖`,
/// padded by one) spans the 16-bit range. Computed serially and
/// deterministically once per bound lifetime — the parallel path derives
/// the identical scale, so rescan behaviour matches the serial path
/// exactly. Larger distances merely saturate the stored bound downward,
/// which is admissible.
fn quant_scale(points: &[f32], n: usize, c_sq: &[f32]) -> f64 {
    let max_x_sq = points.chunks_exact(n.max(1)).map(sq_norm).fold(0f32, f32::max) as f64;
    let max_c_sq = c_sq.iter().cloned().fold(0f32, f32::max) as f64;
    (2.0 * max_x_sq.sqrt() + max_c_sq.sqrt() + 1.0) / (u16::MAX as f64)
}

/// Quantise a lower bound (distance domain). Truncation rounds toward
/// zero and the `as` cast saturates at both ends (NaN → 0), so the
/// dequantised value never exceeds `d`: quantisation can only *weaken* a
/// lower bound, never overstate it.
#[inline]
fn quantize_lb(d: f64, scale: f64) -> u16 {
    (d / scale) as u16
}

impl ElkanEngine {
    fn geometry(&self, centroids: &[f32], k: usize, n: usize, q_scale: f64) -> ElkanGeometry {
        let deflate = 1.0 - self.margin;
        let mut cc_lo = vec![0f64; k * k];
        let mut s_lo = vec![f64::INFINITY; k];
        for l in 0..k {
            for j in (l + 1)..k {
                let d2 = sq_dist(&centroids[l * n..(l + 1) * n], &centroids[j * n..(j + 1) * n]);
                let d_lo = ((d2 as f64) * deflate).max(0.0).sqrt();
                cc_lo[l * k + j] = d_lo;
                cc_lo[j * k + l] = d_lo;
                s_lo[l] = s_lo[l].min(0.5 * d_lo);
                s_lo[j] = s_lo[j].min(0.5 * d_lo);
            }
        }
        if k == 1 {
            s_lo[0] = f64::INFINITY;
        }
        ElkanGeometry { cc_lo, s_lo, q_scale }
    }

    /// Serial Elkan assignment over one contiguous row block (the parallel
    /// path calls this per worker window).
    #[allow(clippy::too_many_arguments)]
    fn elkan_block(
        &self,
        points: &[f32],
        centroids: &[f32],
        n: usize,
        k: usize,
        c_sq: &[f32],
        geo: &ElkanGeometry,
        slice: ElkanSlice<'_>,
        active: bool,
        counters: &mut Counters,
    ) -> AssignOut {
        let rows = slice.labels.len();
        debug_assert_eq!(points.len(), rows * n);
        debug_assert_eq!(centroids.len(), k * n);
        debug_assert_eq!(slice.lower_q.len(), rows * k);
        let ElkanSlice { labels, upper, lower_q, x_sq: x_sq_cache } = slice;
        let q_scale = geo.q_scale;
        let c_sq_max = c_sq.iter().cloned().fold(0f32, f32::max) as f64;
        let slack_factor = eval_slack(n);
        let mut out_labels = vec![0u32; rows];
        let mut mins = vec![0f32; rows];
        let mut sums = vec![0f64; k * n];
        let mut counts = vec![0u64; k];
        let mut objective = 0f64;
        let mut evals = 0u64;
        let mut pruned = 0u64;

        for i in 0..rows {
            let x = &points[i * n..(i + 1) * n];
            let lb_row = &mut lower_q[i * k..(i + 1) * k];
            let (best, best_d) = if !active {
                // Init pass: evaluate every centroid in index order (panel
                // arithmetic + tie-breaking), seeding all k lower bounds
                // with the exact distances.
                let x_sq = sq_norm(x);
                x_sq_cache[i] = x_sq;
                evals += k as u64;
                let mut bj = 0usize;
                let mut bd = f32::INFINITY;
                for (j, lb) in lb_row.iter_mut().enumerate() {
                    let d = sq_dist_decomp(x, x_sq, &centroids[j * n..(j + 1) * n], c_sq[j]);
                    *lb = quantize_lb((d as f64).sqrt(), q_scale);
                    if d < bd {
                        bd = d;
                        bj = j;
                    }
                }
                labels[i] = bj as u32;
                upper[i] = (bd as f64).sqrt();
                (bj, bd)
            } else {
                let x_sq = x_sq_cache[i];
                let l = labels[i] as usize;
                // Tighten: one exact evaluation against the assigned
                // centroid.
                let d_l = sq_dist_decomp(x, x_sq, &centroids[l * n..(l + 1) * n], c_sq[l]);
                let u = (d_l as f64).sqrt();
                upper[i] = u;
                lb_row[l] = quantize_lb(u, q_scale);
                let thr = u * (1.0 + self.margin);
                let slack = (x_sq as f64 + c_sq_max) * slack_factor;
                let thr2s = thr * thr + slack;
                let slack_d = slack.sqrt();
                let s_l = geo.s_lo[l];
                // Global test: every j ≠ l sits at least `2·s_l` from the
                // assigned centroid, so `d(x, c_j) ≥ 2·s_l − upper`; when
                // that clears the margined upper bound, all k−1 others are
                // ruled out at once.
                let lb_g = 2.0 * s_l - thr - slack_d;
                if s_l.is_finite() && lb_g > 0.0 && thr2s <= lb_g * lb_g {
                    evals += 1;
                    pruned += (k - 1) as u64;
                    (l, d_l)
                } else if s_l.is_infinite() {
                    // k == 1: nothing to compare against.
                    evals += 1;
                    (l, d_l)
                } else {
                    // Per-centroid scan in index order; skipped centroids
                    // are strictly worse, evaluated ones compete with the
                    // panel's strict-< tie-breaking. `bj` starts at the
                    // current label with an infinite distance, so the first
                    // strict improvement in index order wins exactly as in
                    // the panel scan (and a pathological all-∞ row keeps a
                    // valid label).
                    let cc_row = &geo.cc_lo[l * k..(l + 1) * k];
                    let mut bj = l;
                    let mut bd = f32::INFINITY;
                    evals += 1; // the tighten evaluation
                    for j in 0..k {
                        if j == l {
                            if d_l < bd {
                                bd = d_l;
                                bj = l;
                            }
                            continue;
                        }
                        let lb = lb_row[j] as f64 * q_scale;
                        if lb > 0.0 && thr2s <= lb * lb {
                            pruned += 1;
                            continue;
                        }
                        let lb_cc = cc_row[j] - thr - slack_d;
                        if lb_cc > 0.0 && thr2s <= lb_cc * lb_cc {
                            pruned += 1;
                            continue;
                        }
                        let d = sq_dist_decomp(x, x_sq, &centroids[j * n..(j + 1) * n], c_sq[j]);
                        evals += 1;
                        lb_row[j] = quantize_lb((d as f64).sqrt(), q_scale);
                        if d < bd {
                            bd = d;
                            bj = j;
                        }
                    }
                    labels[i] = bj as u32;
                    upper[i] = (bd as f64).sqrt();
                    (bj, bd)
                }
            };
            out_labels[i] = best as u32;
            mins[i] = best_d;
            objective += best_d as f64;
            counts[best] += 1;
            let srow = &mut sums[best * n..(best + 1) * n];
            for (sv, xv) in srow.iter_mut().zip(x) {
                *sv += *xv as f64;
            }
        }
        counters.add_distance_evals(evals);
        counters.add_pruned_evals(pruned);
        AssignOut { labels: out_labels, mins, sums, counts, objective }
    }
}

impl KernelEngine for ElkanEngine {
    fn kind(&self) -> KernelEngineKind {
        KernelEngineKind::Elkan
    }

    fn name(&self) -> &'static str {
        "elkan"
    }

    fn assign_step(
        &self,
        points: &[f32],
        centroids: &[f32],
        m: usize,
        n: usize,
        k: usize,
        state: &mut LloydState,
        counters: &mut Counters,
    ) -> AssignOut {
        assert_eq!(points.len(), m * n, "points shape");
        assert_eq!(centroids.len(), k * n, "centroids shape");
        assert_eq!(state.len(), m, "state length");
        assert!(k > 0, "k must be positive");
        state.ensure_allocated_elkan(k);
        let c_sq: Vec<f32> = (0..k).map(|j| sq_norm(&centroids[j * n..(j + 1) * n])).collect();
        if !state.active {
            // New bound lifetime: fix the quantum before any bound is
            // stored.
            state.q_scale = quant_scale(points, n, &c_sq);
        }
        let geo = self.geometry(centroids, k, n, state.q_scale);
        let active = state.active;
        let slice = ElkanSlice {
            labels: &mut state.labels[..],
            upper: &mut state.upper[..],
            lower_q: &mut state.lower_q[..],
            x_sq: &mut state.x_sq[..],
        };
        let out = self.elkan_block(points, centroids, n, k, &c_sq, &geo, slice, active, counters);
        state.active = true;
        out
    }

    fn assign_step_parallel(
        &self,
        pool: &ThreadPool,
        points: &[f32],
        centroids: &[f32],
        m: usize,
        n: usize,
        k: usize,
        state: &mut LloydState,
        counters: &mut Counters,
    ) -> AssignOut {
        assert_eq!(points.len(), m * n, "points shape");
        assert_eq!(centroids.len(), k * n, "centroids shape");
        assert_eq!(state.len(), m, "state length");
        // The shared partition rule keeps thresholds and merge order
        // engine-independent.
        let Some(jobs) = assign::partition_rows(pool, m) else {
            return self.assign_step(points, centroids, m, n, k, state, counters);
        };
        state.ensure_allocated_elkan(k);
        let c_sq: Vec<f32> = (0..k).map(|j| sq_norm(&centroids[j * n..(j + 1) * n])).collect();
        if !state.active {
            // Same serial, deterministic pre-scan as the serial path, so
            // both derive the identical quantum.
            state.q_scale = quant_scale(points, n, &c_sq);
        }
        let geo = self.geometry(centroids, k, n, state.q_scale);
        let active = state.active;
        let mut views: Vec<(usize, ElkanSlice<'_>)> = Vec::with_capacity(jobs.len());
        {
            let mut lab_rest: &mut [u32] = &mut state.labels;
            let mut up_rest: &mut [f64] = &mut state.upper;
            let mut lo_rest: &mut [u16] = &mut state.lower_q;
            let mut xs_rest: &mut [f32] = &mut state.x_sq;
            for &(start, end) in &jobs {
                let rows = end - start;
                let (lab, lab_tail) = lab_rest.split_at_mut(rows);
                let (up, up_tail) = up_rest.split_at_mut(rows);
                let (lo, lo_tail) = lo_rest.split_at_mut(rows * k);
                let (xs, xs_tail) = xs_rest.split_at_mut(rows);
                lab_rest = lab_tail;
                up_rest = up_tail;
                lo_rest = lo_tail;
                xs_rest = xs_tail;
                views.push((start, ElkanSlice { labels: lab, upper: up, lower_q: lo, x_sq: xs }));
            }
        }
        let mut partials: Vec<Option<(usize, AssignOut, Counters)>> =
            (0..views.len()).map(|_| None).collect();
        let c_sq_ref: &[f32] = &c_sq;
        let geo_ref: &ElkanGeometry = &geo;
        let closures: Vec<_> = views
            .into_iter()
            .zip(partials.iter_mut())
            .map(|((start, slice), slot)| {
                let rows = slice.labels.len();
                let pts = &points[start * n..(start + rows) * n];
                move || {
                    let mut local = Counters::new();
                    let out = self.elkan_block(
                        pts, centroids, n, k, c_sq_ref, geo_ref, slice, active, &mut local,
                    );
                    *slot = Some((start, out, local));
                }
            })
            .collect();
        pool.scope_run_all(closures);
        state.active = true;

        let mut labels = vec![0u32; m];
        let mut mins = vec![0f32; m];
        let mut sums = vec![0f64; k * n];
        let mut counts = vec![0u64; k];
        let mut objective = 0f64;
        for part in partials.into_iter().flatten() {
            let (start, out, local) = part;
            let rows = out.labels.len();
            labels[start..start + rows].copy_from_slice(&out.labels);
            mins[start..start + rows].copy_from_slice(&out.mins);
            for (acc, v) in sums.iter_mut().zip(&out.sums) {
                *acc += *v;
            }
            for (acc, v) in counts.iter_mut().zip(&out.counts) {
                *acc += *v;
            }
            objective += out.objective;
            counters.merge(&local);
        }
        AssignOut { labels, mins, sums, counts, objective }
    }
}

/// Rescan-adaptive Hamerly→Elkan composition.
///
/// Every chunk starts on the Hamerly path ([`BoundedEngine`]): two bounds
/// per point, `O(m)` state, ideal while most points prune. Hamerly's
/// accounting makes the observed rescan rate exact — a step over an
/// active state spends one evaluation per pruned point and `k + 1` per
/// rescan, so `rescans = (evals − m) / k`. Once a step rescans more than
/// `switch_threshold · m` points, the chunk's [`LloydState`] flips
/// permanently to the Elkan path ([`ElkanEngine`]), whose per-centroid
/// bounds keep pruning where Hamerly's single lower bound has collapsed.
/// Both constituent engines are panel-exact, so the switch never changes
/// a label — it only moves work between pruning strategies. Switches are
/// counted in [`Counters::hybrid_switches`].
pub struct HybridEngine {
    bounded: BoundedEngine,
    elkan: ElkanEngine,
    /// Rescanned fraction of the chunk above which the state switches.
    pub switch_threshold: f64,
}

/// Built-in Hamerly→Elkan switch threshold (rescanned fraction of the
/// chunk). `--hybrid-threshold` / a tuner-learned value override it.
pub const DEFAULT_HYBRID_THRESHOLD: f64 = 0.25;

impl Default for HybridEngine {
    fn default() -> Self {
        HybridEngine {
            bounded: BoundedEngine::default(),
            elkan: ElkanEngine::default(),
            switch_threshold: DEFAULT_HYBRID_THRESHOLD,
        }
    }
}

impl HybridEngine {
    /// Decide from one step's counters whether the chunk should switch.
    /// Init passes (`!was_active`) are excluded — their `m·k` evaluations
    /// say nothing about steady-state rescan behaviour.
    fn should_switch(&self, was_active: bool, step: &Counters, m: usize, k: usize) -> bool {
        if !was_active || k < 2 || m == 0 {
            return false;
        }
        let rescans = step.distance_evals.saturating_sub(m as u64) / k as u64;
        (rescans as f64) > self.switch_threshold * (m as f64)
    }
}

/// Record one steady-state Hamerly step's rescan count and row count
/// into `cnt` (the hybrid rescan-rate accounting). Init passes are
/// excluded for the same reason `should_switch` excludes them.
fn record_rescans(was_active: bool, cnt: &mut Counters, m: usize, k: usize) {
    if was_active && k >= 2 && m > 0 {
        cnt.hybrid_rescans += cnt.distance_evals.saturating_sub(m as u64) / k as u64;
        cnt.hybrid_scan_rows += m as u64;
    }
}

impl KernelEngine for HybridEngine {
    fn kind(&self) -> KernelEngineKind {
        KernelEngineKind::Hybrid
    }

    fn name(&self) -> &'static str {
        "hybrid"
    }

    fn assign_step(
        &self,
        points: &[f32],
        centroids: &[f32],
        m: usize,
        n: usize,
        k: usize,
        state: &mut LloydState,
        counters: &mut Counters,
    ) -> AssignOut {
        if state.hybrid_elkan {
            return self.elkan.assign_step(points, centroids, m, n, k, state, counters);
        }
        let was_active = state.active;
        let mut cnt = Counters::new();
        let out = self.bounded.assign_step(points, centroids, m, n, k, state, &mut cnt);
        record_rescans(was_active, &mut cnt, m, k);
        if self.should_switch(was_active, &cnt, m, k) {
            state.hybrid_elkan = true;
            cnt.hybrid_switches += 1;
        }
        counters.merge(&cnt);
        out
    }

    fn assign_step_parallel(
        &self,
        pool: &ThreadPool,
        points: &[f32],
        centroids: &[f32],
        m: usize,
        n: usize,
        k: usize,
        state: &mut LloydState,
        counters: &mut Counters,
    ) -> AssignOut {
        if state.hybrid_elkan {
            let elkan = &self.elkan;
            return elkan.assign_step_parallel(pool, points, centroids, m, n, k, state, counters);
        }
        let was_active = state.active;
        let mut cnt = Counters::new();
        let bounded = &self.bounded;
        let out = bounded.assign_step_parallel(pool, points, centroids, m, n, k, state, &mut cnt);
        // The per-worker counters are summed before the decision, so the
        // switch step — and the rescan accounting — is identical to the
        // serial path's.
        record_rescans(was_active, &mut cnt, m, k);
        if self.should_switch(was_active, &cnt, m, k) {
            state.hybrid_elkan = true;
            cnt.hybrid_switches += 1;
        }
        counters.merge(&cnt);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::update::update_centroids;
    use crate::util::rng::Rng;

    fn random_problem(seed: u64, m: usize, n: usize, k: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let pts: Vec<f32> = (0..m * n).map(|_| rng.f32() * 10.0 - 5.0).collect();
        let cs: Vec<f32> = pts[..k * n].to_vec();
        (pts, cs)
    }

    /// Run `iters` full Lloyd iterations with the given engine, returning
    /// the final step output plus the counters.
    fn iterate(
        engine: &dyn KernelEngine,
        pts: &[f32],
        m: usize,
        n: usize,
        k: usize,
        iters: usize,
        seed_c: &[f32],
    ) -> (AssignOut, Counters, Vec<f32>) {
        let mut c = seed_c.to_vec();
        let mut old = vec![0f32; k * n];
        let mut state = LloydState::new(m);
        let mut counters = Counters::new();
        let mut last = None;
        for _ in 0..iters {
            let out = engine.assign_step(pts, &c, m, n, k, &mut state, &mut counters);
            old.copy_from_slice(&c);
            update_centroids(&out.sums, &out.counts, &mut c, k, n);
            state.apply_update(&old, &c, k, n);
            last = Some(out);
        }
        (last.unwrap(), counters, c)
    }

    #[test]
    fn bounded_matches_panel_over_iterations() {
        for seed in 1..6u64 {
            let (m, n, k) = (257, 5, 6);
            let (pts, cs) = random_problem(seed, m, n, k);
            let (pa, _, ca) = iterate(&PanelEngine, &pts, m, n, k, 5, &cs);
            let (pb, cb, cbds) = iterate(&BoundedEngine::default(), &pts, m, n, k, 5, &cs);
            assert_eq!(pa.labels, pb.labels, "seed {seed}");
            assert_eq!(pa.counts, pb.counts, "seed {seed}");
            assert_eq!(ca, cbds, "seed {seed}: centroid trajectories diverged");
            assert!(
                (pa.objective - pb.objective).abs() <= 1e-6 * pa.objective.abs() + 1e-12,
                "seed {seed}: {} vs {}",
                pa.objective,
                pb.objective
            );
            assert!(cb.distance_evals > 0);
        }
    }

    #[test]
    fn bounded_prunes_on_separated_blobs() {
        let mut rng = Rng::new(9);
        let centers = [(-8.0f32, -8.0f32), (8.0, 8.0), (-8.0, 8.0)];
        let m = 300;
        let mut pts = Vec::with_capacity(m * 2);
        for i in 0..m {
            let (cx, cy) = centers[i % 3];
            pts.push(cx + 0.2 * rng.gaussian() as f32);
            pts.push(cy + 0.2 * rng.gaussian() as f32);
        }
        let cs: Vec<f32> = pts[..6].to_vec();
        let iters = 6u64;
        let full = iters * (m as u64) * 3;
        let (_, counters, _) = iterate(&BoundedEngine::default(), &pts, m, 2, 3, iters as usize, &cs);
        assert!(counters.pruned_evals > 0, "no pruning on separated blobs");
        // Pruning must produce a real saving over the unpruned engine...
        assert!(
            counters.distance_evals < full,
            "evals {} not below unpruned {full}",
            counters.distance_evals
        );
        // ...and the accounting must close: every pruned point costs 1 eval
        // and avoids k−1, every rescan costs k+1, the init pass costs k —
        // so done + avoided covers at least every m·k slot.
        assert!(counters.distance_evals + counters.pruned_evals >= full);
    }

    #[test]
    fn parallel_bounded_matches_serial_bounded() {
        // Both paths follow the SAME centroid trajectory (updated from the
        // serial output), so every per-point quantity must match exactly —
        // the parallel path only changes the f64 *merge* order of sums,
        // which this test deliberately keeps out of the trajectory.
        let (m, n, k) = (2048, 4, 5);
        let (pts, cs) = random_problem(3, m, n, k);
        let pool = ThreadPool::new(4);
        let engine = BoundedEngine::default();
        let mut c = cs.clone();
        let mut st_s = LloydState::new(m);
        let mut st_p = LloydState::new(m);
        let mut cnt_s = Counters::new();
        let mut cnt_p = Counters::new();
        let mut old = vec![0f32; k * n];
        for _ in 0..4 {
            let a = engine.assign_step(&pts, &c, m, n, k, &mut st_s, &mut cnt_s);
            let b = engine.assign_step_parallel(&pool, &pts, &c, m, n, k, &mut st_p, &mut cnt_p);
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.mins, b.mins);
            assert_eq!(a.counts, b.counts);
            assert!((a.objective - b.objective).abs() <= 1e-6 * a.objective.abs() + 1e-12);
            old.copy_from_slice(&c);
            update_centroids(&a.sums, &a.counts, &mut c, k, n);
            st_s.apply_update(&old, &c, k, n);
            st_p.apply_update(&old, &c, k, n);
        }
        assert_eq!(cnt_s.distance_evals, cnt_p.distance_evals);
        assert_eq!(cnt_s.pruned_evals, cnt_p.pruned_evals);
    }

    #[test]
    fn k_equals_one_always_prunes_after_init() {
        let (m, n, k) = (64, 3, 1);
        let (pts, cs) = random_problem(5, m, n, k);
        let engine = BoundedEngine::default();
        let mut state = LloydState::new(m);
        let mut counters = Counters::new();
        let mut c = cs.clone();
        let mut old = vec![0f32; n];
        let first = engine.assign_step(&pts, &c, m, n, k, &mut state, &mut counters);
        old.copy_from_slice(&c);
        update_centroids(&first.sums, &first.counts, &mut c, k, n);
        state.apply_update(&old, &c, k, n);
        let before = counters.distance_evals;
        engine.assign_step(&pts, &c, m, n, k, &mut state, &mut counters);
        // With a single centroid the lower bound is infinite: every point
        // prunes with exactly one evaluation.
        assert_eq!(counters.distance_evals - before, m as u64);
    }

    #[test]
    fn kind_roundtrip_and_names() {
        assert_eq!(KernelEngineKind::parse("panel"), Some(KernelEngineKind::Panel));
        assert_eq!(KernelEngineKind::parse("bounded"), Some(KernelEngineKind::Bounded));
        assert_eq!(KernelEngineKind::parse("elkan"), Some(KernelEngineKind::Elkan));
        assert_eq!(KernelEngineKind::parse("hybrid"), Some(KernelEngineKind::Hybrid));
        assert_eq!(KernelEngineKind::parse("warp"), None);
        assert_eq!(KernelEngineKind::Panel.build().name(), "panel");
        assert_eq!(KernelEngineKind::Bounded.build().kind(), KernelEngineKind::Bounded);
        assert_eq!(KernelEngineKind::Elkan.build().name(), "elkan");
        assert_eq!(KernelEngineKind::Hybrid.build().name(), "hybrid");
        for kind in [
            KernelEngineKind::Panel,
            KernelEngineKind::Bounded,
            KernelEngineKind::Elkan,
            KernelEngineKind::Hybrid,
        ] {
            assert_eq!(KernelEngineKind::parse(kind.name()), Some(kind));
        }
    }

    #[test]
    fn elkan_matches_panel_over_iterations() {
        for seed in 1..6u64 {
            let (m, n, k) = (257, 5, 6);
            let (pts, cs) = random_problem(seed, m, n, k);
            let (pa, _, ca) = iterate(&PanelEngine, &pts, m, n, k, 5, &cs);
            let (pe, ce, ceds) = iterate(&ElkanEngine::default(), &pts, m, n, k, 5, &cs);
            assert_eq!(pa.labels, pe.labels, "seed {seed}");
            assert_eq!(pa.counts, pe.counts, "seed {seed}");
            assert_eq!(ca, ceds, "seed {seed}: centroid trajectories diverged");
            assert!(
                (pa.objective - pe.objective).abs() <= 1e-6 * pa.objective.abs() + 1e-12,
                "seed {seed}: {} vs {}",
                pa.objective,
                pe.objective
            );
            assert!(ce.distance_evals > 0);
        }
    }

    #[test]
    fn elkan_prunes_harder_than_bounded_on_separated_blobs() {
        let mut rng = Rng::new(9);
        let centers = [(-8.0f32, -8.0f32), (8.0, 8.0), (-8.0, 8.0)];
        let m = 300;
        let mut pts = Vec::with_capacity(m * 2);
        for i in 0..m {
            let (cx, cy) = centers[i % 3];
            pts.push(cx + 0.2 * rng.gaussian() as f32);
            pts.push(cy + 0.2 * rng.gaussian() as f32);
        }
        let cs: Vec<f32> = pts[..6].to_vec();
        let iters = 6usize;
        let (_, cb, _) = iterate(&BoundedEngine::default(), &pts, m, 2, 3, iters, &cs);
        let (_, ce, _) = iterate(&ElkanEngine::default(), &pts, m, 2, 3, iters, &cs);
        assert!(ce.pruned_evals > 0, "no Elkan pruning on separated blobs");
        assert!(
            ce.distance_evals <= cb.distance_evals,
            "elkan ({}) should prune at least as hard as bounded ({}) here",
            ce.distance_evals,
            cb.distance_evals
        );
    }

    #[test]
    fn parallel_elkan_matches_serial_elkan() {
        let (m, n, k) = (2048, 4, 5);
        let (pts, cs) = random_problem(3, m, n, k);
        let pool = ThreadPool::new(4);
        let engine = ElkanEngine::default();
        let mut c = cs.clone();
        let mut st_s = LloydState::new(m);
        let mut st_p = LloydState::new(m);
        let mut cnt_s = Counters::new();
        let mut cnt_p = Counters::new();
        let mut old = vec![0f32; k * n];
        for _ in 0..4 {
            let a = engine.assign_step(&pts, &c, m, n, k, &mut st_s, &mut cnt_s);
            let b = engine.assign_step_parallel(&pool, &pts, &c, m, n, k, &mut st_p, &mut cnt_p);
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.mins, b.mins);
            assert_eq!(a.counts, b.counts);
            assert!((a.objective - b.objective).abs() <= 1e-6 * a.objective.abs() + 1e-12);
            old.copy_from_slice(&c);
            update_centroids(&a.sums, &a.counts, &mut c, k, n);
            st_s.apply_update(&old, &c, k, n);
            st_p.apply_update(&old, &c, k, n);
        }
        assert_eq!(cnt_s.distance_evals, cnt_p.distance_evals);
        assert_eq!(cnt_s.pruned_evals, cnt_p.pruned_evals);
    }

    #[test]
    fn switching_engine_families_on_one_state_stays_exact() {
        // One LloydState driven alternately by Elkan and Bounded (a misuse
        // no pipeline performs, but the API allows): each switch must
        // re-initialise the bounds instead of trusting the other family's
        // state — labels stay panel-identical throughout.
        let (m, n, k) = (300, 4, 5);
        let (pts, cs) = random_problem(11, m, n, k);
        let bounded = BoundedEngine::default();
        let elkan = ElkanEngine::default();
        let panel = PanelEngine;
        let mut c = cs.clone();
        let mut shared = LloydState::new(m);
        let mut panel_state = LloydState::new(m);
        let mut cnt = Counters::new();
        let mut cnt_p = Counters::new();
        let mut old = vec![0f32; k * n];
        for step in 0..6 {
            let engine: &dyn KernelEngine =
                if step % 2 == 0 { &elkan } else { &bounded };
            let a = engine.assign_step(&pts, &c, m, n, k, &mut shared, &mut cnt);
            let b = panel.assign_step(&pts, &c, m, n, k, &mut panel_state, &mut cnt_p);
            assert_eq!(a.labels, b.labels, "step {step}");
            assert_eq!(a.mins, b.mins, "step {step}");
            old.copy_from_slice(&c);
            update_centroids(&a.sums, &a.counts, &mut c, k, n);
            shared.apply_update(&old, &c, k, n);
        }
    }

    #[test]
    fn hybrid_matches_panel_and_takes_the_switch() {
        // Uniform random data keeps Hamerly rescanning, so with a zero
        // threshold the hybrid engine must take the Elkan switch — while
        // staying bit-identical to the panel engine at every step, before
        // and after.
        let (m, n, k) = (300, 4, 8);
        let (pts, cs) = random_problem(7, m, n, k);
        let hybrid = HybridEngine { switch_threshold: 0.0, ..HybridEngine::default() };
        let panel = PanelEngine;
        let mut c = cs.clone();
        let mut st_h = LloydState::new(m);
        let mut st_p = LloydState::new(m);
        let mut cnt_h = Counters::new();
        let mut cnt_p = Counters::new();
        let mut old = vec![0f32; k * n];
        for step in 0..6 {
            let a = hybrid.assign_step(&pts, &c, m, n, k, &mut st_h, &mut cnt_h);
            let b = panel.assign_step(&pts, &c, m, n, k, &mut st_p, &mut cnt_p);
            assert_eq!(a.labels, b.labels, "step {step}");
            assert_eq!(a.mins, b.mins, "step {step}");
            assert_eq!(a.counts, b.counts, "step {step}");
            old.copy_from_slice(&c);
            update_centroids(&a.sums, &a.counts, &mut c, k, n);
            st_h.apply_update(&old, &c, k, n);
        }
        assert_eq!(cnt_h.hybrid_switches, 1, "expected exactly one Hamerly→Elkan switch");
        assert!(st_h.hybrid_elkan, "state should have latched the Elkan path");
    }

    #[test]
    fn parallel_hybrid_matches_serial_hybrid() {
        let (m, n, k) = (2048, 4, 5);
        let (pts, cs) = random_problem(3, m, n, k);
        let pool = ThreadPool::new(4);
        let engine = HybridEngine::default();
        let mut c = cs.clone();
        let mut st_s = LloydState::new(m);
        let mut st_p = LloydState::new(m);
        let mut cnt_s = Counters::new();
        let mut cnt_p = Counters::new();
        let mut old = vec![0f32; k * n];
        for _ in 0..4 {
            let a = engine.assign_step(&pts, &c, m, n, k, &mut st_s, &mut cnt_s);
            let b = engine.assign_step_parallel(&pool, &pts, &c, m, n, k, &mut st_p, &mut cnt_p);
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.mins, b.mins);
            assert_eq!(a.counts, b.counts);
            old.copy_from_slice(&c);
            update_centroids(&a.sums, &a.counts, &mut c, k, n);
            st_s.apply_update(&old, &c, k, n);
            st_p.apply_update(&old, &c, k, n);
        }
        // Summed step counters drive the switch, so serial and parallel
        // must make the same decision at the same step.
        assert_eq!(cnt_s.distance_evals, cnt_p.distance_evals);
        assert_eq!(cnt_s.pruned_evals, cnt_p.pruned_evals);
        assert_eq!(cnt_s.hybrid_switches, cnt_p.hybrid_switches);
    }

    #[test]
    fn elkan_k_equals_one_always_prunes_after_init() {
        let (m, n, k) = (64, 3, 1);
        let (pts, cs) = random_problem(5, m, n, k);
        let engine = ElkanEngine::default();
        let mut state = LloydState::new(m);
        let mut counters = Counters::new();
        let mut c = cs.clone();
        let mut old = vec![0f32; n];
        let first = engine.assign_step(&pts, &c, m, n, k, &mut state, &mut counters);
        old.copy_from_slice(&c);
        update_centroids(&first.sums, &first.counts, &mut c, k, n);
        state.apply_update(&old, &c, k, n);
        let before = counters.distance_evals;
        engine.assign_step(&pts, &c, m, n, k, &mut state, &mut counters);
        assert_eq!(counters.distance_evals - before, m as u64);
    }
}
