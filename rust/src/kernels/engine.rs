//! Pluggable kernel engines for the assignment hot path.
//!
//! Every pipeline (sequential, chunk-parallel, streaming, VNS, baselines)
//! runs its Lloyd iterations through a [`KernelEngine`], selected by
//! [`KernelEngineKind`] in the configuration / CLI (`--engine`):
//!
//! * [`PanelEngine`] — the exact blocked-panel path: fused
//!   `‖x‖² − 2x·c + ‖c‖²` panel + in-register argmin
//!   ([`super::distance::sq_dist_panel_argmin`]), every point evaluated
//!   against every centroid each iteration.
//! * [`BoundedEngine`] — Hamerly-style triangle-inequality pruning: one
//!   upper and one lower bound per point, relaxed by per-centroid drift
//!   after each centroid update ([`LloydState::apply_update`]). A point
//!   whose (tightened) upper bound sits below its lower bound keeps its
//!   label with **one** distance evaluation instead of `k` — on separated
//!   clusters most of the chunk converges and the assignment cost drops
//!   toward `O(m)` per iteration. Pruning is *exact*: both engines use the
//!   identical decomposition arithmetic, so labels, counts, and objectives
//!   agree (cross-checked by `tests/property_engines.rs`). Evaluations
//!   avoided by pruning are reported in
//!   [`crate::metrics::Counters::pruned_evals`] so the paper's `n_d` tables
//!   can show the saving.
//!
//! The bounds live in a [`LloydState`] owned by the Lloyd loop and persist
//! across iterations; the parallel path hands each worker a disjoint slice
//! of the state (`split_at_mut`), so pruning composes with the row-blocked
//! `ThreadPool` assignment without locks.

use crate::metrics::Counters;
use crate::util::threadpool::ThreadPool;

use super::assign::{self, AssignOut};
use super::distance::{nearest2_decomp, sq_dist, sq_dist_decomp, sq_norm};

/// Which kernel engine runs the assignment step (config / CLI level).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelEngineKind {
    /// Exact blocked panel with fused argmin (the default).
    Panel,
    /// Hamerly-bound pruned exact assignment.
    Bounded,
}

impl KernelEngineKind {
    /// Instantiate the engine.
    pub fn build(self) -> Box<dyn KernelEngine> {
        match self {
            KernelEngineKind::Panel => Box::new(PanelEngine),
            KernelEngineKind::Bounded => Box::new(BoundedEngine::default()),
        }
    }

    /// Parse a CLI token (`panel` / `bounded`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "panel" => Some(KernelEngineKind::Panel),
            "bounded" => Some(KernelEngineKind::Bounded),
            _ => None,
        }
    }
}

/// Per-point assignment state that persists across Lloyd iterations.
///
/// For the bounded engine this holds the current label plus Hamerly
/// upper/lower bounds (in *distance*, not squared-distance, domain — the
/// triangle inequality is linear). The panel engine never activates it,
/// and the vectors allocate lazily, so carrying a `LloydState` through a
/// panel run costs nothing.
#[derive(Clone, Debug)]
pub struct LloydState {
    m: usize,
    labels: Vec<u32>,
    /// Upper bound on the distance to the assigned centroid.
    upper: Vec<f64>,
    /// Lower bound on the distance to every *other* centroid.
    lower: Vec<f64>,
    /// Cached `‖x‖²` per point — invariant across iterations (the points
    /// of one Lloyd run never change), filled by the init pass.
    x_sq: Vec<f32>,
    /// Set by the first bounded assignment; `apply_update` is a no-op (and
    /// drift tracking is skipped entirely) while inactive.
    active: bool,
}

impl LloydState {
    /// Fresh state for `m` points. The bound vectors are allocated lazily
    /// by the first bounded assignment, so panel runs that thread a state
    /// through the Lloyd loop pay nothing for it.
    pub fn new(m: usize) -> Self {
        LloydState {
            m,
            labels: Vec::new(),
            upper: Vec::new(),
            lower: Vec::new(),
            x_sq: Vec::new(),
            active: false,
        }
    }

    /// Number of points the state tracks.
    pub fn len(&self) -> usize {
        self.m
    }

    pub fn is_empty(&self) -> bool {
        self.m == 0
    }

    /// Materialise the per-point vectors (first bounded use).
    fn ensure_allocated(&mut self) {
        if self.labels.len() != self.m {
            self.labels = vec![0u32; self.m];
            self.upper = vec![0f64; self.m];
            self.lower = vec![0f64; self.m];
            self.x_sq = vec![0f32; self.m];
        }
    }

    /// Whether a bounded assignment has initialised the bounds.
    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Labels from the most recent bounded assignment (meaningless while
    /// inactive).
    pub fn labels(&self) -> &[u32] {
        &self.labels
    }

    /// Relax the bounds for a centroid update `old → new` (Hamerly): each
    /// centroid's drift widens the upper bound of the points assigned to it,
    /// and the largest drift among the *other* centroids shrinks every lower
    /// bound. Call after every `update_centroids`; no-op while inactive.
    pub fn apply_update(
        &mut self,
        old_centroids: &[f32],
        new_centroids: &[f32],
        k: usize,
        n: usize,
    ) {
        if !self.active {
            return;
        }
        debug_assert_eq!(old_centroids.len(), k * n);
        debug_assert_eq!(new_centroids.len(), k * n);
        let mut drift = vec![0f64; k];
        // Largest and second-largest drift, so points assigned to the
        // fastest-moving centroid get the tighter (second-largest) bound.
        let mut max1 = 0f64;
        let mut max1_j = 0usize;
        let mut max2 = 0f64;
        for (j, d) in drift.iter_mut().enumerate() {
            let dj = (sq_dist(
                &old_centroids[j * n..(j + 1) * n],
                &new_centroids[j * n..(j + 1) * n],
            ) as f64)
                .sqrt();
            *d = dj;
            if dj > max1 {
                max2 = max1;
                max1 = dj;
                max1_j = j;
            } else if dj > max2 {
                max2 = dj;
            }
        }
        if max1 == 0.0 {
            return; // nothing moved — bounds stay exact
        }
        for i in 0..self.labels.len() {
            let l = self.labels[i] as usize;
            self.upper[i] += drift[l];
            self.lower[i] -= if l == max1_j { max2 } else { max1 };
        }
    }
}

/// A disjoint per-worker window into a [`LloydState`] (plus the rows of the
/// point block it covers) — the unit the parallel bounded path hands to
/// each `ThreadPool` worker.
struct StateSlice<'a> {
    labels: &'a mut [u32],
    upper: &'a mut [f64],
    lower: &'a mut [f64],
    x_sq: &'a mut [f32],
}

/// Strategy interface for the fused assignment step.
///
/// `assign_step` is the stateful per-iteration entry point Lloyd loops use;
/// `assign_once` is the stateless labels+mins pass (final full-dataset
/// assignment, D² weights). Engines are `Send + Sync` so one instance can
/// serve the pool-parallel path.
pub trait KernelEngine: Send + Sync {
    /// Engine kind (for reports and config round-trips).
    fn kind(&self) -> KernelEngineKind;

    /// Human-readable engine name.
    fn name(&self) -> &'static str;

    /// Fused assignment + per-cluster reduction for one Lloyd iteration,
    /// reading and updating the persistent `state`. `state.len()` must
    /// equal `m`.
    fn assign_step(
        &self,
        points: &[f32],
        centroids: &[f32],
        m: usize,
        n: usize,
        k: usize,
        state: &mut LloydState,
        counters: &mut Counters,
    ) -> AssignOut;

    /// Row-blocked parallel variant of [`KernelEngine::assign_step`]
    /// (per-worker state slices). Semantically identical to the serial
    /// path: labels, mins, and counts match exactly; f64 accumulations up
    /// to merge order.
    fn assign_step_parallel(
        &self,
        pool: &ThreadPool,
        points: &[f32],
        centroids: &[f32],
        m: usize,
        n: usize,
        k: usize,
        state: &mut LloydState,
        counters: &mut Counters,
    ) -> AssignOut;

    /// Stateless nearest-centroid pass: `(labels, min_sq_dists)`.
    fn assign_once(
        &self,
        points: &[f32],
        centroids: &[f32],
        m: usize,
        n: usize,
        k: usize,
        counters: &mut Counters,
    ) -> (Vec<u32>, Vec<f32>) {
        assign::assign_only(points, centroids, m, n, k, counters)
    }
}

/// The exact blocked-panel engine (fused panel + argmin, no pruning).
pub struct PanelEngine;

impl KernelEngine for PanelEngine {
    fn kind(&self) -> KernelEngineKind {
        KernelEngineKind::Panel
    }

    fn name(&self) -> &'static str {
        "panel"
    }

    fn assign_step(
        &self,
        points: &[f32],
        centroids: &[f32],
        m: usize,
        n: usize,
        k: usize,
        _state: &mut LloydState,
        counters: &mut Counters,
    ) -> AssignOut {
        assign::assign_accumulate(points, centroids, m, n, k, counters)
    }

    fn assign_step_parallel(
        &self,
        pool: &ThreadPool,
        points: &[f32],
        centroids: &[f32],
        m: usize,
        n: usize,
        k: usize,
        _state: &mut LloydState,
        counters: &mut Counters,
    ) -> AssignOut {
        assign::assign_accumulate_parallel(pool, points, centroids, m, n, k, counters)
    }
}

/// Hamerly-bound pruned exact assignment.
///
/// The prune test combines two safety slacks so a stale bound can never
/// keep a label the panel engine would change:
///
/// * a *relative* margin (`upper·(1+margin) ≤ lower`) covering the drift
///   accumulation across iterations, and
/// * an *absolute* squared-domain slack scaled by `‖x‖² + max‖c‖²`,
///   covering the cancellation error of the f32 `‖x‖² − 2x·c + ‖c‖²`
///   decomposition — which is absolute in the norms, not relative to the
///   distance, and dominates for tight clusters far from the origin.
///
/// Failing to prune only costs a rescan (still exact), so both slacks
/// trade a little pruning for label identity with the panel engine.
pub struct BoundedEngine {
    /// Relative safety slack on the prune test.
    pub margin: f64,
}

impl Default for BoundedEngine {
    fn default() -> Self {
        BoundedEngine { margin: 1e-2 }
    }
}

/// Absolute error bound (squared-distance domain) of one decomposition
/// evaluation: `(x_sq + c_sq_max) · eval_slack(n)`. The factor counts the
/// rounding steps of the lane-tiled dot product (`n / LANES` adds per
/// lane + reduction + the 3-term combination), padded generously — the
/// cost of overestimating is a few extra rescans, never a wrong label.
fn eval_slack(n: usize) -> f64 {
    (n as f64 / 16.0 + 8.0) * (f32::EPSILON as f64)
}

impl BoundedEngine {
    /// Serial bounded assignment over one contiguous row block. `slice`
    /// windows the persistent state for exactly these rows; `active` is the
    /// state flag captured before slicing (shared by all workers of one
    /// step).
    #[allow(clippy::too_many_arguments)]
    fn bounded_block(
        &self,
        points: &[f32],
        centroids: &[f32],
        n: usize,
        k: usize,
        c_sq: &[f32],
        slice: StateSlice<'_>,
        active: bool,
        counters: &mut Counters,
    ) -> AssignOut {
        let rows = slice.labels.len();
        debug_assert_eq!(points.len(), rows * n);
        debug_assert_eq!(centroids.len(), k * n);
        debug_assert_eq!(c_sq.len(), k);
        let StateSlice { labels, upper, lower, x_sq: x_sq_cache } = slice;
        let c_sq_max = c_sq.iter().cloned().fold(0f32, f32::max) as f64;
        let slack_factor = eval_slack(n);
        let mut out_labels = vec![0u32; rows];
        let mut mins = vec![0f32; rows];
        let mut sums = vec![0f64; k * n];
        let mut counts = vec![0u64; k];
        let mut objective = 0f64;
        let mut evals = 0u64;
        let mut pruned = 0u64;

        for i in 0..rows {
            let x = &points[i * n..(i + 1) * n];
            let (best, best_d) = if !active {
                // Init pass: full best/second-best scan, caching the
                // iteration-invariant point norm alongside the bounds.
                let x_sq = sq_norm(x);
                x_sq_cache[i] = x_sq;
                evals += k as u64;
                let (j1, d1, d2) = nearest2_decomp(x, x_sq, centroids, c_sq, k, n);
                labels[i] = j1 as u32;
                upper[i] = (d1 as f64).sqrt();
                lower[i] = (d2 as f64).sqrt();
                (j1, d1)
            } else {
                let x_sq = x_sq_cache[i];
                let l = labels[i] as usize;
                // Tighten: one exact evaluation against the assigned
                // centroid. With the tightened upper bound below the lower
                // bound on every other centroid, `l` is still the nearest
                // and `d_l` is the exact min — no further evaluations.
                let d_l = sq_dist_decomp(x, x_sq, &centroids[l * n..(l + 1) * n], c_sq[l]);
                let ub = (d_l as f64).sqrt();
                upper[i] = ub;
                // Prune test in the squared domain (avoids a division when
                // converting the absolute slack): lower² must clear the
                // margined upper² plus the decomposition's cancellation
                // error band.
                let thr = ub * (1.0 + self.margin);
                let slack = (x_sq as f64 + c_sq_max) * slack_factor;
                let lb = lower[i];
                if lb > 0.0 && thr * thr + slack <= lb * lb {
                    evals += 1;
                    pruned += (k - 1) as u64;
                    (l, d_l)
                } else {
                    // Bounds inconclusive: full rescan (same arithmetic and
                    // tie-breaking as the panel path), refreshing both
                    // bounds from the exact best / second-best.
                    evals += (k + 1) as u64;
                    let (j1, d1, d2) = nearest2_decomp(x, x_sq, centroids, c_sq, k, n);
                    labels[i] = j1 as u32;
                    upper[i] = (d1 as f64).sqrt();
                    lower[i] = (d2 as f64).sqrt();
                    (j1, d1)
                }
            };
            out_labels[i] = best as u32;
            mins[i] = best_d;
            objective += best_d as f64;
            counts[best] += 1;
            let srow = &mut sums[best * n..(best + 1) * n];
            for (sv, xv) in srow.iter_mut().zip(x) {
                *sv += *xv as f64;
            }
        }
        counters.add_distance_evals(evals);
        counters.add_pruned_evals(pruned);
        AssignOut { labels: out_labels, mins, sums, counts, objective }
    }
}

impl KernelEngine for BoundedEngine {
    fn kind(&self) -> KernelEngineKind {
        KernelEngineKind::Bounded
    }

    fn name(&self) -> &'static str {
        "bounded"
    }

    fn assign_step(
        &self,
        points: &[f32],
        centroids: &[f32],
        m: usize,
        n: usize,
        k: usize,
        state: &mut LloydState,
        counters: &mut Counters,
    ) -> AssignOut {
        assert_eq!(points.len(), m * n, "points shape");
        assert_eq!(centroids.len(), k * n, "centroids shape");
        assert_eq!(state.len(), m, "state length");
        assert!(k > 0, "k must be positive");
        state.ensure_allocated();
        let c_sq: Vec<f32> = (0..k).map(|j| sq_norm(&centroids[j * n..(j + 1) * n])).collect();
        let active = state.active;
        let slice = StateSlice {
            labels: &mut state.labels[..],
            upper: &mut state.upper[..],
            lower: &mut state.lower[..],
            x_sq: &mut state.x_sq[..],
        };
        let out = self.bounded_block(points, centroids, n, k, &c_sq, slice, active, counters);
        state.active = true;
        out
    }

    fn assign_step_parallel(
        &self,
        pool: &ThreadPool,
        points: &[f32],
        centroids: &[f32],
        m: usize,
        n: usize,
        k: usize,
        state: &mut LloydState,
        counters: &mut Counters,
    ) -> AssignOut {
        assert_eq!(points.len(), m * n, "points shape");
        assert_eq!(centroids.len(), k * n, "centroids shape");
        assert_eq!(state.len(), m, "state length");
        // The shared partition rule keeps thresholds and merge order
        // engine-independent.
        let Some(jobs) = assign::partition_rows(pool, m) else {
            return self.assign_step(points, centroids, m, n, k, state, counters);
        };
        state.ensure_allocated();
        let c_sq: Vec<f32> = (0..k).map(|j| sq_norm(&centroids[j * n..(j + 1) * n])).collect();
        let active = state.active;
        // Carve the state into disjoint per-worker windows (jobs tile
        // `0..m` in order, so successive split_at_mut calls line up).
        let mut views: Vec<(usize, StateSlice<'_>)> = Vec::with_capacity(jobs.len());
        {
            let mut lab_rest: &mut [u32] = &mut state.labels;
            let mut up_rest: &mut [f64] = &mut state.upper;
            let mut lo_rest: &mut [f64] = &mut state.lower;
            let mut xs_rest: &mut [f32] = &mut state.x_sq;
            for &(start, end) in &jobs {
                let rows = end - start;
                let (lab, lab_tail) = lab_rest.split_at_mut(rows);
                let (up, up_tail) = up_rest.split_at_mut(rows);
                let (lo, lo_tail) = lo_rest.split_at_mut(rows);
                let (xs, xs_tail) = xs_rest.split_at_mut(rows);
                lab_rest = lab_tail;
                up_rest = up_tail;
                lo_rest = lo_tail;
                xs_rest = xs_tail;
                views.push((start, StateSlice { labels: lab, upper: up, lower: lo, x_sq: xs }));
            }
        }
        let mut partials: Vec<Option<(usize, AssignOut, Counters)>> =
            (0..views.len()).map(|_| None).collect();
        let c_sq_ref: &[f32] = &c_sq;
        let closures: Vec<_> = views
            .into_iter()
            .zip(partials.iter_mut())
            .map(|((start, slice), slot)| {
                let rows = slice.labels.len();
                let pts = &points[start * n..(start + rows) * n];
                move || {
                    let mut local = Counters::new();
                    let out = self
                        .bounded_block(pts, centroids, n, k, c_sq_ref, slice, active, &mut local);
                    *slot = Some((start, out, local));
                }
            })
            .collect();
        pool.scope_run_all(closures);
        state.active = true;

        let mut labels = vec![0u32; m];
        let mut mins = vec![0f32; m];
        let mut sums = vec![0f64; k * n];
        let mut counts = vec![0u64; k];
        let mut objective = 0f64;
        for part in partials.into_iter().flatten() {
            let (start, out, local) = part;
            let rows = out.labels.len();
            labels[start..start + rows].copy_from_slice(&out.labels);
            mins[start..start + rows].copy_from_slice(&out.mins);
            for (acc, v) in sums.iter_mut().zip(&out.sums) {
                *acc += *v;
            }
            for (acc, v) in counts.iter_mut().zip(&out.counts) {
                *acc += *v;
            }
            objective += out.objective;
            counters.merge(&local);
        }
        AssignOut { labels, mins, sums, counts, objective }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::update::update_centroids;
    use crate::util::rng::Rng;

    fn random_problem(seed: u64, m: usize, n: usize, k: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let pts: Vec<f32> = (0..m * n).map(|_| rng.f32() * 10.0 - 5.0).collect();
        let cs: Vec<f32> = pts[..k * n].to_vec();
        (pts, cs)
    }

    /// Run `iters` full Lloyd iterations with the given engine, returning
    /// the final step output plus the counters.
    fn iterate(
        engine: &dyn KernelEngine,
        pts: &[f32],
        m: usize,
        n: usize,
        k: usize,
        iters: usize,
        seed_c: &[f32],
    ) -> (AssignOut, Counters, Vec<f32>) {
        let mut c = seed_c.to_vec();
        let mut old = vec![0f32; k * n];
        let mut state = LloydState::new(m);
        let mut counters = Counters::new();
        let mut last = None;
        for _ in 0..iters {
            let out = engine.assign_step(pts, &c, m, n, k, &mut state, &mut counters);
            old.copy_from_slice(&c);
            update_centroids(&out.sums, &out.counts, &mut c, k, n);
            state.apply_update(&old, &c, k, n);
            last = Some(out);
        }
        (last.unwrap(), counters, c)
    }

    #[test]
    fn bounded_matches_panel_over_iterations() {
        for seed in 1..6u64 {
            let (m, n, k) = (257, 5, 6);
            let (pts, cs) = random_problem(seed, m, n, k);
            let (pa, _, ca) = iterate(&PanelEngine, &pts, m, n, k, 5, &cs);
            let (pb, cb, cbds) = iterate(&BoundedEngine::default(), &pts, m, n, k, 5, &cs);
            assert_eq!(pa.labels, pb.labels, "seed {seed}");
            assert_eq!(pa.counts, pb.counts, "seed {seed}");
            assert_eq!(ca, cbds, "seed {seed}: centroid trajectories diverged");
            assert!(
                (pa.objective - pb.objective).abs() <= 1e-6 * pa.objective.abs() + 1e-12,
                "seed {seed}: {} vs {}",
                pa.objective,
                pb.objective
            );
            assert!(cb.distance_evals > 0);
        }
    }

    #[test]
    fn bounded_prunes_on_separated_blobs() {
        let mut rng = Rng::new(9);
        let centers = [(-8.0f32, -8.0f32), (8.0, 8.0), (-8.0, 8.0)];
        let m = 300;
        let mut pts = Vec::with_capacity(m * 2);
        for i in 0..m {
            let (cx, cy) = centers[i % 3];
            pts.push(cx + 0.2 * rng.gaussian() as f32);
            pts.push(cy + 0.2 * rng.gaussian() as f32);
        }
        let cs: Vec<f32> = pts[..6].to_vec();
        let iters = 6u64;
        let full = iters * (m as u64) * 3;
        let (_, counters, _) = iterate(&BoundedEngine::default(), &pts, m, 2, 3, iters as usize, &cs);
        assert!(counters.pruned_evals > 0, "no pruning on separated blobs");
        // Pruning must produce a real saving over the unpruned engine...
        assert!(
            counters.distance_evals < full,
            "evals {} not below unpruned {full}",
            counters.distance_evals
        );
        // ...and the accounting must close: every pruned point costs 1 eval
        // and avoids k−1, every rescan costs k+1, the init pass costs k —
        // so done + avoided covers at least every m·k slot.
        assert!(counters.distance_evals + counters.pruned_evals >= full);
    }

    #[test]
    fn parallel_bounded_matches_serial_bounded() {
        // Both paths follow the SAME centroid trajectory (updated from the
        // serial output), so every per-point quantity must match exactly —
        // the parallel path only changes the f64 *merge* order of sums,
        // which this test deliberately keeps out of the trajectory.
        let (m, n, k) = (2048, 4, 5);
        let (pts, cs) = random_problem(3, m, n, k);
        let pool = ThreadPool::new(4);
        let engine = BoundedEngine::default();
        let mut c = cs.clone();
        let mut st_s = LloydState::new(m);
        let mut st_p = LloydState::new(m);
        let mut cnt_s = Counters::new();
        let mut cnt_p = Counters::new();
        let mut old = vec![0f32; k * n];
        for _ in 0..4 {
            let a = engine.assign_step(&pts, &c, m, n, k, &mut st_s, &mut cnt_s);
            let b = engine.assign_step_parallel(&pool, &pts, &c, m, n, k, &mut st_p, &mut cnt_p);
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.mins, b.mins);
            assert_eq!(a.counts, b.counts);
            assert!((a.objective - b.objective).abs() <= 1e-6 * a.objective.abs() + 1e-12);
            old.copy_from_slice(&c);
            update_centroids(&a.sums, &a.counts, &mut c, k, n);
            st_s.apply_update(&old, &c, k, n);
            st_p.apply_update(&old, &c, k, n);
        }
        assert_eq!(cnt_s.distance_evals, cnt_p.distance_evals);
        assert_eq!(cnt_s.pruned_evals, cnt_p.pruned_evals);
    }

    #[test]
    fn k_equals_one_always_prunes_after_init() {
        let (m, n, k) = (64, 3, 1);
        let (pts, cs) = random_problem(5, m, n, k);
        let engine = BoundedEngine::default();
        let mut state = LloydState::new(m);
        let mut counters = Counters::new();
        let mut c = cs.clone();
        let mut old = vec![0f32; n];
        let first = engine.assign_step(&pts, &c, m, n, k, &mut state, &mut counters);
        old.copy_from_slice(&c);
        update_centroids(&first.sums, &first.counts, &mut c, k, n);
        state.apply_update(&old, &c, k, n);
        let before = counters.distance_evals;
        engine.assign_step(&pts, &c, m, n, k, &mut state, &mut counters);
        // With a single centroid the lower bound is infinite: every point
        // prunes with exactly one evaluation.
        assert_eq!(counters.distance_evals - before, m as u64);
    }

    #[test]
    fn kind_roundtrip_and_names() {
        assert_eq!(KernelEngineKind::parse("panel"), Some(KernelEngineKind::Panel));
        assert_eq!(KernelEngineKind::parse("bounded"), Some(KernelEngineKind::Bounded));
        assert_eq!(KernelEngineKind::parse("warp"), None);
        assert_eq!(KernelEngineKind::Panel.build().name(), "panel");
        assert_eq!(KernelEngineKind::Bounded.build().kind(), KernelEngineKind::Bounded);
    }
}
