//! Centroid update step: means from per-cluster sums, preserving the
//! positions of degenerate (empty) clusters — the contract the coordinator
//! and the L2 model share.

/// Compute new centroids from reduction output. Degenerate clusters (count
/// 0) keep their previous position and are reported back. Returns the list
/// of degenerate cluster indices.
pub fn update_centroids(
    sums: &[f64],
    counts: &[u64],
    centroids: &mut [f32],
    k: usize,
    n: usize,
) -> Vec<usize> {
    assert_eq!(sums.len(), k * n);
    assert_eq!(counts.len(), k);
    assert_eq!(centroids.len(), k * n);
    let mut degenerate = Vec::new();
    for j in 0..k {
        if counts[j] == 0 {
            degenerate.push(j);
            continue;
        }
        let inv = 1.0 / counts[j] as f64;
        let dst = &mut centroids[j * n..(j + 1) * n];
        let src = &sums[j * n..(j + 1) * n];
        for (d, s) in dst.iter_mut().zip(src) {
            *d = (s * inv) as f32;
        }
    }
    degenerate
}

/// Indices of degenerate clusters given counts.
pub fn degenerate_indices(counts: &[u64]) -> Vec<usize> {
    counts
        .iter()
        .enumerate()
        .filter_map(|(j, &c)| (c == 0).then_some(j))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn means_computed_and_degenerates_kept() {
        let sums = vec![4.0, 8.0, 0.0, 0.0]; // k=2, n=2
        let counts = vec![2u64, 0];
        let mut cs = vec![9.0f32, 9.0, 7.0, 7.0];
        let deg = update_centroids(&sums, &counts, &mut cs, 2, 2);
        assert_eq!(deg, vec![1]);
        assert_eq!(&cs[..2], &[2.0, 4.0]); // mean
        assert_eq!(&cs[2..], &[7.0, 7.0]); // untouched
    }

    #[test]
    fn degenerate_indices_finds_all() {
        assert_eq!(degenerate_indices(&[1, 0, 3, 0]), vec![1, 3]);
        assert!(degenerate_indices(&[1, 1]).is_empty());
    }
}
