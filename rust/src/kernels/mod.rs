//! Native rust kernel substrate: the same primitives the L1/L2 AOT
//! artifacts implement (assignment, reduction, Lloyd, K-means++,
//! objective), for arbitrary shapes and for the baseline algorithms.
//! Cross-checked against the HLO path in `tests/integration_runtime.rs`.
//!
//! # Roofline: the SIMD dispatch table
//!
//! Every byte the system touches — sampling shots, Lloyd iterations, the
//! canonical final pass, served `assign` batches — funnels through the
//! distance primitives, so their throughput sets the whole pipeline's
//! ceiling. [`distance`] keeps the auto-vectorized scalar tiles as the
//! reference implementation and dispatches at runtime to the explicit
//! backends in [`simd`]:
//!
//! | ISA      | arch    | selection                                         |
//! |----------|---------|---------------------------------------------------|
//! | `scalar` | any     | always available (the reference)                  |
//! | `avx512` | x86_64  | `is_x86_feature_detected!("avx512f")` + rustc≥1.89 |
//! | `avx2`   | x86_64  | `is_x86_feature_detected!("avx2")`                |
//! | `neon`   | aarch64 | architecture baseline                             |
//!
//! Selection order: CLI `--isa` ([`simd::set_isa`], which rejects an
//! unavailable request with an error listing [`simd::detected_isas`]) >
//! `BIGMEANS_ISA` env > auto-detect (avx512 > avx2 > neon > scalar),
//! resolved once and cached in an atomic.
//!
//! **Reduction-order contract.** All backends are bit-identical to the
//! scalar path: 16 independent f32 lane accumulators filled in chunk
//! order, combined by a pairwise tree (width 8 → 4 → 2 → 1), with a
//! separately-accumulated scalar tail added last — and *no* fused
//! multiply-add anywhere, because the scalar reference is uncontracted.
//! This is what lets the ISA be swapped mid-process (bench A/B rows, the
//! `--isa` test matrix) without perturbing a single label: the gating
//! sweep in `tests/property_engines.rs` bit-compares every backend.
//!
//! **Quantisation slack model.** The Elkan engine's `O(m·k)` lower-bound
//! matrix is stored as `u16` quanta of a per-activation scale
//! (`LloydState`), cutting bound-state traffic 4× vs `f64`. Rounding is
//! one-sided: stores truncate toward zero and saturate downward, drift
//! relaxation subtracts `ceil(drift/scale)` quanta, so a dequantised
//! bound never exceeds the true distance. Quantisation therefore only
//! *weakens* bounds — each quantised bound forgoes at most one scale-step
//! of pruning power (the slack), buying extra rescans but never a wrong
//! label; labels and objectives stay bit-identical to the exact-bound
//! engines.

pub mod assign;
pub mod distance;
pub mod engine;
pub mod kmeanspp;
pub mod lloyd;
pub mod objective;
pub mod simd;
pub mod update;

pub use assign::{
    assign_accumulate, assign_accumulate_parallel, assign_only, assign_only_pooled,
    panel_assign_into, AssignOut,
};
pub use engine::{
    BoundedEngine, ElkanEngine, HybridEngine, KernelEngine, KernelEngineKind, LloydState,
    PanelEngine, DEFAULT_HYBRID_THRESHOLD,
};
pub use kmeanspp::{kmeanspp, reseed_degenerate, reseed_degenerate_random};
pub use lloyd::{lloyd, lloyd_with_engine, LloydParams, LloydResult};
pub use objective::{objective, objective_parallel};
pub use simd::{active_isa, detect as detect_isa, detected_isas, set_isa, DistanceIsa};
pub use update::{degenerate_indices, update_centroids};
