//! Native rust kernel substrate: the same primitives the L1/L2 AOT
//! artifacts implement (assignment, reduction, Lloyd, K-means++,
//! objective), for arbitrary shapes and for the baseline algorithms.
//! Cross-checked against the HLO path in `tests/integration_runtime.rs`.

pub mod assign;
pub mod distance;
pub mod engine;
pub mod kmeanspp;
pub mod lloyd;
pub mod objective;
pub mod update;

pub use assign::{
    assign_accumulate, assign_accumulate_parallel, assign_only, assign_only_pooled,
    panel_assign_into, AssignOut,
};
pub use engine::{
    BoundedEngine, ElkanEngine, KernelEngine, KernelEngineKind, LloydState, PanelEngine,
};
pub use kmeanspp::{kmeanspp, reseed_degenerate, reseed_degenerate_random};
pub use lloyd::{lloyd, lloyd_with_engine, LloydParams, LloydResult};
pub use objective::{objective, objective_parallel};
pub use update::{degenerate_indices, update_centroids};
