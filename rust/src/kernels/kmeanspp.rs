//! K-means++ seeding (Algorithm 2 of the paper) and partial reseeding of
//! degenerate centroids — the `Init` ingredient of Big-means.
//!
//! Uses the incremental D² update: after each selection only the distances
//! to the *new* centroid are computed (`O(m·n)` per draw), so a full seeding
//! costs `m·k` distance evaluations, matching the paper's complexity claim.
//! The paper evaluates 3 candidate points per draw and keeps the best
//! (§5.7, "three candidate points are considered"); `candidates` exposes
//! that knob.

use crate::metrics::Counters;
use crate::util::rng::Rng;

use super::distance::sq_dist;

/// Number of candidate points per D² draw (paper §5.7 uses 3).
pub const DEFAULT_CANDIDATES: usize = 3;

/// Full K-means++ seeding: choose `k` centroids from `points`.
pub fn kmeanspp(
    points: &[f32],
    m: usize,
    n: usize,
    k: usize,
    candidates: usize,
    rng: &mut Rng,
    counters: &mut Counters,
) -> Vec<f32> {
    assert!(m > 0 && k > 0 && k <= m, "kmeanspp: need 0 < k <= m");
    let mut centroids = vec![0f32; k * n];
    // First centroid: uniform.
    let first = rng.usize(m);
    centroids[..n].copy_from_slice(&points[first * n..(first + 1) * n]);
    if k == 1 {
        return centroids;
    }
    // d2[i] = min squared distance to chosen centroids.
    let mut d2 = vec![0f64; m];
    for i in 0..m {
        d2[i] = sq_dist(&points[i * n..(i + 1) * n], &centroids[..n]) as f64;
    }
    counters.add_distance_evals(m as u64);

    for j in 1..k {
        let idx = pick_candidate(points, m, n, &d2, candidates, rng, counters);
        let cj = &points[idx * n..(idx + 1) * n];
        centroids[j * n..(j + 1) * n].copy_from_slice(cj);
        // Incremental D² update against the new centroid only.
        for i in 0..m {
            let d = sq_dist(&points[i * n..(i + 1) * n], cj) as f64;
            if d < d2[i] {
                d2[i] = d;
            }
        }
        counters.add_distance_evals(m as u64);
    }
    centroids
}

/// Reseed `slots` (degenerate centroid indices) inside an existing centroid
/// set using D² weighting against the *non-degenerate* centroids — the
/// Big-means degenerate-reinit step.
pub fn reseed_degenerate(
    points: &[f32],
    m: usize,
    n: usize,
    k: usize,
    centroids: &mut [f32],
    slots: &[usize],
    candidates: usize,
    rng: &mut Rng,
    counters: &mut Counters,
) {
    assert_eq!(centroids.len(), k * n);
    if slots.is_empty() {
        return;
    }
    let alive: Vec<usize> = (0..k).filter(|j| !slots.contains(j)).collect();
    // D² to the alive set (all-degenerate → uniform weights).
    let mut d2 = vec![1f64; m];
    if !alive.is_empty() {
        for i in 0..m {
            let x = &points[i * n..(i + 1) * n];
            let mut best = f64::INFINITY;
            for &j in &alive {
                let d = sq_dist(x, &centroids[j * n..(j + 1) * n]) as f64;
                if d < best {
                    best = d;
                }
            }
            d2[i] = best;
        }
        counters.add_distance_evals((m * alive.len()) as u64);
    }
    for &slot in slots {
        let idx = pick_candidate(points, m, n, &d2, candidates, rng, counters);
        let cj = &points[idx * n..(idx + 1) * n];
        centroids[slot * n..(slot + 1) * n].copy_from_slice(cj);
        for i in 0..m {
            let d = sq_dist(&points[i * n..(i + 1) * n], cj) as f64;
            if d < d2[i] {
                d2[i] = d;
            }
        }
        counters.add_distance_evals(m as u64);
    }
}

/// Uniform (Forgy-style) reseeding of degenerate slots — the ablation
/// comparator for `reinit: Random` in the config.
pub fn reseed_degenerate_random(
    points: &[f32],
    m: usize,
    n: usize,
    centroids: &mut [f32],
    slots: &[usize],
    rng: &mut Rng,
) {
    for &slot in slots {
        let idx = rng.usize(m);
        centroids[slot * n..(slot + 1) * n]
            .copy_from_slice(&points[idx * n..(idx + 1) * n]);
    }
}

/// Draw `candidates` D²-weighted indices and keep the one that most reduces
/// the potential (greedy candidate selection, paper §5.7). With
/// `candidates == 1` this is the classic K-means++ draw.
fn pick_candidate(
    points: &[f32],
    m: usize,
    n: usize,
    d2: &[f64],
    candidates: usize,
    rng: &mut Rng,
    counters: &mut Counters,
) -> usize {
    let total: f64 = d2.iter().sum();
    if total <= 0.0 {
        // All points coincide with existing centroids: any point works.
        return rng.usize(m);
    }
    let draw = |rng: &mut Rng| -> usize {
        let mut cursor = rng.f64() * total;
        for (i, &w) in d2.iter().enumerate() {
            if w > 0.0 {
                if cursor < w {
                    return i;
                }
                cursor -= w;
            }
        }
        // fp slack: last positive-weight index
        d2.iter().rposition(|&w| w > 0.0).unwrap_or(m - 1)
    };
    if candidates <= 1 {
        return draw(rng);
    }
    let mut best_idx = 0usize;
    let mut best_pot = f64::INFINITY;
    for _ in 0..candidates {
        let idx = draw(rng);
        let cand = &points[idx * n..(idx + 1) * n];
        // Potential if we were to add this candidate.
        let mut pot = 0f64;
        for i in 0..m {
            let d = sq_dist(&points[i * n..(i + 1) * n], cand) as f64;
            pot += d.min(d2[i]);
        }
        counters.add_distance_evals(m as u64);
        if pot < best_pot {
            best_pot = pot;
            best_idx = idx;
        }
    }
    best_idx
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob_data() -> (Vec<f32>, usize) {
        // 4 tight blobs on a square, 25 pts each.
        let mut rng = Rng::new(7);
        let centers = [(0.0f32, 0.0f32), (50.0, 0.0), (0.0, 50.0), (50.0, 50.0)];
        let mut pts = Vec::new();
        for &(cx, cy) in &centers {
            for _ in 0..25 {
                pts.push(cx + 0.1 * rng.gaussian() as f32);
                pts.push(cy + 0.1 * rng.gaussian() as f32);
            }
        }
        (pts, 100)
    }

    #[test]
    fn selects_actual_points() {
        let (pts, m) = blob_data();
        let mut rng = Rng::new(1);
        let mut c = Counters::new();
        let cs = kmeanspp(&pts, m, 2, 4, 1, &mut rng, &mut c);
        for j in 0..4 {
            let cj = &cs[j * 2..j * 2 + 2];
            let found = (0..m).any(|i| sq_dist(&pts[i * 2..i * 2 + 2], cj) < 1e-12);
            assert!(found, "centroid {j} is not a data point");
        }
    }

    #[test]
    fn hits_all_separated_blobs_whp() {
        let (pts, m) = blob_data();
        let mut hits = 0;
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let mut c = Counters::new();
            let cs = kmeanspp(&pts, m, 2, 4, 3, &mut rng, &mut c);
            let mut blobs_hit = std::collections::HashSet::new();
            for j in 0..4 {
                let cj = &cs[j * 2..j * 2 + 2];
                let bx = (cj[0] > 25.0) as u8;
                let by = (cj[1] > 25.0) as u8;
                blobs_hit.insert((bx, by));
            }
            if blobs_hit.len() == 4 {
                hits += 1;
            }
        }
        assert!(hits >= 18, "k-means++ hit all 4 blobs only {hits}/20 times");
    }

    #[test]
    fn k_equals_one_and_k_equals_m() {
        let pts = vec![0.0f32, 0.0, 1.0, 1.0, 2.0, 2.0];
        let mut rng = Rng::new(2);
        let mut c = Counters::new();
        let c1 = kmeanspp(&pts, 3, 2, 1, 1, &mut rng, &mut c);
        assert_eq!(c1.len(), 2);
        let c3 = kmeanspp(&pts, 3, 2, 3, 1, &mut rng, &mut c);
        // With k == m and distinct points, all points selected.
        let mut sel: Vec<_> = (0..3)
            .map(|j| (c3[j * 2] as i32, c3[j * 2 + 1] as i32))
            .collect();
        sel.sort_unstable();
        assert_eq!(sel, vec![(0, 0), (1, 1), (2, 2)]);
    }

    #[test]
    fn duplicate_points_dont_crash() {
        let pts = vec![1.0f32; 20]; // 10 identical 2-d points
        let mut rng = Rng::new(3);
        let mut c = Counters::new();
        let cs = kmeanspp(&pts, 10, 2, 3, 3, &mut rng, &mut c);
        assert_eq!(cs.len(), 6);
        assert!(cs.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn reseed_fills_only_requested_slots() {
        let (pts, m) = blob_data();
        let mut rng = Rng::new(4);
        let mut c = Counters::new();
        let mut cs = vec![0.0f32; 8];
        cs[0..2].copy_from_slice(&[0.0, 0.0]);
        cs[2..4].copy_from_slice(&[50.0, 50.0]);
        cs[4..6].copy_from_slice(&[123.0, 456.0]); // degenerate slot 2
        cs[6..8].copy_from_slice(&[50.0, 0.0]);
        let before: Vec<f32> = cs.clone();
        reseed_degenerate(&pts, m, 2, 4, &mut cs, &[2], 3, &mut rng, &mut c);
        assert_eq!(&cs[0..2], &before[0..2]);
        assert_eq!(&cs[2..4], &before[2..4]);
        assert_eq!(&cs[6..8], &before[6..8]);
        // Slot 2 now holds a real point, most likely from the uncovered blob
        // (0, 50) — D² mass concentrates there.
        let c2 = &cs[4..6];
        assert!(c2[0] < 25.0 && c2[1] > 25.0, "reseeded to {c2:?}, expected blob (0,50)");
    }

    #[test]
    fn reseed_all_degenerate_uses_uniform() {
        let (pts, m) = blob_data();
        let mut rng = Rng::new(5);
        let mut c = Counters::new();
        let mut cs = vec![f32::MAX; 4];
        reseed_degenerate(&pts, m, 2, 2, &mut cs, &[0, 1], 1, &mut rng, &mut c);
        assert!(cs.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn distance_eval_budget_matches_complexity() {
        let (pts, m) = blob_data();
        let mut rng = Rng::new(6);
        let mut c = Counters::new();
        let k = 4;
        kmeanspp(&pts, m, 2, k, 1, &mut rng, &mut c);
        // first pass m + (k-1) incremental passes of m each
        assert_eq!(c.distance_evals, (m * k) as u64);
    }
}
