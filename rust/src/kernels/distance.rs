//! Squared Euclidean distance primitives.
//!
//! These mirror the L1 Pallas kernel's `‖x‖² − 2·x·c + ‖c‖²` decomposition
//! where it pays off (blocked assignment over many centroids) and use the
//! direct subtract-square form for single pairs. Every public function
//! reports how many *distance-function evaluations* it performed through
//! [`crate::metrics::counters::DistanceCounter`]-compatible return values —
//! the paper's `n_d` metric counts point↔centroid distance evaluations.
//!
//! The public entry points dispatch at runtime to the hand-written SIMD
//! backends in [`super::simd`] when one is active; the `*_scalar`
//! functions in this file are the auto-vectorized reference
//! implementations every backend must match **bit for bit** (see the
//! roofline section in [`super`] for the reduction-order contract).

use super::simd;

/// SIMD lane width for the accumulator arrays: 16 f32 = one AVX-512
/// register (still fine on AVX2 — LLVM splits into two 8-lane registers).
/// The explicit backends in [`super::simd`] tile by the same width.
const LANES: usize = 16;

/// Pairwise tree reduction of one `[f32; LANES]` accumulator tile
/// (`width = 8, 4, 2, 1`). The single source of truth for the combine
/// order: the scalar kernels below and the SIMD backends all reduce their
/// 16 lanes in exactly this order, which is what makes them bit-identical.
#[inline(always)]
fn reduce_lanes(acc: &mut [f32; LANES]) -> f32 {
    let mut width = LANES / 2;
    while width > 0 {
        for l in 0..width {
            acc[l] += acc[l + width];
        }
        width /= 2;
    }
    acc[0]
}

/// Shared lane-tiled accumulator loop: `Σ term(a[i], b[i])` with `LANES`
/// independent per-lane partial sums (so LLVM keeps the whole reduction in
/// vector registers without violating strict-FP ordering per lane), a
/// separately-accumulated scalar tail, and the [`reduce_lanes`] tree.
/// `sq_dist` and `dot` (hence `sq_norm`) are thin instantiations of this
/// one loop.
#[inline(always)]
fn lane_accumulate<F: Fn(f32, f32) -> f32>(a: &[f32], b: &[f32], term: F) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let chunks = a.len() / LANES;
    for i in 0..chunks {
        let j = i * LANES;
        let av = &a[j..j + LANES];
        let bv = &b[j..j + LANES];
        for l in 0..LANES {
            acc[l] += term(av[l], bv[l]);
        }
    }
    let mut tail = 0.0f32;
    for j in chunks * LANES..a.len() {
        tail += term(a[j], b[j]);
    }
    reduce_lanes(&mut acc) + tail
}

/// Scalar reference for [`sq_dist`] (auto-vectorized lane tiles).
#[inline]
pub(crate) fn sq_dist_scalar(a: &[f32], b: &[f32]) -> f32 {
    lane_accumulate(a, b, |x, y| {
        let d = x - y;
        d * d
    })
}

/// Scalar reference for [`dot`] (auto-vectorized lane tiles).
#[inline]
pub(crate) fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    lane_accumulate(a, b, |x, y| x * y)
}

/// Direct squared Euclidean distance between two vectors.
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(target_arch = "x86_64", bigmeans_avx512))]
    if simd::active_isa() == simd::DistanceIsa::Avx512 {
        // SAFETY: Avx512 only activates after runtime feature detection.
        return unsafe { simd::avx512::sq_dist(a, b) };
    }
    #[cfg(target_arch = "x86_64")]
    if simd::active_isa() == simd::DistanceIsa::Avx2 {
        // SAFETY: Avx2 only activates after runtime feature detection.
        return unsafe { simd::avx2::sq_dist(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if simd::active_isa() == simd::DistanceIsa::Neon {
        // SAFETY: NEON is baseline on aarch64; lengths are asserted above.
        return unsafe { simd::neon::sq_dist(a, b) };
    }
    sq_dist_scalar(a, b)
}

/// Dot product (used by the decomposition path).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(target_arch = "x86_64", bigmeans_avx512))]
    if simd::active_isa() == simd::DistanceIsa::Avx512 {
        // SAFETY: Avx512 only activates after runtime feature detection.
        return unsafe { simd::avx512::dot(a, b) };
    }
    #[cfg(target_arch = "x86_64")]
    if simd::active_isa() == simd::DistanceIsa::Avx2 {
        // SAFETY: Avx2 only activates after runtime feature detection.
        return unsafe { simd::avx2::dot(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if simd::active_isa() == simd::DistanceIsa::Neon {
        // SAFETY: NEON is baseline on aarch64; lengths are asserted above.
        return unsafe { simd::neon::dot(a, b) };
    }
    dot_scalar(a, b)
}

/// Squared L2 norm.
#[inline]
pub fn sq_norm(a: &[f32]) -> f32 {
    dot(a, a)
}

/// Find the nearest centroid to `point`; returns `(index, sq_dist)`.
/// Performs `centroids_rows` distance evaluations.
#[inline]
pub fn nearest(point: &[f32], centroids: &[f32], k: usize, n: usize) -> (usize, f32) {
    debug_assert_eq!(centroids.len(), k * n);
    debug_assert!(k > 0);
    let mut best = 0usize;
    let mut best_d = f32::INFINITY;
    for j in 0..k {
        let d = sq_dist(point, &centroids[j * n..(j + 1) * n]);
        if d < best_d {
            best_d = d;
            best = j;
        }
    }
    (best, best_d)
}

/// Dense `(rows, k)` squared-distance panel via the decomposition form:
/// `d[i][j] = ‖x_i‖² − 2·x_i·c_j + ‖c_j‖²`, writing into `out` (row-major,
/// `rows*k`). `x_sq`/`c_sq` are precomputed squared norms. This is the
/// rust analogue of the Pallas tile body and is what the blocked assignment
/// uses for large `k·n`.
pub fn sq_dist_panel(
    points: &[f32],
    x_sq: &[f32],
    centroids: &[f32],
    c_sq: &[f32],
    rows: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(points.len(), rows * n);
    debug_assert_eq!(centroids.len(), k * n);
    debug_assert_eq!(out.len(), rows * k);
    // 4-wide centroid micro-kernel: each point row is loaded once per 4
    // centroids instead of once per centroid (≈1.5× on the assignment
    // panel — EXPERIMENTS.md §Perf).
    let k4 = k / 4 * 4;
    for i in 0..rows {
        let x = &points[i * n..(i + 1) * n];
        let row = &mut out[i * k..(i + 1) * k];
        let mut j = 0;
        while j < k4 {
            let c0 = &centroids[j * n..(j + 1) * n];
            let c1 = &centroids[(j + 1) * n..(j + 2) * n];
            let c2 = &centroids[(j + 2) * n..(j + 3) * n];
            let c3 = &centroids[(j + 3) * n..(j + 4) * n];
            let (d0, d1, d2, d3) = dot4(x, c0, c1, c2, c3);
            row[j] = (x_sq[i] + c_sq[j] - 2.0 * d0).max(0.0);
            row[j + 1] = (x_sq[i] + c_sq[j + 1] - 2.0 * d1).max(0.0);
            row[j + 2] = (x_sq[i] + c_sq[j + 2] - 2.0 * d2).max(0.0);
            row[j + 3] = (x_sq[i] + c_sq[j + 3] - 2.0 * d3).max(0.0);
            j += 4;
        }
        while j < k {
            let c = &centroids[j * n..(j + 1) * n];
            let d = x_sq[i] + c_sq[j] - 2.0 * dot(x, c);
            row[j] = d.max(0.0);
            j += 1;
        }
    }
}

/// Fused distance panel + per-row argmin: evaluates the same decomposition
/// `d[i][j] = ‖x_i‖² − 2·x_i·c_j + ‖c_j‖²` as [`sq_dist_panel`] but reduces
/// each row to `(argmin, min)` inside the panel loop — the per-row best
/// stays in registers instead of round-tripping through a `rows×k` buffer
/// and a second scan. Distance values and tie-breaking (lowest index wins)
/// are bit-identical to [`sq_dist_panel`] followed by a forward argmin.
/// Dispatches the whole panel loop to the active SIMD backend so the
/// micro-kernel inlines into it.
#[allow(clippy::too_many_arguments)]
pub fn sq_dist_panel_argmin(
    points: &[f32],
    x_sq: &[f32],
    centroids: &[f32],
    c_sq: &[f32],
    rows: usize,
    k: usize,
    n: usize,
    labels: &mut [u32],
    mins: &mut [f32],
) {
    debug_assert_eq!(points.len(), rows * n);
    debug_assert_eq!(centroids.len(), k * n);
    debug_assert_eq!(labels.len(), rows);
    debug_assert_eq!(mins.len(), rows);
    debug_assert!(k > 0);
    #[cfg(all(target_arch = "x86_64", bigmeans_avx512))]
    if simd::active_isa() == simd::DistanceIsa::Avx512 {
        // SAFETY: Avx512 only activates after runtime feature detection.
        return unsafe {
            simd::avx512::sq_dist_panel_argmin(
                points, x_sq, centroids, c_sq, rows, k, n, labels, mins,
            )
        };
    }
    #[cfg(target_arch = "x86_64")]
    if simd::active_isa() == simd::DistanceIsa::Avx2 {
        // SAFETY: Avx2 only activates after runtime feature detection.
        return unsafe {
            simd::avx2::sq_dist_panel_argmin(
                points, x_sq, centroids, c_sq, rows, k, n, labels, mins,
            )
        };
    }
    #[cfg(target_arch = "aarch64")]
    if simd::active_isa() == simd::DistanceIsa::Neon {
        // SAFETY: NEON is baseline on aarch64; shapes are asserted above.
        return unsafe {
            simd::neon::sq_dist_panel_argmin(
                points, x_sq, centroids, c_sq, rows, k, n, labels, mins,
            )
        };
    }
    sq_dist_panel_argmin_scalar(points, x_sq, centroids, c_sq, rows, k, n, labels, mins)
}

/// Scalar reference for [`sq_dist_panel_argmin`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn sq_dist_panel_argmin_scalar(
    points: &[f32],
    x_sq: &[f32],
    centroids: &[f32],
    c_sq: &[f32],
    rows: usize,
    k: usize,
    n: usize,
    labels: &mut [u32],
    mins: &mut [f32],
) {
    let k4 = k / 4 * 4;
    for i in 0..rows {
        let x = &points[i * n..(i + 1) * n];
        let mut best = 0u32;
        let mut best_d = f32::INFINITY;
        let mut j = 0;
        while j < k4 {
            let c0 = &centroids[j * n..(j + 1) * n];
            let c1 = &centroids[(j + 1) * n..(j + 2) * n];
            let c2 = &centroids[(j + 2) * n..(j + 3) * n];
            let c3 = &centroids[(j + 3) * n..(j + 4) * n];
            let (p0, p1, p2, p3) = dot4_scalar(x, c0, c1, c2, c3);
            let d0 = (x_sq[i] + c_sq[j] - 2.0 * p0).max(0.0);
            let d1 = (x_sq[i] + c_sq[j + 1] - 2.0 * p1).max(0.0);
            let d2 = (x_sq[i] + c_sq[j + 2] - 2.0 * p2).max(0.0);
            let d3 = (x_sq[i] + c_sq[j + 3] - 2.0 * p3).max(0.0);
            if d0 < best_d {
                best_d = d0;
                best = j as u32;
            }
            if d1 < best_d {
                best_d = d1;
                best = (j + 1) as u32;
            }
            if d2 < best_d {
                best_d = d2;
                best = (j + 2) as u32;
            }
            if d3 < best_d {
                best_d = d3;
                best = (j + 3) as u32;
            }
            j += 4;
        }
        while j < k {
            let c = &centroids[j * n..(j + 1) * n];
            let d = (x_sq[i] + c_sq[j] - 2.0 * dot_scalar(x, c)).max(0.0);
            if d < best_d {
                best_d = d;
                best = j as u32;
            }
            j += 1;
        }
        labels[i] = best;
        mins[i] = best_d;
    }
}

/// Squared distance of one point to one centroid via the *same*
/// decomposition arithmetic as the panel kernels (`x_sq + c_sq − 2·x·c`,
/// clamped at 0). Engines that mix per-point and panel evaluation use this
/// so their values are bit-identical to the panel's for the same pair.
#[inline]
pub fn sq_dist_decomp(x: &[f32], x_sq: f32, c: &[f32], c_sq: f32) -> f32 {
    (x_sq + c_sq - 2.0 * dot(x, c)).max(0.0)
}

/// Best and second-best squared distances of one point against all `k`
/// centroids, decomposition form with the 4-wide centroid micro-kernel —
/// per-value bit-identical to a [`sq_dist_panel`] row; ties break to the
/// lowest index. `d2` is `INFINITY` when `k == 1`. The bounded engine's
/// init pass and rescans use this.
pub fn nearest2_decomp(
    x: &[f32],
    x_sq: f32,
    centroids: &[f32],
    c_sq: &[f32],
    k: usize,
    n: usize,
) -> (usize, f32, f32) {
    debug_assert_eq!(centroids.len(), k * n);
    debug_assert_eq!(c_sq.len(), k);
    debug_assert!(k > 0);
    let mut j1 = 0usize;
    let mut d1 = f32::INFINITY;
    let mut d2 = f32::INFINITY;
    let mut consider = |j: usize, d: f32| {
        if d < d1 {
            d2 = d1;
            d1 = d;
            j1 = j;
        } else if d < d2 {
            d2 = d;
        }
    };
    let k4 = k / 4 * 4;
    let mut j = 0;
    while j < k4 {
        let c0 = &centroids[j * n..(j + 1) * n];
        let c1 = &centroids[(j + 1) * n..(j + 2) * n];
        let c2 = &centroids[(j + 2) * n..(j + 3) * n];
        let c3 = &centroids[(j + 3) * n..(j + 4) * n];
        let (p0, p1, p2, p3) = dot4(x, c0, c1, c2, c3);
        consider(j, (x_sq + c_sq[j] - 2.0 * p0).max(0.0));
        consider(j + 1, (x_sq + c_sq[j + 1] - 2.0 * p1).max(0.0));
        consider(j + 2, (x_sq + c_sq[j + 2] - 2.0 * p2).max(0.0));
        consider(j + 3, (x_sq + c_sq[j + 3] - 2.0 * p3).max(0.0));
        j += 4;
    }
    while j < k {
        let c = &centroids[j * n..(j + 1) * n];
        consider(j, (x_sq + c_sq[j] - 2.0 * dot(x, c)).max(0.0));
        j += 1;
    }
    drop(consider);
    (j1, d1, d2)
}

/// Four simultaneous dot products against a shared left vector,
/// dispatched to the active SIMD backend.
#[inline]
fn dot4(x: &[f32], c0: &[f32], c1: &[f32], c2: &[f32], c3: &[f32]) -> (f32, f32, f32, f32) {
    #[cfg(all(target_arch = "x86_64", bigmeans_avx512))]
    if simd::active_isa() == simd::DistanceIsa::Avx512 {
        // SAFETY: Avx512 only activates after runtime feature detection.
        return unsafe { simd::avx512::dot4(x, c0, c1, c2, c3) };
    }
    #[cfg(target_arch = "x86_64")]
    if simd::active_isa() == simd::DistanceIsa::Avx2 {
        // SAFETY: Avx2 only activates after runtime feature detection.
        return unsafe { simd::avx2::dot4(x, c0, c1, c2, c3) };
    }
    #[cfg(target_arch = "aarch64")]
    if simd::active_isa() == simd::DistanceIsa::Neon {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { simd::neon::dot4(x, c0, c1, c2, c3) };
    }
    dot4_scalar(x, c0, c1, c2, c3)
}

/// Scalar reference for the 4-wide micro-kernel. The four accumulator
/// tiles are fully independent, so this is bit-identical to four separate
/// [`dot_scalar`] calls — the SIMD backends rely on that equivalence.
#[inline]
pub(crate) fn dot4_scalar(
    x: &[f32],
    c0: &[f32],
    c1: &[f32],
    c2: &[f32],
    c3: &[f32],
) -> (f32, f32, f32, f32) {
    let n = x.len();
    let mut a0 = [0.0f32; LANES];
    let mut a1 = [0.0f32; LANES];
    let mut a2 = [0.0f32; LANES];
    let mut a3 = [0.0f32; LANES];
    let chunks = n / LANES;
    for i in 0..chunks {
        let j = i * LANES;
        let xv = &x[j..j + LANES];
        let c0v = &c0[j..j + LANES];
        let c1v = &c1[j..j + LANES];
        let c2v = &c2[j..j + LANES];
        let c3v = &c3[j..j + LANES];
        for l in 0..LANES {
            a0[l] += xv[l] * c0v[l];
            a1[l] += xv[l] * c1v[l];
            a2[l] += xv[l] * c2v[l];
            a3[l] += xv[l] * c3v[l];
        }
    }
    let (mut t0, mut t1, mut t2, mut t3) = (0.0f32, 0.0, 0.0, 0.0);
    for j in chunks * LANES..n {
        t0 += x[j] * c0[j];
        t1 += x[j] * c1[j];
        t2 += x[j] * c2[j];
        t3 += x[j] * c3[j];
    }
    (
        reduce_lanes(&mut a0) + t0,
        reduce_lanes(&mut a1) + t1,
        reduce_lanes(&mut a2) + t2,
        reduce_lanes(&mut a3) + t3,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sq_dist_basic() {
        assert_eq!(sq_dist(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(sq_dist(&[1.0; 7], &[1.0; 7]), 0.0);
    }

    #[test]
    fn sq_dist_matches_naive_for_odd_lengths() {
        for len in 1..20 {
            let a: Vec<f32> = (0..len).map(|i| i as f32 * 0.5).collect();
            let b: Vec<f32> = (0..len).map(|i| (len - i) as f32 * 0.25).collect();
            let naive: f32 = a
                .iter()
                .zip(&b)
                .map(|(x, y)| (x - y) * (x - y))
                .sum();
            assert!((sq_dist(&a, &b) - naive).abs() < 1e-4);
        }
    }

    #[test]
    fn scalar_helpers_agree_with_public_entry_points_numerically() {
        // Whatever backend is active, the dispatched value must be the
        // bit-exact scalar value — the dispatch is invisible.
        let a: Vec<f32> = (0..37).map(|i| (i as f32 * 0.61 - 9.0).sin() * 5.0).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32 * 0.23 + 2.0).cos() * 5.0).collect();
        assert_eq!(sq_dist(&a, &b).to_bits(), sq_dist_scalar(&a, &b).to_bits());
        assert_eq!(dot(&a, &b).to_bits(), dot_scalar(&a, &b).to_bits());
        let four = dot4(&a, &b, &a, &b, &a);
        let fours = dot4_scalar(&a, &b, &a, &b, &a);
        assert_eq!(four.0.to_bits(), fours.0.to_bits());
        assert_eq!(four.1.to_bits(), fours.1.to_bits());
        assert_eq!(four.2.to_bits(), fours.2.to_bits());
        assert_eq!(four.3.to_bits(), fours.3.to_bits());
        // dot4's independent accumulators == four standalone dots.
        assert_eq!(fours.0.to_bits(), dot_scalar(&a, &b).to_bits());
        assert_eq!(fours.1.to_bits(), dot_scalar(&a, &a).to_bits());
    }

    #[test]
    fn nearest_picks_min_and_breaks_ties_low() {
        let centroids = [0.0f32, 0.0, 5.0, 5.0, 0.0, 0.0]; // c0 == c2
        let (idx, d) = nearest(&[1.0, 0.0], &centroids, 3, 2);
        assert_eq!(idx, 0);
        assert_eq!(d, 1.0);
    }

    #[test]
    fn fused_argmin_matches_panel_plus_scan() {
        let mut state = 0x9E3779B97F4A7C15u64;
        let mut next = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as f32 / (1u64 << 31) as f32) * 20.0 - 10.0
        };
        for &(rows, k, n) in &[(7usize, 1usize, 3usize), (5, 4, 1), (9, 6, 5), (3, 9, 16), (8, 5, 17)] {
            let pts: Vec<f32> = (0..rows * n).map(|_| next()).collect();
            let cs: Vec<f32> = (0..k * n).map(|_| next()).collect();
            let x_sq: Vec<f32> = (0..rows).map(|i| sq_norm(&pts[i * n..(i + 1) * n])).collect();
            let c_sq: Vec<f32> = (0..k).map(|j| sq_norm(&cs[j * n..(j + 1) * n])).collect();
            let mut panel = vec![0f32; rows * k];
            sq_dist_panel(&pts, &x_sq, &cs, &c_sq, rows, k, n, &mut panel);
            let mut labels = vec![0u32; rows];
            let mut mins = vec![0f32; rows];
            sq_dist_panel_argmin(&pts, &x_sq, &cs, &c_sq, rows, k, n, &mut labels, &mut mins);
            // The explicitly-scalar panel must agree bit for bit with the
            // dispatched one, whichever backend is live.
            let mut labels_s = vec![0u32; rows];
            let mut mins_s = vec![0f32; rows];
            sq_dist_panel_argmin_scalar(
                &pts, &x_sq, &cs, &c_sq, rows, k, n, &mut labels_s, &mut mins_s,
            );
            assert_eq!(labels, labels_s, "rows={rows} k={k} n={n}");
            for i in 0..rows {
                assert_eq!(mins[i].to_bits(), mins_s[i].to_bits());
            }
            for i in 0..rows {
                let row = &panel[i * k..(i + 1) * k];
                let mut best = 0usize;
                let mut best_d = row[0];
                for (j, &d) in row.iter().enumerate().skip(1) {
                    if d < best_d {
                        best_d = d;
                        best = j;
                    }
                }
                assert_eq!(labels[i] as usize, best, "rows={rows} k={k} n={n} i={i}");
                assert_eq!(mins[i].to_bits(), best_d.to_bits());
            }
        }
    }

    #[test]
    fn decomp_single_matches_panel_column() {
        // k = 5 exercises both the 4-wide micro-kernel (dot4) and the
        // remainder column; n = 19 exercises lane chunks + tail. The single
        // decomposition must match the panel *bit for bit* — the bounded
        // engine's exactness contract rests on this.
        let (rows, k, n) = (6usize, 5usize, 19usize);
        let pts: Vec<f32> = (0..rows * n).map(|i| (i as f32 * 0.37 - 20.0).sin() * 8.0).collect();
        let cs: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.71 + 3.0).cos() * 8.0).collect();
        let x_sq: Vec<f32> = (0..rows).map(|i| sq_norm(&pts[i * n..(i + 1) * n])).collect();
        let c_sq: Vec<f32> = (0..k).map(|j| sq_norm(&cs[j * n..(j + 1) * n])).collect();
        let mut panel = vec![0f32; rows * k];
        sq_dist_panel(&pts, &x_sq, &cs, &c_sq, rows, k, n, &mut panel);
        for i in 0..rows {
            for j in 0..k {
                let d = sq_dist_decomp(&pts[i * n..(i + 1) * n], x_sq[i], &cs[j * n..(j + 1) * n], c_sq[j]);
                assert_eq!(d.to_bits(), panel[i * k + j].to_bits(), "i={i} j={j}");
            }
        }
    }

    #[test]
    fn nearest2_matches_panel_row_scan() {
        let (rows, k, n) = (5usize, 7usize, 9usize);
        let pts: Vec<f32> = (0..rows * n).map(|i| (i as f32 * 0.53 - 4.0).sin() * 12.0).collect();
        let cs: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.29 + 1.0).cos() * 12.0).collect();
        let x_sq: Vec<f32> = (0..rows).map(|i| sq_norm(&pts[i * n..(i + 1) * n])).collect();
        let c_sq: Vec<f32> = (0..k).map(|j| sq_norm(&cs[j * n..(j + 1) * n])).collect();
        let mut panel = vec![0f32; rows * k];
        sq_dist_panel(&pts, &x_sq, &cs, &c_sq, rows, k, n, &mut panel);
        for i in 0..rows {
            let row = &panel[i * k..(i + 1) * k];
            // Reference best/second-best over the panel row.
            let (mut j1, mut d1, mut d2) = (0usize, f32::INFINITY, f32::INFINITY);
            for (j, &d) in row.iter().enumerate() {
                if d < d1 {
                    d2 = d1;
                    d1 = d;
                    j1 = j;
                } else if d < d2 {
                    d2 = d;
                }
            }
            let got = nearest2_decomp(&pts[i * n..(i + 1) * n], x_sq[i], &cs, &c_sq, k, n);
            assert_eq!(got.0, j1, "i={i}");
            assert_eq!(got.1.to_bits(), d1.to_bits());
            assert_eq!(got.2.to_bits(), d2.to_bits());
        }
        // k == 1: no second-best.
        let one = nearest2_decomp(&pts[..n], x_sq[0], &cs[..n], &c_sq[..1], 1, n);
        assert_eq!(one.0, 0);
        assert_eq!(one.2, f32::INFINITY);
    }

    #[test]
    fn panel_matches_direct() {
        let pts: Vec<f32> = (0..12).map(|i| i as f32).collect(); // 4×3
        let cs: Vec<f32> = (0..6).map(|i| (i * 2) as f32).collect(); // 2×3
        let x_sq: Vec<f32> = (0..4).map(|i| sq_norm(&pts[i * 3..i * 3 + 3])).collect();
        let c_sq: Vec<f32> = (0..2).map(|j| sq_norm(&cs[j * 3..j * 3 + 3])).collect();
        let mut out = vec![0.0; 8];
        sq_dist_panel(&pts, &x_sq, &cs, &c_sq, 4, 2, 3, &mut out);
        for i in 0..4 {
            for j in 0..2 {
                let direct = sq_dist(&pts[i * 3..i * 3 + 3], &cs[j * 3..j * 3 + 3]);
                assert!((out[i * 2 + j] - direct).abs() < 1e-3);
            }
        }
    }
}
