//! Runtime-dispatched SIMD backends for the distance primitives.
//!
//! The scalar `[f32; LANES]` tiles in [`super::distance`] rely on LLVM's
//! auto-vectorizer; this module provides hand-written `std::arch`
//! equivalents (AVX-512 and AVX2 on x86_64, NEON on aarch64) selected
//! **once at startup** behind a [`DistanceIsa`] dispatch table. The contract that
//! makes runtime dispatch safe to hot-swap anywhere — mid-run, per bench
//! row, per test — is *bit-identicality*: every backend performs the exact
//! same f32 operations in the exact same order as the scalar reference
//! (see the roofline section in [`super`]), so the choice of ISA is
//! observable only in wall-clock time, never in labels or objectives.
//!
//! Two rules keep that true:
//!
//! * **No fused multiply-add.** Rust never contracts `a * b + c` in the
//!   scalar path, so `_mm256_fmadd_ps` / `vfmaq_f32` would change the
//!   rounding. All backends use separate multiply and add.
//! * **Same reduction tree.** The scalar kernels keep `LANES = 16`
//!   independent accumulators combined by a pairwise tree
//!   (`width = 8, 4, 2, 1`) plus a separately-accumulated scalar tail.
//!   The SIMD kernels hold the same 16 lanes in registers (1×16 on
//!   AVX-512, 2×8 on AVX2, 4×4 on NEON) and reduce them with the same
//!   tree, then add the same scalar tail last. The AVX-512 kernels
//!   process 32-element tiles per iteration, but as two *sequential*
//!   adds into one 16-lane accumulator — lane `l` still sees chunk `2i`
//!   before chunk `2i+1`, exactly the scalar per-lane order.
//!
//! Selection order: explicit [`set_isa`] (CLI `--isa`, which *fails* with
//! an error listing the detected ISAs when the host lacks the request) >
//! the `BIGMEANS_ISA` environment variable (silently falls back to
//! [`detect`] when unavailable, so one exported variable can span a
//! heterogeneous fleet) > [`detect`], whose preference order is
//! avx512 > avx2 > neon > scalar. The gating sweep in
//! `tests/property_engines.rs` bit-compares every backend against scalar.
//!
//! AVX-512 needs rustc ≥ 1.89 for the stable `_mm512_*` intrinsics;
//! `build.rs` probes the toolchain and sets `cfg(bigmeans_avx512)`. On
//! older compilers the backend is compiled out and dispatch falls back
//! to AVX2.

use std::sync::atomic::{AtomicU8, Ordering};

/// Which SIMD backend the distance primitives dispatch to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DistanceIsa {
    /// The auto-vectorized scalar tiles in `kernels::distance` (always
    /// available; the reference for bit-identicality).
    Scalar = 1,
    /// Hand-written AVX2 kernels (x86_64, runtime-detected).
    Avx2 = 2,
    /// Hand-written NEON kernels (aarch64 baseline).
    Neon = 3,
    /// Hand-written AVX-512 kernels (x86_64, runtime-detected; needs
    /// rustc ≥ 1.89 — see `build.rs`).
    Avx512 = 4,
}

impl DistanceIsa {
    /// Canonical token (CLI/JSON labels).
    pub fn name(self) -> &'static str {
        match self {
            DistanceIsa::Scalar => "scalar",
            DistanceIsa::Avx2 => "avx2",
            DistanceIsa::Neon => "neon",
            DistanceIsa::Avx512 => "avx512",
        }
    }

    /// Parse a CLI/env token (`scalar` / `avx2` / `neon` / `avx512`).
    /// `auto` is not a concrete ISA — callers map it to [`detect`].
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "scalar" => Some(DistanceIsa::Scalar),
            "avx2" => Some(DistanceIsa::Avx2),
            "neon" => Some(DistanceIsa::Neon),
            "avx512" => Some(DistanceIsa::Avx512),
            _ => None,
        }
    }

    /// Whether this backend can run on the current host.
    pub fn available(self) -> bool {
        match self {
            DistanceIsa::Scalar => true,
            DistanceIsa::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            DistanceIsa::Neon => cfg!(target_arch = "aarch64"),
            DistanceIsa::Avx512 => {
                #[cfg(all(target_arch = "x86_64", bigmeans_avx512))]
                {
                    std::arch::is_x86_feature_detected!("avx512f")
                }
                #[cfg(not(all(target_arch = "x86_64", bigmeans_avx512)))]
                {
                    false
                }
            }
        }
    }
}

/// Best backend available on this host. Preference order:
/// avx512 > avx2 > neon > scalar.
#[allow(unreachable_code)]
pub fn detect() -> DistanceIsa {
    #[cfg(target_arch = "x86_64")]
    {
        #[cfg(bigmeans_avx512)]
        if std::arch::is_x86_feature_detected!("avx512f") {
            return DistanceIsa::Avx512;
        }
        if std::arch::is_x86_feature_detected!("avx2") {
            return DistanceIsa::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return DistanceIsa::Neon;
    }
    DistanceIsa::Scalar
}

/// Every backend the current host can run, best-first — i.e. the
/// [`detect`] preference order filtered to what is available. Always
/// ends with `scalar`.
pub fn detected_isas() -> Vec<DistanceIsa> {
    [DistanceIsa::Avx512, DistanceIsa::Avx2, DistanceIsa::Neon, DistanceIsa::Scalar]
        .into_iter()
        .filter(|isa| isa.available())
        .collect()
}

/// 0 = uninitialised; otherwise a `DistanceIsa` discriminant. Relaxed
/// ordering is enough: every backend is bit-identical, so a racing reader
/// seeing the old value computes the same result.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

/// The backend the distance primitives currently dispatch to. Initialises
/// lazily on first use: `BIGMEANS_ISA` (`auto`/`scalar`/`avx2`/`neon`/
/// `avx512`) if set and available, else [`detect`].
#[inline]
pub fn active_isa() -> DistanceIsa {
    match ACTIVE.load(Ordering::Relaxed) {
        1 => DistanceIsa::Scalar,
        2 => DistanceIsa::Avx2,
        3 => DistanceIsa::Neon,
        4 => DistanceIsa::Avx512,
        _ => init_isa(),
    }
}

#[cold]
fn init_isa() -> DistanceIsa {
    let isa = match std::env::var("BIGMEANS_ISA") {
        Ok(v) => DistanceIsa::parse(v.trim()).filter(|i| i.available()).unwrap_or_else(detect),
        Err(_) => detect(),
    };
    ACTIVE.store(isa as u8, Ordering::Relaxed);
    isa
}

/// Pin the dispatch to one backend (CLI `--isa`, bench A/B rows, the
/// SIMD ≡ scalar property sweep). Fails — naming the request and listing
/// every ISA this host *can* run — instead of silently falling back, so
/// a typo'd or over-optimistic `--isa avx512` surfaces immediately.
pub fn set_isa(isa: DistanceIsa) -> Result<(), String> {
    if !isa.available() {
        let detected: Vec<&str> = detected_isas().iter().map(|i| i.name()).collect();
        return Err(format!(
            "isa `{}` is not available on this host (detected: {})",
            isa.name(),
            detected.join(", ")
        ));
    }
    ACTIVE.store(isa as u8, Ordering::Relaxed);
    Ok(())
}

/// AVX2 kernels. Every function mirrors its scalar counterpart in
/// `kernels::distance` operation for operation; see the module docs for
/// the reduction-order contract.
#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    use core::arch::x86_64::*;

    /// Must match `distance::LANES` — the tile the reduction tree spans.
    const LANES: usize = 16;

    /// Reduce 16 lanes held as two 8-lane registers (`lo` = lanes 0–7,
    /// `hi` = lanes 8–15) with the scalar pairwise tree:
    /// width-8 (`lo + hi`), width-4, width-2, width-1.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn reduce16(lo: __m256, hi: __m256) -> f32 {
        let v = _mm256_add_ps(lo, hi);
        let w = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps::<1>(v));
        let x = _mm_add_ps(w, _mm_movehl_ps(w, w));
        _mm_cvtss_f32(_mm_add_ss(x, _mm_movehdup_ps(x)))
    }

    /// Direct squared Euclidean distance; bit-identical to
    /// `distance::sq_dist`.
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available on the running CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / LANES;
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut lo = _mm256_setzero_ps();
        let mut hi = _mm256_setzero_ps();
        for i in 0..chunks {
            let j = i * LANES;
            let (a0, a1) = (_mm256_loadu_ps(ap.add(j)), _mm256_loadu_ps(ap.add(j + 8)));
            let (b0, b1) = (_mm256_loadu_ps(bp.add(j)), _mm256_loadu_ps(bp.add(j + 8)));
            let d0 = _mm256_sub_ps(a0, b0);
            let d1 = _mm256_sub_ps(a1, b1);
            // mul + add, never fmadd — the scalar path is uncontracted.
            lo = _mm256_add_ps(lo, _mm256_mul_ps(d0, d0));
            hi = _mm256_add_ps(hi, _mm256_mul_ps(d1, d1));
        }
        let mut tail = 0.0f32;
        for j in chunks * LANES..n {
            let d = a[j] - b[j];
            tail += d * d;
        }
        reduce16(lo, hi) + tail
    }

    /// Dot product; bit-identical to `distance::dot`.
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available on the running CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / LANES;
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut lo = _mm256_setzero_ps();
        let mut hi = _mm256_setzero_ps();
        for i in 0..chunks {
            let j = i * LANES;
            let (a0, a1) = (_mm256_loadu_ps(ap.add(j)), _mm256_loadu_ps(ap.add(j + 8)));
            let (b0, b1) = (_mm256_loadu_ps(bp.add(j)), _mm256_loadu_ps(bp.add(j + 8)));
            lo = _mm256_add_ps(lo, _mm256_mul_ps(a0, b0));
            hi = _mm256_add_ps(hi, _mm256_mul_ps(a1, b1));
        }
        let mut tail = 0.0f32;
        for j in chunks * LANES..n {
            tail += a[j] * b[j];
        }
        reduce16(lo, hi) + tail
    }

    /// Four simultaneous dot products against a shared left vector;
    /// bit-identical to `distance::dot4_scalar`.
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available on the running CPU.
    #[inline]
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot4(
        x: &[f32],
        c0: &[f32],
        c1: &[f32],
        c2: &[f32],
        c3: &[f32],
    ) -> (f32, f32, f32, f32) {
        let n = x.len();
        debug_assert!(c0.len() == n && c1.len() == n && c2.len() == n && c3.len() == n);
        let chunks = n / LANES;
        let xp = x.as_ptr();
        let (p0, p1, p2, p3) = (c0.as_ptr(), c1.as_ptr(), c2.as_ptr(), c3.as_ptr());
        let mut lo0 = _mm256_setzero_ps();
        let mut hi0 = _mm256_setzero_ps();
        let mut lo1 = _mm256_setzero_ps();
        let mut hi1 = _mm256_setzero_ps();
        let mut lo2 = _mm256_setzero_ps();
        let mut hi2 = _mm256_setzero_ps();
        let mut lo3 = _mm256_setzero_ps();
        let mut hi3 = _mm256_setzero_ps();
        for i in 0..chunks {
            let j = i * LANES;
            let xlo = _mm256_loadu_ps(xp.add(j));
            let xhi = _mm256_loadu_ps(xp.add(j + 8));
            lo0 = _mm256_add_ps(lo0, _mm256_mul_ps(xlo, _mm256_loadu_ps(p0.add(j))));
            hi0 = _mm256_add_ps(hi0, _mm256_mul_ps(xhi, _mm256_loadu_ps(p0.add(j + 8))));
            lo1 = _mm256_add_ps(lo1, _mm256_mul_ps(xlo, _mm256_loadu_ps(p1.add(j))));
            hi1 = _mm256_add_ps(hi1, _mm256_mul_ps(xhi, _mm256_loadu_ps(p1.add(j + 8))));
            lo2 = _mm256_add_ps(lo2, _mm256_mul_ps(xlo, _mm256_loadu_ps(p2.add(j))));
            hi2 = _mm256_add_ps(hi2, _mm256_mul_ps(xhi, _mm256_loadu_ps(p2.add(j + 8))));
            lo3 = _mm256_add_ps(lo3, _mm256_mul_ps(xlo, _mm256_loadu_ps(p3.add(j))));
            hi3 = _mm256_add_ps(hi3, _mm256_mul_ps(xhi, _mm256_loadu_ps(p3.add(j + 8))));
        }
        let (mut t0, mut t1, mut t2, mut t3) = (0.0f32, 0.0, 0.0, 0.0);
        for j in chunks * LANES..n {
            t0 += x[j] * c0[j];
            t1 += x[j] * c1[j];
            t2 += x[j] * c2[j];
            t3 += x[j] * c3[j];
        }
        (
            reduce16(lo0, hi0) + t0,
            reduce16(lo1, hi1) + t1,
            reduce16(lo2, hi2) + t2,
            reduce16(lo3, hi3) + t3,
        )
    }

    /// Fused distance panel + per-row argmin; the whole loop is compiled
    /// with AVX2 enabled so [`dot4`]/[`dot`] inline into it. Bit-identical
    /// to `distance::sq_dist_panel_argmin` (same decomposition arithmetic,
    /// same strict-`<` lowest-index tie-breaking).
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available on the running CPU.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn sq_dist_panel_argmin(
        points: &[f32],
        x_sq: &[f32],
        centroids: &[f32],
        c_sq: &[f32],
        rows: usize,
        k: usize,
        n: usize,
        labels: &mut [u32],
        mins: &mut [f32],
    ) {
        debug_assert_eq!(points.len(), rows * n);
        debug_assert_eq!(centroids.len(), k * n);
        debug_assert_eq!(labels.len(), rows);
        debug_assert_eq!(mins.len(), rows);
        debug_assert!(k > 0);
        let k4 = k / 4 * 4;
        for i in 0..rows {
            let x = &points[i * n..(i + 1) * n];
            let mut best = 0u32;
            let mut best_d = f32::INFINITY;
            let mut j = 0;
            while j < k4 {
                let c0 = &centroids[j * n..(j + 1) * n];
                let c1 = &centroids[(j + 1) * n..(j + 2) * n];
                let c2 = &centroids[(j + 2) * n..(j + 3) * n];
                let c3 = &centroids[(j + 3) * n..(j + 4) * n];
                let (p0, p1, p2, p3) = dot4(x, c0, c1, c2, c3);
                let d0 = (x_sq[i] + c_sq[j] - 2.0 * p0).max(0.0);
                let d1 = (x_sq[i] + c_sq[j + 1] - 2.0 * p1).max(0.0);
                let d2 = (x_sq[i] + c_sq[j + 2] - 2.0 * p2).max(0.0);
                let d3 = (x_sq[i] + c_sq[j + 3] - 2.0 * p3).max(0.0);
                if d0 < best_d {
                    best_d = d0;
                    best = j as u32;
                }
                if d1 < best_d {
                    best_d = d1;
                    best = (j + 1) as u32;
                }
                if d2 < best_d {
                    best_d = d2;
                    best = (j + 2) as u32;
                }
                if d3 < best_d {
                    best_d = d3;
                    best = (j + 3) as u32;
                }
                j += 4;
            }
            while j < k {
                let c = &centroids[j * n..(j + 1) * n];
                let d = (x_sq[i] + c_sq[j] - 2.0 * dot(x, c)).max(0.0);
                if d < best_d {
                    best_d = d;
                    best = j as u32;
                }
                j += 1;
            }
            labels[i] = best;
            mins[i] = best_d;
        }
    }
}

/// AVX-512 kernels. The 16 scalar lane accumulators live in **one** zmm
/// register; each main-loop iteration covers a 32-element tile as two
/// *dependent* adds into that accumulator, so lane `l` accumulates chunk
/// `2i` before chunk `2i+1` — the scalar per-lane order. An odd trailing
/// 16-element chunk gets a single add, and the sub-16 tail stays the
/// sequential scalar loop (a masked vector tail would reassociate the
/// tail sum and break bit-identicality). Reduction splits the zmm into
/// the same `lo`/`hi` ymm halves the AVX2 backend keeps in registers and
/// replays its pairwise tree; the split uses two `extractf32x4` + an
/// `insertf128` so only AVX512F is required (no DQ).
#[cfg(all(target_arch = "x86_64", bigmeans_avx512))]
pub mod avx512 {
    use core::arch::x86_64::*;

    /// Must match `distance::LANES` — the tile the reduction tree spans.
    const LANES: usize = 16;

    /// Reduce the 16 lanes of one zmm accumulator with the scalar
    /// pairwise tree: width-8 (`lo + hi`), width-4, width-2, width-1.
    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn reduce16(v: __m512) -> f32 {
        let lo = _mm512_castps512_ps256(v);
        let hi = _mm256_insertf128_ps::<1>(
            _mm256_castps128_ps256(_mm512_extractf32x4_ps::<2>(v)),
            _mm512_extractf32x4_ps::<3>(v),
        );
        let w = _mm256_add_ps(lo, hi);
        let x = _mm_add_ps(_mm256_castps256_ps128(w), _mm256_extractf128_ps::<1>(w));
        let y = _mm_add_ps(x, _mm_movehl_ps(x, x));
        _mm_cvtss_f32(_mm_add_ss(y, _mm_movehdup_ps(y)))
    }

    /// Direct squared Euclidean distance; bit-identical to
    /// `distance::sq_dist`.
    ///
    /// # Safety
    /// Caller must ensure AVX-512F is available on the running CPU.
    #[target_feature(enable = "avx512f")]
    pub unsafe fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / LANES;
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc = _mm512_setzero_ps();
        let pairs = chunks / 2;
        for i in 0..pairs {
            let j = i * 2 * LANES;
            let d0 = _mm512_sub_ps(_mm512_loadu_ps(ap.add(j)), _mm512_loadu_ps(bp.add(j)));
            let d1 = _mm512_sub_ps(
                _mm512_loadu_ps(ap.add(j + LANES)),
                _mm512_loadu_ps(bp.add(j + LANES)),
            );
            // mul + add, never fmadd — and two sequential adds into the
            // one accumulator to preserve the scalar per-lane order.
            acc = _mm512_add_ps(acc, _mm512_mul_ps(d0, d0));
            acc = _mm512_add_ps(acc, _mm512_mul_ps(d1, d1));
        }
        if chunks % 2 == 1 {
            let j = (chunks - 1) * LANES;
            let d = _mm512_sub_ps(_mm512_loadu_ps(ap.add(j)), _mm512_loadu_ps(bp.add(j)));
            acc = _mm512_add_ps(acc, _mm512_mul_ps(d, d));
        }
        let mut tail = 0.0f32;
        for j in chunks * LANES..n {
            let d = a[j] - b[j];
            tail += d * d;
        }
        reduce16(acc) + tail
    }

    /// Dot product; bit-identical to `distance::dot`.
    ///
    /// # Safety
    /// Caller must ensure AVX-512F is available on the running CPU.
    #[target_feature(enable = "avx512f")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / LANES;
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc = _mm512_setzero_ps();
        let pairs = chunks / 2;
        for i in 0..pairs {
            let j = i * 2 * LANES;
            let p0 = _mm512_mul_ps(_mm512_loadu_ps(ap.add(j)), _mm512_loadu_ps(bp.add(j)));
            let p1 = _mm512_mul_ps(
                _mm512_loadu_ps(ap.add(j + LANES)),
                _mm512_loadu_ps(bp.add(j + LANES)),
            );
            acc = _mm512_add_ps(acc, p0);
            acc = _mm512_add_ps(acc, p1);
        }
        if chunks % 2 == 1 {
            let j = (chunks - 1) * LANES;
            let p = _mm512_mul_ps(_mm512_loadu_ps(ap.add(j)), _mm512_loadu_ps(bp.add(j)));
            acc = _mm512_add_ps(acc, p);
        }
        let mut tail = 0.0f32;
        for j in chunks * LANES..n {
            tail += a[j] * b[j];
        }
        reduce16(acc) + tail
    }

    /// Four simultaneous dot products against a shared left vector;
    /// bit-identical to `distance::dot4_scalar`.
    ///
    /// # Safety
    /// Caller must ensure AVX-512F is available on the running CPU.
    #[inline]
    #[target_feature(enable = "avx512f")]
    pub unsafe fn dot4(
        x: &[f32],
        c0: &[f32],
        c1: &[f32],
        c2: &[f32],
        c3: &[f32],
    ) -> (f32, f32, f32, f32) {
        let n = x.len();
        debug_assert!(c0.len() == n && c1.len() == n && c2.len() == n && c3.len() == n);
        let chunks = n / LANES;
        let xp = x.as_ptr();
        let (p0, p1, p2, p3) = (c0.as_ptr(), c1.as_ptr(), c2.as_ptr(), c3.as_ptr());
        let mut a0 = _mm512_setzero_ps();
        let mut a1 = _mm512_setzero_ps();
        let mut a2 = _mm512_setzero_ps();
        let mut a3 = _mm512_setzero_ps();
        let pairs = chunks / 2;
        for i in 0..pairs {
            let j = i * 2 * LANES;
            let x0 = _mm512_loadu_ps(xp.add(j));
            let x1 = _mm512_loadu_ps(xp.add(j + LANES));
            a0 = _mm512_add_ps(a0, _mm512_mul_ps(x0, _mm512_loadu_ps(p0.add(j))));
            a0 = _mm512_add_ps(a0, _mm512_mul_ps(x1, _mm512_loadu_ps(p0.add(j + LANES))));
            a1 = _mm512_add_ps(a1, _mm512_mul_ps(x0, _mm512_loadu_ps(p1.add(j))));
            a1 = _mm512_add_ps(a1, _mm512_mul_ps(x1, _mm512_loadu_ps(p1.add(j + LANES))));
            a2 = _mm512_add_ps(a2, _mm512_mul_ps(x0, _mm512_loadu_ps(p2.add(j))));
            a2 = _mm512_add_ps(a2, _mm512_mul_ps(x1, _mm512_loadu_ps(p2.add(j + LANES))));
            a3 = _mm512_add_ps(a3, _mm512_mul_ps(x0, _mm512_loadu_ps(p3.add(j))));
            a3 = _mm512_add_ps(a3, _mm512_mul_ps(x1, _mm512_loadu_ps(p3.add(j + LANES))));
        }
        if chunks % 2 == 1 {
            let j = (chunks - 1) * LANES;
            let x0 = _mm512_loadu_ps(xp.add(j));
            a0 = _mm512_add_ps(a0, _mm512_mul_ps(x0, _mm512_loadu_ps(p0.add(j))));
            a1 = _mm512_add_ps(a1, _mm512_mul_ps(x0, _mm512_loadu_ps(p1.add(j))));
            a2 = _mm512_add_ps(a2, _mm512_mul_ps(x0, _mm512_loadu_ps(p2.add(j))));
            a3 = _mm512_add_ps(a3, _mm512_mul_ps(x0, _mm512_loadu_ps(p3.add(j))));
        }
        let (mut t0, mut t1, mut t2, mut t3) = (0.0f32, 0.0, 0.0, 0.0);
        for j in chunks * LANES..n {
            t0 += x[j] * c0[j];
            t1 += x[j] * c1[j];
            t2 += x[j] * c2[j];
            t3 += x[j] * c3[j];
        }
        (reduce16(a0) + t0, reduce16(a1) + t1, reduce16(a2) + t2, reduce16(a3) + t3)
    }

    /// Fused distance panel + per-row argmin; the whole loop is compiled
    /// with AVX-512F enabled so [`dot4`]/[`dot`] inline into it.
    /// Bit-identical to `distance::sq_dist_panel_argmin` (same
    /// decomposition arithmetic, same strict-`<` lowest-index
    /// tie-breaking).
    ///
    /// # Safety
    /// Caller must ensure AVX-512F is available on the running CPU.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx512f")]
    pub unsafe fn sq_dist_panel_argmin(
        points: &[f32],
        x_sq: &[f32],
        centroids: &[f32],
        c_sq: &[f32],
        rows: usize,
        k: usize,
        n: usize,
        labels: &mut [u32],
        mins: &mut [f32],
    ) {
        debug_assert_eq!(points.len(), rows * n);
        debug_assert_eq!(centroids.len(), k * n);
        debug_assert_eq!(labels.len(), rows);
        debug_assert_eq!(mins.len(), rows);
        debug_assert!(k > 0);
        let k4 = k / 4 * 4;
        for i in 0..rows {
            let x = &points[i * n..(i + 1) * n];
            let mut best = 0u32;
            let mut best_d = f32::INFINITY;
            let mut j = 0;
            while j < k4 {
                let c0 = &centroids[j * n..(j + 1) * n];
                let c1 = &centroids[(j + 1) * n..(j + 2) * n];
                let c2 = &centroids[(j + 2) * n..(j + 3) * n];
                let c3 = &centroids[(j + 3) * n..(j + 4) * n];
                let (p0, p1, p2, p3) = dot4(x, c0, c1, c2, c3);
                let d0 = (x_sq[i] + c_sq[j] - 2.0 * p0).max(0.0);
                let d1 = (x_sq[i] + c_sq[j + 1] - 2.0 * p1).max(0.0);
                let d2 = (x_sq[i] + c_sq[j + 2] - 2.0 * p2).max(0.0);
                let d3 = (x_sq[i] + c_sq[j + 3] - 2.0 * p3).max(0.0);
                if d0 < best_d {
                    best_d = d0;
                    best = j as u32;
                }
                if d1 < best_d {
                    best_d = d1;
                    best = (j + 1) as u32;
                }
                if d2 < best_d {
                    best_d = d2;
                    best = (j + 2) as u32;
                }
                if d3 < best_d {
                    best_d = d3;
                    best = (j + 3) as u32;
                }
                j += 4;
            }
            while j < k {
                let c = &centroids[j * n..(j + 1) * n];
                let d = (x_sq[i] + c_sq[j] - 2.0 * dot(x, c)).max(0.0);
                if d < best_d {
                    best_d = d;
                    best = j as u32;
                }
                j += 1;
            }
            labels[i] = best;
            mins[i] = best_d;
        }
    }
}

/// NEON kernels (aarch64 baseline — no runtime detection needed). Same
/// reduction-order contract as the AVX2 module: 16 lanes as four 4-lane
/// registers, pairwise tree, scalar tail last, no fused multiply-add.
#[cfg(target_arch = "aarch64")]
pub mod neon {
    use core::arch::aarch64::*;

    /// Must match `distance::LANES`.
    const LANES: usize = 16;

    /// Reduce 16 lanes held as four 4-lane registers (`a0` = lanes 0–3 …
    /// `a3` = lanes 12–15) with the scalar pairwise tree.
    #[inline]
    unsafe fn reduce16(a0: float32x4_t, a1: float32x4_t, a2: float32x4_t, a3: float32x4_t) -> f32 {
        // width-8: lanes l += l+8.
        let v0 = vaddq_f32(a0, a2);
        let v1 = vaddq_f32(a1, a3);
        // width-4.
        let w = vaddq_f32(v0, v1);
        // width-2.
        let x = vadd_f32(vget_low_f32(w), vget_high_f32(w));
        // width-1.
        vget_lane_f32::<0>(x) + vget_lane_f32::<1>(x)
    }

    /// Direct squared Euclidean distance; bit-identical to
    /// `distance::sq_dist`.
    ///
    /// # Safety
    /// Dereferences raw slice pointers; the slices must be equal-length
    /// (checked in debug builds).
    pub unsafe fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / LANES;
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut a0 = vdupq_n_f32(0.0);
        let mut a1 = vdupq_n_f32(0.0);
        let mut a2 = vdupq_n_f32(0.0);
        let mut a3 = vdupq_n_f32(0.0);
        for i in 0..chunks {
            let j = i * LANES;
            let d0 = vsubq_f32(vld1q_f32(ap.add(j)), vld1q_f32(bp.add(j)));
            let d1 = vsubq_f32(vld1q_f32(ap.add(j + 4)), vld1q_f32(bp.add(j + 4)));
            let d2 = vsubq_f32(vld1q_f32(ap.add(j + 8)), vld1q_f32(bp.add(j + 8)));
            let d3 = vsubq_f32(vld1q_f32(ap.add(j + 12)), vld1q_f32(bp.add(j + 12)));
            a0 = vaddq_f32(a0, vmulq_f32(d0, d0));
            a1 = vaddq_f32(a1, vmulq_f32(d1, d1));
            a2 = vaddq_f32(a2, vmulq_f32(d2, d2));
            a3 = vaddq_f32(a3, vmulq_f32(d3, d3));
        }
        let mut tail = 0.0f32;
        for j in chunks * LANES..n {
            let d = a[j] - b[j];
            tail += d * d;
        }
        reduce16(a0, a1, a2, a3) + tail
    }

    /// Dot product; bit-identical to `distance::dot`.
    ///
    /// # Safety
    /// Dereferences raw slice pointers; the slices must be equal-length
    /// (checked in debug builds).
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / LANES;
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut a0 = vdupq_n_f32(0.0);
        let mut a1 = vdupq_n_f32(0.0);
        let mut a2 = vdupq_n_f32(0.0);
        let mut a3 = vdupq_n_f32(0.0);
        for i in 0..chunks {
            let j = i * LANES;
            a0 = vaddq_f32(a0, vmulq_f32(vld1q_f32(ap.add(j)), vld1q_f32(bp.add(j))));
            a1 = vaddq_f32(a1, vmulq_f32(vld1q_f32(ap.add(j + 4)), vld1q_f32(bp.add(j + 4))));
            a2 = vaddq_f32(a2, vmulq_f32(vld1q_f32(ap.add(j + 8)), vld1q_f32(bp.add(j + 8))));
            a3 = vaddq_f32(a3, vmulq_f32(vld1q_f32(ap.add(j + 12)), vld1q_f32(bp.add(j + 12))));
        }
        let mut tail = 0.0f32;
        for j in chunks * LANES..n {
            tail += a[j] * b[j];
        }
        reduce16(a0, a1, a2, a3) + tail
    }

    /// Four simultaneous dot products against a shared left vector;
    /// bit-identical to `distance::dot4_scalar`.
    ///
    /// # Safety
    /// Dereferences raw slice pointers; all five slices must be
    /// equal-length (checked in debug builds).
    #[inline]
    pub unsafe fn dot4(
        x: &[f32],
        c0: &[f32],
        c1: &[f32],
        c2: &[f32],
        c3: &[f32],
    ) -> (f32, f32, f32, f32) {
        let n = x.len();
        debug_assert!(c0.len() == n && c1.len() == n && c2.len() == n && c3.len() == n);
        // Four outputs × four lane groups would need 16 live accumulators;
        // run the shared-x dot per centroid instead — x reloads stay in L1.
        (dot(x, c0), dot(x, c1), dot(x, c2), dot(x, c3))
    }

    /// Fused distance panel + per-row argmin; bit-identical to
    /// `distance::sq_dist_panel_argmin`.
    ///
    /// # Safety
    /// Dereferences raw slice pointers; shapes must satisfy the debug
    /// assertions.
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn sq_dist_panel_argmin(
        points: &[f32],
        x_sq: &[f32],
        centroids: &[f32],
        c_sq: &[f32],
        rows: usize,
        k: usize,
        n: usize,
        labels: &mut [u32],
        mins: &mut [f32],
    ) {
        debug_assert_eq!(points.len(), rows * n);
        debug_assert_eq!(centroids.len(), k * n);
        debug_assert_eq!(labels.len(), rows);
        debug_assert_eq!(mins.len(), rows);
        debug_assert!(k > 0);
        let k4 = k / 4 * 4;
        for i in 0..rows {
            let x = &points[i * n..(i + 1) * n];
            let mut best = 0u32;
            let mut best_d = f32::INFINITY;
            let mut j = 0;
            while j < k4 {
                let c0 = &centroids[j * n..(j + 1) * n];
                let c1 = &centroids[(j + 1) * n..(j + 2) * n];
                let c2 = &centroids[(j + 2) * n..(j + 3) * n];
                let c3 = &centroids[(j + 3) * n..(j + 4) * n];
                let (p0, p1, p2, p3) = dot4(x, c0, c1, c2, c3);
                let d0 = (x_sq[i] + c_sq[j] - 2.0 * p0).max(0.0);
                let d1 = (x_sq[i] + c_sq[j + 1] - 2.0 * p1).max(0.0);
                let d2 = (x_sq[i] + c_sq[j + 2] - 2.0 * p2).max(0.0);
                let d3 = (x_sq[i] + c_sq[j + 3] - 2.0 * p3).max(0.0);
                if d0 < best_d {
                    best_d = d0;
                    best = j as u32;
                }
                if d1 < best_d {
                    best_d = d1;
                    best = (j + 1) as u32;
                }
                if d2 < best_d {
                    best_d = d2;
                    best = (j + 2) as u32;
                }
                if d3 < best_d {
                    best_d = d3;
                    best = (j + 3) as u32;
                }
                j += 4;
            }
            while j < k {
                let c = &centroids[j * n..(j + 1) * n];
                let d = (x_sq[i] + c_sq[j] - 2.0 * dot(x, c)).max(0.0);
                if d < best_d {
                    best_d = d;
                    best = j as u32;
                }
                j += 1;
            }
            labels[i] = best;
            mins[i] = best_d;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_name_roundtrip_and_scalar_always_available() {
        for isa in
            [DistanceIsa::Scalar, DistanceIsa::Avx2, DistanceIsa::Neon, DistanceIsa::Avx512]
        {
            assert_eq!(DistanceIsa::parse(isa.name()), Some(isa));
        }
        assert_eq!(DistanceIsa::parse("auto"), None);
        assert_eq!(DistanceIsa::parse("sse9"), None);
        assert!(DistanceIsa::Scalar.available());
        assert!(detect().available());
        // The detected ISA must be settable; scalar always is.
        assert!(set_isa(detect()).is_ok());
        assert!(set_isa(DistanceIsa::Scalar).is_ok());
        assert_eq!(active_isa(), DistanceIsa::Scalar);
        assert!(set_isa(detect()).is_ok());
    }

    #[test]
    fn unavailable_isa_is_rejected_with_detected_list() {
        // At most one of these is the host arch; the other must refuse
        // with an error naming the request and the detected ISAs.
        let foreign =
            if cfg!(target_arch = "aarch64") { DistanceIsa::Avx2 } else { DistanceIsa::Neon };
        let err = set_isa(foreign).unwrap_err();
        assert!(err.contains(foreign.name()), "error must name the rejected isa: {err}");
        assert!(err.contains("detected:"), "error must list detected isas: {err}");
        assert!(err.contains("scalar"), "scalar is always detected: {err}");
    }

    #[test]
    fn detect_order_prefers_widest_available_isa() {
        let detected = detected_isas();
        // Scalar is always last; detect() is always the head.
        assert_eq!(detected.last().copied(), Some(DistanceIsa::Scalar));
        assert_eq!(detect(), detected[0]);
        // The list must follow the documented preference order:
        // avx512 > avx2 > neon > scalar.
        let order =
            [DistanceIsa::Avx512, DistanceIsa::Avx2, DistanceIsa::Neon, DistanceIsa::Scalar];
        let positions: Vec<usize> = detected
            .iter()
            .map(|isa| order.iter().position(|o| o == isa).expect("unknown isa"))
            .collect();
        assert!(positions.windows(2).all(|w| w[0] < w[1]), "detected_isas out of order");
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_kernels_bit_match_scalar() {
        use crate::kernels::distance;
        if !DistanceIsa::Avx2.available() {
            return; // nothing to compare on this host
        }
        let mut state = 0x1234_5678_9ABC_DEFu64;
        let mut next = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as f32 / (1u64 << 31) as f32) * 16.0 - 8.0
        };
        for n in [1usize, 3, 7, 8, 15, 16, 17, 31, 32, 33, 48, 100] {
            let a: Vec<f32> = (0..n).map(|_| next()).collect();
            let b: Vec<f32> = (0..n).map(|_| next()).collect();
            let c: Vec<f32> = (0..n).map(|_| next()).collect();
            let d: Vec<f32> = (0..n).map(|_| next()).collect();
            let e: Vec<f32> = (0..n).map(|_| next()).collect();
            unsafe {
                assert_eq!(
                    avx2::sq_dist(&a, &b).to_bits(),
                    distance::sq_dist_scalar(&a, &b).to_bits(),
                    "sq_dist n={n}"
                );
                assert_eq!(
                    avx2::dot(&a, &b).to_bits(),
                    distance::dot_scalar(&a, &b).to_bits(),
                    "dot n={n}"
                );
                let simd4 = avx2::dot4(&a, &b, &c, &d, &e);
                let ref4 = distance::dot4_scalar(&a, &b, &c, &d, &e);
                assert_eq!(simd4.0.to_bits(), ref4.0.to_bits(), "dot4.0 n={n}");
                assert_eq!(simd4.1.to_bits(), ref4.1.to_bits(), "dot4.1 n={n}");
                assert_eq!(simd4.2.to_bits(), ref4.2.to_bits(), "dot4.2 n={n}");
                assert_eq!(simd4.3.to_bits(), ref4.3.to_bits(), "dot4.3 n={n}");
            }
        }
    }

    #[cfg(all(target_arch = "x86_64", bigmeans_avx512))]
    #[test]
    fn avx512_kernels_bit_match_scalar() {
        use crate::kernels::distance;
        if !DistanceIsa::Avx512.available() {
            return; // nothing to compare on this host
        }
        let mut state = 0xFEED_F00D_5EED_0001u64;
        let mut next = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as f32 / (1u64 << 31) as f32) * 16.0 - 8.0
        };
        // Shapes straddle every tail regime of the 32-element tile: sub-16
        // scalar tails, one odd trailing 16-chunk (n = 48), and multiples
        // of 32.
        for n in [1usize, 3, 7, 8, 15, 16, 17, 31, 32, 33, 47, 48, 63, 64, 100] {
            let a: Vec<f32> = (0..n).map(|_| next()).collect();
            let b: Vec<f32> = (0..n).map(|_| next()).collect();
            let c: Vec<f32> = (0..n).map(|_| next()).collect();
            let d: Vec<f32> = (0..n).map(|_| next()).collect();
            let e: Vec<f32> = (0..n).map(|_| next()).collect();
            unsafe {
                assert_eq!(
                    avx512::sq_dist(&a, &b).to_bits(),
                    distance::sq_dist_scalar(&a, &b).to_bits(),
                    "sq_dist n={n}"
                );
                assert_eq!(
                    avx512::dot(&a, &b).to_bits(),
                    distance::dot_scalar(&a, &b).to_bits(),
                    "dot n={n}"
                );
                let simd4 = avx512::dot4(&a, &b, &c, &d, &e);
                let ref4 = distance::dot4_scalar(&a, &b, &c, &d, &e);
                assert_eq!(simd4.0.to_bits(), ref4.0.to_bits(), "dot4.0 n={n}");
                assert_eq!(simd4.1.to_bits(), ref4.1.to_bits(), "dot4.1 n={n}");
                assert_eq!(simd4.2.to_bits(), ref4.2.to_bits(), "dot4.2 n={n}");
                assert_eq!(simd4.3.to_bits(), ref4.3.to_bits(), "dot4.3 n={n}");
            }
        }
        // Panel argmin: a small dense panel with a masked-tail n.
        let (rows, k, n) = (9usize, 7usize, 33usize);
        let points: Vec<f32> = (0..rows * n).map(|_| next()).collect();
        let centroids: Vec<f32> = (0..k * n).map(|_| next()).collect();
        let x_sq: Vec<f32> = (0..rows)
            .map(|i| {
                let x = &points[i * n..(i + 1) * n];
                distance::dot_scalar(x, x)
            })
            .collect();
        let c_sq: Vec<f32> = (0..k)
            .map(|j| {
                let c = &centroids[j * n..(j + 1) * n];
                distance::dot_scalar(c, c)
            })
            .collect();
        let mut labels = vec![0u32; rows];
        let mut mins = vec![0f32; rows];
        let mut ref_labels = vec![0u32; rows];
        let mut ref_mins = vec![0f32; rows];
        unsafe {
            avx512::sq_dist_panel_argmin(
                &points, &x_sq, &centroids, &c_sq, rows, k, n, &mut labels, &mut mins,
            );
        }
        distance::sq_dist_panel_argmin_scalar(
            &points,
            &x_sq,
            &centroids,
            &c_sq,
            rows,
            k,
            n,
            &mut ref_labels,
            &mut ref_mins,
        );
        assert_eq!(labels, ref_labels);
        for (m, r) in mins.iter().zip(&ref_mins) {
            assert_eq!(m.to_bits(), r.to_bits());
        }
    }
}
