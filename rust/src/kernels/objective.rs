//! MSSC objective evaluation: `f(C, X) = Σᵢ minⱼ ‖xᵢ − cⱼ‖²` (eq. 1).

use crate::metrics::Counters;
use crate::util::threadpool::ThreadPool;

use super::distance::nearest;

/// Full objective over `points` for the given centroids. Counts `m·k`
/// distance evaluations.
pub fn objective(
    points: &[f32],
    centroids: &[f32],
    m: usize,
    n: usize,
    k: usize,
    counters: &mut Counters,
) -> f64 {
    assert_eq!(points.len(), m * n);
    assert_eq!(centroids.len(), k * n);
    let mut total = 0f64;
    for i in 0..m {
        let (_, d) = nearest(&points[i * n..(i + 1) * n], centroids, k, n);
        total += d as f64;
    }
    counters.add_distance_evals((m * k) as u64);
    total
}

/// Parallel objective (row-blocked). Workers borrow the inputs through the
/// pool's scoped API — no buffer cloning.
pub fn objective_parallel(
    pool: &ThreadPool,
    points: &[f32],
    centroids: &[f32],
    m: usize,
    n: usize,
    k: usize,
    counters: &mut Counters,
) -> f64 {
    if m < 4096 {
        return objective(points, centroids, m, n, k, counters);
    }
    let nworkers = pool.size();
    let block = m.div_ceil(nworkers);
    let jobs: Vec<(usize, usize)> = (0..nworkers)
        .map(|w| (w * block, ((w + 1) * block).min(m)))
        .filter(|(s, e)| s < e)
        .collect();
    let mut parts = vec![0f64; jobs.len()];
    let closures: Vec<_> = jobs
        .into_iter()
        .zip(parts.iter_mut())
        .map(|((s, e), slot)| {
            move || {
                let mut local = 0f64;
                for i in s..e {
                    let (_, d) = nearest(&points[i * n..(i + 1) * n], centroids, k, n);
                    local += d as f64;
                }
                *slot = local;
            }
        })
        .collect();
    pool.scope_run_all(closures);
    counters.add_distance_evals((m * k) as u64);
    parts.into_iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn objective_of_exact_centroids_is_zero() {
        let pts = vec![1.0f32, 2.0, 5.0, 6.0];
        let cs = pts.clone();
        let mut c = Counters::new();
        assert_eq!(objective(&pts, &cs, 2, 2, 2, &mut c), 0.0);
    }

    #[test]
    fn objective_known_value() {
        // points (0,0), (2,0); centroid (1,0) → 1 + 1 = 2
        let pts = vec![0.0f32, 0.0, 2.0, 0.0];
        let cs = vec![1.0f32, 0.0];
        let mut c = Counters::new();
        assert_eq!(objective(&pts, &cs, 2, 2, 1, &mut c), 2.0);
        assert_eq!(c.distance_evals, 2);
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::new(1);
        let (m, n, k) = (10_000, 5, 4);
        let pts: Vec<f32> = (0..m * n).map(|_| rng.f32()).collect();
        let cs: Vec<f32> = (0..k * n).map(|_| rng.f32()).collect();
        let pool = ThreadPool::new(4);
        let mut c1 = Counters::new();
        let mut c2 = Counters::new();
        let a = objective(&pts, &cs, m, n, k, &mut c1);
        let b = objective_parallel(&pool, &pts, &cs, m, n, k, &mut c2);
        assert!((a - b).abs() < 1e-6 * a);
        assert_eq!(c1.distance_evals, c2.distance_evals);
    }
}
